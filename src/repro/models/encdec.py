"""Encoder-decoder backbone (SeamlessM4T-medium). The speech frontend is stubbed:
the encoder consumes precomputed frame embeddings ("frames") projected to d_model.
Decoder = causal self-attention + cross-attention into the encoder memory.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.common import dense_init, embed_init, pshard, stack_init

Params = Dict[str, Any]


def _init_enc_block(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 4)
    return {
        "norm1": L.init_norm(ks[0], cfg.d_model, cfg.norm, dtype),
        "attn": L.init_attention(ks[1], cfg, dtype),
        "norm2": L.init_norm(ks[2], cfg.d_model, cfg.norm, dtype),
        "ffn": L.init_ffn(ks[3], cfg.d_model, cfg.d_ff, cfg.ffn, dtype),
    }


def _init_dec_block(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 6)
    return {
        "norm1": L.init_norm(ks[0], cfg.d_model, cfg.norm, dtype),
        "self_attn": L.init_attention(ks[1], cfg, dtype),
        "norm_x": L.init_norm(ks[2], cfg.d_model, cfg.norm, dtype),
        "cross_attn": L.init_attention(ks[3], cfg, dtype),
        "norm2": L.init_norm(ks[4], cfg.d_model, cfg.norm, dtype),
        "ffn": L.init_ffn(ks[5], cfg.d_model, cfg.d_ff, cfg.ffn, dtype),
    }


def init_params(key, cfg: ModelConfig, dtype=jnp.float32, window_override: int = 0) -> Params:
    ks = jax.random.split(key, 5)
    return {
        "embed": embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype),
        "frontend_proj": dense_init(ks[1], (cfg.frontend_embed_dim, cfg.d_model), dtype),
        "encoder": stack_init(lambda k: _init_enc_block(k, cfg, dtype), ks[2], cfg.encoder_layers),
        "decoder": stack_init(lambda k: _init_dec_block(k, cfg, dtype), ks[3], cfg.num_layers),
        "final_norm": L.init_norm(ks[4], cfg.d_model, cfg.norm, dtype),
    }


def encode(params: Params, cfg: ModelConfig, frames: jax.Array, *, remat: bool = True):
    x = jnp.einsum("bsf,fd->bsd", frames, params["frontend_proj"])
    x = pshard(x, "act_dmodel")
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def block(x, p):
        h = L.apply_norm(p["norm1"], x, cfg.norm)
        out, _ = L.apply_attention(p["attn"], cfg, h, positions, attn_mode="full")
        x = x + out
        h = L.apply_norm(p["norm2"], x, cfg.norm)
        return x + L.apply_ffn(p["ffn"], h, cfg.ffn), None

    body = jax.checkpoint(block) if remat else block
    x, _ = jax.lax.scan(body, x, params["encoder"])
    # feature-shard the memory: its per-decoder-layer cotangent stacks are the
    # dominant train-time buffer otherwise
    return pshard(x, "act_resid")


def _cross_kv(cfg: ModelConfig, p: Params, memory: jax.Array):
    B, S, _ = memory.shape
    kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    k = jnp.einsum("bsd,de->bse", memory, p["wk"]).reshape(B, S, kh, hd)
    v = jnp.einsum("bsd,de->bse", memory, p["wv"]).reshape(B, S, kh, hd)
    return k, v


def _dec_block(cfg, p, x, positions, memory, cache, cache_index, window_override):
    h = L.apply_norm(p["norm1"], x, cfg.norm)
    mode = "window" if window_override else "causal"
    out, new_kv = L.apply_attention(
        p["self_attn"], cfg, h, positions, attn_mode=mode, window=window_override,
        cache=None if cache is None else cache["self"], cache_index=cache_index)
    x = x + out
    h = L.apply_norm(p["norm_x"], x, cfg.norm)
    ck, cv = _cross_kv(cfg, p["cross_attn"], memory)
    out, _ = L.apply_attention(p["cross_attn"], cfg, h, positions, attn_mode="full",
                               cross_kv=(ck, cv))
    x = x + out
    h = L.apply_norm(p["norm2"], x, cfg.norm)
    x = x + L.apply_ffn(p["ffn"], h, cfg.ffn)
    return x, ({"self": new_kv} if new_kv is not None else None)


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            remat: bool = True, window_override: int = 0,
            cache: Optional[Params] = None, cache_index=None, memory=None):
    """batch: {"frames": [B,Se,Df] (unless memory given), "tokens": [B,Sd]}."""
    if memory is None:
        memory = encode(params, cfg, batch["frames"], remat=remat)
    x = L.embed_lookup(params["embed"], batch["tokens"]) * jnp.sqrt(
        jnp.asarray(cfg.d_model))
    x = pshard(x.astype(memory.dtype), "act_dmodel")
    B, Sd = batch["tokens"].shape
    base = jnp.asarray(0 if cache_index is None else cache_index)
    positions = jnp.broadcast_to(jnp.arange(Sd)[None] + base, (B, Sd))

    def block(x, xs):
        p = xs[0] if cache is not None else xs
        c = xs[1] if cache is not None else None
        x, nc = _dec_block(cfg, p, x, positions, memory, c, cache_index, window_override)
        return x, (nc if nc is not None else 0)

    body = jax.checkpoint(block) if (remat and cache is None) else block
    xs = params["decoder"] if cache is None else (params["decoder"], cache["decoder"])
    x, ys = jax.lax.scan(body, x, xs)
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = L.unembed_logits(params["embed"], x)
    new_cache = None
    if cache is not None:
        new_cache = {"decoder": ys, "memory": memory}
    return logits, jnp.zeros((), jnp.float32), new_cache


def loss_fn(params: Params, cfg: ModelConfig, batch, *, remat: bool = True,
            window_override: int = 0):
    logits, _, _ = forward(params, cfg, batch, remat=remat,
                           window_override=window_override)
    ce = L.cross_entropy(logits, batch["labels"])
    return ce, {"ce": ce, "aux": jnp.zeros(())}


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               enc_len: int = 4096, window_override: int = 0) -> Params:
    kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    kv = {
        "self": {
            "k": jnp.zeros((cfg.num_layers, batch, max_len, kh, hd), dtype),
            "v": jnp.zeros((cfg.num_layers, batch, max_len, kh, hd), dtype),
        }
    }
    return {"decoder": kv, "memory": jnp.zeros((batch, enc_len, cfg.d_model), dtype)}


def prefill(params: Params, cfg: ModelConfig, batch, cache, *, window_override: int = 0):
    logits, _, new_cache = forward(params, cfg, batch, remat=False, cache=cache,
                                   cache_index=jnp.asarray(0, jnp.int32),
                                   window_override=window_override)
    return logits, new_cache


def decode_step(params: Params, cfg: ModelConfig, tokens, cache, index, *,
                window_override: int = 0):
    logits, _, new_cache = forward(
        params, cfg, {"tokens": tokens}, remat=False, cache=cache,
        cache_index=index, memory=cache["memory"], window_override=window_override)
    return logits, new_cache
