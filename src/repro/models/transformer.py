"""Decoder-only transformer assembly covering the dense / moe / ssm / hybrid / vlm
families.

Layers are grouped into *super-blocks* — the smallest repeating pattern of block
kinds (e.g. (rglru, rglru, local-attn) for RecurrentGemma, (chunk, chunk, chunk,
global) for Llama-4's iRoPE) — and the stack is a `lax.scan` over stacked
super-block parameters, with any remainder layers unrolled as a tail. This keeps
compile time O(period) instead of O(num_layers) for the full-size dry-runs.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import ssm as S
from repro.models.common import embed_init, pshard, stack_init

Params = Dict[str, Any]


class LayerSpec(NamedTuple):
    kind: str  # attn | mla | rglru | ssd
    attn_mode: str = "causal"  # causal | window | chunk
    window: int = 0
    use_rope: bool = True
    has_moe: bool = False


def build_plan(cfg: ModelConfig, window_override: int = 0) -> Tuple[Tuple[LayerSpec, ...], int, Tuple[LayerSpec, ...]]:
    """Returns (period_specs, n_repeats, tail_specs)."""

    def attn_spec(i: int) -> LayerSpec:
        kind = "mla" if cfg.mla is not None else "attn"
        mode, win, rope = "causal", 0, True
        if cfg.sliding_window:
            mode, win = "window", cfg.sliding_window
        if cfg.chunk_attn_window:
            if (i % cfg.global_attn_every) == cfg.global_attn_every - 1:
                mode, win, rope = "causal", 0, False  # iRoPE global layer: NoPE
            else:
                mode, win = "chunk", cfg.chunk_attn_window
        if window_override and mode == "causal":
            mode, win = "window", window_override
        has_moe = cfg.moe is not None and (i % cfg.moe.every == 0)
        return LayerSpec(kind, mode, win, rope, has_moe)

    if cfg.family == "ssm":
        return (LayerSpec("ssd"),), cfg.num_layers, ()
    if cfg.rglru is not None:
        r = cfg.rglru
        period = []
        for i in range(r.pattern_period):
            if i in r.attn_positions:
                period.append(LayerSpec("attn", "window", r.local_window, True,
                                        cfg.moe is not None))
            else:
                period.append(LayerSpec("rglru", has_moe=False))
        period = tuple(period)
        n = cfg.num_layers // r.pattern_period
        tail = period[: cfg.num_layers % r.pattern_period]
        return period, n, tail
    if cfg.chunk_attn_window:
        period = tuple(attn_spec(i) for i in range(cfg.global_attn_every))
        n = cfg.num_layers // cfg.global_attn_every
        tail = period[: cfg.num_layers % cfg.global_attn_every]
        return period, n, tail
    period = (attn_spec(0),)
    return period, cfg.num_layers, ()


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_block(key, cfg: ModelConfig, spec: LayerSpec, dtype) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": L.init_norm(ks[0], cfg.d_model, cfg.norm, dtype)}
    if spec.kind == "attn":
        p["attn"] = L.init_attention(ks[1], cfg, dtype)
    elif spec.kind == "mla":
        p["attn"] = L.init_mla(ks[1], cfg, dtype)
    elif spec.kind == "rglru":
        p["attn"] = R.init_rglru(ks[1], cfg, dtype)
    else:
        p["attn"] = S.init_ssd(ks[1], cfg, dtype)
    if spec.kind != "ssd":
        p["norm2"] = L.init_norm(ks[2], cfg.d_model, cfg.norm, dtype)
        if spec.has_moe:
            p["ffn"] = M.init_moe(ks[3], cfg, dtype)
        elif cfg.d_ff:
            p["ffn"] = L.init_ffn(ks[3], cfg.d_model, cfg.d_ff, cfg.ffn, dtype)
    return p


def init_params(key, cfg: ModelConfig, dtype=jnp.float32,
                window_override: int = 0) -> Params:
    period, n, tail = build_plan(cfg, window_override)
    keys = jax.random.split(key, 4 + len(period) + len(tail))
    p: Params = {
        "embed": embed_init(keys[0], (cfg.vocab_size, cfg.d_model), dtype),
        "final_norm": L.init_norm(keys[1], cfg.d_model, cfg.norm, dtype),
        "layers": [
            stack_init(lambda k, s=spec: _init_block(k, cfg, s, dtype), keys[4 + i], n)
            for i, spec in enumerate(period)
        ],
        "tail": [
            _init_block(keys[4 + len(period) + i], cfg, spec, dtype)
            for i, spec in enumerate(tail)
        ],
    }
    if cfg.frontend_embed_dim:
        from repro.models.common import dense_init
        p["frontend_proj"] = dense_init(keys[2], (cfg.frontend_embed_dim, cfg.d_model), dtype)
    if not cfg.tie_embeddings:
        p["unembed"] = embed_init(keys[3], (cfg.vocab_size, cfg.d_model), dtype)
    return p


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------


def _apply_block(cfg: ModelConfig, spec: LayerSpec, p: Params, x, positions,
                 cache=None, cache_index=None):
    h = L.apply_norm(p["norm1"], x, cfg.norm)
    new_cache = None
    if spec.kind == "attn":
        out, new_cache = L.apply_attention(
            p["attn"], cfg, h, positions, attn_mode=spec.attn_mode,
            window=spec.window, use_rope=spec.use_rope,
            cache=cache, cache_index=cache_index)
    elif spec.kind == "mla":
        out, new_cache = L.apply_mla(
            p["attn"], cfg, h, positions, attn_mode=spec.attn_mode,
            window=spec.window, cache=cache, cache_index=cache_index)
    elif spec.kind == "rglru":
        out, new_cache = R.apply_rglru(p["attn"], cfg, h, state=cache)
    else:
        out, new_cache = S.apply_ssd(p["attn"], cfg, h, state=cache)
    x = x + out
    aux = jnp.zeros((), jnp.float32)
    if spec.kind != "ssd" and "ffn" in p:
        h2 = L.apply_norm(p["norm2"], x, cfg.norm)
        if spec.has_moe:
            out2, aux = M.apply_moe(p["ffn"], cfg, h2)
        else:
            out2 = L.apply_ffn(p["ffn"], h2, cfg.ffn)
        x = x + out2
    # shard the residual stream (and thus the remat-scan carries) over `model`
    x = pshard(x, "act_resid")
    return x, new_cache, aux


def _embed(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
    x = L.embed_lookup(params["embed"], batch["tokens"])
    x = x * jnp.sqrt(jnp.asarray(cfg.d_model, x.dtype))
    if cfg.frontend_embed_dim and "patches" in batch:
        # early fusion: precomputed modality embeddings occupy a prefix of the
        # sequence (frontend itself is stubbed per the brief)
        pe = jnp.einsum("bnf,fd->bnd", batch["patches"].astype(x.dtype),
                        params["frontend_proj"])
        n = pe.shape[1]
        x = jnp.concatenate([pe, x[:, n:]], axis=1)
    return pshard(x, "act_dmodel")


def _unembed(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    return L.unembed_logits(params.get("unembed", params["embed"]), x)


# ---------------------------------------------------------------------------
# Forward / loss (train + prefill), decode
# ---------------------------------------------------------------------------


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            window_override: int = 0, remat: bool = True,
            cache: Optional[Params] = None, cache_index=None):
    """Returns (logits, aux_loss, new_cache)."""
    period, n_rep, tail = build_plan(cfg, window_override)
    x = _embed(cfg, params, batch)
    B, Sq = batch["tokens"].shape
    base = jnp.asarray(0 if cache_index is None else cache_index)
    if base.ndim == 1:  # per-slot decode: row b starts at its own position
        base = base[:, None]
    positions = jnp.broadcast_to(jnp.arange(Sq)[None] + base, (B, Sq))

    def superblock(carry, xs):
        x, aux = carry
        lp = xs[0]
        cs = xs[1] if cache is not None else [None] * len(period)
        new_cs = []
        for pos, spec in enumerate(period):
            # per-layer checkpoint nested inside the superblock checkpoint:
            # the superblock backward replays one layer at a time instead of
            # keeping all `period` layers' intermediates live
            blk = partial(_apply_block, cfg, spec)
            if remat and cache is None and len(period) > 1:
                blk = jax.checkpoint(blk)
            x, nc, a = blk(lp[pos], x, positions, cache=cs[pos],
                           cache_index=cache_index)
            new_cs.append(nc if nc is not None else 0)
            aux = aux + a
        return (x, aux), (tuple(new_cs) if cache is not None else 0)

    body = jax.checkpoint(superblock) if (remat and cache is None) else superblock
    xs = (params["layers"],) if cache is None else (params["layers"], cache["layers"])
    (x, aux), ys = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)

    new_cache = None
    if cache is not None:
        new_cache = {"layers": list(ys), "tail": []}
    for i, spec in enumerate(tail):
        tc = cache["tail"][i] if cache is not None else None
        x, nc, a = _apply_block(cfg, spec, params["tail"][i], x, positions,
                                cache=tc, cache_index=cache_index)
        aux = aux + a
        if cache is not None:
            new_cache["tail"].append(nc)

    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = _unembed(cfg, params, x)
    return logits, aux, new_cache


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            remat: bool = True, window_override: int = 0):
    logits, aux, _ = forward(params, cfg, batch, remat=remat,
                             window_override=window_override)
    ce = L.cross_entropy(logits, batch["labels"])
    aux_w = cfg.moe.router_aux_loss_weight if cfg.moe is not None else 0.0
    n_layers = max(cfg.num_layers, 1)
    loss = ce + aux_w * aux / n_layers
    return loss, {"ce": ce, "aux": aux / n_layers}


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------


def _init_block_cache(cfg: ModelConfig, spec: LayerSpec, batch: int, max_len: int, dtype):
    if spec.kind == "attn":
        kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        # ring-buffer option: windowed layers never look back more than W, so a
        # W-slot ring suffices (perf iteration, EXPERIMENTS.md §Perf); baseline
        # allocates the full seq_len
        eff = max_len
        if cfg.ring_buffer_cache and spec.attn_mode == "window" and spec.window:
            eff = min(max_len, spec.window)
        return {
            "k": jnp.zeros((batch, eff, kh, hd), dtype),
            "v": jnp.zeros((batch, eff, kh, hd), dtype),
        }
    if spec.kind == "mla":
        m = cfg.mla
        return {
            "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, max_len, 1, m.qk_rope_head_dim), dtype),
        }
    if spec.kind == "rglru":
        return R.init_rglru_state(cfg, batch, dtype)
    return S.init_ssd_state(cfg, batch, dtype)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               window_override: int = 0) -> Params:
    period, n, tail = build_plan(cfg, window_override)

    def stacked(spec):
        c = _init_block_cache(cfg, spec, batch, max_len, dtype)
        return jax.tree.map(lambda v: jnp.broadcast_to(v[None], (n, *v.shape)), c)

    return {
        "layers": [stacked(s) for s in period],
        "tail": [_init_block_cache(cfg, s, batch, max_len, dtype) for s in tail],
    }


def prefill(params: Params, cfg: ModelConfig, batch, cache, *, window_override: int = 0):
    logits, _, new_cache = forward(params, cfg, batch, remat=False, cache=cache,
                                   cache_index=jnp.asarray(0, jnp.int32),
                                   window_override=window_override)
    return logits, new_cache


def decode_step(params: Params, cfg: ModelConfig, tokens, cache, index, *,
                window_override: int = 0):
    """tokens: [B, 1]; index: scalar int32 (current length) or [B] int32
    vector (per-slot lengths, continuous batching). Returns (logits, cache)."""
    logits, _, new_cache = forward(params, cfg, {"tokens": tokens}, remat=False,
                                   cache=cache, cache_index=index,
                                   window_override=window_override)
    return logits, new_cache
