"""Mamba-2 SSD (state-space duality) block. [arXiv:2405.21060]

Training uses the chunked dual form (quadratic within chunk_size-length chunks,
linear across chunks via a state recurrence scanned with lax.scan). Decoding uses
the O(1) recurrent update on a persistent state, which is what makes long_500k
native for this family.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.common import dense_init, pshard

Params = Dict[str, Any]


def _dims(cfg: ModelConfig):
    s: SSMConfig = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    return s, d_inner, nheads


def init_ssd(key, cfg: ModelConfig, dtype) -> Params:
    s, d_inner, nheads = _dims(cfg)
    d = cfg.d_model
    conv_dim = d_inner + 2 * s.ngroups * s.state_dim
    ks = jax.random.split(key, 6)
    return {
        # fused input projection: [z (gate), x, B, C, dt]
        "w_in": dense_init(ks[0], (d, 2 * d_inner + 2 * s.ngroups * s.state_dim + nheads), dtype),
        "conv_w": dense_init(ks[1], (s.conv_width, conv_dim), dtype, fan_in=s.conv_width),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((nheads,), 0.01))).astype(jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "w_out": dense_init(ks[2], (d_inner, d), dtype, fan_in=d_inner),
    }


def _segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j < k <= i} x[..., k] (−inf for j > i)."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """SSD dual form.

    x: [b, S, H, P]; dt: [b, S, H]; A: [H] (positive; decay = exp(-dt*A));
    Bm, Cm: [b, S, G, N]. Returns (y [b,S,H,P], final_state [b,H,P,N]).
    """
    b, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    nchunks = S // chunk
    rep = H // G

    xs = x.reshape(b, nchunks, chunk, H, P)
    dts = dt.reshape(b, nchunks, chunk, H)
    Bs = Bm.reshape(b, nchunks, chunk, G, N)
    Cs = Cm.reshape(b, nchunks, chunk, G, N)

    dA = -dts * A  # [b, c, q, H] log-decay per step (negative)

    # intra-chunk (diagonal blocks): y = (C B^T ∘ L) x, L from segsum of dA
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))  # [b, c, H, q, q]
    L = pshard(L, "act_ssm_l")
    CB = jnp.einsum("bcqgn,bckgn->bcgqk", Cs, Bs)  # [b,c,G,q,k]
    CB = jnp.repeat(CB, rep, axis=2)  # [b,c,H,q,k]
    scores = CB * L * dts.transpose(0, 1, 3, 2)[:, :, :, None, :]  # weight by dt_k
    scores = pshard(scores, "act_ssm_l")
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", scores, xs)
    y_diag = pshard(y_diag, "act_ssm_y")

    # chunk-final states: sum_k exp(sum_{j>k} dA_j) * dt_k * B_k x_k
    dA_cum = jnp.cumsum(dA, axis=2)  # [b,c,q,H]
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)  # [b,c,q,H]
    Brep = jnp.repeat(Bs, rep, axis=3)  # [b,c,q,H,N]
    states = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn",
                        decay_to_end * dts, Brep, xs)  # [b,c,H,P,N]
    states = pshard(states, "act_ssm_state")

    # inter-chunk recurrence over chunk index
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])  # [b,c,H]

    def scan_fn(h, inp):
        st, dec = inp  # st: [b,H,P,N], dec: [b,H]
        h_new = h * dec[:, :, None, None] + st
        return h_new, h  # emit state *entering* the chunk

    h0 = jnp.zeros((b, H, P, N), jnp.float32) if init_state is None else init_state
    final, h_in = jax.lax.scan(scan_fn, h0,
                               (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
                                chunk_decay.transpose(1, 0, 2)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)  # [b,c,H,P,N]

    # contribution of the incoming state to each position
    state_decay = jnp.exp(dA_cum)  # decay from chunk start to q inclusive
    Crep = jnp.repeat(Cs, rep, axis=3)  # [b,c,q,H,N]
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", Crep, h_in.astype(x.dtype), state_decay)
    y_off = pshard(y_off, "act_ssm_y")

    y = (y_diag + y_off).reshape(b, S, H, P)
    return y, final


def apply_ssd(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,  # [B, S, D]
    *,
    state: Optional[Params] = None,  # {"h": [B,H,P,N], "conv": [B,W-1,convdim]}
) -> Tuple[jax.Array, Optional[Params]]:
    s, d_inner, nheads = _dims(cfg)
    B, S, D = x.shape
    G, N, P = s.ngroups, s.state_dim, s.head_dim
    conv_dim = d_inner + 2 * G * N

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, d_inner + conv_dim], axis=-1)
    xbc = pshard(xbc, "act_ff")

    # causal depthwise conv over time
    W = s.conv_width
    new_state = None
    if state is None:
        pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([state["conv"].astype(xbc.dtype), xbc], axis=1)
        conv_tail = pad[:, -(W - 1):, :]
    conv = sum(pad[:, i: i + S, :] * p["conv_w"][i] for i in range(W)) + p["conv_b"]
    xbc = jax.nn.silu(conv)

    xi, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + G * N], axis=-1)
    xi = xi.reshape(B, S, nheads, P)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)
    A = jnp.exp(p["A_log"])  # [H] positive
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]

    if S == 1 and state is not None:
        # O(1) recurrent decode step
        h = state["h"]  # [B,H,P,N] fp32
        dec = jnp.exp(-dt[:, 0] * A)  # [B,H]
        Brep = jnp.repeat(Bm[:, 0], nheads // G, axis=1)  # [B,H,N]
        inj = jnp.einsum("bh,bhn,bhp->bhpn", dt[:, 0], Brep.astype(jnp.float32),
                         xi[:, 0].astype(jnp.float32))
        h = h * dec[:, :, None, None] + inj
        Crep = jnp.repeat(Cm[:, 0], nheads // G, axis=1)
        y = jnp.einsum("bhn,bhpn->bhp", Crep.astype(jnp.float32), h)[:, None]  # [B,1,H,P]
        new_state = {"h": h, "conv": conv_tail}
    else:
        chunk = min(s.chunk_size, S)
        Spad = ((S + chunk - 1) // chunk) * chunk
        if Spad != S:
            padlen = Spad - S
            xi = jnp.pad(xi, ((0, 0), (0, padlen), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, padlen), (0, 0)))
            Bm = jnp.pad(Bm, ((0, 0), (0, padlen), (0, 0), (0, 0)))
            Cm = jnp.pad(Cm, ((0, 0), (0, padlen), (0, 0), (0, 0)))
        y, h_final = ssd_chunked(xi, dt, A, Bm, Cm, chunk)
        y = y[:, :S]
        if state is not None:
            new_state = {"h": h_final, "conv": conv_tail}

    y = y + xi[:, :S].astype(y.dtype) * p["D"][:, None]
    y = y.reshape(B, S, d_inner).astype(x.dtype)

    # gated RMSNorm (Mamba-2 norm-before-out)
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    yf = yf * jax.lax.rsqrt(jnp.mean(yf**2, -1, keepdims=True) + 1e-6)
    y = (yf * p["norm_scale"].astype(jnp.float32)).astype(x.dtype)

    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return pshard(out, "act_dmodel"), new_state


def init_ssd_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    s, d_inner, nheads = _dims(cfg)
    conv_dim = d_inner + 2 * s.ngroups * s.state_dim
    return {
        "h": jnp.zeros((batch, nheads, s.head_dim, s.state_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
    }
