"""Core neural layers: norms, RoPE, blockwise (memory-efficient) attention with
causal / sliding-window / chunked-local masking, GQA and MLA attention blocks with
KV caches, and gated FFNs.

Attention is written in the blockwise online-softmax form so that the full-size
dry-runs never materialize an S x S score matrix; the Pallas flash kernel in
``repro.kernels`` implements the same contract for TPUs and is validated against
``repro.kernels.ref`` which mirrors this math.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.models.common import dense_init, ones_init, pshard

Params = Dict[str, Any]

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(key, dim: int, kind: str, dtype=jnp.float32) -> Params:
    p = {"scale": ones_init(key, (dim,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((dim,), dtype)
    return p


def apply_norm(p: Params, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_headdim(x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Parameter-free QK-norm over the head dim (Chameleon / Llama-4 style)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)  # [head_dim/2]


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # [d/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, d/2]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, d/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Masked blockwise attention
# ---------------------------------------------------------------------------


def _mask_block(q_pos: jax.Array, k_pos: jax.Array, *, causal: bool, window: int,
                chunk: int, kv_valid: Optional[jax.Array]) -> jax.Array:
    """Boolean [q, k] mask from absolute positions. window/chunk of 0 disable."""
    qp = q_pos[:, None]
    kp = k_pos[None, :]
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= kp <= qp
    if window > 0:
        m &= kp > qp - window
    if chunk > 0:
        m &= (kp // chunk) == (qp // chunk)
    if kv_valid is not None:
        m &= kp < kv_valid
    return m


def blockwise_attention(
    q: jax.Array,  # [B, Sq, H, D]
    k: jax.Array,  # [B, Sk, KH, D]
    v: jax.Array,  # [B, Sk, KH, Dv]
    *,
    causal: bool = True,
    window: int = 0,
    chunk: int = 0,
    q_offset: Any = 0,
    kv_valid: Optional[jax.Array] = None,  # scalar or [B]: #valid cache slots
    kv_block: int = 512,
    softmax_scale: Optional[float] = None,
) -> jax.Array:
    """Online-softmax attention; never materializes [Sq, Sk] for Sk > kv_block.

    GQA kv heads are broadcast to the full H before the score einsum so every
    blockwise intermediate carries a head axis that shards evenly over the
    `model` mesh axis (all assigned archs have H >= 16).
    `q_offset` is the absolute position of q[0] (int or [B] array, for decode).
    """
    B, Sq, H, D = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // KH
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)
    q_pos_base = jnp.arange(Sq)

    if G > 1:  # broadcast kv to full heads: [B, Sk, H, *]
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)

    if Sq <= 16:
        # decode fast path: one masked dot over the whole cache — no block
        # scan (whose reshape-to-blocks would regather sharded caches).
        # bf16 inputs + f32 accumulation: no materialized f32 cache copy.
        s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(k.dtype), k,
                       preferred_element_type=jnp.float32) * scale
        s = pshard(s, "act_scores")
        k_pos = jnp.arange(Sk)
        qoff = jnp.asarray(q_offset)
        kvv = jnp.broadcast_to(jnp.asarray(Sk if kv_valid is None else kv_valid), (B,))

        def mk_mask(qo, kv_n):
            return _mask_block(q_pos_base + qo, k_pos, causal=causal,
                               window=window, chunk=chunk, kv_valid=kv_n)

        if qoff.ndim == 0:
            mask = mk_mask(qoff, None)[None] & (k_pos[None, None] < kvv[:, None, None])
        else:
            mask = jax.vmap(mk_mask)(qoff, kvv)
        s = jnp.where(mask[:, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        return out.reshape(B, Sq, H, Dv).astype(v.dtype)

    nblocks = max(1, (Sk + kv_block - 1) // kv_block)
    pad = nblocks * kv_block - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kv_valid_eff = jnp.asarray(Sk if kv_valid is None else kv_valid)

    kb = k.reshape(B, nblocks, kv_block, H, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblocks, kv_block, H, Dv).transpose(1, 0, 2, 3, 4)

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        blk_idx, kblk, vblk = xs
        # scores: [B, H, Sq, kv_block], head axis sharded over `model`
        # (bf16 inputs, f32 accumulation — the MXU-native formulation)
        s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(kblk.dtype), kblk,
                       preferred_element_type=jnp.float32) * scale
        s = pshard(s, "act_scores")
        k_pos = blk_idx * kv_block + jnp.arange(kv_block)

        def mk_mask(qoff, kvv):
            return _mask_block(q_pos_base + qoff, k_pos, causal=causal, window=window,
                               chunk=chunk, kv_valid=kvv)

        qoff = jnp.asarray(q_offset)
        kvv = jnp.broadcast_to(kv_valid_eff, (B,)) if kv_valid_eff.ndim <= 1 else kv_valid_eff
        if qoff.ndim == 0:
            mask = mk_mask(qoff, None)[None]  # [1, Sq, kv_block]
            mask = mask & (k_pos[None, None, :] < kvv[:, None, None])
        else:
            mask = jax.vmap(mk_mask)(qoff, kvv)  # [B, Sq, kv_block]
        s = jnp.where(mask[:, None], s, NEG_INF)
        m_blk = jnp.max(s, axis=-1)  # [B, H, Sq]
        m_new = jnp.maximum(m_prev, m_blk)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)  # zero out fully-masked rows later via l
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(vblk.dtype), vblk,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    acc0 = jnp.zeros((B, H, Sq, Dv), jnp.float32)
    if nblocks == 1:
        (m, l, acc), _ = body((m0, l0, acc0), (jnp.asarray(0), kb[0], vb[0]))
    else:
        # checkpoint each kv-block step: backward recomputes the block's
        # probabilities instead of saving O(Sq x Sk) residuals (flash-style)
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), (m0, l0, acc0),
                                      (jnp.arange(nblocks), kb, vb))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(0, 2, 1, 3).reshape(B, Sq, H, Dv)
    return out.astype(v.dtype)


# ---------------------------------------------------------------------------
# GQA attention block (with optional KV cache)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, dtype) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KH = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, H * hd), dtype),
        "wk": dense_init(ks[1], (d, KH * hd), dtype),
        "wv": dense_init(ks[2], (d, KH * hd), dtype),
        "wo": dense_init(ks[3], (H * hd, d), dtype, fan_in=H * hd),
    }


def apply_attention(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,  # [B, S, D]
    positions: jax.Array,  # [B, S] absolute positions
    *,
    attn_mode: str = "causal",  # causal | window | chunk | full (encoder)
    window: int = 0,
    use_rope: bool = True,
    cache: Optional[Params] = None,  # {"k","v"} [B, S_max, KH, hd]
    cache_index: Optional[jax.Array] = None,  # scalar int or [B] vector (per-slot
    # decode, continuous batching): write offset per batch row; vector form
    # requires S == 1
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Params]]:
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    H, KH = cfg.num_heads, cfg.num_kv_heads

    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(B, S, H, hd)
    if cross_kv is None:
        k = jnp.einsum("bsd,de->bse", x, p["wk"]).reshape(B, S, KH, hd)
        v = jnp.einsum("bsd,de->bse", x, p["wv"]).reshape(B, S, KH, hd)
    else:
        k, v = cross_kv

    if cfg.use_qk_norm:
        q, k = rms_norm_headdim(q), (rms_norm_headdim(k) if cross_kv is None else k)
    if use_rope and cross_kv is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = pshard(q, "act_heads")

    causal = attn_mode in ("causal", "window", "chunk")
    eff_window = window if attn_mode == "window" else 0
    eff_chunk = window if attn_mode == "chunk" else 0

    new_cache = None
    ring = (cache is not None and cross_kv is None and cfg.ring_buffer_cache
            and attn_mode == "window" and window
            and cache["k"].shape[1] <= window)
    if ring:
        # W-slot ring buffer: slot(p) = p % W. RoPE is applied before the
        # write, so slots need no absolute positions; validity is purely a
        # count. Prefill assumes cache_index == 0.
        W = cache["k"].shape[1]
        if S == 1:
            slot = cache_index % W
            if getattr(cache_index, "ndim", 0) == 1:
                rows = jnp.arange(B)
                ck = cache["k"].at[rows, slot].set(k[:, 0].astype(cache["k"].dtype))
                cv = cache["v"].at[rows, slot].set(v[:, 0].astype(cache["v"].dtype))
            else:
                ck = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
                cv = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
            new_cache = {"k": ck, "v": cv}
            kvv = jnp.minimum(cache_index + 1, W)
            out = blockwise_attention(q, ck, cv, causal=False, kv_valid=kvv)
        else:
            out = blockwise_attention(q, k, v, causal=True, window=window)
            if S >= W:
                shift = (S - W) % W
                ck = jnp.roll(k[:, -W:], shift, axis=1).astype(cache["k"].dtype)
                cv = jnp.roll(v[:, -W:], shift, axis=1).astype(cache["v"].dtype)
            else:
                ck = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
                cv = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
            new_cache = {"k": ck, "v": cv}
        out = jnp.einsum("bse,ed->bsd", out.reshape(B, S, H * hd), p["wo"])
        return pshard(out, "act_dmodel"), new_cache
    if cache is not None and cross_kv is None:
        # align the freshly-computed K/V with the cache's layout BEFORE the
        # update-slice, or SPMD stacks unsharded per-layer copies (decode's
        # single-position slice stays unconstrained)
        if S > 1:
            k = pshard(k, "act_kv")
            v = pshard(v, "act_kv")
        if getattr(cache_index, "ndim", 0) == 1:
            # per-slot decode: row b writes its own position cache_index[b]
            # (scatter instead of dynamic_update_slice); S must be 1
            rows = jnp.arange(B)
            ck = cache["k"].at[rows, cache_index].set(k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[rows, cache_index].set(v[:, 0].astype(cache["v"].dtype))
        else:
            ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                              (0, cache_index, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                              (0, cache_index, 0, 0))
        new_cache = {"k": pshard(ck, "act_cache_kv"), "v": pshard(cv, "act_cache_kv")}
        k, v = ck, cv
        kv_valid = cache_index + S
        q_offset = cache_index + jnp.asarray(0)
        out = blockwise_attention(q, k, v, causal=causal, window=eff_window,
                                  chunk=eff_chunk, q_offset=q_offset, kv_valid=kv_valid)
    else:
        out = blockwise_attention(q, k, v, causal=causal and cross_kv is None,
                                  window=eff_window, chunk=eff_chunk)

    out = jnp.einsum("bse,ed->bsd", out.reshape(B, S, H * hd), p["wo"])
    return pshard(out, "act_dmodel"), new_cache


# ---------------------------------------------------------------------------
# MLA (Multi-head Latent Attention) block
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig, dtype) -> Params:
    m: MLAConfig = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], (d, m.q_lora_rank), dtype),
        "wq_b": dense_init(ks[1], (m.q_lora_rank, H * qk), dtype),
        "wkv_a": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), dtype),
        "wkv_b": dense_init(ks[3], (m.kv_lora_rank, H * (m.qk_nope_head_dim + m.v_head_dim)), dtype),
        "wo": dense_init(ks[4], (H * m.v_head_dim, d), dtype, fan_in=H * m.v_head_dim),
        "norm_kv": ones_init(ks[5], (m.kv_lora_rank,), dtype),
    }


def apply_mla(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    attn_mode: str = "causal",
    window: int = 0,
    cache: Optional[Params] = None,  # {"ckv": [B,S,rank], "krope": [B,S,1,rope]}
    cache_index: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Params]]:
    m: MLAConfig = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    qk_n, qk_r, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    q = jnp.einsum("bsd,dr->bsr", x, p["wq_a"])
    q = jnp.einsum("bsr,re->bse", q, p["wq_b"]).reshape(B, S, H, qk_n + qk_r)
    q_nope, q_rope = q[..., :qk_n], q[..., qk_n:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    ckv, k_rope = kv[..., : m.kv_lora_rank], kv[..., m.kv_lora_rank:]
    # rms-norm the latent (DeepSeek-V2 style)
    ckvf = ckv.astype(jnp.float32)
    ckv = (ckvf * jax.lax.rsqrt(jnp.mean(ckvf**2, -1, keepdims=True) + 1e-6)).astype(x.dtype)
    ckv = ckv * p["norm_kv"]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # [B,S,1,qk_r]

    new_cache = None
    kv_valid = None
    q_offset = 0
    if cache is not None:
        if getattr(cache_index, "ndim", 0) == 1:
            # per-slot decode (S == 1): scatter each row at its own position
            rows = jnp.arange(B)
            c1 = cache["ckv"].at[rows, cache_index].set(
                ckv[:, 0].astype(cache["ckv"].dtype))
            c2 = cache["krope"].at[rows, cache_index].set(
                k_rope[:, 0].astype(cache["krope"].dtype))
        else:
            c1 = jax.lax.dynamic_update_slice(cache["ckv"], ckv.astype(cache["ckv"].dtype),
                                              (0, cache_index, 0))
            c2 = jax.lax.dynamic_update_slice(cache["krope"], k_rope.astype(cache["krope"].dtype),
                                              (0, cache_index, 0, 0))
        new_cache = {"ckv": c1, "krope": c2}
        ckv, k_rope = c1, c2
        kv_valid = cache_index + S
        q_offset = cache_index + jnp.asarray(0)

    kv_up = jnp.einsum("bsr,re->bse", ckv, p["wkv_b"]).reshape(
        ckv.shape[0], ckv.shape[1], H, qk_n + dv)
    k_nope, v = kv_up[..., :qk_n], kv_up[..., qk_n:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, (*k_rope.shape[:2], H, qk_r)).astype(k_nope.dtype)], axis=-1)
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)

    eff_window = window if attn_mode == "window" else 0
    out = blockwise_attention(qfull, k, v, causal=True, window=eff_window,
                              q_offset=q_offset, kv_valid=kv_valid,
                              softmax_scale=1.0 / math.sqrt(qk_n + qk_r))
    out = jnp.einsum("bse,ed->bsd", out.reshape(B, S, H * dv), p["wo"])
    return pshard(out, "act_dmodel"), new_cache


# ---------------------------------------------------------------------------
# Vocab projection + loss (sharding-aware)
# ---------------------------------------------------------------------------


def embed_lookup(emb: jax.Array, tokens: jax.Array) -> jax.Array:
    """Token embedding gather. Tables whose vocab doesn't divide the model axis
    are stored d-sharded; SPMD mishandles row-gathers from those, so replicate
    them for the lookup (small: <0.5 GiB for every assigned arch)."""
    from repro.models.common import current_mesh
    mesh = current_mesh()
    if mesh is not None and emb.shape[0] % mesh.shape["model"]:
        emb = pshard(emb, "emb_replicated")
    return emb[tokens]


def unembed_logits(emb: jax.Array, x: jax.Array) -> jax.Array:
    """logits = x @ emb^T with the vocab dim padded to a multiple of 16 so it
    shards over the `model` axis even for non-divisible vocabularies (e.g.
    50280); padded entries are masked to NEG_INF so downstream softmax/CE are
    exact."""
    V = emb.shape[0]
    Vp = ((V + 15) // 16) * 16
    if Vp != V:
        emb = jnp.pad(emb, ((0, Vp - V), (0, 0)))
    # make sure the (padded) table is vocab-sharded here even when the stored
    # param had to fall back to d_model sharding (non-divisible vocab)
    emb = pshard(emb, "emb_vocab")
    logits = jnp.einsum("bsd,vd->bsv", x, emb)
    logits = pshard(logits, "act_vocab")
    if Vp != V:
        vpos = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(vpos < V, logits, jnp.asarray(NEG_INF, logits.dtype))
    return logits


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean CE over positions with label >= 0. Gold scores via a one-hot
    contraction (keeps the sharded vocab dim sharded; take_along_axis would
    all-gather it)."""
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    onehot = jax.nn.one_hot(jnp.maximum(labels, 0), logits.shape[-1],
                            dtype=jnp.float32)
    onehot = pshard(onehot, "act_vocab")
    gold = jnp.einsum("bsv,bsv->bs", lf, onehot)
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------


def init_ffn(key, d_model: int, d_ff: int, kind: str, dtype) -> Params:
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], (d_model, d_ff), dtype),
            "w_up": dense_init(ks[1], (d_model, d_ff), dtype),
            "w_down": dense_init(ks[2], (d_ff, d_model), dtype, fan_in=d_ff),
        }
    return {
        "w_up": dense_init(ks[0], (d_model, d_ff), dtype),
        "w_down": dense_init(ks[1], (d_ff, d_model), dtype, fan_in=d_ff),
    }


def apply_ffn(p: Params, x: jax.Array, kind: str) -> jax.Array:
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("bsd,df->bsf", x, p["w_gate"])) * jnp.einsum(
            "bsd,df->bsf", x, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_up"]))
    h = pshard(h, "act_ff")
    return pshard(jnp.einsum("bsf,fd->bsd", h, p["w_down"]), "act_dmodel")
