"""RG-LRU recurrent block (RecurrentGemma / Griffin). [arXiv:2402.19427]

Training uses `lax.associative_scan` over the linear recurrence
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
decode is a single O(1) state update. The block is the Griffin "recurrent block":
two input branches (gate, main), a short causal depthwise conv, the RG-LRU, and an
output projection.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RGLRUConfig
from repro.models.common import dense_init, pshard

Params = Dict[str, Any]

_C = 8.0  # Griffin's fixed exponent scale


def init_rglru(key, cfg: ModelConfig, dtype) -> Params:
    r: RGLRUConfig = cfg.rglru
    d = cfg.d_model
    w = r.lru_width or d
    ks = jax.random.split(key, 7)
    return {
        "w_gate_in": dense_init(ks[0], (d, w), dtype),
        "w_main_in": dense_init(ks[1], (d, w), dtype),
        "conv_w": dense_init(ks[2], (r.conv_width, w), dtype, fan_in=r.conv_width),
        "conv_b": jnp.zeros((w,), dtype),
        "w_rec_gate": dense_init(ks[3], (w, w), dtype),
        "w_inp_gate": dense_init(ks[4], (w, w), dtype),
        # Lambda param: a = sigmoid(lam); init so a^c in [0.9, 0.999]
        "lam": jnp.log(jnp.linspace(0.9, 0.999, w) ** (1 / _C)
                       / (1 - jnp.linspace(0.9, 0.999, w) ** (1 / _C))).astype(jnp.float32),
        "w_out": dense_init(ks[5], (w, d), dtype, fan_in=w),
    }


def _rglru_scan(x: jax.Array, rec_gate: jax.Array, inp_gate: jax.Array,
                lam: jax.Array, h0: Optional[jax.Array]):
    """x, gates: [B, S, W] fp32. Returns (y [B,S,W], h_final [B,W])."""
    log_a0 = jax.nn.log_sigmoid(lam)  # [W] log of base decay
    log_a = _C * rec_gate * log_a0  # [B,S,W], rec_gate in (0,1)
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed stably
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    u = beta * (inp_gate * x)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    if h0 is not None:
        # fold initial state in as a virtual first step
        u = jnp.concatenate([h0[:, None, :], u], axis=1)
        a = jnp.concatenate([jnp.ones_like(h0)[:, None, :], a], axis=1)
    _, y = jax.lax.associative_scan(combine, (a, u), axis=1)
    if h0 is not None:
        y = y[:, 1:]
    return y, y[:, -1]


def apply_rglru(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,  # [B, S, D]
    *,
    state: Optional[Params] = None,  # {"h": [B,W] fp32, "conv": [B,W-1,w]}
) -> Tuple[jax.Array, Optional[Params]]:
    r: RGLRUConfig = cfg.rglru
    B, S, D = x.shape
    W = r.conv_width
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate_in"]))
    main = jnp.einsum("bsd,dw->bsw", x, p["w_main_in"])
    main = pshard(main, "act_ff")

    # causal depthwise conv on the main branch
    new_state = None
    if state is None:
        pad = jnp.pad(main, ((0, 0), (W - 1, 0), (0, 0)))
        conv_tail = None
    else:
        pad = jnp.concatenate([state["conv"].astype(main.dtype), main], axis=1)
        conv_tail = pad[:, -(W - 1):, :]
    main = sum(pad[:, i: i + S, :] * p["conv_w"][i] for i in range(W)) + p["conv_b"]

    mf = main.astype(jnp.float32)
    rec_gate = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", mf, p["w_rec_gate"].astype(jnp.float32)))
    inp_gate = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", mf, p["w_inp_gate"].astype(jnp.float32)))

    if S == 1 and state is not None:
        log_a = _C * rec_gate[:, 0] * jax.nn.log_sigmoid(p["lam"])
        a = jnp.exp(log_a)
        beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
        h = a * state["h"] + beta * (inp_gate[:, 0] * mf[:, 0])
        y = h[:, None, :]
        new_state = {"h": h, "conv": conv_tail}
    else:
        h0 = state["h"] if state is not None else None
        y, h_final = _rglru_scan(mf, rec_gate, inp_gate, p["lam"], h0)
        if state is not None:
            new_state = {"h": h_final, "conv": conv_tail}

    out = (y.astype(x.dtype) * gate)
    out = jnp.einsum("bsw,wd->bsd", out, p["w_out"])
    return pshard(out, "act_dmodel"), new_state


def init_rglru_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    r: RGLRUConfig = cfg.rglru
    w = r.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, r.conv_width - 1, w), dtype),
    }
