"""Mixture-of-Experts layer: top-k routing with capacity-based dispatch, shared
experts, and the load-balance auxiliary loss. Experts are sharded over the `model`
mesh axis (expert parallelism); dispatch/combine are einsums that XLA lowers to
all-to-all on the expert axis.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.common import dense_init, pshard

Params = Dict[str, Any]


def init_moe(key, cfg: ModelConfig, dtype) -> Params:
    m: MoEConfig = cfg.moe
    d = cfg.d_model
    eff = m.expert_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    p: Params = {
        "router": dense_init(ks[0], (d, m.num_experts), jnp.float32),
        "we_gate": dense_init(ks[1], (m.num_experts, d, eff), dtype),
        "we_up": dense_init(ks[2], (m.num_experts, d, eff), dtype),
        "we_down": dense_init(ks[3], (m.num_experts, eff, d), dtype, fan_in=eff),
    }
    if m.num_shared_experts:
        sk = jax.random.split(ks[4], 3)
        sd = m.num_shared_experts * eff
        p["shared"] = {
            "w_gate": dense_init(sk[0], (d, sd), dtype),
            "w_up": dense_init(sk[1], (d, sd), dtype),
            "w_down": dense_init(sk[2], (sd, d), dtype, fan_in=sd),
        }
    return p


GROUP_TOKENS = 4096  # GShard-style dispatch group size


def apply_moe(p: Params, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Returns (output [B,S,D], aux load-balance loss scalar).

    Dispatch is *grouped* (GShard-style): tokens are split into groups of
    GROUP_TOKENS, each with its own capacity C = cf * group * K / E, so the
    one-hot dispatch tensor is O(T * group * K * cf) instead of O(T^2 * K / E).
    Groups shard over the data axes; experts shard over `model`.
    """
    m: MoEConfig = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, K = m.num_experts, m.top_k
    group = min(GROUP_TOKENS, T)
    while T % group:
        group //= 2
    G = T // group
    xg = x.reshape(G, group, D)

    # f32 routing accuracy WITHOUT materializing an f32 copy of every token
    logits = jnp.einsum("gtd,de->gte", xg, p["router"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # [G, t, E]

    gate_vals, expert_idx = jax.lax.top_k(probs, K)  # [G, t, K]
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # Load-balance aux loss (Switch-style): E * sum_e f_e * P_e (global means)
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)  # [G, t, K, E]
    frac_tokens = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))  # [E]
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs)

    # per-group capacity dispatch
    C = max(K, int(m.capacity_factor * group * K / E))
    flat = onehot.reshape(G, group * K, E)
    pos = jnp.cumsum(flat, axis=1) - 1.0  # position within expert queue
    pos = jnp.sum(pos * flat, axis=-1).reshape(G, group, K)
    keep = pos < C
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    slot = jax.nn.one_hot(jnp.where(keep, pos, C).astype(jnp.int32), C,
                          dtype=x.dtype)  # [G, t, K, C]
    disp = jnp.einsum("gtke,gtkc->gtec", onehot.astype(x.dtype), slot)
    comb = jnp.einsum("gtke,gtkc,gtk->gtec", onehot.astype(jnp.float32),
                      slot.astype(jnp.float32), gate_vals).astype(x.dtype)

    xe = jnp.einsum("gtd,gtec->egcd", xg, disp)  # [E, G, C, D] (all-to-all)
    xe = pshard(xe, "moe_expert")
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xe, p["we_gate"])) * jnp.einsum(
        "egcd,edf->egcf", xe, p["we_up"])
    ye = jnp.einsum("egcf,efd->egcd", h, p["we_down"])
    ye = pshard(ye, "moe_expert")
    y = jnp.einsum("egcd,gtec->gtd", ye, comb).reshape(B, S, D)

    if "shared" in p:
        sp = p["shared"]
        xt = x.reshape(T, D)
        hs = jax.nn.silu(jnp.einsum("td,df->tf", xt, sp["w_gate"])) * jnp.einsum(
            "td,df->tf", xt, sp["w_up"])
        y = y + jnp.einsum("tf,fd->td", hs, sp["w_down"]).reshape(B, S, D)

    return pshard(y, "act_dmodel"), aux
