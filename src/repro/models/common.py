"""Shared utilities for the model zoo: mesh-aware sharding constraints and
parameter initializers.

Models are written mesh-agnostically; `launch/` installs a mesh + logical sharding
rules through :func:`set_mesh_rules`, and :func:`pshard` becomes a no-op when no
mesh is installed (single-host tests, paper experiments).
"""
from __future__ import annotations

import contextlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None
_RULES: Dict[str, Tuple[Optional[str], ...]] = {}


def set_mesh_rules(mesh: Optional[Mesh], rules: Optional[Dict[str, P]] = None) -> None:
    global _MESH, _RULES
    _MESH = mesh
    _RULES = dict(rules or {})


@contextlib.contextmanager
def mesh_rules(mesh: Optional[Mesh], rules: Optional[Dict[str, P]] = None):
    global _MESH, _RULES
    prev = (_MESH, _RULES)
    set_mesh_rules(mesh, rules)
    try:
        yield
    finally:
        _MESH, _RULES = prev


def current_mesh() -> Optional[Mesh]:
    return _MESH


def pshard(x: jax.Array, rule: str) -> jax.Array:
    """Apply a named logical sharding constraint if a mesh is installed."""
    if _MESH is None:
        return x
    spec = _RULES.get(rule)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))


# ---------------------------------------------------------------------------
# Initializers (plain functional params-as-pytree style)
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: float = 1.0, fan_in: Optional[int] = None):
    fi = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / jnp.sqrt(jnp.asarray(fi, jnp.float32))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def zeros_init(_key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype):
    return jnp.ones(shape, dtype)


def split_tree(key: jax.Array, n: int):
    return list(jax.random.split(key, n))


def stack_init(init_fn, key, n: int):
    """vmap an init function over `n` stacked copies (for lax.scan layer stacks)."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)
