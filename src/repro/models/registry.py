"""Unified model API over the decoder-only and encoder-decoder assemblies, plus
`input_specs` — the ShapeDtypeStruct stand-ins used by the multi-pod dry-run
(weak-type-correct, shardable, no device allocation).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, transformer

# Encoder memory length for enc-dec decode/prefill shapes (frames are the stubbed
# frontend's output); documented in docs/DESIGN.md §Enc-dec memory length.
ENC_LEN = 4096
# Early-fusion image prefix length for VLM/early-fusion train shapes.
IMG_PREFIX = 256


def _mod(cfg: ModelConfig):
    return encdec if cfg.is_encdec else transformer


def init_params(key, cfg: ModelConfig, dtype=jnp.float32, window_override: int = 0):
    return _mod(cfg).init_params(key, cfg, dtype, window_override=window_override)


def loss_fn(params, cfg: ModelConfig, batch, *, remat=True, window_override: int = 0):
    return _mod(cfg).loss_fn(params, cfg, batch, remat=remat,
                             window_override=window_override)


def forward(params, cfg: ModelConfig, batch, **kw):
    return _mod(cfg).forward(params, cfg, batch, **kw)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               window_override: int = 0):
    if cfg.is_encdec:
        return encdec.init_cache(cfg, batch, max_len, dtype, enc_len=ENC_LEN,
                                 window_override=window_override)
    return transformer.init_cache(cfg, batch, max_len, dtype,
                                  window_override=window_override)


def prefill(params, cfg: ModelConfig, batch, cache, *, window_override: int = 0):
    return _mod(cfg).prefill(params, cfg, batch, cache, window_override=window_override)


def decode_step(params, cfg: ModelConfig, tokens, cache, index, *,
                window_override: int = 0):
    return _mod(cfg).decode_step(params, cfg, tokens, cache, index,
                                 window_override=window_override)


# ---------------------------------------------------------------------------
# Dry-run input specs
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract model inputs for (arch x input-shape), per the dry-run contract.

    train/prefill: full-sequence tokens (+labels for train, + stub modality
    embeddings where the arch is early-fusion / enc-dec).
    decode: ONE new token; the KV cache of seq_len is a separate spec built by
    `cache_specs` in launch/dryrun.py.
    """
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.mode == "decode":
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        return specs
    specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if shape.mode == "train":
        specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.is_encdec:
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, min(ENC_LEN, S), cfg.frontend_embed_dim), jnp.bfloat16)
    elif cfg.frontend_embed_dim and shape.mode == "train":
        specs["patches"] = jax.ShapeDtypeStruct(
            (B, IMG_PREFIX, cfg.frontend_embed_dim), jnp.bfloat16)
    return specs


def synth_batch(key, cfg: ModelConfig, shape_or_batch, seq_len: Optional[int] = None,
                mode: str = "train") -> Dict[str, jax.Array]:
    """Concrete random batch matching input_specs (for smoke tests/examples)."""
    if isinstance(shape_or_batch, ShapeConfig):
        B, S, mode = shape_or_batch.global_batch, shape_or_batch.seq_len, shape_or_batch.mode
    else:
        B, S = shape_or_batch, seq_len
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size, jnp.int32)}
    if mode == "train":
        batch["labels"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size, jnp.int32)
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            ks[2], (B, min(ENC_LEN, S), cfg.frontend_embed_dim), jnp.float32)
    elif cfg.frontend_embed_dim and mode == "train":
        batch["patches"] = jax.random.normal(
            ks[2], (B, min(IMG_PREFIX, S), cfg.frontend_embed_dim), jnp.float32)
    return batch
