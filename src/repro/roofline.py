"""Roofline analysis over dry-run artifacts (deliverable g).

Three terms per (arch x shape x mesh), from the compiled artifact:

    compute term    = HLO_FLOPs_total / (chips * peak_FLOP/s)
    memory term     = HLO_bytes_total / (chips * HBM_bw)
    collective term = collective_bytes_total / (chips * link_bw)

`cost_analysis()` on an SPMD executable reports per-device FLOPs/bytes, and the
collective parser sums per-device HLO result bytes, so the totals are
per_device * chips and the chips factor cancels: each term is simply
per-device work / per-chip rate. Ring all-reduce moves ~2x the payload
(reduce-scatter + all-gather); XLA reports the result shape once, so all-reduce
bytes are doubled when converting to wire bytes.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
(3D-torus neighbor links; we charge the per-chip injection rate).
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

# wire-byte multiplier per collective kind (ring algorithms, payload ~= result)
WIRE_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
             "all-to-all": 1.0, "collective-permute": 1.0}


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    mode: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_total: float
    useful_ratio: float
    peak_gib: float
    collectives: Dict[str, float]
    microbatches: int = 1

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Optimistic no-overlap-free estimate: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def mfu(self) -> float:
        """MODEL_FLOPS utilization implied by the roofline step time."""
        if self.step_time_s <= 0:
            return 0.0
        chips = 512 if self.mesh == "2x16x16" else 256
        return self.model_flops / (self.step_time_s * chips * PEAK_FLOPS)


def collective_wire_bytes(coll: Dict[str, float]) -> float:
    tot = 0.0
    for kind, mult in WIRE_MULT.items():
        tot += coll.get(kind, 0) * mult
    return tot


def model_flops_for(rec: dict) -> float:
    """6*N*D for training (N = active params), 2*N per decoded token, 2*N*D for
    prefill."""
    n_active = rec["active_params"]
    from repro.configs import SHAPES
    shape = SHAPES[rec["shape"]]
    tokens = shape.global_batch * shape.seq_len
    if rec["mode"] == "train":
        return 6.0 * n_active * tokens
    if rec["mode"] == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token per sequence


def analytic_hw_flops(rec: dict) -> float:
    """Hardware FLOPs actually executed (the compute-roofline numerator):
    matmul flops (k * N_active * tokens, k = 8 for remat training = fwd 2 +
    recompute 2 + bwd 4; 2 for inference) plus attention score/value flops with
    the effective context of each layer's mask.

    Used because XLA's HloCostAnalysis counts while-loop bodies once, so
    `cost.flops` under-reports scanned models (recorded as `useful` diagnostics).
    """
    from repro.configs import SHAPES, get_config
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    tokens = shape.global_batch * shape.seq_len
    k = 8.0 if rec["mode"] == "train" else 2.0
    total = k * rec["active_params"] * (
        tokens if rec["mode"] != "decode" else shape.global_batch)

    if cfg.num_heads:
        H, hd = cfg.num_heads, cfg.resolved_head_dim
        wo = rec.get("window_override", 0)
        try:
            from repro.models.transformer import build_plan
            period, n_rep, tail = build_plan(cfg, wo)
            specs = list(period) * n_rep + list(tail)
        except Exception:
            specs = []
        attn = 0.0
        S = shape.seq_len
        for sp in specs:
            if sp.kind not in ("attn", "mla"):
                continue
            if rec["mode"] == "decode":
                ctx = min(S, sp.window) if sp.window else S
                n_tok = shape.global_batch
                mult = 1.0
            else:
                ctx = (min(S, sp.window) if sp.window else S / 2.0)
                n_tok = tokens
                mult = 3.0 if rec["mode"] == "train" else 1.0
            attn += 4.0 * n_tok * ctx * H * hd * mult
        total += attn
    return total


def analyze(rec: dict) -> Roofline:
    # cost_analysis flops/bytes are per-device for SPMD executables, but XLA
    # counts while-loop bodies ONCE: scale bytes by the recorded loop trips;
    # compute flops analytically (see analytic_hw_flops); collective bytes are
    # already trip-corrected by the dry-run's HLO parser.
    scale = rec.get("trips", {}).get("scale", 1)
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    # prefer the per-computation trip-corrected HBM estimate from the HLO
    # parser; fall back to naive trip scaling of cost_analysis bytes
    hbm_est = rec.get("collectives", {}).get("hbm_bytes_est", 0.0)
    bytes_dev = hbm_est if hbm_est else rec["cost"]["bytes"] * scale
    coll_dev = collective_wire_bytes(rec.get("collectives", {}))
    mf = model_flops_for(rec)
    hw_flops_dev = analytic_hw_flops(rec) / chips
    hlo_total = rec["cost"]["flops"] * scale * chips
    return Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"], mode=rec["mode"],
        compute_s=hw_flops_dev / PEAK_FLOPS,
        memory_s=bytes_dev / HBM_BW,
        collective_s=coll_dev / ICI_BW,
        model_flops=mf,
        hlo_flops_total=hlo_total,
        useful_ratio=mf / hlo_total if hlo_total else 0.0,
        peak_gib=rec["memory"].get("peak_tpu_adjusted_gib", rec["memory"]["peak_gib"]),
        collectives=rec.get("collectives", {}),
        microbatches=rec.get("microbatches", 1),
    )


def load_artifacts(pattern: str = "artifacts/dryrun/*.json") -> List[dict]:
    out = []
    for path in sorted(glob.glob(pattern)):
        if os.path.basename(path).startswith("_"):
            continue
        with open(path) as f:
            out.append(json.load(f))
    return out


def table(rows: List[Roofline]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':8s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'collect_s':>10s} {'dominant':>10s} "
           f"{'useful':>7s} {'MFU':>6s} {'peak_GiB':>9s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:24s} {r.shape:12s} {r.mesh:8s} {r.compute_s:10.4f} "
            f"{r.memory_s:10.4f} {r.collective_s:10.4f} {r.dominant:>10s} "
            f"{r.useful_ratio:7.2f} {r.mfu:6.2f} {r.peak_gib:9.2f}")
    return "\n".join(lines)


def main():
    recs = load_artifacts()
    rows = [analyze(r) for r in recs]
    rows.sort(key=lambda r: (r.mesh, r.arch, r.shape))
    print(table(rows))


if __name__ == "__main__":
    main()
