"""Serving launcher: prefill a batch of prompts and decode with the sharded KV
cache. On this container use --reduced; the full configs are exercised through
launch.dryrun's decode shapes.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b --reduced \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

# perf hygiene BEFORE the jax import (XLA reads XLA_FLAGS / TF log level at
# import time); `--no-env-tuning` on the command line skips it
from repro.launch import env as _env

_env.apply_from_argv()

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced as reduce_cfg
from repro.models import registry
from repro.serve import engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--window-override", type=int, default=0)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--no-env-tuning", action="store_true",
                    help="skip the launcher perf hygiene (launch/env.py); "
                         "applied at import time, declared here for --help")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching decode loop (slot-based "
                         "admission, prefill-on-admit) instead of the static "
                         "batch generate path")
    ap.add_argument("--slots", type=int, default=4,
                    help="KV slot pool size for --continuous")
    ap.add_argument("--requests", type=int, default=16,
                    help="synthetic requests to serve with --continuous")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    dtype = jnp.dtype(args.dtype)
    params = registry.init_params(jax.random.PRNGKey(0), cfg, dtype,
                                  window_override=args.window_override)
    if args.continuous:
        _serve_continuous(cfg, params, args, dtype)
        return
    prompt = registry.synth_batch(jax.random.PRNGKey(1), cfg, args.batch,
                                  args.prompt_len, mode="prefill")
    max_len = args.prompt_len + args.gen

    t0 = time.time()
    st = engine.init_serve(cfg, args.batch, max_len, dtype,
                           window_override=args.window_override)
    st = engine.prefill(params, cfg, prompt, st,
                        window_override=args.window_override)
    t_prefill = time.time() - t0

    step = jax.jit(lambda s: engine.serve_step(
        params, cfg, s, window_override=args.window_override))
    toks = [st.last_tokens]
    t0 = time.time()
    for _ in range(args.gen - 1):
        st, t = step(st)
        toks.append(t)
    jax.block_until_ready(toks[-1])
    t_decode = time.time() - t0
    out = jnp.concatenate(toks, axis=1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill: {t_prefill:.2f}s  decode: {t_decode:.2f}s "
          f"({args.batch * (args.gen - 1) / max(t_decode, 1e-9):.1f} tok/s)")
    print("sample token ids:", out[0, :16].tolist())


def _serve_continuous(cfg, params, args, dtype):
    """Continuous-batching loop over synthetic prompts (the production decode
    path; see docs/DESIGN.md §Train-to-serve publication)."""
    import numpy as np

    max_len = args.prompt_len + args.gen
    eng = engine.ContinuousBatchingEngine(
        cfg, params, slots=args.slots, max_len=max_len, dtype=dtype,
        window_override=args.window_override)
    rng = np.random.default_rng(0)
    rids = [eng.submit(rng.integers(0, cfg.vocab_size, size=args.prompt_len),
                       args.gen) for _ in range(args.requests)]
    t0 = time.time()
    eng.drain()
    wall = time.time() - t0
    done = [eng.result(r) for r in rids]
    toks = sum(len(r.tokens) for r in done)
    print(f"arch={cfg.name} slots={args.slots} requests={args.requests} "
          f"prompt={args.prompt_len} gen={args.gen}")
    print(f"continuous decode: {wall:.2f}s  {toks} tokens "
          f"({toks / max(wall, 1e-9):.1f} tok/s, "
          f"{eng.decode_steps} decode steps)")
    print("sample token ids:", done[0].tokens[:16])


if __name__ == "__main__":
    main()
