"""Production training launcher.

On real hardware this runs the full assigned config on the production mesh; on
this CPU container use --reduced to train the family-faithful reduced variant
end-to-end (the full configs are exercised via launch.dryrun).

Example:
  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --reduced \
      --steps 50 --batch 8 --seq 256 --averaging gossip --rounds 4
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config, reduced as reduce_cfg
from repro.configs.base import AveragingConfig, RunConfig, StreamConfig
from repro.data.lm import MarkovTokenStream
from repro.data.pipeline import StreamingPipeline
from repro.launch import sharding as shlib
from repro.launch.mesh import make_host_mesh, make_production_mesh, n_data_nodes
from repro.models.common import mesh_rules
from repro.train import checkpoint as ckpt
from repro.train.trainer import (TrainState, build_train_step, init_state,
                                 make_node_batch, replicate_for_nodes)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adam")
    ap.add_argument("--averaging", default="exact",
                    choices=["exact", "gossip", "hierarchical"])
    ap.add_argument("--rounds", type=int, default=1)
    ap.add_argument("--topology", default="ring")
    ap.add_argument("--streaming-rate", type=float, default=0.0)
    ap.add_argument("--processing-rate", type=float, default=0.0)
    ap.add_argument("--comms-rate", type=float, default=0.0)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 mesh (requires 256 devices)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    mesh = make_production_mesh() if args.production_mesh else make_host_mesh()
    run = RunConfig(
        model=cfg, shape=SHAPES["train_4k"],
        averaging=AveragingConfig(args.averaging, args.rounds, args.topology),
        stream=StreamConfig(args.streaming_rate, args.processing_rate,
                            args.comms_rate),
        optimizer=args.optimizer, learning_rate=args.lr, param_dtype=args.dtype)

    n_nodes = n_data_nodes(mesh)
    decentralized = args.averaging != "exact"
    rules = shlib.activation_rules(mesh, run.shape, node_axis=decentralized)

    data = MarkovTokenStream(cfg.vocab_size, seed=0)
    pipeline = StreamingPipeline(
        lambda rng, n: next(iter([_draw(data, rng, n, args.seq)])),
        run.stream, n_nodes, args.rounds, batch=args.batch)
    print(f"plan: B={pipeline.plan.B} mu={pipeline.plan.mu} "
          f"regime={pipeline.plan.regime} nodes={n_nodes}")

    with mesh_rules(mesh, rules):
        state = init_state(run, jax.random.PRNGKey(run.seed))
        if decentralized:
            state = replicate_for_nodes(state, n_nodes)
        step, _ = build_train_step(run, mesh)
        step = jax.jit(step, donate_argnums=0)
        t0 = time.time()
        for i, batch in zip(range(args.steps), pipeline):
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if decentralized:
                batch = make_node_batch(batch, n_nodes)
            state, metrics = step(state, batch)
            if i % args.log_every == 0 or i == args.steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                print(f"step {i:5d} loss {m['loss']:.4f} ce {m['ce']:.4f} "
                      f"consensus_err {m['consensus_err']:.2e} "
                      f"t'={pipeline.samples_arrived} "
                      f"({time.time() - t0:.1f}s)", flush=True)
    if args.checkpoint:
        ckpt.save(args.checkpoint, state, step=args.steps,
                  meta={"arch": args.arch, "reduced": args.reduced})
        print(f"checkpoint -> {args.checkpoint}")


def _draw(data: MarkovTokenStream, rng: np.random.Generator, n: int, seq: int):
    toks = data.sample(rng, n, seq + 1)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


if __name__ == "__main__":
    main()
