"""Production training launcher, wired onto the superstep streaming engine
(`train.driver`): K-round device scans, async device prefetch, and the
closed-loop (B, mu) governor.

On real hardware this runs the full assigned config on the production mesh; on
this CPU container use --reduced to train the family-faithful reduced variant
end-to-end (the full configs are exercised via launch.dryrun).

Example:
  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --reduced \
      --steps 48 --batch 8 --seq 256 --averaging gossip --rounds 4 \
      --superstep 8 --prefetch 2
"""
from __future__ import annotations

import argparse

# perf hygiene BEFORE the jax import (XLA reads XLA_FLAGS / TF log level at
# import time); `--no-env-tuning` on the command line skips it
from repro.launch import env as _env

_env.apply_from_argv()

import jax
import numpy as np

from repro.configs import SHAPES, get_config, reduced as reduce_cfg
from repro.configs.base import (AveragingConfig, GovernorConfig, PublishConfig,
                                RunConfig, StreamConfig)
from repro.core.faults import FaultSchedule
from repro.data.lm import MarkovTokenStream
from repro.launch import sharding as shlib
from repro.launch.mesh import make_host_mesh, make_production_mesh, n_data_nodes
from repro.models.common import mesh_rules
from repro.train import checkpoint as ckpt
from repro.train.driver import EngineConfig, StreamingDriver
from repro.train.trainer import (init_state, replicate_for_nodes,
                                 superstep_builder)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100,
                    help="total rounds (rounded up to whole supersteps)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adam")
    ap.add_argument("--averaging", default="exact",
                    choices=["exact", "gossip", "hierarchical"])
    ap.add_argument("--rounds", type=int, default=1)
    ap.add_argument("--topology", default="ring")
    ap.add_argument("--streaming-rate", type=float, default=0.0)
    ap.add_argument("--processing-rate", type=float, default=0.0)
    ap.add_argument("--comms-rate", type=float, default=0.0)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--checkpoint", default="",
                    help="checkpoint directory; with --checkpoint-every 0 a "
                         "single end-of-run save, otherwise the root for "
                         "step_NNNNNNNN/ async snapshots")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="async snapshot cadence in supersteps (0 = only the "
                         "legacy end-of-run save); requires --checkpoint")
    ap.add_argument("--keep-last", type=int, default=3,
                    help="checkpoints retained under the root (the newest "
                         "VALID one is never pruned)")
    ap.add_argument("--checkpoint-budget", type=float, default=0.05,
                    help="snapshot-governor overhead budget: max fraction of "
                         "train wall time spent dispatching snapshot copies")
    ap.add_argument("--resume", default="",
                    help="resume from this checkpoint root (newest valid "
                         "step) or a specific step_NNNNNNNN directory")
    ap.add_argument("--compilation-cache-dir", default="",
                    help="persistent XLA compilation cache directory so a "
                         "resumed run skips recompiles (launch/env.py); "
                         "applied at import time, declared here for --help")
    ap.add_argument("--log-every", type=int, default=1,
                    help="log every this many supersteps")
    ap.add_argument("--superstep", type=int, default=8,
                    help="K: rounds folded into one jitted device scan")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="async prefetch ring depth (0 = synchronous staging)")
    ap.add_argument("--replan-every", type=int, default=1,
                    help="supersteps between closed-loop (B, mu) re-plans; "
                         "0 disables the governor feedback")
    ap.add_argument("--buckets", default="",
                    help="comma-separated B bucket ladder for the adaptive "
                         "governor (e.g. '8,16,32'); empty pins B to --batch")
    ap.add_argument("--n-buckets", type=int, default=1,
                    help="auto geometric ladder size around the planned B "
                         "when --buckets is empty (1 = pinned B)")
    ap.add_argument("--bucket-hysteresis", type=int, default=2,
                    help="consecutive re-plans that must agree on a bucket "
                         "before the governor switches B")
    ap.add_argument("--no-rate-estimator", action="store_true",
                    help="disable the online least-squares (R_p, R_c) "
                         "estimator; fall back to the config comms constant")
    ap.add_argument("--horizon", type=float, default=0.0,
                    help="sample horizon t' for Theorem 4's B <= sqrt(t') "
                         "bucket ceiling (0 = no ceiling)")
    ap.add_argument("--faults", default="",
                    help="fault-injection spec for elastic membership, e.g. "
                         "'death:1@5-12,slow:0@3-9x4' "
                         "(see core/faults.py; needs --averaging gossip)")
    ap.add_argument("--scenario", default="",
                    help="named scenario from core/scenarios.py: replaces "
                         "--topology/--rounds with the scenario's "
                         "time-varying mixing schedule and adds its link "
                         "model (loss/bandwidth) to --faults; the stream "
                         "axis stays the LM token stream (the synthetic "
                         "streams are exercised by "
                         "benchmarks/bench_scenarios.py); needs "
                         "--averaging gossip")
    ap.add_argument("--straggler-policy", default="wait",
                    choices=["wait", "drop", "deadline"],
                    help="straggler handling: wait (lockstep), drop "
                         "(exclude nodes slower than --straggler-factor x "
                         "median), deadline (--straggler-deadline seconds)")
    ap.add_argument("--straggler-factor", type=float, default=2.0)
    ap.add_argument("--straggler-deadline", type=float, default=0.0)
    ap.add_argument("--no-rejoin-sync", action="store_true",
                    help="keep a rejoining node's stale iterate instead of "
                         "syncing it to the cohort mean")
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 mesh (requires 256 devices)")
    ap.add_argument("--no-env-tuning", action="store_true",
                    help="skip the launcher perf hygiene (launch/env.py); "
                         "applied at import time, declared here for --help")
    ap.add_argument("--publish", action="store_true",
                    help="publish consensus param snapshots at superstep "
                         "boundaries (serve/publisher.py) for a serving "
                         "replica to adopt")
    ap.add_argument("--publish-budget", type=float, default=0.05,
                    help="publish-governor overhead budget: max fraction of "
                         "train wall time spent on snapshot copies")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    mesh = make_production_mesh() if args.production_mesh else make_host_mesh()
    n_nodes = n_data_nodes(mesh)
    scenario = None
    averaging = AveragingConfig(args.averaging, args.rounds, args.topology)
    if args.scenario:
        if args.averaging != "gossip":
            ap.error("--scenario needs --averaging gossip")
        import dataclasses

        from repro.core import scenarios as scenario_lib

        scenario = scenario_lib.get_scenario(args.scenario)
        if scenario.n_nodes != n_nodes:
            # scenarios are registered at their canonical size; re-root the
            # schedule on this mesh's node axis (link endpoints must fit)
            scenario = dataclasses.replace(scenario, n_nodes=n_nodes)
        averaging = scenario_lib.averaging_config(scenario)
    run = RunConfig(
        model=cfg, shape=SHAPES["train_4k"],
        averaging=averaging,
        stream=StreamConfig(args.streaming_rate, args.processing_rate,
                            args.comms_rate),
        optimizer=args.optimizer, learning_rate=args.lr, param_dtype=args.dtype)

    decentralized = args.averaging != "exact"
    rules = shlib.activation_rules(mesh, run.shape, node_axis=decentralized)
    buckets = tuple(int(b) for b in args.buckets.split(",") if b.strip())
    governor = GovernorConfig(buckets=buckets, n_buckets=args.n_buckets,
                              hysteresis=args.bucket_hysteresis,
                              estimate_rates=not args.no_rate_estimator,
                              straggler_policy=args.straggler_policy,
                              straggler_slow_factor=args.straggler_factor,
                              straggler_deadline_s=args.straggler_deadline,
                              sync_on_rejoin=not args.no_rejoin_sync)
    # the scenario's link model rides the same fault schedule as any node
    # faults from --faults (link windows index consensus rounds, node
    # windows supersteps — core/faults.py)
    fault_spec = ",".join(
        s for s in (args.faults, scenario.links if scenario else "") if s)
    faults = (FaultSchedule.parse(fault_spec, n_nodes,
                                  seed=scenario.seed if scenario else 0)
              if fault_spec else None)
    builder = (superstep_builder(run, mesh, n_nodes=n_nodes,
                                 mix=scenario_lib.build_mix(scenario))
               if scenario is not None else None)
    engine = EngineConfig(superstep=args.superstep,
                          prefetch_depth=args.prefetch,
                          replan_every=args.replan_every,
                          governor=governor)
    supersteps = -(-args.steps // engine.superstep)

    data = MarkovTokenStream(cfg.vocab_size, seed=0)
    sample_fn = lambda rng, n: _draw(data, rng, n, args.seq)

    publisher = None
    if args.publish:
        from repro.serve.publisher import SnapshotPublisher

        pub_cfg = PublishConfig(enabled=True,
                                overhead_budget=args.publish_budget)
        publisher = SnapshotPublisher(
            overhead_budget=pub_cfg.overhead_budget,
            min_interval_s=pub_cfg.min_interval_s, block=pub_cfg.block)

    snapshotter = None
    if args.checkpoint_every > 0:
        if not args.checkpoint:
            ap.error("--checkpoint-every needs --checkpoint DIR as the root")
        from repro.train.snapshot import RunSnapshotter

        snapshotter = RunSnapshotter(args.checkpoint,
                                     every=args.checkpoint_every,
                                     keep_last=args.keep_last,
                                     overhead_budget=args.checkpoint_budget)

    with mesh_rules(mesh, rules):
        state = init_state(run, jax.random.PRNGKey(run.seed))
        if decentralized:
            state = replicate_for_nodes(state, n_nodes)
        with StreamingDriver(run, mesh, state, sample_fn, engine=engine,
                             superstep_builder=builder,
                             batch=args.batch, faults=faults,
                             horizon=args.horizon or None,
                             publisher=publisher, snapshotter=snapshotter,
                             resume_from=args.resume or None) as driver:
            plan = driver.pipeline.plan
            if scenario is not None:
                sched = " ".join(f"{t}x{s}"
                                 for t, s in scenario.topology_schedule)
                print(f"scenario: {scenario.name} [{sched}] "
                      f"links='{scenario.links}' rounds={scenario.rounds}")
            if driver.resumed_from:
                print(f"resumed: {driver.resumed_from} "
                      f"(superstep {driver._supersteps_done})")
            print(f"plan: B={plan.B} mu={plan.mu} regime={plan.regime} "
                  f"nodes={n_nodes} K={engine.superstep} "
                  f"prefetch={engine.prefetch_depth} "
                  f"buckets={list(driver.ladder.buckets)}")
            state, history = driver.run(supersteps, log_fn=_log,
                                        log_every=args.log_every)
    if publisher is not None:
        st = publisher.stats
        stale = publisher.staleness(supersteps)
        print(f"publisher: v{publisher.version} publishes={st.publishes} "
              f"skipped(budget={st.skipped_budget} "
              f"interval={st.skipped_interval}) "
              f"cost_ewma={st.cost_ewma_s * 1e3:.2f}ms "
              f"total_cost={st.total_cost_s:.3f}s "
              f"staleness={stale['supersteps']} supersteps "
              f"/ {stale['wall_s']:.2f}s")
    if snapshotter is not None:
        st = snapshotter.stats
        print(f"snapshotter: saves={st.saves} "
              f"skipped(cadence={st.skipped_cadence} "
              f"budget={st.skipped_budget} busy={st.skipped_busy}) "
              f"failures={st.failures} "
              f"cost_ewma={st.cost_ewma_s * 1e3:.2f}ms "
              f"total_cost={st.total_cost_s:.3f}s -> {args.checkpoint}")
    elif args.checkpoint:
        ckpt.save(args.checkpoint, state, step=supersteps * engine.superstep,
                  meta={"arch": args.arch, "reduced": args.reduced})
        print(f"checkpoint -> {args.checkpoint}")


def _log(rec):
    m = rec["metrics"]
    c = rec["counters"]
    plan = rec.get("replanned", rec["plan"])
    gov = ""
    if rec["plan"].membership is not None:
        gov += f" N={rec['n_active']}/{rec['plan'].membership.n}"
    if "bucket_switch" in rec:
        gov += f" B:{rec['bucket_switch'][0]}->{rec['bucket_switch'][1]}"
    if "est_Rc" in rec:
        rc = rec["est_Rc"]
        gov += f" est_Rc={'inf' if rc <= 0 else f'{rc:.3g}'}"
    print(f"round {rec['round']:5d} loss {m['loss']:.4f} ce {m['ce']:.4f} "
          f"consensus_err {m['consensus_err']:.2e} "
          f"t'={c.samples_arrived} B={rec['bucket']} mu={plan.mu} "
          f"{plan.regime}{gov} "
          f"({rec['rounds_per_s']:.1f} rounds/s, "
          f"{rec['samples_per_s']:.0f} samples/s)", flush=True)


def _draw(data: MarkovTokenStream, rng: np.random.Generator, n: int, seq: int):
    toks = data.sample(rng, n, seq + 1)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


if __name__ == "__main__":
    main()
