"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1):
    """Whatever this host actually has — for smoke-scale integration tests."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def n_data_nodes(mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n
