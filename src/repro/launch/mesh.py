"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """`axis_types` for jax.make_mesh where the installed jax supports it.
    The pinned jax (0.4.37) predates `jax.sharding.AxisType`; auto sharding
    is the implicit (and only) behavior there, so the kwarg is omitted."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_mesh(shape: tuple, axes: tuple):
    """jax.make_mesh with version-portable Auto axis types."""
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever this host actually has — for smoke-scale integration tests."""
    n = len(jax.devices())
    assert n % model == 0
    return make_mesh((n // model, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def n_data_nodes(mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n
