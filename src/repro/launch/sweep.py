"""Dry-run sweep driver: every (architecture x input shape) on the single-pod
16x16 mesh (the 40 baselines), plus the multi-pod 2x16x16 pass, plus the
paper-technique averaging variants. Each combo runs in a fresh subprocess (jax
locks device counts; compilation memory is reclaimed per run) and writes a JSON
artifact under artifacts/dryrun/.

Usage:  PYTHONPATH=src python -m repro.launch.sweep [--only baselines|multipod|averaging]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import ARCH_IDS, SHAPES

ART = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")

# encoder-only / inapplicable skips would be listed here; all 10 assigned archs
# support all four shapes (full-attention archs use the windowed long_500k
# variant, recorded in the artifact as window_override)
SKIPS: set = set()


def combos(kind: str):
    if kind in ("baselines", "all"):
        for arch in ARCH_IDS:
            for shape in SHAPES:
                if (arch, shape) not in SKIPS:
                    yield {"arch": arch, "shape": shape, "multi_pod": False,
                           "averaging": "exact", "tag": "base"}
    if kind in ("multipod", "all"):
        for arch in ARCH_IDS:
            for shape in SHAPES:
                if (arch, shape) not in SKIPS:
                    yield {"arch": arch, "shape": shape, "multi_pod": True,
                           "averaging": "exact", "tag": "multipod"}
    if kind in ("averaging", "all"):
        # the paper's technique variants on train_4k (one per family exemplar)
        for arch in ("granite-8b", "qwen2-moe-a2.7b", "mamba2-2.7b"):
            yield {"arch": arch, "shape": "train_4k", "multi_pod": False,
                   "averaging": "gossip", "rounds": 4, "tag": "gossip_r4"}
        yield {"arch": "granite-8b", "shape": "train_4k", "multi_pod": True,
               "averaging": "hierarchical", "rounds": 4, "tag": "hier_r4"}


def artifact_path(c) -> str:
    return os.path.join(ART, f"{c['arch']}__{c['shape']}__{c['tag']}.json")


def run_combo(c, timeout=1200) -> dict:
    out = artifact_path(c)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", c["arch"],
           "--shape", c["shape"], "--averaging", c.get("averaging", "exact"),
           "--rounds", str(c.get("rounds", 1)), "--out", out]
    if c["multi_pod"]:
        cmd.append("--multi-pod")
    t0 = time.time()
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    try:
        p = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout,
                           env=env)
        ok = p.returncode == 0 and os.path.exists(out)
        err = "" if ok else (p.stderr[-2000:] or p.stdout[-2000:])
    except subprocess.TimeoutExpired:
        ok, err = False, "timeout"
    return {"combo": c, "ok": ok, "wall_s": round(time.time() - t0, 1), "err": err}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all",
                    choices=["baselines", "multipod", "averaging", "all"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(ART, exist_ok=True)
    results = []
    for c in combos(args.only):
        if not args.force and os.path.exists(artifact_path(c)):
            print(f"skip (exists): {c['arch']} {c['shape']} {c['tag']}")
            continue
        r = run_combo(c)
        status = "OK " if r["ok"] else "FAIL"
        print(f"{status} {c['arch']:24s} {c['shape']:12s} {c['tag']:9s} "
              f"{r['wall_s']:7.1f}s {r['err'][:200]}", flush=True)
        results.append(r)
    with open(os.path.join(ART, "_sweep_log.json"), "a") as f:
        json.dump(results, f, indent=1)
    fails = [r for r in results if not r["ok"]]
    print(f"\n{len(results) - len(fails)} ok, {len(fails)} failed")
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
