"""Sharding rules: parameter specs by name, activation constraint rules, and
KV-cache specs per input shape.

Convention: every parameter leaf gets a *base* spec keyed by its dict name; the
spec covers the trailing dims and is left-padded with None for any leading
stacking dims (layer scan stacks, node axes), so the same table serves the
per-layer, stacked and decentralized-parameter representations.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import data_axes

Tree = Any

M = "model"

# base specs over each param's own (unstacked) trailing dims
_NAME_SPECS: Dict[str, Tuple] = {
    # embeddings
    "embed": (M, None),
    "unembed": (M, None),
    "frontend_proj": (None, M),
    # attention
    "wq": (None, M), "wk": (None, M), "wv": (None, M), "wo": (M, None),
    # MLA
    "wq_a": (None, None), "wq_b": (None, M),
    "wkv_a": (None, None), "wkv_b": (None, M),
    "norm_kv": (None,),
    # dense/shared FFN
    "w_gate": (None, M), "w_up": (None, M), "w_down": (M, None),
    # MoE (expert-parallel over the model axis)
    "router": (None, None),
    "we_gate": (M, None, None), "we_up": (M, None, None), "we_down": (M, None, None),
    # SSD (mamba2)
    "w_in": (None, M), "conv_w": (None, M), "conv_b": (M,),
    "A_log": (None,), "D": (None,), "dt_bias": (None,),
    "norm_scale": (M,), "w_out": (M, None),
    # RG-LRU
    "w_gate_in": (None, M), "w_main_in": (None, M),
    "w_rec_gate": (None, M), "w_inp_gate": (None, M), "lam": (M,),
    # norms
    "scale": (None,), "bias": (None,),
}


# fallback candidates when a base spec's dims don't divide the mesh axis
# (e.g. vocab 50280 % 16 != 0 -> shard the d_model dim; 60 experts % 16 != 0 ->
# tensor-shard within experts; kv heads < 16 in caches -> shard head_dim)
_ALT_SPECS: Dict[str, Tuple[Tuple, ...]] = {
    "embed": ((None, M),),
    "unembed": ((None, M),),
    "we_gate": ((None, None, M),),
    "we_up": ((None, None, M),),
    "we_down": ((None, M, None),),
}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
    return ""


def _axis_size(mesh: Mesh, d) -> int:
    axes = d if isinstance(d, tuple) else (d,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fits(dims: Tuple, shape: Tuple[int, ...], mesh: Mesh) -> bool:
    return all(d is None or s % _axis_size(mesh, d) == 0
               for d, s in zip(dims, shape))


def _sanitize(dims: Tuple, shape: Tuple[int, ...], mesh: Mesh) -> Tuple:
    return tuple(d if (d is None or shape[i] % _axis_size(mesh, d) == 0) else None
                 for i, d in enumerate(dims))


def _resolve(name: str, shape: Tuple[int, ...], mesh: Mesh, lead: Tuple) -> Tuple:
    base = _NAME_SPECS.get(name, ())
    pad = len(shape) - len(base) - len(lead)
    assert pad >= 0, f"{name}: shape {shape} < spec {base}"
    for cand in (base,) + _ALT_SPECS.get(name, ()):
        dims = lead + (None,) * pad + tuple(cand)
        if _fits(dims, shape, mesh):
            return dims
    return _sanitize(lead + (None,) * pad + tuple(base), shape, mesh)


def param_specs(params: Tree, mesh: Optional[Mesh] = None, *,
                node_axes: Optional[Tuple[str, ...]] = None) -> Tree:
    """PartitionSpecs for a parameter pytree. If `node_axes` is given, params
    carry a leading decentralized-node dim sharded over those mesh axes."""

    def spec(path, leaf):
        name = _leaf_name(path)
        lead = (node_axes,) if node_axes else ()
        if mesh is not None:
            return P(*_resolve(name, leaf.shape, mesh, lead))
        base = _NAME_SPECS.get(name, ())
        pad = leaf.ndim - len(base) - len(lead)
        assert pad >= 0, f"{name}: ndim {leaf.ndim} < spec {base}"
        return P(*(lead + (None,) * pad + tuple(base)))

    return jax.tree_util.tree_map_with_path(spec, params)


def zero1_specs(params: Tree, mesh: Mesh, *,
                node_axes: Optional[Tuple[str, ...]] = None) -> Tree:
    """ZeRO-1 specs for optimizer moments: the param spec plus the data axes on
    the first still-replicated dim whose size divides evenly. Keeps fp32 Adam
    state at 1/(data*model) per chip instead of 1/model."""
    dp = data_axes(mesh)
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]
    base = param_specs(params, mesh, node_axes=node_axes)

    def add_dp(path, leaf, spec):
        if node_axes:  # node axis already consumes the data axes
            return spec
        dims = list(spec) + [None] * (leaf.ndim - len(spec))
        # never shard the leading stack dim of scanned layer weights: the
        # per-layer dynamic-slice would all-gather the whole stack every layer
        order = list(range(1, leaf.ndim)) + ([0] if leaf.ndim < 3 else [])
        for i in order:
            if dims[i] is None and leaf.shape[i] % ndp == 0 and leaf.shape[i] > 0:
                dims[i] = dp
                return P(*dims)
        return spec

    return jax.tree_util.tree_map_with_path(
        lambda pth, leaf, sp: add_dp(pth, leaf, sp), params, base)


def activation_rules(mesh: Mesh, shape: ShapeConfig,
                     node_axis: bool = False) -> Dict[str, P]:
    """Logical rules consumed by models.common.pshard."""
    dp = data_axes(mesh)
    if node_axis:
        # under vmap over the node axis, constraints see the un-batched shape;
        # rely on propagation instead (docs/DESIGN.md §Mesh & sharding)
        return {}
    if shape.mode == "decode" and shape.global_batch < mesh.shape["data"]:
        # long-context decode: batch too small to shard; replicate activations,
        # shard heads/features over model (the cache itself is sequence-sharded)
        return {
            "act_dmodel": P(None, None, None),
            "act_resid": P(None, None, None),
            "act_ff": P(None, None, M),
            "act_heads": P(None, None, M, None),
            "act_scores": P(None, M, None, None),
            "act_vocab": P(None, None, M),
            "emb_vocab": P(M, None),
            "emb_replicated": P(None, None),
            "moe_expert": P(M, None, None, None),
            "act_ssm_l": P(None, None, M, None, None),
            "act_ssm_y": P(None, None, None, M, None),
            "act_ssm_state": P(None, None, M, None, None),
        }
    return {
        "act_dmodel": P(dp, None, None),
        # residual stream saved by the remat layer-scan: also shard over model
        # (Megatron-style; re-gathered per layer). Perf iteration B2 tried
        # replicating it at inference: collective -29% but HBM +15% on the
        # memory-dominated rg prefill -> net regression, REVERTED (EXPERIMENTS
        # §Perf B2).
        "act_resid": P(dp, None, M),
        "act_ff": P(dp, None, M),
        "act_heads": P(dp, None, M, None),
        "act_scores": P(dp, M, None, None),
        "act_vocab": P(dp, None, M),
        "emb_vocab": P(M, None),
        "emb_replicated": P(None, None),
        "moe_expert": P(M, dp, None, None),
        # SSD internals: [b,c,H,q,q] decay blocks, [b,c,q,H,p] outputs,
        # [b,c,H,P,N] chunk states — head axis over model
        "act_ssm_l": P(dp, None, M, None, None),
        "act_ssm_y": P(dp, None, None, M, None),
        "act_ssm_state": P(dp, None, M, None, None),
    }


def kv_rules(mesh: Mesh, shape: ShapeConfig, kv_heads: int) -> Dict[str, P]:
    """Rules for fresh K/V ("act_kv") and the updated cache ("act_cache_kv"),
    matched to cache_specs' layout for this arch's KV-head divisibility."""
    dp = data_axes(mesh)
    msize = mesh.shape[M]
    seq_parallel = shape.global_batch < mesh.shape["data"]
    heads_ok = kv_heads > 0 and kv_heads % msize == 0
    if seq_parallel:
        cache = P(None, dp, M, None) if heads_ok else P(None, dp, None, M)
        fresh = P(None, None, M, None) if heads_ok else P(None, None, None, M)
    elif heads_ok:
        cache = fresh = P(dp, None, M, None)
    else:
        cache = P(dp, M, None, None)
        fresh = P(dp, M, None, None)
    return {"act_cache_kv": cache, "act_kv": fresh}


def batch_specs(batch_shapes: Tree, mesh: Mesh, shape: ShapeConfig,
                node_axis: bool = False) -> Tree:
    dp = data_axes(mesh)
    small = shape.global_batch < mesh.shape["data"]

    def spec(path, leaf):
        if small:
            return P(*([None] * leaf.ndim))
        if node_axis:
            # [node, B/node, ...]
            return P(dp, *([None] * (leaf.ndim - 1)))
        return P(dp, *([None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(spec, batch_shapes)


def cache_specs(cache: Tree, mesh: Mesh, shape: ShapeConfig) -> Tree:
    """KV/state cache specs. decode_32k shards the cache batch over data and
    kv-heads/latents over model; long_500k (batch 1) shards the *sequence* dim
    over data (sequence-parallel cache) and heads over model."""
    dp = data_axes(mesh)
    seq_parallel = shape.global_batch < mesh.shape["data"]

    def spec(path, leaf):
        name = _leaf_name(path)
        stacked = leaf.ndim and path and any(
            getattr(e, "key", None) in ("layers", "decoder") for e in path)
        lead = (None,) if stacked else ()
        nb = (None,) if seq_parallel else (dp,)
        if name in ("k", "v"):  # [B, S, KH, hd]
            body = nb + ((dp,) if seq_parallel else (None,)) + (M, None)
            if not _fits(lead + body, leaf.shape, mesh):  # KH < model size
                if not seq_parallel:
                    # shard the sequence dim over model instead: head-dim
                    # sharding provokes involuntary full-remat copies in SPMD
                    body = nb + (M, None, None)
                else:
                    body = nb + (dp, None, M)
        elif name == "ckv":  # [B, S, rank]
            body = nb + ((dp,) if seq_parallel else (None,)) + (M,)
        elif name == "krope":  # [B, S, 1, rope]
            body = nb + ((dp,) if seq_parallel else (None,)) + (None, None)
        elif name == "h":  # ssd [B, H, P, N] / rglru [B, w]
            body = nb + (M,) + (None,) * (leaf.ndim - len(lead) - 2)
        elif name == "conv":  # [B, W-1, convdim]
            body = nb + (None, M)
        elif name == "memory":  # enc-dec memory [B, S_enc, D]
            body = nb + (None, None)
            lead = ()
        else:
            body = (None,) * (leaf.ndim - len(lead))
        assert len(lead) + len(body) == leaf.ndim, f"{name}: {leaf.ndim} vs {lead + body}"
        return P(*_sanitize(lead + body, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(spec, cache)


def named(tree_specs: Tree, mesh: Mesh) -> Tree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def abstract_with_sharding(shapes: Tree, specs: Tree, mesh: Mesh) -> Tree:
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, sp)),
        shapes, specs)
