import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: prove the distribution config is coherent without
hardware.

For every (architecture x input-shape x mesh) this lowers + compiles the real
train/prefill/serve step against ShapeDtypeStruct stand-ins (no allocation),
prints memory_analysis() (fits per chip?) and cost_analysis() (FLOPs/bytes for
the roofline), parses the optimized HLO for collective bytes, and writes a JSON
artifact consumed by repro.roofline and EXPERIMENTS.md.

NOTE the two lines above: jax locks the device count at first init, so the
XLA_FLAGS export precedes every import, including `from repro...`.
"""
import argparse
import json
import re
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.configs.base import AveragingConfig, RunConfig
from repro.launch import sharding as shlib
from repro.launch.mesh import data_axes, make_production_mesh, n_data_nodes
from repro.models import registry
from repro.models.common import mesh_rules
from repro.serve import engine
from repro.train import trainer

# default gradient-accumulation factor per arch for train shapes (keeps the
# per-chip activation working set inside v5e HBM; see EXPERIMENTS.md §Dry-run)
TRAIN_MICROBATCHES = {
    "llama4-scout-17b-a16e": 16,
    "chameleon-34b": 16,
    "recurrentgemma-9b": 4,
    "starcoder2-15b": 2,
    "seamless-m4t-medium": 4,
}

# archs whose faithful config is full attention: long_500k runs a sliding-window
# variant (docs/DESIGN.md §long_500k applicability)
WINDOWED_FOR_500K = {
    "granite-8b": 8192,
    "phi4-mini-3.8b": 8192,
    "minicpm3-4b": 8192,
    "chameleon-34b": 8192,
    "seamless-m4t-medium": 8192,
}

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f64": 8,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "s16": 2, "u16": 2}

_COLLECTIVE_RE = re.compile(
    r"=\s+(\w+)\[([\d,]*)\][^\n]*?\b"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


_UPCAST_RE = re.compile(r"\(param_[\w.]+: bf16\[([\d,]+)\]\) -> f32\[")


def cost_analysis_dict(compiled) -> dict:
    """Version-portable `compiled.cost_analysis()`: jax <= 0.4.x returns a
    per-device list of dicts, newer jax a single dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def parse_cpu_upcasts(hlo: str) -> float:
    """Bytes of hoisted bf16->f32 parameter upcasts. The CPU backend has no
    native bf16 GEMM, so it converts whole weight tensors to f32 before the
    layer loop; TPU MXUs consume bf16 directly, so these buffers don't exist on
    the target hardware. Reported so the peak can be TPU-adjusted."""
    total = 0.0
    for m in _UPCAST_RE.finditer(hlo):
        n = 4
        for d in m.group(1).split(","):
            n *= int(d)
        total += n
    return total


_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+) \([^)]*\) -> ", re.M)
_WHILE_RE = re.compile(r"while\([^)]*\), condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r"compare\([^)]*\), direction=LT")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo: str):
    """-> {comp_name: body_text} from optimized HLO text."""
    comps = {}
    cur, buf = None, []
    for line in hlo.splitlines():
        m = _COMP_RE.match(line)
        if m and line.rstrip().endswith("{"):
            if cur:
                comps[cur] = "\n".join(buf)
            cur, buf = m.group(1), []
        elif cur is not None:
            if line.startswith("}"):
                comps[cur] = "\n".join(buf)
                cur, buf = None, []
            else:
                buf.append(line)
    return comps


def _trip_count(cond_body: str) -> int:
    """Loop trip count from the while condition (induction var < constant)."""
    if _TRIP_RE.search(cond_body):
        consts = [int(c) for c in _CONST_RE.findall(cond_body)]
        if consts:
            return max(consts)
    return 1


def parse_collectives(hlo: str):
    """Sum result-shape bytes per collective kind from optimized HLO,
    multiplying ops inside while loops by their trip counts (XLA cost analysis
    and HLO text report loop bodies once)."""
    comps = _split_computations(hlo)
    mult = {name: 1 for name in comps}
    changed, guard = True, 0
    while changed and guard < 20:
        changed, guard = False, guard + 1
        for name, body in comps.items():
            for wm in _WHILE_RE.finditer(body):
                cond, wbody = wm.group(1), wm.group(2)
                trips = _trip_count(comps.get(cond, ""))
                want = mult.get(name, 1) * trips
                for target in (wbody, cond):
                    if target in mult and mult[target] < want:
                        mult[target] = want
                        changed = True
    # propagate into fusion/call computations
    call_re = re.compile(r"(?:calls=|to_apply=)%?([\w.\-]+)")
    for _ in range(3):
        for name, body in comps.items():
            for cm in call_re.finditer(body):
                callee = cm.group(1)
                if callee in mult and mult[callee] < mult.get(name, 1):
                    mult[callee] = mult[name]

    out = {}
    hbm = 0.0
    shape_re = re.compile(r"=\s+(\w+)\[([\d,]*)\]")
    for name, body in comps.items():
        scale = mult.get(name, 1)
        for m in _COLLECTIVE_RE.finditer(body):
            dtype, dims, kind = m.group(1), m.group(2), m.group(3)
            nbytes = _DTYPE_BYTES.get(dtype, 4)
            for d in dims.split(","):
                if d:
                    nbytes *= int(d)
            out[kind] = out.get(kind, 0) + nbytes * scale
            out[kind + ".count"] = out.get(kind + ".count", 0) + scale
        # HBM traffic estimate: result bytes of every materializing op, x2 for
        # the read side, trip-scaled (fusion internals excluded by only
        # counting each op's result once)
        for m in shape_re.finditer(body):
            dtype = m.group(1)
            if dtype not in _DTYPE_BYTES:
                continue
            nbytes = _DTYPE_BYTES[dtype]
            for d in m.group(2).split(","):
                if d:
                    nbytes *= int(d)
            hbm += 2.0 * nbytes * scale
    out["hbm_bytes_est"] = hbm
    return out


def window_override_for(arch: str, shape_name: str) -> int:
    if shape_name == "long_500k":
        return WINDOWED_FOR_500K.get(arch, 0)
    return 0


def build_lowerable(arch: str, shape_name: str, mesh, averaging: str,
                    rounds: int, topology: str = "ring",
                    microbatches: int = 0, master_weights: bool = True,
                    ring_cache: bool = False, remat: bool = True):
    """Returns (fn, abstract_args) ready for jit(...).lower(*args)."""
    import dataclasses as _dc
    cfg = get_config(arch)
    if ring_cache:
        cfg = _dc.replace(cfg, ring_buffer_cache=True)
    shape = SHAPES[shape_name]
    wo = window_override_for(arch, shape_name)
    key = jax.random.PRNGKey(0)
    decentralized = averaging != "exact"
    mb = microbatches or TRAIN_MICROBATCHES.get(arch, 1)
    run = RunConfig(model=cfg, shape=shape,
                    averaging=AveragingConfig(mode=averaging, rounds=rounds,
                                              topology=topology),
                    optimizer="adam", param_dtype="bfloat16", microbatches=mb,
                    master_weights=master_weights, remat=remat)

    if shape.mode == "train":
        state_shapes = jax.eval_shape(lambda k: trainer.init_state(run, k), key)
        n_nodes = n_data_nodes(mesh)
        if decentralized:
            state_shapes = jax.eval_shape(
                partial(trainer.replicate_for_nodes, n_nodes=n_nodes), state_shapes)
        step, spec_fn = trainer.build_train_step(run, mesh)
        state_specs = spec_fn(state_shapes)
        state_abs = shlib.abstract_with_sharding(state_shapes, state_specs, mesh)
        batch_shapes = registry.input_specs(cfg, shape)
        if decentralized:
            batch_shapes = jax.eval_shape(
                partial(trainer.make_node_batch, n_nodes=n_nodes), batch_shapes)
        bspecs = shlib.batch_specs(batch_shapes, mesh, shape, node_axis=decentralized)
        batch_abs = shlib.abstract_with_sharding(batch_shapes, bspecs, mesh)
        out_shardings = (shlib.named(state_specs, mesh), None)
        fn = jax.jit(step, out_shardings=out_shardings, donate_argnums=0)
        return fn, (state_abs, batch_abs), run

    # inference paths share param setup
    params_shapes = jax.eval_shape(
        lambda k: registry.init_params(k, cfg, jnp.bfloat16, window_override=wo), key)
    # serving keeps weights model-sharded (latency); very large models (llama4)
    # additionally shard over data or they cannot fit next to the KV cache
    per_dev_gib = cfg.param_count() * 2 / mesh.shape["model"] / 2**30
    if per_dev_gib > 6.0:
        pspecs = shlib.zero1_specs(params_shapes, mesh)
    else:
        pspecs = shlib.param_specs(params_shapes, mesh)
    params_abs = shlib.abstract_with_sharding(params_shapes, pspecs, mesh)

    if shape.mode == "prefill":
        serve_shapes = jax.eval_shape(
            lambda: engine.init_serve(cfg, shape.global_batch, shape.seq_len,
                                      jnp.bfloat16, window_override=wo))
        sspec = engine.ServeState(
            shlib.cache_specs(serve_shapes.cache, mesh, shape),
            shlib.batch_specs(serve_shapes.last_tokens, mesh, shape),
            jax.sharding.PartitionSpec())
        serve_abs = shlib.abstract_with_sharding(serve_shapes, sspec, mesh)
        batch_shapes = registry.input_specs(cfg, shape)
        bspecs = shlib.batch_specs(batch_shapes, mesh, shape)
        batch_abs = shlib.abstract_with_sharding(batch_shapes, bspecs, mesh)

        def prefill_step(params, batch, st):
            return engine.prefill(params, cfg, batch, st, window_override=wo)

        fn = jax.jit(prefill_step, donate_argnums=2,
                     out_shardings=engine.ServeState(*jax.tree.map(
                         lambda s: shlib.named(s, mesh), tuple(sspec),
                         is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))))
        return fn, (params_abs, batch_abs, serve_abs), None

    # decode: ONE token against a seq_len cache
    serve_shapes = jax.eval_shape(
        lambda: engine.init_serve(cfg, shape.global_batch, shape.seq_len,
                                  jnp.bfloat16, window_override=wo))
    sspec = engine.ServeState(
        shlib.cache_specs(serve_shapes.cache, mesh, shape),
        shlib.batch_specs(serve_shapes.last_tokens, mesh, shape),
        jax.sharding.PartitionSpec())
    serve_abs = shlib.abstract_with_sharding(serve_shapes, sspec, mesh)

    def step(params, st):
        return engine.serve_step(params, cfg, st, window_override=wo)

    fn = jax.jit(step, donate_argnums=1)
    return fn, (params_abs, serve_abs), None


def run_dryrun(arch: str, shape_name: str, *, multi_pod: bool = False,
               averaging: str = "exact", rounds: int = 1, topology: str = "ring",
               microbatches: int = 0, ring_cache: bool = False,
               remat: bool = True, print_analysis: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    rules = shlib.activation_rules(mesh, shape,
                                   node_axis=(averaging != "exact"))
    if shape.mode in ("prefill", "decode"):
        rules.update(shlib.kv_rules(mesh, shape, cfg.num_kv_heads))
    from repro.models.transformer import build_plan
    if cfg.is_encdec:
        layer_trips = cfg.num_layers  # encoder and decoder scans both trip this
    else:
        period, n_rep, tail = build_plan(cfg, window_override_for(arch, shape_name))
        layer_trips = max(n_rep, 1)
    mb_eff = (microbatches or TRAIN_MICROBATCHES.get(arch, 1)) if shape.mode == "train" else 1
    rec = {"arch": arch, "shape": shape_name,
           "trips": {"microbatch": mb_eff, "layer_scan": layer_trips,
                     "scale": mb_eff * layer_trips},
           "microbatches": TRAIN_MICROBATCHES.get(arch, 1) if shape.mode == "train" else 0,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "averaging": averaging, "rounds": rounds,
           "mode": shape.mode,
           "params": cfg.param_count(), "active_params": cfg.active_param_count(),
           "window_override": window_override_for(arch, shape_name),
           "ring_cache": ring_cache}
    def compile_once(master: bool):
        with mesh_rules(mesh, rules):
            fn, args, _ = build_lowerable(arch, shape_name, mesh, averaging,
                                          rounds, topology,
                                          microbatches=microbatches,
                                          master_weights=master,
                                          ring_cache=ring_cache, remat=remat)
            t0 = time.time()
            lowered = fn.lower(*args)
            rec["lower_s"] = round(time.time() - t0, 2)
            t0 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t0, 2)
        return compiled

    rec["master_weights"] = shape.mode == "train"
    compiled = compile_once(rec["master_weights"])
    if shape.mode == "train":
        ma0 = compiled.memory_analysis()
        peak = (ma0.argument_size_in_bytes + ma0.output_size_in_bytes
                + ma0.temp_size_in_bytes - ma0.alias_size_in_bytes) / 2**30
        peak -= parse_cpu_upcasts(compiled.as_text()) / 2**30
        if peak > 15.5:
            # fp32 masters don't fit next to this model: fall back to bf16
            # weight updates and record the tradeoff (EXPERIMENTS.md §Dry-run)
            rec["master_weights"] = False
            compiled = compile_once(False)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_gib": ma.argument_size_in_bytes / 2**30,
        "output_gib": ma.output_size_in_bytes / 2**30,
        "temp_gib": ma.temp_size_in_bytes / 2**30,
        "alias_gib": ma.alias_size_in_bytes / 2**30,
        # live per-chip working set: args + outputs - aliased + temps
        "peak_gib": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                     + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30,
    }
    ca = cost_analysis_dict(compiled)
    rec["cost"] = {"flops": ca.get("flops", 0.0),
                   "bytes": ca.get("bytes accessed", 0.0)}
    hlo_text = compiled.as_text()
    rec["collectives"] = parse_collectives(hlo_text)
    upcast_gib = parse_cpu_upcasts(hlo_text) / 2**30
    rec["memory"]["cpu_upcast_gib"] = upcast_gib
    rec["memory"]["peak_tpu_adjusted_gib"] = rec["memory"]["peak_gib"] - upcast_gib
    if print_analysis:
        print(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--averaging", default="exact",
                    choices=["exact", "gossip", "hierarchical"])
    ap.add_argument("--rounds", type=int, default=1)
    ap.add_argument("--topology", default="ring")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--ring-cache", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    rec = run_dryrun(args.arch, args.shape, multi_pod=args.multi_pod,
                     averaging=args.averaging, rounds=args.rounds,
                     topology=args.topology, microbatches=args.microbatches,
                     ring_cache=args.ring_cache, remat=not args.no_remat)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
