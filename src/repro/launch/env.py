"""Launcher-level perf hygiene: process environment the XLA runtime reads at
import time (tcmalloc preload detection, XLA step-marker flags, TF log
noise), applied by `launch/train.py` and `launch/serve.py` BEFORE `import
jax`.

This module must therefore stay import-light: no jax, no repro modules that
pull jax in. Everything is pure env-dict manipulation so it is unit-testable
without touching the real process environment.

Escape hatch: pass `--no-env-tuning` on any launcher command line (peeked
from argv before argparse runs, because the tuning must land before the jax
import that argparse-time application would be too late for).
"""
from __future__ import annotations

import os
import sys
from typing import Dict, List, Optional

# Well-known tcmalloc locations (Debian/Ubuntu package paths). Preloading
# tcmalloc avoids glibc-malloc contention on the host-side staging threads;
# we can only *detect and report* here — LD_PRELOAD must be set before the
# process starts to affect it, so the launcher exports it for children and
# prints a hint when the current process runs without it.
TCMALLOC_PATHS = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc.so.4",
)

# Keep one-off large-allocation reports from spamming the log (the superstep
# staging buffers trip the default 1 GiB threshold constantly).
TCMALLOC_REPORT_THRESHOLD = "60000000000"

# --xla_step_marker_location=1: mark the outer while loop (the K-round
# superstep scan) as the step boundary for profiler alignment; 0 would mark
# the program entry. TPU-only: CPU/GPU XLA builds do not register the flag
# and hard-fail ("Check failed: Flags::Parse") on any unknown XLA_FLAGS
# entry, so it is injected only when a TPU runtime is detectable.
XLA_STEP_MARKER = "--xla_step_marker_location=1"


def tpu_available(env: Optional[Dict[str, str]] = None) -> bool:
    """Best-effort TPU detection WITHOUT importing jax (this module runs
    before the jax import). An explicit platform request (JAX_PLATFORMS /
    JAX_PLATFORM_NAME) is authoritative — a toolchain image can ship libtpu
    while pinning the cpu backend, whose XLA client rejects TPU flags.
    Without one, a libtpu install or a /dev accel device means jax will
    initialize the TPU plugin."""
    env = os.environ if env is None else env
    plat = env.get("JAX_PLATFORMS", env.get("JAX_PLATFORM_NAME", ""))
    if plat:
        return "tpu" in plat
    try:
        import importlib.util
        if importlib.util.find_spec("libtpu") is not None:
            return True
    except (ImportError, ValueError):
        pass
    return any(os.path.exists(f"/dev/accel{i}") for i in range(4))


def find_tcmalloc() -> Optional[str]:
    """First existing well-known tcmalloc shared object, or None."""
    for p in TCMALLOC_PATHS:
        if os.path.exists(p):
            return p
    return None


def tuned_env(env: Optional[Dict[str, str]] = None,
              tpu: Optional[bool] = None) -> Dict[str, str]:
    """Return the perf-hygiene mutations as a dict (pure; does not apply).

    * TF_CPP_MIN_LOG_LEVEL=4 — silence TF/XLA C++ info spam on the hot path
      (only if the user has not chosen a level).
    * XLA_FLAGS gains the step-marker flag on TPU runtimes (idempotent:
      never duplicated, user-provided flags preserved; CPU/GPU XLA rejects
      unknown flags outright, so non-TPU backends are left untouched).
    * TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD raised (if unset).
    * LD_PRELOAD set to a detected tcmalloc (if unset and one exists) so
      *child* processes get it; the current process is unaffected.

    `tpu` overrides the runtime detection (tests); None = auto-detect.
    """
    env = dict(os.environ if env is None else env)
    out: Dict[str, str] = {}
    if "TF_CPP_MIN_LOG_LEVEL" not in env:
        out["TF_CPP_MIN_LOG_LEVEL"] = "4"
    xla = env.get("XLA_FLAGS", "")
    tpu = tpu_available(env) if tpu is None else tpu
    if tpu and "--xla_step_marker_location" not in xla:
        out["XLA_FLAGS"] = f"{XLA_STEP_MARKER} {xla}".strip()
    if "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD" not in env:
        out["TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD"] = TCMALLOC_REPORT_THRESHOLD
    tc = find_tcmalloc()
    if tc is not None and not env.get("LD_PRELOAD"):
        out["LD_PRELOAD"] = tc
    return out


def wants_tuning(argv: Optional[List[str]] = None) -> bool:
    """The escape hatch, peeked from raw argv (pre-argparse)."""
    argv = sys.argv if argv is None else argv
    return "--no-env-tuning" not in argv


# ---------------------------------------------------------------------------
# Persistent compilation cache (docs/DESIGN.md §Fault-tolerant streaming)
# ---------------------------------------------------------------------------

def compilation_cache_env(cache_dir: str) -> Dict[str, str]:
    """Env mutations enabling jax's persistent compilation cache at
    `cache_dir` (pure; must land before `import jax`). Opt-in: a restarted
    run re-traces every (B, cohort) bucket-ladder signature, and without the
    cache each retrace pays a cold XLA compile — with it, restart cost is a
    disk hit per signature. Thresholds are zeroed so even the small
    supersteps of tests/benchmarks are cached (jax's defaults skip
    sub-second compiles)."""
    return {"JAX_COMPILATION_CACHE_DIR": cache_dir,
            "JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS": "0",
            "JAX_PERSISTENT_CACHE_MIN_ENTRY_SIZE_BYTES": "-1"}


def compilation_cache_dir_from_argv(argv: Optional[List[str]] = None
                                    ) -> Optional[str]:
    """Peek `--compilation-cache-dir PATH` (or `=PATH`) from raw argv —
    pre-argparse, because the cache location must be in the environment
    before the jax import that argparse-time application would be too late
    for."""
    argv = sys.argv if argv is None else argv
    for i, a in enumerate(argv):
        if a == "--compilation-cache-dir" and i + 1 < len(argv):
            return argv[i + 1]
        if a.startswith("--compilation-cache-dir="):
            return a.split("=", 1)[1]
    return None


def enable_compilation_cache(cache_dir: str) -> None:
    """In-process variant for code running after `import jax` (tests, the
    kill-and-resume workers): point the live jax config at `cache_dir` with
    the same zeroed thresholds."""
    import jax

    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)


def apply(env: Optional[Dict[str, str]] = None, *, echo: bool = False) -> Dict[str, str]:
    """Apply `tuned_env` to os.environ (or the given dict, for tests).
    Returns the mutations that were applied."""
    target = os.environ if env is None else env
    changes = tuned_env(dict(target))
    target.update(changes)
    if echo and changes:
        print("env tuning: " + " ".join(f"{k}={v}" for k, v in
                                        sorted(changes.items())),
              file=sys.stderr)
    if echo and find_tcmalloc() and "tcmalloc" not in os.environ.get(
            "LD_PRELOAD", ""):
        print("env tuning: tcmalloc present but not preloaded in THIS "
              "process (LD_PRELOAD only affects children); relaunch with "
              f"LD_PRELOAD={find_tcmalloc()} for host-thread malloc relief",
              file=sys.stderr)
    return changes


def apply_from_argv(argv: Optional[List[str]] = None) -> Dict[str, str]:
    """What launcher modules call at import time, before `import jax`:
    apply tuning unless `--no-env-tuning` is on the command line, and wire
    the persistent compilation cache when `--compilation-cache-dir` is.
    The cache is independent of the tuning escape hatch — it is opt-in via
    its own flag, not perf hygiene."""
    changes: Dict[str, str] = {}
    cache_dir = compilation_cache_dir_from_argv(argv)
    if cache_dir is not None:
        cc = compilation_cache_env(cache_dir)
        os.environ.update(cc)
        changes.update(cc)
    if wants_tuning(argv):
        changes.update(apply(echo=False))
    return changes
