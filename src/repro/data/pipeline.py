"""Host-side streaming input pipeline: glues a token/sample source to the
streaming governor (core.streaming) and the trainer.

The governor decides (B, mu) from the rate model; the pipeline yields
device-ready batches of exactly B samples per round, discarding mu, and tracks
t' (samples arrived) so training curves can be plotted against the paper's
x-axis.

Two streaming-engine extensions (see train.driver for the full picture):

* **Supersteps** — `next_superstep(K)` draws K governed rounds and stacks them
  on a new leading K axis, feeding the K-round `lax.scan` inside the jitted
  train step so dispatch and metric-fetch overhead is paid once per K rounds.
* **Async prefetch** — `DevicePrefetcher` runs the governed splitter in a
  background thread and stages the *next* superstep onto devices
  (`jax.device_put`) while the current one computes, overlapping host sample
  synthesis + H2D with device work (the compute/stream overlap of Fig. 4).
  Each staged item carries a counter snapshot so consumer-visible accounting
  (`samples_arrived`, `samples_discarded`, `rounds`) stays coherent with the
  batch being trained on, not with how far ahead the producer has run.
* **Checkpoint continuity** — the splitter's exact stream position
  (counter quad + PRNG bit-generator state + live plan) is exported by
  `splitter_state()` / restored by `load_splitter_state()` (both from
  `GovernedPlanMixin`). `train.snapshot` threads that snapshot through the
  prefetch ring via the `meta` hook, so a resumed run re-deals the
  staged-but-unconsumed supersteps a crash threw away instead of skipping
  those stream samples (docs/DESIGN.md §Fault-tolerant streaming).
* **Adaptive B** — `update_plan` may move B between the buckets of an adopted
  `core.rates.BucketLadder` mid-stream
  (docs/DESIGN.md §Adaptive batch buckets). The plan is latched once per
  superstep under a lock, so every
  superstep is dealt at a single width even when the swap lands from the
  consumer thread mid-production; supersteps already staged in the prefetch
  ring keep their old width (their samples were drawn — dropping them would
  lose stream samples) and drain through the pre-compiled old-bucket
  superstep, while each staged item's `meta` snapshot tells the consumer
  which plan dealt it.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Dict, Iterator, NamedTuple, Optional

import numpy as np

from repro.configs.base import StreamConfig
from repro.core.rates import BucketLadder, Plan, plan as make_plan
from repro.core.streaming import GovernedPlanMixin


class StreamCounters(NamedTuple):
    """Splitter accounting as of a specific round (the paper's t' bookkeeping)."""

    samples_arrived: int
    samples_consumed: int
    samples_discarded: int
    rounds: int


class StreamingPipeline(GovernedPlanMixin):
    def __init__(self, sample_fn: Callable[[np.random.Generator, int], Dict[str, np.ndarray]],
                 stream_cfg: StreamConfig, n_nodes: int, rounds_R: int, *,
                 batch: Optional[int] = None, horizon: Optional[float] = None,
                 ladder: Optional[BucketLadder] = None, seed: int = 0):
        if stream_cfg.streaming_rate > 0:
            self.plan = make_plan(stream_cfg, n_nodes, rounds_R, B=batch,
                                  horizon_samples=horizon)
        else:
            self.plan = Plan(B=batch or n_nodes, mu=max(stream_cfg.forced_mu, 0),
                             R=rounds_R, Re=float("inf"), regime="resourceful")
        self.stream_cfg = stream_cfg
        self.sample_fn = sample_fn
        self.n_nodes = n_nodes
        # adopt_ladder / update_plan / last_superstep_plan: GovernedPlanMixin
        self._init_plan_state(ladder, horizon)
        self._rng = np.random.default_rng(seed)
        self.samples_arrived = 0
        self.samples_consumed = 0
        self.samples_discarded = 0
        self.rounds = 0

    def counters(self) -> StreamCounters:
        return StreamCounters(self.samples_arrived, self.samples_consumed,
                              self.samples_discarded, self.rounds)

    def _round(self, plan: Plan) -> Dict[str, np.ndarray]:
        B, mu = plan.B, plan.mu
        batch = self.sample_fn(self._rng, B + mu)
        batch = {k: v[:B] for k, v in batch.items()}  # splitter discards mu
        self.samples_arrived += B + mu
        self.samples_consumed += B
        self.samples_discarded += mu
        self.rounds += 1
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        return self._round(self._latch_plan())

    def next_superstep(self, k: int) -> Dict[str, np.ndarray]:
        """Draw K governed rounds and stack them: leaves [K, B, ...]. The
        plan is latched once for the whole superstep, so a concurrent
        `update_plan` can never produce ragged round widths within one
        stack."""
        plan = self._latch_plan()
        rounds = [self._round(plan) for _ in range(k)]
        out = {key: np.stack([r[key] for r in rounds]) for key in rounds[0]}
        self._last_superstep_plan = plan
        return out


class _Stop:
    pass


class _Raise(NamedTuple):
    exc: BaseException


class DevicePrefetcher:
    """Depth-bounded prefetch ring between a host-side producer and the
    training loop: a daemon thread repeatedly calls `produce()` (host sample
    synthesis through the governed splitter) and `stage()` (sharded
    `jax.device_put`) so the next superstep's H2D transfer happens while the
    current superstep computes.

    `counters()` is sampled immediately after each produce; `__next__` returns
    the staged batch after adopting that snapshot into `self.counters`, so the
    consumer sees exactly the accounting a synchronous loop would have seen at
    that round — regardless of how far ahead the producer ring has run. The
    optional `meta` hook rides the same snapshot mechanism (e.g. the
    pipeline's `last_superstep_plan`, so the consumer knows which batch
    bucket a staged superstep was dealt at even while the ring drains items
    produced under a superseded plan).
    """

    def __init__(self, produce: Callable[[], Any], *,
                 stage: Optional[Callable[[Any], Any]] = None,
                 counters: Optional[Callable[[], StreamCounters]] = None,
                 meta: Optional[Callable[[], Any]] = None,
                 depth: int = 2):
        if depth < 1:
            raise ValueError("prefetch depth must be >= 1")
        self._produce = produce
        self._stage = stage or (lambda x: x)
        self._counters = counters or (lambda: None)
        self._meta = meta or (lambda: None)
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._final: Optional[Any] = None  # latched _Stop/_Raise terminal state
        self._undelivered: Optional[_Raise] = None  # error stranded by close()
        self._close_raised = False  # close() re-raises a pending error ONCE
        self._error_delivered = False  # __next__ already surfaced the error
        self.counters: Optional[StreamCounters] = None
        self.meta: Optional[Any] = None
        self._thread = threading.Thread(target=self._worker, daemon=True,
                                        name="device-prefetch")
        self._thread.start()

    def _put_stopaware(self, item: Any) -> bool:
        """Bounded-ring put that wakes promptly when close() sets the stop
        event (a plain blocking put could deadlock against close()'s drain).
        Returns False when the item could not be delivered because the ring
        was shut down first — terminal `_Raise` items must then be stashed,
        not dropped, or a pending producer error would vanish."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _worker(self) -> None:
        try:
            while not self._stop.is_set():
                try:
                    item = self._produce()
                except StopIteration:
                    break
                snap = self._counters()
                meta = self._meta()
                staged = self._stage(item)
                self._put_stopaware((staged, snap, meta))
        except BaseException as e:  # surface producer failures at the consumer
            if not self._put_stopaware(_Raise(e)):
                self._undelivered = _Raise(e)
            return
        self._put_stopaware(_Stop())

    def __iter__(self) -> "DevicePrefetcher":
        return self

    def __next__(self):
        # once the worker has signalled termination, nothing will ever be
        # enqueued again — keep resolving without touching the queue
        got = self._final if self._final is not None else self._q.get()
        if isinstance(got, _Stop):
            self._final = got
            raise StopIteration
        if isinstance(got, _Raise):
            self._final = got
            self._error_delivered = True
            raise got.exc
        staged, snap, meta = got
        if snap is not None:
            self.counters = snap
        if meta is not None:
            self.meta = meta
        return staged

    def _drain(self) -> Optional[_Raise]:
        """Empty the ring; return the last pending `_Raise` found, if any."""
        pending = None
        try:
            while True:
                item = self._q.get_nowait()
                if isinstance(item, _Raise):
                    pending = item
        except queue.Empty:
            pass
        return pending

    def close(self) -> None:
        """Shut the ring down. Never deadlocks against a worker blocked on a
        full ring (`_put_stopaware` polls the stop event), and re-raises a
        producer error that was still pending — staged in the ring or
        stranded by the shutdown itself — exactly once; an error already
        delivered through `__next__` is not raised again. Idempotent
        otherwise."""
        self._stop.set()
        # drain so a blocked producer can observe the stop event
        pending = self._drain()
        self._thread.join(timeout=5.0)
        # the worker may have enqueued (or stashed) its error between the
        # first drain and its exit
        pending = self._drain() or pending or self._undelivered
        self._undelivered = None
        if self._final is None:
            # nothing will ever be enqueued again: a post-close __next__
            # must not block on the dead worker
            self._final = pending if pending is not None else _Stop()
        if (pending is not None and not self._error_delivered
                and not self._close_raised):
            self._close_raised = True
            raise pending.exc

    def __enter__(self) -> "DevicePrefetcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
