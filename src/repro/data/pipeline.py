"""Host-side streaming input pipeline: glues a token/sample source to the
streaming governor (core.streaming) and the trainer.

The governor decides (B, mu) from the rate model; the pipeline yields
device-ready batches of exactly B samples per round, discarding mu, and tracks
t' (samples arrived) so training curves can be plotted against the paper's
x-axis.
"""
from __future__ import annotations

from typing import Callable, Dict, Iterator, Optional

import numpy as np

from repro.configs.base import StreamConfig
from repro.core.rates import Plan, plan as make_plan


class StreamingPipeline:
    def __init__(self, sample_fn: Callable[[np.random.Generator, int], Dict[str, np.ndarray]],
                 stream_cfg: StreamConfig, n_nodes: int, rounds_R: int, *,
                 batch: Optional[int] = None, horizon: Optional[float] = None,
                 seed: int = 0):
        if stream_cfg.streaming_rate > 0:
            self.plan = make_plan(stream_cfg, n_nodes, rounds_R, B=batch,
                                  horizon_samples=horizon)
        else:
            self.plan = Plan(B=batch or n_nodes, mu=max(stream_cfg.forced_mu, 0),
                             R=rounds_R, Re=float("inf"), regime="resourceful")
        self.sample_fn = sample_fn
        self.n_nodes = n_nodes
        self._rng = np.random.default_rng(seed)
        self.samples_arrived = 0
        self.rounds = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        B, mu = self.plan.B, self.plan.mu
        batch = self.sample_fn(self._rng, B + mu)
        batch = {k: v[:B] for k, v in batch.items()}  # splitter discards mu
        self.samples_arrived += B + mu
        self.rounds += 1
        return batch
