"""Synthetic language-model token streams (no external datasets offline).

A Zipfian unigram model with Markov bigram structure gives a stream whose loss
actually *decreases* under training (unlike uniform noise), which the e2e
example uses to train a ~100M model for a few hundred steps.
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


class MarkovTokenStream:
    """z_t' ~ P(. | z_{t'-1}) with a sparse random bigram table over a Zipf
    unigram prior. Stateless draws per (seq, position) via counter-based RNG."""

    def __init__(self, vocab_size: int, branch: int = 32, alpha: float = 1.2,
                 seed: int = 0):
        self.V = vocab_size
        rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        self.unigram = (ranks ** -alpha)
        self.unigram /= self.unigram.sum()
        # each token transitions to `branch` successors (hash-based, O(1) memory)
        self._a = rng.integers(1, 2**31 - 1)
        self._b = rng.integers(1, 2**31 - 1)
        self.branch = branch
        self._seed = seed

    def _succ(self, tok: np.ndarray, j: np.ndarray) -> np.ndarray:
        return ((tok * self._a + j * self._b + 12345) % (2**31 - 1)) % self.V

    def sample(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        toks = np.empty((batch, seq), dtype=np.int32)
        toks[:, 0] = rng.choice(self.V, size=batch, p=self.unigram)
        js = rng.integers(0, self.branch, size=(batch, seq))
        for t in range(1, seq):
            toks[:, t] = self._succ(toks[:, t - 1], js[:, t])
        return toks

    def batches(self, batch: int, seq: int, seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        rng = np.random.default_rng(seed)
        while True:
            toks = self.sample(rng, batch, seq + 1)
            yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
