"""The paper's synthetic data generators.

* Logistic-link labels with standard-normal features (Fig. 6): w* ~ N(0, I),
  x ~ N(0, I_d), Pr(y=1|x) = sigmoid(w*.x + b*).
* Conditional Gaussians (Fig. 9): mu_{+-1} ~ N(0, I), x | y ~ N(mu_y, sigma_x^2 I).
* Spiked / linear-spectrum covariance streams for the PCA experiments
  (Figs. 7-8): Sigma with lambda_1 = 1 and a prescribed eigengap.

All draws are stateless (key-in, samples-out) so stream steps can live inside
`lax.scan`.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.paper_logreg import LogRegConfig
from repro.configs.paper_pca import PCAConfig


class LogRegStream(NamedTuple):
    draw: Callable  # draw(key, n) -> (x [n,d], y [n])
    w_star: jax.Array  # [d+1] (weights, bias) ground truth


def make_logreg_stream(cfg: LogRegConfig) -> LogRegStream:
    key = jax.random.PRNGKey(cfg.seed)
    if cfg.generator == "logistic_link":
        kw, = jax.random.split(key, 1)
        w_star = jax.random.normal(kw, (cfg.dim + 1,))

        def draw(k, n):
            kx, ky = jax.random.split(k)
            x = jax.random.normal(kx, (n, cfg.dim))
            logits = x @ w_star[:-1] + w_star[-1]
            y = 2.0 * jax.random.bernoulli(ky, jax.nn.sigmoid(logits)) - 1.0
            return x, y

        return LogRegStream(draw, w_star)

    # conditional Gaussians (Fig. 9)
    km, = jax.random.split(key, 1)
    mus = jax.random.normal(km, (2, cfg.dim))  # rows: class -1, +1
    # Bayes-optimal linear separator for equal-covariance Gaussians:
    # w* = (mu_1 - mu_0)/sigma^2, b* = -(|mu_1|^2 - |mu_0|^2)/(2 sigma^2)
    w = (mus[1] - mus[0]) / cfg.noise_var
    b = -(jnp.sum(mus[1] ** 2) - jnp.sum(mus[0] ** 2)) / (2 * cfg.noise_var)
    w_star = jnp.concatenate([w, b[None]])

    def draw(k, n):
        ky, kx = jax.random.split(k)
        y = 2.0 * jax.random.bernoulli(ky, 0.5, (n,)) - 1.0
        mu = jnp.where(y[:, None] > 0, mus[1], mus[0])
        x = mu + jnp.sqrt(cfg.noise_var) * jax.random.normal(kx, (n, cfg.dim))
        return x, y

    return LogRegStream(draw, w_star)


class PCAStream(NamedTuple):
    draw: Callable  # draw(key, n) -> z [n, d]
    cov: jax.Array  # [d, d]
    top_eigvec: jax.Array  # [d]
    lambda1: float
    eigengap: float
    sqrt_cov: jax.Array  # [d, d] symmetric square root of cov


def make_pca_stream(cfg: PCAConfig) -> PCAStream:
    key = jax.random.PRNGKey(cfg.seed)
    d = cfg.dim
    lam2 = cfg.lambda1 - cfg.eigengap
    if cfg.spectrum == "power":
        rest = lam2 * (jnp.arange(1, d) ** -0.7)
    else:
        rest = jnp.linspace(lam2, 0.01 * cfg.lambda1, d - 1)
    evals = jnp.concatenate([jnp.array([cfg.lambda1]), rest])
    q, _ = jnp.linalg.qr(jax.random.normal(key, (d, d)))
    cov = (q * evals) @ q.T
    sqrt_cov = (q * jnp.sqrt(evals)) @ q.T

    def draw(k, n):
        return jax.random.normal(k, (n, d)) @ sqrt_cov

    return PCAStream(draw, cov, q[:, 0], float(cfg.lambda1),
                     float(cfg.eigengap), sqrt_cov)


def make_pca_host_sampler(stream: PCAStream) -> Callable:
    """Host-side splitter source for the streaming engine: the same covariance
    stream as `PCAStream.draw`, but numpy-generated (np.random.Generator in,
    {"z": [n, d]} dict out) so `data.pipeline.StreamingPipeline` and the
    `DevicePrefetcher` thread can synthesize samples off the device's critical
    path (the draws are NOT the same sequence as the threefry-keyed device
    draw — same distribution, different entropy source)."""
    import numpy as np

    sqrt_cov = np.asarray(stream.sqrt_cov, np.float32)
    d = sqrt_cov.shape[0]

    def sample(rng: "np.random.Generator", n: int):
        z = rng.standard_normal((n, d), dtype=np.float32) @ sqrt_cov
        return {"z": z}

    return sample
