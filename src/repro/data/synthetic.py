"""The paper's synthetic data generators.

* Logistic-link labels with standard-normal features (Fig. 6): w* ~ N(0, I),
  x ~ N(0, I_d), Pr(y=1|x) = sigmoid(w*.x + b*).
* Conditional Gaussians (Fig. 9): mu_{+-1} ~ N(0, I), x | y ~ N(mu_y, sigma_x^2 I).
* Spiked / linear-spectrum covariance streams for the PCA experiments
  (Figs. 7-8): Sigma with lambda_1 = 1 and a prescribed eigengap.

All draws are stateless (key-in, samples-out) so stream steps can live inside
`lax.scan`.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.paper_logreg import LogRegConfig
from repro.configs.paper_pca import PCAConfig


class LogRegStream(NamedTuple):
    draw: Callable  # draw(key, n) -> (x [n,d], y [n])
    w_star: jax.Array  # [d+1] (weights, bias) ground truth


def make_logreg_stream(cfg: LogRegConfig) -> LogRegStream:
    key = jax.random.PRNGKey(cfg.seed)
    if cfg.generator == "logistic_link":
        kw, = jax.random.split(key, 1)
        w_star = jax.random.normal(kw, (cfg.dim + 1,))

        def draw(k, n):
            kx, ky = jax.random.split(k)
            x = jax.random.normal(kx, (n, cfg.dim))
            logits = x @ w_star[:-1] + w_star[-1]
            y = 2.0 * jax.random.bernoulli(ky, jax.nn.sigmoid(logits)) - 1.0
            return x, y

        return LogRegStream(draw, w_star)

    # conditional Gaussians (Fig. 9)
    km, = jax.random.split(key, 1)
    mus = jax.random.normal(km, (2, cfg.dim))  # rows: class -1, +1
    # Bayes-optimal linear separator for equal-covariance Gaussians:
    # w* = (mu_1 - mu_0)/sigma^2, b* = -(|mu_1|^2 - |mu_0|^2)/(2 sigma^2)
    w = (mus[1] - mus[0]) / cfg.noise_var
    b = -(jnp.sum(mus[1] ** 2) - jnp.sum(mus[0] ** 2)) / (2 * cfg.noise_var)
    w_star = jnp.concatenate([w, b[None]])

    def draw(k, n):
        ky, kx = jax.random.split(k)
        y = 2.0 * jax.random.bernoulli(ky, 0.5, (n,)) - 1.0
        mu = jnp.where(y[:, None] > 0, mus[1], mus[0])
        x = mu + jnp.sqrt(cfg.noise_var) * jax.random.normal(kx, (n, cfg.dim))
        return x, y

    return LogRegStream(draw, w_star)


class PCAStream(NamedTuple):
    draw: Callable  # draw(key, n) -> z [n, d]
    cov: jax.Array  # [d, d]
    top_eigvec: jax.Array  # [d]
    lambda1: float
    eigengap: float
    sqrt_cov: jax.Array  # [d, d] symmetric square root of cov


def make_pca_stream(cfg: PCAConfig) -> PCAStream:
    key = jax.random.PRNGKey(cfg.seed)
    d = cfg.dim
    lam2 = cfg.lambda1 - cfg.eigengap
    if cfg.spectrum == "power":
        rest = lam2 * (jnp.arange(1, d) ** -0.7)
    else:
        rest = jnp.linspace(lam2, 0.01 * cfg.lambda1, d - 1)
    evals = jnp.concatenate([jnp.array([cfg.lambda1]), rest])
    q, _ = jnp.linalg.qr(jax.random.normal(key, (d, d)))
    cov = (q * evals) @ q.T
    sqrt_cov = (q * jnp.sqrt(evals)) @ q.T

    def draw(k, n):
        return jax.random.normal(k, (n, d)) @ sqrt_cov

    return PCAStream(draw, cov, q[:, 0], float(cfg.lambda1),
                     float(cfg.eigengap), sqrt_cov)


def make_pca_host_sampler(stream: PCAStream) -> Callable:
    """Host-side splitter source for the streaming engine: the same covariance
    stream as `PCAStream.draw`, but numpy-generated (np.random.Generator in,
    {"z": [n, d]} dict out) so `data.pipeline.StreamingPipeline` and the
    `DevicePrefetcher` thread can synthesize samples off the device's critical
    path (the draws are NOT the same sequence as the threefry-keyed device
    draw — same distribution, different entropy source)."""
    import numpy as np

    sqrt_cov = np.asarray(stream.sqrt_cov, np.float32)
    d = sqrt_cov.shape[0]

    def sample(rng: "np.random.Generator", n: int):
        z = rng.standard_normal((n, d), dtype=np.float32) @ sqrt_cov
        return {"z": z}

    return sample


# ---------------------------------------------------------------------------
# Non-IID streams (scenario harness — docs/DESIGN.md §Scenario harness)
# ---------------------------------------------------------------------------


class DriftingPCAStream(NamedTuple):
    """Host-side PCA stream whose top eigenvector rotates over time."""

    sample: Callable  # (np rng, n) -> {"z": [n, d]}
    top_eigvec_at: Callable  # t_samples -> [d] unit vector (ground truth)
    cov_at: Callable  # t_samples -> [d, d]
    rate: float  # radians of rotation per sample drawn
    lambda1: float
    eigengap: float


def make_drifting_pca_sampler(cfg: PCAConfig, *, rate: float,
                              ) -> DriftingPCAStream:
    """Drifting-covariance PCA stream for `data.pipeline.StreamingPipeline`:
    the spectrum (lambda_1, eigengap, tail) is `make_pca_stream`'s, but the
    top eigenvector rotates in the fixed plane spanned by the first two
    eigenvectors at `rate` radians per sample drawn — a stateful host sampler
    (the splitter produces sequentially, so the drift clock is deterministic
    for a fixed seed regardless of prefetch depth; discarded mu samples
    advance it too, matching the paper's sample budget t').

    Deviation from the stationary model: the covariance is held constant
    *within* each drawn batch (piecewise-constant drift at batch
    granularity); `top_eigvec_at(t)` / `cov_at(t)` give the ground truth at
    sample count t for the statistical tests."""
    import numpy as np

    base = make_pca_stream(cfg)
    cov0 = np.asarray(base.cov, np.float64)
    evals, q = np.linalg.eigh(cov0)
    order = np.argsort(evals)[::-1]
    evals, q = np.maximum(evals[order], 0.0), q[:, order]
    d = q.shape[0]

    def _basis_at(t: float):
        theta = rate * float(t)
        c, s = np.cos(theta), np.sin(theta)
        qt = q.copy()
        qt[:, 0] = c * q[:, 0] + s * q[:, 1]
        qt[:, 1] = -s * q[:, 0] + c * q[:, 1]
        return qt

    def top_eigvec_at(t: float):
        return _basis_at(t)[:, 0]

    def cov_at(t: float):
        qt = _basis_at(t)
        return (qt * evals) @ qt.T

    state = {"t": 0}

    def sample(rng: "np.random.Generator", n: int):
        qt = _basis_at(state["t"])
        state["t"] += n
        sqrt_cov = ((qt * np.sqrt(evals)) @ qt.T).astype(np.float32)
        z = rng.standard_normal((n, d), dtype=np.float32) @ sqrt_cov
        return {"z": z}

    return DriftingPCAStream(sample, top_eigvec_at, cov_at, float(rate),
                             float(cfg.lambda1), float(cfg.eigengap))


class SkewedLogRegStream(NamedTuple):
    """Label-skewed per-node logreg stream (host-side, conditional Gaussians)."""

    sample: Callable  # (np rng, n) -> {"x": [n, d], "y": [n] in {-1, +1}}
    w_star: Any  # [d+1] Bayes-optimal (weights, bias) under the POOLED mixture
    node_pos_prob: Any  # [n_nodes] per-node P(y = +1)
    alpha: float
    n_nodes: int


def make_skewed_logreg_sampler(cfg: LogRegConfig, n_nodes: int, *,
                               alpha: float, seed: Optional[int] = None,
                               ) -> SkewedLogRegStream:
    """Label-skewed logreg partitions: each node's class-(+1) proportion is an
    independent draw p_i ~ Beta(alpha, alpha) — the 2-class Dirichlet(alpha)
    partition standard in the federated non-IID literature (small alpha =
    severe skew, large alpha -> IID). Features are the paper's Fig. 9
    conditional Gaussians around fixed class means.

    Every draw of n samples lays the nodes out as *contiguous blocks* (node i
    owns samples [i*n/N, (i+1)*n/N)), exactly the split
    `train.trainer.make_node_batch`'s contiguous reshape applies — so with
    mu = 0 and B a multiple of n_nodes, node i's device batch is node i's
    skewed partition. (A governed mu > 0 draws B+mu and keeps the first B,
    shifting the block boundaries; the scenario cells that assert per-node
    skew therefore run ungoverned — docs/DESIGN.md §Scenario harness.)"""
    import numpy as np

    if n_nodes < 1:
        raise ValueError(f"need at least one node: {n_nodes}")
    if alpha <= 0:
        raise ValueError(f"Dirichlet concentration must be > 0: {alpha}")
    rng0 = np.random.default_rng(cfg.seed if seed is None else seed)
    mus = rng0.standard_normal((2, cfg.dim))  # rows: class -1, +1
    # alpha = inf is the exact IID limit (Beta(inf, inf) -> point mass at 1/2)
    p = (np.full(n_nodes, 0.5) if np.isinf(alpha)
         else rng0.beta(alpha, alpha, size=n_nodes))
    # Bayes-optimal separator of the pooled (label-balanced in expectation)
    # mixture — same form as `make_logreg_stream`'s cond_gauss path
    prior = float(np.mean(p))
    w = (mus[1] - mus[0]) / cfg.noise_var
    b = (-(np.sum(mus[1] ** 2) - np.sum(mus[0] ** 2)) / (2 * cfg.noise_var)
         + np.log(prior / max(1.0 - prior, 1e-12)))
    w_star = np.concatenate([w, [b]]).astype(np.float32)
    sig = np.sqrt(cfg.noise_var)

    def sample(rng: "np.random.Generator", n: int):
        xs, ys = [], []
        for i, idx in enumerate(np.array_split(np.arange(n), n_nodes)):
            c = len(idx)
            y = np.where(rng.random(c) < p[i], 1.0, -1.0).astype(np.float32)
            mu = np.where(y[:, None] > 0, mus[1], mus[0])
            x = mu + sig * rng.standard_normal((c, cfg.dim))
            xs.append(x.astype(np.float32))
            ys.append(y)
        return {"x": np.concatenate(xs), "y": np.concatenate(ys)}

    return SkewedLogRegStream(sample, w_star, p.astype(np.float64),
                              float(alpha), n_nodes)
