from repro.optim.optimizers import (  # noqa: F401
    OptState,
    init_optimizer,
    make_optimizer,
    polyak_init,
    polyak_update,
)
