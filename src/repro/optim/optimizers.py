"""Pytree optimizers for the framework-scale trainer: SGD(+momentum), Adam, and
the paper's accelerated SGD (eqs. 9-11, Lan's method) generalized to pytrees,
plus stepsize-weighted Polyak-Ruppert iterate averaging (eq. 7).

All optimizers keep fp32 master state regardless of parameter dtype.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Tree = Any


class OptState(NamedTuple):
    step: jax.Array
    m: Tree  # momentum / first moment / Nesterov v
    v: Tree  # second moment (Adam) or unused
    master: Tree = ()  # fp32 master weights (mixed-precision training)
    # per-node error-feedback residuals for compressed gossip
    # (`core.averaging.ef_average_and_error`); () unless
    # AveragingConfig.error_feedback is on. The update rules never touch it —
    # the trainer re-attaches the mixed residual via `_replace` each step.
    ef_residual: Tree = ()


def _zeros_like_f32(params: Tree) -> Tree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def init_optimizer(name: str, params: Tree, *, master_weights: bool = False,
                   error_feedback: bool = False) -> OptState:
    master = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
              if master_weights else ())
    # EF residuals live in the gradient dtype: they pack alongside the
    # gradient buffers under the same PackSpec dtype grouping
    ef = (jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
          if error_feedback else ())
    if name == "accel":
        # v iterate initialized at params (fp32)
        v0 = jax.tree.map(lambda p: p.astype(jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32), v0, _zeros_like_f32(params),
                        master, ef)
    return OptState(jnp.zeros((), jnp.int32), _zeros_like_f32(params),
                    _zeros_like_f32(params), master, ef)


def make_optimizer(name: str, lr: float, *, weight_decay: float = 0.0,
                   b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                   momentum: float = 0.0,
                   lr_schedule: Callable | None = None) -> Callable:
    """Returns update(grads, state, params) -> (new_params, new_state)."""

    def lr_at(step):
        base = lr_schedule(step) if lr_schedule is not None else 1.0
        return lr * base

    if name == "sgd":
        def update(grads, state: OptState, params):
            step = state.step + 1
            eta = lr_at(step)
            if momentum:
                m = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                                 state.m, grads)
            else:
                m = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            new_params = jax.tree.map(
                lambda p, mm: (p.astype(jnp.float32) - eta * (mm + weight_decay * p.astype(jnp.float32))).astype(p.dtype),
                params, m)
            return new_params, OptState(step, m if momentum else state.m, state.v, state.master)
        return update

    if name == "adam":
        def update(grads, state: OptState, params):
            step = state.step + 1
            eta = lr_at(step)
            m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                             state.m, grads)
            v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                             state.v, grads)
            bc1 = 1 - b1 ** step.astype(jnp.float32)
            bc2 = 1 - b2 ** step.astype(jnp.float32)

            def upd(p, mm, vv):
                mhat = mm / bc1
                vhat = vv / bc2
                delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
                return p.astype(jnp.float32) - eta * delta

            if state.master != ():
                # mixed precision: accumulate into fp32 masters, cast out
                new_master = jax.tree.map(upd, state.master, m, v)
                new_params = jax.tree.map(lambda nm, p: nm.astype(p.dtype),
                                          new_master, params)
                return new_params, OptState(step, m, v, new_master)
            new_params = jax.tree.map(
                lambda p, mm, vv: upd(p, mm, vv).astype(p.dtype), params, m, v)
            return new_params, OptState(step, m, v)
        return update

    if name == "accel":
        # Paper eqs. (9)-(11) with beta_t = (t+1)/2: gradients must be evaluated
        # at u_t; the trainer calls `accel_point` first.
        def update(grads, state: OptState, params):
            step = state.step + 1
            t = step.astype(jnp.float32)
            beta = (t + 1.0) / 2.0
            eta = lr_at(step)
            v_new = jax.tree.map(
                lambda v, g: v - eta * g.astype(jnp.float32), state.m, grads)  # eq. 10 at u
            new_params = jax.tree.map(
                lambda w, v: (v / beta + (1 - 1 / beta) * w.astype(jnp.float32)).astype(w.dtype),
                params, v_new)  # eq. 11
            return new_params, OptState(step, v_new, state.v, state.master)
        return update

    raise ValueError(f"unknown optimizer {name!r}")


def accel_point(state: OptState, params: Tree) -> Tree:
    """u_t = beta^-1 v_t + (1-beta^-1) w_t (eq. 9): where accelerated SGD takes
    its gradient."""
    t = (state.step + 1).astype(jnp.float32)
    beta = (t + 1.0) / 2.0
    return jax.tree.map(
        lambda v, w: (v / beta + (1 - 1 / beta) * w.astype(jnp.float32)).astype(w.dtype),
        state.m, params)


class PolyakState(NamedTuple):
    eta_sum: jax.Array
    avg: Tree


def polyak_init(params: Tree) -> PolyakState:
    return PolyakState(jnp.zeros(()), jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def polyak_update(state: PolyakState, params: Tree, eta: jax.Array) -> PolyakState:
    s = state.eta_sum + eta
    avg = jax.tree.map(
        lambda a, p: (state.eta_sum * a + eta * p.astype(jnp.float32)) / s,
        state.avg, params)
    return PolyakState(s, avg)
