"""Minimal dependency-free checkpointing: pytree -> a directory with one .npy
per leaf plus a JSON manifest (paths, dtypes, optimizer step, RunConfig echo).

Arrays are fetched with jax.device_get (works for sharded arrays on any
addressable mesh) and restored with the caller-provided sharding function, so
restore works across mesh changes — the manifest stores only logical shapes.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Callable, Optional

import jax
import numpy as np

Tree = Any

_SEP = "::"


def _flatten(tree: Tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


def save(path: str, tree: Tree, *, step: int = 0, meta: Optional[dict] = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    manifest = {"step": step, "meta": meta or {}, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "_") + ".npy"
        np.save(os.path.join(path, fname), arr)
        manifest["leaves"][key] = {"file": fname, "dtype": str(arr.dtype),
                                   "shape": list(arr.shape)}
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def restore(path: str, like: Tree, *, put: Optional[Callable] = None) -> Tree:
    """Restore into the structure of `like`. `put(key, np_array)` may place each
    leaf onto devices (e.g. with a NamedSharding); default: jnp.asarray."""
    import jax.numpy as jnp

    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like = _flatten(like)
    leaves_out = {}
    for key in flat_like:
        ent = manifest["leaves"][key]
        arr = np.load(os.path.join(path, ent["file"]))
        leaves_out[key] = put(key, arr) if put else jnp.asarray(arr)
    # rebuild in the order of `like`'s flatten
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    keys = [_SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            for path, _ in paths_leaves]
    return jax.tree_util.tree_unflatten(treedef, [leaves_out[k] for k in keys])


def loaded_step(path: str) -> int:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["step"]
