"""Minimal dependency-free checkpointing: pytree -> a directory with one .npy
per leaf plus a JSON manifest (paths, dtypes, CRC32 checksums, optimizer step,
RunConfig echo).

Arrays are fetched with jax.device_get (works for sharded arrays on any
addressable mesh) and restored with the caller-provided sharding function, so
restore works across mesh changes — the manifest stores only logical shapes.

On top of the single-directory save/restore, this module provides the
multi-checkpoint layout the async snapshot subsystem (`train.snapshot`) uses:
step-numbered subdirectories (`step_00000042/`), `newest_valid` scanning that
skips torn or corrupt checkpoints, and `prune` retention of the last k.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import time
import zlib
from typing import Any, Callable, List, Optional

import jax
import numpy as np

Tree = Any

_SEP = "::"
_STEP_DIR_RE = re.compile(r"^step_(\d{8})$")


def _flatten(tree: Tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


def _crc32(arr: np.ndarray) -> int:
    """Content checksum of a leaf: CRC32 over the raw array bytes (C order).
    Computed on the exact bytes handed to np.save, so a torn write, a
    bit-rotted block, or a truncated file fails verification on restore."""
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _save_leaf(path: str, arr: np.ndarray, *, retries: int = 0,
               backoff_s: float = 0.05) -> None:
    """np.save with retry-with-backoff for transient OSErrors (full disk
    being drained, an NFS blip): up to `retries` retries with exponential
    backoff, then the last error propagates. A partial file from a failed
    attempt is overwritten by the retry (np.save truncates)."""
    attempt = 0
    while True:
        try:
            np.save(path, arr)
            return
        except OSError:
            if attempt >= retries:
                raise
            time.sleep(backoff_s * (2 ** attempt))
            attempt += 1


def _live_files(path: str) -> set:
    """Leaf files the current durable manifest references (empty if none).
    A re-save must never write over these: they back the checkpoint that
    stays restorable if the new save crashes partway."""
    try:
        return {ent["file"] for ent in load_manifest(path)["leaves"].values()}
    except Exception:
        return set()


def save(path: str, tree: Tree, *, step: int = 0, meta: Optional[dict] = None,
         retries: int = 0, backoff_s: float = 0.05) -> None:
    """Crash-safe save: every leaf .npy is written BEFORE the manifest, and
    the manifest lands via temp-file + atomic `os.replace` — so a checkpoint
    directory either has a manifest whose leaves are all complete on disk, or
    no (new) manifest at all. Leaf files are step-versioned and never reuse a
    name the live manifest references, so an in-place re-save cannot clobber
    the previous checkpoint's data mid-write: the manifest replace atomically
    switches which leaf set is live. A crash mid-save can leave orphan leaf
    files but never a manifest pointing at missing/torn arrays. Once the new
    manifest is durable, leaf files it does not reference (this save's
    predecessors, or debris from a crashed save) are deleted.

    Each leaf entry carries a CRC32 of the array bytes; `restore` verifies
    them so silent corruption fails loudly with the leaf name. Transient
    leaf-write OSErrors are retried `retries` times with exponential
    backoff (`_save_leaf`)."""
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    live = _live_files(path)
    manifest = {"step": step, "meta": meta or {}, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        base = key.replace("/", "_") + f".{step:08d}"
        fname = base + ".npy"
        g = 0
        while fname in live:
            g += 1
            fname = f"{base}.g{g}.npy"
        _save_leaf(os.path.join(path, fname), arr, retries=retries,
                   backoff_s=backoff_s)
        manifest["leaves"][key] = {"file": fname, "dtype": str(arr.dtype),
                                   "shape": list(arr.shape),
                                   "crc32": _crc32(arr)}
    tmp = os.path.join(path, "manifest.json.tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(path, "manifest.json"))
    _clean_orphans(path, manifest)


def _clean_orphans(path: str, manifest: dict) -> None:
    """Delete leaf files the durable manifest does not reference — the
    debris a previous crashed save documented itself as leaving. Runs only
    after a successful manifest replace, so everything removed is provably
    unreachable; removal errors are ignored (orphans are harmless, just
    disk)."""
    referenced = {ent["file"] for ent in manifest["leaves"].values()}
    try:
        entries = os.listdir(path)
    except OSError:
        return
    for fname in entries:
        if fname.endswith(".npy") and fname not in referenced:
            try:
                os.remove(os.path.join(path, fname))
            except OSError:
                pass


def load_manifest(path: str) -> dict:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)


def restore(path: str, like: Tree, *, put: Optional[Callable] = None,
            verify: bool = True) -> Tree:
    """Restore into the structure of `like`. `put(key, np_array)` may place each
    leaf onto devices (e.g. with a NamedSharding); default: jnp.asarray.

    A structure mismatch between `like` and the checkpoint raises ValueError
    naming the missing and extra leaf keys — a renamed optimizer field or a
    stale checkpoint fails with the actual diff, not a bare KeyError. With
    `verify` (default), each loaded leaf is checked against its manifest
    CRC32: a torn or bit-rotted file raises ValueError naming the leaf
    instead of silently loading garbage."""
    import jax.numpy as jnp

    manifest = load_manifest(path)
    flat_like = _flatten(like)
    want, have = set(flat_like), set(manifest["leaves"])
    if want != have:
        missing = sorted(want - have)
        extra = sorted(have - want)
        raise ValueError(
            f"checkpoint at {path!r} does not match the restore target: "
            f"missing from checkpoint: {missing or 'none'}; "
            f"present in checkpoint but not in target: {extra or 'none'}")
    leaves_out = {}
    for key in flat_like:
        ent = manifest["leaves"][key]
        fpath = os.path.join(path, ent["file"])
        try:
            arr = np.load(fpath)
        except Exception as e:
            raise ValueError(
                f"checkpoint leaf {key!r} ({ent['file']}) at {path!r} is "
                f"unreadable: {e}") from e
        if verify and "crc32" in ent and _crc32(arr) != ent["crc32"]:
            raise ValueError(
                f"checkpoint leaf {key!r} ({ent['file']}) at {path!r} failed "
                f"its CRC32 check: the file is torn or corrupt")
        leaves_out[key] = put(key, arr) if put else jnp.asarray(arr)
    # rebuild in the order of `like`'s flatten
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    keys = [_SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            for path, _ in paths_leaves]
    return jax.tree_util.tree_unflatten(treedef, [leaves_out[k] for k in keys])


def loaded_step(path: str) -> int:
    return load_manifest(path)["step"]


# ---------------------------------------------------------------------------
# Multi-checkpoint layout (used by train.snapshot)
# ---------------------------------------------------------------------------


def step_dir(root: str, step: int) -> str:
    """The step-numbered checkpoint subdirectory for a snapshot at `step`."""
    return os.path.join(root, f"step_{step:08d}")


def list_steps(root: str) -> List[int]:
    """Ascending snapshot steps present under `root` (manifest or not)."""
    try:
        entries = os.listdir(root)
    except OSError:
        return []
    steps = []
    for e in entries:
        m = _STEP_DIR_RE.match(e)
        if m and os.path.isdir(os.path.join(root, e)):
            steps.append(int(m.group(1)))
    return sorted(steps)


def is_valid(path: str) -> bool:
    """A checkpoint directory is valid iff its manifest parses and every
    referenced leaf file passes its CRC32 check — i.e. `restore` would
    succeed structurally. Cheap enough to scan at resume time (one read per
    leaf) and strict enough that a SIGKILL mid-save can never be selected."""
    try:
        manifest = load_manifest(path)
        for key, ent in manifest["leaves"].items():
            arr = np.load(os.path.join(path, ent["file"]))
            if "crc32" in ent and _crc32(arr) != ent["crc32"]:
                return False
    except Exception:
        return False
    return True


def newest_valid(root: str) -> Optional[str]:
    """The newest *valid* checkpoint directory under `root`, or None. A torn
    newest checkpoint (killed mid-save: missing manifest, or corrupt leaves)
    falls back to the next-newest valid one — resume never loads garbage."""
    for step in reversed(list_steps(root)):
        path = step_dir(root, step)
        if is_valid(path):
            return path
    return None


def prune(root: str, keep_last: int) -> List[str]:
    """Retention: delete all but the newest `keep_last` step directories.
    Returns the removed paths. Never removes the newest valid checkpoint
    (even if older than `keep_last` invalid ones sit above it)."""
    import shutil

    if keep_last < 1:
        raise ValueError(f"keep_last must be >= 1: {keep_last}")
    steps = list_steps(root)
    if len(steps) <= keep_last:
        return []
    keep = set(steps[-keep_last:])
    newest = newest_valid(root)
    removed = []
    for step in steps:
        path = step_dir(root, step)
        if step in keep or path == newest:
            continue
        try:
            shutil.rmtree(path)
            removed.append(path)
        except OSError:
            pass
    return removed
