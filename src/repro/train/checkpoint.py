"""Minimal dependency-free checkpointing: pytree -> a directory with one .npy
per leaf plus a JSON manifest (paths, dtypes, optimizer step, RunConfig echo).

Arrays are fetched with jax.device_get (works for sharded arrays on any
addressable mesh) and restored with the caller-provided sharding function, so
restore works across mesh changes — the manifest stores only logical shapes.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Callable, Optional

import jax
import numpy as np

Tree = Any

_SEP = "::"


def _flatten(tree: Tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


def save(path: str, tree: Tree, *, step: int = 0, meta: Optional[dict] = None) -> None:
    """Crash-safe save: every leaf .npy is written BEFORE the manifest, and
    the manifest lands via temp-file + atomic `os.replace` — so a checkpoint
    directory either has a manifest whose leaves are all complete on disk, or
    no (new) manifest at all. A crash mid-save can leave orphan leaf files
    but never a manifest pointing at missing/truncated arrays, and an
    overwrite of an existing checkpoint keeps the old manifest valid until
    the new one is fully durable."""
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    manifest = {"step": step, "meta": meta or {}, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "_") + ".npy"
        np.save(os.path.join(path, fname), arr)
        manifest["leaves"][key] = {"file": fname, "dtype": str(arr.dtype),
                                   "shape": list(arr.shape)}
    tmp = os.path.join(path, "manifest.json.tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(path, "manifest.json"))


def restore(path: str, like: Tree, *, put: Optional[Callable] = None) -> Tree:
    """Restore into the structure of `like`. `put(key, np_array)` may place each
    leaf onto devices (e.g. with a NamedSharding); default: jnp.asarray.

    A structure mismatch between `like` and the checkpoint raises ValueError
    naming the missing and extra leaf keys — a renamed optimizer field or a
    stale checkpoint fails with the actual diff, not a bare KeyError."""
    import jax.numpy as jnp

    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like = _flatten(like)
    want, have = set(flat_like), set(manifest["leaves"])
    if want != have:
        missing = sorted(want - have)
        extra = sorted(have - want)
        raise ValueError(
            f"checkpoint at {path!r} does not match the restore target: "
            f"missing from checkpoint: {missing or 'none'}; "
            f"present in checkpoint but not in target: {extra or 'none'}")
    leaves_out = {}
    for key in flat_like:
        ent = manifest["leaves"][key]
        arr = np.load(os.path.join(path, ent["file"]))
        leaves_out[key] = put(key, arr) if put else jnp.asarray(arr)
    # rebuild in the order of `like`'s flatten
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    keys = [_SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            for path, _ in paths_leaves]
    return jax.tree_util.tree_unflatten(treedef, [leaves_out[k] for k in keys])


def loaded_step(path: str) -> int:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["step"]
