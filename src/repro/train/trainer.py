"""Framework-scale streaming trainer: builds the sharded train_step for a
RunConfig, with the paper's averaging mode as a first-class switch.

Two representations:

* **exact** (paper-faithful DMB, Alg. 1): standard data-parallel pjit. The mean
  loss over the global batch makes XLA emit the AllReduce of gradients — exactly
  the paper's exact-averaging step 7 (B = global batch, N = data-parallel size,
  local mini-batch B/N per node).
* **gossip / hierarchical** (D-SGD, Algs. 3-4 / TPU adaptation): decentralized
  parameters. Every leaf carries a leading node axis sharded over the data mesh
  axes; per-node gradients are computed with vmap and mixed by
  `core.averaging.average_gradients` (R rounds of collective-permute consensus);
  each node applies its own optimizer update. Node disagreement is observable
  via `core.averaging.consensus_error`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig
from repro.core.averaging import (average_and_error, ef_average_and_error,
                                  make_gossip_mix, resolve_packed)
from repro.core.mixing import ScheduledMixOp
from repro.core.quantize import STOCHASTIC
from repro.launch import sharding as shlib
from repro.launch.mesh import data_axes, n_data_nodes
from repro.models import registry
from repro.models.common import mesh_rules
from repro.optim import init_optimizer, make_optimizer

Tree = Any


class TrainState(NamedTuple):
    params: Tree
    opt: Tree


def _dtype(run: RunConfig):
    return jnp.dtype(run.param_dtype)


def init_state(run: RunConfig, key) -> TrainState:
    params = registry.init_params(key, run.model, _dtype(run))
    use_master = run.master_weights and _dtype(run) != jnp.float32
    use_ef = (run.averaging.error_feedback != "off"
              and run.averaging.mode == "gossip")
    return TrainState(params, init_optimizer(run.optimizer, params,
                                             master_weights=use_master,
                                             error_feedback=use_ef))


def replicate_for_nodes(state: TrainState, n_nodes: int) -> TrainState:
    """Attach the decentralized node axis (identical initial copies)."""
    return jax.tree.map(lambda p: jnp.broadcast_to(p[None], (n_nodes, *p.shape)),
                        state)


def publish_extract(n_nodes: Optional[int] = None) -> Callable:
    """Extract fn for `serve.publisher.SnapshotPublisher`: map the live
    TrainState to the params a serving replica should load.

    Exact-averaging runs (`n_nodes=None`) publish `state.params` as-is. A
    decentralized run passes `n_nodes` and a [N] float membership mask as the
    publisher's `aux`: leaves carrying the leading node axis are reduced to
    the *consensus iterate* — the mask-weighted mean over active nodes (the
    quantity the paper's eq. 17 averaging drives every node toward), so
    dropped nodes' stale rows never pollute the served weights. Runs inside
    the publisher's jitted copy, billed to the publish governor."""

    def extract(state, mask=None):
        params = state.params if hasattr(state, "params") else state
        if n_nodes is None or mask is None:
            return params
        w = mask / jnp.sum(mask)

        def consensus(p):
            if getattr(p, "ndim", 0) and p.shape[0] == n_nodes:
                return jnp.tensordot(w, p, axes=1).astype(p.dtype)
            return p

        return jax.tree.map(consensus, params)

    return extract


def build_train_step(run: RunConfig, mesh, *,
                     n_nodes: Optional[int] = None,
                     mix: Optional[Any] = None) -> Tuple[Callable, Callable]:
    """Returns (train_step, state_spec_fn).

    train_step(state, batch) -> (state, metrics); call under `mesh_rules`.

    `n_nodes` overrides the mesh-derived decentralized node count: passing
    N > n_data_nodes(mesh) emulates the paper's N-node network on fewer
    devices (the vmap'd node axis is then partly or fully local), which is how
    the CPU container exercises gossip semantics and the pipeline benchmark
    drives decentralized supersteps on one device.

    `mix` overrides the consensus engine built from `run.averaging` — the
    scenario harness (core/scenarios.py) injects a time-varying
    `core.mixing.ScheduledMixOp` here; the optimizer's step counter is its
    phase clock. Scheduled operators are linear-only, so quantized configs
    reject the override.
    """
    cfg = run.model
    # pin the tri-state packed default against THIS mesh (packed="auto"
    # gates off on model-parallel layouts — core.averaging.resolve_packed)
    run = dataclasses.replace(run, averaging=dataclasses.replace(
        run.averaging, packed=resolve_packed(run.averaging, mesh)))
    update = make_optimizer(run.optimizer, run.learning_rate,
                            weight_decay=run.weight_decay)
    n_nodes = n_nodes or n_data_nodes(mesh)
    pods = mesh.shape.get("pod", 1)
    decentralized = run.averaging.mode != "exact"
    ef_on = run.averaging.error_feedback != "off"
    if ef_on and run.averaging.mode != "gossip":
        raise ValueError("error_feedback requires averaging mode 'gossip' "
                         f"(got {run.averaging.mode!r})")

    def loss(params, batch):
        return registry.loss_fn(params, cfg, batch, remat=run.remat)

    if not decentralized:
        def grad_of(params, batch):
            return jax.value_and_grad(loss, has_aux=True)(params, batch)

        # the fp32 grad accumulator must be ZeRO-sharded explicitly: left to
        # propagation, XLA keeps the scan carry model-sharded only (8x memory)
        state_shapes = jax.eval_shape(lambda k: init_state(run, k),
                                      jax.random.PRNGKey(0))
        gspec = shlib.zero1_specs(state_shapes.params, mesh)

        def shard_like_zero1(tree):
            return jax.tree.map(
                lambda x, s: jax.lax.with_sharding_constraint(
                    x, jax.NamedSharding(mesh, s)), tree, gspec)

        def train_step(state: TrainState, batch):
            mb = run.microbatches
            if mb > 1:
                # gradient accumulation: process the local mini-batch B/N in
                # `mb` sequential slices (paper Section II-C, compute-limited)
                mbatch = jax.tree.map(
                    lambda a: a.reshape(mb, a.shape[0] // mb, *a.shape[1:]), batch)

                def acc_fn(accu, b):
                    (l, metrics), grads = grad_of(state.params, b)
                    # reduce to ZeRO slices BEFORE the f32 cast: otherwise a
                    # full f32 copy of the gradient tree goes live per microbatch
                    grads = shard_like_zero1(grads)
                    acc_g, acc_l, acc_m = accu
                    acc_g = jax.tree.map(
                        lambda x, g: x + g.astype(jnp.float32) / mb, acc_g, grads)
                    acc_g = shard_like_zero1(acc_g)
                    return (acc_g, acc_l + l / mb,
                            jax.tree.map(lambda x, y: x + y / mb, acc_m, metrics)), None

                zero_g = shard_like_zero1(jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), state.params))
                zero_m = {"ce": jnp.zeros(()), "aux": jnp.zeros(())}
                (grads, l, metrics), _ = jax.lax.scan(
                    acc_fn, (zero_g, jnp.zeros(()), zero_m), mbatch)
            else:
                (l, metrics), grads = grad_of(state.params, batch)
            new_params, new_opt = update(grads, state.opt, state.params)
            metrics = dict(metrics, loss=l, consensus_err=jnp.zeros(()))
            return TrainState(new_params, new_opt), metrics
        return train_step, partial(_state_specs, run=run, mesh=mesh, node_axes=None)

    node_axes = data_axes(mesh)
    # the consensus engine: the R-round mixing operator is precomputed HERE,
    # once per build, not once per round inside the jitted step; the mesh
    # lets impl="auto" keep the collective-permute roll lowering on sharded
    # node axes and take the matmul/kernel fast path on single-device runs
    gossip_n = pods if run.averaging.mode == "hierarchical" else n_nodes
    if mix is None:
        mix = make_gossip_mix(run.averaging, gossip_n, mesh=mesh)
    elif isinstance(mix, ScheduledMixOp) and run.averaging.quantization != "none":
        raise ValueError("ScheduledMixOp is linear-only: quantized averaging "
                         "configs keep their static per-round operator")

    def train_step(state: TrainState, batch):
        # batch leaves: [n_nodes, B/n_nodes, ...]
        def node_loss_grad(params, node_batch):
            return jax.value_and_grad(loss, has_aux=True)(params, node_batch)

        (l, metrics), grads = jax.vmap(node_loss_grad)(state.params, batch)
        # packed (AveragingConfig.packed, the default): grads are flattened
        # into one [N, D] buffer per dtype, the consensus engine and the
        # error diagnostic both run on that buffer — one pack per step,
        # one mixing pass per buffer instead of one chain per leaf
        #
        # the optimizer's step counter doubles as the round clock: stochastic
        # compressors fold it into their key, and a time-varying
        # ScheduledMixOp reads it as the schedule phase index — runtime data
        # either way, so the K-round scan stays a single trace
        t = jnp.reshape(state.opt.step, (-1,))[0]
        step_key = None
        if run.averaging.quantization in STOCHASTIC:
            # per-STEP base key for the stochastic compressor: fold the
            # optimizer's step counter into the MixOp's static seed so a
            # K-round superstep scan draws fresh per-round noise every round
            # instead of replaying the seed-derived sequence (the MixOp still
            # folds the round index in per consensus round)
            step_key = jax.random.fold_in(jax.random.PRNGKey(mix.seed), t)
        if ef_on:
            # error-feedback compressed gossip: compress once per step on the
            # packed residual-corrected gradients, mix LINEARLY, carry the
            # residual in OptState.ef_residual (core.averaging docstring)
            mixed, new_ef, cerr, ef_norm, ef_rel = ef_average_and_error(
                grads, state.opt.ef_residual, run.averaging,
                n_nodes=n_nodes, mix=mix, key=step_key, t=t)
        else:
            mixed, cerr = average_and_error(grads, run.averaging,
                                            n_nodes=n_nodes, pods=pods,
                                            mix=mix, key=step_key, t=t)
        new_params, new_opt = jax.vmap(update)(mixed, state.opt, state.params)
        metrics = jax.tree.map(jnp.mean, metrics)
        metrics = dict(metrics, loss=jnp.mean(l), consensus_err=cerr)
        if ef_on:
            # the optimizer update rules never touch ef_residual (they return
            # it at its default); re-attach the fresh residual here
            new_opt = new_opt._replace(ef_residual=new_ef)
            metrics = dict(metrics, ef_norm=ef_norm, ef_rel=ef_rel)
        return TrainState(new_params, new_opt), metrics

    return train_step, partial(_state_specs, run=run, mesh=mesh, node_axes=node_axes)


def _state_specs(state_shapes: TrainState, *, run: RunConfig, mesh, node_axes):
    # FSDP: params sharded over model AND data axes (all-gathered per layer at
    # use under the scan); decentralized mode instead uses the node axis.
    pspec = (shlib.param_specs(state_shapes.params, mesh, node_axes=node_axes)
             if node_axes else shlib.zero1_specs(state_shapes.params, mesh))

    def opt_spec(leaf):
        # OptState.step is scalar; moment trees mirror params
        return None

    # opt state: map each leaf by matching structure against params where possible
    opt = state_shapes.opt
    same = lambda t: jax.tree_util.tree_structure(t) == jax.tree_util.tree_structure(
        state_shapes.params)
    # ZeRO-1: fp32 Adam moments additionally sharded over the data axes
    m_spec = shlib.zero1_specs(opt.m, mesh, node_axes=node_axes) if same(
        opt.m) else jax.tree.map(lambda _: jax.sharding.PartitionSpec(), opt.m)
    v_spec = shlib.zero1_specs(opt.v, mesh, node_axes=node_axes) if same(
        opt.v) else jax.tree.map(lambda _: jax.sharding.PartitionSpec(), opt.v)
    master_spec = (shlib.zero1_specs(opt.master, mesh, node_axes=node_axes)
                   if opt.master != () else ())
    ef_spec = (shlib.zero1_specs(opt.ef_residual, mesh, node_axes=node_axes)
               if opt.ef_residual != () else ())
    from repro.optim.optimizers import OptState
    return TrainState(pspec, OptState(jax.sharding.PartitionSpec(), m_spec,
                                      v_spec, master_spec, ef_spec))


def build_superstep(run: RunConfig, mesh, *,
                    n_nodes: Optional[int] = None,
                    mix: Optional[Any] = None) -> Tuple[Callable, Callable]:
    """The K-round device scan: fold K consecutive train steps into ONE jitted
    call via `lax.scan` (paper Fig. 4's amortization of fixed per-round costs).

    Returns (superstep, state_spec_fn) where
    `superstep(state, batches) -> (state, metrics)`: batch leaves carry a
    leading K axis ([K, B, ...] exact / [K, N, B/N, ...] decentralized) and
    metric leaves come back stacked [K] — accumulated on-device, so the host
    pays one dispatch and one metric fetch per K rounds instead of per round.
    K is read from the batch shapes at trace time; each distinct K compiles
    once (jit caches by shape), so pick K once per run.
    """
    train_step, spec_fn = build_train_step(run, mesh, n_nodes=n_nodes, mix=mix)

    def superstep(state: TrainState, batches):
        return jax.lax.scan(train_step, state, batches)

    return superstep, spec_fn


def superstep_builder(run: RunConfig, mesh, *,
                      n_nodes: Optional[int] = None,
                      mix: Optional[Any] = None) -> Callable[..., Callable]:
    """Bucket-keyed superstep factory for the adaptive-B governor
    (docs/DESIGN.md §Adaptive batch buckets): `build(B) -> superstep` hands
    `train.driver.StreamingDriver` the function to compile for each
    registered bucket of its `core.rates.BucketLadder`.

    The K-round scan reads K, B, and the node split from its batch shapes at
    trace time, so one closure serves every bucket — the per-bucket identity
    lives in the driver's compiled-superstep registry (one jitted executable
    per bucket, built lazily, reused with zero retrace when the governor
    revisits a bucket). The loss/grad/optimizer graph is built once here, not
    once per bucket.

    `build(B, membership=None)` — a partial `core.mixing.Membership` asks for
    the *cohort* superstep: the same scan rebuilt (and cached) at
    n_nodes = n_active, with the gossip operator recomposed over the active
    cohort (docs/DESIGN.md §Elastic membership). The driver wraps it with the
    full-axis gather/scatter (`train.driver.elastic_superstep`), so this
    builder only ever sees dense node axes.

    The prebuilt `mix` override (scenario harness) only applies at full
    membership — its operator stack is sized for the full node axis; cohort
    supersteps recompose their own operator over the active cohort."""
    n_full = n_nodes or n_data_nodes(mesh)
    cohort_cache: Dict[int, Callable] = {}

    def _for_cohort(m: int) -> Callable:
        fn = cohort_cache.get(m)
        if fn is None:
            fn, _ = build_superstep(run, mesh, n_nodes=m,
                                    mix=mix if m == n_full else None)
            cohort_cache[m] = fn
        return fn

    def build(B: int, membership=None) -> Callable:
        m = n_full if membership is None else membership.n_active
        return _for_cohort(m)

    return build


def make_node_batch(batch: Dict[str, jax.Array], n_nodes: int,
                    axis: int = 0) -> Dict[str, jax.Array]:
    """[B, ...] -> [n_nodes, B/n_nodes, ...] (the splitter of Fig. 3(c)).
    `axis=1` splits superstep batches [K, B, ...] -> [K, n_nodes, B/n_nodes, ...]."""
    def split(a):
        shp = a.shape
        return a.reshape(*shp[:axis], n_nodes, shp[axis] // n_nodes, *shp[axis + 1:])
    return jax.tree.map(split, batch)
