"""Superstep streaming engine: the execution loop that keeps the device fed at
the rate the paper's analysis assumes.

The paper's Fig. 3(c) splits a streaming learner into a *splitter* (one node
receives the stream and deals B samples per round, discarding mu) and the
*compute network* (N nodes process their B/N shares, then average). Fig. 4
shows why the split matters: whenever the stream outpaces the effective
processing rate R_e (eq. 4), samples pile up or drop. A naive training loop —
one jitted step per Python iteration with host-side sample synthesis, a
blocking H2D copy, and a blocking metric fetch between steps — throttles R_p
far below hardware and makes that mismatch self-inflicted. This driver removes
it with three stages:

1. **Splitter (host thread)** — `data.pipeline.StreamingPipeline` runs the
   governed splitter of Fig. 3(c): per round it draws B + mu samples, keeps B,
   and stacks K rounds into one superstep batch (leading K axis).
2. **Stage (H2D overlap)** — `data.pipeline.DevicePrefetcher` stages the
   *next* superstep onto devices (sharded `jax.device_put`) from a background
   thread while the current superstep computes — the overlap of sample arrival
   with processing in Fig. 4's timeline, so host synthesis and transfer time
   disappear from the critical path.
3. **Compute (device)** — `train.trainer.build_superstep` folds the K rounds
   into a single `lax.scan` inside one jitted call (TrainState donated where
   the backend supports it); dispatch and metric-fetch overhead is paid once
   per K rounds instead of once per round.

The driver is workload-agnostic: any superstep of signature
`superstep(state, batches) -> (state, metrics)` (batch leaves [K, ...],
metric leaves stacked [K]) plugs in via `superstep_fn` — or, bucket-keyed,
via `superstep_builder` (`build(B) -> superstep`; see
`train.trainer.superstep_builder` and
`core.krasulina.krasulina_superstep_builder`) — the nonconvex PCA track
rides the same splitter, prefetch ring, and governor as the LM trainer; when
both are omitted the trainer's builder is constructed here. `run_cfg` only
needs `.stream` and `.averaging` (a full `RunConfig`, or a lightweight carrier
like `configs.paper_pca.PCARunConfig`).

Closing the loop, the driver times every superstep, inverts eq. 4 to get the
*measured* R_p / R_e (`core.rates.measured_processing_rate`), and re-plans
(B, mu) via `core.rates.replan` — so an under-provisioned run discards the mu
its hardware actually requires (Fig. 4's drop rule), not what nominal config
constants predicted. With a multi-bucket `GovernorConfig` ladder the re-plan
adapts **B as well as mu**: B may move between the registered buckets of a
`core.rates.BucketLadder`, each of which the driver compiles (lazily, once)
into its own superstep executable — so a steady-state bucket switch costs one
plan swap and zero retrace — while an online `core.rates.RoundTimeEstimator`
decomposes round times observed at different buckets into a running
(R_p, R_c) estimate that replaces the config's comms constant in the eq. 4
inversion. Bucket switches are debounced (`core.rates.BucketHysteresis`) so
timing jitter cannot thrash the ladder, and the first superstep of every
newly compiled jit signature is excluded from governor input (compile time is
not processing time). See docs/DESIGN.md §Adaptive batch buckets.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import GovernorConfig, RunConfig
from repro.core import rates
from repro.data.pipeline import DevicePrefetcher, StreamCounters, StreamingPipeline
from repro.launch.mesh import data_axes, n_data_nodes
from repro.train.trainer import (TrainState, make_node_batch,
                                 superstep_builder as lm_superstep_builder)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Knobs of the streaming engine (all host-side; no retrace on change)."""

    superstep: int = 8  # K: rounds folded into one device scan
    prefetch_depth: int = 2  # staged supersteps in flight; 0 = synchronous
    replan_every: int = 1  # supersteps between governor re-plans; 0 = open loop
    # supersteps whose timings the governor ignores on the INITIAL jit
    # signature: the first two calls pay XLA compilation (one per signature —
    # freshly-built then committed state), and treating compile time as
    # processing time would make replan discard thousands of samples for a
    # one-off cost
    warmup_supersteps: int = 2
    # same gate for every LATER-compiled signature (a bucket first visited
    # mid-run pays one batch-shape retrace): its first `warmup_per_bucket`
    # supersteps are excluded from governor timings and the rate estimator
    warmup_per_bucket: int = 1
    # the adaptive-B bucket ladder + online (R_p, R_c) estimator; the default
    # (single-bucket) config pins B and adapts mu only
    governor: GovernorConfig = GovernorConfig()


class StreamingDriver:
    """Owns the three-stage loop: governed splitter -> prefetch ring ->
    K-round device scan, plus the closed-loop (B, mu) governor.

    Call `run()` under the same `mesh_rules` context the initial state was
    built in. `clock` is injectable so tests can fake slow hardware and watch
    the governor raise mu.
    """

    def __init__(self, run_cfg: RunConfig, mesh, state: Any,
                 sample_fn: Callable[[np.random.Generator, int], Dict[str, np.ndarray]],
                 *, superstep_fn: Optional[Callable] = None,
                 superstep_builder: Optional[Callable[[int], Callable]] = None,
                 engine: EngineConfig = EngineConfig(),
                 batch: Optional[int] = None, horizon: Optional[float] = None,
                 n_nodes: Optional[int] = None, seed: int = 0,
                 clock: Callable[[], float] = time.perf_counter):
        if engine.superstep < 1:
            raise ValueError("superstep K must be >= 1")
        if mesh is None and n_nodes is None:
            raise ValueError("pass n_nodes when driving without a mesh")
        self.run_cfg = run_cfg
        self.mesh = mesh
        self.state = state
        self.engine = engine
        self.clock = clock
        self.decentralized = run_cfg.averaging.mode != "exact"
        self.n_nodes = n_nodes or n_data_nodes(mesh)
        self._horizon = horizon
        self.pipeline = StreamingPipeline(
            sample_fn, run_cfg.stream, self.n_nodes, run_cfg.averaging.rounds,
            batch=batch, horizon=horizon, seed=seed)
        self.ladder = self._make_ladder(engine.governor)
        self.pipeline.adopt_ladder(self.ladder)
        # superstep source, most to least specific: an explicit bucket-keyed
        # builder, a single superstep_fn (served to every bucket), or the LM
        # trainer's builder
        if superstep_builder is None:
            if superstep_fn is not None:
                superstep_builder = lambda B: superstep_fn
            else:
                superstep_builder = lm_superstep_builder(run_cfg, mesh,
                                                         n_nodes=self.n_nodes)
        self._builder = superstep_builder
        # donation updates the state in place across supersteps; CPU lacks
        # donation support and would only warn (see core.dsgd.jit_driver)
        self._donate = (0,) if jax.default_backend() in ("tpu", "gpu") else ()
        # one compiled superstep per bucket, built lazily on first visit and
        # reused with zero retrace on every revisit
        self._compiled: Dict[int, Callable] = {}
        self._sharding = self._batch_sharding()
        self._prefetcher: Optional[DevicePrefetcher] = None
        self._supersteps_done = 0  # across run() calls
        # governor warm-up gate, per jit signature: supersteps completed at
        # each bucket (the first of a fresh signature pays XLA compile time
        # and must not feed replan or the rate estimator)
        self._sig_seen: Dict[int, int] = {}
        self._initial_B = self.pipeline.plan.B
        gov = engine.governor
        self._hysteresis = rates.BucketHysteresis(gov.hysteresis)
        self._estimator = (rates.RoundTimeEstimator(
            self.n_nodes, run_cfg.averaging.rounds, window=gov.window)
            if gov.estimate_rates else None)
        self.history: List[Dict[str, Any]] = []

    def _make_ladder(self, gov: GovernorConfig) -> rates.BucketLadder:
        """Resolve the governor's B ladder: explicit buckets (clipped to the
        Theorem-4 horizon ceiling, snapped to multiples of N), an auto
        geometric ladder around the planned B, or the pinned single-bucket
        ladder (the pre-adaptive behavior)."""
        N = self.n_nodes
        base_B = self.pipeline.plan.B
        if gov.buckets:
            return rates.BucketLadder.from_buckets(
                gov.buckets, N, horizon_samples=self._horizon)
        if gov.n_buckets == 1:
            # pinned B: keep the planned/user batch EXACTLY (the pre-ladder
            # behavior), including a B that is not a multiple of N in exact
            # mode where no node split happens
            return rates.BucketLadder((base_B,))
        return rates.BucketLadder.build(
            base_B, N, n_buckets=gov.n_buckets, factor=gov.bucket_factor,
            horizon_samples=self._horizon)

    @property
    def compiled_buckets(self) -> Tuple[int, ...]:
        """Buckets whose superstep executable exists (visited at least once)."""
        return tuple(sorted(self._compiled))

    def _superstep_for(self, B: int) -> Callable:
        fn = self._compiled.get(B)
        if fn is None:
            fn = jax.jit(self._builder(B), donate_argnums=self._donate)
            self._compiled[B] = fn
        return fn

    # ---------------------------------------------------------------- stages

    def _host_superstep(self) -> Dict[str, np.ndarray]:
        """Stage 1: K governed splitter rounds, stacked [K, B, ...] (exact)
        or split [K, N, B/N, ...] (decentralized node axis)."""
        batch = self.pipeline.next_superstep(self.engine.superstep)
        if self.decentralized:
            batch = make_node_batch(batch, self.n_nodes, axis=1)
        return batch

    def _batch_sharding(self) -> Optional[NamedSharding]:
        """Leading-K batches shard their second axis (global batch / node) over
        the data axes; on a single-device mesh a plain `device_put` suffices."""
        if self.mesh is None or self.mesh.devices.size == 1:
            return None
        dp = data_axes(self.mesh)
        extent = 1
        for a in dp:
            extent *= self.mesh.shape[a]
        if extent == 1 or (self.decentralized and self.n_nodes % extent != 0):
            return None
        return NamedSharding(self.mesh, P(None, dp))

    def _stage(self, batch: Dict[str, np.ndarray]):
        """Stage 2: H2D — runs on the prefetch thread when depth > 0."""
        if self._sharding is None:
            return jax.device_put(batch)
        return jax.device_put(batch, self._sharding)

    # ------------------------------------------------------------- main loop

    def run(self, supersteps: int, *,
            log_fn: Optional[Callable[[Dict[str, Any]], None]] = None,
            log_every: int = 1) -> Tuple[TrainState, List[Dict[str, Any]]]:
        """Drive `supersteps` supersteps (K rounds each). Returns the final
        TrainState and the per-superstep history of metrics, throughput, and
        governor decisions.

        The prefetch ring persists across calls (it keeps staging between
        runs, bounded at `prefetch_depth`), so a warm-up `run()` leaves the
        ring hot for a subsequent timed one; call `close()` (or use the
        driver as a context manager) when done."""
        if self.engine.prefetch_depth > 0 and self._prefetcher is None:
            self._prefetcher = DevicePrefetcher(
                self._host_superstep, stage=self._stage,
                counters=self.pipeline.counters,
                meta=lambda: self.pipeline.last_superstep_plan,
                depth=self.engine.prefetch_depth)
        source = self._prefetcher
        for i in range(supersteps):
            # the timed window covers batch acquisition too: when the HOST is
            # the bottleneck (prefetch ring empty, slow synthesis), that wait
            # must show up in measured_Re or the governor would keep calling
            # an input-bound run "resourceful"
            t0 = self.clock()
            if source is not None:
                staged = next(source)
                counters = source.counters
                used_plan = source.meta
            else:
                staged = self._stage(self._host_superstep())
                counters = self.pipeline.counters()
                used_plan = self.pipeline.last_superstep_plan
            # after a bucket switch the ring may still drain supersteps dealt
            # at the old width: each batch runs through the compiled
            # executable of the bucket that DEALT it (their samples were
            # drawn from the stream — dropping them would lose samples)
            used_plan = used_plan or self.pipeline.plan
            self.state, metrics = self._superstep_for(used_plan.B)(self.state,
                                                                   staged)
            metrics = jax.device_get(metrics)  # one fetch per K rounds
            wall_s = max(self.clock() - t0, 1e-12)
            rec = self._observe(metrics, wall_s, counters, used_plan)
            if log_fn and (i % log_every == 0 or i == supersteps - 1):
                log_fn(rec)
        return self.state, self.history

    def close(self) -> None:
        """Stop the prefetch thread (idempotent)."""
        if self._prefetcher is not None:
            self._prefetcher.close()
            self._prefetcher = None

    def __enter__(self) -> "StreamingDriver":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -------------------------------------------------------------- governor

    def _observe(self, metrics: Dict[str, np.ndarray], wall_s: float,
                 counters: Optional[StreamCounters],
                 used_plan: rates.Plan) -> Dict[str, Any]:
        i = self._supersteps_done
        self._supersteps_done += 1
        K = self.engine.superstep
        round_s = wall_s / K
        stream = self.run_cfg.stream
        B_used = used_plan.B
        # per-jit-signature warm-up gate: a superstep that paid a fresh XLA
        # compile (any bucket's first visit — not just the global first two
        # supersteps) must not feed the governor or the rate estimator
        seen = self._sig_seen.get(B_used, 0)
        self._sig_seen[B_used] = seen + 1
        warm = seen >= (self.engine.warmup_supersteps
                        if B_used == self._initial_B
                        else self.engine.warmup_per_bucket)
        measured_Rp = rates.measured_processing_rate(
            B_used, self.n_nodes, used_plan.R, round_s, stream.comms_rate)
        rec: Dict[str, Any] = {
            "superstep": i,
            "round": (i + 1) * K,
            # last round of the scan == what a per-round loop would print
            "metrics": {k: float(np.asarray(v)[-1]) for k, v in metrics.items()},
            "wall_s": wall_s,
            "rounds_per_s": K / wall_s,
            "samples_per_s": K * B_used / wall_s,
            "measured_Rp": measured_Rp,
            "measured_Re": rates.measured_effective_rate(round_s),
            "plan": used_plan,
            "bucket": B_used,
            "counters": counters,
        }
        governed = stream.streaming_rate > 0
        if governed and warm and self._estimator is not None:
            self._estimator.observe(B_used, round_s)
        every = self.engine.replan_every
        if governed and every > 0 and (i + 1) % every == 0 and warm:
            est = self._estimator.estimate() if self._estimator else None
            if est is not None:
                rec["est_Rp"], rec["est_Rc"] = est.Rp, est.Rc
            cur = self.pipeline.plan
            if len(self.ladder) > 1:
                observed = rates.observed_stream(
                    stream, self.n_nodes, used_plan.R, B_used, round_s,
                    estimate=est)
                target_B = rates.select_bucket(
                    self.ladder, observed, self.n_nodes, cur.R,
                    horizon_samples=self._horizon)
                rec["target_bucket"] = target_B
                # hysteresis: only `governor.hysteresis` consecutive re-plans
                # agreeing on the same bucket confirm a switch
                decided_B = self._hysteresis.step(cur.B, target_B)
            else:
                decided_B = cur.B
            # the wall-time inversion happens at the OBSERVED bucket (the
            # ring may still drain old-width supersteps); the plan is derived
            # at the hysteresis-confirmed one
            new_plan = rates.replan(stream, self.n_nodes, cur.R, B_used,
                                    round_s, ladder=self.ladder, estimate=est,
                                    decided_B=decided_B,
                                    horizon_samples=self._horizon)
            if new_plan.B != cur.B:
                self.pipeline.update_plan(new_plan)
                rec["replanned"] = new_plan
                rec["bucket_switch"] = (cur.B, new_plan.B)
            # Re is measured and jitters every superstep; only an actual
            # change of the governor's *decision* (mu / regime) counts
            elif (new_plan.mu, new_plan.regime) != (cur.mu, cur.regime):
                self.pipeline.update_plan(new_plan)
                rec["replanned"] = new_plan
        self.history.append(rec)
        return rec
