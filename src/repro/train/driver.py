"""Superstep streaming engine: the execution loop that keeps the device fed at
the rate the paper's analysis assumes.

The paper's Fig. 3(c) splits a streaming learner into a *splitter* (one node
receives the stream and deals B samples per round, discarding mu) and the
*compute network* (N nodes process their B/N shares, then average). Fig. 4
shows why the split matters: whenever the stream outpaces the effective
processing rate R_e (eq. 4), samples pile up or drop. A naive training loop —
one jitted step per Python iteration with host-side sample synthesis, a
blocking H2D copy, and a blocking metric fetch between steps — throttles R_p
far below hardware and makes that mismatch self-inflicted. This driver removes
it with three stages:

1. **Splitter (host thread)** — `data.pipeline.StreamingPipeline` runs the
   governed splitter of Fig. 3(c): per round it draws B + mu samples, keeps B,
   and stacks K rounds into one superstep batch (leading K axis).
2. **Stage (H2D overlap)** — `data.pipeline.DevicePrefetcher` stages the
   *next* superstep onto devices (sharded `jax.device_put`) from a background
   thread while the current superstep computes — the overlap of sample arrival
   with processing in Fig. 4's timeline, so host synthesis and transfer time
   disappear from the critical path.
3. **Compute (device)** — `train.trainer.build_superstep` folds the K rounds
   into a single `lax.scan` inside one jitted call (TrainState donated where
   the backend supports it); dispatch and metric-fetch overhead is paid once
   per K rounds instead of once per round.

The driver is workload-agnostic: any superstep of signature
`superstep(state, batches) -> (state, metrics)` (batch leaves [K, ...],
metric leaves stacked [K]) plugs in via `superstep_fn` — or, bucket-keyed,
via `superstep_builder` (`build(B) -> superstep`; see
`train.trainer.superstep_builder` and
`core.krasulina.krasulina_superstep_builder`) — the nonconvex PCA track
rides the same splitter, prefetch ring, and governor as the LM trainer; when
both are omitted the trainer's builder is constructed here. `run_cfg` only
needs `.stream` and `.averaging` (a full `RunConfig`, or a lightweight carrier
like `configs.paper_pca.PCARunConfig`).

Closing the loop, the driver times every superstep, inverts eq. 4 to get the
*measured* R_p / R_e (`core.rates.measured_processing_rate`), and re-plans
(B, mu) via `core.rates.replan` — so an under-provisioned run discards the mu
its hardware actually requires (Fig. 4's drop rule), not what nominal config
constants predicted. With a multi-bucket `GovernorConfig` ladder the re-plan
adapts **B as well as mu**: B may move between the registered buckets of a
`core.rates.BucketLadder`, each of which the driver compiles (lazily, once)
into its own superstep executable — so a steady-state bucket switch costs one
plan swap and zero retrace — while an online `core.rates.RoundTimeEstimator`
decomposes round times observed at different buckets into a running
(R_p, R_c) estimate that replaces the config's comms constant in the eq. 4
inversion. Bucket switches are debounced (`core.rates.BucketHysteresis`) so
timing jitter cannot thrash the ladder, and the first superstep of every
newly compiled jit signature is excluded from governor input (compile time is
not processing time). See docs/DESIGN.md §Adaptive batch buckets.
"""
from __future__ import annotations

import dataclasses
import inspect
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import GovernorConfig, RunConfig
from repro.core import dsgd, rates
from repro.core.faults import FaultSchedule
from repro.core.mixing import Membership
from repro.data.pipeline import DevicePrefetcher, StreamCounters, StreamingPipeline
from repro.launch.mesh import data_axes, n_data_nodes
from repro.train.trainer import (TrainState, make_node_batch,
                                 superstep_builder as lm_superstep_builder)


def elastic_superstep(cohort_fn: Callable, n_full: int) -> Callable:
    """Adapt a cohort-sized superstep to the full node axis
    (docs/DESIGN.md §Elastic membership).

    State leaves keep their full [n_full, ...] extent across membership
    changes (no reshape, no reallocation); the wrapper gathers the active
    rows `ids`, runs the cohort superstep on the dense [m, ...] block, and
    scatters the results back — dropped rows pass through untouched (their
    mixing row has degraded to self-weight 1). `ids` is a runtime [m] array,
    not a static argument, so every membership of the same cohort size
    shares one compiled executable: churn that revisits a cohort size never
    retraces."""

    def fn(state, ids, batches):
        def take(p):
            if getattr(p, "ndim", 0) and p.shape[0] == n_full:
                return jnp.take(p, ids, axis=0)
            return p

        def put(p, s):
            if getattr(p, "ndim", 0) and p.shape[0] == n_full:
                return p.at[ids].set(s.astype(p.dtype))
            return s

        sub = jax.tree.map(take, state)
        sub, metrics = cohort_fn(sub, batches)
        return jax.tree.map(put, state, sub), metrics

    return fn


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Knobs of the streaming engine (all host-side; no retrace on change)."""

    superstep: int = 8  # K: rounds folded into one device scan
    # staged supersteps in flight; 0 = synchronous. Default backed by the
    # pipeline/prefetch_sweep/* bench rows: depth 1 covers steady-state host
    # synthesis, depth 2 also absorbs scheduling jitter, deeper is staging
    # memory without throughput on this container.
    prefetch_depth: int = 2
    replan_every: int = 1  # supersteps between governor re-plans; 0 = open loop
    # supersteps whose timings the governor ignores on the INITIAL jit
    # signature: the first two calls pay XLA compilation (one per signature —
    # freshly-built then committed state), and treating compile time as
    # processing time would make replan discard thousands of samples for a
    # one-off cost
    warmup_supersteps: int = 2
    # same gate for every LATER-compiled signature (a bucket first visited
    # mid-run pays one batch-shape retrace): its first `warmup_per_bucket`
    # supersteps are excluded from governor timings and the rate estimator
    warmup_per_bucket: int = 1
    # the adaptive-B bucket ladder + online (R_p, R_c) estimator; the default
    # (single-bucket) config pins B and adapts mu only
    governor: GovernorConfig = GovernorConfig()


class StreamingDriver:
    """Owns the three-stage loop: governed splitter -> prefetch ring ->
    K-round device scan, plus the closed-loop (B, mu) governor.

    Call `run()` under the same `mesh_rules` context the initial state was
    built in. `clock` is injectable so tests can fake slow hardware and watch
    the governor raise mu.
    """

    def __init__(self, run_cfg: RunConfig, mesh, state: Any,
                 sample_fn: Callable[[np.random.Generator, int], Dict[str, np.ndarray]],
                 *, superstep_fn: Optional[Callable] = None,
                 superstep_builder: Optional[Callable[[int], Callable]] = None,
                 engine: EngineConfig = EngineConfig(),
                 batch: Optional[int] = None, horizon: Optional[float] = None,
                 n_nodes: Optional[int] = None, seed: int = 0,
                 clock: Callable[[], float] = time.perf_counter,
                 faults: Optional[FaultSchedule] = None,
                 publisher: Optional[Any] = None,
                 snapshotter: Optional[Any] = None,
                 resume_from: Optional[str] = None):
        if engine.superstep < 1:
            raise ValueError("superstep K must be >= 1")
        if mesh is None and n_nodes is None:
            raise ValueError("pass n_nodes when driving without a mesh")
        self.run_cfg = run_cfg
        self.mesh = mesh
        self.state = state
        self.engine = engine
        self.clock = clock
        self.decentralized = run_cfg.averaging.mode != "exact"
        self.n_nodes = n_nodes or n_data_nodes(mesh)
        self._horizon = horizon
        gov = engine.governor
        # elastic membership (docs/DESIGN.md §Elastic membership): NODE
        # faults and/or a non-lockstep straggler policy turn joins/leaves
        # into plan swaps on the governed pipeline. Link-only schedules
        # (loss / bandwidth — docs/DESIGN.md §Scenario harness) stay on the
        # standard path: they reshape the mixing operator and the round
        # times, not the cohort
        self._faults = faults
        if faults is not None and faults.n != self.n_nodes:
            raise ValueError(f"fault schedule covers {faults.n} nodes "
                             f"but the driver has {self.n_nodes}")
        self._elastic = ((faults is not None and faults.has_node_faults)
                         or gov.straggler_policy != "wait")
        if self._elastic:
            if not self.decentralized:
                raise ValueError("elastic membership needs a decentralized "
                                 "node axis (averaging mode gossip)")
            if run_cfg.averaging.mode == "hierarchical":
                raise ValueError("elastic membership is not defined for "
                                 "pod-structured hierarchical averaging")
        self._straggler = (rates.StragglerPolicy(
            self.n_nodes, gov.straggler_policy,
            slow_factor=gov.straggler_slow_factor,
            deadline_s=gov.straggler_deadline_s,
            patience=gov.straggler_patience) if self._elastic else None)
        self.pipeline = StreamingPipeline(
            sample_fn, run_cfg.stream, self.n_nodes, run_cfg.averaging.rounds,
            batch=batch, horizon=horizon, seed=seed)
        self.ladder = self._make_ladder(engine.governor)
        self.pipeline.adopt_ladder(self.ladder)
        # cohort ladders always derive from the FULL-membership base ladder,
        # so a rejoin to a previously seen cohort size restores that cohort's
        # exact buckets (and their compiled supersteps) rather than drifting
        self._base_ladder = self.ladder
        self._cohort_ladders: Dict[int, rates.BucketLadder] = {
            self.n_nodes: self.ladder}
        self._membership: Optional[Membership] = None
        self._ids_cache: Dict[Membership, jax.Array] = {}
        self._last_round_s: Optional[float] = None
        self.membership_events: List[Dict[str, Any]] = []
        if self._elastic:
            self._membership = Membership.full(self.n_nodes)
            self.pipeline.swap_membership(self._membership, self.ladder)
        # superstep source, most to least specific: an explicit bucket-keyed
        # builder, a single superstep_fn (served to every bucket), or the LM
        # trainer's builder
        if superstep_builder is None:
            if superstep_fn is not None:
                superstep_builder = lambda B: superstep_fn
            else:
                superstep_builder = lm_superstep_builder(run_cfg, mesh,
                                                         n_nodes=self.n_nodes)
        self._builder = superstep_builder
        # membership-aware builders take (B, membership); legacy builders
        # (and the superstep_fn adapter above) take B alone and can only
        # serve full-membership supersteps
        try:
            params = inspect.signature(superstep_builder).parameters
            self._builder_elastic = len(params) >= 2
        except (TypeError, ValueError):
            self._builder_elastic = False
        # donation updates the TrainState in place across supersteps where
        # the backend honors it — feature-detected, not a backend list (the
        # pinned jax implements CPU donation; see core.dsgd.donation_supported)
        self._donate = (0,) if dsgd.donation_supported() else ()
        # one compiled superstep per (bucket, cohort size), built lazily on
        # first visit and reused with zero retrace on every revisit — the
        # active ids are a runtime argument, so all same-size memberships
        # share one executable
        self._compiled: Dict[Tuple[int, int], Callable] = {}
        self._sharding = self._batch_sharding()
        self._prefetcher: Optional[DevicePrefetcher] = None
        self._supersteps_done = 0  # across run() calls
        # governor warm-up gate, per jit signature: supersteps completed at
        # each (bucket, cohort) signature (the first of a fresh signature
        # pays XLA compile time and must not feed replan or the estimator)
        self._sig_seen: Dict[Tuple[int, int], int] = {}
        self._initial_B = self.pipeline.plan.B
        self._initial_sig = (self._initial_B, self.n_nodes)
        self._hysteresis = rates.BucketHysteresis(gov.hysteresis)
        self._estimator = (rates.RoundTimeEstimator(
            self.n_nodes, run_cfg.averaging.rounds, window=gov.window)
            if gov.estimate_rates else None)
        # train-to-serve publication (see docs/DESIGN.md
        # §Train-to-serve publication): snapshots are taken at the
        # superstep boundary, after
        # the timed window — publication cost is engine bookkeeping the
        # publisher's own governor budgets, not stream processing
        self._publisher = publisher
        if publisher is not None:
            from repro.train.trainer import publish_extract
            publisher.configure(extract=publish_extract(
                self.n_nodes if self.decentralized else None))
        self._pub_masks: Dict[Optional[Membership], Optional[jax.Array]] = {}
        self.history: List[Dict[str, Any]] = []
        # fault tolerance (docs/DESIGN.md §Fault-tolerant streaming): the
        # snapshotter runs at the superstep boundary, after publication —
        # same barrier, same async-dispatch discipline, its own cost governor.
        # `_last_splitter_state` is the splitter snapshot that rode the
        # prefetch `meta` with the superstep just consumed: restoring it
        # re-deals the staged-but-unconsumed supersteps a crash threw away.
        self._snapshotter = snapshotter
        self._last_splitter_state: Optional[dict] = None
        self.resumed_from: Optional[str] = None
        if resume_from is not None:
            from repro.train import snapshot as _snapshot
            self.resumed_from = _snapshot.restore_driver(self, resume_from)

    def _make_ladder(self, gov: GovernorConfig) -> rates.BucketLadder:
        """Resolve the governor's B ladder: explicit buckets (clipped to the
        Theorem-4 horizon ceiling, snapped to multiples of N), an auto
        geometric ladder around the planned B, or the pinned single-bucket
        ladder (the pre-adaptive behavior)."""
        N = self.n_nodes
        base_B = self.pipeline.plan.B
        if gov.buckets:
            return rates.BucketLadder.from_buckets(
                gov.buckets, N, horizon_samples=self._horizon)
        if gov.n_buckets == 1:
            # pinned B: keep the planned/user batch EXACTLY (the pre-ladder
            # behavior), including a B that is not a multiple of N in exact
            # mode where no node split happens
            return rates.BucketLadder((base_B,))
        return rates.BucketLadder.build(
            base_B, N, n_buckets=gov.n_buckets, factor=gov.bucket_factor,
            horizon_samples=self._horizon)

    @property
    def compiled_buckets(self) -> Tuple[int, ...]:
        """Buckets whose superstep executable exists (visited at least once)."""
        return tuple(sorted({b for b, _ in self._compiled}))

    @property
    def compiled_signatures(self) -> Tuple[Tuple[int, int], ...]:
        """(bucket, cohort size) pairs with a compiled superstep executable."""
        return tuple(sorted(self._compiled))

    @property
    def membership(self) -> Optional[Membership]:
        """The active cohort future supersteps will be dealt under (None on a
        non-elastic driver)."""
        return self._membership

    def _superstep_for(self, p: rates.Plan) -> Callable:
        mem = p.membership
        partial_cohort = mem is not None and not mem.is_full
        m = mem.n_active if mem is not None else self.n_nodes
        fn = self._compiled.get((p.B, m))
        if fn is None:
            if self._builder_elastic:
                raw = self._builder(p.B, mem if partial_cohort else None)
            elif partial_cohort:
                raise ValueError(
                    "elastic membership needs a membership-aware superstep "
                    "builder `build(B, membership)`; this driver was given a "
                    "single-argument builder (or a bare superstep_fn)")
            else:
                raw = self._builder(p.B)
            if partial_cohort:
                raw = elastic_superstep(raw, self.n_nodes)
            fn = jax.jit(raw, donate_argnums=self._donate)
            self._compiled[(p.B, m)] = fn
        return fn

    def _ids_for(self, mem: Membership) -> jax.Array:
        ids = self._ids_cache.get(mem)
        if ids is None:
            ids = jnp.asarray(np.asarray(mem.active_ids, np.int32))
            self._ids_cache[mem] = ids
        return ids

    # ---------------------------------------------------------------- stages

    def _host_superstep(self) -> Dict[str, np.ndarray]:
        """Stage 1: K governed splitter rounds, stacked [K, B, ...] (exact)
        or split [K, N, B/N, ...] over the *active cohort* (decentralized
        node axis; the latched plan's membership decides the split)."""
        batch = self.pipeline.next_superstep(self.engine.superstep)
        if self.decentralized:
            p = self.pipeline.last_superstep_plan
            m = self.n_nodes if p.membership is None else p.membership.n_active
            batch = make_node_batch(batch, m, axis=1)
        return batch

    def _batch_sharding(self) -> Optional[NamedSharding]:
        """Leading-K batches shard their second axis (global batch / node) over
        the data axes; on a single-device mesh a plain `device_put` suffices."""
        if self.mesh is None or self.mesh.devices.size == 1:
            return None
        if self._elastic:
            # churn makes the node extent vary (m <= N need not divide the
            # data axes); plain device_put keeps every cohort shape valid
            return None
        dp = data_axes(self.mesh)
        extent = 1
        for a in dp:
            extent *= self.mesh.shape[a]
        if extent == 1 or (self.decentralized and self.n_nodes % extent != 0):
            return None
        return NamedSharding(self.mesh, P(None, dp))

    def _stage(self, batch: Dict[str, np.ndarray]):
        """Stage 2: H2D — runs on the prefetch thread when depth > 0."""
        if self._sharding is None:
            return jax.device_put(batch)
        return jax.device_put(batch, self._sharding)

    # ------------------------------------------------------------- main loop

    def run(self, supersteps: int, *,
            log_fn: Optional[Callable[[Dict[str, Any]], None]] = None,
            log_every: int = 1) -> Tuple[TrainState, List[Dict[str, Any]]]:
        """Drive `supersteps` supersteps (K rounds each). Returns the final
        TrainState and the per-superstep history of metrics, throughput, and
        governor decisions.

        The prefetch ring persists across calls (it keeps staging between
        runs, bounded at `prefetch_depth`), so a warm-up `run()` leaves the
        ring hot for a subsequent timed one; call `close()` (or use the
        driver as a context manager) when done."""
        if self.engine.prefetch_depth > 0 and self._prefetcher is None:
            self._prefetcher = DevicePrefetcher(
                self._host_superstep, stage=self._stage,
                counters=self.pipeline.counters,
                # the meta snapshot carries BOTH the plan that dealt the
                # superstep and the splitter's post-deal stream position, so
                # the consumer-side checkpoint pins exactly what it consumed
                meta=lambda: (self.pipeline.last_superstep_plan,
                              self.pipeline.splitter_state()),
                depth=self.engine.prefetch_depth)
        source = self._prefetcher
        for i in range(supersteps):
            # membership changes land OUTSIDE the timed window: the swap (and
            # any rejoin state sync) is engine bookkeeping, not stream
            # processing the governor should bill to R_p
            if self._elastic:
                self._apply_membership(self._supersteps_done)
            # the timed window covers batch acquisition too: when the HOST is
            # the bottleneck (prefetch ring empty, slow synthesis), that wait
            # must show up in measured_Re or the governor would keep calling
            # an input-bound run "resourceful"
            t0 = self.clock()
            if source is not None:
                staged = next(source)
                counters = source.counters
                used_plan, split_state = source.meta or (None, None)
            else:
                staged = self._stage(self._host_superstep())
                counters = self.pipeline.counters()
                used_plan = self.pipeline.last_superstep_plan
                split_state = self.pipeline.splitter_state()
            if split_state is not None:
                self._last_splitter_state = split_state
            # after a bucket or membership switch the ring may still drain
            # supersteps dealt at the old width/cohort: each batch runs
            # through the compiled executable of the (bucket, cohort) that
            # DEALT it (their samples were drawn from the stream — dropping
            # them would lose samples)
            used_plan = used_plan or self.pipeline.plan
            fn = self._superstep_for(used_plan)
            mem = used_plan.membership
            if mem is not None and not mem.is_full:
                self.state, metrics = fn(self.state, self._ids_for(mem),
                                         staged)
            else:
                self.state, metrics = fn(self.state, staged)
            metrics = jax.device_get(metrics)  # one fetch per K rounds
            wall_s = max(self.clock() - t0, 1e-12)
            rec = self._observe(metrics, wall_s, counters, used_plan)
            if self._publisher is not None:
                # outside the timed window, at the plan-latch barrier: the
                # publisher's copy dispatch is async and its own governor
                # keeps the cost within the configured overhead budget
                snap = self._publisher.maybe_publish(
                    self.state, self._supersteps_done, aux=self._publish_aux())
                rec["published_version"] = snap.version if snap else None
            if self._snapshotter is not None:
                # superstep boundary, after publication: the copy dispatch is
                # async and the writer thread owns all disk I/O — the
                # snapshotter's cost governor bounds what lands here
                ck = self._snapshotter.maybe_snapshot(self)
                rec["checkpoint"] = ck["step"] if ck else None
            if log_fn and (i % log_every == 0 or i == supersteps - 1):
                log_fn(rec)
        return self.state, self.history

    def _publish_aux(self) -> Optional[jax.Array]:
        """The publisher extract's aux: a [N] float membership mask for
        decentralized runs (consensus mean over *active* nodes), None in
        exact mode. Cached per membership so steady state pays no H2D."""
        if not self.decentralized:
            return None
        mem = self._membership
        mask = self._pub_masks.get(mem)
        if mask is None:
            mask = (jnp.ones((self.n_nodes,), jnp.float32) if mem is None
                    else jnp.asarray(np.asarray(mem.active, np.float32)))
            self._pub_masks[mem] = mask
        return mask

    # ---------------------------------------------------------- membership

    def _ladder_for(self, m: int) -> rates.BucketLadder:
        lad = self._cohort_ladders.get(m)
        if lad is None:
            lad = self._base_ladder.for_cohort(m,
                                               horizon_samples=self._horizon)
            self._cohort_ladders[m] = lad
        return lad

    def _apply_membership(self, step: int) -> None:
        """Resolve the cohort for superstep `step`: the fault layer's alive
        mask intersected with the straggler policy's debounced verdicts. A
        change is a `swap_membership` plan swap on the pipeline (eq. 4
        re-inverted at the cohort, B snapped onto the cohort's ladder) —
        never a restart; supersteps already staged drain under the
        membership that dealt them."""
        desired = (self._faults.alive(step) if self._faults is not None
                   else Membership.full(self.n_nodes))
        if self._straggler is not None:
            if self._faults is not None and self._last_round_s:
                # per-node times are synthesized from MEASURED warm-up round
                # times only: before the first timed superstep there is no
                # base to scale the fault factors by, and feeding a made-up
                # 1.0 s seed would pollute every node's EWMA with the same
                # large constant — ratios to the cohort median then stay
                # ~1 until the seed decays, delaying eviction by ~1/alpha
                # supersteps (the pre-PR-7 behavior)
                self._straggler.observe(
                    self._faults.round_s_per_node(step, self._last_round_s))
            desired = self._straggler.propose(desired)
        prev = self._membership
        if desired == prev:
            return
        ladder = self._ladder_for(desired.n_active)
        new_plan = self.pipeline.swap_membership(desired, ladder)
        self.ladder = ladder
        if prev is not None and self.engine.governor.sync_on_rejoin:
            self._sync_rejoined(prev, desired)
        self._membership = desired
        self.membership_events.append({
            "superstep": step, "from": prev, "to": desired,
            "plan": new_plan})

    def _sync_rejoined(self, prev: Membership, new: Membership) -> None:
        """Overwrite rejoining nodes' state rows with the mean of the nodes
        that stayed active, so a stale iterate re-enters at the cohort's
        consensus point instead of dragging the consensus error back up.
        A rare host-side op (once per rejoin), not part of any superstep."""
        joined = [i for i in new.active_ids if not prev.active[i]]
        donors = [i for i in prev.active_ids if new.active[i]]
        if not joined or not donors:
            return
        j = jnp.asarray(np.asarray(joined, np.int32))
        d = jnp.asarray(np.asarray(donors, np.int32))
        n = self.n_nodes

        def fix(p):
            if not getattr(p, "ndim", 0) or p.shape[0] != n:
                return p
            mean = jnp.mean(jnp.take(p, d, axis=0), axis=0).astype(p.dtype)
            return p.at[j].set(mean)

        self.state = jax.tree.map(fix, self.state)

    def close(self) -> None:
        """Stop the prefetch thread and flush/stop the snapshot writer
        (idempotent)."""
        if self._prefetcher is not None:
            self._prefetcher.close()
            self._prefetcher = None
        if self._snapshotter is not None:
            self._snapshotter.close()

    def __enter__(self) -> "StreamingDriver":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -------------------------------------------------------------- governor

    def _observe(self, metrics: Dict[str, np.ndarray], wall_s: float,
                 counters: Optional[StreamCounters],
                 used_plan: rates.Plan) -> Dict[str, Any]:
        i = self._supersteps_done
        self._supersteps_done += 1
        K = self.engine.superstep
        round_s = wall_s / K
        self._last_round_s = round_s
        stream = self.run_cfg.stream
        B_used = used_plan.B
        # the cohort that processed THIS superstep (may differ from the
        # current cohort while the ring drains churn-era items)
        m_used = used_plan.n_active or self.n_nodes
        sig = (B_used, m_used)
        # per-jit-signature warm-up gate: a superstep that paid a fresh XLA
        # compile (any (bucket, cohort)'s first visit — not just the global
        # first two supersteps) must not feed the governor or the estimator
        seen = self._sig_seen.get(sig, 0)
        self._sig_seen[sig] = seen + 1
        warm = seen >= (self.engine.warmup_supersteps
                        if sig == self._initial_sig
                        else self.engine.warmup_per_bucket)
        measured_Rp = rates.measured_processing_rate(
            B_used, m_used, used_plan.R, round_s, stream.comms_rate)
        rec: Dict[str, Any] = {
            "superstep": i,
            "round": (i + 1) * K,
            # last round of the scan == what a per-round loop would print
            "metrics": {k: float(np.asarray(v)[-1]) for k, v in metrics.items()},
            "wall_s": wall_s,
            "rounds_per_s": K / wall_s,
            "samples_per_s": K * B_used / wall_s,
            "measured_Rp": measured_Rp,
            "measured_Re": rates.measured_effective_rate(round_s),
            "plan": used_plan,
            "bucket": B_used,
            "n_active": m_used,
            "counters": counters,
        }
        if self._faults is not None and self._faults.has_link_faults:
            # link-model observability (docs/DESIGN.md §Scenario harness):
            # the active bandwidth slowdown and the Bernoulli edge drops
            # realized at this superstep's last consensus round
            rec["bw_factor"] = self._faults.bw_factor(rec["round"])
            rec["link_drops"] = self._faults.link_drops(rec["round"])
        governed = stream.streaming_rate > 0
        if governed and warm and self._estimator is not None:
            if m_used != self.n_nodes:
                self._estimator.observe_cohort(B_used, m_used, round_s)
            else:
                self._estimator.observe(B_used, round_s)
        every = self.engine.replan_every
        if governed and every > 0 and (i + 1) % every == 0 and warm:
            est = self._estimator.estimate() if self._estimator else None
            if est is not None:
                rec["est_Rp"], rec["est_Rc"] = est.Rp, est.Rc
            # the re-plan targets the CURRENT cohort (eq. 4 re-inverted at
            # N = n_active), even while drain-era supersteps are observed
            cur = self.pipeline.plan
            m_cur = cur.n_active or self.n_nodes
            if len(self.ladder) > 1:
                observed = rates.observed_stream(
                    stream, m_used, used_plan.R, B_used, round_s,
                    estimate=est)
                target_B = rates.select_bucket(
                    self.ladder, observed, m_cur, cur.R,
                    horizon_samples=self._horizon)
                rec["target_bucket"] = target_B
                # hysteresis: only `governor.hysteresis` consecutive re-plans
                # agreeing on the same bucket confirm a switch
                decided_B = self._hysteresis.step(cur.B, target_B)
            else:
                decided_B = cur.B
            # the wall-time inversion happens at the OBSERVED bucket (the
            # ring may still drain old-width supersteps); the plan is derived
            # at the hysteresis-confirmed one
            new_plan = rates.replan(stream, m_cur, cur.R, B_used,
                                    round_s, ladder=self.ladder, estimate=est,
                                    decided_B=decided_B,
                                    horizon_samples=self._horizon,
                                    membership=cur.membership)
            if new_plan.B != cur.B:
                self.pipeline.update_plan(new_plan)
                rec["replanned"] = new_plan
                rec["bucket_switch"] = (cur.B, new_plan.B)
            # Re is measured and jitters every superstep; only an actual
            # change of the governor's *decision* (mu / regime) counts
            elif (new_plan.mu, new_plan.regime) != (cur.mu, cur.regime):
                self.pipeline.update_plan(new_plan)
                rec["replanned"] = new_plan
        self.history.append(rec)
        return rec
