"""Full-loop async checkpoint/restore for the streaming engine
(docs/DESIGN.md §Fault-tolerant streaming).

The stream cannot be replayed — samples not processed within a superstep are
discarded by design (eq. 4's mu) — so a crash without checkpoints loses the
run. `RunSnapshotter` captures the COMPLETE run state at the superstep
boundary (the PR 5 plan latch already makes that a consistency barrier):

* the TrainState (device arrays),
* the splitter's exact stream position — `StreamCounters` quad + PRNG
  bit-generator state + the plan that dealt the last *consumed* superstep
  (`GovernedPlanMixin.splitter_state`, threaded through the prefetch ring's
  `meta` hook so staged-but-unconsumed supersteps are re-dealt on resume,
  not skipped),
* the governor: `RoundTimeEstimator` window, `BucketHysteresis` streak,
  per-signature warm-up counts, the live post-replan `Plan`,
* elastic membership: the active `Membership` and `StragglerPolicy`
  per-node EWMAs / debounce verdicts,
* the publisher's version counter (monotone across restart).

The training thread never blocks on disk: `maybe_snapshot` dispatches a
jitted `a + 0` copy of the state (fresh buffers, async dispatch — the
`serve.publisher.SnapshotPublisher` idiom), gathers the host-side meta
(microseconds of dict building), and hands both to a background writer
thread on the `data.pipeline.DevicePrefetcher` staging pattern. The writer
does the `device_get`, the retried leaf writes, the atomic manifest, and
last-k retention (`train.checkpoint`); a failed save is recorded in
`SnapshotStats` and never propagates into the training thread.

Snapshot cadence is governed twice: a superstep cadence (`every`) and an
EWMA cost governor mirroring the publisher's — the smoothed training-thread
dispatch cost must stay under `overhead_budget` x the wall time since the
last snapshot, so checkpointing can never eat more than the configured
fraction of the loop no matter how small `every` is set.

`restore_driver` rebuilds a `StreamingDriver` mid-stream from the newest
*valid* checkpoint (torn saves are skipped — `train.checkpoint.newest_valid`)
with exact counter/plan/cohort continuity: on the deterministic clock in
exact mode the resumed run is bit-identical to the uninterrupted one.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax

from repro.core.mixing import Membership
from repro.core.rates import Plan
from repro.train import checkpoint


@dataclasses.dataclass
class SnapshotStats:
    saves: int = 0  # durable manifests written by the writer thread
    dispatches: int = 0  # snapshots handed to the writer
    skipped_cadence: int = 0  # not on the `every` superstep grid
    skipped_budget: int = 0  # EWMA cost would exceed the overhead budget
    skipped_busy: int = 0  # writer still on the previous snapshot
    failures: int = 0  # saves that exhausted retries (training unaffected)
    last_error: Optional[str] = None
    cost_ewma_s: Optional[float] = None  # smoothed training-thread dispatch cost
    total_cost_s: float = 0.0  # summed training-thread dispatch cost


def capture_meta(driver) -> dict:
    """Everything host-side a resumed driver needs, as one JSON-serializable
    dict. Captured at the superstep boundary AFTER `_observe` (replan) and
    publication, so the live plan is the post-replan one that deals future
    supersteps, while the splitter snapshot pins the stream position of the
    last consumed superstep."""
    meta: Dict[str, Any] = {
        "supersteps_done": int(driver._supersteps_done),
        "splitter": (driver._last_splitter_state
                     if driver._last_splitter_state is not None
                     else driver.pipeline.splitter_state()),
        "live_plan": driver.pipeline.plan.to_json(),
        "last_round_s": driver._last_round_s,
        "sig_seen": [[int(b), int(m), int(c)]
                     for (b, m), c in sorted(driver._sig_seen.items())],
        "hysteresis": driver._hysteresis.state_dict(),
        "estimator": (driver._estimator.state_dict()
                      if driver._estimator is not None else None),
        "straggler": (driver._straggler.state_dict()
                      if driver._straggler is not None else None),
        "membership": (driver._membership.to_json()
                       if driver._membership is not None else None),
        "publisher": (driver._publisher.state_dict()
                      if driver._publisher is not None else None),
    }
    return meta


def _restore_put(state) -> Callable:
    """A `checkpoint.restore` put that lands each leaf back on the sharding
    the live state's corresponding leaf occupies (restore across the same
    mesh the driver was built under). Committed-ness is mirrored too: an
    explicit-device `device_put` yields a COMMITTED array, and commitment
    feeds the jit compile options — restoring an uncommitted leaf as
    committed would give the resumed process different XLA cache keys than
    the run it is resuming, defeating the persistent compilation cache's
    warm restart."""
    flat = checkpoint._flatten(state)

    def put(key, arr):
        like = flat[key]
        sharding = getattr(like, "sharding", None)
        if sharding is not None and getattr(like, "committed", True):
            return jax.device_put(arr, sharding)
        return jax.device_put(arr)

    return put


def restore_driver(driver, root_or_path: str) -> str:
    """Restore a freshly constructed `StreamingDriver` to the exact point a
    snapshot was taken. `root_or_path` is either a snapshot root (the newest
    valid step directory is selected — torn saves are skipped) or one step
    directory. Returns the path restored from; raises FileNotFoundError when
    no valid checkpoint exists.

    The driver must be constructed with the same config the snapshot was
    taken under (same N, R, buckets, workload); deterministically derived
    objects — cohort ladders, compiled supersteps, ids caches — are NOT in
    the snapshot and are rebuilt lazily, exactly as the uninterrupted run
    built them (with a persistent compilation cache, re-compiles become
    cache hits; see `launch.env.enable_compilation_cache`)."""
    if checkpoint.list_steps(root_or_path):
        path = checkpoint.newest_valid(root_or_path)
        if path is None:
            raise FileNotFoundError(
                f"no valid checkpoint under {root_or_path!r} "
                f"(every step directory is torn or corrupt)")
    elif checkpoint.is_valid(root_or_path):
        path = root_or_path
    else:
        raise FileNotFoundError(
            f"no valid checkpoint at {root_or_path!r}")

    meta = checkpoint.load_manifest(path)["meta"]
    driver.state = checkpoint.restore(path, jax.eval_shape(lambda: driver.state),
                                      put=_restore_put(driver.state))

    live_plan = Plan.from_json(meta["live_plan"])
    mem = meta.get("membership")
    if mem is not None:
        membership = Membership.from_json(mem)
        driver._membership = membership
        # cohort ladders re-derive from the full-membership base ladder, so a
        # rejoin after resume restores the same buckets (and re-uses the same
        # compiled signatures) the uninterrupted run would
        driver.ladder = driver._ladder_for(membership.n_active)
    driver.pipeline.ladder = driver.ladder
    driver.pipeline.load_splitter_state(meta["splitter"], plan=live_plan)

    driver._supersteps_done = int(meta["supersteps_done"])
    driver._last_round_s = meta.get("last_round_s")
    driver._sig_seen = {(int(b), int(m)): int(c)
                        for b, m, c in meta.get("sig_seen", [])}
    driver._last_splitter_state = meta["splitter"]
    driver._hysteresis.load_state_dict(meta["hysteresis"])
    if meta.get("estimator") is not None and driver._estimator is not None:
        driver._estimator.load_state_dict(meta["estimator"])
    if meta.get("straggler") is not None and driver._straggler is not None:
        driver._straggler.load_state_dict(meta["straggler"])
    if meta.get("publisher") is not None and driver._publisher is not None:
        driver._publisher.load_state_dict(meta["publisher"])
    return path


class _Flush:
    pass


class RunSnapshotter:
    """Async snapshot writer for `StreamingDriver` (attach via the driver's
    `snapshotter=` argument; `maybe_snapshot` runs at every superstep
    boundary, outside the governor-timed window).

    `every` is the superstep cadence (a snapshot is considered every
    `every`-th superstep); `overhead_budget` caps the smoothed
    training-thread dispatch cost as a fraction of wall time between
    snapshots; `keep_last` is the retention depth (`train.checkpoint.prune`);
    `retries`/`backoff_s` feed the writer's retry-with-backoff around leaf
    writes. `block=True` makes `maybe_snapshot` wait for the durable
    manifest — for deterministic tests, never production."""

    def __init__(self, root: str, *, every: int = 1, keep_last: int = 3,
                 overhead_budget: float = 0.05, retries: int = 3,
                 backoff_s: float = 0.05, block: bool = False,
                 alpha: float = 0.5,
                 clock: Callable[[], float] = time.perf_counter):
        if every < 1:
            raise ValueError(f"snapshot cadence must be >= 1: {every}")
        if keep_last < 1:
            raise ValueError(f"keep_last must be >= 1: {keep_last}")
        if overhead_budget < 0:
            raise ValueError(f"overhead_budget must be >= 0: {overhead_budget}")
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1]: {alpha}")
        self.root = root
        self.every = every
        self.keep_last = keep_last
        self.overhead_budget = overhead_budget
        self.retries = retries
        self.backoff_s = backoff_s
        self.block = block
        self.alpha = alpha
        self.clock = clock
        self.stats = SnapshotStats()
        self._copy = None  # jitted lazily, once per state treedef
        self._last_dispatch_t: Optional[float] = None
        self._in_flight: Optional[threading.Event] = None  # last save's done
        # depth-1 ring: at most one snapshot in flight; a second arriving
        # while the writer is mid-save is skipped (the next cadence hit
        # takes a fresher one anyway) rather than queueing unbounded copies
        self._q: "queue.Queue" = queue.Queue(maxsize=1)
        self._closed = False
        self._thread = threading.Thread(target=self._worker, daemon=True,
                                        name="snapshot-writer")
        self._thread.start()

    # ------------------------------------------------------------- capture

    def _copy_fn(self) -> Callable:
        if self._copy is None:
            # fresh buffers, async dispatch: the checkpointed leaves must not
            # alias the trainer's (potentially donated) buffers, and the
            # device-to-device copy overlaps the next superstep — the
            # training thread pays dispatch cost only (the publisher idiom)
            self._copy = jax.jit(
                lambda t: jax.tree.map(lambda a: a + 0, t))
        return self._copy

    def maybe_snapshot(self, driver) -> Optional[Dict[str, Any]]:
        """Snapshot the driver if the cadence and the cost governor allow.
        Returns {"step", "path"} when a snapshot was dispatched (with
        `block=True`, when it is durable), else None. Never blocks on disk
        and never raises for I/O trouble — a failed save shows up in
        `stats.failures` and the next cadence hit tries again."""
        step = driver._supersteps_done
        if step % self.every != 0:
            self.stats.skipped_cadence += 1
            return None
        if self._last_dispatch_t is not None and self.overhead_budget > 0:
            elapsed = max(self.clock() - self._last_dispatch_t, 1e-12)
            ewma = self.stats.cost_ewma_s
            if ewma is not None and ewma > self.overhead_budget * elapsed:
                self.stats.skipped_budget += 1
                return None
        # depth-1 discipline: at most one snapshot in flight — the queue can
        # be empty while the writer is still mid-save, so busy-ness is the
        # previous save's done event, not queue occupancy
        if (self._q.full() or
                (self._in_flight is not None and not self._in_flight.is_set())):
            self.stats.skipped_busy += 1
            return None
        t0 = self.clock()
        copied = self._copy_fn()(driver.state)
        meta = capture_meta(driver)
        done = threading.Event()
        path = checkpoint.step_dir(self.root, step)
        try:
            self._q.put_nowait((step, copied, meta, done))
        except queue.Full:  # raced with a straggling writer
            self.stats.skipped_busy += 1
            return None
        self._in_flight = done
        cost = self.clock() - t0
        st = self.stats
        st.dispatches += 1
        st.total_cost_s += cost
        st.cost_ewma_s = cost if st.cost_ewma_s is None else (
            self.alpha * cost + (1.0 - self.alpha) * st.cost_ewma_s)
        self._last_dispatch_t = self.clock()
        if self.block:
            done.wait()
        return {"step": step, "path": path}

    # -------------------------------------------------------------- writer

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            if isinstance(item, tuple) and isinstance(item[0], _Flush):
                item[1].set()
                continue
            step, copied, meta, done = item
            try:
                checkpoint.save(checkpoint.step_dir(self.root, step), copied,
                                step=step, meta=meta, retries=self.retries,
                                backoff_s=self.backoff_s)
                checkpoint.prune(self.root, self.keep_last)
                self.stats.saves += 1
            except Exception as e:  # never kill the training thread
                self.stats.failures += 1
                self.stats.last_error = f"{type(e).__name__}: {e}"
            finally:
                done.set()

    def flush(self) -> None:
        """Wait until every dispatched snapshot is durable (or failed)."""
        if self._closed:
            return
        done = threading.Event()
        self._q.put((_Flush(), done))
        done.wait()

    def close(self) -> None:
        """Flush pending snapshots and stop the writer (idempotent)."""
        if self._closed:
            return
        self._closed = True
        self._q.put(None)
        self._thread.join(timeout=30.0)

    def __enter__(self) -> "RunSnapshotter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
