"""The paper's streaming 1-PCA experiment (Section IV-D).

Fig. 7: Sigma in R^{10x10}, lambda_1 = 1, eigengap 0.1, t' = 1e6 Gaussian samples.
Fig. 8: CIFAR-10 (d=3072). CIFAR is not bundled offline; `highd` reproduces the
regime with a synthetic spiked-covariance dataset of the same dimension and a
comparable spectral profile (documented deviation, docs/DESIGN.md §Deviations).
"""
from dataclasses import dataclass, field

from repro.configs.base import AveragingConfig, StreamConfig


@dataclass(frozen=True)
class PCAConfig:
    dim: int = 10
    eigengap: float = 0.1
    lambda1: float = 1.0
    spectrum: str = "linear"  # linear decay below lambda_2
    seed: int = 0


FIG7 = PCAConfig(dim=10, eigengap=0.1)
HIGHD = PCAConfig(dim=3072, eigengap=0.3, lambda1=1.0, spectrum="power")


@dataclass(frozen=True)
class PCARunConfig:
    """Distribution setup for the PCA track on the streaming engine — the
    subset of `RunConfig` that `train.driver.StreamingDriver` consumes
    (`.averaging` for the consensus engine / node split, `.stream` for the
    governor's rate model), with the PCA problem in place of a ModelConfig.
    Pair it with `core.krasulina.build_krasulina_superstep` as the driver's
    `superstep_fn`."""

    pca: PCAConfig = FIG7
    averaging: AveragingConfig = field(default_factory=AveragingConfig)
    stream: StreamConfig = field(default_factory=StreamConfig)
