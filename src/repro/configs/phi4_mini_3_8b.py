"""Phi-4-mini 3.8B. [arXiv:2412.08905]

Dense: RoPE, SwiGLU, GQA kv=8. Full attention -> long_500k via sliding-window
variant.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=200_064,
    rope_theta=10_000.0,
    ffn="swiglu",
    source="arXiv:2412.08905",
)
