"""RecurrentGemma-9B (Griffin). [arXiv:2402.19427]

Hybrid: RG-LRU recurrent blocks with local sliding-window attention in a
(recurrent, recurrent, local-attn) repeating pattern — "1:2". GQA with a single
KV head (MQA) in the attention blocks. Attention-light -> long_500k native.
"""
from repro.configs.base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12_288,
    vocab_size=256_000,
    head_dim=256,
    ffn="geglu",
    rglru=RGLRUConfig(lru_width=0, conv_width=4, pattern_period=3,
                      attn_positions=(2,), local_window=2048),
    source="arXiv:2402.19427",
)
