"""StarCoder2-15B. [arXiv:2402.19173]

Dense code model: GQA kv=4, RoPE, sliding-window attention (4096) per the model
card -> long_500k runs with its native sub-quadratic window.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    d_ff=24_576,
    vocab_size=49_152,
    rope_theta=100_000.0,
    sliding_window=4096,
    ffn="gelu",
    norm="layernorm",
    source="arXiv:2402.19173",
)
