"""Mamba2-2.7B. [arXiv:2405.21060]

Attention-free SSM with SSD (state-space duality): chunked dual form for training,
O(1) recurrent state for decode -> long_500k native. d_ff=0 (the Mamba block is
the whole layer).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    ffn="none",
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk_size=256, conv_width=4, ngroups=1),
    source="arXiv:2405.21060",
)
