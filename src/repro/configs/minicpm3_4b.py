"""MiniCPM3-4B. [hf:openbmb/MiniCPM3-4B]

Dense with Multi-head Latent Attention (MLA): low-rank KV compression; all 40
heads share the compressed latent (config lists kv=40 i.e. no GQA grouping at the
head level — MLA compresses along the feature dim instead).
Full attention -> long_500k via sliding-window variant.
"""
from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73_448,
    head_dim=96,  # qk_nope(64) + qk_rope(32)
    ffn="swiglu",
    mla=MLAConfig(kv_lora_rank=256, q_lora_rank=768, qk_nope_head_dim=64,
                  qk_rope_head_dim=32, v_head_dim=64),
    source="hf:openbmb/MiniCPM3-4B",
)
