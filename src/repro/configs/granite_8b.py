"""IBM Granite Code 8B. [arXiv:2405.04324]

Llama-architecture dense code model: GQA kv=8, RoPE, SwiGLU.
Full attention -> long_500k runs only as an explicit sliding-window variant.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=49_152,
    rope_theta=10_000_000.0,
    ffn="swiglu",
    source="arXiv:2405.04324",
)
