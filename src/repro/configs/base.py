"""Config system for `repro`.

Every assigned architecture is described by a :class:`ModelConfig`. Input shapes are
described by :class:`ShapeConfig`. The training/serving distribution setup (mesh,
gradient-averaging mode per the paper) lives in :class:`RunConfig`.

All configs are plain frozen dataclasses: hashable (usable as jit static args),
serializable, and composable.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

# ---------------------------------------------------------------------------
# Model configs
# ---------------------------------------------------------------------------

BlockKind = str  # "attn" | "rglru" | "ssd"


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style)."""

    kv_lora_rank: int = 256
    q_lora_rank: int = 768
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 1
    num_shared_experts: int = 0
    # d_ff of each routed expert (shared experts use ModelConfig.d_ff)
    expert_d_ff: int = 0
    router_aux_loss_weight: float = 0.01
    # every `every` layers is MoE (1 = all layers)
    every: int = 1
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD parameters."""

    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk_size: int = 256
    conv_width: int = 4
    ngroups: int = 1


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma / Griffin recurrent block parameters."""

    lru_width: int = 0  # 0 -> d_model
    conv_width: int = 4
    # block pattern: indices i with i % pattern_period in attn_positions are local-attn
    pattern_period: int = 3
    attn_positions: Tuple[int, ...] = (2,)
    local_window: int = 2048


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # attention flavor
    rope_theta: float = 10_000.0
    use_qk_norm: bool = False
    sliding_window: int = 0  # 0 = full attention
    # iRoPE-style chunked-local attention: layers with (i % global_every != global_offset)
    # use local chunks of `chunk_attn_window`; 0 disables.
    chunk_attn_window: int = 0
    global_attn_every: int = 4
    # ffn flavor: "swiglu" | "geglu" | "gelu"
    ffn: str = "swiglu"
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    tie_embeddings: bool = True
    # sub-configs
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # encoder-decoder (audio): number of encoder layers (decoder = num_layers)
    encoder_layers: int = 0
    # modality frontend stub: if set, inputs may be precomputed embeddings with
    # this feature dim (projected to d_model by a learned projector).
    frontend_embed_dim: int = 0
    # serve-time option: allocate sliding-window attention caches as W-slot
    # ring buffers instead of full seq_len (perf iteration, EXPERIMENTS.md §Perf)
    ring_buffer_cache: bool = False
    # citation / provenance
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def block_kind(self, layer_idx: int) -> BlockKind:
        if self.family == "ssm":
            return "ssd"
        if self.rglru is not None:
            pat = self.rglru
            return "attn" if (layer_idx % pat.pattern_period) in pat.attn_positions else "rglru"
        return "attn"

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for roofline MODEL_FLOPS."""
        d, V = self.d_model, self.vocab_size
        emb = V * d if self.tie_embeddings else 2 * V * d
        total = emb
        hd = self.resolved_head_dim
        for i in range(self.num_layers + self.encoder_layers):
            kind = self.block_kind(i % max(self.num_layers, 1))
            if kind == "attn" or self.is_encdec:
                if self.mla is not None:
                    m = self.mla
                    attn = (
                        d * m.q_lora_rank
                        + m.q_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                        + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                        + m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
                        + self.num_heads * m.v_head_dim * d
                    )
                else:
                    attn = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd + self.num_heads * hd * d
            elif kind == "rglru":
                w = self.rglru.lru_width or d
                attn = 2 * d * w + 2 * w + w * d  # in/gate projections + lru params + out
            else:  # ssd
                s = self.ssm
                dinner = s.expand * d
                nheads = dinner // s.head_dim
                attn = d * (2 * dinner + 2 * s.ngroups * s.state_dim + nheads) + dinner * d
            if self.moe is not None and (i % self.moe.every == 0):
                eff = self.moe.expert_d_ff or self.d_ff
                ff_mults = 3 if self.ffn in ("swiglu", "geglu") else 2
                ffn = self.moe.num_experts * ff_mults * d * eff + self.moe.num_shared_experts * ff_mults * d * eff
                ffn += d * self.moe.num_experts  # router
            else:
                ff_mults = 3 if self.ffn in ("swiglu", "geglu") else 2
                ffn = ff_mults * d * self.d_ff
            total += attn + ffn + 2 * d  # + norms
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k + shared experts count)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        eff = self.moe.expert_d_ff or self.d_ff
        ff_mults = 3 if self.ffn in ("swiglu", "geglu") else 2
        per_layer_all = self.moe.num_experts * ff_mults * d * eff
        per_layer_active = (self.moe.top_k) * ff_mults * d * eff
        n_moe_layers = sum(1 for i in range(self.num_layers) if i % self.moe.every == 0)
        return self.param_count() - n_moe_layers * (per_layer_all - per_layer_active)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


# ---------------------------------------------------------------------------
# Run (distribution + paper technique) config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AveragingConfig:
    """The paper's gradient-aggregation knob (Sections IV & V).

    mode:
      exact        -- AllReduce/psum over all data-parallel axes (DMB, Alg. 1)
      gossip       -- R rounds of doubly-stochastic consensus over the data axis
                      (D-SGD/AD-SGD, Algs. 3-4, eq. 17)
      hierarchical -- psum within pod, gossip across pods (TPU adaptation)
    """

    mode: str = "exact"
    rounds: int = 1  # R
    topology: str = "ring"  # ring | torus | circulant2 (deg-4 expander)
    self_weight: float = 0.0  # 0 -> uniform 1/(deg+1)
    quantization: str = "none"  # none | sign | int8 | int8_stoch
    # pack the gradient pytree into one flat [N, D] buffer per dtype so the
    # mixing operator runs once per step instead of once per leaf
    # (core.packing); per-leaf fallback when off. Quantized stats="global"
    # always takes the per-leaf oracle path (bit-identity contract).
    # Tri-state: "auto" (default) packs everywhere EXCEPT layouts whose
    # param leaves are actually sharded over a model axis — numeric parity
    # under a model split is test-covered, but the pack's relayout cost on a
    # real mesh is un-profiled (ROADMAP real-TPU debt), so those layouts opt
    # in explicitly with True. The trainer resolves this against its mesh
    # via `core.averaging.resolve_packed`; direct `core.averaging` callers
    # see "auto" as on (truthy).
    packed: Any = "auto"  # "auto" | True | False
    # quantizer statistic granularity: global (exact per-round oracle) |
    # segment (per-leaf scales on the packed buffer) | tile (fused kernel,
    # per-[N, quant_block_d]-tile scales computed in-register) | node
    # (sender-local per-[1, quant_block_d] row-tile scales — the only
    # granularity whose wire values survive a node-axis device split, so the
    # shard_map gossip kernels require it)
    quant_stats: str = "global"
    quant_block_d: int = 512
    # error-feedback compressed gossip, see
    # docs/DESIGN.md §Decentralized LM track: "off" | "grads". With
    # "grads", the compressor runs ONCE per
    # step on v = grad + residual (sender-local per-node tile statistics),
    # the R consensus rounds mix the compressed values with the exact LINEAR
    # operator (so the composed-roll / matmul / shard_map implementations
    # apply under compression), and the residual v - C(v) is carried per node
    # in `OptState.ef_residual` — compression error stays in optimizer state
    # instead of accumulating as iterate bias under momentum. Gossip mode
    # only.
    error_feedback: str = "off"


@dataclass(frozen=True)
class StreamConfig:
    """The paper's rate model (Section II-C)."""

    streaming_rate: float = 0.0  # R_s samples/s; 0 = no governor (consume everything)
    processing_rate: float = 0.0  # R_p samples/s/node
    comms_rate: float = 0.0  # R_c messages/s
    # If positive, force this many discarded samples per round (mu); otherwise planned.
    forced_mu: int = -1


@dataclass(frozen=True)
class GovernorConfig:
    """Closed-loop governor knobs beyond the per-round rate model: the
    adaptive-B bucket ladder and the online (R_p, R_c) estimator
    (docs/DESIGN.md §Adaptive batch buckets).

    The network mini-batch B may only move between *registered* buckets —
    each one a multiple of N with a pre-compiled superstep — so a re-plan
    costs a plan swap, never a retrace. `n_buckets=1` with no explicit
    `buckets` pins B (the pre-ladder governor: only mu adapts).
    """

    # explicit B ladder (each a multiple of the node count); () -> auto
    buckets: Tuple[int, ...] = ()
    # auto-ladder size around the planned B when `buckets` is not given
    n_buckets: int = 1
    bucket_factor: int = 2  # geometric spacing of the auto ladder
    # consecutive re-plans that must agree on a new bucket before the switch
    # (timing jitter must not thrash the ladder)
    hysteresis: int = 2
    # fit (R_p, R_c) online by least squares over observed (B, round-time)
    # pairs instead of trusting the config's comms_rate when inverting eq. 4
    estimate_rates: bool = True
    window: int = 64  # estimator observation window (supersteps)

    # --- elastic membership (docs/DESIGN.md §Elastic membership) ---
    # straggler policy over per-node round times: "wait" (lockstep, never
    # drop — the paper's assumption), "drop" (exclude nodes slower than
    # straggler_slow_factor x the active-cohort median), "deadline" (exclude
    # nodes slower than the absolute straggler_deadline_s)
    straggler_policy: str = "wait"
    straggler_slow_factor: float = 2.0
    straggler_deadline_s: float = 0.0
    # consecutive verdicts before a node is dropped or readmitted (per-node
    # BucketHysteresis — same debounce discipline as bucket switches)
    straggler_patience: int = 2
    # on rejoin, overwrite the returning node's rows with the active-cohort
    # mean so its stale iterate cannot blow up the consensus error
    sync_on_rejoin: bool = True


@dataclass(frozen=True)
class ScenarioConfig:
    """One named cell of the scenario harness (`core/scenarios.py`;
    docs/DESIGN.md §Scenario harness): a seeded, deterministic composition of
    the three orthogonal axes the paper's assumptions quantify over —

    * **topology schedule**: the time-varying mixing graph of eq. 17, as
      (topology, rounds) segments cycled by the consensus round counter.
      Topologies: ring | torus | circulant2 | expander | geometric.
    * **link model**: per-edge loss/bandwidth faults in the extended
      `core.faults.FaultSchedule` DSL ('link:1-2@4-20p0.1,bw:0-3@5-15x4');
      empty = loss-free links. Link windows index consensus rounds.
    * **stream**: the per-node data distribution — iid_pca | drift_pca |
      iid_logreg | skew_logreg, with `stream_param` the drift rate
      (radians/sample) or the Dirichlet concentration alpha.

    Pure data (hashable, serializable); `core.scenarios` owns construction of
    the operators, samplers, and fault schedules it names."""

    name: str
    n_nodes: int = 8
    rounds: int = 2  # R consensus rounds per algorithm step
    # ((topology, n_rounds), ...): consecutive segments of the cyclic schedule
    topology_schedule: Tuple[Tuple[str, int], ...] = (("ring", 1),)
    links: str = ""  # FaultSchedule DSL, link:/bw: tokens only
    stream: str = "iid_pca"
    stream_param: float = 0.0
    seed: int = 0
    self_weight: float = 0.0  # circulant self-weight (0 -> uniform)
    # link-loss realization horizon in rounds (0 -> auto: cover the link
    # windows and the topology period; realizations repeat beyond it)
    period_rounds: int = 0


@dataclass(frozen=True)
class PublishConfig:
    """Train-to-serve snapshot publication knobs
    (`serve/publisher.py`; docs/DESIGN.md §Train-to-serve publication).

    The publisher snapshots the consensus iterate at superstep boundaries
    into double-buffered device-resident copies with a monotone version
    counter; `overhead_budget` caps the fraction of training wall time its
    own governor lets publication consume."""

    enabled: bool = False
    overhead_budget: float = 0.05  # publish cost / train wall-time ceiling
    min_interval_s: float = 0.0  # floor between publishes (0 = budget only)
    block: bool = False  # block on the copy (deterministic tests/benchmarks)


@dataclass(frozen=True)
class SnapshotConfig:
    """Fault-tolerance knobs for the async checkpoint subsystem
    (`train/snapshot.py`; docs/DESIGN.md §Fault-tolerant streaming).

    The snapshotter captures the full run state (TrainState + governor +
    splitter + membership + publisher version) at superstep boundaries and
    writes it from a background thread; `overhead_budget` caps the smoothed
    training-thread dispatch cost as a fraction of wall time between
    snapshots, mirroring the publisher's governor."""

    enabled: bool = False
    root: str = ""  # checkpoint directory (step_NNNNNNNN/ subdirs)
    every: int = 1  # superstep cadence between snapshot attempts
    keep_last: int = 3  # retention depth (newest-valid fallback on restore)
    overhead_budget: float = 0.05  # snapshot cost / train wall-time ceiling
    retries: int = 3  # leaf-write retry-with-backoff attempts in the writer
    backoff_s: float = 0.05
    block: bool = False  # wait for durability (deterministic tests only)


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    averaging: AveragingConfig = field(default_factory=AveragingConfig)
    stream: StreamConfig = field(default_factory=StreamConfig)
    # mesh
    multi_pod: bool = False
    # optimizer
    optimizer: str = "adam"  # sgd | adam | accel (paper eqs. 9-11)
    learning_rate: float = 3e-4
    weight_decay: float = 0.0
    polyak: bool = False  # Polyak-Ruppert iterate averaging (eq. 7)
    # numerics
    param_dtype: str = "bfloat16"
    # fp32 master weights for mixed precision (ZeRO-sharded); without them,
    # sub-bf16-resolution updates vanish
    master_weights: bool = True
    remat: bool = True
    # sequential microbatches per step (gradient accumulation): the paper's
    # compute-limited regime knob — the local mini-batch B/N is processed in
    # `microbatches` sequential slices per round
    microbatches: int = 1
    seed: int = 0


def reduced(cfg: ModelConfig, layers: int = 2, d_model: int = 256, experts: int = 4) -> ModelConfig:
    """A smoke-test-sized member of the same architecture family (brief: 2 layers,
    d_model<=512, <=4 experts)."""
    num_heads = max(2, min(cfg.num_heads, d_model // 64))
    ratio = max(1, cfg.num_heads // max(cfg.num_kv_heads, 1))
    num_kv = max(1, num_heads // ratio)
    changes = dict(
        num_layers=layers,
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv,
        d_ff=2 * d_model,
        vocab_size=512,
        head_dim=64 if cfg.head_dim else 0,
    )
    if cfg.mla is not None:
        changes["mla"] = MLAConfig(kv_lora_rank=64, q_lora_rank=128, qk_nope_head_dim=32,
                                   qk_rope_head_dim=16, v_head_dim=32)
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=min(experts, cfg.moe.num_experts),
            top_k=min(cfg.moe.top_k, min(experts, cfg.moe.num_experts)),
            num_shared_experts=min(1, cfg.moe.num_shared_experts),
            expert_d_ff=(2 * d_model if cfg.moe.expert_d_ff else 0))
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(cfg.ssm, state_dim=32, head_dim=32, chunk_size=64)
    if cfg.rglru is not None:
        changes["rglru"] = dataclasses.replace(cfg.rglru, lru_width=0, local_window=128)
    if cfg.encoder_layers:
        changes["encoder_layers"] = layers
    if cfg.sliding_window:
        changes["sliding_window"] = 128
    if cfg.chunk_attn_window:
        changes["chunk_attn_window"] = 128
    if cfg.frontend_embed_dim:
        changes["frontend_embed_dim"] = 128
    return dataclasses.replace(cfg, **changes)
