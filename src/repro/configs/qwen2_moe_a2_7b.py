"""Qwen1.5-MoE-A2.7B. [hf:Qwen/Qwen1.5-MoE-A2.7B]

Fine-grained MoE: 60 routed experts top-4 plus 4 shared experts, expert d_ff 1408,
GQA kv=16 (no grouping), RoPE, SwiGLU.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5632,  # shared-expert path width (4 x 1408)
    vocab_size=151_936,
    rope_theta=1_000_000.0,
    ffn="swiglu",
    moe=MoEConfig(num_experts=60, top_k=4, num_shared_experts=4, expert_d_ff=1408, every=1),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
