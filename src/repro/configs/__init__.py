"""Architecture registry: ``--arch <id>`` resolves through :func:`get_config`."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (  # noqa: F401
    AveragingConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RGLRUConfig,
    RunConfig,
    SHAPES,
    SSMConfig,
    ShapeConfig,
    StreamConfig,
    reduced,
)

_ARCH_MODULES = {
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "starcoder2-15b": "starcoder2_15b",
    "granite-8b": "granite_8b",
    "minicpm3-4b": "minicpm3_4b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "chameleon-34b": "chameleon_34b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "mamba2-2.7b": "mamba2_2_7b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
