"""Llama-4 Scout 17B-active / 16 experts. [hf:meta-llama/Llama-4-Scout-17B-16E]

MoE with 16 routed experts, top-1 routing plus one shared expert, early-fusion
multimodal (vision frontend stubbed per brief), GQA kv=8, iRoPE-style chunked-local
attention on 3 of every 4 layers which makes long_500k sub-quadratic.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig  # noqa: F401

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    head_dim=128,
    rope_theta=500_000.0,
    use_qk_norm=True,
    chunk_attn_window=8192,
    global_attn_every=4,
    ffn="swiglu",
    moe=MoEConfig(num_experts=16, top_k=1, num_shared_experts=1, expert_d_ff=8192, every=1),
    frontend_embed_dim=1408,  # ViT patch embeddings stub (early fusion)
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
