"""SeamlessM4T-medium. [arXiv:2308.11596]

Encoder-decoder multimodal translation backbone. The speech frontend
(mel-spectrogram + conformer feature extractor) is stubbed: input_specs provides
precomputed frame embeddings (frontend_embed_dim) that a learned projector maps
to d_model. 12 encoder + 12 decoder layers, post-LN transformer, GELU FFN,
no GQA grouping (kv=16).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,  # decoder
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256_206,
    ffn="gelu",
    norm="layernorm",
    tie_embeddings=True,
    frontend_embed_dim=160,  # 80-dim mel x2 frame stacking stub
    source="arXiv:2308.11596",
)
