"""The paper's own convex experiment: binary logistic regression (Sections IV-B, V-C).

Two data generators are used by the paper:
  - Fig. 6: w* ~ N(0,I), x ~ N(0,I_d) with d=5, Bernoulli labels via the logistic link.
  - Fig. 9: conditional Gaussians, d=20, sigma_x^2=2, class means ~ N(0, I).
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class LogRegConfig:
    dim: int = 5
    generator: str = "logistic_link"  # logistic_link | cond_gauss
    noise_var: float = 2.0  # sigma_x^2 for cond_gauss
    seed: int = 0


FIG6 = LogRegConfig(dim=5, generator="logistic_link")
FIG9 = LogRegConfig(dim=20, generator="cond_gauss", noise_var=2.0)
