"""Chameleon-34B. [arXiv:2405.09818]

Early-fusion mixed-modal decoder: VQ image tokens share the 65536 text vocab, so
the backbone is a plain decoder LM consuming interleaved token ids (the VQ-GAN
tokenizer is the stubbed frontend). Uses QK-norm per the paper. Full attention ->
long_500k via sliding-window variant.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22_016,
    vocab_size=65_536,
    use_qk_norm=True,
    ffn="swiglu",
    norm="layernorm",
    source="arXiv:2405.09818",
)
