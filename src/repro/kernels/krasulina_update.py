"""Pallas TPU kernels: fused mini-batch Krasulina pseudo-gradient, per node
and — for the decentralized D-Krasulina track — fused with the R-round gossip
consensus that follows it.

The paper's PCA hot spot (Alg. 2 steps 3-5) is, per node and round, a fused
BLAS-2 pass over the local mini-batch: s = Z w, then xi = Z^T s / B - (mean(s^2)
/ ||w||^2) w. A naive implementation streams Z from HBM twice (once for s, once
for Z^T s) or materializes B rank-1 updates. `krasulina_xi_pallas` tiles Z into
VMEM once per block and accumulates both Z^T s and sum(s^2) in a single pass —
arithmetic intensity doubles versus the two-pass form, which matters because
the op is memory-bound (2*B*d flops over B*d*dtype bytes).

`krasulina_xi_gossip_pallas` goes one step further for the gossip-averaged
variant (Alg. 2 step 6 replaced by eq. 17 consensus): the unfused path writes
the per-node xi [N, d] to HBM and then pays (deg+1)*R more passes over it for
the R gossip rounds. Here the xi tile is computed in-register per [N, block_d]
column tile and ALL R rounds of shift/weight/accumulate run on the resident
tile before the single write-back (the `kernels.consensus` trick applied to a
producer-consumer pair). The full-d reductions xi needs (s_n = Z_n w_n,
||w_n||^2) are accumulated by a first grid phase over the same tiles, so the
kernel streams Z twice and the [N, d] consensus state exactly once.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(w_ref, z_ref, o_ref, acc_ref, s2_ref, *, n_tiles: int, batch: int):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        s2_ref[...] = jnp.zeros_like(s2_ref)

    z = z_ref[...].astype(jnp.float32)  # [tb, d]
    w = w_ref[...].astype(jnp.float32)  # [1, d]
    s = jax.lax.dot_general(z, w, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [tb, 1]
    acc_ref[...] += jax.lax.dot_general(s, z, (((0,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)  # [1, d]
    s2_ref[0, 0] += jnp.sum(s * s)

    @pl.when(t == n_tiles - 1)
    def _epilogue():
        wf = w_ref[...].astype(jnp.float32)
        nrm2 = jnp.maximum(jnp.sum(wf * wf), 1e-30)
        mean_s2 = s2_ref[0, 0] / batch
        o_ref[...] = (acc_ref[...] / batch - (mean_s2 / nrm2) * wf).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def krasulina_xi_pallas(w: jax.Array, z: jax.Array, *, block_b: int = 256,
                        interpret: bool = True) -> jax.Array:
    """w: [d]; z: [B, d] -> xi [d]. Pads B up to a multiple of block_b (zero rows
    contribute nothing to either accumulator, but the mean uses the true B)."""
    B, d = z.shape
    n_tiles = max(1, (B + block_b - 1) // block_b)
    pad = n_tiles * block_b - B
    if pad:
        z = jnp.pad(z, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, n_tiles=n_tiles, batch=B),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, d), lambda t: (0, 0)),  # w stays resident
            pl.BlockSpec((block_b, d), lambda t: (t, 0)),  # stream Z tiles
        ],
        out_specs=pl.BlockSpec((1, d), lambda t: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, d), w.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(w[None], z)
    return out[0]


def _xi_gossip_kernel(w_ref, z_ref, o_ref, s_ref, nrm2_ref, *,
                      shifts: Tuple[int, ...], weights: Tuple[float, ...],
                      rounds: int, batch_n: int):
    """Grid (2, n_tiles). Phase 0 accumulates the full-d reductions (s = Z w
    per node, ||w||^2 per node) tile by tile; phase 1 revisits each tile,
    forms the xi column block for all N nodes and runs every gossip round on
    the resident [N, block_d] tile before the one write-back."""
    p, t = pl.program_id(0), pl.program_id(1)
    w = w_ref[...].astype(jnp.float32)  # [N, bd]
    z = z_ref[...].astype(jnp.float32)  # [N, Bn, bd]

    @pl.when(p == 0)
    def _accumulate():
        @pl.when(t == 0)
        def _init():
            s_ref[...] = jnp.zeros_like(s_ref)
            nrm2_ref[...] = jnp.zeros_like(nrm2_ref)

        # s_n += Z_n[:, tile] @ w_n[tile]  (batched over the node axis)
        s_ref[...] += jax.lax.dot_general(
            z, w, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)  # [N, Bn]
        nrm2_ref[...] += jnp.sum(w * w, axis=1, keepdims=True)  # [N, 1]

    @pl.when(p == 1)
    def _xi_and_gossip():
        s = s_ref[...]  # [N, Bn], complete after phase 0
        nrm2 = jnp.maximum(nrm2_ref[...], 1e-30)  # [N, 1]
        coeff = jnp.sum(s * s, axis=1, keepdims=True) / (batch_n * nrm2)
        # xi tile: (1/Bn) Z^T s - (mean(s^2)/||w||^2) w, all nodes at once
        zts = jax.lax.dot_general(s, z, (((1,), (1,)), ((0,), (0,))),
                                  preferred_element_type=jnp.float32)  # [N, bd]
        h = zts / batch_n - coeff * w
        for _ in range(rounds):
            acc = None
            for sh, wt in zip(shifts, weights):
                msg = h if sh == 0 else pltpu.roll(h, sh, 0)
                term = wt * msg
                acc = term if acc is None else acc + term
            h = acc
        o_ref[...] = h.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("shifts", "weights", "rounds", "block_d",
                                    "interpret"))
def krasulina_xi_gossip_pallas(w: jax.Array, z: jax.Array,
                               shifts: Tuple[int, ...],
                               weights: Tuple[float, ...], rounds: int, *,
                               block_d: int = 512,
                               interpret: bool = True) -> jax.Array:
    """w: [N, d] per-node iterates; z: [N, Bn, d] per-node mini-batches ->
    [N, d] gossip-mixed pseudo-gradients: R rounds of
    `sum_s w_s * roll(xi, s, axis=0)` applied to xi_n = krasulina_xi(w_n, z_n).

    Pads d up to a multiple of block_d (zero columns contribute nothing to
    s/||w||^2 and stay zero through the rolls). The whole [N, Bn] s-matrix is
    kept in VMEM scratch, so Bn is assumed streaming-small (B/N per the
    splitter), not a full epoch."""
    n, bn, d = z.shape
    assert w.shape == (n, d), (w.shape, z.shape)
    shifts = tuple(int(s) % n for s in shifts)
    block_d = min(block_d, d)
    n_tiles = (d + block_d - 1) // block_d
    pad = n_tiles * block_d - d
    if pad:
        w = jnp.pad(w, ((0, 0), (0, pad)))
        z = jnp.pad(z, ((0, 0), (0, 0), (0, pad)))
    out = pl.pallas_call(
        functools.partial(_xi_gossip_kernel, shifts=shifts, weights=weights,
                          rounds=rounds, batch_n=bn),
        grid=(2, n_tiles),
        in_specs=[
            pl.BlockSpec((n, block_d), lambda p, t: (0, t)),
            pl.BlockSpec((n, bn, block_d), lambda p, t: (0, 0, t)),
        ],
        out_specs=pl.BlockSpec((n, block_d), lambda p, t: (0, t)),
        out_shape=jax.ShapeDtypeStruct((n, n_tiles * block_d), w.dtype),
        scratch_shapes=[
            pltpu.VMEM((n, bn), jnp.float32),
            pltpu.VMEM((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(w, z)
    return out[:, :d] if pad else out


# ---------------------------------------------------------------------------
# shard_map partitioning rule (sharded node axis)
# ---------------------------------------------------------------------------


def krasulina_xi_gossip_shard(w: jax.Array, z: jax.Array, sched, rounds: int,
                              mesh, node_axes: Tuple[str, ...],
                              axis: str) -> jax.Array:
    """Fused xi + R-round gossip over a node axis sharded across `node_axes`
    of `mesh` (`axis`: the nontrivial one the ppermute ring runs over).

    The xi pass (Alg. 2 step 4) is node-local — each shard computes its own
    rows' pseudo-gradients without any exchange — and only the consensus
    rounds communicate, as per-round halo ppermutes + fused slice-sum tile
    mixing (`kernels.consensus` shard rules). Matches the strict per-round
    oracle `ref.gossip_mix_ref(vmap(ref.krasulina_xi_ref), ...)` to f32
    round-off (xi itself is shard-invariant bitwise).
    w: [n, d], z: [n, B, d], both sharded on the node axis."""
    from jax.experimental import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.kernels import ref
    from repro.kernels.consensus import _ext_tile, _slice_round, halo_reach

    n = w.shape[0]
    extent = int(mesh.shape[axis])
    n_local = n // extent
    sched = tuple(sched)
    ru, rd = halo_reach(sched, n)

    def local(w_l, z_l):
        h = jax.vmap(ref.krasulina_xi_ref)(w_l, z_l)  # [n_local, d], no comms
        for _ in range(rounds):
            ext = _ext_tile(h, ru, rd, axis, extent, n_local)
            h = _slice_round(ext, sched, n, ru, n_local)
        return h

    wspec = P(node_axes, None)
    zspec = P(node_axes, None, None)
    return shard_map.shard_map(local, mesh=mesh, in_specs=(wspec, zspec),
                               out_specs=wspec)(w, z)
