"""Pallas TPU kernel: fused mini-batch Krasulina pseudo-gradient.

The paper's PCA hot spot (Alg. 2 steps 3-5) is, per node and round, a fused
BLAS-2 pass over the local mini-batch: s = Z w, then xi = Z^T s / B - (mean(s^2)
/ ||w||^2) w. A naive implementation streams Z from HBM twice (once for s, once
for Z^T s) or materializes B rank-1 updates. This kernel tiles Z into VMEM once
per block and accumulates both Z^T s and sum(s^2) in a single pass — arithmetic
intensity doubles versus the two-pass form, which matters because the op is
memory-bound (2*B*d flops over B*d*dtype bytes).

Grid: one sequential axis over batch tiles; accumulators live in VMEM scratch
and the epilogue (last tile) applies the w-correction term.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(w_ref, z_ref, o_ref, acc_ref, s2_ref, *, n_tiles: int, batch: int):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        s2_ref[...] = jnp.zeros_like(s2_ref)

    z = z_ref[...].astype(jnp.float32)  # [tb, d]
    w = w_ref[...].astype(jnp.float32)  # [1, d]
    s = jax.lax.dot_general(z, w, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [tb, 1]
    acc_ref[...] += jax.lax.dot_general(s, z, (((0,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)  # [1, d]
    s2_ref[0, 0] += jnp.sum(s * s)

    @pl.when(t == n_tiles - 1)
    def _epilogue():
        wf = w_ref[...].astype(jnp.float32)
        nrm2 = jnp.maximum(jnp.sum(wf * wf), 1e-30)
        mean_s2 = s2_ref[0, 0] / batch
        o_ref[...] = (acc_ref[...] / batch - (mean_s2 / nrm2) * wf).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def krasulina_xi_pallas(w: jax.Array, z: jax.Array, *, block_b: int = 256,
                        interpret: bool = True) -> jax.Array:
    """w: [d]; z: [B, d] -> xi [d]. Pads B up to a multiple of block_b (zero rows
    contribute nothing to either accumulator, but the mean uses the true B)."""
    B, d = z.shape
    n_tiles = max(1, (B + block_b - 1) // block_b)
    pad = n_tiles * block_b - B
    if pad:
        z = jnp.pad(z, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, n_tiles=n_tiles, batch=B),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, d), lambda t: (0, 0)),  # w stays resident
            pl.BlockSpec((block_b, d), lambda t: (t, 0)),  # stream Z tiles
        ],
        out_specs=pl.BlockSpec((1, d), lambda t: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, d), w.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(w[None], z)
    return out[0]
