"""jit'd public wrappers for the Pallas kernels.

On TPU the Pallas path compiles natively; on CPU (this container) the kernels
run in interpret mode for correctness validation, and callers that want XLA
performance on CPU use the jnp reference path. `use_pallas()` picks per backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.consensus import (gossip_mix_pallas, gossip_mix_quant_pallas,
                                     gossip_mix_quant_shard, gossip_mix_shard,
                                     shard_compatible)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.krasulina_update import (krasulina_xi_gossip_pallas,
                                            krasulina_xi_gossip_shard,
                                            krasulina_xi_pallas)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def node_shard_info(mesh, n: int, sched=None):
    """(node_axes, ring_axis) when the `kernels.consensus` shard rules cover
    mixing an [n, ...] buffer on this mesh, else None.

    Covered: the mesh's node axes ("pod"/"data") shard the node dimension with
    exactly one nontrivial axis (the ppermute ring), even row tiles, and — when
    `sched` is given — a one-round halo reach neighbors can serve."""
    if mesh is None:
        return None
    node_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    sizes = [int(mesh.shape[a]) for a in node_axes]
    live = [a for a, s in zip(node_axes, sizes) if s > 1]
    if len(live) != 1:
        return None  # unsharded, or a ring spanning two mesh axes
    extent = sizes[node_axes.index(live[0])]
    if n % extent or extent > n:
        return None
    if sched is not None and not shard_compatible(sched, n, extent):
        return None
    return node_axes, live[0]


def gossip_mix(x: jax.Array, sched, rounds: int, *,
               force_pallas: bool = False) -> jax.Array:
    """R rounds of circulant gossip consensus over axis 0 (eq. 17), fused into
    a single HBM pass on TPU. `sched`: ((shift, weight), ...) one-round
    schedule. Unquantized path — quantized gossip goes through
    `quant_gossip_mix` (tile stats) or the per-round loop in
    `core.mixing.CirculantMixOp` (global-stats oracle)."""
    shifts = tuple(s for s, _ in sched)
    weights = tuple(w for _, w in sched)
    if _on_tpu() or force_pallas:
        return gossip_mix_pallas(x, shifts, weights, rounds,
                                 interpret=not _on_tpu())
    return ref.gossip_mix_ref(x, sched, rounds)


def quant_gossip_mix(x: jax.Array, sched, rounds: int, quantization: str, *,
                     block_d: int = 512, valid_d=None, key=None,
                     force_pallas: bool = False,
                     per_node: bool = False) -> jax.Array:
    """R rounds of QUANTIZED gossip with per-[n, block_d]-tile compressor
    statistics (the `stats="tile"` fused path), one HBM read+write per buffer
    on TPU. The stochastic int8 compressor and off-TPU callers take the
    single-dispatch XLA tile chain (`ref.gossip_mix_quant_ref`) so threefry
    randomness is backend-independent and CPU keeps XLA performance.
    `per_node=True` selects sender-local row-tile statistics (`stats="node"`,
    the sharded wire's granularity) — XLA tile chain only, no fused kernel."""
    fuse = (_on_tpu() or force_pallas) and quantization in ("sign", "int8") \
        and not per_node
    if per_node:
        return ref.gossip_mix_quant_ref(x, sched, rounds, quantization,
                                        block_d=block_d, valid_d=valid_d,
                                        key=key, per_node=True)
    if fuse:
        shifts = tuple(s for s, _ in sched)
        weights = tuple(w for _, w in sched)
        return gossip_mix_quant_pallas(
            x, shifts, weights, rounds, quantization, block_d=block_d,
            valid_d=-1 if valid_d is None else valid_d,
            interpret=not _on_tpu())
    return ref.gossip_mix_quant_ref(x, sched, rounds, quantization,
                                    block_d=block_d, valid_d=valid_d, key=key)


def sharded_gossip_mix(x: jax.Array, sched, rounds: int, mesh,
                       node_axes, ring_axis: str) -> jax.Array:
    """R rounds of gossip on a node axis sharded over `mesh` — the shard_map
    partitioning rule (per-round halo ppermutes + fused slice-sum tile mixing)
    replacing the roll fallback. Bit-identical to `ref.gossip_mix_ref`; pass
    the (node_axes, ring_axis) pair from `node_shard_info`."""
    return gossip_mix_shard(x, sched, rounds, mesh, tuple(node_axes),
                            ring_axis)


def sharded_quant_gossip_mix(x: jax.Array, sched, rounds: int,
                             quantization: str, mesh, node_axes,
                             ring_axis: str, *, block_d: int = 512,
                             valid_d=None, key=None) -> jax.Array:
    """Quantized gossip on a sharded node axis with per-node tile statistics
    (`stats="node"` — sender-local scales, the only granularity invariant
    under the device split). Matches `ref.gossip_mix_quant_ref(...,
    per_node=True)` — wire values bit-identically, sums to f32 round-off."""
    return gossip_mix_quant_shard(
        x, sched, rounds, quantization, mesh, tuple(node_axes), ring_axis,
        block_d=block_d, valid_d=-1 if valid_d is None else valid_d, key=key)


def sharded_krasulina_xi_gossip(w: jax.Array, z: jax.Array, sched,
                                rounds: int, mesh, node_axes,
                                ring_axis: str) -> jax.Array:
    """Fused xi + R-round gossip on a sharded node axis: xi is node-local per
    shard, only the consensus rounds communicate. Matches the strict
    per-round oracle `gossip_mix_ref(vmap(krasulina_xi_ref), ...)` to f32
    round-off."""
    return krasulina_xi_gossip_shard(w, z, sched, rounds, mesh,
                                     tuple(node_axes), ring_axis)


def krasulina_xi(w: jax.Array, z: jax.Array, *, force_pallas: bool = False) -> jax.Array:
    """Fused mini-batch Krasulina pseudo-gradient (Alg. 2 steps 3-5)."""
    if _on_tpu() or force_pallas:
        return krasulina_xi_pallas(w, z, interpret=not _on_tpu())
    return ref.krasulina_xi_ref(w, z)


def krasulina_xi_gossip(w: jax.Array, z: jax.Array, sched, rounds: int, *,
                        block_d: int = 512,
                        force_pallas: bool = False) -> jax.Array:
    """Fused D-Krasulina hot path: per-node pseudo-gradients (Alg. 2 steps
    3-5) + ALL R gossip rounds (eq. 17) in one pass. w: [N, d]; z: [N, Bn, d];
    `sched`: ((shift, weight), ...) one-round circulant schedule. On TPU the
    Pallas kernel keeps each [N, block_d] xi tile resident through every
    round (one HBM write of the consensus state); off-TPU the XLA reference
    applies the composed R-round schedule in a single weighted-roll pass."""
    if _on_tpu() or force_pallas:
        shifts = tuple(s for s, _ in sched)
        weights = tuple(w_ for _, w_ in sched)
        return krasulina_xi_gossip_pallas(w, z, shifts, weights, rounds,
                                          block_d=block_d,
                                          interpret=not _on_tpu())
    return ref.krasulina_xi_gossip_ref(w, z, sched, rounds)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
              window: int = 0, chunk: int = 0, force_pallas: bool = False) -> jax.Array:
    """Blockwise attention, [B, H, S, D] layout, GQA pre-broadcast."""
    if _on_tpu() or force_pallas:
        return flash_attention(q, k, v, causal=causal, window=window, chunk=chunk,
                               interpret=not _on_tpu())
    return ref.attention_ref(q, k, v, causal=causal, window=window, chunk=chunk)
