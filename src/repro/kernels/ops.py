"""jit'd public wrappers for the Pallas kernels.

On TPU the Pallas path compiles natively; on CPU (this container) the kernels
run in interpret mode for correctness validation, and callers that want XLA
performance on CPU use the jnp reference path. `use_pallas()` picks per backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.consensus import gossip_mix_pallas, gossip_mix_quant_pallas
from repro.kernels.flash_attention import flash_attention
from repro.kernels.krasulina_update import (krasulina_xi_gossip_pallas,
                                            krasulina_xi_pallas)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gossip_mix(x: jax.Array, sched, rounds: int, *,
               force_pallas: bool = False) -> jax.Array:
    """R rounds of circulant gossip consensus over axis 0 (eq. 17), fused into
    a single HBM pass on TPU. `sched`: ((shift, weight), ...) one-round
    schedule. Unquantized path — quantized gossip goes through
    `quant_gossip_mix` (tile stats) or the per-round loop in
    `core.mixing.CirculantMixOp` (global-stats oracle)."""
    shifts = tuple(s for s, _ in sched)
    weights = tuple(w for _, w in sched)
    if _on_tpu() or force_pallas:
        return gossip_mix_pallas(x, shifts, weights, rounds,
                                 interpret=not _on_tpu())
    return ref.gossip_mix_ref(x, sched, rounds)


def quant_gossip_mix(x: jax.Array, sched, rounds: int, quantization: str, *,
                     block_d: int = 512, valid_d=None, key=None,
                     force_pallas: bool = False) -> jax.Array:
    """R rounds of QUANTIZED gossip with per-[n, block_d]-tile compressor
    statistics (the `stats="tile"` fused path), one HBM read+write per buffer
    on TPU. The stochastic int8 compressor and off-TPU callers take the
    single-dispatch XLA tile chain (`ref.gossip_mix_quant_ref`) so threefry
    randomness is backend-independent and CPU keeps XLA performance."""
    fuse = (_on_tpu() or force_pallas) and quantization in ("sign", "int8")
    if fuse:
        shifts = tuple(s for s, _ in sched)
        weights = tuple(w for _, w in sched)
        return gossip_mix_quant_pallas(
            x, shifts, weights, rounds, quantization, block_d=block_d,
            valid_d=-1 if valid_d is None else valid_d,
            interpret=not _on_tpu())
    return ref.gossip_mix_quant_ref(x, sched, rounds, quantization,
                                    block_d=block_d, valid_d=valid_d, key=key)


def krasulina_xi(w: jax.Array, z: jax.Array, *, force_pallas: bool = False) -> jax.Array:
    """Fused mini-batch Krasulina pseudo-gradient (Alg. 2 steps 3-5)."""
    if _on_tpu() or force_pallas:
        return krasulina_xi_pallas(w, z, interpret=not _on_tpu())
    return ref.krasulina_xi_ref(w, z)


def krasulina_xi_gossip(w: jax.Array, z: jax.Array, sched, rounds: int, *,
                        block_d: int = 512,
                        force_pallas: bool = False) -> jax.Array:
    """Fused D-Krasulina hot path: per-node pseudo-gradients (Alg. 2 steps
    3-5) + ALL R gossip rounds (eq. 17) in one pass. w: [N, d]; z: [N, Bn, d];
    `sched`: ((shift, weight), ...) one-round circulant schedule. On TPU the
    Pallas kernel keeps each [N, block_d] xi tile resident through every
    round (one HBM write of the consensus state); off-TPU the XLA reference
    applies the composed R-round schedule in a single weighted-roll pass."""
    if _on_tpu() or force_pallas:
        shifts = tuple(s for s, _ in sched)
        weights = tuple(w_ for _, w_ in sched)
        return krasulina_xi_gossip_pallas(w, z, shifts, weights, rounds,
                                          block_d=block_d,
                                          interpret=not _on_tpu())
    return ref.krasulina_xi_gossip_ref(w, z, sched, rounds)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
              window: int = 0, chunk: int = 0, force_pallas: bool = False) -> jax.Array:
    """Blockwise attention, [B, H, S, D] layout, GQA pre-broadcast."""
    if _on_tpu() or force_pallas:
        return flash_attention(q, k, v, causal=causal, window=window, chunk=chunk,
                               interpret=not _on_tpu())
    return ref.attention_ref(q, k, v, causal=causal, window=window, chunk=chunk)
