"""jit'd public wrappers for the Pallas kernels.

On TPU the Pallas path compiles natively; on CPU (this container) the kernels
run in interpret mode for correctness validation, and callers that want XLA
performance on CPU use the jnp reference path. `use_pallas()` picks per backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.krasulina_update import krasulina_xi_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def krasulina_xi(w: jax.Array, z: jax.Array, *, force_pallas: bool = False) -> jax.Array:
    """Fused mini-batch Krasulina pseudo-gradient (Alg. 2 steps 3-5)."""
    if _on_tpu() or force_pallas:
        return krasulina_xi_pallas(w, z, interpret=not _on_tpu())
    return ref.krasulina_xi_ref(w, z)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
              window: int = 0, chunk: int = 0, force_pallas: bool = False) -> jax.Array:
    """Blockwise attention, [B, H, S, D] layout, GQA pre-broadcast."""
    if _on_tpu() or force_pallas:
        return flash_attention(q, k, v, causal=causal, window=window, chunk=chunk,
                               interpret=not _on_tpu())
    return ref.attention_ref(q, k, v, causal=causal, window=window, chunk=chunk)
