"""jit'd public wrappers for the Pallas kernels.

On TPU the Pallas path compiles natively; on CPU (this container) the kernels
run in interpret mode for correctness validation, and callers that want XLA
performance on CPU use the jnp reference path. `use_pallas()` picks per backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.consensus import gossip_mix_pallas
from repro.kernels.flash_attention import flash_attention
from repro.kernels.krasulina_update import krasulina_xi_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gossip_mix(x: jax.Array, sched, rounds: int, *,
               force_pallas: bool = False) -> jax.Array:
    """R rounds of circulant gossip consensus over axis 0 (eq. 17), fused into
    a single HBM pass on TPU. `sched`: ((shift, weight), ...) one-round
    schedule. Unquantized path only — quantized gossip keeps the per-round
    loop in `core.mixing.CirculantMixOp`."""
    shifts = tuple(s for s, _ in sched)
    weights = tuple(w for _, w in sched)
    if _on_tpu() or force_pallas:
        return gossip_mix_pallas(x, shifts, weights, rounds,
                                 interpret=not _on_tpu())
    return ref.gossip_mix_ref(x, sched, rounds)


def krasulina_xi(w: jax.Array, z: jax.Array, *, force_pallas: bool = False) -> jax.Array:
    """Fused mini-batch Krasulina pseudo-gradient (Alg. 2 steps 3-5)."""
    if _on_tpu() or force_pallas:
        return krasulina_xi_pallas(w, z, interpret=not _on_tpu())
    return ref.krasulina_xi_ref(w, z)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
              window: int = 0, chunk: int = 0, force_pallas: bool = False) -> jax.Array:
    """Blockwise attention, [B, H, S, D] layout, GQA pre-broadcast."""
    if _on_tpu() or force_pallas:
        return flash_attention(q, k, v, causal=causal, window=window, chunk=chunk,
                               interpret=not _on_tpu())
    return ref.attention_ref(q, k, v, causal=causal, window=window, chunk=chunk)
