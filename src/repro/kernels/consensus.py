"""Pallas TPU kernels: fused R-round gossip consensus (paper eq. 17),
unquantized and quantized.

The reference device path applies R rounds of weighted circular shifts over the
node axis; each round reads and writes the full [N, d] leaf, so one consensus
step costs (deg+1)*R HBM passes. Since N (the node count) is small, these
kernels tile the [N, block_d] slab into VMEM once and run ALL R rounds of
shift/weight/accumulate in-register before writing back — one HBM read and one
HBM write per buffer regardless of R. The shift schedule and R are static, so
the round loop fully unrolls into VPU adds plus sublane rotations.

Message quantization (Section VI) is fused here too, with **per-tile**
compressor statistics (`gossip_mix_quant_pallas`): each [n, block_d] tile
computes its own scale (mean-|x| for sign, max-|x| for int8) in-register, so
quantized gossip also costs one HBM read+write per buffer instead of
(deg+1)*R passes. This changes the compressor's statistic granularity relative
to the whole-array ("global") form — `core.mixing.CirculantMixOp(stats=...)`
selects between the exact global-stats per-round oracle and this fused tile
form; `benchmarks/bench_consensus.py` carries the accuracy study. Ragged and
padded tails are masked out of every statistic (`valid_d`). The stochastic
int8 compressor stays on the XLA tile path (`core.quantize.tile_compress`) —
threefry keys, not in-kernel PRNG — so its randomness is identical on every
backend.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, o_ref, *, shifts: Tuple[int, ...], weights: Tuple[float, ...],
            rounds: int):
    h = x_ref[...].astype(jnp.float32)  # [n, block_d], resident for all rounds
    for _ in range(rounds):
        acc = None
        for s, w in zip(shifts, weights):
            msg = h if s == 0 else pltpu.roll(h, s, 0)
            term = w * msg
            acc = term if acc is None else acc + term
        h = acc
    o_ref[...] = h.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("shifts", "weights", "rounds", "block_d",
                                    "interpret"))
def gossip_mix_pallas(x: jax.Array, shifts: Tuple[int, ...],
                      weights: Tuple[float, ...], rounds: int, *,
                      block_d: int = 512, interpret: bool = True) -> jax.Array:
    """R rounds of `sum_s w_s * roll(x, s, axis=0)` in a single HBM pass.

    x: [n, ...] (any rank; trailing dims are flattened). shifts/weights: the
    one-round circulant schedule. Matches R sequential `roll_mix` applications
    (quantization off) to f32 accuracy.
    """
    n = x.shape[0]
    shifts = tuple(int(s) % n for s in shifts)
    orig_shape = x.shape
    flat = x.reshape(n, -1)
    d = flat.shape[1]
    block_d = min(block_d, d)
    n_tiles = (d + block_d - 1) // block_d
    pad = n_tiles * block_d - d
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    out = pl.pallas_call(
        functools.partial(_kernel, shifts=shifts, weights=weights,
                          rounds=rounds),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((n, block_d), lambda t: (0, t))],
        out_specs=pl.BlockSpec((n, block_d), lambda t: (0, t)),
        out_shape=jax.ShapeDtypeStruct(flat.shape, x.dtype),
        interpret=interpret,
    )(flat)
    if pad:
        out = out[:, :d]
    return out.reshape(orig_shape)


def _quant_kernel(x_ref, o_ref, *, shifts: Tuple[int, ...],
                  weights: Tuple[float, ...], rounds: int, quant: str,
                  block_d: int, valid_d: int):
    """All R quantized-gossip rounds on one resident [n, block_d] tile.

    Compress-once-broadcast per round: the tile scale is invariant under the
    node-axis roll (the roll permutes rows, the stat reduces over them), so
    each round quantizes the resident tile ONCE in-register and accumulates
    rolled copies of the compressed tile — the `stats="tile"` semantics
    `core.quantize.tile_compress` oracles. Columns past `valid_d` (ragged
    tail / caller padding) are zero on input, so they contribute nothing to
    the sum/max statistics; only the mean's element count needs the mask.
    """
    t = pl.program_id(0)
    h = x_ref[...].astype(jnp.float32)  # [n, block_d]
    col = jax.lax.broadcasted_iota(jnp.int32, h.shape, 1) + t * block_d
    nvalid = jnp.maximum(
        jnp.sum((col < valid_d).astype(jnp.float32)), 1.0)
    for _ in range(rounds):
        a = jnp.abs(h)
        if quant == "sign":
            q = jnp.sign(h) * (jnp.sum(a) / nvalid)
        else:  # int8
            scale = jnp.maximum(jnp.max(a), 1e-12) / 127.0
            q = jnp.clip(jnp.round(h / scale), -127, 127) * scale
        acc = None
        for s, w in zip(shifts, weights):
            term = w * (h if s == 0 else pltpu.roll(q, s, 0))
            acc = term if acc is None else acc + term
        h = acc
    o_ref[...] = h.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("shifts", "weights", "rounds", "quant",
                                    "block_d", "valid_d", "interpret"))
def gossip_mix_quant_pallas(x: jax.Array, shifts: Tuple[int, ...],
                            weights: Tuple[float, ...], rounds: int,
                            quant: str, *, block_d: int = 512,
                            valid_d: int = -1,
                            interpret: bool = True) -> jax.Array:
    """R rounds of quantized gossip (tile-statistics compressors) in a single
    HBM pass. quant: "sign" | "int8" (deterministic — the stochastic variant
    stays on the XLA path). `valid_d`: flattened columns >= valid_d are pad
    (must be zero) and are masked out of the compressor statistics; -1 means
    all columns are valid."""
    if quant not in ("sign", "int8"):
        raise ValueError(f"fused quantized kernel supports sign/int8, "
                         f"got {quant!r}")
    n = x.shape[0]
    shifts = tuple(int(s) % n for s in shifts)
    orig_shape = x.shape
    flat = x.reshape(n, -1)
    d = flat.shape[1]
    dv = d if valid_d < 0 else valid_d
    block_d = min(block_d, d)
    n_tiles = (d + block_d - 1) // block_d
    pad = n_tiles * block_d - d
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    out = pl.pallas_call(
        functools.partial(_quant_kernel, shifts=shifts, weights=weights,
                          rounds=rounds, quant=quant, block_d=block_d,
                          valid_d=dv),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((n, block_d), lambda t: (0, t))],
        out_specs=pl.BlockSpec((n, block_d), lambda t: (0, t)),
        out_shape=jax.ShapeDtypeStruct(flat.shape, x.dtype),
        interpret=interpret,
    )(flat)
    if pad:
        out = out[:, :d]
    return out.reshape(orig_shape)
