"""Pallas TPU kernels: fused R-round gossip consensus (paper eq. 17),
unquantized and quantized.

The reference device path applies R rounds of weighted circular shifts over the
node axis; each round reads and writes the full [N, d] leaf, so one consensus
step costs (deg+1)*R HBM passes. Since N (the node count) is small, these
kernels tile the [N, block_d] slab into VMEM once and run ALL R rounds of
shift/weight/accumulate in-register before writing back — one HBM read and one
HBM write per buffer regardless of R. The shift schedule and R are static, so
the round loop fully unrolls into VPU adds plus sublane rotations.

Message quantization (Section VI) is fused here too, with **per-tile**
compressor statistics (`gossip_mix_quant_pallas`): each [n, block_d] tile
computes its own scale (mean-|x| for sign, max-|x| for int8) in-register, so
quantized gossip also costs one HBM read+write per buffer instead of
(deg+1)*R passes. This changes the compressor's statistic granularity relative
to the whole-array ("global") form — `core.mixing.CirculantMixOp(stats=...)`
selects between the exact global-stats per-round oracle and this fused tile
form; `benchmarks/bench_consensus.py` carries the accuracy study. Ragged and
padded tails are masked out of every statistic (`valid_d`). The stochastic
int8 compressor stays on the XLA tile path (`core.quantize.tile_compress`) —
threefry keys, not in-kernel PRNG — so its randomness is identical on every
backend.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, o_ref, *, shifts: Tuple[int, ...], weights: Tuple[float, ...],
            rounds: int):
    h = x_ref[...].astype(jnp.float32)  # [n, block_d], resident for all rounds
    for _ in range(rounds):
        acc = None
        for s, w in zip(shifts, weights):
            msg = h if s == 0 else pltpu.roll(h, s, 0)
            term = w * msg
            acc = term if acc is None else acc + term
        h = acc
    o_ref[...] = h.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("shifts", "weights", "rounds", "block_d",
                                    "interpret"))
def gossip_mix_pallas(x: jax.Array, shifts: Tuple[int, ...],
                      weights: Tuple[float, ...], rounds: int, *,
                      block_d: int = 512, interpret: bool = True) -> jax.Array:
    """R rounds of `sum_s w_s * roll(x, s, axis=0)` in a single HBM pass.

    x: [n, ...] (any rank; trailing dims are flattened). shifts/weights: the
    one-round circulant schedule. Matches R sequential `roll_mix` applications
    (quantization off) to f32 accuracy.
    """
    n = x.shape[0]
    shifts = tuple(int(s) % n for s in shifts)
    orig_shape = x.shape
    flat = x.reshape(n, -1)
    d = flat.shape[1]
    block_d = min(block_d, d)
    n_tiles = (d + block_d - 1) // block_d
    pad = n_tiles * block_d - d
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    out = pl.pallas_call(
        functools.partial(_kernel, shifts=shifts, weights=weights,
                          rounds=rounds),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((n, block_d), lambda t: (0, t))],
        out_specs=pl.BlockSpec((n, block_d), lambda t: (0, t)),
        out_shape=jax.ShapeDtypeStruct(flat.shape, x.dtype),
        interpret=interpret,
    )(flat)
    if pad:
        out = out[:, :d]
    return out.reshape(orig_shape)


def _quant_kernel(x_ref, o_ref, *, shifts: Tuple[int, ...],
                  weights: Tuple[float, ...], rounds: int, quant: str,
                  block_d: int, valid_d: int):
    """All R quantized-gossip rounds on one resident [n, block_d] tile.

    Compress-once-broadcast per round: the tile scale is invariant under the
    node-axis roll (the roll permutes rows, the stat reduces over them), so
    each round quantizes the resident tile ONCE in-register and accumulates
    rolled copies of the compressed tile — the `stats="tile"` semantics
    `core.quantize.tile_compress` oracles. Columns past `valid_d` (ragged
    tail / caller padding) are zero on input, so they contribute nothing to
    the sum/max statistics; only the mean's element count needs the mask.
    """
    t = pl.program_id(0)
    h = x_ref[...].astype(jnp.float32)  # [n, block_d]
    col = jax.lax.broadcasted_iota(jnp.int32, h.shape, 1) + t * block_d
    nvalid = jnp.maximum(
        jnp.sum((col < valid_d).astype(jnp.float32)), 1.0)
    for _ in range(rounds):
        a = jnp.abs(h)
        if quant == "sign":
            q = jnp.sign(h) * (jnp.sum(a) / nvalid)
        else:  # int8
            scale = jnp.maximum(jnp.max(a), 1e-12) / 127.0
            q = jnp.clip(jnp.round(h / scale), -127, 127) * scale
        acc = None
        for s, w in zip(shifts, weights):
            term = w * (h if s == 0 else pltpu.roll(q, s, 0))
            acc = term if acc is None else acc + term
        h = acc
    o_ref[...] = h.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("shifts", "weights", "rounds", "quant",
                                    "block_d", "valid_d", "interpret"))
def gossip_mix_quant_pallas(x: jax.Array, shifts: Tuple[int, ...],
                            weights: Tuple[float, ...], rounds: int,
                            quant: str, *, block_d: int = 512,
                            valid_d: int = -1,
                            interpret: bool = True) -> jax.Array:
    """R rounds of quantized gossip (tile-statistics compressors) in a single
    HBM pass. quant: "sign" | "int8" (deterministic — the stochastic variant
    stays on the XLA path). `valid_d`: flattened columns >= valid_d are pad
    (must be zero) and are masked out of the compressor statistics; -1 means
    all columns are valid."""
    if quant not in ("sign", "int8"):
        raise ValueError(f"fused quantized kernel supports sign/int8, "
                         f"got {quant!r}")
    n = x.shape[0]
    shifts = tuple(int(s) % n for s in shifts)
    orig_shape = x.shape
    flat = x.reshape(n, -1)
    d = flat.shape[1]
    dv = d if valid_d < 0 else valid_d
    block_d = min(block_d, d)
    n_tiles = (d + block_d - 1) // block_d
    pad = n_tiles * block_d - d
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    out = pl.pallas_call(
        functools.partial(_quant_kernel, shifts=shifts, weights=weights,
                          rounds=rounds, quant=quant, block_d=block_d,
                          valid_d=dv),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((n, block_d), lambda t: (0, t))],
        out_specs=pl.BlockSpec((n, block_d), lambda t: (0, t)),
        out_shape=jax.ShapeDtypeStruct(flat.shape, x.dtype),
        interpret=interpret,
    )(flat)
    if pad:
        out = out[:, :d]
    return out.reshape(orig_shape)


# ---------------------------------------------------------------------------
# shard_map partitioning rules (sharded node axis)
#
# GSPMD has no good partition for the circular-shift form: `jnp.roll` on a
# sharded axis lowers to a collective-permute plus a wraparound concat that
# XLA cannot fuse into the weighted sum, so every schedule term pays a full
# pass over the local shard. These rules partition the gossip explicitly:
# each round exchanges only the halo rows the schedule reaches (a
# `lax.ppermute` ring, one hop per n_local rows of reach), builds a
# halo-extended local tile, and applies the round as a weighted sum of
# *contiguous slices* — no wraparound, so XLA fuses the whole round into one
# pass over the tile. Per-round semantics are preserved (bit-identical to
# `ref.gossip_mix_ref`), which is exactly the form the quantized wire path
# requires. The local slice-sum is the kernel's tile mixing restricted to a
# shard; on real TPU the same extended-tile form is the candidate body for a
# per-shard `pallas_call` (ROADMAP: real-TPU validation debt).
# ---------------------------------------------------------------------------


def centered_shift(s: int, n: int) -> int:
    """Canonical shift representative in (-n/2, n/2]."""
    s = s % n
    return s if s <= n // 2 else s - n


def halo_reach(sched, n: int) -> Tuple[int, int]:
    """(rows needed from preceding shards, rows from following shards) for one
    round of `sched` on an [n, ...] buffer: roll by +s pulls rows from s above."""
    up = max((centered_shift(s, n) for s, _ in sched
              if centered_shift(s, n) > 0), default=0)
    down = max((-centered_shift(s, n) for s, _ in sched
                if centered_shift(s, n) < 0), default=0)
    return up, down


def _gather_halo(h, reach: int, axis, extent: int, n_local: int, up: bool):
    """Collect `reach` boundary rows from ring neighbors, one whole-tile hop
    per n_local rows (ceil(reach / n_local) ppermutes)."""
    rows = []
    need, hop = reach, 1
    while need > 0:
        take = min(need, n_local)
        if up:  # rows preceding this shard: tail rows of device i-hop
            rows.insert(0, jax.lax.ppermute(
                h[n_local - take:], axis,
                [(i, (i + hop) % extent) for i in range(extent)]))
        else:   # rows following: head rows of device i+hop
            rows.append(jax.lax.ppermute(
                h[:take], axis,
                [(i, (i - hop) % extent) for i in range(extent)]))
        need -= take
        hop += 1
    return rows


def _ext_tile(h, ru: int, rd: int, axis, extent: int, n_local: int):
    up = _gather_halo(h, ru, axis, extent, n_local, up=True)
    dn = _gather_halo(h, rd, axis, extent, n_local, up=False)
    return jnp.concatenate(up + [h] + dn, axis=0) if (up or dn) else h


def _slice_round(ext, sched, n: int, ru: int, n_local: int, self_term=None):
    """One gossip round as a weighted sum of contiguous row slices of the
    halo-extended tile. `self_term` (optional) substitutes the s==0 source —
    the quantized wire keeps the resident tile uncompressed for itself."""
    acc = None
    for s, w in sched:
        sc = centered_shift(s, n)
        if sc == 0 and self_term is not None:
            t = w * self_term
        else:
            t = w * jax.lax.slice_in_dim(ext, ru - sc, ru - sc + n_local, axis=0)
        acc = t if acc is None else acc + t
    return acc


def shard_compatible(sched, n: int, extent: int) -> bool:
    """True when the halo rules cover this (schedule, split): even row tiles
    and a one-round reach that neighbors can serve without wrapping onto the
    resident shard."""
    if extent <= 1 or n % extent:
        return False
    ru, rd = halo_reach(sched, n)
    return ru + rd <= n - n // extent


def gossip_mix_shard(x: jax.Array, sched, rounds: int, mesh,
                     node_axes: Tuple[str, ...], axis: str) -> jax.Array:
    """R rounds of circulant gossip over a node axis sharded across
    `node_axes` of `mesh` (`axis`: the single nontrivial one the ppermute ring
    runs over). Per-round halo exchange + fused local slice-sum; bit-identical
    to `ref.gossip_mix_ref`."""
    from jax.experimental import shard_map
    from jax.sharding import PartitionSpec as P

    n = x.shape[0]
    extent = int(mesh.shape[axis])
    n_local = n // extent
    sched = tuple(sched)
    ru, rd = halo_reach(sched, n)

    def local(h):
        shape = h.shape
        h = h.reshape(n_local, -1)
        for _ in range(rounds):
            ext = _ext_tile(h, ru, rd, axis, extent, n_local)
            h = _slice_round(ext, sched, n, ru, n_local)
        return h.reshape(shape)

    spec = P(node_axes, *([None] * (x.ndim - 1)))
    return shard_map.shard_map(local, mesh=mesh, in_specs=spec,
                               out_specs=spec)(x)


def gossip_mix_quant_shard(x: jax.Array, sched, rounds: int, quant: str,
                           mesh, node_axes: Tuple[str, ...], axis: str, *,
                           block_d: int = 512, valid_d: int = -1,
                           key=None) -> jax.Array:
    """Quantized per-round gossip on a sharded node axis with **per-node**
    tile statistics (`core.quantize.tile_compress(per_node=True)`): each node
    scales its outgoing message from its own rows — the statistic a real
    sender can compute locally — so the compressed wire values are invariant
    under the device split and the sharded path matches the unsharded
    `stats="node"` oracle (`ref.gossip_mix_quant_ref(per_node=True)`) — wire
    values bit-identically, the weighted sum to f32 round-off (program
    layouts associate the accumulation differently).
    Stochastic compressors fold the shard index into the key (deterministic,
    but layout-dependent noise — sign/int8 are layout-invariant)."""
    from repro.core.quantize import STOCHASTIC, tile_compress
    from jax.experimental import shard_map
    from jax.sharding import PartitionSpec as P

    n = x.shape[0]
    extent = int(mesh.shape[axis])
    n_local = n // extent
    sched = tuple(sched)
    ru, rd = halo_reach(sched, n)
    dv = None if valid_d is None or valid_d < 0 else valid_d

    def local(h):
        shape = h.shape
        h = h.reshape(n_local, -1).astype(jnp.float32)
        k0 = key
        if quant in STOCHASTIC and k0 is not None:
            k0 = jax.random.fold_in(k0, jax.lax.axis_index(axis))
        for r in range(rounds):
            k = jax.random.fold_in(k0, r) if k0 is not None else None
            q = tile_compress(h, quant, block_d, valid_d=dv, key=k,
                              per_node=True)
            ext = _ext_tile(q, ru, rd, axis, extent, n_local)
            h = _slice_round(ext, sched, n, ru, n_local, self_term=h)
        return h.reshape(shape).astype(x.dtype)

    spec = P(node_axes, *([None] * (x.ndim - 1)))
    return shard_map.shard_map(local, mesh=mesh, in_specs=spec,
                               out_specs=spec)(x)
