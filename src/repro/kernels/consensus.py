"""Pallas TPU kernel: fused R-round gossip consensus (paper eq. 17).

The reference device path applies R rounds of weighted circular shifts over the
node axis; each round reads and writes the full [N, d] leaf, so one consensus
step costs (deg+1)*R HBM passes. Since N (the node count) is small, this kernel
tiles the [N, block_d] slab into VMEM once and runs ALL R rounds of
shift/weight/accumulate in-register before writing back — one HBM read and one
HBM write per leaf regardless of R. The shift schedule and R are static, so the
round loop fully unrolls into VPU adds plus sublane rotations.

Message quantization (Section VI) is deliberately NOT fused here: the
compressors are nonlinear with *global* (whole-leaf) statistics, so a tiled
in-register pass would change their semantics. Quantized configs keep the exact
per-round XLA loop (see `core.mixing.CirculantMixOp`).
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, o_ref, *, shifts: Tuple[int, ...], weights: Tuple[float, ...],
            rounds: int):
    h = x_ref[...].astype(jnp.float32)  # [n, block_d], resident for all rounds
    for _ in range(rounds):
        acc = None
        for s, w in zip(shifts, weights):
            msg = h if s == 0 else pltpu.roll(h, s, 0)
            term = w * msg
            acc = term if acc is None else acc + term
        h = acc
    o_ref[...] = h.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("shifts", "weights", "rounds", "block_d",
                                    "interpret"))
def gossip_mix_pallas(x: jax.Array, shifts: Tuple[int, ...],
                      weights: Tuple[float, ...], rounds: int, *,
                      block_d: int = 512, interpret: bool = True) -> jax.Array:
    """R rounds of `sum_s w_s * roll(x, s, axis=0)` in a single HBM pass.

    x: [n, ...] (any rank; trailing dims are flattened). shifts/weights: the
    one-round circulant schedule. Matches R sequential `roll_mix` applications
    (quantization off) to f32 accuracy.
    """
    n = x.shape[0]
    shifts = tuple(int(s) % n for s in shifts)
    orig_shape = x.shape
    flat = x.reshape(n, -1)
    d = flat.shape[1]
    block_d = min(block_d, d)
    n_tiles = (d + block_d - 1) // block_d
    pad = n_tiles * block_d - d
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    out = pl.pallas_call(
        functools.partial(_kernel, shifts=shifts, weights=weights,
                          rounds=rounds),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((n, block_d), lambda t: (0, t))],
        out_specs=pl.BlockSpec((n, block_d), lambda t: (0, t)),
        out_shape=jax.ShapeDtypeStruct(flat.shape, x.dtype),
        interpret=interpret,
    )(flat)
    if pad:
        out = out[:, :d]
    return out.reshape(orig_shape)
