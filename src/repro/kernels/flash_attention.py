"""Pallas TPU kernel: blockwise (flash) attention with causal, sliding-window and
chunked-local masking — the serving/backbone hot spot of the framework.

TPU adaptation notes (vs. the CUDA flash-attention algorithm):
* tiles are MXU-aligned (block_q x block_k >= 128x128) and live in VMEM;
* the kv axis is the innermost *sequential* grid dimension, so the online-softmax
  running max / sum / accumulator persist in VMEM scratch across kv steps
  (no atomics / shared-memory reductions, which have no TPU analogue);
* fully-masked (q, kv) block pairs are skipped with `pl.when` — for sliding
  windows this turns O(S^2) into O(S * window) compute.

Softmax statistics are kept as (block_q, 128) tiles (lane-replicated) to stay
vector-register-shaped on TPU.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale, causal, window, chunk, block_q, block_k, n_k):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_first = qi * block_q
    q_last = q_first + block_q - 1
    k_first = ki * block_k
    k_last = k_first + block_k - 1

    # block-level skip predicate (structural sparsity)
    live = True
    if causal:
        live = jnp.logical_and(live, k_first <= q_last)
    if window:
        live = jnp.logical_and(live, k_last > q_first - window)
    if chunk:
        live = jnp.logical_and(live, (k_first // chunk) <= (q_last // chunk))
        live = jnp.logical_and(live, (k_last // chunk) >= (q_first // chunk))

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # [bq, d]
        k = k_ref[0].astype(jnp.float32)  # [bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qp = q_first + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kp = k_first + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= kp <= qp
        if window:
            mask &= kp > qp - window
        if chunk:
            mask &= (kp // chunk) == (qp // chunk)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]  # [bq, 1]
        m_blk = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_blk)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)  # [bq, 1]
        l_new = l_ref[:, :1] * corr + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(p, v_ref[0].astype(jnp.float32),
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == n_k - 1)
    def _epilogue():
        l = l_ref[:, :1]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "chunk", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0, chunk: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q/k/v: [B, H, S, D] (GQA heads pre-broadcast). Returns [B, H, Sq, D]."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    scale = 1.0 / math.sqrt(D)
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    nq = (Sq + bq - 1) // bq
    nk = (Sk + bk - 1) // bk
    if nq * bq != Sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, nq * bq - Sq), (0, 0)))
    if nk * bk != Sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, nk * bk - Sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, nk * bk - Sk), (0, 0)))
        if not (causal or window or chunk):
            raise ValueError("unmasked attention requires Sk divisible by block_k")

    qf = q.reshape(B * H, nq * bq, D)
    kf = k.reshape(B * H, nk * bk, D)
    vf = v.reshape(B * H, nk * bk, D)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, chunk=chunk, block_q=bq, block_k=bk,
                          n_k=nk),
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, nq * bq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, nq * bq, D)[:, :, :Sq]
