"""Pure-jnp oracles for the Pallas kernels. These are the ground truth the
kernels are validated against (per-kernel allclose sweeps in tests/test_kernels.py).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def krasulina_xi_ref(w: jax.Array, z: jax.Array) -> jax.Array:
    """Mini-batch Krasulina pseudo-gradient (Alg. 2 step 4, batch-averaged).

    w: [d]; z: [B, d]. xi = (1/B) Z^T (Z w) - (mean((Zw)^2) / ||w||^2) w.
    """
    zw = z.astype(jnp.float32) @ w.astype(jnp.float32)
    nrm2 = jnp.maximum(jnp.sum(w.astype(jnp.float32) ** 2), 1e-30)
    xi = (z.astype(jnp.float32).T @ zw) / z.shape[0] - (
        jnp.mean(zw**2) / nrm2) * w.astype(jnp.float32)
    return xi.astype(w.dtype)


def krasulina_xi_gossip_ref(w: jax.Array, z: jax.Array, sched,
                            rounds: int) -> jax.Array:
    """Fused D-Krasulina consensus step: per-node pseudo-gradients followed by
    R rounds of circulant gossip, as ONE pass — xi via `krasulina_xi_ref` and
    the R-round schedule collapsed by `core.mixing.compose_schedule` (the
    consensus is linear, so the composition is exact up to f32 reassociation).
    This is the XLA oracle (and CPU execution path) for
    `kernels.krasulina_update.krasulina_xi_gossip_pallas`; the strict
    per-round form is `gossip_mix_ref(vmap(krasulina_xi_ref), sched, rounds)`.
    """
    from repro.core.mixing import compose_schedule

    xi = jax.vmap(krasulina_xi_ref)(w, z)
    if rounds == 0 or w.shape[0] == 1:
        return xi
    fused = compose_schedule(sched, rounds, w.shape[0])
    return gossip_mix_ref(xi, fused, 1)


def gossip_mix_ref(x: jax.Array, sched, rounds: int) -> jax.Array:
    """R sequential rounds of weighted circular shifts over axis 0 — the
    uncompressed gossip oracle the fused consensus kernel is validated against.
    """
    for _ in range(rounds):
        out = None
        for shift, w in sched:
            term = w * (x if shift == 0 else jnp.roll(x, shift, axis=0))
            out = term if out is None else out + term
        x = out
    return x


def gossip_mix_quant_ref(x: jax.Array, sched, rounds: int, quant: str, *,
                         block_d: int = 512, valid_d: Optional[int] = None,
                         key=None, per_node: bool = False) -> jax.Array:
    """R rounds of quantized gossip with per-[n, block_d]-tile compressor
    statistics — the XLA oracle (and CPU execution path) for
    `kernels.consensus.gossip_mix_quant_pallas`, plus the keyed stochastic
    variant the kernel does not fuse. One jitted chain over one flat buffer;
    per-round nonlinearity is preserved (no operator collapsing).

    Compress-once-broadcast: tile scales are roll-invariant (the roll permutes
    rows, the stats reduce over them), so each round quantizes the buffer ONCE
    and rolls the compressed copy — identical in exact arithmetic to
    compressing every rolled message, at (1 compress + deg rolls) per round.

    `per_node=True` selects per-[1, block_d] row-tile statistics (sender-local
    scales, `stats="node"`): still compress-once-broadcast — each node's scale
    travels with its rows under the roll — and the oracle for the sharded
    wire path `kernels.consensus.gossip_mix_quant_shard`."""
    from repro.core.quantize import tile_compress

    n = x.shape[0]
    orig_shape = x.shape
    h = x.reshape(n, -1).astype(jnp.float32)
    for r in range(rounds):
        k = jax.random.fold_in(key, r) if key is not None else None
        q = tile_compress(h, quant, block_d, valid_d=valid_d, key=k,
                          per_node=per_node)
        out = None
        for shift, w in sched:
            term = w * (h if shift == 0 else jnp.roll(q, shift, axis=0))
            out = term if out is None else out + term
        h = out
    return h.reshape(orig_shape).astype(x.dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0, chunk: int = 0,
                  scale: Optional[float] = None) -> jax.Array:
    """Dense masked attention. q/k/v: [B, H, S, D] (same head count)."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= kp > qp - window
    if chunk:
        mask &= (kp // chunk) == (qp // chunk)
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(v.dtype)
