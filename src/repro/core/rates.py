"""The paper's rate model (Section II-C): streaming rate R_s, per-node compute
rate R_p, communications rate R_c, consensus rounds R, network-wide mini-batch B,
N nodes — and the provisioning planner implied by Theorems 4-7.

    R_e  = ( B/(N*R_p) + R/R_c )^-1                      (eq. 4)
    R   <= floor( B*R_c * (1/R_s - 1/(N*R_p)) )          (eq. 3)

A system keeps up with the stream iff R_s <= B*R_e; otherwise it must discard
mu = R_s/R_e - B samples per round (Algorithms 1-2, steps 9-10).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

from repro.configs.base import StreamConfig


def effective_rate(B: float, N: int, R: int, Rp: float, Rc: float) -> float:
    """Mini-batches per second the network can process (eq. 4)."""
    return 1.0 / (B / (N * Rp) + R / Rc)


def max_rounds(B: float, N: int, Rs: float, Rp: float, Rc: float) -> int:
    """Largest R compatible with keeping up with the stream (eq. 3)."""
    slack = 1.0 / Rs - 1.0 / (N * Rp)
    return max(0, math.floor(B * Rc * slack))


def discards_per_round(B: int, N: int, R: int, Rs: float, Rp: float, Rc: float) -> int:
    """mu = max(0, R_s/R_e - B): samples dropped at the splitter per round."""
    Re = effective_rate(B, N, R, Rp, Rc)
    # epsilon guard: B chosen exactly at the keep-up boundary must give mu = 0
    return max(0, math.ceil(Rs / Re - B - 1e-9))


@dataclass(frozen=True)
class Plan:
    B: int
    mu: int
    R: int
    Re: float
    regime: str  # "resourceful" | "under-provisioned"


def plan(stream: StreamConfig, N: int, R: int, *, B: Optional[int] = None,
         horizon_samples: Optional[float] = None) -> Plan:
    """Choose (B, mu) for a stream. If B is not given, pick the smallest B that
    keeps up (R_s <= B*R_e), clipped to the order-optimality ceiling
    B <= sqrt(t') from Theorem 4 when a sample horizon is known."""
    Rs, Rp, Rc = stream.streaming_rate, stream.processing_rate, stream.comms_rate
    if B is None:
        # R_s <= B*R_e  <=>  R_s*(B/(N Rp) + R/Rc) <= B
        #              <=>  B*(1 - Rs/(N Rp)) >= Rs*R/Rc
        denom = 1.0 - Rs / (N * Rp)
        if denom <= 0:
            raise ValueError(
                f"stream faster than total compute: R_s={Rs} >= N*R_p={N * Rp}")
        B = max(N, math.ceil((Rs * R / Rc) / denom))
        B = ((B + N - 1) // N) * N  # B must split evenly across nodes
    if horizon_samples:
        ceiling = max(N, int(math.sqrt(horizon_samples)))
        ceiling = (ceiling // N) * N or N
        B = min(B, ceiling)
    if stream.forced_mu >= 0:
        mu = stream.forced_mu
    else:
        mu = discards_per_round(B, N, R, Rs, Rp, Rc)
    Re = effective_rate(B, N, R, Rp, Rc)
    return Plan(B=B, mu=mu, R=R,
                Re=Re, regime="resourceful" if mu == 0 else "under-provisioned")


def dmb_stepsize(t: int, L: float, sigma: float, D_W: float) -> float:
    """Theorem 4's stepsize: eta_t = 1 / (L + (sigma/D_W) * sqrt(t))."""
    return 1.0 / (L + (sigma / D_W) * math.sqrt(max(t, 1)))


def krasulina_stepsize(t: int, c: float, Q: float) -> float:
    """Theorems 3/5 stepsize: eta_t = c / (Q + t)."""
    return c / (Q + t)


def min_comms_rate_for_optimality(B: int, N: int, R: int, Rs: float, Rp: float) -> float:
    """Eq. (26): R_c >= N*R*R_s*R_p / (B*(N*R_p - R_s))."""
    denom = B * (N * Rp - Rs)
    if denom <= 0:
        return float("inf")
    return N * R * Rs * Rp / denom
