"""The paper's rate model (Section II-C): streaming rate R_s, per-node compute
rate R_p, communications rate R_c, consensus rounds R, network-wide mini-batch B,
N nodes — and the provisioning planner implied by Theorems 4-7.

    R_e  = ( B/(N*R_p) + R/R_c )^-1                      (eq. 4)
    R   <= floor( B*R_c * (1/R_s - 1/(N*R_p)) )          (eq. 3)

A system keeps up with the stream iff R_s <= B*R_e; otherwise it must discard
mu = R_s/R_e - B samples per round (Algorithms 1-2, steps 9-10).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional

from repro.configs.base import StreamConfig


def _comm_time(R: int, Rc: float) -> float:
    """Per-round communication time R/R_c; R_c <= 0 means 'no comms model'
    (infinitely fast network), not a zero-rate one."""
    return R / Rc if Rc > 0 else 0.0


def effective_rate(B: float, N: int, R: int, Rp: float, Rc: float) -> float:
    """Mini-batches per second the network can process (eq. 4)."""
    return 1.0 / (B / (N * Rp) + _comm_time(R, Rc))


def max_rounds(B: float, N: int, Rs: float, Rp: float, Rc: float) -> int:
    """Largest R compatible with keeping up with the stream (eq. 3)."""
    slack = 1.0 / Rs - 1.0 / (N * Rp)
    return max(0, math.floor(B * Rc * slack))


def discards_per_round(B: int, N: int, R: int, Rs: float, Rp: float, Rc: float) -> int:
    """mu = max(0, R_s/R_e - B): samples dropped at the splitter per round."""
    Re = effective_rate(B, N, R, Rp, Rc)
    # epsilon guard: B chosen exactly at the keep-up boundary must give mu = 0
    return max(0, math.ceil(Rs / Re - B - 1e-9))


@dataclass(frozen=True)
class Plan:
    B: int
    mu: int
    R: int
    Re: float
    regime: str  # "resourceful" | "under-provisioned"


def plan(stream: StreamConfig, N: int, R: int, *, B: Optional[int] = None,
         horizon_samples: Optional[float] = None) -> Plan:
    """Choose (B, mu) for a stream. If B is not given, pick the smallest B that
    keeps up (R_s <= B*R_e), clipped to the order-optimality ceiling
    B <= sqrt(t') from Theorem 4 when a sample horizon is known."""
    Rs, Rp, Rc = stream.streaming_rate, stream.processing_rate, stream.comms_rate
    if B is None:
        # R_s <= B*R_e  <=>  R_s*(B/(N Rp) + R/Rc) <= B
        #              <=>  B*(1 - Rs/(N Rp)) >= Rs*R/Rc
        denom = 1.0 - Rs / (N * Rp)
        if denom <= 0:
            raise ValueError(
                f"stream faster than total compute: R_s={Rs} >= N*R_p={N * Rp}")
        B = max(N, math.ceil(Rs * _comm_time(R, Rc) / denom))
        B = ((B + N - 1) // N) * N  # B must split evenly across nodes
    if horizon_samples:
        ceiling = max(N, int(math.sqrt(horizon_samples)))
        ceiling = (ceiling // N) * N or N
        B = min(B, ceiling)
    if stream.forced_mu >= 0:
        mu = stream.forced_mu
    else:
        mu = discards_per_round(B, N, R, Rs, Rp, Rc)
    Re = effective_rate(B, N, R, Rp, Rc)
    return Plan(B=B, mu=mu, R=R,
                Re=Re, regime="resourceful" if mu == 0 else "under-provisioned")


def measured_processing_rate(B: int, N: int, R: int, wall_s_per_round: float,
                             Rc: float = 0.0) -> float:
    """Invert eq. 4: recover the per-node compute rate R_p actually achieved
    from an observed per-round wall time.

    The round time decomposes as T = B/(N*R_p) + R/R_c; subtracting the
    modeled communication term leaves the compute term. With no comms model
    (Rc <= 0) the whole wall time is attributed to compute, which makes the
    recovered R_p a conservative (pessimistic) estimate. If the observed wall
    time is at or below the modeled comm floor R/R_c, the measurement has
    disproven the comms constant — the whole wall time is attributed to
    compute rather than trusting the model over the observation (which would
    yield an absurd R_p)."""
    comm_s = _comm_time(R, Rc)
    if wall_s_per_round <= comm_s:
        comm_s = 0.0
    compute_s = max(wall_s_per_round - comm_s, 1e-12)
    return B / (N * compute_s)


def measured_effective_rate(wall_s_per_round: float) -> float:
    """Observed R_e: mini-batches per second actually completed."""
    return 1.0 / max(wall_s_per_round, 1e-12)


def replan(stream: StreamConfig, N: int, R: int, B: int,
           wall_s_per_round: float, *,
           horizon_samples: Optional[float] = None) -> Plan:
    """Closed-loop governor step: re-derive (B, mu) from the *measured* round
    time instead of the config's nominal R_p (Nokleby & Bajwa 2017 style
    adaptation of the DMB plan).

    B is held fixed — changing it would change batch shapes and force a
    recompile of the jitted superstep — so the adaptation shows up purely in
    mu, the number of samples the splitter must discard per round to keep up
    with R_s at the rate the hardware is actually delivering.

    A user-pinned `forced_mu >= 0` stays in force (the experiment knob wins
    over the feedback loop); the re-plan then only refreshes the measured
    Re / regime diagnosis."""
    if wall_s_per_round <= _comm_time(R, stream.comms_rate):
        # the round finished faster than the modeled comm floor: the R_c
        # constant is disproven by observation — drop the comm term entirely
        # instead of letting it dominate the re-planned R_e
        stream = dataclasses.replace(stream, comms_rate=0.0)
    Rp = measured_processing_rate(B, N, R, wall_s_per_round, stream.comms_rate)
    observed = dataclasses.replace(stream, processing_rate=Rp)
    return plan(observed, N, R, B=B, horizon_samples=horizon_samples)


def checked_plan_swap(current: Plan, new: Plan) -> Plan:
    """Guard for closed-loop plan swaps (`update_plan` on the governed
    streams): B must stay fixed because the node-split batch shape feeds
    compiled code; only mu and the Re/regime diagnosis may adapt."""
    if new.B != current.B:
        raise ValueError(
            f"closed-loop replan must keep B fixed: {current.B} -> {new.B}")
    return new


def dmb_stepsize(t: int, L: float, sigma: float, D_W: float) -> float:
    """Theorem 4's stepsize: eta_t = 1 / (L + (sigma/D_W) * sqrt(t))."""
    return 1.0 / (L + (sigma / D_W) * math.sqrt(max(t, 1)))


def krasulina_stepsize(t: int, c: float, Q: float) -> float:
    """Theorems 3/5 stepsize: eta_t = c / (Q + t)."""
    return c / (Q + t)


def min_comms_rate_for_optimality(B: int, N: int, R: int, Rs: float, Rp: float) -> float:
    """Eq. (26): R_c >= N*R*R_s*R_p / (B*(N*R_p - R_s))."""
    denom = B * (N * Rp - Rs)
    if denom <= 0:
        return float("inf")
    return N * R * Rs * Rp / denom
