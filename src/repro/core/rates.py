"""The paper's rate model (Section II-C): streaming rate R_s, per-node compute
rate R_p, communications rate R_c, consensus rounds R, network-wide mini-batch B,
N nodes — and the provisioning planner implied by Theorems 4-7.

    R_e  = ( B/(N*R_p) + R/R_c )^-1                      (eq. 4)
    R   <= floor( B*R_c * (1/R_s - 1/(N*R_p)) )          (eq. 3)

A system keeps up with the stream iff R_s <= B*R_e; otherwise it must discard
mu = R_s/R_e - B samples per round (Algorithms 1-2, steps 9-10).

The closed-loop half of the module feeds the streaming driver's governor
(docs/DESIGN.md §Adaptive batch buckets): `BucketLadder` registers the B
values the plan may move between (each with a pre-compiled superstep),
`RoundTimeEstimator` decomposes round times observed at different buckets
into a running (R_p, R_c) estimate by least squares, and `replan` /
`checked_plan_swap` re-derive and validate the (B, mu) plan from those
measured rates.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from dataclasses import dataclass
from typing import Deque, Optional, Tuple

from repro.configs.base import StreamConfig


def _comm_time(R: int, Rc: float) -> float:
    """Per-round communication time R/R_c; R_c <= 0 means 'no comms model'
    (infinitely fast network), not a zero-rate one."""
    return R / Rc if Rc > 0 else 0.0


def effective_rate(B: float, N: int, R: int, Rp: float, Rc: float) -> float:
    """Mini-batches per second the network can process (eq. 4)."""
    return 1.0 / (B / (N * Rp) + _comm_time(R, Rc))


def rate_limited(stream: StreamConfig, bw_factor: float) -> StreamConfig:
    """The stream as seen through a bandwidth-capped network: a `bw:i-jxF`
    link fault (core/faults.py) makes the lockstep consensus round block on
    the capped edge, which is equivalent to dividing the network rate R_c by
    the cap factor in eq. 4. Used by the scenario harness to derive ground
    truth for simulated round times; the closed loop itself never consumes
    this — the governor *measures* the inflated round time and its estimator
    recovers the lower R_c on its own (that direction is what
    `benchmarks/bench_scenarios.py` asserts). A no-comms-model stream
    (comms_rate <= 0) has nothing to cap and passes through unchanged."""
    if bw_factor < 1.0:
        raise ValueError(f"bandwidth cap factor must be >= 1: {bw_factor}")
    if stream.comms_rate <= 0 or bw_factor == 1.0:
        return stream
    return dataclasses.replace(stream, comms_rate=stream.comms_rate / bw_factor)


def max_rounds(B: float, N: int, Rs: float, Rp: float, Rc: float) -> int:
    """Largest R compatible with keeping up with the stream (eq. 3)."""
    slack = 1.0 / Rs - 1.0 / (N * Rp)
    return max(0, math.floor(B * Rc * slack))


def discards_per_round(B: int, N: int, R: int, Rs: float, Rp: float, Rc: float) -> int:
    """mu = max(0, R_s/R_e - B): samples dropped at the splitter per round."""
    Re = effective_rate(B, N, R, Rp, Rc)
    # epsilon guard: B chosen exactly at the keep-up boundary must give mu = 0
    return max(0, math.ceil(Rs / Re - B - 1e-9))


@dataclass(frozen=True)
class Plan:
    B: int
    mu: int
    R: int
    Re: float
    regime: str  # "resourceful" | "under-provisioned"
    # Active-cohort snapshot (a `core.mixing.Membership`, or None = full/static
    # membership). Rides the per-superstep plan latch, so in-flight prefetched
    # batches drain under the membership that dealt them
    # (docs/DESIGN.md §Elastic membership).
    membership: Optional[object] = None

    @property
    def n_active(self) -> Optional[int]:
        return None if self.membership is None else self.membership.n_active

    def to_json(self) -> dict:
        """JSON form for checkpoint manifests (train.snapshot): everything a
        resumed driver needs to adopt the exact plan, membership included."""
        return {"B": self.B, "mu": self.mu, "R": self.R, "Re": self.Re,
                "regime": self.regime,
                "membership": (None if self.membership is None
                               else self.membership.to_json())}

    @classmethod
    def from_json(cls, state: dict) -> "Plan":
        mem = state.get("membership")
        if mem is not None:
            from repro.core.mixing import Membership
            mem = Membership.from_json(mem)
        return cls(B=int(state["B"]), mu=int(state["mu"]), R=int(state["R"]),
                   Re=float(state["Re"]), regime=state["regime"],
                   membership=mem)


def plan(stream: StreamConfig, N: int, R: int, *, B: Optional[int] = None,
         horizon_samples: Optional[float] = None) -> Plan:
    """Choose (B, mu) for a stream. If B is not given, pick the smallest B that
    keeps up (R_s <= B*R_e), clipped to the order-optimality ceiling
    B <= sqrt(t') from Theorem 4 when a sample horizon is known."""
    Rs, Rp, Rc = stream.streaming_rate, stream.processing_rate, stream.comms_rate
    if B is None:
        # R_s <= B*R_e  <=>  R_s*(B/(N Rp) + R/Rc) <= B
        #              <=>  B*(1 - Rs/(N Rp)) >= Rs*R/Rc
        denom = 1.0 - Rs / (N * Rp)
        if denom <= 0:
            raise ValueError(
                f"stream faster than total compute: R_s={Rs} >= N*R_p={N * Rp}")
        B = max(N, math.ceil(Rs * _comm_time(R, Rc) / denom))
        B = ((B + N - 1) // N) * N  # B must split evenly across nodes
    if horizon_samples:
        B = min(B, horizon_ceiling(N, horizon_samples))
    if stream.forced_mu >= 0:
        mu = stream.forced_mu
    else:
        mu = discards_per_round(B, N, R, Rs, Rp, Rc)
    Re = effective_rate(B, N, R, Rp, Rc)
    return Plan(B=B, mu=mu, R=R,
                Re=Re, regime="resourceful" if mu == 0 else "under-provisioned")


def horizon_ceiling(N: int, horizon_samples: float) -> int:
    """Theorem 4's order-optimality ceiling B <= sqrt(t'), rounded down to a
    multiple of N (and never below N)."""
    ceiling = max(N, int(math.sqrt(horizon_samples)))
    return (ceiling // N) * N or N


@dataclass(frozen=True)
class BucketLadder:
    """The registered network mini-batch sizes the governor may plan between
    (docs/DESIGN.md §Adaptive batch buckets).

    Each bucket's superstep is compiled (lazily, once) by the streaming
    driver, so a plan swap between registered buckets never retraces; an
    unregistered B is rejected at `checked_plan_swap`. Buckets are ascending,
    distinct multiples of N, and — when a sample horizon is known — clipped
    to Theorem 4's B <= sqrt(t') ceiling.
    """

    buckets: Tuple[int, ...]
    # The node count the buckets were derived for. Buckets are multiples of N
    # (the batch must split evenly across nodes), so a cohort change silently
    # invalidates them; storing N lets `snap`/`for_cohort` reject or re-derive
    # instead. None = legacy hand-built ladder, no cohort checking.
    N: Optional[int] = None

    def __post_init__(self):
        if not self.buckets:
            raise ValueError("a BucketLadder needs at least one bucket")
        if list(self.buckets) != sorted(set(self.buckets)):
            raise ValueError(f"buckets must be ascending and distinct: "
                             f"{self.buckets}")
        if self.buckets[0] < 1:
            raise ValueError(f"buckets must be positive: {self.buckets}")
        if self.N is not None:
            if self.N < 1:
                raise ValueError(f"ladder N must be positive: {self.N}")
            bad = [b for b in self.buckets if b % self.N]
            if bad:
                raise ValueError(
                    f"buckets {bad} are not multiples of N={self.N}; "
                    f"re-derive the ladder for the new cohort via "
                    f"`for_cohort`")

    def __len__(self) -> int:
        return len(self.buckets)

    def __contains__(self, B: int) -> bool:
        return B in self.buckets

    def snap(self, B: int, *, N: Optional[int] = None) -> int:
        """Smallest registered bucket >= B (the keep-up direction), or the
        largest bucket when B exceeds the ladder. Pass the current cohort
        size `N` to assert the ladder is still valid for it — snapping onto
        a ladder derived for a different cohort would hand compiled code a
        batch that no longer splits evenly across nodes."""
        if N is not None and self.N is not None and N != self.N:
            raise ValueError(
                f"ladder was derived for N={self.N} but cohort is now "
                f"N={N}; re-derive via `for_cohort`")
        for b in self.buckets:
            if b >= B:
                return b
        return self.buckets[-1]

    def for_cohort(self, n_active: int, *,
                   horizon_samples: Optional[float] = None) -> "BucketLadder":
        """Re-derive the ladder for a changed cohort size: the same candidate
        buckets re-normalized to multiples of `n_active` (and re-clipped to
        the Theorem-4 ceiling, itself a multiple of the new N). Identity when
        the cohort already matches, so full-membership ladders are reused
        (and their compiled supersteps with them)."""
        if n_active == self.N:
            return self
        return BucketLadder.from_buckets(self.buckets, n_active,
                                         horizon_samples=horizon_samples)

    @classmethod
    def from_buckets(cls, raw, N: int, *,
                     horizon_samples: Optional[float] = None) -> "BucketLadder":
        """Normalize arbitrary candidate buckets into a valid ladder: each
        rounded up to a multiple of N (never below N), clipped to the
        Theorem-4 sqrt-horizon ceiling (itself a multiple of N — candidates
        above it collapse onto the ceiling, the largest order-optimal B),
        then deduped/sorted. Guarantees every registered bucket survives
        `plan`'s horizon clip unchanged, so a plan at a registered bucket
        can never be clipped to an unregistered value mid-run."""
        cand = {max(N, -(-int(c) // N) * N) for c in raw}
        if horizon_samples:
            ceil_B = horizon_ceiling(N, horizon_samples)
            cand = {min(c, ceil_B) for c in cand}
        return cls(tuple(sorted(cand)), N=N)

    @classmethod
    def build(cls, base_B: int, N: int, *, n_buckets: int = 3,
              factor: int = 2,
              horizon_samples: Optional[float] = None) -> "BucketLadder":
        """Geometric ladder centered on `base_B`: floor((n-1)/2) buckets below
        and the rest above, normalized by `from_buckets` (multiples of N,
        Theorem-4 ceiling)."""
        if n_buckets < 1:
            raise ValueError("n_buckets must be >= 1")
        if factor < 2:
            raise ValueError("bucket_factor must be >= 2")
        below = (n_buckets - 1) // 2
        cand = [base_B * factor ** i for i in range(-below, n_buckets - below)]
        return cls.from_buckets(cand, N, horizon_samples=horizon_samples)


@dataclass(frozen=True)
class RateEstimate:
    """Online decomposition of observed round times into the rate model's
    compute and communication terms: T = B/(N*R_p) + R/R_c. `Rc = 0.0` means
    the fitted comm intercept was ~0 (no comms model), matching the
    `comms_rate <= 0` convention everywhere else in this module."""

    Rp: float
    Rc: float
    n_obs: int = 0


class RoundTimeEstimator:
    """Least-squares (R_p, R_c) estimation from per-round wall times observed
    at *different* network mini-batch sizes B
    (docs/DESIGN.md §Adaptive batch buckets).

    Eq. 4's round time is affine in B — T(B) = a*B + c with a = 1/(N*R_p)
    and c = R/R_c — so supersteps timed at two or more distinct buckets
    identify both terms: slope -> R_p, intercept -> R_c. This replaces the
    binary comm-floor-disproof heuristic of `replan` (which can only either
    trust the config's R_c or zero it) with a measurement; with only one
    bucket visited the system is unidentifiable and `estimate()` returns
    None, falling back to that heuristic. A bounded window keeps the fit
    tracking the hardware's *current* rates.
    """

    def __init__(self, N: int, R: int, *, window: int = 64):
        if N < 1 or R < 0:
            raise ValueError(f"bad estimator dims N={N} R={R}")
        self.N, self.R = N, R
        # (equivalent full-cohort B, seconds); B is fractional after the
        # `observe_cohort` x = B*N/m normalization
        self._obs: Deque[Tuple[float, float]] = deque(maxlen=max(2, window))

    def observe(self, B: int, round_s: float) -> None:
        if B > 0 and round_s > 0 and math.isfinite(round_s):
            self._obs.append((B, round_s))

    def observe_cohort(self, B: int, n_active: int, round_s: float) -> None:
        """Observe a round timed at a partial cohort of `n_active` nodes.

        The affine model T(B) = B/(N*R_p) + R/R_c assumes all N nodes share
        the compute; at a cohort of m nodes the compute term is B/(m*R_p) =
        (B*N/m)/(N*R_p), so the observation enters the fit at the equivalent
        full-cohort regressor x = B*N/m. This keeps one estimator coherent
        across membership eras instead of resetting the window on every
        churn event."""
        if n_active < 1:
            return
        self.observe(B * self.N / n_active, round_s)

    def state_dict(self) -> dict:
        """JSON-serializable snapshot of the fit: the observation window as
        [[B, round_s], ...] plus the dims it was built for (checked on load
        so a checkpoint cannot silently feed a differently-shaped fit)."""
        return {"N": self.N, "R": self.R,
                "window": self._obs.maxlen,
                "obs": [[float(b), float(t)] for b, t in self._obs]}

    def load_state_dict(self, state: dict) -> None:
        """Restore the observation window exactly — the resumed estimator
        produces bit-identical estimates to the uninterrupted one."""
        if (state["N"], state["R"]) != (self.N, self.R):
            raise ValueError(
                f"estimator snapshot is for N={state['N']} R={state['R']}, "
                f"but this estimator has N={self.N} R={self.R}")
        self._obs = deque(((b, t) for b, t in state["obs"]),
                          maxlen=state.get("window", self._obs.maxlen))

    def estimate(self) -> Optional[RateEstimate]:
        n = len(self._obs)
        if n < 3 or len({b for b, _ in self._obs}) < 2:
            return None  # slope and intercept are not separable yet
        sx = sum(b for b, _ in self._obs)
        sy = sum(t for _, t in self._obs)
        sxx = sum(b * b for b, _ in self._obs)
        sxy = sum(b * t for b, t in self._obs)
        denom = n * sxx - sx * sx
        if denom <= 0:
            return None
        a = (n * sxy - sx * sy) / denom
        if a <= 0:
            return None  # negative compute term: noise dominates, keep fallback
        c = max((sy - a * sx) / n, 0.0)
        Rp = 1.0 / (self.N * a)
        Rc = self.R / c if c > 1e-12 else 0.0
        return RateEstimate(Rp=Rp, Rc=Rc, n_obs=n)


class BucketHysteresis:
    """Debounce bucket proposals: a switch is confirmed only after `patience`
    consecutive re-plans agree on the same target bucket, so one jittery
    superstep timing cannot thrash the ladder. `patience=1` switches
    immediately; proposals equal to the current bucket reset the streak."""

    def __init__(self, patience: int = 2):
        if patience < 1:
            raise ValueError("hysteresis patience must be >= 1")
        self.patience = patience
        self._pending: Optional[int] = None
        self._streak = 0

    def state_dict(self) -> dict:
        return {"pending": self._pending, "streak": self._streak}

    def load_state_dict(self, state: dict) -> None:
        self._pending = state["pending"]
        self._streak = int(state["streak"])

    def step(self, current_B: int, target_B: int) -> int:
        """Returns the bucket to adopt now: `target_B` once confirmed, else
        `current_B`."""
        if target_B == current_B:
            self._pending, self._streak = None, 0
            return current_B
        if target_B == self._pending:
            self._streak += 1
        else:
            self._pending, self._streak = target_B, 1
        if self._streak >= self.patience:
            self._pending, self._streak = None, 0
            return target_B
        return current_B


class PerNodeRoundTime:
    """Per-node EWMA of observed round times
    (docs/DESIGN.md §Elastic membership).

    The superstep itself only yields one wall time (the slowest node's —
    gossip is lockstep), so per-node times come from outside the engine: a
    `core.faults.FaultSchedule` in tests/benchmarks, node-local heartbeats in
    a real deployment. The EWMA smooths one-off jitter so the straggler
    policy reacts to sustained slowdowns, not noise."""

    def __init__(self, n: int, *, alpha: float = 0.5):
        if n < 1 or not 0.0 < alpha <= 1.0:
            raise ValueError(f"bad PerNodeRoundTime n={n} alpha={alpha}")
        self.n = n
        self.alpha = alpha
        self._ewma: list = [None] * n

    def observe_all(self, round_s_per_node) -> None:
        """Fold one round's per-node wall times into the EWMAs. Entries that
        are None / non-finite / non-positive (e.g. dead nodes) are skipped —
        their EWMA freezes at the last live value."""
        if len(round_s_per_node) != self.n:
            raise ValueError(f"expected {self.n} per-node times, "
                             f"got {len(round_s_per_node)}")
        for i, t in enumerate(round_s_per_node):
            if t is None or not math.isfinite(t) or t <= 0:
                continue
            prev = self._ewma[i]
            self._ewma[i] = t if prev is None else (
                self.alpha * t + (1.0 - self.alpha) * prev)

    def state_dict(self) -> dict:
        return {"n": self.n, "alpha": self.alpha,
                "ewma": [None if v is None else float(v)
                         for v in self._ewma]}

    def load_state_dict(self, state: dict) -> None:
        if state["n"] != self.n:
            raise ValueError(f"EWMA snapshot is for n={state['n']}, "
                             f"but this tracker has n={self.n}")
        self._ewma = list(state["ewma"])

    def value(self, node: int) -> Optional[float]:
        return self._ewma[node]

    @property
    def seeded(self) -> bool:
        """True once any node has a real observation. The first observation
        seeds a node's EWMA directly (no synthetic prior), so callers should
        withhold made-up fallback times — e.g. the driver only feeds times
        scaled from *measured* warm-up rounds, never a constant seed — or the
        constant dominates every node's EWMA equally and masks slow/fast
        ratios until it decays."""
        return any(v is not None for v in self._ewma)

    def median(self, ids=None) -> Optional[float]:
        """Median EWMA over `ids` (default: all nodes with observations)."""
        vals = sorted(v for i, v in enumerate(self._ewma)
                      if v is not None and (ids is None or i in ids))
        if not vals:
            return None
        k = len(vals)
        return vals[k // 2] if k % 2 else 0.5 * (vals[k // 2 - 1] + vals[k // 2])


class StragglerPolicy:
    """Decide which nodes the governor should wait for
    (docs/DESIGN.md §Elastic membership).

    Three modes, all fed by `PerNodeRoundTime`:

    * "wait"     — never drop anyone; the superstep runs at the slowest
                   active node's pace (the paper's lockstep assumption — the
                   baseline the benchmarks compare against).
    * "drop"     — a node whose EWMA round time exceeds `slow_factor` x the
                   active-cohort median is proposed out; it is proposed back
                   in once it recovers below the threshold.
    * "deadline" — a node slower than the absolute `deadline_s` is proposed
                   out (and back in on recovery); the effective round time
                   is capped at the deadline.

    Every in/out proposal is debounced through a per-node `BucketHysteresis`
    (membership bit as a two-rung ladder), so one jittery reading can neither
    evict nor readmit a node — the same patience discipline the governor
    applies to bucket switches."""

    MODES = ("wait", "drop", "deadline")

    def __init__(self, n: int, mode: str = "wait", *, slow_factor: float = 2.0,
                 deadline_s: float = 0.0, patience: int = 2,
                 alpha: float = 0.5):
        if mode not in self.MODES:
            raise ValueError(f"unknown straggler policy {mode!r}; "
                             f"one of {self.MODES}")
        if mode == "drop" and slow_factor <= 1.0:
            raise ValueError(f"slow_factor must be > 1: {slow_factor}")
        if mode == "deadline" and deadline_s <= 0.0:
            raise ValueError(f"deadline_s must be > 0: {deadline_s}")
        self.n, self.mode = n, mode
        self.slow_factor, self.deadline_s = slow_factor, deadline_s
        self.times = PerNodeRoundTime(n, alpha=alpha)
        self._hyst = [BucketHysteresis(patience) for _ in range(n)]
        self._kept = [True] * n  # straggler verdict per node (debounced)

    def state_dict(self) -> dict:
        """JSON-serializable snapshot of everything the policy accumulates:
        per-node EWMAs, debounce streaks, and the kept/evicted verdicts. The
        policy's *parameters* (mode, factors, patience) come from config and
        are echoed only for a consistency check on load."""
        return {"n": self.n, "mode": self.mode,
                "times": self.times.state_dict(),
                "hyst": [h.state_dict() for h in self._hyst],
                "kept": [bool(k) for k in self._kept]}

    def load_state_dict(self, state: dict) -> None:
        if (state["n"], state["mode"]) != (self.n, self.mode):
            raise ValueError(
                f"straggler snapshot is for n={state['n']} "
                f"mode={state['mode']!r}, but this policy has n={self.n} "
                f"mode={self.mode!r}")
        self.times.load_state_dict(state["times"])
        for h, hs in zip(self._hyst, state["hyst"]):
            h.load_state_dict(hs)
        self._kept = [bool(k) for k in state["kept"]]

    def _too_slow(self, node: int, cohort_ids) -> bool:
        t = self.times.value(node)
        if t is None:
            return False  # no evidence — keep the node
        if self.mode == "deadline":
            return t > self.deadline_s
        med = self.times.median(cohort_ids)
        return med is not None and t > self.slow_factor * med

    def observe(self, round_s_per_node) -> None:
        self.times.observe_all(round_s_per_node)

    def propose(self, membership) -> "object":
        """Intersect a fault-layer membership with the debounced straggler
        verdicts: nodes the fault layer killed stay out regardless; of the
        survivors, sustained stragglers are dropped (drop/deadline modes).
        Never empties the cohort — the least-slow node is always kept."""
        if self.mode == "wait":
            return membership
        ids = membership.active_ids
        for i in ids:
            want = 0 if self._too_slow(i, ids) else 1
            self._kept[i] = bool(
                self._hyst[i].step(int(self._kept[i]), want))
        kept = [i for i in ids if self._kept[i]]
        if not kept:  # never stall the whole stream on a universal verdict
            best = min(ids, key=lambda i: self.times.value(i) or 0.0)
            kept = [best]
        out = membership
        for i in ids:
            if i not in kept:
                out = out.drop(i)
        return out

    def effective_round_s(self, membership, round_s_per_node) -> float:
        """The wall time one gossip round actually costs under this policy:
        the slowest *retained* node ("wait": slowest active node — lockstep;
        "drop": stragglers excluded; "deadline": capped at the deadline)."""
        vals = [round_s_per_node[i] for i in membership.active_ids
                if round_s_per_node[i] is not None]
        if not vals:
            return 0.0
        worst = max(vals)
        if self.mode == "deadline":
            return min(worst, self.deadline_s)
        return worst


def measured_processing_rate(B: int, N: int, R: int, wall_s_per_round: float,
                             Rc: float = 0.0) -> float:
    """Invert eq. 4: recover the per-node compute rate R_p actually achieved
    from an observed per-round wall time.

    The round time decomposes as T = B/(N*R_p) + R/R_c; subtracting the
    modeled communication term leaves the compute term. With no comms model
    (Rc <= 0) the whole wall time is attributed to compute, which makes the
    recovered R_p a conservative (pessimistic) estimate. If the observed wall
    time is at or below the modeled comm floor R/R_c, the measurement has
    disproven the comms constant — the whole wall time is attributed to
    compute rather than trusting the model over the observation (which would
    yield an absurd R_p)."""
    comm_s = _comm_time(R, Rc)
    if wall_s_per_round <= comm_s:
        comm_s = 0.0
    compute_s = max(wall_s_per_round - comm_s, 1e-12)
    return B / (N * compute_s)


def measured_effective_rate(wall_s_per_round: float) -> float:
    """Observed R_e: mini-batches per second actually completed."""
    return 1.0 / max(wall_s_per_round, 1e-12)


def observed_stream(stream: StreamConfig, N: int, R: int, B: int,
                    wall_s_per_round: float, *,
                    estimate: Optional[RateEstimate] = None) -> StreamConfig:
    """StreamConfig with (R_p, R_c) replaced by what measurement supports.

    With a `RoundTimeEstimator` estimate (supersteps observed at two or more
    buckets) both rates come from the least-squares fit. Without one, a
    single (B, wall-time) point cannot separate compute from comms, so the
    fallback keeps the config's R_c unless the observation disproves it: a
    round finished at or under the modeled comm floor R/R_c zeroes the comm
    term rather than letting a wrong constant dominate the re-planned R_e."""
    if estimate is not None:
        return dataclasses.replace(stream, processing_rate=estimate.Rp,
                                   comms_rate=estimate.Rc)
    if wall_s_per_round <= _comm_time(R, stream.comms_rate):
        stream = dataclasses.replace(stream, comms_rate=0.0)
    Rp = measured_processing_rate(B, N, R, wall_s_per_round, stream.comms_rate)
    return dataclasses.replace(stream, processing_rate=Rp)


def select_bucket(ladder: BucketLadder, stream: StreamConfig, N: int, R: int,
                  *, horizon_samples: Optional[float] = None) -> int:
    """The bucket the rate model asks for: the smallest registered B that
    keeps up with the stream (eq. 4's keep-up condition, Theorem-4 ceiling
    applied by `plan`), or the largest bucket when no B can keep up — B*R_e
    is increasing in B, so the top of the ladder minimizes the discard rate
    R_s - B*R_e in the under-provisioned regime."""
    try:
        target = ladder.snap(plan(stream, N, R,
                                  horizon_samples=horizon_samples).B)
    except ValueError:  # stream outruns total compute: nothing keeps up
        target = ladder.buckets[-1]
    if horizon_samples:
        # ladders built via `from_buckets` are already ceiling-clipped; for
        # a hand-built ladder, never select a bucket that `plan` would clip
        # down to an unregistered B
        ceil_B = horizon_ceiling(N, horizon_samples)
        fits = [b for b in ladder.buckets if b <= ceil_B]
        if fits and target > ceil_B:
            target = fits[-1]
    return target


def snap_plan_to_ladder(current: Plan, stream: StreamConfig, N: int,
                        ladder: BucketLadder, *,
                        horizon_samples: Optional[float] = None) -> Plan:
    """Fit an existing plan onto a ladder: if its B is already registered the
    plan is returned unchanged; otherwise B snaps to the nearest keep-up
    bucket and mu is re-derived (for ungoverned streams only B is replaced).
    Shared by the governed sources' `adopt_ladder` so the snap semantics
    cannot drift between them."""
    if current.B in ladder:
        return current
    B = ladder.snap(current.B)
    if stream.streaming_rate > 0:
        out = plan(stream, N, current.R, B=B,
                   horizon_samples=horizon_samples)
        return dataclasses.replace(out, membership=current.membership)
    return dataclasses.replace(current, B=B)


def replan(stream: StreamConfig, N: int, R: int, B: int,
           wall_s_per_round: float, *,
           ladder: Optional[BucketLadder] = None,
           estimate: Optional[RateEstimate] = None,
           decided_B: Optional[int] = None,
           horizon_samples: Optional[float] = None,
           membership: Optional[object] = None) -> Plan:
    """Closed-loop governor step: re-derive (B, mu) from the *measured* round
    time instead of the config's nominal R_p (Nokleby & Bajwa 2017 style
    adaptation of the DMB plan). `B` is the batch size the wall time was
    observed at.

    Without a `ladder` (or with a single-bucket one) B is held fixed — the
    node-split batch shape feeds compiled code — and the adaptation shows up
    purely in mu, the number of samples the splitter must discard per round
    to keep up with R_s at the rate the hardware is actually delivering.
    With a multi-bucket ladder the re-plan may also move B to another
    *registered* bucket (`select_bucket`), each of which has a pre-compiled
    superstep, so the swap still never retraces. Pass `estimate` from a
    `RoundTimeEstimator` to close the loop on R_c as well.

    A user-pinned `forced_mu >= 0` stays in force (the experiment knob wins
    over the feedback loop); the re-plan then only refreshes the measured
    Re / regime diagnosis.

    `decided_B` overrides the bucket selection: pass it when the target went
    through an external debounce (the driver's `BucketHysteresis` sits
    between `select_bucket` and the plan) — the wall-time inversion still
    happens at the observed `B`, but the plan is derived at `decided_B`.

    `N` is the *active cohort* size (eq. 4 re-inverted per cohort); pass
    `membership` to stamp the cohort snapshot onto the returned plan."""
    observed = observed_stream(stream, N, R, B, wall_s_per_round,
                               estimate=estimate)
    if decided_B is not None:
        target_B = decided_B
    elif ladder is not None and len(ladder) > 1:
        target_B = select_bucket(ladder, observed, N, R,
                                 horizon_samples=horizon_samples)
    else:
        target_B = B
    out = plan(observed, N, R, B=target_B, horizon_samples=horizon_samples)
    if membership is not None:
        out = dataclasses.replace(out, membership=membership)
    if ladder is not None and out.B not in ladder:
        # misconfigured hand-built ladder: no registered bucket fits the
        # Theorem-4 ceiling, so the horizon clip just produced an
        # unregistered B that `checked_plan_swap` would reject mid-run —
        # hold the nearest registered bucket (un-clipped) instead of
        # crashing the governor loop. Ladders from `from_buckets` can never
        # hit this.
        out = plan(observed, N, R, B=ladder.snap(out.B))
        if membership is not None:
            out = dataclasses.replace(out, membership=membership)
    return out


def checked_plan_swap(current: Plan, new: Plan,
                      ladder: Optional[BucketLadder] = None) -> Plan:
    """Guard for closed-loop plan swaps (`update_plan` on the governed
    streams): the node-split batch shape feeds compiled code, so B may only
    move to a bucket whose superstep is registered (and pre-compiled) on the
    ladder. Without a ladder B must stay fixed — the pre-ladder pinned-B
    behavior; only mu and the Re/regime diagnosis may adapt."""
    if ladder is not None:
        if new.B not in ladder:
            raise ValueError(
                f"replan proposed unregistered batch bucket B={new.B}; "
                f"registered buckets: {list(ladder.buckets)}")
        return new
    if new.B != current.B:
        raise ValueError(
            f"closed-loop replan must keep B fixed: {current.B} -> {new.B}")
    return new


def dmb_stepsize(t: int, L: float, sigma: float, D_W: float) -> float:
    """Theorem 4's stepsize: eta_t = 1 / (L + (sigma/D_W) * sqrt(t))."""
    return 1.0 / (L + (sigma / D_W) * math.sqrt(max(t, 1)))


def krasulina_stepsize(t: int, c: float, Q: float) -> float:
    """Theorems 3/5 stepsize: eta_t = c / (Q + t)."""
    return c / (Q + t)


def min_comms_rate_for_optimality(B: int, N: int, R: int, Rs: float, Rp: float) -> float:
    """Eq. (26): R_c >= N*R*R_s*R_p / (B*(N*R_p - R_s))."""
    denom = B * (N * Rp - Rs)
    if denom <= 0:
        return float("inf")
    return N * R * Rs * Rp / denom
