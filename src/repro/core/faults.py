"""Deterministic fault injection for the elastic node axis
(docs/DESIGN.md §Elastic membership).

A `FaultSchedule` scripts node churn against the driver's superstep counter:
node death (with optional rejoin), transient slowdown factors, and flaky
periodic dropout. The same schedule object drives

* the mixing mask — `alive(step)` yields the `core.mixing.Membership` the
  superstep must run under, and
* the clock — `time_factors(step)` yields per-node wall-time multipliers the
  tests/benchmarks fold into their fake clocks and the straggler policy's
  per-node round times.

Keeping faults a pure function of the step index (no RNG, no wall clock)
makes every churn scenario replayable: tests, benchmarks, and the launch CLI
all share one spec format, parsed by `FaultSchedule.parse`:

    death:1@5        node 1 dies at step 5, never returns
    death:1@5-12     node 1 dies at step 5, rejoins at step 12
    slow:0@3-9x4     node 0 runs 4x slower during steps [3, 9)
    flaky:2@4-20p3   node 2 alternates dead/alive every 3 steps in [4, 20)

Comma-separate multiple faults: "death:1@5-12,slow:0@3-9x4".
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Sequence, Tuple

import numpy as np

from repro.core.mixing import Membership

KINDS = ("death", "slow", "flaky")

_SPEC_RE = re.compile(
    r"^(?P<kind>death|slow|flaky):(?P<node>\d+)@(?P<start>\d+)"
    r"(?:-(?P<end>\d+))?(?:x(?P<factor>[0-9.]+))?(?:p(?P<period>\d+))?$")


@dataclass(frozen=True)
class NodeFault:
    """One scripted fault on one node over the step window [start, end)."""

    node: int
    kind: str  # death | slow | flaky
    start: int
    end: int = -1  # exclusive; -1 = until the end of the run
    factor: float = 1.0  # slowdown multiplier (kind == "slow")
    period: int = 0  # dead/alive alternation period (kind == "flaky")

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; one of {KINDS}")
        if self.node < 0 or self.start < 0:
            raise ValueError(f"bad fault target: node={self.node} "
                             f"start={self.start}")
        if self.end != -1 and self.end <= self.start:
            raise ValueError(f"fault window is empty: [{self.start}, {self.end})")
        if self.kind == "slow" and self.factor <= 1.0:
            raise ValueError(f"slowdown factor must be > 1: {self.factor}")
        if self.kind == "flaky" and self.period < 1:
            raise ValueError(f"flaky fault needs period >= 1: {self.period}")

    def _in_window(self, step: int) -> bool:
        return step >= self.start and (self.end == -1 or step < self.end)

    def dead_at(self, step: int) -> bool:
        if not self._in_window(step):
            return False
        if self.kind == "death":
            return True
        if self.kind == "flaky":
            # starts dead at `start`, alternates every `period` steps
            return ((step - self.start) // self.period) % 2 == 0
        return False

    def factor_at(self, step: int) -> float:
        if self.kind == "slow" and self._in_window(step):
            return self.factor
        return 1.0


class FaultSchedule:
    """A replayable script of node faults over `n` node slots."""

    def __init__(self, n: int, faults: Sequence[NodeFault] = ()):
        if n < 1:
            raise ValueError(f"need at least one node: n={n}")
        for f in faults:
            if f.node >= n:
                raise ValueError(f"fault targets node {f.node} but n={n}")
        self.n = n
        self.faults: Tuple[NodeFault, ...] = tuple(faults)

    @classmethod
    def parse(cls, spec: str, n: int) -> "FaultSchedule":
        """Parse the comma-separated fault DSL (module docstring)."""
        faults = []
        for tok in filter(None, (t.strip() for t in spec.split(","))):
            m = _SPEC_RE.match(tok)
            if not m:
                raise ValueError(f"bad fault spec {tok!r}; expected e.g. "
                                 f"'death:1@5-12', 'slow:0@3-9x4', "
                                 f"'flaky:2@4-20p3'")
            g = m.groupdict()
            faults.append(NodeFault(
                node=int(g["node"]), kind=g["kind"], start=int(g["start"]),
                end=-1 if g["end"] is None else int(g["end"]),
                factor=1.0 if g["factor"] is None else float(g["factor"]),
                period=0 if g["period"] is None else int(g["period"])))
        return cls(n, faults)

    def alive(self, step: int) -> Membership:
        """The membership the fault layer dictates at a driver superstep."""
        mask = [True] * self.n
        for f in self.faults:
            if f.dead_at(step):
                mask[f.node] = False
        if not any(mask):
            raise ValueError(f"fault schedule kills every node at step {step}")
        return Membership(self.n, tuple(mask))

    def time_factors(self, step: int) -> np.ndarray:
        """Per-node wall-time multipliers at a step (1.0 = nominal). Factors
        from overlapping slowdowns on the same node multiply."""
        out = np.ones(self.n)
        for f in self.faults:
            out[f.node] *= f.factor_at(step)
        return out

    def round_s_per_node(self, step: int, base_round_s: float) -> list:
        """Simulated per-node round times at a step: the nominal round time
        scaled by each node's slowdown factor, None for dead nodes. This is
        the feed for `core.rates.StragglerPolicy.observe` in tests and
        `benchmarks/bench_elastic.py`."""
        alive = self.alive(step).active
        factors = self.time_factors(step)
        return [base_round_s * float(factors[i]) if alive[i] else None
                for i in range(self.n)]

    def events_between(self, lo: int, hi: int) -> bool:
        """True if membership differs anywhere in (lo, hi] from step lo —
        a cheap way for callers to skip mask recomputation on quiet spans."""
        base = self.alive(lo).active
        return any(self.alive(s).active != base for s in range(lo + 1, hi + 1))
