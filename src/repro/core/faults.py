"""Deterministic fault injection for the elastic node axis
(docs/DESIGN.md §Elastic membership).

A `FaultSchedule` scripts node churn against the driver's superstep counter:
node death (with optional rejoin), transient slowdown factors, and flaky
periodic dropout. The same schedule object drives

* the mixing mask — `alive(step)` yields the `core.mixing.Membership` the
  superstep must run under, and
* the clock — `time_factors(step)` yields per-node wall-time multipliers the
  tests/benchmarks fold into their fake clocks and the straggler policy's
  per-node round times.

Keeping faults a pure function of the step index (no RNG, no wall clock)
makes every churn scenario replayable: tests, benchmarks, and the launch CLI
all share one spec format, parsed by `FaultSchedule.parse`:

    death:1@5        node 1 dies at step 5, never returns
    death:1@5-12     node 1 dies at step 5, rejoins at step 12
    slow:0@3-9x4     node 0 runs 4x slower during steps [3, 9)
    flaky:2@4-20p3   node 2 alternates dead/alive every 3 steps in [4, 20)

The scenario harness (core/scenarios.py, docs/DESIGN.md §Scenario harness)
extends the grammar with *link* faults — per-edge models after Nokleby &
Bajwa's rate-limited networks (arXiv:1704.07888) and the lossy collaborative
setting of Ozfatura, Gündüz & Poor (arXiv:2112.05559):

    link:1-2@4-20p0.1   edge (1, 2) loses each round w.p. 0.1 in steps [4, 20)
    bw:0-3@5-15x4       edge (0, 3) runs at 1/4 bandwidth in steps [5, 15)

Link loss realizations stay a pure function of (seed, step, edge) — drawn
from a counter-based generator, never a shared RNG stream — so the same
scenario seed replays the identical drop masks across runs and prefetch
depths. Dropped links degrade to self-weights (`lossy_matrix`), keeping the
round's operator doubly stochastic. Bandwidth caps slow the edge's endpoints
(`round_s_per_node`), which is how they reach the straggler policy and the
governor's round-time estimator.

Comma-separate multiple faults: "death:1@5-12,slow:0@3-9x4,link:1-2@4-20p0.1".
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Sequence, Tuple

import numpy as np

from repro.core.mixing import (Membership, _connected, metropolis_weights)

KINDS = ("death", "slow", "flaky")
LINK_KINDS = ("link", "bw")

_SPEC_RE = re.compile(
    r"^(?P<kind>death|slow|flaky):(?P<node>\d+)@(?P<start>\d+)"
    r"(?:-(?P<end>\d+))?(?:x(?P<factor>[0-9.]+))?(?:p(?P<period>\d+))?$")

_LINK_RE = re.compile(
    r"^(?P<kind>link|bw):(?P<i>\d+)-(?P<j>\d+)@(?P<start>\d+)"
    r"(?:-(?P<end>\d+))?(?:x(?P<factor>[0-9.]+))?(?:p(?P<prob>[0-9.]+))?$")


def _fmt(v: float) -> str:
    """Canonical numeric spelling for round-tripping specs ('4', '0.1')."""
    return f"{v:g}"


@dataclass(frozen=True)
class NodeFault:
    """One scripted fault on one node over the step window [start, end)."""

    node: int
    kind: str  # death | slow | flaky
    start: int
    end: int = -1  # exclusive; -1 = until the end of the run
    factor: float = 1.0  # slowdown multiplier (kind == "slow")
    period: int = 0  # dead/alive alternation period (kind == "flaky")

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; one of {KINDS}")
        if self.node < 0 or self.start < 0:
            raise ValueError(f"bad fault target: node={self.node} "
                             f"start={self.start}")
        if self.end != -1 and self.end <= self.start:
            raise ValueError(f"fault window is empty: [{self.start}, {self.end})")
        if self.kind == "slow" and self.factor <= 1.0:
            raise ValueError(f"slowdown factor must be > 1: {self.factor}")
        if self.kind == "flaky" and self.period < 1:
            raise ValueError(f"flaky fault needs period >= 1: {self.period}")

    def _in_window(self, step: int) -> bool:
        return step >= self.start and (self.end == -1 or step < self.end)

    def dead_at(self, step: int) -> bool:
        if not self._in_window(step):
            return False
        if self.kind == "death":
            return True
        if self.kind == "flaky":
            # starts dead at `start`, alternates every `period` steps
            return ((step - self.start) // self.period) % 2 == 0
        return False

    def factor_at(self, step: int) -> float:
        if self.kind == "slow" and self._in_window(step):
            return self.factor
        return 1.0

    def spec(self) -> str:
        """Canonical DSL token: `parse` of it reproduces this fault."""
        end = "" if self.end == -1 else f"-{self.end}"
        tok = f"{self.kind}:{self.node}@{self.start}{end}"
        if self.kind == "slow":
            tok += f"x{_fmt(self.factor)}"
        elif self.kind == "flaky":
            tok += f"p{self.period}"
        return tok


@dataclass(frozen=True)
class LinkFault:
    """One scripted fault on one undirected edge over steps [start, end).

    kind "link": the edge drops each round independently with probability
    `prob` (Bernoulli packet loss). kind "bw": messages over the edge take
    `factor`x longer (bandwidth cap) — the edge stays in the mixing graph but
    gates the lockstep round time of both endpoints."""

    i: int
    j: int
    kind: str  # link | bw
    start: int
    end: int = -1  # exclusive; -1 = until the end of the run
    prob: float = 0.0  # per-round loss probability (kind == "link")
    factor: float = 1.0  # bandwidth slowdown multiplier (kind == "bw")

    def __post_init__(self):
        if self.kind not in LINK_KINDS:
            raise ValueError(
                f"unknown link fault kind {self.kind!r}; one of {LINK_KINDS}")
        if self.i < 0 or self.j < 0 or self.i == self.j:
            raise ValueError(f"bad link target: {self.i}-{self.j}")
        if self.start < 0:
            raise ValueError(f"bad fault start: {self.start}")
        if self.end != -1 and self.end <= self.start:
            raise ValueError(f"fault window is empty: [{self.start}, {self.end})")
        if self.kind == "link" and not 0.0 < self.prob <= 1.0:
            raise ValueError(f"link loss needs prob in (0, 1]: {self.prob}")
        if self.kind == "bw" and self.factor <= 1.0:
            raise ValueError(f"bandwidth factor must be > 1: {self.factor}")

    def _in_window(self, step: int) -> bool:
        return step >= self.start and (self.end == -1 or step < self.end)

    @property
    def edge(self) -> Tuple[int, int]:
        return (min(self.i, self.j), max(self.i, self.j))

    def spec(self) -> str:
        end = "" if self.end == -1 else f"-{self.end}"
        tok = f"{self.kind}:{self.i}-{self.j}@{self.start}{end}"
        if self.kind == "link":
            tok += f"p{_fmt(self.prob)}"
        else:
            tok += f"x{_fmt(self.factor)}"
        return tok


class FaultSchedule:
    """A replayable script of node and link faults over `n` node slots.

    `seed` feeds the counter-based generator behind Bernoulli link-loss
    realizations (`link_drops`); it is not part of the DSL string, so
    equality and the `parse(str(s), n, seed)` round trip carry it
    explicitly."""

    def __init__(self, n: int, faults: Sequence[NodeFault] = (),
                 links: Sequence[LinkFault] = (), seed: int = 0):
        if n < 1:
            raise ValueError(f"need at least one node: n={n}")
        for f in faults:
            if f.node >= n:
                raise ValueError(f"fault targets node {f.node} but n={n}")
        for lf in links:
            if lf.i >= n or lf.j >= n:
                raise ValueError(f"fault targets link {lf.i}-{lf.j} but n={n}")
        self.n = n
        self.faults: Tuple[NodeFault, ...] = tuple(faults)
        self.links: Tuple[LinkFault, ...] = tuple(links)
        self.seed = seed

    @classmethod
    def parse(cls, spec: str, n: int, seed: int = 0) -> "FaultSchedule":
        """Parse the comma-separated fault DSL (module docstring)."""
        faults, links = [], []
        for tok in filter(None, (t.strip() for t in spec.split(","))):
            kind = tok.split(":", 1)[0]
            if kind in LINK_KINDS:
                m = _LINK_RE.match(tok)
                if not m:
                    raise ValueError(f"bad link fault spec {tok!r}; expected "
                                     f"e.g. 'link:1-2@4-20p0.1', "
                                     f"'bw:0-3@5-15x4'")
                g = m.groupdict()
                links.append(LinkFault(
                    i=int(g["i"]), j=int(g["j"]), kind=g["kind"],
                    start=int(g["start"]),
                    end=-1 if g["end"] is None else int(g["end"]),
                    prob=0.0 if g["prob"] is None else float(g["prob"]),
                    factor=1.0 if g["factor"] is None else float(g["factor"])))
                continue
            m = _SPEC_RE.match(tok)
            if not m:
                raise ValueError(f"bad fault spec {tok!r}; expected e.g. "
                                 f"'death:1@5-12', 'slow:0@3-9x4', "
                                 f"'flaky:2@4-20p3', 'link:1-2@4-20p0.1'")
            g = m.groupdict()
            faults.append(NodeFault(
                node=int(g["node"]), kind=g["kind"], start=int(g["start"]),
                end=-1 if g["end"] is None else int(g["end"]),
                factor=1.0 if g["factor"] is None else float(g["factor"]),
                period=0 if g["period"] is None else int(g["period"])))
        return cls(n, faults, links, seed)

    def __str__(self) -> str:
        return ",".join(f.spec() for f in self.faults + self.links)

    def __repr__(self) -> str:
        return (f"FaultSchedule({self.n}, {str(self)!r}, seed={self.seed})")

    def __eq__(self, other) -> bool:
        if not isinstance(other, FaultSchedule):
            return NotImplemented
        return (self.n, self.faults, self.links, self.seed) == (
            other.n, other.faults, other.links, other.seed)

    def __hash__(self) -> int:
        return hash((self.n, self.faults, self.links, self.seed))

    @property
    def has_node_faults(self) -> bool:
        return bool(self.faults)

    @property
    def has_link_faults(self) -> bool:
        return bool(self.links)

    def alive(self, step: int) -> Membership:
        """The membership the fault layer dictates at a driver superstep."""
        mask = [True] * self.n
        for f in self.faults:
            if f.dead_at(step):
                mask[f.node] = False
        if not any(mask):
            raise ValueError(f"fault schedule kills every node at step {step}")
        return Membership(self.n, tuple(mask))

    def time_factors(self, step: int) -> np.ndarray:
        """Per-node wall-time multipliers at a step (1.0 = nominal). Factors
        from overlapping slowdowns on the same node multiply."""
        out = np.ones(self.n)
        for f in self.faults:
            out[f.node] *= f.factor_at(step)
        return out

    def round_s_per_node(self, step: int, base_round_s: float) -> list:
        """Simulated per-node round times at a step: the nominal round time
        scaled by each node's slowdown factor — including bandwidth caps on
        incident links, which slow both endpoints — None for dead nodes. This
        is the feed for `core.rates.StragglerPolicy.observe` in tests and
        `benchmarks/bench_elastic.py`."""
        alive = self.alive(step).active
        factors = self.time_factors(step) * self.link_time_factors(step)
        return [base_round_s * float(factors[i]) if alive[i] else None
                for i in range(self.n)]

    # -- link models (scenario harness) -----------------------------------

    def link_time_factors(self, step: int) -> np.ndarray:
        """Per-node wall-time multipliers from bandwidth-capped incident
        links: a `bw:i-j@a-bx4` fault makes both endpoints' rounds 4x longer
        while active (the consensus round blocks on the slowest edge).
        Overlapping caps on a node take the max, not the product — the edges
        transfer concurrently and the slowest gates."""
        out = np.ones(self.n)
        for lf in self.links:
            if lf.kind == "bw" and lf._in_window(step):
                out[lf.i] = max(out[lf.i], lf.factor)
                out[lf.j] = max(out[lf.j], lf.factor)
        return out

    def bw_factor(self, step: int) -> float:
        """The lockstep round's communication slowdown at a step: the max
        active bandwidth-cap factor (1.0 = links at nominal rate). Scales the
        comm term of simulated round times, which is how rate-limited links
        reach the governor's (R_p, R_c) estimator."""
        f = 1.0
        for lf in self.links:
            if lf.kind == "bw" and lf._in_window(step):
                f = max(f, lf.factor)
        return f

    def link_drops(self, step: int) -> Tuple[Tuple[int, int], ...]:
        """The undirected edges lost at a step, as a sorted (i, j) tuple.

        Each active `link` fault draws an independent Bernoulli(prob) from a
        counter-based generator keyed on (seed, step, edge) — a pure function
        of the arguments, with no RNG stream shared across steps — so masks
        are identical across runs, resumes, and prefetch depths."""
        drops = set()
        for lf in self.links:
            if lf.kind != "link" or not lf._in_window(step):
                continue
            i, j = lf.edge
            rng = np.random.default_rng(
                np.random.SeedSequence(entropy=(self.seed, step, i, j)))
            if rng.random() < lf.prob:
                drops.add((i, j))
        return tuple(sorted(drops))

    def lossy_matrix(self, A: np.ndarray, step: int) -> np.ndarray:
        """Realize this step's link losses on a symmetric doubly-stochastic
        one-round mixing matrix.

        Dropped edges leave the graph for the round; the survivors are
        re-derived by Metropolis reweighting (`core.mixing`), which puts the
        lost mass on the endpoints' self-weights — the operator stays doubly
        stochastic and, while the realization stays connected, contractive.
        If a draw disconnects the graph, the dropped weight is folded onto
        the diagonal directly (each lost edge degrades to self-weight);
        still doubly stochastic, merely non-contracting for that round —
        eq. 17's B-connectivity over the window restores progress."""
        A = np.array(A, dtype=float, copy=True)
        n = A.shape[0]
        if n != self.n:
            raise ValueError(f"matrix n={n} vs schedule n={self.n}")
        drops = [e for e in self.link_drops(step)
                 if e[0] < n and e[1] < n and A[e[0], e[1]] != 0.0]
        if not drops:
            return A
        adj = np.abs(A) > 0
        np.fill_diagonal(adj, False)
        for i, j in drops:
            adj[i, j] = adj[j, i] = False
        if _connected(adj):
            return metropolis_weights(adj.astype(float))
        for i, j in drops:
            A[i, i] += A[i, j]
            A[j, j] += A[j, i]
            A[i, j] = A[j, i] = 0.0
        return A

    def events_between(self, lo: int, hi: int) -> bool:
        """True if membership differs anywhere in (lo, hi] from step lo —
        a cheap way for callers to skip mask recomputation on quiet spans."""
        base = self.alive(lo).active
        return any(self.alive(s).active != base for s in range(lo + 1, hi + 1))
