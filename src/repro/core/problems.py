"""Loss oracles for the paper's experiments: smooth convex losses (logistic,
hinge-smoothed) and the 1-PCA loss (eq. 13) with Krasulina's pseudo-gradient.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Logistic regression (convex, smooth)
# ---------------------------------------------------------------------------


def logistic_loss(w: jax.Array, x: jax.Array, y: jax.Array) -> jax.Array:
    """w: [d+1] (weights, bias); x: [n, d]; y: [n] in {-1, +1}."""
    z = x @ w[:-1] + w[-1]
    return jnp.mean(jnp.logaddexp(0.0, -y * z))


logistic_grad = jax.grad(logistic_loss)


def logistic_risk(w: jax.Array, draw, key, n: int = 20_000) -> jax.Array:
    x, y = draw(key, n)
    return logistic_loss(w, x, y)


def project_ball(w: jax.Array, radius: float) -> jax.Array:
    """Projection onto the l2 ball of given radius (bounded model space W)."""
    nrm = jnp.linalg.norm(w)
    return jnp.where(nrm > radius, w * (radius / nrm), w)


# ---------------------------------------------------------------------------
# 1-PCA (structured nonconvex, eq. 13)
# ---------------------------------------------------------------------------


def pca_loss(w: jax.Array, cov: jax.Array) -> jax.Array:
    """Population risk f(w) = -w^T Sigma w / ||w||^2."""
    return -(w @ cov @ w) / jnp.maximum(w @ w, 1e-30)


def pca_excess_risk(w: jax.Array, cov: jax.Array, lambda1: float) -> jax.Array:
    return pca_loss(w, cov) + lambda1


def krasulina_xi(w: jax.Array, z: jax.Array) -> jax.Array:
    """Mini-batch Krasulina pseudo-gradient (Alg. 2, step 4, averaged over the
    local batch): xi = mean_b [ z_b (z_b.w) - ((w.z_b)^2/||w||^2) w ]."""
    zw = z @ w  # [n]
    nrm2 = jnp.maximum(w @ w, 1e-30)
    return (z.T @ zw) / z.shape[0] - (jnp.mean(zw**2) / nrm2) * w


def sin2_error(w: jax.Array, v: jax.Array) -> jax.Array:
    """sin^2 angle between w and the true eigenvector v (alignment error)."""
    c = (w @ v) ** 2 / (jnp.maximum(w @ w, 1e-30) * (v @ v))
    return 1.0 - c
