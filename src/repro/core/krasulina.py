"""Algorithm 2 — the D(M)-Krasulina family: distributed mini-batch Krasulina's
method for streaming 1-PCA, with mu discarded samples per round
(under-provisioned regime, Theorem 5) and the averaging of the per-node
pseudo-gradients xi as a first-class knob:

* **exact** (`run_dm_krasulina`, DM-Krasulina [75]): `jnp.mean` over the node
  axis — Alg. 2 step 6 verbatim. All nodes stay bit-identical, so the state is
  one shared iterate. This path is the R -> infinity oracle the gossip variant
  is validated against, and it is kept bit-identical to the seed
  implementation.
* **gossip** (`run_d_krasulina` with an `AveragingConfig`): each node keeps its
  own iterate; the xi's are averaged through the consensus engine
  (`core.mixing.CirculantMixOp` — precomputed R-round operator, optionally
  quantized per Section VI) exactly as the convex D-SGD track. On TPU the
  per-node xi and all R gossip rounds fuse into one kernel pass
  (`kernels.ops.krasulina_xi_gossip`).

The per-node pseudo-gradient goes through `kernels.ops.krasulina_xi`, so the
fused single-HBM-pass Pallas kernel is on the hot path on TPU (the jnp
reference path serves CPU).

`build_krasulina_superstep` packages a K-round `lax.scan` over either variant
for `train.driver.StreamingDriver`, which provisions the PCA stream with the
same governed splitter / prefetch ring / closed-loop (B, mu) governor the
logreg track uses (Fig. 3(c), eq. 4).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AveragingConfig
from repro.core.averaging import make_gossip_mix
from repro.core.dsgd import jit_driver
from repro.core.mixing import CirculantMixOp, DenseMixOp, ScheduledMixOp
from repro.core.quantize import STOCHASTIC
from repro.kernels.ops import krasulina_xi, krasulina_xi_gossip


class KrasulinaResult(NamedTuple):
    w: jax.Array
    trace_t_prime: jax.Array
    trace_metric: jax.Array


class DKrasulinaResult(NamedTuple):
    w_nodes: jax.Array  # [N, d] final per-node iterates
    w: jax.Array  # [d] node-mean iterate (== w_nodes[i] in exact mode)
    trace_t_prime: jax.Array
    trace_metric: jax.Array  # metric of the node-mean iterate per round


def _resolve_fuse_xi(mix: CirculantMixOp, fuse_xi: Optional[bool]) -> bool:
    """The combined xi+gossip kernel replaces `mix(vmap(xi))` when it wins:
    always on TPU (tile-resident consensus, one HBM write), never by default
    on CPU/GPU where the MixOp's composed-schedule impl (roll/matmul) is the
    fast path and the kernel would run in interpret mode. Quantized configs
    can't fuse (nonlinear per-round compressor), and time-varying
    `ScheduledMixOp` schedules never do (the kernel bakes one circulant
    schedule; the scheduled op's phase is runtime data)."""
    if isinstance(mix, (ScheduledMixOp, DenseMixOp)):
        return False  # no circulant schedule for the kernel to bake
    if mix.quantization != "none":
        return False
    if fuse_xi is not None:
        return fuse_xi
    return jax.default_backend() == "tpu"


def _gossip_xi(w: jax.Array, z: jax.Array, mix: CirculantMixOp, fused: bool,
               t: jax.Array) -> jax.Array:
    """Gossip-averaged pseudo-gradients: xi per node, R consensus rounds.
    `t` (the round counter) is folded into the MixOp seed so stochastic
    compressors draw fresh per-round noise every scan step (the fused kernel
    path only exists for quantization="none", where the key is moot). A
    time-varying `ScheduledMixOp` receives `t` itself — the carry's round
    counter is the schedule clock, so topology switches are pure runtime
    data (zero retraces) and replay identically on resume."""
    if fused:
        return krasulina_xi_gossip(w, z, mix.sched, mix.rounds)
    h = jax.vmap(krasulina_xi)(w, z)
    if isinstance(mix, ScheduledMixOp):
        return mix(h, t=t)
    if isinstance(mix, DenseMixOp):
        return mix(h)  # dense operators are linear-only, no key to thread
    step_key = None
    if mix.quantization in STOCHASTIC:
        step_key = jax.random.fold_in(jax.random.PRNGKey(mix.seed), t)
    return mix(h, key=step_key)


def _check_averaging(averaging: AveragingConfig) -> None:
    """The PCA track averages one [N, d] vector — pod-structured hierarchical
    reduce-scatter has no meaning without a mesh; reject it loudly instead of
    silently running flat gossip with reinterpreted semantics."""
    if averaging.mode not in ("exact", "gossip"):
        raise ValueError(
            f"D-Krasulina supports exact|gossip averaging, got "
            f"{averaging.mode!r}")


def run_d_krasulina(
    draw: Callable,  # draw(key, n) -> z [n, d]
    w0: jax.Array,  # [d] common init
    *,
    N: int,
    B: int,
    mu: int = 0,
    steps: int,
    stepsize: Callable,  # stepsize(t) -> eta_t (Thm 5: c/(Q+t))
    averaging: Optional[AveragingConfig] = None,  # None -> exact (DM-Krasulina)
    mix: Optional[CirculantMixOp] = None,  # prebuilt consensus engine override
    trace_metric: Optional[Callable] = None,
    fuse_xi: Optional[bool] = None,  # None -> auto (kernel on TPU)
    seed: int = 0,
) -> DKrasulinaResult:
    """The D-Krasulina family: `averaging=None` (or mode="exact") is
    DM-Krasulina with exact xi averaging — bit-identical to
    `run_dm_krasulina`; a gossip `AveragingConfig` replaces step 6 with R
    rounds of (optionally quantized) circulant consensus through the MixOp
    engine, with per-node iterates."""
    assert B % N == 0
    if averaging is not None:
        _check_averaging(averaging)
    metric = trace_metric or (lambda w: jnp.zeros(()))
    exact = averaging is None or averaging.mode == "exact"
    ts = jnp.arange(1, steps + 1)
    t_prime = ts * (B + mu)

    if exact:
        def round_fn(carry, t):
            w, key = carry
            key, kd = jax.random.split(key)
            z = draw(kd, B + mu)[:B].reshape(N, B // N, -1)
            xi_n = jax.vmap(lambda zn: krasulina_xi(w, zn))(z)  # steps 3-5
            xi = jnp.mean(xi_n, axis=0)  # exact averaging (step 6)
            w_new = w + stepsize(t) * xi  # step 7
            return (w_new, key), metric(w_new)

        drive = jit_driver(lambda init, ts: jax.lax.scan(round_fn, init, ts))
        # copy w0: the carry is donated, and the caller keeps ownership of w0
        (w, _), metrics = drive((jnp.array(w0), jax.random.PRNGKey(seed)), ts)
        return DKrasulinaResult(jnp.broadcast_to(w[None], (N, w.shape[0])), w,
                                t_prime, metrics)

    if mix is None:
        mix = make_gossip_mix(averaging, N)
    fused = _resolve_fuse_xi(mix, fuse_xi)

    def round_fn(carry, t):
        w, key = carry  # w: [N, d] per-node iterates
        key, kd = jax.random.split(key)
        z = draw(kd, B + mu)[:B].reshape(N, B // N, -1)
        h = _gossip_xi(w, z, mix, fused, t)  # steps 3-6, consensus form
        w_new = w + stepsize(t) * h  # step 7, per node
        return (w_new, key), metric(jnp.mean(w_new, axis=0))

    w_nodes = jnp.tile(w0[None], (N, 1))
    drive = jit_driver(lambda init, ts: jax.lax.scan(round_fn, init, ts))
    (w, _), metrics = drive((w_nodes, jax.random.PRNGKey(seed)), ts)
    return DKrasulinaResult(w, jnp.mean(w, axis=0), t_prime, metrics)


def run_dm_krasulina(
    draw: Callable,
    w0: jax.Array,
    *,
    N: int,
    B: int,
    mu: int = 0,
    steps: int,
    stepsize: Callable,
    trace_metric: Optional[Callable] = None,
    seed: int = 0,
) -> KrasulinaResult:
    """Exact-averaging DM-Krasulina (Alg. 2 as printed) — the R -> infinity
    oracle of the gossip family, kept bit-identical to the seed path."""
    res = run_d_krasulina(draw, w0, N=N, B=B, mu=mu, steps=steps,
                          stepsize=stepsize, trace_metric=trace_metric,
                          seed=seed)
    return KrasulinaResult(res.w, res.trace_t_prime, res.trace_metric)


# ---------------------------------------------------------------------------
# Superstep integration (train.driver)
# ---------------------------------------------------------------------------


class KrasulinaState(NamedTuple):
    """Carry of the K-round PCA superstep: the iterate(s) and the global round
    counter t that Theorem 5's stepsize c/(Q+t) indexes."""

    w: jax.Array  # [d] (exact) or [N, d] (decentralized)
    t: jax.Array  # scalar int32, rounds completed


def init_krasulina_state(w0: jax.Array, averaging: AveragingConfig,
                         n_nodes: int) -> KrasulinaState:
    """Initial superstep carry: exact mode shares one iterate, gossip mode
    replicates it per node (identical copies, like the trainer's
    `replicate_for_nodes`)."""
    w0 = jnp.asarray(w0)
    if averaging.mode != "exact":
        w0 = jnp.tile(w0[None], (n_nodes, 1))
    return KrasulinaState(w0, jnp.zeros((), jnp.int32))


def build_krasulina_superstep(averaging: AveragingConfig, n_nodes: int,
                              stepsize: Callable, *,
                              metric: Optional[Callable] = None,
                              mix: Optional[CirculantMixOp] = None,
                              fuse_xi: Optional[bool] = None) -> Callable:
    """The PCA counterpart of `train.trainer.build_superstep`: one jitted
    K-round `lax.scan` per dispatch, consumable by
    `train.driver.StreamingDriver` (pass it as `superstep_fn`).

    superstep(state, batches) -> (state, metrics): batches = {"z": ...} with a
    leading K axis — [K, B, d] in exact mode, [K, N, B/N, d] decentralized
    (the driver's splitter does the node split); metric leaves come back
    stacked [K]. Metrics: `metric` of the node-mean iterate (or zeros) and
    the consensus spread max_n ||w_n - w_bar|| / ||w_bar||."""
    _check_averaging(averaging)
    exact = averaging.mode == "exact"
    metric_fn = metric or (lambda w: jnp.zeros(()))
    if not exact and mix is None:
        mix = make_gossip_mix(averaging, n_nodes)
    fused = False if exact else _resolve_fuse_xi(mix, fuse_xi)

    def round_fn(state: KrasulinaState, batch):
        w, t = state
        t = t + 1
        z = batch["z"]
        if exact:
            zn = z.reshape(n_nodes, z.shape[0] // n_nodes, -1)
            h = jnp.mean(jax.vmap(lambda zb: krasulina_xi(w, zb))(zn), axis=0)
            w_new = w + stepsize(t) * h
            wbar, spread = w_new, jnp.zeros(())
        else:
            h = _gossip_xi(w, z, mix, fused, t)
            w_new = w + stepsize(t) * h
            wbar = jnp.mean(w_new, axis=0)
            num = jnp.max(jnp.linalg.norm(w_new - wbar[None], axis=1))
            spread = num / (jnp.linalg.norm(wbar) + 1e-30)
        metrics = {"metric": metric_fn(wbar), "consensus_err": spread}
        return KrasulinaState(w_new, t), metrics

    def superstep(state: KrasulinaState, batches):
        return jax.lax.scan(round_fn, state, batches)

    return superstep


def krasulina_superstep_builder(averaging: AveragingConfig, n_nodes: int,
                                stepsize: Callable, *,
                                metric: Optional[Callable] = None,
                                mix: Optional[CirculantMixOp] = None,
                                fuse_xi: Optional[bool] = None,
                                ) -> Callable[..., Callable]:
    """Bucket-keyed PCA superstep factory for the adaptive-B governor: the
    counterpart of `train.trainer.superstep_builder`, consumable as
    `StreamingDriver(superstep_builder=...)`. The K-round scan derives every
    shape (K, the per-node share B/N) from its batch at trace time, so one
    closure serves all buckets; the MixOp consensus engine is built once
    here, and the driver compiles one executable per registered bucket
    (docs/DESIGN.md §Adaptive batch buckets).

    `build(B, membership=None)` — a partial `core.mixing.Membership` asks for
    the cohort superstep (n_nodes = n_active, gossip schedule recomposed over
    the active cohort — docs/DESIGN.md §Elastic membership); the prebuilt
    `mix` override only applies at full membership, since its schedule is
    sized for the full node axis."""
    full = build_krasulina_superstep(averaging, n_nodes, stepsize,
                                     metric=metric, mix=mix, fuse_xi=fuse_xi)
    cohort_cache = {n_nodes: full}

    def build(B: int, membership=None) -> Callable:
        m = n_nodes if membership is None else membership.n_active
        fn = cohort_cache.get(m)
        if fn is None:
            fn = build_krasulina_superstep(averaging, m, stepsize,
                                           metric=metric, fuse_xi=fuse_xi)
            cohort_cache[m] = fn
        return fn

    return build


def theorem5_Q(d: int, kappa: float, sigma_B2: float, c: float, delta: float = 0.25):
    """Q1 + Q2 from Theorem 5 (eq. 22) — the stepsize offset."""
    import math

    e = math.e
    Q1 = 64 * e * d * kappa**4 * max(1.0, c**2) / delta**2 * math.log(4 / delta)
    Q2 = 512 * e**2 * d**2 * sigma_B2 * max(1.0, c**2) / delta**4 * math.log(4 / delta)
    return Q1 + Q2
