"""Algorithm 2 — DM-Krasulina [75]: distributed mini-batch Krasulina's method for
streaming 1-PCA, with exact averaging of the per-node pseudo-gradients xi and
support for mu discarded samples per round (under-provisioned regime).

The per-node pseudo-gradient goes through `kernels.ops.krasulina_xi`, so the
fused single-HBM-pass Pallas kernel is on the hot path on TPU (the jnp
reference path serves CPU).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.dsgd import jit_driver
from repro.kernels.ops import krasulina_xi


class KrasulinaResult(NamedTuple):
    w: jax.Array
    trace_t_prime: jax.Array
    trace_metric: jax.Array


def run_dm_krasulina(
    draw: Callable,  # draw(key, n) -> z [n, d]
    w0: jax.Array,
    *,
    N: int,
    B: int,
    mu: int = 0,
    steps: int,
    stepsize: Callable,  # stepsize(t) -> eta_t (Thm 5: c/(Q+t))
    trace_metric: Optional[Callable] = None,
    seed: int = 0,
) -> KrasulinaResult:
    assert B % N == 0
    metric = trace_metric or (lambda w: jnp.zeros(()))

    def round_fn(carry, t):
        w, key = carry
        key, kd = jax.random.split(key)
        z = draw(kd, B + mu)[:B].reshape(N, B // N, -1)
        xi_n = jax.vmap(lambda zn: krasulina_xi(w, zn))(z)  # steps 3-5
        xi = jnp.mean(xi_n, axis=0)  # exact averaging (step 6)
        w_new = w + stepsize(t) * xi  # step 7
        return (w_new, key), metric(w_new)

    drive = jit_driver(lambda init, ts: jax.lax.scan(round_fn, init, ts))
    # copy w0: the carry is donated, and the caller keeps ownership of w0
    (w, _), metrics = drive((jnp.array(w0), jax.random.PRNGKey(seed)),
                            jnp.arange(1, steps + 1))
    t_prime = jnp.arange(1, steps + 1) * (B + mu)
    return KrasulinaResult(w, t_prime, metrics)


def theorem5_Q(d: int, kappa: float, sigma_B2: float, c: float, delta: float = 0.25):
    """Q1 + Q2 from Theorem 5 (eq. 22) — the stepsize offset."""
    import math

    e = math.e
    Q1 = 64 * e * d * kappa**4 * max(1.0, c**2) / delta**2 * math.log(4 / delta)
    Q2 = 512 * e**2 * d**2 * sigma_B2 * max(1.0, c**2) / delta**4 * math.log(4 / delta)
    return Q1 + Q2
