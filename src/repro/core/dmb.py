"""Algorithm 1 — the Distributed Mini-batch (DMB) algorithm [Dekel et al., 108].

Faithful semantics: each round, B samples are split across N nodes; each node
averages gradients over its local B/N mini-batch; mini-batch gradients are
*exactly* averaged network-wide (AllReduce); every node applies the identical
projected-SGD step. Under-provisioned systems additionally discard mu samples
per round at the splitter (steps 9-11).

The whole run is a single `lax.scan`; samples are drawn statelessly per round so
arbitrarily long streams never materialize.

`w0` may be a pytree: it is packed ONCE into a flat buffer (`core.packing`)
outside the scan, so the update / projection / Polyak-average arithmetic runs
as single fused elementwise ops on one contiguous vector instead of one chain
per leaf; `grad_fn`, `project`, and `trace_metric` still see (and return) the
original tree structure, and the result is unpacked back to it.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import packing


class DMBResult(NamedTuple):
    w: Any
    w_av: Any  # Polyak-Ruppert average (eq. 7, stepsize-weighted)
    trace_t_prime: jax.Array  # samples *arrived* (consumed + discarded)
    trace_metric: jax.Array


def run_dmb(
    grad_fn: Callable,  # grad_fn(w, *z_local) -> local mini-batch avg gradient
    draw: Callable,  # draw(key, n) -> one round's samples (tuple or array)
    w0: jax.Array,
    *,
    N: int,
    B: int,
    mu: int = 0,
    steps: int,
    stepsize: Callable,  # stepsize(t) -> eta_t, jnp-traceable, t starts at 1
    project: Optional[Callable] = None,
    trace_metric: Optional[Callable] = None,  # trace_metric(w) -> scalar
    seed: int = 0,
) -> DMBResult:
    assert B % N == 0, "B must split evenly across N nodes (Section II-B)"
    leaves = jax.tree.leaves(w0)
    is_tree = len(leaves) != 1 or leaves[0] is not w0
    if is_tree:
        # pack the parameter pytree once, outside the scan; user callables
        # keep the tree view via unpack/repack shims at the trace boundary
        bufs, spec = packing.pack_tree(w0, lead=0)
        assert len(bufs) == 1, "pytree w0 must share a single dtype"
        unpack = lambda b: packing.unpack_tree((b,), spec)
        repack = lambda t: packing.pack_tree(t, spec)[0][0]
        user_grad, user_proj, user_metric = grad_fn, project, trace_metric
        grad_fn = lambda w, *z: repack(user_grad(unpack(w), *z))
        project = ((lambda w: repack(user_proj(unpack(w))))
                   if user_proj is not None else None)
        trace_metric = ((lambda w: user_metric(unpack(w)))
                        if user_metric is not None else None)
        w0 = bufs[0]
    proj = project or (lambda w: w)
    metric = trace_metric or (lambda w: jnp.zeros(()))

    def round_fn(carry, t):
        w, w_av, eta_sum, key = carry
        key, kd = jax.random.split(key)
        # the splitter receives B + mu samples and discards mu (step 10)
        z = draw(kd, B + mu)
        z = jax.tree.map(lambda a: a[:B].reshape(N, B // N, *a.shape[1:]), z)
        g_n = jax.vmap(lambda zn: grad_fn(w, *jax.tree.leaves(zn)))(z)  # [N, d]
        g = jnp.mean(g_n, axis=0)  # exact averaging (step 7)
        eta = stepsize(t)
        w_new = proj(w - eta * g)  # step 8
        # stepsize-weighted Polyak-Ruppert average (eq. 7)
        eta_sum_new = eta_sum + eta
        w_av_new = (eta_sum * w_av + eta * w_new) / eta_sum_new
        return (w_new, w_av_new, eta_sum_new, key), metric(w_new)

    key = jax.random.PRNGKey(seed)
    init = (w0, jnp.zeros_like(w0), jnp.zeros(()), key)
    (w, w_av, _, _), metrics = jax.lax.scan(round_fn, init,
                                            jnp.arange(1, steps + 1))
    t_prime = jnp.arange(1, steps + 1) * (B + mu)
    if is_tree:
        return DMBResult(unpack(w), unpack(w_av), t_prime, metrics)
    return DMBResult(w, w_av, t_prime, metrics)
