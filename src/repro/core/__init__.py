"""The paper's contribution: distributed mini-batch streaming stochastic
approximation with exact (AllReduce) and inexact (consensus) averaging, plus the
rate-model planner."""
from repro.core import averaging, dmb, dsgd, krasulina, mixing, problems, quantize, rates, streaming  # noqa: F401
