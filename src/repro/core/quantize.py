"""Message compressors for consensus rounds (paper Section VI, "Message
quantization" — signSGD [125] and int8 stochastic rounding). Beyond-paper
feature; applied to gossip messages in `core.averaging`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sign_compress(x: jax.Array) -> jax.Array:
    """1-bit signSGD compressor with the scale-preserving mean-|x| factor."""
    scale = jnp.mean(jnp.abs(x))
    return jnp.sign(x) * scale


def int8_compress(x: jax.Array) -> jax.Array:
    """Deterministic symmetric int8 quantization (dequantized back to float —
    models the wire format's precision loss)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    return q * scale


COMPRESSORS = {"none": lambda x: x, "sign": sign_compress, "int8": int8_compress}
