"""Message compressors for consensus rounds (paper Section VI, "Message
quantization" — signSGD [125] and int8 rounding, deterministic and threefry-
keyed stochastic). Beyond-paper feature; applied to gossip messages in
`core.averaging` / `core.mixing`.

Three statistics granularities, selected by `core.mixing.CirculantMixOp.stats`:

* **global**  — one scale per message array (`sign_compress` / `int8_compress`
  exactly as shipped since PR 1: the bit-identity oracle).
* **segment** — one scale per leaf segment of a packed flat buffer
  (`core.packing`): reproduces the per-leaf path's statistics on the single
  packed buffer, so a hundred-leaf tree pays one compressor pass, not hundreds.
* **tile**    — one scale per `[n, block_d]` column tile (`tile_compress`):
  the statistics the fused Pallas kernel computes in-register
  (`kernels.consensus.gossip_mix_quant_pallas`); this XLA form is its oracle
  and the CPU execution path.

All stat reductions accept an optional validity `mask` so zero-padded columns
(hierarchical reduce-scatter padding, tile padding) never perturb the scales.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

_EPS = 1e-12
_DEFAULT_SEED = 0x5EED


def _abs_mean(x: jax.Array, mask) -> jax.Array:
    if mask is None:
        return jnp.mean(jnp.abs(x))
    m = jnp.broadcast_to(mask, x.shape)
    cnt = jnp.maximum(jnp.sum(m.astype(x.dtype)), 1)
    return jnp.sum(jnp.where(m, jnp.abs(x), 0)) / cnt


def _abs_max(x: jax.Array, mask) -> jax.Array:
    if mask is None:
        return jnp.max(jnp.abs(x))
    return jnp.max(jnp.where(mask, jnp.abs(x), 0))


def sign_compress(x: jax.Array, *, mask=None) -> jax.Array:
    """1-bit signSGD compressor with the scale-preserving mean-|x| factor."""
    scale = _abs_mean(x, mask)
    return jnp.sign(x) * scale


def int8_compress(x: jax.Array, *, mask=None) -> jax.Array:
    """Deterministic symmetric int8 quantization (dequantized back to float —
    models the wire format's precision loss)."""
    scale = jnp.maximum(_abs_max(x, mask), _EPS) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    return q * scale


def int8_stoch_compress(x: jax.Array, *, key=None, mask=None) -> jax.Array:
    """Unbiased symmetric int8: threefry-keyed stochastic rounding.
    floor(v + u), u ~ U[0, 1) rounds v up with probability frac(v), so
    E[dequant] = x (up to the clip). `key=None` uses a fixed module key —
    deterministic per call site; the mixing loop folds the round index in."""
    if key is None:
        key = jax.random.PRNGKey(_DEFAULT_SEED)
    scale = jnp.maximum(_abs_max(x, mask), _EPS) / 127.0
    v = x.astype(jnp.float32) / scale.astype(jnp.float32)
    u = jax.random.uniform(key, x.shape, dtype=jnp.float32)
    q = jnp.clip(jnp.floor(v + u), -127, 127)
    return (q * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# Segment statistics (packed flat buffers, `core.packing`)
# ---------------------------------------------------------------------------


def segment_scales(x: jax.Array, seg_widths, kind: str) -> jax.Array:
    """Per-column scale vector [D] for a packed buffer x: [..., D] whose
    trailing axis is the concatenation of contiguous leaf segments of (static)
    widths `seg_widths`: each segment gets the statistic (`kind`: "mean_abs" |
    "max_abs") it would get on the per-leaf path.

    Contiguity is the whole trick: per-segment sums are differences of one
    cumulative sum at static boundaries, and the broadcast back is a static
    `repeat` — no scatter/gather `segment_sum`, which is the slow path on
    CPU/TPU backends."""
    from repro.core.packing import segment_sums

    widths = np.asarray(seg_widths, np.int64)
    d = int(widths.sum())
    if x.shape[-1] != d:
        raise ValueError(f"buffer width {x.shape[-1]} != sum(seg_widths)={d}")
    bounds = np.cumsum(widths)[:-1]
    a = jnp.abs(x).reshape(-1, d)
    rows = a.shape[0]
    # XLA CPU reduces a strided leading axis poorly; the row count is small
    # (node axis), so collapse it via gemv (sum) / an unrolled maximum chain
    # (max) before the cheap per-segment step (static contiguous slices —
    # exact, unlike a float32 cumsum-difference, which cancels at scale)
    if kind == "mean_abs":
        col = jnp.ones((rows,), a.dtype) @ a  # [D], row-sum as gemv
        per_seg = segment_sums(col, widths) / \
            jnp.asarray(np.maximum(widths * rows, 1), col.dtype)
    elif kind == "max_abs":
        col = _row_max(a)  # [D]
        parts = jnp.split(col, list(bounds))
        per_seg = jnp.stack([jnp.max(p) if p.size else jnp.zeros((), col.dtype)
                             for p in parts])
    else:
        raise ValueError(f"unknown statistic {kind!r}")
    return jnp.repeat(per_seg, widths, total_repeat_length=d)  # [D]


def _row_max(a: jax.Array) -> jax.Array:
    """max over the (small, static) leading axis as an unrolled elementwise
    chain — row-sequential access instead of XLA's column-strided reduce."""
    m = a[0]
    for i in range(1, a.shape[0]):
        m = jnp.maximum(m, a[i])
    return m


def _segment_compress(x, name, seg_widths, *, key=None):
    if name == "sign":
        return jnp.sign(x) * segment_scales(x, seg_widths, "mean_abs")
    s = jnp.maximum(segment_scales(x, seg_widths, "max_abs"), _EPS) / 127.0
    if name == "int8":
        return jnp.clip(jnp.round(x / s), -127, 127) * s
    if name == "int8_stoch":
        if key is None:
            key = jax.random.PRNGKey(_DEFAULT_SEED)
        v = x.astype(jnp.float32) / s.astype(jnp.float32)
        u = jax.random.uniform(key, x.shape, dtype=jnp.float32)
        return (jnp.clip(jnp.floor(v + u), -127, 127) * s).astype(x.dtype)
    raise ValueError(f"unknown compressor {name!r}")


# ---------------------------------------------------------------------------
# Tile statistics (the fused kernel's in-register form; XLA oracle/CPU path)
# ---------------------------------------------------------------------------


def tile_valid_counts(d: int, block_d: int, valid_d: Optional[int] = None
                      ) -> np.ndarray:
    """Static per-tile count of valid columns for a [*, d] buffer tiled at
    `block_d` with columns >= `valid_d` being pad."""
    bd = min(block_d, d)
    tiles = -(-d // bd)
    dv = d if valid_d is None else valid_d
    lo = np.arange(tiles) * bd
    return np.clip(np.minimum(lo + bd, dv) - lo, 0, bd)


def tile_compress(x: jax.Array, name: str, block_d: int, *,
                  valid_d: Optional[int] = None, key=None,
                  per_node: bool = False) -> jax.Array:
    """Quantize x: [n, D] with one scale per [n, block_d] column tile.

    Matches `kernels.consensus.gossip_mix_quant_pallas` statistics: f32
    computation, and the ragged tail / columns >= `valid_d` excluded from
    every statistic. Pad columns are REQUIRED to be zero (both pad sources —
    kernel tiling and the hierarchical reduce-scatter — zero-fill), which is
    what lets the statistics use plain contiguous reductions with static
    counts instead of runtime masks. Output dtype follows x.

    `per_node=True` keeps the node axis out of the statistic: one scale per
    [1, block_d] row tile — the statistic a real sender computes from its own
    message alone, and the only granularity whose wire values are invariant
    under a node-axis device split (`kernels.consensus.gossip_mix_quant_shard`
    computes it shard-locally, bit-identical to this form)."""
    n, d = x.shape
    bd = min(block_d, d)
    tiles = -(-d // bd)
    pad = tiles * bd - d
    xf = x.astype(jnp.float32)
    if pad:
        xf = jnp.pad(xf, ((0, 0), (0, pad)))
    xt = xf.reshape(n, tiles, bd)
    a = jnp.abs(xt)
    # reduce the contiguous lane axis FIRST, then the tiny remainder — XLA
    # CPU reduces strided leading axes an order of magnitude slower
    if name == "sign":
        rows = 1 if per_node else n
        cnt = jnp.asarray(
            np.maximum(tile_valid_counts(d, block_d, valid_d) * rows, 1),
            jnp.float32)
        s = a.sum(2)  # [n, tiles]
        scale = s / cnt if per_node else s.sum(0)[None] / cnt  # [n|1, tiles]
        out = jnp.sign(xt) * scale[:, :, None]
    else:
        amax = a.max(2) if per_node else a.max(2).max(0)[None]  # [n|1, tiles]
        scale = jnp.maximum(amax, _EPS) / 127.0
        v = xt / scale[:, :, None]
        if name == "int8":
            out = jnp.clip(jnp.round(v), -127, 127) * scale[:, :, None]
        elif name == "int8_stoch":
            if key is None:
                key = jax.random.PRNGKey(_DEFAULT_SEED)
            u = jax.random.uniform(key, v.shape, dtype=jnp.float32)
            out = jnp.clip(jnp.floor(v + u), -127, 127) * scale[:, :, None]
        else:
            raise ValueError(f"unknown compressor {name!r}")
    out = out.reshape(n, tiles * bd)
    if pad:
        out = out[:, :d]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Registry / factory
# ---------------------------------------------------------------------------

STOCHASTIC = ("int8_stoch",)

COMPRESSORS = {
    "none": lambda x: x,
    "sign": sign_compress,
    "int8": int8_compress,
    "int8_stoch": int8_stoch_compress,
}


def make_compressor(name: str, *, key=None, mask=None, seg_widths=None
                    ) -> Callable[[jax.Array], jax.Array]:
    """Unary message compressor with the requested statistics.

    With every keyword at its default this is exactly ``COMPRESSORS[name]`` —
    the bit-identity contract of the `stats="global"` oracle path.
    `seg_widths` (static per-segment widths of a packed buffer) switches to
    per-leaf-segment statistics; `mask` excludes padded columns from the
    global statistics; `key` feeds stochastic compressors (ignored by
    deterministic ones)."""
    if name == "none":
        return lambda x: x
    if name not in COMPRESSORS:
        raise ValueError(f"unknown compressor {name!r}")
    if seg_widths is not None:
        return lambda x: _segment_compress(x, name, seg_widths, key=key)
    if name == "sign":
        return lambda x: sign_compress(x, mask=mask)
    if name == "int8":
        return lambda x: int8_compress(x, mask=mask)
    return lambda x: int8_stoch_compress(x, key=key, mask=mask)
