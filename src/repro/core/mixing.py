"""Doubly-stochastic mixing matrices / topologies for averaging consensus
(paper eq. 17 and Section V).

Two representations:

* **Dense matrices** (numpy) for the paper-scale experiments — including the
  6-regular random expanders used in Fig. 9 — consumed by `core.dsgd` via matmul
  over an explicit node axis.
* **Shift schedules** (circulant topologies) for the device-mesh gossip path —
  consumed by `core.averaging` as weighted `jnp.roll`s over the data axis, which
  XLA lowers to `collective-permute` chains on the TPU ICI torus.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

Schedule = Tuple[Tuple[int, float], ...]  # ((shift, weight), ...) includes shift 0


# ---------------------------------------------------------------------------
# Circulant schedules (device path)
# ---------------------------------------------------------------------------


def schedule(topology: str, n: int, self_weight: float = 0.0) -> Schedule:
    """Doubly-stochastic circulant mixing schedule over `n` nodes."""
    if n == 1:
        return ((0, 1.0),)
    if topology == "ring":
        shifts = [-1, 1] if n > 2 else [1]
    elif topology == "circulant2":  # degree-4 circulant expander
        shifts = [s for s in (-2, -1, 1, 2) if abs(s) < n]
    elif topology == "torus":  # 2D torus on a near-square factorization
        a = int(np.sqrt(n))
        while n % a:
            a -= 1
        b = n // a
        shifts = sorted({s % n for s in (-1, 1, -b, b) if (s % n) != 0})
        shifts = [s if s <= n // 2 else s - n for s in shifts]
    else:
        raise ValueError(f"unknown topology {topology!r}")
    deg = len(shifts)
    w_self = self_weight if self_weight > 0 else 1.0 / (deg + 1)
    w = (1.0 - w_self) / deg
    return tuple([(0, float(w_self))] + [(s, float(w)) for s in shifts])


def schedule_matrix(sched: Schedule, n: int) -> np.ndarray:
    """Dense matrix equivalent of a circulant schedule (for tests/analysis)."""
    A = np.zeros((n, n))
    for shift, w in sched:
        for i in range(n):
            # roll(x, shift)[i] = x[(i - shift) % n]
            A[i, (i - shift) % n] += w
    return A


# ---------------------------------------------------------------------------
# Dense matrices (paper experiments)
# ---------------------------------------------------------------------------


def ring_matrix(n: int, self_weight: float = 0.0) -> np.ndarray:
    return schedule_matrix(schedule("ring", n, self_weight), n)


def metropolis_weights(adj: np.ndarray) -> np.ndarray:
    """Metropolis-Hastings doubly-stochastic weights for an undirected graph."""
    n = adj.shape[0]
    deg = adj.sum(1)
    A = np.zeros((n, n))
    for i in range(n):
        for j in range(n):
            if i != j and adj[i, j]:
                A[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
        A[i, i] = 1.0 - A[i].sum()
    return A


def random_regular_expander(n: int, deg: int = 6, seed: int = 0,
                            max_tries: int = 50) -> np.ndarray:
    """Random `deg`-regular graph, Metropolis weights — the paper's Fig. 9
    topology family. Sampled by double-edge-swap randomization of a circulant
    `deg`-regular base graph (keeps the graph simple and regular by
    construction; connectivity is re-checked after mixing)."""
    if deg >= n:
        raise ValueError("degree must be < n")
    rng = np.random.default_rng(seed)
    for _ in range(max_tries):
        adj = _circulant_regular(n, deg)
        edges = [(u, v) for u in range(n) for v in range(u + 1, n) if adj[u, v]]
        for _ in range(20 * len(edges)):
            i, j = rng.integers(len(edges)), rng.integers(len(edges))
            (a, b), (c, d) = edges[i], edges[j]
            if len({a, b, c, d}) < 4:
                continue
            if adj[a, c] or adj[b, d]:
                continue
            adj[a, b] = adj[b, a] = adj[c, d] = adj[d, c] = False
            adj[a, c] = adj[c, a] = adj[b, d] = adj[d, b] = True
            edges[i], edges[j] = (min(a, c), max(a, c)), (min(b, d), max(b, d))
        if _connected(adj):
            return metropolis_weights(adj.astype(float))
    raise RuntimeError("failed to sample a connected regular graph")


def _circulant_regular(n: int, deg: int) -> np.ndarray:
    """Deterministic connected `deg`-regular circulant graph."""
    adj = np.zeros((n, n), dtype=bool)
    offsets = list(range(1, deg // 2 + 1))
    for i in range(n):
        for o in offsets:
            adj[i, (i + o) % n] = adj[(i + o) % n, i] = True
        if deg % 2:  # odd degree needs the antipodal matching (n must be even)
            if n % 2:
                raise ValueError("odd-degree regular graph needs even n")
            adj[i, (i + n // 2) % n] = adj[(i + n // 2) % n, i] = True
    return adj


def _connected(adj: np.ndarray) -> bool:
    n = adj.shape[0]
    seen = {0}
    frontier = [0]
    while frontier:
        u = frontier.pop()
        for v in np.nonzero(adj[u])[0]:
            if v not in seen:
                seen.add(int(v))
                frontier.append(int(v))
    return len(seen) == n


def lambda2(A: np.ndarray) -> float:
    """Second-largest eigenvalue magnitude — the consensus contraction rate."""
    ev = np.sort(np.abs(np.linalg.eigvals(A)))[::-1]
    return float(ev[1]) if len(ev) > 1 else 0.0


def is_doubly_stochastic(A: np.ndarray, tol: float = 1e-8) -> bool:
    return (
        bool(np.all(A >= -tol))
        and np.allclose(A.sum(0), 1.0, atol=1e-6)
        and np.allclose(A.sum(1), 1.0, atol=1e-6)
    )
