"""Doubly-stochastic mixing matrices / topologies for averaging consensus
(paper eq. 17 and Section V), plus the fused consensus engine (`MixOp`).

Two representations:

* **Dense matrices** (numpy) for the paper-scale experiments — including the
  6-regular random expanders used in Fig. 9 — consumed by `core.dsgd` via matmul
  over an explicit node axis.
* **Shift schedules** (circulant topologies) for the device-mesh gossip path —
  consumed by `core.averaging` as weighted `jnp.roll`s over the data axis, which
  XLA lowers to `collective-permute` chains on the TPU ICI torus.

`MixOp` makes R rounds of eq. 17 cost ~1 round: because the R-round operator is
linear when no message compression is applied, it can be precomputed ONCE
outside the training scan — `A_R = A^R` for dense matrices, the R-fold
convolution of the shift schedule for circulants — and applied as a single
matmul / weighted-shift pass per step.

Quantized configs are nonlinear per-round, so the operator is never collapsed;
what IS tunable is the compressor's statistic granularity (`stats`):

* "global"  — whole-array scales, the exact per-round loop shipped since PR 1
              (bit-identical oracle semantics).
* "segment" — per-leaf-segment scales on a packed flat buffer
              (`core.packing`): the per-leaf path's statistics, paid once per
              buffer instead of once per leaf.
* "tile"    — per-[n, block_d]-tile scales, fused in-register by the Pallas
              kernel (`kernels.consensus.gossip_mix_quant_pallas`): quantized
              gossip drops from (deg+1)*R HBM passes to one read+write per
              buffer. Accuracy study: `benchmarks/bench_consensus.py`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import COMPRESSORS, STOCHASTIC, make_compressor

Schedule = Tuple[Tuple[int, float], ...]  # ((shift, weight), ...) includes shift 0


# ---------------------------------------------------------------------------
# Circulant schedules (device path)
# ---------------------------------------------------------------------------


def schedule(topology: str, n: int, self_weight: float = 0.0) -> Schedule:
    """Doubly-stochastic circulant mixing schedule over `n` nodes."""
    if n == 1:
        return ((0, 1.0),)
    if topology == "ring":
        shifts = [-1, 1] if n > 2 else [1]
    elif topology == "circulant2":  # degree-4 circulant expander
        shifts = [s for s in (-2, -1, 1, 2) if abs(s) < n]
    elif topology == "torus":  # 2D torus on a near-square factorization
        a = int(np.sqrt(n))
        while n % a:
            a -= 1
        b = n // a
        shifts = sorted({s % n for s in (-1, 1, -b, b) if (s % n) != 0})
        shifts = [s if s <= n // 2 else s - n for s in shifts]
    else:
        raise ValueError(f"unknown topology {topology!r}")
    deg = len(shifts)
    w_self = self_weight if self_weight > 0 else 1.0 / (deg + 1)
    w = (1.0 - w_self) / deg
    return tuple([(0, float(w_self))] + [(s, float(w)) for s in shifts])


def schedule_matrix(sched: Schedule, n: int) -> np.ndarray:
    """Dense matrix equivalent of a circulant schedule (for tests/analysis)."""
    A = np.zeros((n, n))
    for shift, w in sched:
        for i in range(n):
            # roll(x, shift)[i] = x[(i - shift) % n]
            A[i, (i - shift) % n] += w
    return A


# ---------------------------------------------------------------------------
# Elastic membership (docs/DESIGN.md §Elastic membership)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Membership:
    """Which of the `n` node slots participate in mixing this superstep.

    The node axis keeps its full extent `n` end-to-end (state arrays never
    change shape); a dropped slot simply stops sending and receiving — its
    mixing row degrades to self-weight 1 — while the active cohort mixes
    over a recomposed operator that is doubly stochastic over the cohort.
    Hashable so it can key compiled-superstep registries; equality is by
    value, so rejoining to full membership compares equal to (and reuses
    operators bit-identical to) the never-left mask.
    """

    n: int
    active: Tuple[bool, ...]

    def __post_init__(self):
        if self.n < 1 or len(self.active) != self.n:
            raise ValueError(f"bad membership: n={self.n} "
                             f"mask length {len(self.active)}")
        if not any(self.active):
            raise ValueError("membership needs at least one active node")

    @classmethod
    def full(cls, n: int) -> "Membership":
        return cls(n, (True,) * n)

    def drop(self, *ids: int) -> "Membership":
        mask = list(self.active)
        for i in ids:
            mask[i] = False
        return Membership(self.n, tuple(mask))

    def rejoin(self, *ids: int) -> "Membership":
        mask = list(self.active)
        for i in ids:
            mask[i] = True
        return Membership(self.n, tuple(mask))

    @property
    def n_active(self) -> int:
        return sum(self.active)

    @property
    def active_ids(self) -> Tuple[int, ...]:
        return tuple(i for i, a in enumerate(self.active) if a)

    @property
    def is_full(self) -> bool:
        return all(self.active)

    def to_json(self) -> dict:
        """JSON form for checkpoint manifests (train.snapshot)."""
        return {"n": self.n, "active": [bool(a) for a in self.active]}

    @classmethod
    def from_json(cls, state: dict) -> "Membership":
        return cls(int(state["n"]), tuple(bool(a) for a in state["active"]))


def masked_schedule(topology: str, membership: Membership,
                    self_weight: float = 0.0) -> Schedule:
    """Circulant schedule over the *relabeled* active cohort.

    The device gossip path compacts the active rows into a dense [m, ...]
    block (gather by `membership.active_ids`), so the cohort is itself a
    circulant ring/expander of size m = n_active and the ordinary schedule
    construction applies verbatim. Full membership returns exactly
    `schedule(topology, n)` — a node that leaves and rejoins gets back the
    bit-identical operator it had before leaving."""
    return schedule(topology, membership.n_active, self_weight)


def masked_matrix(A: np.ndarray, membership: Membership) -> np.ndarray:
    """Degrade a dense one-round mixing matrix to a membership mask.

    Returns a full [n, n] doubly-stochastic matrix: dropped rows/columns are
    identity (self-weight 1 — the node holds its state, sends and receives
    nothing), and the active block is re-derived by Metropolis reweighting of
    the subgraph that `A`'s off-diagonal support induces on the active cohort
    — so the block is doubly stochastic over the cohort rather than leaking
    the dropped nodes' weight mass. Full membership returns `A` unchanged
    (bit-identical rejoin).

    An adversarial drop set can *disconnect* the induced subgraph (e.g.
    dropping every other node of a ring leaves the survivors with no edges),
    in which case Metropolis reweighting degenerates to a non-contracting
    operator (lambda_2 = 1: consensus never converges). That is detected
    (induced block disconnected / lambda_2 ~ 1) and the active cohort falls
    back to **relabeling**: the survivors form their own circulant ring
    (`masked_schedule`'s device-path semantics densified), which is always
    connected and doubly stochastic — graceful degradation instead of a
    silent stall."""
    n = A.shape[0]
    if membership.n != n:
        raise ValueError(f"membership n={membership.n} vs matrix n={n}")
    if membership.is_full:
        return A
    ids = list(membership.active_ids)
    out = np.eye(n, dtype=A.dtype)
    if len(ids) == 1:
        return out
    sub_adj = (np.abs(A[np.ix_(ids, ids)]) > 0).astype(float)
    np.fill_diagonal(sub_adj, 0.0)
    if not _connected(sub_adj > 0):
        # induced subgraph disconnected: relabel the cohort onto its own
        # ring — same fallback the engine's device gossip path uses
        block = ring_matrix(len(ids)).astype(A.dtype)
    else:
        block = metropolis_weights(sub_adj)
        if len(ids) > 1 and lambda2(block) >= 1.0 - 1e-9:
            block = ring_matrix(len(ids)).astype(A.dtype)
    out[np.ix_(ids, ids)] = block
    return out


# ---------------------------------------------------------------------------
# Dense matrices (paper experiments)
# ---------------------------------------------------------------------------


def ring_matrix(n: int, self_weight: float = 0.0) -> np.ndarray:
    return schedule_matrix(schedule("ring", n, self_weight), n)


def metropolis_weights(adj: np.ndarray) -> np.ndarray:
    """Metropolis-Hastings doubly-stochastic weights for an undirected graph."""
    n = adj.shape[0]
    deg = adj.sum(1)
    A = np.zeros((n, n))
    for i in range(n):
        for j in range(n):
            if i != j and adj[i, j]:
                A[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
        A[i, i] = 1.0 - A[i].sum()
    return A


def random_regular_expander(n: int, deg: int = 6, seed: int = 0,
                            max_tries: int = 50) -> np.ndarray:
    """Random `deg`-regular graph, Metropolis weights — the paper's Fig. 9
    topology family. Sampled by double-edge-swap randomization of a circulant
    `deg`-regular base graph (keeps the graph simple and regular by
    construction; connectivity is re-checked after mixing)."""
    if deg >= n:
        raise ValueError("degree must be < n")
    rng = np.random.default_rng(seed)
    for _ in range(max_tries):
        adj = _circulant_regular(n, deg)
        edges = [(u, v) for u in range(n) for v in range(u + 1, n) if adj[u, v]]
        for _ in range(20 * len(edges)):
            i, j = rng.integers(len(edges)), rng.integers(len(edges))
            (a, b), (c, d) = edges[i], edges[j]
            if len({a, b, c, d}) < 4:
                continue
            if adj[a, c] or adj[b, d]:
                continue
            adj[a, b] = adj[b, a] = adj[c, d] = adj[d, c] = False
            adj[a, c] = adj[c, a] = adj[b, d] = adj[d, b] = True
            edges[i], edges[j] = (min(a, c), max(a, c)), (min(b, d), max(b, d))
        if _connected(adj):
            return metropolis_weights(adj.astype(float))
    raise RuntimeError("failed to sample a connected regular graph")


def _circulant_regular(n: int, deg: int) -> np.ndarray:
    """Deterministic connected `deg`-regular circulant graph."""
    adj = np.zeros((n, n), dtype=bool)
    offsets = list(range(1, deg // 2 + 1))
    for i in range(n):
        for o in offsets:
            adj[i, (i + o) % n] = adj[(i + o) % n, i] = True
        if deg % 2:  # odd degree needs the antipodal matching (n must be even)
            if n % 2:
                raise ValueError("odd-degree regular graph needs even n")
            adj[i, (i + n // 2) % n] = adj[(i + n // 2) % n, i] = True
    return adj


def random_geometric(n: int, seed: int = 0, radius: Optional[float] = None,
                     max_tries: int = 50) -> np.ndarray:
    """Random geometric graph on the unit square, Metropolis weights — the
    'spatially clustered' topology family of the scenario harness
    (core/scenarios.py). Nodes are uniform points; edges connect pairs within
    `radius` (default: the standard connectivity threshold
    sqrt(2 ln n / n)). If the sample is disconnected the radius is grown and
    the points resampled — deterministic for a fixed seed."""
    if n == 1:
        return np.ones((1, 1))
    rng = np.random.default_rng(seed)
    r = radius if radius is not None else float(
        np.sqrt(2.0 * np.log(max(n, 2)) / n))
    for _ in range(max_tries):
        pts = rng.random((n, 2))
        d = np.linalg.norm(pts[:, None] - pts[None, :], axis=-1)
        adj = d <= r
        np.fill_diagonal(adj, False)
        if _connected(adj):
            return metropolis_weights(adj.astype(float))
        r *= 1.25
    raise RuntimeError("failed to sample a connected geometric graph")


def _connected(adj: np.ndarray) -> bool:
    n = adj.shape[0]
    seen = {0}
    frontier = [0]
    while frontier:
        u = frontier.pop()
        for v in np.nonzero(adj[u])[0]:
            if v not in seen:
                seen.add(int(v))
                frontier.append(int(v))
    return len(seen) == n


def lambda2(A: np.ndarray) -> float:
    """Second-largest eigenvalue magnitude — the consensus contraction rate."""
    ev = np.sort(np.abs(np.linalg.eigvals(A)))[::-1]
    return float(ev[1]) if len(ev) > 1 else 0.0


def is_doubly_stochastic(A: np.ndarray, tol: float = 1e-8) -> bool:
    return (
        bool(np.all(A >= -tol))
        and np.allclose(A.sum(0), 1.0, atol=1e-6)
        and np.allclose(A.sum(1), 1.0, atol=1e-6)
    )


# ---------------------------------------------------------------------------
# Fused consensus engine (MixOp)
# ---------------------------------------------------------------------------


def roll_mix(x: jax.Array, sched: Schedule,
             compress: Callable[[jax.Array], jax.Array]) -> jax.Array:
    """One consensus round over axis 0 of x via weighted circular shifts.
    `compress` models the wire format: applied to every non-self message."""
    out = None
    for shift, w in sched:
        msg = x if shift == 0 else compress(jnp.roll(x, shift, axis=0))
        term = w * msg
        out = term if out is None else out + term
    return out


def _identity(x: jax.Array) -> jax.Array:
    return x


def compose_schedule(sched: Schedule, rounds: int, n: int) -> Schedule:
    """The effective one-pass schedule of `rounds` consensus rounds: the R-fold
    circular convolution of the shift schedule (shifts add mod n, weights
    multiply). Exactly the circulant form of `schedule_matrix(sched, n)**R`.

    The result has at most n terms, so even for large R a single pass costs no
    more than one full circulant application."""
    cur = {0: 1.0}
    for _ in range(rounds):
        nxt: dict = {}
        for s1, w1 in cur.items():
            for s2, w2 in sched:
                k = (s1 + s2) % n
                nxt[k] = nxt.get(k, 0.0) + w1 * w2
        cur = nxt
    # canonical form: shifts in (-n/2, n/2], self term first, then ascending
    out = []
    for s, w in cur.items():
        s = s if s <= n // 2 else s - n
        out.append((int(s), float(w)))
    out.sort(key=lambda sw: (sw[0] != 0, sw[0]))
    return tuple(out)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DenseMixOp:
    """Precomputed R-round dense consensus operator (paper eq. 17).

    When `A_eff` is set (the default, quantization-free path) the R sequential
    `A @ h` matmuls collapse to the single matmul `A_eff @ h` with
    `A_eff = A^R` — computed once at construction, outside any training scan.
    With `A_eff=None` the per-round scan is preserved (oracle / fallback).
    """

    A: Any  # [N, N] one-round doubly-stochastic matrix
    A_eff: Any  # [N, N] effective R-round operator A^R, or None (per-round)
    rounds: int

    def __call__(self, h: jax.Array) -> jax.Array:
        if self.rounds == 0:
            return h
        if self.A_eff is not None:
            return self.A_eff @ h
        def body(h, _):
            return self.A @ h, None
        h, _ = jax.lax.scan(body, h, None, length=self.rounds)
        return h

    def tree_flatten(self):
        return (self.A, self.A_eff), (self.rounds,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0])


def dense_mix_op(A, rounds: int, *, fuse: bool = True) -> DenseMixOp:
    """Build the dense-path MixOp; `fuse=False` keeps the per-round scan."""
    A = jnp.asarray(A)
    A_eff = None
    if fuse and rounds > 0:
        A_eff = jnp.linalg.matrix_power(A, rounds) if rounds > 1 else A
    return DenseMixOp(A, A_eff, rounds)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CirculantMixOp:
    """Precomputed R-round circulant consensus operator (device gossip path).

    Quantization off: `fused_sched` (the R-fold convolution of the one-round
    schedule) is applied in ONE weighted-shift pass, replacing the
    (deg+1)*R-roll per-step loop. `impl` selects the execution strategy:

    * "roll"   — one `jnp.roll` pass over `fused_sched` (sharding-safe: GSPMD
                 lowers the rolls to collective-permutes, but the wraparound
                 concat defeats fusion — every term pays a full local pass).
    * "matmul" — apply the dense circulant `A_eff` [n, n] as one matmul over
                 the flattened node axis (fastest single-host XLA path, but
                 gathers a sharded node axis — unsharded layouts only).
    * "kernel" — Pallas TPU kernel: the node block is tiled into VMEM once and
                 all R rounds run in-register (one HBM read+write per leaf).
                 Single-device arrays only (no GSPMD partitioning rule).
    * "shard"  — explicit shard_map partitioning rule over a sharded node
                 axis (`kernels.consensus.gossip_mix_shard`): per round, halo
                 ppermutes exchange only the rows the schedule reaches and
                 the local tile mix is a fused slice-sum (no wraparound).
                 PER-ROUND semantics — bit-identical to the fuse=False
                 oracle, unlike the composed single-pass impls. Requires the
                 `mesh` field; layouts the rule does not cover fall back to
                 "roll" at call time.
    * "auto"   — resolved at build time by `circulant_mix_op` via
                 `resolve_auto_impl(mesh)`: the fast path ("matmul" on
                 CPU/GPU, "kernel" on TPU) when the node axis is provably
                 unsharded, "shard" when the mesh reports it sharded and the
                 partitioning rule covers the layout, "roll" otherwise. An op
                 constructed with a literal impl="auto" (bypassing the
                 factory) falls back to "roll" at call time — always safe.

    Quantization on: the compressor is nonlinear, so the operator is never
    collapsed. `stats` picks the statistic granularity: "global" keeps the
    exact per-round `roll_mix` loop bit-identically (the oracle); "segment"
    runs the per-round loop on a packed buffer with per-leaf-segment scales
    (pass the static `seg_widths` at call time); "tile" executes the fused
    quantized path — the Pallas kernel on TPU (one HBM read+write per buffer,
    all R rounds and the per-tile scales in-register), the single-dispatch XLA
    tile chain elsewhere; "node" computes sender-local per-row-tile scales —
    the statistic a real sender derives from its own message alone, and the
    only granularity whose wire values are invariant under a node-axis device
    split, so it is the granularity the sharded quantized rule
    (impl="shard") executes bit-identically.
    """

    sched: Schedule  # one-round schedule (per-round / kernel / shard path)
    fused_sched: Optional[Schedule]  # R-round schedule; None = per-round loop
    #   (quantized configs, or fuse=False in `circulant_mix_op`)
    A_eff: Any  # [n, n] dense form of fused_sched (matmul impl), or None
    n: int
    rounds: int
    quantization: str = "none"
    impl: str = "auto"
    stats: str = "global"  # quantizer statistics: global | segment | tile | node
    block_d: int = 512  # tile width for stats="tile" / "node"
    seed: int = 0  # threefry base for stochastic compressors
    mesh: Any = None  # jax Mesh for impl="shard" (static aux; hashable)

    def __call__(self, x: jax.Array, *, seg_widths: Optional[Tuple[int, ...]] = None,
                 valid_d: Optional[int] = None, key: Any = None) -> jax.Array:
        assert x.shape[0] == self.n, (
            f"MixOp built for n={self.n} applied to node axis {x.shape[0]}")
        if self.rounds == 0 or self.n == 1:
            return x
        if self.quantization != "none":
            return self._quantized(x, seg_widths, valid_d, key)
        impl = "roll" if self.impl == "auto" else self.impl
        if impl == "shard":
            shard = self._shard_info()
            if shard is not None:
                from repro.kernels.ops import sharded_gossip_mix
                return sharded_gossip_mix(x, self.sched, self.rounds,
                                          self.mesh, *shard)
            impl = "roll"  # layout not covered: sharding-safe fallback
        if self.fused_sched is None:  # fuse=False: per-round oracle loop
            for _ in range(self.rounds):
                x = roll_mix(x, self.sched, _identity)
            return x
        if impl == "kernel":
            # an explicit "kernel" choice means the Pallas kernel — interpret
            # mode off-TPU, per the documented fallback
            from repro.kernels.ops import gossip_mix
            return gossip_mix(x, self.sched, self.rounds, force_pallas=True)
        if impl == "matmul":
            flat = x.reshape(self.n, -1)
            out = jnp.asarray(self.A_eff, x.dtype) @ flat
            return out.reshape(x.shape)
        if impl != "roll":
            raise ValueError(f"unknown MixOp impl {self.impl!r}")
        return roll_mix(x, self.fused_sched, _identity)

    def _shard_info(self):
        """(node_axes, ring_axis) when the shard partitioning rule covers this
        (mesh, n, schedule) — None forces the call-time roll fallback."""
        from repro.kernels.ops import node_shard_info
        return node_shard_info(self.mesh, self.n, self.sched)

    def _quantized(self, x, seg_widths, valid_d, key=None):
        """Per-round nonlinear consensus. `valid_d` marks trailing flattened
        columns as padding (masked out of compressor statistics — they must be
        zero on input); stochastic compressors fold the round index into the
        threefry key (messages within a round share it). `key` overrides the
        static-seed base key — callers inside a `lax.scan` over steps pass a
        per-step key (e.g. fold the step counter into their own base) so the
        per-round noise is fresh every step; `key=None` keeps the
        seed-derived key bit-identically (same noise sequence each step)."""
        key0 = None
        if self.quantization in STOCHASTIC:
            key0 = jax.random.PRNGKey(self.seed) if key is None else key
        if self.stats == "node":
            # sender-local row-tile scales: shard-invariant wire values, so
            # the sharded rule and the XLA chain are bit-identical (sign/int8)
            impl = "roll" if self.impl == "auto" else self.impl
            shard = self._shard_info() if impl == "shard" else None
            if shard is not None:
                from repro.kernels.ops import sharded_quant_gossip_mix
                return sharded_quant_gossip_mix(
                    x, self.sched, self.rounds, self.quantization, self.mesh,
                    *shard, block_d=self.block_d, valid_d=valid_d, key=key0)
            from repro.kernels.ops import quant_gossip_mix
            return quant_gossip_mix(x, self.sched, self.rounds,
                                    self.quantization, block_d=self.block_d,
                                    valid_d=valid_d, key=key0, per_node=True)
        if self.stats == "tile":
            from repro.kernels.ops import quant_gossip_mix
            return quant_gossip_mix(x, self.sched, self.rounds,
                                    self.quantization, block_d=self.block_d,
                                    valid_d=valid_d, key=key0)
        if self.stats == "segment" and seg_widths is not None:
            # compress-once-broadcast: segment scales are invariant under the
            # node-axis roll (it permutes rows, the stats reduce over them),
            # so each round quantizes the buffer ONCE and rolls the compressed
            # copy — (1 compress + deg rolls) per round instead of deg
            # compress chains
            for r in range(self.rounds):
                key = jax.random.fold_in(key0, r) if key0 is not None else None
                q = make_compressor(self.quantization, key=key,
                                    seg_widths=seg_widths)(x)
                out = None
                for shift, w in self.sched:
                    term = w * (x if shift == 0 else jnp.roll(q, shift, axis=0))
                    out = term if out is None else out + term
                x = out
            return x
        mask = None
        trailing = int(np.prod(x.shape[1:])) if x.ndim > 1 else 1
        if valid_d is not None and valid_d < trailing:
            mask = (jnp.arange(trailing) < valid_d).reshape(x.shape[1:])
        for r in range(self.rounds):
            key = jax.random.fold_in(key0, r) if key0 is not None else None
            compress = make_compressor(self.quantization, key=key, mask=mask)
            x = roll_mix(x, self.sched, compress)
        return x

    def tree_flatten(self):
        return (self.A_eff,), (self.sched, self.fused_sched, self.n,
                               self.rounds, self.quantization, self.impl,
                               self.stats, self.block_d, self.seed, self.mesh)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(aux[0], aux[1], children[0], *aux[2:])


def resolve_auto_impl(mesh: Any = None) -> str:
    """Pick the fastest *safe* execution strategy for `impl="auto"`.

    The node axis is sharded over the mesh's data axes in the trainer layout,
    so any nontrivial data extent picks "shard" — the explicit shard_map
    partitioning rule (per-round halo ppermutes + fused slice-sum tile
    mixing, `kernels.consensus`); `circulant_mix_op` downgrades it to the
    "roll" fallback when the rule does not cover the (n, schedule, split).
    On an unsharded node axis the dense circulant matmul is the 3-10x fast
    path on CPU/GPU; on TPU the fused Pallas kernel is, but only for
    genuinely single-device arrays (it has no partitioning rule at all).
    With no mesh information and multiple local devices the layout is
    unknowable at build time, so "auto" stays conservative."""
    if mesh is not None:
        node_extent = 1
        for a in mesh.axis_names:
            if a in ("pod", "data"):
                node_extent *= mesh.shape[a]
        if node_extent > 1:
            return "shard"  # node axis sharded: explicit partitioning rule
        single_device = mesh.devices.size == 1
    else:
        single_device = jax.device_count() == 1
        if not single_device:
            return "roll"  # unknown multi-device layout: stay sharding-safe
    if not single_device:
        # node axis local but other dims sharded (e.g. model-parallel mesh):
        # the matmul impl flattens trailing dims and would gather them
        return "roll"
    return "kernel" if jax.default_backend() == "tpu" else "matmul"


def circulant_mix_op(sched: Schedule, n: int, rounds: int, *,
                     quantization: str = "none",
                     impl: str = "auto", fuse: bool = True,
                     mesh: Any = None, stats: str = "global",
                     block_d: int = 512, seed: int = 0) -> CirculantMixOp:
    """Build the circulant-path MixOp from a one-round schedule.

    The R-round operator is precomputed here, once, so constructing the op
    outside `jax.lax.scan` / `jit` keeps the per-step cost at ~one round.
    `fuse=False` keeps the per-round loop (oracle / baseline), as does any
    quantized config (nonlinear compressor — collapsing would change it);
    quantized configs instead pick their statistic granularity via `stats`
    ("global" oracle loop / "segment" packed loop / "tile" fused kernel,
    tile width `block_d`).

    `impl="auto"` resolves at build time via `resolve_auto_impl(mesh)`:
    "matmul" (CPU/GPU) or the Pallas "kernel" (TPU) on unsharded
    single-device layouts, the explicit "shard" partitioning rule when the
    mesh reports the node axis sharded (downgraded here to "roll" when the
    rule does not cover the (n, schedule, split)), "roll" whenever the
    layout is unknowable. The "shard" impl keeps PER-ROUND semantics
    (bit-identical to fuse=False), so it carries no fused schedule and any
    call-time fallback stays on the per-round oracle loop."""
    if impl not in ("auto", "roll", "matmul", "kernel", "shard"):
        raise ValueError(f"unknown MixOp impl {impl!r}")
    if stats not in ("global", "segment", "tile", "node"):
        raise ValueError(f"unknown quantizer stats mode {stats!r}")
    if quantization not in COMPRESSORS:
        raise ValueError(f"unknown quantization {quantization!r}")
    if impl == "auto":
        impl = resolve_auto_impl(mesh)
    if impl == "shard":
        from repro.kernels.ops import node_shard_info
        if node_shard_info(mesh, n, sched) is None:
            impl, mesh = "roll", None  # rule doesn't cover this layout
    if impl != "shard":
        mesh = None  # mesh only rides the op for the shard rule (static aux)
    if quantization != "none" or not fuse or impl == "shard":
        return CirculantMixOp(sched, None, None, n, rounds, quantization, impl,
                              stats, block_d, seed, mesh)
    fused = compose_schedule(sched, rounds, n) if rounds > 0 else ((0, 1.0),)
    # the dense [n, n] operator is only needed by the matmul impl; the others
    # skip the O(n^2) build and the device pin. Kept as host numpy — it
    # crosses to device as a jit constant on first use.
    A_eff = (np.asarray(schedule_matrix(fused, n), np.float32)
             if impl == "matmul" else None)
    return CirculantMixOp(sched, fused, A_eff, n, rounds, quantization, impl,
                          stats, block_d, seed, mesh)


# ---------------------------------------------------------------------------
# Time-varying operators (ScheduledMixOp — scenario harness, eq. 17's
# B-connected graph sequences; docs/DESIGN.md §Scenario harness)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ScheduledMixOp:
    """Time-varying R-round consensus operator: a stack of precomputed
    per-phase effective operators plus a round→phase lookup table, with the
    active phase selected as **runtime data** — switching topology (or
    realizing a per-round lossy-link draw) never retraces the superstep.

    `A_stack` [P, n, n] holds each phase's R-round effective operator,
    constructed for bit-parity with the static paths: circulant phases use the
    same `schedule_matrix(compose_schedule(...))` float32 constants the
    `CirculantMixOp` matmul impl applies, and dense phases the same
    `matrix_power` product `dense_mix_op` builds — so a constant schedule is
    bit-identical to the static op it degenerates to. `phase_by_round`
    [period] int32 maps the round counter t (mod period) to a phase; both are
    pytree *children*, so the phase gather and the matmul trace once and
    re-execute for every subsequent round/realization.

    Linear operator only (no compressor state): `quantization` is always
    "none", and `key`/`seg_widths`/`valid_d` are accepted and ignored so the
    op is call-compatible with `CirculantMixOp` in `core.averaging` and
    `core.krasulina`. Callers pass the traced round counter `t` (the
    Krasulina carry's round index, or the optimizer step on the LM path) to
    advance the schedule; `t=None` pins phase 0 (the static-parity mode)."""

    A_stack: Any  # [P, n, n] per-phase effective R-round operators (child)
    phase_by_round: Any  # [period] int32 round->phase lookup (child)
    n: int
    rounds: int
    period: int
    quantization: str = "none"
    stats: str = "global"

    def __call__(self, x: jax.Array, *, t: Any = None, phase: Any = None,
                 seg_widths: Optional[Tuple[int, ...]] = None,
                 valid_d: Optional[int] = None, key: Any = None) -> jax.Array:
        del seg_widths, valid_d, key  # linear: no compressor statistics
        assert x.shape[0] == self.n, (
            f"MixOp built for n={self.n} applied to node axis {x.shape[0]}")
        if self.rounds == 0 or self.n == 1:
            return x
        if phase is None:
            if t is None:
                phase = 0
            else:
                phase = self.phase_by_round[
                    jnp.asarray(t, jnp.int32) % self.period]
        A = self.A_stack[phase]
        flat = x.reshape(self.n, -1)
        out = jnp.asarray(A, x.dtype) @ flat
        return out.reshape(x.shape)

    @property
    def n_phases(self) -> int:
        return int(self.A_stack.shape[0])

    def phase_at(self, t: int) -> int:
        """Host-side phase lookup (tests / observability)."""
        return int(np.asarray(self.phase_by_round)[int(t) % self.period])

    def tree_flatten(self):
        return (self.A_stack, self.phase_by_round), (
            self.n, self.rounds, self.period, self.quantization, self.stats)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)


def scheduled_mix_op(phases, n: int, rounds: int,
                     phase_by_round=None) -> ScheduledMixOp:
    """Build a time-varying MixOp from per-phase one-round operators.

    Each entry of `phases` is either a circulant `Schedule` (tuple of
    (shift, weight)) or a dense [n, n] doubly-stochastic matrix; its R-round
    effective operator is precomputed here, once, exactly the way the static
    factories do (`compose_schedule`+`schedule_matrix` for circulants,
    `matrix_power` for dense) so a constant schedule stays bit-identical to
    `CirculantMixOp`/`DenseMixOp`. `phase_by_round` maps round t -> phase
    index, cyclic with its length (default: round-robin over the phases)."""
    if not phases:
        raise ValueError("need at least one phase")
    mats = []
    for p in phases:
        if isinstance(p, tuple):  # circulant schedule
            eff = compose_schedule(p, rounds, n) if rounds > 0 else ((0, 1.0),)
            mats.append(jnp.asarray(
                np.asarray(schedule_matrix(eff, n), np.float32)))
        else:
            A = jnp.asarray(p, jnp.float32)
            if A.shape != (n, n):
                raise ValueError(f"phase matrix shape {A.shape} != ({n}, {n})")
            mats.append(jnp.linalg.matrix_power(A, rounds)
                        if rounds > 1 else A)
    if phase_by_round is None:
        phase_by_round = tuple(range(len(mats)))
    lut = np.asarray(phase_by_round, np.int32)
    if lut.ndim != 1 or lut.size == 0:
        raise ValueError("phase_by_round must be a non-empty 1D sequence")
    if lut.min() < 0 or lut.max() >= len(mats):
        raise ValueError(f"phase ids must be in [0, {len(mats)}); got "
                         f"[{lut.min()}, {lut.max()}]")
    return ScheduledMixOp(jnp.stack(mats), jnp.asarray(lut), n, rounds,
                          int(lut.size))
