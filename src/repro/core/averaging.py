"""Gradient-averaging operators for the framework-scale trainer — the paper's
technique as a first-class feature.

The trainer represents the paper's N compute nodes as a leading *node axis* on
the gradient pytree (sharded over the mesh's data axes), so averaging modes are
pure array programs whose collectives are visible in the lowered HLO:

* exact        -- mean over the node axis == AllReduce (DMB, Section IV)
* gossip       -- R rounds of circulant consensus (Section V, eq. 17), executed
                  through `core.mixing.CirculantMixOp`: with quantization off
                  the R-round operator is precomputed once and applied in a
                  single pass (weighted `jnp.roll`s / one circulant matmul /
                  the fused Pallas kernel on TPU)
* hierarchical -- exact within pod, gossip across pods in reduce-scatter form
                  (each intra-pod lane gossips one chunk of the pod mean over
                  DCN, then the pod all-gathers; TPU adaptation)

With `AveragingConfig.packed` (the default) the gossip and hierarchical modes
flatten the gradient pytree into one contiguous [N, D] buffer per dtype
(`core.packing`) so the mixing operator — and the consensus-error diagnostic —
runs ONCE per step instead of once per leaf; a transformer tree with hundreds
of leaves stops paying hundreds of independent roll/compress chains.

Optional message quantization (Section VI) compresses each round's messages;
quantized configs keep the per-round loop (the compressor is nonlinear, so the
operator must not be collapsed). `AveragingConfig.quant_stats` picks the
statistic granularity: "global" pins today's exact per-leaf oracle semantics
(bit-identical, never packed), "segment" reproduces per-leaf scales on the
packed buffer in one pass, "tile" takes the fused quantized kernel.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AveragingConfig
from repro.core import packing
from repro.core.mixing import (CirculantMixOp, ScheduledMixOp,
                               circulant_mix_op, schedule)
from repro.core.quantize import tile_compress

Tree = Any
# the consensus engine: a static CirculantMixOp or a time-varying
# ScheduledMixOp (scenario harness) — both are called uniformly through
# `_mix_call`, which routes the traced round counter `t` to scheduled ops
MixOp = Any


def make_gossip_mix(cfg: AveragingConfig, n_nodes: int, *,
                    impl: str = "auto", mesh: Any = None) -> CirculantMixOp:
    """Build the consensus engine for a config — once, outside the train step.
    For `mode="hierarchical"` pass the pod count as `n_nodes`.

    `impl="auto"` resolves per layout (`core.mixing.resolve_auto_impl`):
    "roll" whenever the node axis is — or may be — sharded over mesh data
    axes (rolls are the form GSPMD partitions into collective-permute
    chains), the dense-matmul fast path on unsharded CPU/GPU layouts, and
    the fused Pallas kernel on single-device TPU. Pass the mesh the op will
    run under so sharded layouts are detected; without it, multi-device
    hosts conservatively get "roll"."""
    sched = schedule(cfg.topology, n_nodes, cfg.self_weight)
    quantization = cfg.quantization
    if cfg.error_feedback != "off":
        # error feedback compresses ONCE per step outside the operator
        # (`ef_average_and_error`); the consensus rounds themselves are exact
        # and linear, so the composed/fused/shard implementations all apply
        # to compressed gossip — the per-round nonlinear chain is bypassed
        quantization = "none"
    return circulant_mix_op(sched, n_nodes, cfg.rounds,
                            quantization=quantization, impl=impl,
                            mesh=mesh, stats=cfg.quant_stats,
                            block_d=cfg.quant_block_d)


def resolve_packed(cfg: AveragingConfig, mesh: Any = None) -> bool:
    """Resolve the tri-state `AveragingConfig.packed` against the layout the
    step runs under. "auto" (the default) packs everywhere EXCEPT layouts
    whose param leaves are actually sharded over a model axis: the pack
    relayouts every leaf into one [N, D] buffer, which is numerically
    parity-tested under a model split (tests/test_shard_gossip.py) but whose
    all-gather cost on a real mesh is un-profiled (ROADMAP real-TPU debt) —
    model-parallel layouts opt in explicitly with `packed=True`."""
    if cfg.packed == "auto":
        if mesh is None:
            return True
        return int(mesh.shape.get("model", 1)) == 1
    return bool(cfg.packed)


def _packable(mix: MixOp) -> bool:
    """Quantized global-stats configs pin per-leaf statistics (the bit-identity
    oracle), so they keep the per-leaf dispatch; everything else packs."""
    return not (mix.quantization != "none" and mix.stats == "global")


def _mix_call(mix: MixOp, x: jax.Array, *, key: Any = None, t: Any = None,
              **kw) -> jax.Array:
    """Uniform call: scheduled (time-varying) ops take the traced round
    counter `t` to pick the active phase; static ops take the compressor key."""
    if isinstance(mix, ScheduledMixOp):
        return mix(x, t=t, **kw)
    return mix(x, key=key, **kw)


def _apply_mix(mix: MixOp, spec: packing.PackSpec, g: int,
               buf: jax.Array, key: Any = None, t: Any = None) -> jax.Array:
    if mix.quantization != "none" and mix.stats == "segment":
        widths = tuple(spec.leaf_width(i) for i in spec.groups[g])
        return _mix_call(mix, buf, key=key, t=t, seg_widths=widths)
    return _mix_call(mix, buf, key=key, t=t)


def gossip_average(tree: Tree, n_nodes: int, cfg: AveragingConfig,
                   mix: Optional[MixOp] = None, *,
                   key: Any = None, t: Any = None) -> Tree:
    """R rounds of doubly-stochastic consensus over the leading node axis —
    one packed pass per dtype group by default, per-leaf when `cfg.packed`
    is off or the quantized global-stats oracle is selected. `key` (optional)
    is the per-step base key for stochastic compressors — see
    `CirculantMixOp.__call__`. `t` (optional) is the traced round counter a
    time-varying `ScheduledMixOp` uses to select its active phase."""
    if mix is None:
        mix = make_gossip_mix(cfg, n_nodes)
    if not (cfg.packed and _packable(mix)):
        return jax.tree.map(lambda g: _mix_call(mix, g, key=key, t=t), tree)
    bufs, spec = packing.pack_tree(tree)
    outs = tuple(_apply_mix(mix, spec, g, b, key, t)
                 for g, b in enumerate(bufs))
    return packing.unpack_tree(outs, spec)


def exact_average(tree: Tree) -> Tree:
    return jax.tree.map(lambda g: jnp.broadcast_to(
        jnp.mean(g, axis=0, keepdims=True), g.shape), tree)


def _hmix_buffer(g: jax.Array, pods: int, per_pod: int,
                 mix: MixOp, key: Any = None, t: Any = None) -> jax.Array:
    """Reduce-scatter hierarchical consensus on one [N, ...] buffer/leaf."""
    shp = g.shape
    flat = g.reshape(pods, per_pod, -1)  # [P, M, F]
    pod_mean = jnp.mean(flat, axis=1)  # reduce ...
    f = pod_mean.shape[-1]
    chunk = -(-f // per_pod)
    pad = chunk * per_pod - f
    if pad:
        pod_mean = jnp.pad(pod_mean, ((0, 0), (0, pad)))
    scattered = pod_mean.reshape(pods, per_pod, chunk)  # ... scatter
    # cross-pod gossip, one chunk per lane; pad columns sit at the tail of
    # the flattened layout and are masked out of compressor statistics
    mixed = _mix_call(mix, scattered, valid_d=f if pad else None, key=key, t=t)
    gathered = mixed.reshape(pods, 1, chunk * per_pod)[..., :f]  # all-gather
    g = jnp.broadcast_to(gathered, (pods, per_pod, f))
    return g.reshape(shp)


def hierarchical_average(tree: Tree, pods: int, per_pod: int,
                         cfg: AveragingConfig,
                         mix: Optional[MixOp] = None, *,
                         key: Any = None, t: Any = None) -> Tree:
    """Exact averaging within each pod (fast ICI), gossip across pods (slow
    DCN) — in reduce-scatter form.

    Instead of materializing the full pod mean on every node and gossiping
    whole vectors from one lane per pod (broadcast-then-gossip), the pod mean
    is reduce-SCATTERED: lane j of each pod ends up owning chunk j of the pod
    mean, the cross-pod gossip mixes only each lane's own chunk (so each DCN
    link carries 1/per_pod of the vector, in parallel across lanes), and an
    intra-pod all-gather reassembles the mixed mean — halving-or-better the
    serialized cross-pod traffic relative to the broadcast form. The result is
    numerically the same consensus (the mix is applied chunkwise over the pod
    axis). Feature dims are zero-padded up to a multiple of per_pod; the pad
    columns are masked out of quantized compressor statistics, so the padded
    reduce-scatter form matches the unpadded broadcast form (Section VI wire
    format) instead of perturbing it. Quantized segment statistics do not
    survive the chunk-scatter relayout; they degrade to global (masked)
    statistics over the scattered pod means here.
    """
    if mix is None:
        mix = make_gossip_mix(cfg, pods)

    def hmix(g):
        return _hmix_buffer(g, pods, per_pod, mix, key, t)

    if not (cfg.packed and _packable(mix)):
        return jax.tree.map(hmix, tree)
    bufs, spec = packing.pack_tree(tree)
    return packing.unpack_tree(tuple(hmix(b) for b in bufs), spec)


def average_gradients(tree: Tree, cfg: AveragingConfig, *, n_nodes: int,
                      pods: int = 1,
                      mix: Optional[MixOp] = None,
                      key: Any = None, t: Any = None) -> Tree:
    """Dispatch on the paper's averaging mode. `tree` leaves: [n_nodes, ...].

    `mix` is the prebuilt consensus engine (gossip: over `n_nodes`;
    hierarchical: over `pods`); built from `cfg` on the fly when omitted.
    `key` is the optional per-step base key for stochastic compressors; `t`
    the optional traced round counter for time-varying schedules."""
    if cfg.mode == "exact":
        return exact_average(tree)
    if cfg.mode == "gossip":
        return gossip_average(tree, n_nodes, cfg, mix, key=key, t=t)
    if cfg.mode == "hierarchical":
        assert n_nodes % pods == 0
        return hierarchical_average(tree, pods, n_nodes // pods, cfg, mix,
                                    key=key, t=t)
    raise ValueError(f"unknown averaging mode {cfg.mode!r}")


def average_and_error(tree: Tree, cfg: AveragingConfig, *, n_nodes: int,
                      pods: int = 1, mix: Optional[MixOp] = None,
                      key: Any = None, t: Any = None) -> Tuple[Tree, jax.Array]:
    """Averaging plus the epsilon-consensus diagnostic with ONE pack: the
    mixed packed buffers feed both the unpack and the fused error reduction,
    so the trainer stops paying a second per-leaf (or re-pack) sweep."""
    if cfg.mode == "exact":
        mixed = exact_average(tree)
        return mixed, consensus_error(mixed)
    if cfg.mode not in ("gossip", "hierarchical"):
        raise ValueError(f"unknown averaging mode {cfg.mode!r}")
    if mix is None:
        mix = make_gossip_mix(cfg, pods if cfg.mode == "hierarchical"
                              else n_nodes)
    if not (cfg.packed and _packable(mix)):
        mixed = average_gradients(tree, cfg, n_nodes=n_nodes, pods=pods,
                                  mix=mix, key=key, t=t)
        return mixed, consensus_error(mixed)
    bufs, spec = packing.pack_tree(tree)
    if cfg.mode == "gossip":
        outs = tuple(_apply_mix(mix, spec, g, b, key, t)
                     for g, b in enumerate(bufs))
    else:
        assert n_nodes % pods == 0
        outs = tuple(_hmix_buffer(b, pods, n_nodes // pods, mix, key, t)
                     for b in bufs)
    err = _packed_consensus_error(outs, spec)
    return packing.unpack_tree(outs, spec), err


def ef_average_and_error(tree: Tree, ef: Tree, cfg: AveragingConfig, *,
                         n_nodes: int, mix: Optional[MixOp] = None,
                         key: Any = None, t: Any = None
                         ) -> Tuple[Tree, Tree, jax.Array, jax.Array, jax.Array]:
    """Error-feedback compressed gossip: ONE pack, ONE compression, exact
    linear consensus rounds (docs/DESIGN.md §Decentralized LM track).

    Per step, on the packed [N, D] buffers: v = g + e (residual-corrected
    gradient), q = C(v) with sender-local per-node tile statistics
    (`quantize.tile_compress(per_node=True)` — the granularity the shard_map
    wire uses), mixed = the R-round LINEAR consensus of q, e' = v - q. The
    compressor runs once OUTSIDE the mixing operator, so the rounds keep the
    composed-roll / matmul / shard_map fast paths that per-round quantized
    chains forfeit, and the compression error is carried in the optimizer
    state (`OptState.ef_residual`) instead of accumulating as iterate bias
    under momentum.

    With `cfg.quantization == "none"` the wire is exact: q = v, e' stays
    zero, and the result equals plain packed linear gossip of g + e.

    Returns (mixed, new_ef, consensus_err, ef_norm, ef_rel): `ef_norm` is
    the global L2 norm of the new residual, `ef_rel` its ratio to ||v||.
    """
    if mix is None:
        mix = make_gossip_mix(cfg, n_nodes)
    if getattr(mix, "quantization", "none") != "none":
        raise ValueError(
            "error feedback needs a LINEAR consensus operator — build it via "
            "make_gossip_mix, which drops the per-round compressor when "
            "cfg.error_feedback is on")
    bufs, spec = packing.pack_tree(tree)
    ebufs, espec = packing.pack_tree(ef)
    outs, res = [], []
    v2 = jnp.zeros((), jnp.float32)
    e2 = jnp.zeros((), jnp.float32)
    for g, (b, e) in enumerate(zip(bufs, ebufs)):
        v = b.astype(jnp.float32) + e.astype(jnp.float32)
        if cfg.quantization == "none" or b.shape[-1] == 0:
            q = v
        else:
            k = jax.random.fold_in(key, g) if key is not None else None
            q = tile_compress(v, cfg.quantization, cfg.quant_block_d,
                              key=k, per_node=True)
        outs.append(_mix_call(mix, q, key=None, t=t).astype(b.dtype))
        r = v - q
        res.append(r.astype(e.dtype))
        v2 = v2 + jnp.sum(v * v)
        e2 = e2 + jnp.sum(r.astype(jnp.float32) ** 2)
    err = _packed_consensus_error(tuple(outs), spec)
    ef_norm = jnp.sqrt(e2)
    ef_rel = ef_norm / (jnp.sqrt(v2) + 1e-30)
    return (packing.unpack_tree(tuple(outs), spec),
            packing.unpack_tree(tuple(res), espec), err, ef_norm, ef_rel)


def _packed_consensus_error(bufs: Tuple[jax.Array, ...],
                            spec: packing.PackSpec) -> jax.Array:
    """max_leaf max_n ||v_n - v_bar|| / ||v_bar|| on the packed buffers: the
    squared deviations are computed in one pass over [N, D] and summed per
    leaf segment by `packing.segment_sums` (static contiguous slices — exact,
    scatter-free, sharding-friendly), so a hundred-leaf tree stops paying a
    hundred independent norm chains."""
    errs = []
    for g, buf in enumerate(bufs):
        if buf.shape[-1] == 0:
            continue
        widths = [spec.leaf_width(i) for i in spec.groups[g]]
        b = buf.astype(jnp.float32)
        bar = jnp.mean(b, axis=0, keepdims=True)
        d2 = packing.segment_sums((b - bar) ** 2, widths)  # [N, S]
        num = jnp.max(jnp.sqrt(d2), axis=0)  # [S]
        den = jnp.sqrt(packing.segment_sums(bar[0] ** 2, widths)) + 1e-30
        errs.append(jnp.max(num / den))
    return jnp.max(jnp.stack(errs)) if errs else jnp.zeros(())


def consensus_error(tree: Tree) -> jax.Array:
    """max_n ||v_n - v_bar|| / ||v_bar|| across the pytree — the paper's
    epsilon-accuracy diagnostic for inexact averaging. Computed on the packed
    flat buffer (single fused reduction; `consensus_error_per_leaf` is the
    per-leaf oracle)."""
    bufs, spec = packing.pack_tree(tree)
    return _packed_consensus_error(bufs, spec)


def consensus_error_per_leaf(tree: Tree) -> jax.Array:
    """Per-leaf oracle form of `consensus_error` (one reduction chain per
    leaf) — kept for verification of the packed reduction."""
    def err(g):
        g = g.astype(jnp.float32)
        bar = jnp.mean(g, axis=0, keepdims=True)
        num = jnp.max(jnp.sqrt(jnp.sum((g - bar) ** 2, axis=tuple(range(1, g.ndim)))))
        den = jnp.sqrt(jnp.sum(bar**2)) + 1e-30
        return num / den
    errs = [err(g) for g in jax.tree.leaves(tree)]
    return jnp.max(jnp.stack(errs)) if errs else jnp.zeros(())
