"""Gradient-averaging operators for the framework-scale trainer — the paper's
technique as a first-class feature.

The trainer represents the paper's N compute nodes as a leading *node axis* on
the gradient pytree (sharded over the mesh's data axes), so averaging modes are
pure array programs whose collectives are visible in the lowered HLO:

* exact        -- mean over the node axis == AllReduce (DMB, Section IV)
* gossip       -- R rounds of circulant consensus: weighted `jnp.roll`s, which
                  XLA lowers to `collective-permute` chains (Section V, eq. 17)
* hierarchical -- exact within pod, gossip across pods (TPU adaptation)

Optional message quantization (Section VI) compresses each round's messages.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import AveragingConfig
from repro.core.mixing import schedule
from repro.core.quantize import COMPRESSORS

Tree = Any


def _roll_mix(x: jax.Array, sched, compress) -> jax.Array:
    """One consensus round over axis 0 of x via weighted circular shifts."""
    out = None
    for shift, w in sched:
        msg = x if shift == 0 else compress(jnp.roll(x, shift, axis=0))
        term = w * msg
        out = term if out is None else out + term
    return out


def gossip_average(tree: Tree, n_nodes: int, cfg: AveragingConfig) -> Tree:
    """R rounds of doubly-stochastic consensus over the leading node axis."""
    sched = schedule(cfg.topology, n_nodes, cfg.self_weight)
    compress = COMPRESSORS[cfg.quantization]

    def mix(g):
        for _ in range(cfg.rounds):
            g = _roll_mix(g, sched, compress)
        return g

    return jax.tree.map(mix, tree)


def exact_average(tree: Tree) -> Tree:
    return jax.tree.map(lambda g: jnp.broadcast_to(
        jnp.mean(g, axis=0, keepdims=True), g.shape), tree)


def hierarchical_average(tree: Tree, pods: int, per_pod: int,
                         cfg: AveragingConfig) -> Tree:
    """Exact psum within each pod (fast ICI), gossip across pods (slow DCN)."""
    def mix(g):
        shp = g.shape
        g = g.reshape(pods, per_pod, *shp[1:])
        g = jnp.broadcast_to(jnp.mean(g, axis=1, keepdims=True), g.shape)
        gp = gossip_average(g[:, 0], pods, cfg)
        g = jnp.broadcast_to(gp[:, None], g.shape)
        return g.reshape(shp)

    return jax.tree.map(mix, tree)


def average_gradients(tree: Tree, cfg: AveragingConfig, *, n_nodes: int,
                      pods: int = 1) -> Tree:
    """Dispatch on the paper's averaging mode. `tree` leaves: [n_nodes, ...]."""
    if cfg.mode == "exact":
        return exact_average(tree)
    if cfg.mode == "gossip":
        return gossip_average(tree, n_nodes, cfg)
    if cfg.mode == "hierarchical":
        assert n_nodes % pods == 0
        return hierarchical_average(tree, pods, n_nodes // pods, cfg)
    raise ValueError(f"unknown averaging mode {cfg.mode!r}")


def consensus_error(tree: Tree) -> jax.Array:
    """max_n ||v_n - v_bar|| / ||v_bar|| across the pytree — the paper's
    epsilon-accuracy diagnostic for inexact averaging."""
    def err(g):
        bar = jnp.mean(g, axis=0, keepdims=True)
        num = jnp.max(jnp.sqrt(jnp.sum((g - bar) ** 2, axis=tuple(range(1, g.ndim)))))
        den = jnp.sqrt(jnp.sum(bar**2)) + 1e-30
        return num / den
    errs = [err(g) for g in jax.tree.leaves(tree)]
    return jnp.max(jnp.stack(errs)) if errs else jnp.zeros(())
