"""Gradient-averaging operators for the framework-scale trainer — the paper's
technique as a first-class feature.

The trainer represents the paper's N compute nodes as a leading *node axis* on
the gradient pytree (sharded over the mesh's data axes), so averaging modes are
pure array programs whose collectives are visible in the lowered HLO:

* exact        -- mean over the node axis == AllReduce (DMB, Section IV)
* gossip       -- R rounds of circulant consensus (Section V, eq. 17), executed
                  through `core.mixing.CirculantMixOp`: with quantization off
                  the R-round operator is precomputed once and applied in a
                  single pass (weighted `jnp.roll`s / one circulant matmul /
                  the fused Pallas kernel on TPU)
* hierarchical -- exact within pod, gossip across pods in reduce-scatter form
                  (each intra-pod lane gossips one chunk of the pod mean over
                  DCN, then the pod all-gathers; TPU adaptation)

Optional message quantization (Section VI) compresses each round's messages;
quantized configs keep the exact per-round loop (the compressor is nonlinear,
so the operator must not be collapsed).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AveragingConfig
from repro.core.mixing import CirculantMixOp, circulant_mix_op, schedule

Tree = Any


def make_gossip_mix(cfg: AveragingConfig, n_nodes: int, *,
                    impl: str = "auto", mesh: Any = None) -> CirculantMixOp:
    """Build the consensus engine for a config — once, outside the train step.
    For `mode="hierarchical"` pass the pod count as `n_nodes`.

    `impl="auto"` resolves per layout (`core.mixing.resolve_auto_impl`):
    "roll" whenever the node axis is — or may be — sharded over mesh data
    axes (rolls are the form GSPMD partitions into collective-permute
    chains), the dense-matmul fast path on unsharded CPU/GPU layouts, and
    the fused Pallas kernel on single-device TPU. Pass the mesh the op will
    run under so sharded layouts are detected; without it, multi-device
    hosts conservatively get "roll"."""
    sched = schedule(cfg.topology, n_nodes, cfg.self_weight)
    return circulant_mix_op(sched, n_nodes, cfg.rounds,
                            quantization=cfg.quantization, impl=impl,
                            mesh=mesh)


def gossip_average(tree: Tree, n_nodes: int, cfg: AveragingConfig,
                   mix: Optional[CirculantMixOp] = None) -> Tree:
    """R rounds of doubly-stochastic consensus over the leading node axis."""
    if mix is None:
        mix = make_gossip_mix(cfg, n_nodes)
    return jax.tree.map(mix, tree)


def exact_average(tree: Tree) -> Tree:
    return jax.tree.map(lambda g: jnp.broadcast_to(
        jnp.mean(g, axis=0, keepdims=True), g.shape), tree)


def hierarchical_average(tree: Tree, pods: int, per_pod: int,
                         cfg: AveragingConfig,
                         mix: Optional[CirculantMixOp] = None) -> Tree:
    """Exact averaging within each pod (fast ICI), gossip across pods (slow
    DCN) — in reduce-scatter form.

    Instead of materializing the full pod mean on every node and gossiping
    whole vectors from one lane per pod (broadcast-then-gossip), the pod mean
    is reduce-SCATTERED: lane j of each pod ends up owning chunk j of the pod
    mean, the cross-pod gossip mixes only each lane's own chunk (so each DCN
    link carries 1/per_pod of the vector, in parallel across lanes), and an
    intra-pod all-gather reassembles the mixed mean — halving-or-better the
    serialized cross-pod traffic relative to the broadcast form. The result is
    numerically the same consensus (the mix is applied chunkwise over the pod
    axis); feature dims are zero-padded up to a multiple of per_pod, which for
    quantized configs slightly perturbs global compressor statistics relative
    to the unpadded broadcast form (wire-format modeling, Section VI).
    """
    if mix is None:
        mix = make_gossip_mix(cfg, pods)

    def hmix(g):
        shp = g.shape
        flat = g.reshape(pods, per_pod, -1)  # [P, M, F]
        pod_mean = jnp.mean(flat, axis=1)  # reduce ...
        f = pod_mean.shape[-1]
        chunk = -(-f // per_pod)
        pad = chunk * per_pod - f
        if pad:
            pod_mean = jnp.pad(pod_mean, ((0, 0), (0, pad)))
        scattered = pod_mean.reshape(pods, per_pod, chunk)  # ... scatter
        mixed = mix(scattered)  # cross-pod gossip, one chunk per lane
        gathered = mixed.reshape(pods, 1, chunk * per_pod)[..., :f]  # all-gather
        g = jnp.broadcast_to(gathered, (pods, per_pod, f))
        return g.reshape(shp)

    return jax.tree.map(hmix, tree)


def average_gradients(tree: Tree, cfg: AveragingConfig, *, n_nodes: int,
                      pods: int = 1,
                      mix: Optional[CirculantMixOp] = None) -> Tree:
    """Dispatch on the paper's averaging mode. `tree` leaves: [n_nodes, ...].

    `mix` is the prebuilt consensus engine (gossip: over `n_nodes`;
    hierarchical: over `pods`); built from `cfg` on the fly when omitted."""
    if cfg.mode == "exact":
        return exact_average(tree)
    if cfg.mode == "gossip":
        return gossip_average(tree, n_nodes, cfg, mix)
    if cfg.mode == "hierarchical":
        assert n_nodes % pods == 0
        return hierarchical_average(tree, pods, n_nodes // pods, cfg, mix)
    raise ValueError(f"unknown averaging mode {cfg.mode!r}")


def consensus_error(tree: Tree) -> jax.Array:
    """max_n ||v_n - v_bar|| / ||v_bar|| across the pytree — the paper's
    epsilon-accuracy diagnostic for inexact averaging."""
    def err(g):
        bar = jnp.mean(g, axis=0, keepdims=True)
        num = jnp.max(jnp.sqrt(jnp.sum((g - bar) ** 2, axis=tuple(range(1, g.ndim)))))
        den = jnp.sqrt(jnp.sum(bar**2)) + 1e-30
        return num / den
    errs = [err(g) for g in jax.tree.leaves(tree)]
    return jnp.max(jnp.stack(errs)) if errs else jnp.zeros(())
