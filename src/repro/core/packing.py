"""Flat-buffer packing of gradient pytrees for the consensus hot path.

A transformer gradient tree has hundreds of leaves; applying the consensus
engine through `jax.tree.map` issues hundreds of independent roll/compress
chains per step — the per-leaf dispatch tax the paper's communication-cost
analysis (Section VI) says the quantized regime can least afford. Packing
flattens the tree ONCE into contiguous ``[*lead, D]`` buffers (one per dtype,
so packing is dtype-preserving) with a static leaf-segment map, so every
averaging mode runs its mixing operator once per step on one buffer, and
per-leaf reductions (consensus error, per-leaf compressor statistics) become
single segment-reduced passes over the buffer.

The segment map is host-side / static: column ``j`` of group ``g``'s buffer
belongs to leaf ``spec.groups[g][spec.segment_ids(g)[j]]``. Leading axes (the
trainer's node axis; none for the DMB parameter vector) are preserved, so a
`PackSpec` built from the parameter tree repacks gradient trees of any node
count.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Tree = Any


@dataclasses.dataclass(frozen=True)
class PackSpec:
    """Static description of a packed pytree.

    treedef:   the pytree structure (for unflattening).
    trailing:  per-leaf shape AFTER the shared leading axes, in leaf order.
    dtypes:    per-leaf dtype name, in leaf order.
    lead:      number of shared leading axes preserved by packing (0 or more).
    groups:    per-buffer tuple of leaf indices; one buffer per distinct dtype,
               leaves in first-appearance order, so single-dtype trees (the
               common gradient case) pack into exactly one ``[*lead, D]``
               buffer.
    """

    treedef: Any
    trailing: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[str, ...]
    lead: int
    groups: Tuple[Tuple[int, ...], ...]

    def leaf_width(self, i: int) -> int:
        return int(np.prod(self.trailing[i], dtype=np.int64)) if self.trailing[i] else 1

    def group_width(self, g: int) -> int:
        return sum(self.leaf_width(i) for i in self.groups[g])

    def segment_ids(self, g: int) -> np.ndarray:
        """int32 [D_g]: position-within-group of the leaf owning each column."""
        widths = [self.leaf_width(i) for i in self.groups[g]]
        return np.repeat(np.arange(len(widths)), widths).astype(np.int32)


def pack_spec(tree: Tree, *, lead: int = 1) -> PackSpec:
    """Build the static segment map for `tree`. All leaves must share their
    first `lead` axis sizes (the trainer's node axis)."""
    leaves, treedef = jax.tree.flatten(tree)
    trailing, dtypes = [], []
    lead_shape = None
    for x in leaves:
        if x.ndim < lead:
            raise ValueError(f"leaf rank {x.ndim} < lead={lead}")
        if lead_shape is None:
            lead_shape = x.shape[:lead]
        elif x.shape[:lead] != lead_shape:
            raise ValueError(
                f"leaves disagree on leading axes: {x.shape[:lead]} vs {lead_shape}")
        trailing.append(tuple(x.shape[lead:]))
        dtypes.append(jnp.dtype(x.dtype).name)
    groups: dict = {}
    for i, dt in enumerate(dtypes):
        groups.setdefault(dt, []).append(i)
    return PackSpec(treedef, tuple(trailing), tuple(dtypes), lead,
                    tuple(tuple(g) for g in groups.values()))


def pack_tree(tree: Tree, spec: Optional[PackSpec] = None, *,
              lead: int = 1) -> Tuple[Tuple[jax.Array, ...], PackSpec]:
    """Flatten `tree` into one contiguous ``[*lead, D]`` buffer per dtype.

    Returns ``(buffers, spec)``. Pass a previously built `spec` to reuse its
    (static) segment map — the tree must match its structure and trailing
    shapes; leading axis sizes may differ (params vs grads, emulated N)."""
    if spec is None:
        spec = pack_spec(tree, lead=lead)
    leaves = jax.tree.leaves(tree)
    if len(leaves) != len(spec.trailing):
        raise ValueError("tree does not match PackSpec leaf count")
    bufs = []
    for group in spec.groups:
        parts = []
        for i in group:
            x = leaves[i]
            if tuple(x.shape[spec.lead:]) != spec.trailing[i]:
                raise ValueError(
                    f"leaf {i} trailing shape {x.shape[spec.lead:]} != "
                    f"spec {spec.trailing[i]}")
            parts.append(x.reshape(*x.shape[:spec.lead], -1))
        bufs.append(parts[0] if len(parts) == 1 else
                    jnp.concatenate(parts, axis=-1))
    return tuple(bufs), spec


def segment_sums(v: jax.Array, widths) -> jax.Array:
    """Exact per-segment sums over the last axis of `v` for contiguous
    segments of static `widths`: one static slice + contiguous reduce per
    segment, stacked to [..., S].

    Deliberately NOT the cumsum-at-boundaries trick: differences of a float32
    running sum over a transformer-scale buffer catastrophically cancel, which
    zeroes (or sign-flips) the statistics of small segments that sit after
    large ones. The static split keeps every partial sum at segment scale."""
    widths = np.asarray(widths, np.int64)
    if widths.size == 0:
        return jnp.zeros(v.shape[:-1] + (0,), v.dtype)
    bounds = np.cumsum(widths)[:-1]
    parts = jnp.split(v, list(bounds), axis=-1)
    return jnp.stack(
        [p.sum(-1) if p.shape[-1] else jnp.zeros(v.shape[:-1], v.dtype)
         for p in parts], axis=-1)


def unpack_tree(bufs: Tuple[jax.Array, ...], spec: PackSpec) -> Tree:
    """Inverse of `pack_tree`: split each buffer at the (static) segment
    boundaries and restore every leaf's shape and position."""
    leaves: list = [None] * len(spec.trailing)
    for g, buf in enumerate(bufs):
        off = 0
        for i in spec.groups[g]:
            w = spec.leaf_width(i)
            piece = jax.lax.slice_in_dim(buf, off, off + w, axis=buf.ndim - 1)
            leaves[i] = piece.reshape(*buf.shape[:-1], *spec.trailing[i])
            off += w
    return jax.tree.unflatten(spec.treedef, leaves)
