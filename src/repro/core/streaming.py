"""The streaming governor: hooks the rate model (core.rates) to a data source and
enforces the paper's provisioning semantics — per round it yields exactly B
samples split N ways and accounts for mu discarded samples (Fig. 4's timeline).

The governor is host-side (it models the splitter of Fig. 3(c)); the device-side
compute consumes its output. It also exposes running counters so experiments can
plot metrics against t' = samples *arrived* rather than samples consumed.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, Optional, Tuple

import numpy as np

from repro.configs.base import StreamConfig
from repro.core.rates import Plan, checked_plan_swap, plan


@dataclasses.dataclass
class GovernedStream:
    draw: Callable  # draw(rng, n) -> np/jnp samples (host-side)
    n_nodes: int
    plan: Plan
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self.samples_arrived = 0
        self.samples_consumed = 0
        self.samples_discarded = 0
        self.rounds = 0

    def update_plan(self, new_plan: Plan) -> None:
        """Closed-loop governor hook (see `core.rates.replan`): adopt a plan
        re-derived from measured rates (B fixed, mu adapts — see
        `core.rates.checked_plan_swap`); counters carry over."""
        self.plan = checked_plan_swap(self.plan, new_plan)

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        B, mu, N = self.plan.B, self.plan.mu, self.n_nodes
        z = self.draw(self._rng, B + mu)
        self.samples_arrived += B + mu
        self.samples_discarded += mu
        self.samples_consumed += B
        self.rounds += 1
        take = z[:B] if not isinstance(z, tuple) else tuple(a[:B] for a in z)
        reshape = lambda a: a.reshape(N, B // N, *a.shape[1:])
        if isinstance(take, tuple):
            return tuple(reshape(a) for a in take)
        return reshape(take)

    def next_superstep(self, k: int):
        """K governed rounds stacked on a leading K axis:
        [K, N, B/N, ...] leaves, ready for the K-round device scan."""
        rounds = [next(self) for _ in range(k)]
        if isinstance(rounds[0], tuple):
            return tuple(np.stack(parts) for parts in zip(*rounds))
        return np.stack(rounds)


def make_governed_stream(draw: Callable, stream_cfg: StreamConfig, n_nodes: int,
                         rounds_R: int, *, B: Optional[int] = None,
                         horizon: Optional[float] = None, seed: int = 0) -> GovernedStream:
    if stream_cfg.streaming_rate <= 0:
        # no governor: consume everything with the requested B
        p = Plan(B=B or n_nodes, mu=max(stream_cfg.forced_mu, 0), R=rounds_R,
                 Re=float("inf"), regime="resourceful")
    else:
        p = plan(stream_cfg, n_nodes, rounds_R, B=B, horizon_samples=horizon)
    return GovernedStream(draw, n_nodes, p, seed)
