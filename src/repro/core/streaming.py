"""The streaming governor: hooks the rate model (core.rates) to a data source and
enforces the paper's provisioning semantics — per round it yields exactly B
samples split N ways and accounts for mu discarded samples (Fig. 4's timeline).

The governor is host-side (it models the splitter of Fig. 3(c)); the device-side
compute consumes its output. It also exposes running counters so experiments can
plot metrics against t' = samples *arrived* rather than samples consumed.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Iterator, Optional, Tuple

import numpy as np

from repro.configs.base import StreamConfig
from repro.core.rates import (BucketLadder, Plan, checked_plan_swap, plan,
                              snap_plan_to_ladder)


class GovernedPlanMixin:
    """Lock-guarded closed-loop plan state shared by the governed sources
    (`GovernedStream` here, `data.pipeline.StreamingPipeline`): `update_plan`
    validates swaps against the adopted bucket ladder, `adopt_ladder` snaps
    an unregistered plan onto it, and the per-superstep latch guarantees
    every superstep is dealt at a single width even when a swap lands from
    the consumer thread mid-production. Hosts must provide `plan`,
    `stream_cfg`, and `n_nodes` before calling `_init_plan_state`.
    """

    def _init_plan_state(self, ladder: Optional[BucketLadder],
                         horizon: Optional[float] = None) -> None:
        self.ladder: Optional[BucketLadder] = None
        self._plan_horizon = horizon
        self._plan_lock = threading.Lock()
        self._last_superstep_plan = self.plan
        if ladder is not None:
            self.adopt_ladder(ladder)

    def adopt_ladder(self, ladder: BucketLadder) -> None:
        """Register the bucket ladder `update_plan` validates against. If the
        current plan's B is not a registered bucket it is snapped to the
        nearest keep-up bucket (mu re-derived) — call before consumption."""
        self.plan = snap_plan_to_ladder(self.plan, self.stream_cfg,
                                        self.n_nodes, ladder,
                                        horizon_samples=self._plan_horizon)
        self.ladder = ladder
        self._last_superstep_plan = self.plan

    def update_plan(self, new_plan: Plan) -> None:
        """Closed-loop governor hook (see `core.rates.replan`): adopt a plan
        re-derived from measured rates. Without a ladder B stays fixed and
        only mu adapts; with one, B may move to any registered bucket
        (`core.rates.checked_plan_swap`); counters carry over."""
        with self._plan_lock:
            self.plan = checked_plan_swap(self.plan, new_plan, self.ladder)

    def swap_membership(self, membership, ladder: Optional[BucketLadder] = None
                        ) -> Plan:
        """Adopt a new active cohort: a join/leave is a plan swap, not a
        restart (docs/DESIGN.md §Elastic membership).

        Under the plan lock, eq. 4 is re-inverted at N = n_active and (B, mu)
        re-derived, snapped onto `ladder` (the cohort's bucket ladder — pass
        the one derived from the full-membership base ladder via
        `BucketLadder.for_cohort` so a return to full membership restores the
        original buckets exactly). Supersteps already dealt keep their old
        plan snapshot and drain under the membership that dealt them; only
        future supersteps latch the new cohort. Returns the adopted plan."""
        with self._plan_lock:
            cur = self.plan
            if cur.membership == membership:
                return cur
            if cur.membership is None and membership.is_full:
                # initial stamp: same cohort, just record the mask — keep the
                # user's exact B rather than re-deriving it
                self.plan = dataclasses.replace(cur, membership=membership)
                return self.plan
            m = membership.n_active
            governed = (self.stream_cfg is not None
                        and self.stream_cfg.streaming_rate > 0)
            if governed:
                try:
                    new = plan(self.stream_cfg, m, cur.R,
                               horizon_samples=self._plan_horizon)
                except ValueError:
                    # the shrunk cohort cannot keep up with the stream at any
                    # B: a death must NOT crash the run — hold the current B
                    # (rounded to the cohort) and let the plan go
                    # under-provisioned (mu > 0 discards, Fig. 4's drop rule)
                    B = -(-cur.B // m) * m
                    new = plan(self.stream_cfg, m, cur.R, B=B,
                               horizon_samples=self._plan_horizon)
                if ladder is not None:
                    new = snap_plan_to_ladder(new, self.stream_cfg, m, ladder,
                                              horizon_samples=self._plan_horizon)
            else:
                # ungoverned: keep B as close as possible while splitting
                # evenly across the cohort
                B = -(-cur.B // m) * m
                new = dataclasses.replace(cur,
                                          B=ladder.snap(B) if ladder else B)
            new = dataclasses.replace(new, membership=membership)
            self.ladder = ladder if ladder is not None else self.ladder
            self.plan = new
            return new

    def splitter_state(self) -> dict:
        """JSON-serializable snapshot of the splitter: the t' counter quad,
        the PRNG's exact bit-generator state, and the live plan
        (docs/DESIGN.md §Fault-tolerant streaming).

        Called from the producer thread right after a superstep is dealt
        (the prefetcher's `meta` hook), the snapshot pins the stream position
        of that superstep's last sample — restoring it re-deals every sample
        after that point identically, which is how staged-but-unconsumed
        supersteps lost in a crash are regenerated rather than skipped. The
        stream itself cannot be replayed; only the synthesis position can."""
        with self._plan_lock:
            return {"counters": [int(self.samples_arrived),
                                 int(self.samples_consumed),
                                 int(self.samples_discarded),
                                 int(self.rounds)],
                    "rng": self._rng.bit_generator.state,
                    "plan": self.plan.to_json()}

    def load_splitter_state(self, state: dict, *,
                            plan: Optional[Plan] = None) -> None:
        """Restore a `splitter_state` snapshot: counters, PRNG position, and
        the live plan (override with `plan` to adopt the consumer-side
        post-replan plan instead of the one the snapshot's producer saw).
        The ladder is not part of the snapshot — hosts re-derive it from
        config and re-adopt before restoring, so the restored plan is never
        re-snapped here."""
        with self._plan_lock:
            (self.samples_arrived, self.samples_consumed,
             self.samples_discarded, self.rounds) = (
                int(x) for x in state["counters"])
            self._rng.bit_generator.state = state["rng"]
            p = plan if plan is not None else Plan.from_json(state["plan"])
            self.plan = p
            self._last_superstep_plan = p

    def _latch_plan(self) -> Plan:
        with self._plan_lock:
            return self.plan

    @property
    def last_superstep_plan(self) -> Plan:
        """The plan that dealt the most recently produced superstep — what a
        prefetcher's `meta` hook snapshots so the consumer knows which plan
        a staged batch belongs to."""
        return self._last_superstep_plan


@dataclasses.dataclass
class GovernedStream(GovernedPlanMixin):
    draw: Callable  # draw(rng, n) -> np/jnp samples (host-side)
    n_nodes: int
    plan: Plan
    seed: int = 0
    # registered B buckets the closed loop may move between; None pins B
    ladder: Optional[BucketLadder] = None
    # rate model behind the plan (for ladder snapping); None = ungoverned
    stream_cfg: Optional[StreamConfig] = None
    horizon: Optional[float] = None

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        if self.stream_cfg is None:
            self.stream_cfg = StreamConfig()
        self._init_plan_state(self.ladder, self.horizon)
        self.samples_arrived = 0
        self.samples_consumed = 0
        self.samples_discarded = 0
        self.rounds = 0

    def _round(self, p: Plan):
        B, mu, N = p.B, p.mu, self.n_nodes
        z = self.draw(self._rng, B + mu)
        self.samples_arrived += B + mu
        self.samples_discarded += mu
        self.samples_consumed += B
        self.rounds += 1
        take = z[:B] if not isinstance(z, tuple) else tuple(a[:B] for a in z)
        reshape = lambda a: a.reshape(N, B // N, *a.shape[1:])
        if isinstance(take, tuple):
            return tuple(reshape(a) for a in take)
        return reshape(take)

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._round(self._latch_plan())

    def next_superstep(self, k: int):
        """K governed rounds stacked on a leading K axis:
        [K, N, B/N, ...] leaves, ready for the K-round device scan. The plan
        is latched once per superstep so a concurrent `update_plan` cannot
        produce ragged round widths within one stack."""
        p = self._latch_plan()
        rounds = [self._round(p) for _ in range(k)]
        self._last_superstep_plan = p
        if isinstance(rounds[0], tuple):
            return tuple(np.stack(parts) for parts in zip(*rounds))
        return np.stack(rounds)


def make_governed_stream(draw: Callable, stream_cfg: StreamConfig, n_nodes: int,
                         rounds_R: int, *, B: Optional[int] = None,
                         horizon: Optional[float] = None,
                         ladder: Optional[BucketLadder] = None,
                         seed: int = 0) -> GovernedStream:
    if stream_cfg.streaming_rate <= 0:
        # no governor: consume everything with the requested B
        p = Plan(B=B or n_nodes, mu=max(stream_cfg.forced_mu, 0), R=rounds_R,
                 Re=float("inf"), regime="resourceful")
    else:
        p = plan(stream_cfg, n_nodes, rounds_R, B=B, horizon_samples=horizon)
    return GovernedStream(draw, n_nodes, p, seed, ladder, stream_cfg, horizon)
