"""Config-driven scenario registry: the paper's assumptions as a testbed.

Every benchmark before this module ran ONE topology (a static ring
circulant), IID synthetic streams, and loss-free links. The convergence
story the paper actually proves (eq. 17, Theorem 4) is about *B-connected
time-varying graphs* under a compute/communication mismatch, Nokleby & Bajwa
(arXiv:1704.07888) analyze the rate-*limited* network regime, and Ozfatura,
Gündüz & Poor (arXiv:2112.05559) motivate lossy/bandwidth-constrained links
for collaborative learning. This registry composes those three orthogonal
axes into named, seeded, deterministic scenarios (`ScenarioConfig` in
`configs/base.py` — mirroring how `configs/` registers models):

* **topology schedules** — the mixing graph switches per consensus round
  (ring -> torus -> expander / random-geometric), compiled into ONE
  `core.mixing.ScheduledMixOp` whose phase is runtime data (zero retraces).
* **link models** — Bernoulli packet loss and bandwidth caps from the
  extended `core.faults.FaultSchedule` DSL; loss realizations are folded
  into the per-round operator table (Metropolis-reweighted, doubly
  stochastic), bandwidth caps reach the governor through simulated round
  times (`core.rates.rate_limited` is the ground-truth model).
* **non-IID streams** — `data.synthetic`'s drifting-covariance PCA and
  Dirichlet label-skewed logreg host samplers, threaded through the governed
  splitter.

Deviations from the paper's eq. 17 assumptions are documented in
docs/DESIGN.md §Scenario harness; `benchmarks/bench_scenarios.py` sweeps the
topology x link x stream matrix and `tests/test_scenarios.py` property-checks
every operator the registry can produce (doubly stochastic, lambda_2 < 1,
B-connected window products).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import numpy as np

from repro.configs.base import AveragingConfig, ScenarioConfig
from repro.configs.paper_logreg import LogRegConfig
from repro.configs.paper_pca import FIG7
from repro.core import mixing
from repro.core.faults import FaultSchedule
from repro.core.mixing import ScheduledMixOp, scheduled_mix_op
from repro.data import synthetic

TOPOLOGIES = ("ring", "torus", "circulant2", "expander", "geometric")
CIRCULANTS = ("ring", "torus", "circulant2")
STREAMS = ("iid_pca", "drift_pca", "iid_logreg", "skew_logreg")

# stream ground-truth configs: the PCA cells run the paper's Fig. 7 spectrum,
# the logreg cells a small conditional-Gaussian problem (Fig. 9 family)
PCA_CFG = FIG7
LOGREG_CFG = LogRegConfig(dim=5, generator="cond_gauss", noise_var=2.0)


# ---------------------------------------------------------------------------
# Per-phase topology operators
# ---------------------------------------------------------------------------


def topology_matrix(name: str, n: int, *, seed: int = 0,
                    self_weight: float = 0.0) -> np.ndarray:
    """Dense one-round doubly-stochastic operator for a named topology.

    Circulant families densify their shift schedule (so scenario operators
    stay bit-comparable with the device gossip path); the dense families
    (expander / geometric) sample a connected graph from `seed` and take
    Metropolis weights."""
    if name in CIRCULANTS:
        return np.asarray(
            mixing.schedule_matrix(mixing.schedule(name, n, self_weight), n))
    if name == "expander":
        if n < 3:
            return np.asarray(
                mixing.schedule_matrix(mixing.schedule("ring", n), n))
        return mixing.random_regular_expander(n, deg=4 if n >= 6 else 2,
                                              seed=seed)
    if name == "geometric":
        return mixing.random_geometric(n, seed=seed)
    raise ValueError(f"unknown topology {name!r}; one of {TOPOLOGIES}")


def _validate(scn: ScenarioConfig) -> None:
    if scn.n_nodes < 1:
        raise ValueError(f"scenario {scn.name!r}: need n_nodes >= 1")
    if scn.rounds < 1:
        raise ValueError(f"scenario {scn.name!r}: need rounds >= 1")
    if not scn.topology_schedule:
        raise ValueError(f"scenario {scn.name!r}: empty topology schedule")
    for topo, seg in scn.topology_schedule:
        if topo not in TOPOLOGIES:
            raise ValueError(f"scenario {scn.name!r}: unknown topology "
                             f"{topo!r}; one of {TOPOLOGIES}")
        if seg < 1:
            raise ValueError(f"scenario {scn.name!r}: segment length {seg}")
    if scn.stream not in STREAMS:
        raise ValueError(f"scenario {scn.name!r}: unknown stream "
                         f"{scn.stream!r}; one of {STREAMS}")
    sched = fault_schedule(scn)
    if sched is not None:
        if sched.has_node_faults:
            raise ValueError(f"scenario {scn.name!r}: node faults belong in "
                             f"the driver's --faults schedule; scenario "
                             f"links take link:/bw: tokens only")
        for lf in sched.links:
            if lf.kind == "link" and lf.end == -1:
                raise ValueError(
                    f"scenario {scn.name!r}: link-loss fault {lf.spec()!r} "
                    f"needs a bounded window — realizations are precomputed "
                    f"over a finite round horizon and repeat beyond it")


def fault_schedule(scn: ScenarioConfig) -> Optional[FaultSchedule]:
    """The scenario's link-fault schedule (windows index consensus rounds),
    seeded by the scenario seed; None when the link model is clean."""
    if not scn.links:
        return None
    return FaultSchedule.parse(scn.links, scn.n_nodes, seed=scn.seed)


def scenario_period(scn: ScenarioConfig) -> int:
    """Rounds before the per-round operator table repeats: the topology
    period, stretched to cover every bounded link window (and any explicit
    `period_rounds`), rounded up to a whole number of topology cycles."""
    t_topo = sum(seg for _, seg in scn.topology_schedule)
    period = max(t_topo, scn.period_rounds)
    sched = fault_schedule(scn)
    if sched is not None:
        for lf in sched.links:
            if lf.end != -1:
                period = max(period, lf.end)
    return -(-period // t_topo) * t_topo


def _phase_name_at(scn: ScenarioConfig, t: int) -> str:
    """Topology name active at (1-based) consensus round t."""
    t_topo = sum(seg for _, seg in scn.topology_schedule)
    r = (t - 1) % t_topo
    for topo, seg in scn.topology_schedule:
        if r < seg:
            return topo
        r -= seg
    raise AssertionError("unreachable")


def one_round_matrices(scn: ScenarioConfig) -> list:
    """The realized one-round operator of every round in the period, indexed
    by t % period (slot 0 holds round t = period): topology phase composed
    with that round's link-loss realization. This is the ground truth the
    property suite checks (doubly stochastic each round, contracting window
    products) and the source `build_mix` compiles."""
    period = scenario_period(scn)
    sched = fault_schedule(scn)
    out = [None] * period
    for t in range(1, period + 1):
        A = topology_matrix(_phase_name_at(scn, t), scn.n_nodes,
                            seed=scn.seed, self_weight=scn.self_weight)
        if sched is not None:
            A = sched.lossy_matrix(A, t)
        out[t % period] = A
    return out


def build_mix(scn: ScenarioConfig) -> ScheduledMixOp:
    """Compile the scenario into one time-varying consensus operator.

    Per-round realized operators are deduplicated (loss-free rounds of the
    same topology phase share one effective operator), then handed to
    `core.mixing.scheduled_mix_op` — circulant phases as shift schedules (so
    a constant clean schedule stays bit-identical to `CirculantMixOp`),
    realized/dense phases as matrices. The round->phase lookup and the
    operator stack are runtime data: every round of every scenario reuses
    one compiled superstep."""
    _validate(scn)
    period = scenario_period(scn)
    sched = fault_schedule(scn)
    phases, lut, index = [], [], {}
    for i in range(period):
        t = period if i == 0 else i  # slot i serves rounds t === i (mod period)
        topo = _phase_name_at(scn, t)
        drops = () if sched is None else sched.link_drops(t)
        if topo in CIRCULANTS and not drops:
            spec = mixing.schedule(topo, scn.n_nodes, scn.self_weight)
            key = ("sched", spec)
        else:
            A = topology_matrix(topo, scn.n_nodes, seed=scn.seed,
                                self_weight=scn.self_weight)
            if sched is not None:
                A = sched.lossy_matrix(A, t)
            spec = np.asarray(A, np.float32)
            key = ("dense", topo, drops)
        if key not in index:
            index[key] = len(phases)
            phases.append(spec)
        lut.append(index[key])
    return scheduled_mix_op(phases, scn.n_nodes, scn.rounds,
                            phase_by_round=lut)


def window_lambda2(scn: ScenarioConfig, window: Optional[int] = None) -> float:
    """eq. 17 B-connectivity check: the worst contraction rate of any
    length-`window` product of consecutive realized one-round operators
    (cyclic over the period; `window=None` uses the full period). < 1 means
    every window mixes — the B-connected condition the time-varying
    convergence results assume."""
    mats = one_round_matrices(scn)
    period = len(mats)
    window = period if window is None else window
    worst = 0.0
    for start in range(period):
        P = np.eye(scn.n_nodes)
        for k in range(window):
            t = start + k + 1  # rounds start at 1
            P = mats[t % period] @ P
        worst = max(worst, mixing.lambda2(P))
    return worst


# ---------------------------------------------------------------------------
# Streams
# ---------------------------------------------------------------------------


class ScenarioStream(NamedTuple):
    """A scenario's host sampler plus its ground truth for metrics/tests."""

    sample: Callable  # (np rng, n) -> batch dict, splitter-compatible
    kind: str
    pca: Optional[synthetic.PCAStream] = None  # iid_pca
    drift: Optional[synthetic.DriftingPCAStream] = None  # drift_pca
    logreg: Optional[synthetic.SkewedLogRegStream] = None  # *_logreg


def build_stream(scn: ScenarioConfig) -> ScenarioStream:
    """The scenario's stream axis: a host sampler for the governed splitter
    (`data.pipeline.StreamingPipeline`) with its ground truth attached.
    Non-IID kinds lay nodes out as contiguous blocks, aligned with
    `train.trainer.make_node_batch` (exact at mu = 0)."""
    _validate(scn)
    if scn.stream == "iid_pca":
        pca = synthetic.make_pca_stream(
            dataclasses.replace(PCA_CFG, seed=scn.seed))
        return ScenarioStream(synthetic.make_pca_host_sampler(pca), "iid_pca",
                              pca=pca)
    if scn.stream == "drift_pca":
        drift = synthetic.make_drifting_pca_sampler(
            dataclasses.replace(PCA_CFG, seed=scn.seed),
            rate=scn.stream_param)
        return ScenarioStream(drift.sample, "drift_pca", drift=drift)
    cfg = dataclasses.replace(LOGREG_CFG, seed=scn.seed)
    alpha = float("inf") if scn.stream == "iid_logreg" else scn.stream_param
    lr = synthetic.make_skewed_logreg_sampler(cfg, scn.n_nodes, alpha=alpha,
                                              seed=scn.seed)
    return ScenarioStream(lr.sample, scn.stream, logreg=lr)


def averaging_config(scn: ScenarioConfig) -> AveragingConfig:
    """The gossip config a scenario superstep runs under. The topology field
    names the first segment for observability; the actual operator sequence
    comes from `build_mix`'s override."""
    topo = scn.topology_schedule[0][0]
    return AveragingConfig(mode="gossip", rounds=scn.rounds,
                           topology=topo if topo in CIRCULANTS else "ring",
                           self_weight=scn.self_weight)


def comm_factor(scn: ScenarioConfig, step: int) -> float:
    """The scenario's communication slowdown at a round (bandwidth caps)."""
    sched = fault_schedule(scn)
    return 1.0 if sched is None else sched.bw_factor(step)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

SCENARIOS: Dict[str, ScenarioConfig] = {}


def register(scn: ScenarioConfig) -> ScenarioConfig:
    """Validate and add a scenario to the registry (names are unique)."""
    if scn.name in SCENARIOS:
        raise ValueError(f"scenario {scn.name!r} already registered")
    _validate(scn)
    SCENARIOS[scn.name] = scn
    return scn


def get_scenario(name: str) -> ScenarioConfig:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; registered: "
                       f"{scenario_names()}") from None


def scenario_names() -> Tuple[str, ...]:
    return tuple(sorted(SCENARIOS))


# The benchmark sweep axes (`benchmarks/bench_scenarios.py` crosses them into
# the excess-risk matrix — >= 3 values per axis). Link windows index
# consensus rounds and must cover the bench horizons.
TOPOLOGY_AXIS: Dict[str, Tuple[Tuple[str, int], ...]] = {
    "ring": (("ring", 1),),
    "tv_rte": (("ring", 2), ("torus", 2), ("expander", 2)),
    "geometric": (("geometric", 1),),
}
LINK_AXIS: Dict[str, str] = {
    "clean": "",
    "lossy": "link:0-1@1-257p0.3,link:2-3@1-257p0.3",
    "ratelimited": "bw:0-1@1-257x4",
}
STREAM_AXIS: Dict[str, Tuple[str, float]] = {
    "iid_pca": ("iid_pca", 0.0),
    "drift_pca": ("drift_pca", 2e-4),
    "skew_logreg": ("skew_logreg", 0.3),
}


def make_scenario(topo_key: str, link_key: str, stream_key: str, *,
                  n_nodes: int = 8, rounds: int = 2,
                  seed: int = 0) -> ScenarioConfig:
    """Compose one cell of the topology x link x stream matrix from the
    named axis values (unregistered; name = 'topo/link/stream')."""
    stream, param = STREAM_AXIS[stream_key]
    return ScenarioConfig(
        name=f"{topo_key}/{link_key}/{stream_key}", n_nodes=n_nodes,
        rounds=rounds, topology_schedule=TOPOLOGY_AXIS[topo_key],
        links=LINK_AXIS[link_key], stream=stream, stream_param=param,
        seed=seed)


# Named scenarios for the launch CLI (`python -m repro.launch.train
# --scenario NAME`) and the tests — one representative per axis extreme.
register(make_scenario("ring", "clean", "iid_pca"))
register(make_scenario("tv_rte", "clean", "iid_pca"))
register(make_scenario("geometric", "clean", "iid_pca"))
register(make_scenario("ring", "lossy", "iid_pca"))
register(make_scenario("ring", "ratelimited", "iid_pca"))
register(make_scenario("ring", "clean", "drift_pca"))
register(make_scenario("geometric", "lossy", "skew_logreg"))
register(make_scenario("tv_rte", "ratelimited", "drift_pca"))
