"""Algorithms 3 & 4 — D-SGD and AD-SGD: distributed stochastic (accelerated)
gradient descent with *inexact* averaging via R rounds of averaging consensus
(eq. 17) over a doubly-stochastic mixing matrix A.

Decentralized-parameter model: every node keeps its own iterate; the state is
[N, d]. Consensus mixes the *gradients* (Alg. 3 steps 7-10). D-SGD additionally
maintains the stepsize-weighted Polyak-Ruppert average per node (step 13);
AD-SGD maintains the (u, v, w) Nesterov triple per node (Alg. 4).

The consensus hot path goes through `core.mixing.MixOp`: the effective R-round
operator A^R is precomputed once outside the training scan, so each step costs
one [N, N] matmul instead of R. Drivers are wrapped in a top-level `jax.jit`
with buffer donation so long-horizon streaming runs update the [N, d] state
in place instead of re-allocating it every step.
"""
from __future__ import annotations

import functools
import warnings
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.mixing import DenseMixOp, dense_mix_op


class DSGDResult(NamedTuple):
    w: jax.Array  # [N, d] final iterates
    w_av: jax.Array  # [N, d] Polyak averages (D-SGD) or final w (AD-SGD)
    trace_t_prime: jax.Array
    trace_metric: jax.Array  # metric of node 0's averaged iterate


def consensus(h: jax.Array, A: jax.Array, rounds: int) -> jax.Array:
    """R rounds of averaging consensus: h <- A h (eq. 17). h: [N, d].

    Per-round oracle form — the fused engine (`core.mixing.dense_mix_op`)
    matches this to float accuracy with a single precomputed matmul."""
    def body(h, _):
        return A @ h, None
    if rounds == 0:
        return h
    h, _ = jax.lax.scan(body, h, None, length=rounds)
    return h


@functools.lru_cache(maxsize=None)
def donation_supported() -> bool:
    """Probe (once per process) whether the pinned jax/backend actually
    honors `donate_argnums`: compile a tiny donated step and check that the
    input buffer is consumed WITHOUT the "donated buffers were not usable"
    warning. The support matrix has moved across jax releases (CPU donation
    used to be a warn-and-ignore no-op; the pinned PJRT CPU client implements
    it), so detect instead of hard-coding a backend list."""
    f = jax.jit(lambda a: a + 1.0, donate_argnums=0)
    x = jnp.zeros((8,), jnp.float32)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        jax.block_until_ready(f(x))
    unusable = any("donated" in str(w.message).lower() for w in caught)
    return bool(x.is_deleted() and not unusable)


def jit_driver(fn: Callable) -> Callable:
    """Top-level jit for a scan driver `fn(init, ts)`, donating the carry
    buffers where the backend supports it (feature-detected — see
    `donation_supported`). Compiles per driver invocation (the closure is
    fresh each call) — same as the pre-jit tracing cost; the win is in-place
    [N, d] state updates across the steps *within* a run."""
    donate = (0,) if donation_supported() else ()
    return jax.jit(fn, donate_argnums=donate)


def run_dsgd(
    grad_fn: Callable,  # grad_fn(w, *z) -> gradient for one node's local batch
    draw: Callable,  # draw(key, n) -> round samples
    w0: jax.Array,  # [d] common init
    A: jax.Array,  # [N, N] doubly-stochastic mixing matrix
    *,
    B: int,
    rounds: int,  # R consensus rounds per iteration
    steps: int,
    stepsize: Callable,
    project: Optional[Callable] = None,
    trace_metric: Optional[Callable] = None,
    accelerated: bool = False,
    beta: Optional[Callable] = None,  # AD-SGD beta_t (default (t+1)/2)
    mix: Optional[DenseMixOp] = None,  # override the consensus engine
    seed: int = 0,
) -> DSGDResult:
    N = A.shape[0]
    assert B % N == 0
    proj = project or (lambda w: w)
    metric = trace_metric or (lambda w: jnp.zeros(()))
    beta_fn = beta or (lambda t: (t + 1.0) / 2.0)
    # the R-round operator, precomputed ONCE outside the scan
    mix = mix if mix is not None else dense_mix_op(A, rounds)

    def local_grads(w_nodes, key):
        z = draw(key, B)
        z = jax.tree.map(lambda a: a.reshape(N, B // N, *a.shape[1:]), z)
        return jax.vmap(lambda w, zn: grad_fn(w, *jax.tree.leaves(zn)))(w_nodes, z)

    ts = jnp.arange(1, steps + 1)
    t_prime = ts * B

    if not accelerated:
        def round_fn(carry, t):
            w, w_av, eta_sum, key = carry
            key, kd = jax.random.split(key)
            g = local_grads(w, kd)  # [N, d] (steps 2-6)
            h = mix(g)  # steps 7-10, one fused pass
            eta = stepsize(t)
            w_new = jax.vmap(proj)(w - eta * h)  # step 12
            eta_sum_new = eta_sum + eta
            w_av_new = (eta_sum * w_av + eta * w_new) / eta_sum_new  # step 13
            return (w_new, w_av_new, eta_sum_new, key), metric(w_av_new[0])

        w_nodes = jnp.tile(w0[None], (N, 1))
        init = (w_nodes, jnp.zeros_like(w_nodes), jnp.zeros(()), jax.random.PRNGKey(seed))
        drive = jit_driver(lambda init, ts: jax.lax.scan(round_fn, init, ts))
        (w, w_av, _, _), metrics = drive(init, ts)
        return DSGDResult(w, w_av, t_prime, metrics)

    def round_fn(carry, t):
        v, w, key = carry
        key, kd = jax.random.split(key)
        b = beta_fn(t)
        u = v / b + (1.0 - 1.0 / b) * w  # step 2 (eq. 9)
        g = local_grads(u, kd)  # steps 3-7 (gradients at u)
        h = mix(g)  # steps 8-11, one fused pass
        v_new = jax.vmap(proj)(u - stepsize(t) * h)  # step 13 (eq. 10)
        w_new = v_new / b + (1.0 - 1.0 / b) * w  # step 14 (eq. 11)
        return (v_new, w_new, key), metric(w_new[0])

    w_nodes = jnp.tile(w0[None], (N, 1))
    # v and w need distinct buffers: the donated carry writes each in place
    init = (w_nodes, jnp.array(w_nodes), jax.random.PRNGKey(seed))
    drive = jit_driver(lambda init, ts: jax.lax.scan(round_fn, init, ts))
    (v, w, _), metrics = drive(init, ts)
    return DSGDResult(w, w, t_prime, metrics)


def run_local_sgd(grad_fn, draw, w0, *, N, B, steps, stepsize, project=None,
                  trace_metric=None, seed=0) -> DSGDResult:
    """The paper's `local` baseline: nodes run SGD on their own streams with no
    collaboration (A = I, R = 0)."""
    A = jnp.eye(N)
    return run_dsgd(grad_fn, draw, w0, A, B=B, rounds=0, steps=steps,
                    stepsize=stepsize, project=project, trace_metric=trace_metric,
                    seed=seed)


def run_dgd(
    grad_fn, draw, w0, A, *, B, steps, stepsize, project=None,
    trace_metric=None, mode: str = "minibatched", rho: float = 1.0,
    mix: Optional[DenseMixOp] = None, seed: int = 0,
) -> DSGDResult:
    """Communications-constrained DGD adaptation (Section V-C, eq. 18):
    one consensus round on the *iterates* per step, gradient on local data.

    mode="naive": discards samples that arrive during comm rounds (keeps B/N=1
    sample per node per step, drops the rest implied by rho).
    mode="minibatched": local mini-batch of size B/N = 1/rho per step.
    """
    N = A.shape[0]
    metric = trace_metric or (lambda w: jnp.zeros(()))
    proj = project or (lambda w: w)
    Bn = max(1, B // N) if mode == "minibatched" else 1
    drawn = N * Bn
    mix = mix if mix is not None else dense_mix_op(A, 1)

    def round_fn(carry, t):
        w, key = carry
        key, kd = jax.random.split(key)
        z = draw(kd, drawn)
        z = jax.tree.map(lambda a: a.reshape(N, Bn, *a.shape[1:]), z)
        g = jax.vmap(lambda wn, zn: grad_fn(wn, *jax.tree.leaves(zn)))(w, z)
        w_new = jax.vmap(proj)(mix(w) - stepsize(t) * g)  # eq. (18)
        return (w_new, key), metric(w_new[0])

    w_nodes = jnp.tile(w0[None], (N, 1))
    drive = jit_driver(lambda init, ts: jax.lax.scan(round_fn, init, ts))
    (w, _), metrics = drive((w_nodes, jax.random.PRNGKey(seed)),
                            jnp.arange(1, steps + 1))
    # in the naive mode the system still *receives* B samples per step
    t_prime = jnp.arange(1, steps + 1) * B
    return DSGDResult(w, w, t_prime, metrics)
