"""Train-to-serve snapshot publication
(docs/DESIGN.md §Train-to-serve publication).

The paper's premise is real-time incorporation of streaming data into the
*inference* model, so the consensus iterate the superstep loop maintains must
reach a serving replica without stalling either side. `SnapshotPublisher`
implements the bridge:

* **Double-buffered device-resident copies.** `publish` runs a jitted
  extract-and-copy (`a + 0` per leaf) that materializes the served params in
  fresh device buffers, decoupled from the trainer's (donatable) TrainState
  buffers. JAX dispatch is asynchronous: the copy is enqueued and `publish`
  returns without blocking the training thread — the device-to-device copy
  overlaps the next superstep. Two snapshots are live at any time (the
  published one and its predecessor, kept as the back buffer); readers that
  grabbed the old version keep valid buffers for as long as they hold the
  reference — immutability makes torn reads impossible.
* **Atomic version flip.** The published snapshot is swapped under a lock by
  a single reference assignment; `snapshot()` returns a consistent
  `(version, params, superstep, wall)` tuple or the previous one — never a
  mix. Versions are strictly monotone.
* **Publish-rate governor.** Each publish's host-side cost (dispatch wall
  time; the full copy wall time with `block=True`) feeds an EWMA, and a
  publish is skipped whenever `cost_ewma > overhead_budget x (time since the
  last publish)` — so publication overhead on the training loop stays under
  the configured budget no matter how often `maybe_publish` is called. The
  first call always publishes.

The publisher is driven from `train.driver.StreamingDriver` at superstep
boundaries (the plan-latch barrier), outside the governor-timed window.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, NamedTuple, Optional

import jax

Tree = Any


class Snapshot(NamedTuple):
    """One published param version (immutable; safe to read from any thread)."""

    version: int
    params: Tree
    superstep: int  # trainer superstep the params were captured at
    published_at: float  # publisher clock at the flip


@dataclasses.dataclass
class PublisherStats:
    publishes: int = 0
    skipped_budget: int = 0  # governor verdict: cost would exceed the budget
    skipped_interval: int = 0  # below min_interval_s since the last publish
    cost_ewma_s: Optional[float] = None  # smoothed per-publish host cost
    total_cost_s: float = 0.0  # summed measured publish cost


class SnapshotPublisher:
    """Versioned, non-blocking param snapshots from trainer to server.

    `extract` maps the published tree (e.g. a TrainState) to the served
    params; it runs inside the jitted copy, so its cost is billed to the
    publish governor. It may take one auxiliary argument (e.g. a membership
    mask for the consensus mean over the node axis) passed through
    `maybe_publish(..., aux=...)`. Use `configure` to install an extract
    after construction (the driver does this when none was given).
    """

    def __init__(self, *, overhead_budget: float = 0.05,
                 min_interval_s: float = 0.0,
                 extract: Optional[Callable] = None,
                 block: bool = False, alpha: float = 0.5,
                 clock: Callable[[], float] = time.perf_counter):
        if overhead_budget < 0:
            raise ValueError(f"overhead_budget must be >= 0: {overhead_budget}")
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1]: {alpha}")
        self.overhead_budget = overhead_budget
        self.min_interval_s = min_interval_s
        self.block = block
        self.alpha = alpha
        self.clock = clock
        self.stats = PublisherStats()
        self._extract = extract
        self._copy = None  # jitted lazily (extract may be configured later)
        self._lock = threading.Lock()
        self._snapshot: Optional[Snapshot] = None
        self._back: Optional[Snapshot] = None  # double buffer: previous version
        self._version = 0
        self._last_publish_t: Optional[float] = None

    def reset_stats(self, *, keep_ewma: bool = True) -> None:
        """Zero the counters for a fresh measurement window (benchmarks warm
        the jitted copy, then reset so one-time compile cost is not billed to
        the governed run). The cost EWMA is kept by default — it is the
        governor's steady-state estimate."""
        self.stats = PublisherStats(
            cost_ewma_s=self.stats.cost_ewma_s if keep_ewma else None)

    def configure(self, *, extract: Optional[Callable] = None) -> None:
        """Install an extract fn if none was set (idempotent; the driver calls
        this so a bare `SnapshotPublisher()` publishes the consensus params of
        whatever workload it is attached to)."""
        if extract is not None and self._extract is None:
            self._extract = extract
            self._copy = None

    # ------------------------------------------------------------- publishing

    def _copy_fn(self) -> Callable:
        if self._copy is None:
            extract = self._extract

            def copied(tree, *aux):
                out = extract(tree, *aux) if extract is not None else tree
                # force fresh buffers: the published leaves must not alias the
                # trainer's (potentially donated) state
                return jax.tree.map(lambda a: a + 0, out)

            self._copy = jax.jit(copied)
        return self._copy

    def publish(self, tree: Tree, superstep: int, *, aux: Any = None) -> Snapshot:
        """Unconditional publish: dispatch the copy (non-blocking unless
        `block=True`), flip the snapshot atomically, bump the version."""
        t0 = self.clock()
        args = (tree,) if aux is None else (tree, aux)
        params = self._copy_fn()(*args)
        if self.block:
            jax.block_until_ready(params)
        cost = self.clock() - t0
        st = self.stats
        st.total_cost_s += cost
        st.cost_ewma_s = cost if st.cost_ewma_s is None else (
            self.alpha * cost + (1.0 - self.alpha) * st.cost_ewma_s)
        now = self.clock()
        with self._lock:
            self._version += 1
            snap = Snapshot(self._version, params, superstep, now)
            self._back = self._snapshot
            self._snapshot = snap
        self._last_publish_t = now
        st.publishes += 1
        return snap

    def maybe_publish(self, tree: Tree, superstep: int, *,
                      aux: Any = None) -> Optional[Snapshot]:
        """Governed publish: skip when the smoothed publish cost would exceed
        `overhead_budget` as a fraction of the wall time since the last
        publish (or when inside `min_interval_s`). Returns the new Snapshot,
        or None if skipped."""
        if self._last_publish_t is not None:
            elapsed = max(self.clock() - self._last_publish_t, 1e-12)
            if elapsed < self.min_interval_s:
                self.stats.skipped_interval += 1
                return None
            ewma = self.stats.cost_ewma_s
            if (self.overhead_budget > 0 and ewma is not None
                    and ewma > self.overhead_budget * elapsed):
                self.stats.skipped_budget += 1
                return None
        return self.publish(tree, superstep, aux=aux)

    # ------------------------------------------------------------ persistence

    def state_dict(self) -> dict:
        """JSON-serializable continuity state for checkpoint/restore
        (train.snapshot): the version counter and cost EWMA. The snapshot
        buffers themselves are NOT persisted — served params are re-derived
        from the restored TrainState at the next publish; what must survive
        a restart is version monotonicity, so a subscriber that saw version
        v before the crash can never observe a *different* params tree
        labelled <= v after it."""
        st = self.stats
        return {"version": self._version, "cost_ewma_s": st.cost_ewma_s}

    def load_state_dict(self, state: dict) -> None:
        with self._lock:
            if state["version"] < self._version:
                raise ValueError(
                    f"publisher version would move backwards: "
                    f"{self._version} -> {state['version']}")
            self._version = int(state["version"])
        if state.get("cost_ewma_s") is not None:
            self.stats.cost_ewma_s = float(state["cost_ewma_s"])

    # ---------------------------------------------------------------- readers

    def snapshot(self) -> Optional[Snapshot]:
        """The currently published snapshot (None before the first publish).
        Safe from any thread; the returned tuple is immutable."""
        with self._lock:
            return self._snapshot

    @property
    def version(self) -> int:
        """Monotone version counter (0 before the first publish)."""
        with self._lock:
            return self._version

    def staleness(self, live_superstep: int) -> Optional[dict]:
        """How far the published snapshot lags the live iterate:
        `{"supersteps": ..., "wall_s": ...}` (None before the first
        publish). Bounded by the publish cadence: at most the supersteps /
        wall time elapsed since the last publish."""
        snap = self.snapshot()
        if snap is None:
            return None
        return {"supersteps": int(live_superstep) - snap.superstep,
                "wall_s": max(self.clock() - snap.published_at, 0.0)}
