"""Batched serving: prefill + greedy/temperature decode over the sharded KV
cache. `serve_step` is the unit the decode-shape dry-runs lower: ONE new token
against a cache of seq_len."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import registry

Tree = Any


class ServeState(NamedTuple):
    cache: Tree
    last_tokens: jax.Array  # [B, 1]
    index: jax.Array  # scalar int32: number of valid cache positions


def init_serve(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               window_override: int = 0) -> ServeState:
    cache = registry.init_cache(cfg, batch, max_len, dtype,
                                window_override=window_override)
    return ServeState(cache, jnp.zeros((batch, 1), jnp.int32),
                      jnp.zeros((), jnp.int32))


def prefill(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            state: ServeState, *, window_override: int = 0) -> ServeState:
    logits, cache = registry.prefill(params, cfg, batch, state.cache,
                                     window_override=window_override)
    nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    return ServeState(cache, nxt, jnp.asarray(batch["tokens"].shape[1], jnp.int32))


def serve_step(params, cfg: ModelConfig, state: ServeState, *,
               window_override: int = 0, temperature: float = 0.0,
               key: Optional[jax.Array] = None) -> Tuple[ServeState, jax.Array]:
    """Decode ONE token for the whole batch. Returns (state, token [B, 1])."""
    logits, cache = registry.decode_step(params, cfg, state.last_tokens,
                                         state.cache, state.index,
                                         window_override=window_override)
    lf = logits[:, -1].astype(jnp.float32)
    if temperature > 0.0 and key is not None:
        nxt = jax.random.categorical(key, lf / temperature, axis=-1)[:, None]
    else:
        nxt = jnp.argmax(lf, axis=-1)[:, None]
    nxt = nxt.astype(jnp.int32)
    return ServeState(cache, nxt, state.index + 1), nxt


def generate(params, cfg: ModelConfig, prompt: Dict[str, jax.Array], max_len: int,
             steps: int, *, dtype=jnp.bfloat16, window_override: int = 0) -> jax.Array:
    """Simple eager generate loop (examples / tests)."""
    B = prompt["tokens"].shape[0]
    st = init_serve(cfg, B, max_len, dtype, window_override=window_override)
    st = prefill(params, cfg, prompt, st, window_override=window_override)
    toks = [st.last_tokens]
    step = jax.jit(lambda s: serve_step(params, cfg, s,
                                        window_override=window_override))
    for _ in range(steps - 1):
        st, t = step(st)
        toks.append(t)
    return jnp.concatenate(toks, axis=1)
