"""Batched serving: prefill + greedy/temperature decode over the sharded KV
cache. `serve_step` is the unit the decode-shape dry-runs lower: ONE new token
against a cache of seq_len.

`ContinuousBatchingEngine` is the production decode loop on top of the same
model API (docs/DESIGN.md §Train-to-serve publication): a fixed pool of KV
slots, requests admitted (prefill-on-admit) and retired per decode step, and
hot weight swaps between steps — params is a traced argument of the one
compiled decode step, so a newly published version changes neither shapes nor
the executable, and in-flight requests continue bit-exactly on the new
weights with zero loss.
"""
from __future__ import annotations

from collections import deque
from functools import partial
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry

Tree = Any


class ServeState(NamedTuple):
    cache: Tree
    last_tokens: jax.Array  # [B, 1]
    index: jax.Array  # scalar int32: number of valid cache positions


def init_serve(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               window_override: int = 0) -> ServeState:
    cache = registry.init_cache(cfg, batch, max_len, dtype,
                                window_override=window_override)
    return ServeState(cache, jnp.zeros((batch, 1), jnp.int32),
                      jnp.zeros((), jnp.int32))


def prefill(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            state: ServeState, *, window_override: int = 0) -> ServeState:
    logits, cache = registry.prefill(params, cfg, batch, state.cache,
                                     window_override=window_override)
    nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    return ServeState(cache, nxt, jnp.asarray(batch["tokens"].shape[1], jnp.int32))


def serve_step(params, cfg: ModelConfig, state: ServeState, *,
               window_override: int = 0, temperature: float = 0.0,
               key: Optional[jax.Array] = None) -> Tuple[ServeState, jax.Array]:
    """Decode ONE token for the whole batch. Returns (state, token [B, 1])."""
    logits, cache = registry.decode_step(params, cfg, state.last_tokens,
                                         state.cache, state.index,
                                         window_override=window_override)
    lf = logits[:, -1].astype(jnp.float32)
    if temperature > 0.0 and key is not None:
        nxt = jax.random.categorical(key, lf / temperature, axis=-1)[:, None]
    else:
        nxt = jnp.argmax(lf, axis=-1)[:, None]
    nxt = nxt.astype(jnp.int32)
    return ServeState(cache, nxt, state.index + 1), nxt


def generate(params, cfg: ModelConfig, prompt: Dict[str, jax.Array], max_len: int,
             steps: int, *, dtype=jnp.bfloat16, window_override: int = 0) -> jax.Array:
    """Simple eager generate loop (examples / tests)."""
    B = prompt["tokens"].shape[0]
    st = init_serve(cfg, B, max_len, dtype, window_override=window_override)
    st = prefill(params, cfg, prompt, st, window_override=window_override)
    toks = [st.last_tokens]
    step = jax.jit(lambda s: serve_step(params, cfg, s,
                                        window_override=window_override))
    for _ in range(steps - 1):
        st, t = step(st)
        toks.append(t)
    return jnp.concatenate(toks, axis=1)


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------


class Request:
    """Host-side bookkeeping for one in-flight generation request."""

    __slots__ = ("rid", "prompt", "max_new", "tokens", "versions", "slot",
                 "submitted_step", "finished_step")

    def __init__(self, rid: int, prompt: np.ndarray, max_new: int):
        self.rid = rid
        self.prompt = prompt
        self.max_new = max_new
        self.tokens: List[int] = []  # generated token ids
        self.versions: List[int] = []  # param version each token was decoded under
        self.slot: Optional[int] = None
        self.submitted_step: Optional[int] = None
        self.finished_step: Optional[int] = None


class StepEvents(NamedTuple):
    """What one `ContinuousBatchingEngine.step` did."""

    admitted: Tuple[int, ...]  # request ids that entered a slot (prefilled)
    retired: Tuple[int, ...]  # request ids completed this step
    tokens: Dict[int, int]  # rid -> token decoded this step
    version: int  # param version the decode ran under
    active: int  # slots occupied after the step


def _decode_fn(cfg: ModelConfig, window_override: int, params, last, cache,
               index, max_len: int):
    """One batched decode step over all slots; `index` is the per-slot [S]
    position vector. Idle slots decode garbage safely (their row is fully
    overwritten on the next admission) and their index is clamped so a long
    idle stretch can never scatter out of bounds."""
    logits, new_cache = registry.decode_step(params, cfg, last, cache, index,
                                             window_override=window_override)
    nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
    nxt = nxt[:, None].astype(jnp.int32)
    return new_cache, nxt, jnp.minimum(index + 1, max_len - 1)


def _insert_fn(cache, pcache, slot):
    """Scatter a batch=1 prefilled cache into slot `slot` of the pooled
    cache. "layers" leaves are super-block-stacked [n_rep, B, ...] (batch at
    axis 1); "tail" leaves are [B, ...] (axis 0). `slot` is a traced scalar,
    so every slot shares one executable."""

    def put(axis):
        def f(dst, src):
            return jax.lax.dynamic_update_index_in_dim(
                dst, jnp.squeeze(src, axis).astype(dst.dtype), slot, axis)
        return f

    return {"layers": jax.tree.map(put(1), cache["layers"], pcache["layers"]),
            "tail": jax.tree.map(put(0), cache["tail"], pcache["tail"])}


class ContinuousBatchingEngine:
    """Slot-based continuous-batching decode loop with hot weight swaps.

    * A fixed pool of `slots` KV-cache rows; `submit` enqueues a request and
      `step` admits queued requests into free slots (prefill-on-admit: a
      batch=1 prefill compiled per prompt length, its cache row scattered
      into the slot), decodes ONE token for every occupied slot in a single
      batched call, and retires requests that hit `max_new`.
    * `swap_params` installs a newly published param version BETWEEN decode
      steps. The decode step takes params as a traced jit argument, so a
      swap is a host-side reference assignment: zero retrace, zero in-flight
      request loss — slots keep their cache rows and continue under the new
      weights at the next step.
    * Greedy decode only (the benchmark/contract path); recurrent families
      (rglru/ssm) ride the same cache plumbing since their state is
      positionless. Encoder-decoder families are not supported.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 128, dtype=jnp.float32,
                 window_override: int = 0, version: int = 0):
        if cfg.is_encdec:
            raise NotImplementedError(
                "continuous batching is decoder-only; encoder-decoder "
                "families still use the static `generate` path")
        if slots < 1 or max_len < 2:
            raise ValueError(f"bad pool: slots={slots} max_len={max_len}")
        self.cfg = cfg
        self.params = params
        self.version = int(version)
        self.slots = slots
        self.max_len = max_len
        self._dtype = dtype
        self._wo = window_override
        self.cache = registry.init_cache(cfg, slots, max_len, dtype,
                                         window_override=window_override)
        self.index = jnp.zeros((slots,), jnp.int32)
        self.last = jnp.zeros((slots, 1), jnp.int32)
        self._free: List[int] = list(range(slots))[::-1]
        self._active: Dict[int, Request] = {}  # slot -> request
        self._queue: deque = deque()
        self._done: Dict[int, Request] = {}
        self._next_rid = 0
        self.decode_steps = 0
        self.swaps = 0
        self._decode = jax.jit(partial(_decode_fn, cfg, window_override),
                               static_argnames=("max_len",))
        self._insert = jax.jit(_insert_fn)
        self._prefills: Dict[int, Any] = {}  # prompt_len -> jitted prefill

    # ------------------------------------------------------------- interface

    def submit(self, prompt, max_new: int) -> int:
        """Enqueue a generation request. `prompt`: [L] int token ids with
        0 < L, L + max_new <= max_len. Returns the request id."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        if max_new < 1 or prompt.size + max_new > self.max_len:
            raise ValueError(f"prompt_len={prompt.size} + max_new={max_new} "
                             f"exceeds max_len={self.max_len}")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, prompt, max_new)
        req.submitted_step = self.decode_steps
        self._queue.append(req)
        return rid

    def swap_params(self, params, version: Optional[int] = None) -> int:
        """Install new weights between decode steps (never mid-step: `step`
        reads `self.params` exactly once). Versions must be monotone."""
        new_v = self.version + 1 if version is None else int(version)
        if new_v <= self.version:
            raise ValueError(f"non-monotone param version: "
                             f"{self.version} -> {new_v}")
        self.params = params
        self.version = new_v
        self.swaps += 1
        return new_v

    def poll(self, publisher) -> bool:
        """Adopt the publisher's current snapshot if it is newer than the
        engine's installed version. Returns True on a swap."""
        snap = publisher.snapshot()
        if snap is None or snap.version <= self.version:
            return False
        self.swap_params(snap.params, snap.version)
        return True

    @property
    def n_active(self) -> int:
        return len(self._active)

    @property
    def n_queued(self) -> int:
        return len(self._queue)

    def result(self, rid: int) -> Optional[Request]:
        """The completed request (None while queued or in flight)."""
        return self._done.get(rid)

    # ----------------------------------------------------------- decode loop

    def _prefill_fn(self, L: int):
        fn = self._prefills.get(L)
        if fn is None:
            cfg, wo, dtype, max_len = self.cfg, self._wo, self._dtype, self.max_len

            def f(params, tokens):
                c = registry.init_cache(cfg, 1, max_len, dtype,
                                        window_override=wo)
                logits, cache = registry.prefill(params, cfg,
                                                 {"tokens": tokens}, c,
                                                 window_override=wo)
                nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
                return cache, nxt.astype(jnp.int32)

            fn = jax.jit(f)
            self._prefills[L] = fn
        return fn

    def _admit(self) -> List[int]:
        admitted = []
        while self._free and self._queue:
            req = self._queue.popleft()
            slot = self._free.pop()
            L = int(req.prompt.size)
            pcache, nxt = self._prefill_fn(L)(self.params,
                                              jnp.asarray(req.prompt)[None])
            self.cache = self._insert(self.cache, pcache,
                                      jnp.asarray(slot, jnp.int32))
            self.index = self.index.at[slot].set(L)
            self.last = self.last.at[slot].set(nxt)
            req.slot = slot
            # prefill emits the first generated token
            req.tokens.append(int(nxt[0]))
            req.versions.append(self.version)
            self._active[slot] = req
            admitted.append(req.rid)
        return admitted

    def _retire(self) -> List[int]:
        retired = []
        for slot, req in list(self._active.items()):
            if len(req.tokens) >= req.max_new:
                req.finished_step = self.decode_steps
                req.slot = None
                self._done[req.rid] = req
                del self._active[slot]
                self._free.append(slot)
                # park the freed slot at position 0; its row is garbage until
                # the next admission fully overwrites it
                self.index = self.index.at[slot].set(0)
                self.last = self.last.at[slot].set(0)
                retired.append(req.rid)
        return retired

    def step(self) -> StepEvents:
        """One engine iteration: retire finished requests, admit from the
        queue, then decode one token for every occupied slot (a single
        batched call under the currently installed params)."""
        retired = self._retire()
        admitted = self._admit()
        # a request whose max_new == 1 completes on its prefill token
        retired += self._retire()
        toks: Dict[int, int] = {}
        if self._active:
            self.cache, self.last, self.index = self._decode(
                self.params, self.last, self.cache, self.index,
                max_len=self.max_len)
            self.decode_steps += 1
            out = np.asarray(self.last)  # the per-step host sync point
            for slot, req in self._active.items():
                tok = int(out[slot, 0])
                req.tokens.append(tok)
                req.versions.append(self.version)
                toks[req.rid] = tok
        return StepEvents(tuple(admitted), tuple(retired), toks,
                          self.version, len(self._active))

    def drain(self, max_steps: int = 10_000) -> None:
        """Step until queue and slots are empty (tests / end-of-benchmark)."""
        for _ in range(max_steps):
            if not self._active and not self._queue:
                return
            self.step()
        raise RuntimeError("drain did not converge")
