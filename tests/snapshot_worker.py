"""Subprocess target for the kill-and-resume regression suite
(tests/test_snapshot.py). Runs the elastic krasulina driver — the same
config as tests/test_elastic.py's `_elastic_driver` — on a deterministic
fake clock with per-superstep blocking snapshots, so the parent can SIGKILL
it at a known point and a resumed process must reproduce the uninterrupted
trajectory bit-for-bit.

Usage:
  python tests/snapshot_worker.py --root DIR --supersteps N [--resume]
      [--out FILE.npz] [--faults SPEC] [--cache-dir DIR]

Env knobs (victim-only torture):
  SNAPSHOT_SLOW_AFTER_STEP=K   sleep SNAPSHOT_SLOW_WRITE_S (default 120)
                               after the first leaf write of any save with
                               step >= K, so a SIGKILL lands mid-save and
                               leaves that step directory torn.
"""
from __future__ import annotations

import argparse
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", required=True)
    ap.add_argument("--supersteps", type=int, required=True)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--out", default="")
    ap.add_argument("--faults", default="death:4@2-5")
    ap.add_argument("--no-snapshots", action="store_true",
                    help="uninterrupted reference run: no snapshotter at all")
    ap.add_argument("--cache-dir", default="")
    args = ap.parse_args()

    if args.cache_dir:
        # must land before the jax import below
        from repro.launch import env as _env

        os.environ.update(_env.compilation_cache_env(args.cache_dir))

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import AveragingConfig, GovernorConfig
    from repro.configs.paper_pca import FIG7, PCARunConfig
    from repro.core import krasulina
    from repro.core.faults import FaultSchedule
    from repro.data.synthetic import make_pca_host_sampler, make_pca_stream
    from repro.train import checkpoint
    from repro.train.driver import EngineConfig, StreamingDriver
    from repro.train.snapshot import RunSnapshotter

    slow_after = os.environ.get("SNAPSHOT_SLOW_AFTER_STEP")
    if slow_after is not None:
        _arm_slow_save(checkpoint, int(slow_after),
                       float(os.environ.get("SNAPSHOT_SLOW_WRITE_S", "120")))

    class FakeClock:
        def __init__(self, dt):
            self.t, self.dt = 0.0, dt

        def __call__(self):
            self.t += self.dt
            return self.t

    n, batch = 5, 10
    run_cfg = PCARunConfig(
        pca=FIG7, averaging=AveragingConfig(mode="gossip", rounds=2))
    builder = krasulina.krasulina_superstep_builder(
        run_cfg.averaging, n, lambda t: 10.0 / t)
    w0 = jax.random.normal(jax.random.PRNGKey(0), (FIG7.dim,))
    state = krasulina.init_krasulina_state(w0 / jnp.linalg.norm(w0),
                                           run_cfg.averaging, n)
    faults = FaultSchedule.parse(args.faults, n) if args.faults else None

    clock = FakeClock(1e-3)
    resume_from = None
    if args.resume:
        # the driver reads the clock exactly twice per superstep: advance the
        # fake clock to where the uninterrupted run's clock stood at the
        # checkpoint, so governed timings replay identically
        path = checkpoint.newest_valid(args.root)
        if path is None:
            print("RESUME-FAILED: no valid checkpoint", flush=True)
            sys.exit(3)
        done = int(checkpoint.load_manifest(path)["meta"]["supersteps_done"])
        for _ in range(2 * done):
            clock()
        resume_from = args.root

    snapshotter = None
    if not args.no_snapshots:
        # block=True: a printed "CKPT k" line means that step is DURABLE, so
        # the parent's kill point is well-defined
        snapshotter = RunSnapshotter(args.root, every=1, keep_last=100,
                                     overhead_budget=0, block=True)

    driver = StreamingDriver(
        run_cfg, None, state, make_pca_host_sampler(make_pca_stream(FIG7)),
        superstep_builder=builder, n_nodes=n, batch=batch, faults=faults,
        engine=EngineConfig(superstep=2, prefetch_depth=0, replan_every=1,
                            warmup_supersteps=0, warmup_per_bucket=0,
                            governor=GovernorConfig()),
        clock=clock, snapshotter=snapshotter, resume_from=resume_from)
    start = driver._supersteps_done
    print(f"START {start}", flush=True)

    def log(rec):
        ck = rec.get("checkpoint")
        if ck is not None:
            print(f"CKPT {ck}", flush=True)

    with driver:
        driver.run(args.supersteps - start, log_fn=log)

    if args.out:
        leaves = checkpoint._flatten(driver.state)
        arrs = {f"state::{k}": np.asarray(v) for k, v in leaves.items()}
        arrs["eras"] = np.array([(r["bucket"], r["n_active"])
                                 for r in driver.history])
        arrs["counters"] = np.array(driver.history[-1]["counters"])
        arrs["resumed_at"] = np.array(start)
        np.savez(args.out, **arrs)
    if args.cache_dir:
        n_cache = len([f for f in os.listdir(args.cache_dir)
                       if f.endswith("-cache")])
        print(f"CACHE-ENTRIES {n_cache}", flush=True)
    print("DONE", flush=True)


def _arm_slow_save(checkpoint, after_step: int, sleep_s: float) -> None:
    """Make every save with step >= `after_step` hang after its first leaf
    write, so a SIGKILL during the hang leaves a torn step directory (leaves
    present, no manifest)."""
    import time

    orig = checkpoint._save_leaf
    hung_steps = set()

    def slow(path, arr, **kw):
        orig(path, arr, **kw)
        step_dir = os.path.basename(os.path.dirname(path))
        if step_dir.startswith("step_"):
            step = int(step_dir[len("step_"):])
            if step >= after_step and step not in hung_steps:
                hung_steps.add(step)
                print(f"SLOW-SAVE {step}", flush=True)
                time.sleep(sleep_s)

    checkpoint._save_leaf = slow


if __name__ == "__main__":
    main()
