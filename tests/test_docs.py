"""Tier-1 docs checks: the documentation system must not rot.

* every relative markdown link in README.md, ROADMAP.md, and docs/*.md
  resolves to an existing file, and every `#anchor` fragment matches a
  heading in the target (GitHub slug rules)
* every repo path cited in backticks in those files exists (absolute from
  the repo root, or `src/repro/`-relative for module shorthand like
  `core/mixing.py`)
* every `docs/DESIGN.md §section` citation in the source tree points at a
  real section heading, and no stale bare `DESIGN.md` reference (pointing
  anywhere but docs/DESIGN.md) survives a move
"""
import glob
import os
import re

HERE = os.path.dirname(__file__)
ROOT = os.path.abspath(os.path.join(HERE, ".."))

MD_FILES = sorted(
    [os.path.join(ROOT, "README.md"), os.path.join(ROOT, "ROADMAP.md")]
    + glob.glob(os.path.join(ROOT, "docs", "*.md")))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*?)\s*$", re.MULTILINE)
BACKTICK_RE = re.compile(r"`([^`\n]+)`")
SECTION_REF_RE = re.compile(r"docs/DESIGN\.md\s+§([A-Za-z0-9_&\- ]+)")
DESIGN_MENTION_RE = re.compile(r"[\w./-]*DESIGN\.md")


def _read(path):
    with open(path, encoding="utf-8") as f:
        return f.read()


def _strip_code_blocks(text):
    """Fenced code blocks are illustrative, not link/citation surface."""
    return re.sub(r"```.*?```", "", text, flags=re.DOTALL)


def _slug(heading):
    """GitHub's anchor slug: lowercase, drop punctuation, spaces->hyphens."""
    h = re.sub(r"[^\w\- ]", "", heading.lower())
    return h.replace(" ", "-")


def _headings(md_path):
    return [m.group(1) for m in HEADING_RE.finditer(_read(md_path))]


def test_docs_exist():
    for p in MD_FILES:
        assert os.path.isfile(p), f"missing doc: {p}"
    assert any(p.endswith("DESIGN.md") for p in MD_FILES)


def test_relative_links_and_anchors_resolve():
    problems = []
    for md in MD_FILES:
        base = os.path.dirname(md)
        for target in LINK_RE.findall(_strip_code_blocks(_read(md))):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path, _, frag = target.partition("#")
            dest = md if not path else os.path.normpath(
                os.path.join(base, path))
            if not os.path.exists(dest):
                problems.append(f"{os.path.relpath(md, ROOT)}: dead link "
                                f"{target!r} -> {os.path.relpath(dest, ROOT)}")
                continue
            if frag:
                if not dest.endswith(".md"):
                    problems.append(f"{os.path.relpath(md, ROOT)}: fragment "
                                    f"on non-markdown target {target!r}")
                    continue
                slugs = {_slug(h) for h in _headings(dest)}
                if frag not in slugs:
                    problems.append(
                        f"{os.path.relpath(md, ROOT)}: anchor {target!r} not "
                        f"among headings of {os.path.relpath(dest, ROOT)}: "
                        f"{sorted(slugs)}")
    assert not problems, "\n".join(problems)


def _cited_path_candidates(text):
    """Backticked tokens that claim to be repo paths."""
    for tok in BACKTICK_RE.findall(text):
        tok = tok.split()[0].split(":")[0].rstrip(".,;")
        if not tok or "*" in tok or tok.startswith(("-", "--", "/")):
            continue
        top = tok.split("/")[0]
        rooted = top in ("src", "docs", "benchmarks", "examples", "tests")
        # bare `a/b/` tokens are row-name prefixes etc., not paths — only
        # file-extension tokens (or tokens rooted at a repo dir) are claims
        pathlike = "/" in tok and tok.endswith((".py", ".md", ".json"))
        if rooted or pathlike:
            yield tok


def test_cited_repo_paths_exist():
    problems = []
    for md in MD_FILES:
        for tok in _cited_path_candidates(_strip_code_blocks(_read(md))):
            cands = [os.path.join(ROOT, tok),
                     os.path.join(ROOT, "src", "repro", tok)]
            if not any(os.path.exists(c) for c in cands):
                problems.append(f"{os.path.relpath(md, ROOT)}: cited path "
                                f"`{tok}` does not exist")
    assert not problems, "\n".join(problems)


def _source_files():
    out = []
    for pat in ("src/**/*.py", "benchmarks/*.py", "examples/*.py",
                "tests/*.py"):
        out.extend(glob.glob(os.path.join(ROOT, pat), recursive=True))
    return sorted(out)


def test_design_md_citations_point_at_real_sections():
    design = os.path.join(ROOT, "docs", "DESIGN.md")
    slugs = {_slug(h) for h in _headings(design)}
    problems = []
    cited = 0
    for src in _source_files():
        if os.path.abspath(src) == os.path.abspath(__file__):
            continue  # this file's docstring describes the citation format
        text = _read(src)
        for m in SECTION_REF_RE.finditer(text):
            cited += 1
            section = m.group(1).strip()
            if _slug(section) not in slugs:
                problems.append(f"{os.path.relpath(src, ROOT)}: cites "
                                f"docs/DESIGN.md §{section} but DESIGN.md has "
                                f"no such heading")
    assert cited >= 4, "expected the four known §-citations to be present"
    assert not problems, "\n".join(problems)


def test_no_stale_design_md_references():
    """Every DESIGN.md mention in the source tree must use the real path —
    a bare `DESIGN.md` (the pre-docs-system spelling) is a dead pointer."""
    problems = []
    for src in _source_files():
        if os.path.abspath(src) == os.path.abspath(__file__):
            continue
        for m in DESIGN_MENTION_RE.finditer(_read(src)):
            if m.group(0) != "docs/DESIGN.md":
                problems.append(
                    f"{os.path.relpath(src, ROOT)}: stale reference "
                    f"{m.group(0)!r} (use docs/DESIGN.md)")
    assert not problems, "\n".join(problems)
