"""Adaptive-B governor regressions (docs/DESIGN.md §Adaptive batch buckets):

* `BucketLadder` construction/snapping, ladder-aware `checked_plan_swap`
* the online least-squares `(R_p, R_c)` estimator recovering a synthetic
  ground-truth comm model (acceptance: R_c within 20%)
* ladder-aware `replan`: downshift when measurement shows the stream is easy,
  upshift to the top of the ladder when nothing keeps up
* fake-clock driver regressions: B downshift / upshift, hysteresis against
  jittery timings, per-jit-signature warm-up gating, and — on both the
  LM-trainer and Krasulina supersteps — a steady-state bucket switch with
  ZERO recompilation (the pre-compiled bucket is reused; the switch is a
  plan swap only)
* prefetch-ring counter coherence across a mid-stream bucket switch (no
  sample loss or duplication; every staged superstep knows the plan that
  dealt it)
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import (AveragingConfig, GovernorConfig, RunConfig,
                                SHAPES, StreamConfig)
from repro.core import krasulina, rates
from repro.data.lm import MarkovTokenStream
from repro.data.pipeline import DevicePrefetcher, StreamingPipeline
from repro.data.synthetic import make_pca_host_sampler, make_pca_stream
from repro.configs.paper_pca import FIG7, PCARunConfig
from repro.launch.mesh import make_mesh
from repro.launch.sharding import activation_rules
from repro.models.common import mesh_rules
from repro.train.driver import EngineConfig, StreamingDriver
from repro.train.trainer import build_superstep, init_state
from _trace import wrap_builder

SEQ = 16
BATCH = 8


# ---------------------------------------------------------------------------
# BucketLadder + checked_plan_swap
# ---------------------------------------------------------------------------

def test_bucket_ladder_build_geometric_multiples_of_N():
    lad = rates.BucketLadder.build(64, 4, n_buckets=4, factor=2)
    assert lad.buckets == (32, 64, 128, 256)  # one below base, two above
    assert all(b % 4 == 0 for b in lad.buckets)
    # non-multiple candidates are rounded UP to a multiple of N
    lad = rates.BucketLadder.build(10, 4, n_buckets=2)
    assert lad.buckets == (12, 20)


def test_bucket_ladder_horizon_ceiling_thm4():
    # sqrt(1e4) = 100: every bucket is clipped to the Theorem-4 ceiling
    lad = rates.BucketLadder.build(64, 4, n_buckets=4, factor=4,
                                   horizon_samples=1e4)
    assert max(lad.buckets) <= 100
    assert lad.buckets[0] == 16  # 64/4, untouched by the ceiling


def test_bucket_ladder_from_buckets_normalizes():
    lad = rates.BucketLadder.from_buckets((6, 8, 30), 4)
    assert lad.buckets == (8, 32)  # rounded up to multiples of N, deduped
    # candidates above the Thm-4 ceiling collapse ONTO it (sqrt(1e4) = 100),
    # so a plan at a registered bucket can never be horizon-clipped to an
    # unregistered value
    lad = rates.BucketLadder.from_buckets((16, 128, 256), 4,
                                          horizon_samples=1e4)
    assert lad.buckets == (16, 100)
    lad = rates.BucketLadder.from_buckets((128,), 4, horizon_samples=1e4)
    assert lad.buckets == (100,)


def test_driver_explicit_buckets_above_horizon_ceiling_dont_crash():
    """Regression: an explicit ladder whose buckets all exceed the Theorem-4
    ceiling used to keep an unregistered-after-clipping bucket, and the first
    warm re-plan crashed in checked_plan_swap. The ladder must collapse onto
    the ceiling bucket and the governed run proceed."""
    stream = StreamConfig(streaming_rate=1e3, processing_rate=1e6,
                          comms_rate=1e6)
    run_cfg = _run_cfg(stream=stream)
    mesh = make_mesh((1, 1), ("data", "model"))
    with mesh_rules(mesh, activation_rules(mesh, run_cfg.shape)):
        state = init_state(run_cfg, jax.random.PRNGKey(0))
        driver = StreamingDriver(
            run_cfg, mesh, state, _sample_fn(), batch=16, horizon=100.0,
            engine=EngineConfig(superstep=2, prefetch_depth=0, replan_every=1,
                                warmup_supersteps=0,
                                governor=GovernorConfig(buckets=(16, 32))),
            clock=_FakeClock(50.0))
        # sqrt(100) = 10: both requested buckets exceed the ceiling, so the
        # ladder is the ceiling itself and the plan snapped onto it
        assert driver.ladder.buckets == (10,)
        assert driver.pipeline.plan.B == 10
        driver.run(3)  # re-plans under a slow clock: must not raise
        assert driver.pipeline.plan.mu > 0


def test_bucket_ladder_snap():
    lad = rates.BucketLadder((8, 16, 32))
    assert lad.snap(1) == 8
    assert lad.snap(16) == 16
    assert lad.snap(17) == 32
    assert lad.snap(1000) == 32  # above the ladder: the largest bucket
    assert 16 in lad and 12 not in lad


def test_bucket_ladder_rejects_malformed():
    with pytest.raises(ValueError):
        rates.BucketLadder(())
    with pytest.raises(ValueError):
        rates.BucketLadder((16, 8))  # not ascending


def test_checked_plan_swap_bucket_aware():
    lad = rates.BucketLadder((8, 16))
    cur = rates.Plan(B=8, mu=0, R=1, Re=1.0, regime="resourceful")
    ok = dataclasses.replace(cur, B=16)
    assert rates.checked_plan_swap(cur, ok, lad).B == 16
    # an unregistered B is rejected, and the error lists the ladder
    with pytest.raises(ValueError, match=r"registered buckets: \[8, 16\]"):
        rates.checked_plan_swap(cur, dataclasses.replace(cur, B=12), lad)
    # no ladder: the pre-ladder pinned-B contract
    with pytest.raises(ValueError, match="keep B fixed"):
        rates.checked_plan_swap(cur, ok)
    # a single-bucket ladder degenerates to pinned B (exact-mode default)
    one = rates.BucketLadder((8,))
    assert rates.checked_plan_swap(cur, dataclasses.replace(cur, mu=3), one).mu == 3
    with pytest.raises(ValueError, match="registered buckets"):
        rates.checked_plan_swap(cur, ok, one)


# ---------------------------------------------------------------------------
# Online (R_p, R_c) estimator
# ---------------------------------------------------------------------------

def test_estimator_recovers_synthetic_comm_model():
    """Acceptance: round times drawn from eq. 4's ground truth at several
    buckets (plus noise) must put the fitted R_c within 20% of truth."""
    N, R, Rp, Rc = 4, 8, 1e5, 2e3
    est = rates.RoundTimeEstimator(N, R, window=64)
    rng = np.random.default_rng(0)
    for _ in range(6):
        for B in (32, 64, 128, 256):
            truth = B / (N * Rp) + R / Rc
            est.observe(B, truth * (1.0 + rng.normal() * 0.02))
    got = est.estimate()
    assert got is not None
    assert got.Rp == pytest.approx(Rp, rel=0.2)
    assert got.Rc == pytest.approx(Rc, rel=0.2)


def test_estimator_unidentifiable_at_single_bucket():
    est = rates.RoundTimeEstimator(2, 1)
    for _ in range(10):
        est.observe(64, 0.5)
    assert est.estimate() is None  # slope/intercept not separable
    # B-independent times (pure comm / fake clock): zero slope -> no estimate
    est = rates.RoundTimeEstimator(2, 1)
    for B in (32, 64, 128):
        est.observe(B, 0.5)
    assert est.estimate() is None


def test_estimator_no_comm_intercept_means_rc_zero():
    N, Rp = 2, 1e4
    est = rates.RoundTimeEstimator(N, 4)
    for B in (16, 32, 64):
        est.observe(B, B / (N * Rp))  # pure compute, zero intercept
    got = est.estimate()
    assert got is not None and got.Rc == 0.0
    assert got.Rp == pytest.approx(Rp, rel=1e-6)


def test_estimator_window_tracks_current_rates():
    """Old observations age out, so the fit follows a slowdown."""
    N, R = 2, 1
    est = rates.RoundTimeEstimator(N, R, window=8)
    for B in (16, 32, 64, 16, 32, 64, 16, 32):
        est.observe(B, B / (N * 1e5) + 1e-3)  # fast era
    for B in (16, 32, 64, 16, 32, 64, 16, 32):
        est.observe(B, B / (N * 1e3) + 1e-3)  # slow era fills the window
    got = est.estimate()
    assert got.Rp == pytest.approx(1e3, rel=1e-6)


def test_replan_with_estimate_overrides_comms_heuristic():
    """The fitted comm model replaces the binary comm-floor-disproof
    heuristic: a wall time UNDER the (wrong) config comm floor used to zero
    the comm term; the estimator's R_c is trusted instead."""
    stream = StreamConfig(streaming_rate=1e3, processing_rate=1e6,
                          comms_rate=1e2)  # config claims 10ms/round comms
    est = rates.RateEstimate(Rp=1e5, Rc=1e4)  # measured: 0.1ms/round
    got = rates.replan(stream, 2, 1, 8, wall_s_per_round=2e-3, estimate=est)
    # plan must be computed from the ESTIMATED rates, not config / heuristic
    assert got.Re == pytest.approx(
        rates.effective_rate(8, 2, 1, 1e5, 1e4), rel=1e-9)


# ---------------------------------------------------------------------------
# Ladder-aware replan
# ---------------------------------------------------------------------------

def test_replan_ladder_downshift_when_stream_is_easy():
    """Measurement shows the hardware keeps up easily -> the plan drops to
    the smallest keep-up bucket (Theorem 4 prefers small B)."""
    stream = StreamConfig(streaming_rate=1e3, processing_rate=1e4,
                          comms_rate=1e6)
    lad = rates.BucketLadder((8, 16, 32, 64))
    got = rates.replan(stream, 2, 1, 32, wall_s_per_round=1e-4, ladder=lad)
    assert got.B == 8 and got.mu == 0


def test_replan_ladder_upshift_when_comm_bound():
    """A comm-heavy estimate forces the keep-up minimum B upward: the plan
    moves to the smallest bucket that satisfies eq. 4's keep-up condition."""
    stream = StreamConfig(streaming_rate=1e3, processing_rate=1e6,
                          comms_rate=1e6)
    lad = rates.BucketLadder((8, 16, 32, 64))
    est = rates.RateEstimate(Rp=1e6, Rc=50.0)  # 20ms comms per round
    got = rates.replan(stream, 2, 1, 8, wall_s_per_round=0.03, ladder=lad,
                       estimate=est)
    # B_min = Rs * (R/Rc) / (1 - Rs/(N*Rp)) ~ 20 -> bucket 32
    assert got.B == 32 and got.mu == 0


def test_replan_ladder_infeasible_takes_largest_bucket():
    """When the stream outruns total compute no B keeps up; B*R_e is
    increasing in B, so the top of the ladder minimizes the discard rate."""
    stream = StreamConfig(streaming_rate=1e3, processing_rate=1e6,
                          comms_rate=1e6)
    lad = rates.BucketLadder((8, 16, 32, 64))
    got = rates.replan(stream, 2, 1, 16, wall_s_per_round=10.0, ladder=lad)
    assert got.B == 64
    assert got.mu > 0 and got.regime == "under-provisioned"


def test_replan_handbuilt_ladder_above_ceiling_holds_registered_bucket():
    """Regression: a hand-built ladder with NO bucket under the Theorem-4
    ceiling used to let the horizon clip produce an unregistered B that
    `checked_plan_swap` rejects mid-run; replan must hold the nearest
    registered bucket instead."""
    stream = StreamConfig(streaming_rate=1e3, processing_rate=1e6,
                          comms_rate=1e6)
    lad = rates.BucketLadder((64, 128))  # ceiling for horizon=100 is 8
    got = rates.replan(stream, 4, 1, 64, wall_s_per_round=1e-2, ladder=lad,
                       horizon_samples=100.0)
    assert got.B in lad


def test_replan_single_bucket_ladder_pins_B():
    stream = StreamConfig(streaming_rate=1e3, processing_rate=1e6,
                          comms_rate=1e6)
    lad = rates.BucketLadder((16,))
    got = rates.replan(stream, 2, 1, 16, wall_s_per_round=10.0, ladder=lad)
    assert got.B == 16 and got.mu > 0  # identical to the pre-ladder replan


def test_bucket_hysteresis_debounces():
    h = rates.BucketHysteresis(patience=2)
    assert h.step(8, 16) == 8     # first proposal: pending
    assert h.step(8, 16) == 16    # second consecutive: confirmed
    assert h.step(8, 16) == 8     # state was reset by the switch
    assert h.step(8, 32) == 8     # a different target restarts the streak
    assert h.step(8, 16) == 8
    assert h.step(8, 8) == 8      # agreeing with current resets pending
    assert h.step(8, 16) == 8     # ...so one more 16 is NOT enough
    assert h.step(8, 16) == 16


# ---------------------------------------------------------------------------
# Pipeline / prefetch ring across a mid-stream bucket switch
# ---------------------------------------------------------------------------

def _xy_pipe(ladder, batch=8, mu=3, seed=7):
    return StreamingPipeline(
        lambda rng, n: {"x": rng.normal(size=(n, 2))},
        StreamConfig(forced_mu=mu), n_nodes=2, rounds_R=1, batch=batch,
        ladder=ladder, seed=seed)


def test_pipeline_bucket_switch_mid_stream():
    lad = rates.BucketLadder((8, 16))
    pipe = _xy_pipe(lad)
    a = pipe.next_superstep(2)
    assert a["x"].shape == (2, 8, 2)
    pipe.update_plan(dataclasses.replace(pipe.plan, B=16))
    b = pipe.next_superstep(2)
    assert b["x"].shape == (2, 16, 2)  # re-dealt at the new width
    assert pipe.last_superstep_plan.B == 16
    # counters account every sample across the switch: 2*(8+3) + 2*(16+3)
    c = pipe.counters()
    assert c.samples_arrived == 22 + 38
    assert c.samples_consumed == 16 + 32
    assert c.samples_discarded == 2 * 3 + 2 * 3
    with pytest.raises(ValueError, match="registered buckets"):
        pipe.update_plan(dataclasses.replace(pipe.plan, B=12))


def test_pipeline_adopt_ladder_snaps_unregistered_plan():
    pipe = StreamingPipeline(lambda rng, n: {"x": rng.normal(size=(n, 2))},
                             StreamConfig(), 2, 1, batch=10)
    pipe.adopt_ladder(rates.BucketLadder((8, 16)))
    assert pipe.plan.B == 16  # snapped up to the nearest keep-up bucket


def test_prefetch_counters_coherent_across_bucket_switch():
    """Every staged superstep carries the plan that dealt it, and successive
    counter snapshots account for exactly that plan's samples — no loss, no
    duplication, even while the ring drains old-width items."""
    lad = rates.BucketLadder((8, 16))
    pipe = _xy_pipe(lad)
    K, n_steps = 2, 8
    pf = DevicePrefetcher(lambda: pipe.next_superstep(K),
                          counters=pipe.counters,
                          meta=lambda: pipe.last_superstep_plan, depth=2)
    consumed = []
    with pf:
        for i in range(n_steps):
            batch = next(pf)
            consumed.append((batch, pf.counters, pf.meta))
            if i == 2:  # switch mid-stream, ring still holds B=8 items
                pipe.update_plan(dataclasses.replace(pipe.plan, B=16, mu=3))
    # the switch eventually lands; items before it keep their old width
    widths = [b["x"].shape[1] for b, _, _ in consumed]
    assert widths[0] == 8 and widths[-1] == 16
    assert widths == sorted(widths)  # monotone: old-width items drain first
    prev_arr = prev_con = 0
    for batch, counters, plan in consumed:
        assert batch["x"].shape == (K, plan.B, 2)  # meta matches the batch
        # each snapshot advances by exactly this superstep's samples
        assert counters.samples_arrived - prev_arr == K * (plan.B + plan.mu)
        assert counters.samples_consumed - prev_con == K * plan.B
        prev_arr, prev_con = counters.samples_arrived, counters.samples_consumed


# ---------------------------------------------------------------------------
# Fake-clock driver regressions
# ---------------------------------------------------------------------------

class _FakeClock:
    """Monotonic clock that jumps `dt` seconds per reading."""

    def __init__(self, dt):
        self.t, self.dt = 0.0, dt

    def __call__(self):
        self.t += self.dt
        return self.t


class _JitteryClock:
    """Alternates between a fast and a slow dt per timed superstep (two
    readings each), emulating scheduler jitter."""

    def __init__(self, dts):
        self.t, self.dts, self.reads = 0.0, dts, 0

    def __call__(self):
        self.t += self.dts[(self.reads // 2) % len(self.dts)]
        self.reads += 1
        return self.t


def _run_cfg(mode="exact", rounds=1, stream=StreamConfig()):
    cfg = dataclasses.replace(
        reduced(get_config("granite-8b"), layers=1, d_model=16),
        vocab_size=32, d_ff=32)
    return RunConfig(model=cfg, shape=SHAPES["train_4k"],
                     averaging=AveragingConfig(mode, rounds), stream=stream,
                     optimizer="adam", learning_rate=1e-3,
                     param_dtype="float32", remat=False)


def _sample_fn(vocab=32, seed=0):
    data = MarkovTokenStream(vocab, seed=seed)

    def draw(rng, n):
        toks = data.sample(rng, n, SEQ + 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    return draw


def _lm_driver(stream, clock, gov, *, batch=BATCH, warmup=0, per_bucket=0,
               prefetch=0, trace_log=None):
    run_cfg = _run_cfg(stream=stream)
    mesh = make_mesh((1, 1), ("data", "model"))
    ctx = mesh_rules(mesh, activation_rules(mesh, run_cfg.shape))
    ctx.__enter__()
    state = init_state(run_cfg, jax.random.PRNGKey(0))
    builder = None
    if trace_log is not None:
        base, _ = build_superstep(run_cfg, mesh)
        builder = wrap_builder(lambda B: base, trace_log)

    driver = StreamingDriver(
        run_cfg, mesh, state, _sample_fn(), batch=batch,
        superstep_builder=builder,
        engine=EngineConfig(superstep=2, prefetch_depth=prefetch,
                            replan_every=1, warmup_supersteps=warmup,
                            warmup_per_bucket=per_bucket, governor=gov),
        clock=clock)
    return driver, ctx


def test_driver_downshifts_B_when_fast():
    """A fast clock proves the hardware keeps up easily: the governor walks B
    down the ladder (Theorem 4 prefers the smallest keep-up B)."""
    stream = StreamConfig(streaming_rate=1e3, processing_rate=1e6,
                          comms_rate=1e6)
    gov = GovernorConfig(buckets=(4, 8, 16), hysteresis=2)
    driver, ctx = _lm_driver(stream, _FakeClock(1e-4), gov, batch=16)
    try:
        assert driver.pipeline.plan.B == 16
        driver.run(6)
        assert driver.pipeline.plan.B == 4
        assert driver.pipeline.plan.mu == 0
        switches = [r["bucket_switch"] for r in driver.history
                    if "bucket_switch" in r]
        assert switches and switches[0][0] == 16
    finally:
        ctx.__exit__(None, None, None)


def test_driver_upshifts_B_when_slow_and_applies_hysteresis():
    """A slow clock puts the run under-provisioned: the governor moves to the
    TOP bucket (B*R_e is increasing in B, so the largest bucket minimizes the
    discard rate) — but only after `hysteresis` consecutive agreeing
    re-plans."""
    stream = StreamConfig(streaming_rate=1e3, processing_rate=1e6,
                          comms_rate=1e6)
    gov = GovernorConfig(buckets=(8, 16), hysteresis=3)
    driver, ctx = _lm_driver(stream, _FakeClock(50.0), gov)
    try:
        driver.run(6)
        hist = driver.history
        # proposals start at superstep 0, so with patience 3 the switch lands
        # exactly at the third agreeing re-plan, not before
        assert all("bucket_switch" not in r for r in hist[:2])
        assert "bucket_switch" in hist[2]
        assert driver.pipeline.plan.B == 16
        assert driver.pipeline.plan.regime == "under-provisioned"
        assert driver.pipeline.plan.mu > 0
    finally:
        ctx.__exit__(None, None, None)


def test_driver_hysteresis_resists_jittery_timings():
    """Timings that flip between keep-up-easily and drowning every superstep
    must not thrash the ladder: no proposal streak ever reaches patience."""
    stream = StreamConfig(streaming_rate=1e3, processing_rate=1e6,
                          comms_rate=1e6)
    gov = GovernorConfig(buckets=(4, 8, 16), hysteresis=2,
                         estimate_rates=False)
    driver, ctx = _lm_driver(stream, _JitteryClock((1e-4, 50.0)), gov)
    try:
        driver.run(8)
        assert all("bucket_switch" not in r for r in driver.history)
        assert driver.pipeline.plan.B == 8  # never moved
    finally:
        ctx.__exit__(None, None, None)


def test_driver_steady_state_switch_zero_recompilation_lm():
    """Acceptance: once both buckets are compiled, switching between them is
    a plan swap only — the pre-compiled superstep is reused, zero retrace."""
    stream = StreamConfig(streaming_rate=1e3, processing_rate=1e6,
                          comms_rate=1e6)
    gov = GovernorConfig(buckets=(8, 16), hysteresis=1, estimate_rates=False)
    traces = []
    # dt flips slow/fast every 4 supersteps -> the governor oscillates B
    class _Phases:
        def __init__(self):
            self.t, self.reads = 0.0, 0

        def __call__(self):
            self.t += 50.0 if (self.reads // 8) % 2 == 0 else 1e-4
            self.reads += 1
            return self.t

    driver, ctx = _lm_driver(stream, _Phases(), gov, trace_log=traces)
    try:
        driver.run(16)
        switches = [r for r in driver.history if "bucket_switch" in r]
        assert len(switches) >= 2  # at least one full down-and-back cycle
        assert driver.compiled_buckets == (8, 16)
        # zero recompilation in steady state: one trace per (bucket,
        # signature), nothing more — revisits hit the jit cache
        assert sorted(set(traces)) == [8, 16]
        assert len(traces) <= len(set(traces)) + 1  # +1: committed-state sig
    finally:
        ctx.__exit__(None, None, None)


def test_driver_steady_state_switch_zero_recompilation_krasulina():
    """Same acceptance on the PCA superstep: bucket switches through
    `krasulina_superstep_builder` reuse the compiled executable."""
    pca_stream = make_pca_stream(FIG7)
    run_cfg = PCARunConfig(
        pca=FIG7, averaging=AveragingConfig(mode="gossip", rounds=2),
        stream=StreamConfig(streaming_rate=1e3, processing_rate=1e6,
                            comms_rate=1e6))
    N = 5
    traces = []
    base = krasulina.build_krasulina_superstep(run_cfg.averaging, N,
                                               lambda t: 10.0 / t)
    builder = wrap_builder(lambda B: base, traces)

    w0 = jax.random.normal(jax.random.PRNGKey(0), (FIG7.dim,))
    state = krasulina.init_krasulina_state(w0 / jnp.linalg.norm(w0),
                                           run_cfg.averaging, N)
    gov = GovernorConfig(buckets=(10, 20), hysteresis=1, estimate_rates=False)

    class _Phases:
        def __init__(self):
            self.t, self.reads = 0.0, 0

        def __call__(self):
            self.t += 50.0 if (self.reads // 8) % 2 == 0 else 1e-4
            self.reads += 1
            return self.t

    driver = StreamingDriver(
        run_cfg, None, state, make_pca_host_sampler(pca_stream),
        superstep_builder=builder, n_nodes=N, batch=10,
        engine=EngineConfig(superstep=2, prefetch_depth=0, replan_every=1,
                            warmup_supersteps=0, warmup_per_bucket=0,
                            governor=gov),
        clock=_Phases())
    driver.run(16)
    switches = [r for r in driver.history if "bucket_switch" in r]
    assert len(switches) >= 2
    assert driver.compiled_buckets == (10, 20)
    assert sorted(set(traces)) == [10, 20]
    assert len(traces) <= len(set(traces)) + 1
    # the consensus spread metric stayed live through the switches
    assert all(np.isfinite(r["metrics"]["consensus_err"])
               for r in driver.history)


def test_driver_new_signature_warmup_excluded_from_governor():
    """Satellite bugfix: the first superstep of a LATER-compiled bucket pays
    XLA compile time; with warmup_per_bucket=1 it must not feed replan (the
    old global gate would have let it poison the timings)."""
    stream = StreamConfig(streaming_rate=1e3, processing_rate=1e6,
                          comms_rate=1e6)
    gov = GovernorConfig(buckets=(8, 16), hysteresis=1, estimate_rates=False)
    driver, ctx = _lm_driver(stream, _FakeClock(50.0), gov,
                             warmup=0, per_bucket=1)
    try:
        driver.run(4)
        hist = driver.history
        # superstep 0 (B=8, initial sig with warmup 0): replans, switch to 16
        assert hist[0].get("bucket_switch") == (8, 16)
        # superstep 1 is the FIRST at the fresh B=16 signature: gated out
        assert hist[1]["bucket"] == 16
        assert "replanned" not in hist[1] and "target_bucket" not in hist[1]
        # superstep 2 at B=16 is warm: the governor engages again (mu adapts)
        assert "replanned" in hist[2]
    finally:
        ctx.__exit__(None, None, None)


def test_driver_estimator_converges_in_loop():
    """End-to-end: a clock whose dt follows eq. 4's ground truth as the
    governor moves between buckets lets the online estimator pin (R_p, R_c)
    within 20% (acceptance), replacing the config constants."""
    N = 1
    Rp_true, Rc_true = 2e3, 50.0  # slow compute AND heavy comms
    stream = StreamConfig(streaming_rate=1e3, processing_rate=1e6,
                          comms_rate=1e6)  # config constants are both wrong
    gov = GovernorConfig(buckets=(8, 16, 32), hysteresis=1, window=64)
    K = 2

    class _ModelClock:
        """Second reading of each pair advances by the eq.-4 round time of
        the superstep just produced (prefetch_depth=0: production happens
        inside the timed window)."""

        def __init__(self):
            self.t, self.reads, self.driver = 0.0, 0, None

        def __call__(self):
            self.reads += 1
            if self.reads % 2 == 0:
                B = self.driver.pipeline.last_superstep_plan.B
                self.t += K * (B / (N * Rp_true) + 1.0 / Rc_true)
            else:
                self.t += 1e-9
            return self.t

    clock = _ModelClock()
    driver, ctx = _lm_driver(stream, clock, gov, batch=8)
    clock.driver = driver
    try:
        driver.run(12)
        ests = [(r["est_Rp"], r["est_Rc"]) for r in driver.history
                if "est_Rc" in r]
        assert ests, "estimator never became identifiable"
        Rp_hat, Rc_hat = ests[-1]
        assert Rp_hat == pytest.approx(Rp_true, rel=0.2)
        assert Rc_hat == pytest.approx(Rc_true, rel=0.2)
    finally:
        ctx.__exit__(None, None, None)


def test_krasulina_exact_mean_path_with_single_bucket_ladder():
    """Satellite: the exact-mode (jnp.mean over nodes) PCA superstep keeps
    working behind a bucket ladder of size 1 — mu adapts, B never moves, and
    a B proposal is rejected with the registered-bucket error."""
    pca_stream = make_pca_stream(FIG7)
    run_cfg = PCARunConfig(
        pca=FIG7, averaging=AveragingConfig(mode="exact"),
        stream=StreamConfig(streaming_rate=1e3, processing_rate=1e6,
                            comms_rate=1e6))
    N = 5
    w0 = jax.random.normal(jax.random.PRNGKey(0), (FIG7.dim,))
    state = krasulina.init_krasulina_state(w0 / jnp.linalg.norm(w0),
                                           run_cfg.averaging, N)
    builder = krasulina.krasulina_superstep_builder(run_cfg.averaging, N,
                                                    lambda t: 10.0 / t)
    driver = StreamingDriver(
        run_cfg, None, state, make_pca_host_sampler(pca_stream),
        superstep_builder=builder, n_nodes=N, batch=10,
        engine=EngineConfig(superstep=2, prefetch_depth=0, replan_every=1,
                            warmup_supersteps=0, warmup_per_bucket=0),
        clock=_FakeClock(50.0))
    assert driver.ladder.buckets == (10,)
    driver.run(3)
    assert driver.pipeline.plan.B == 10
    assert driver.pipeline.plan.mu > 0  # mu adaptation still live
    with pytest.raises(ValueError, match=r"registered buckets: \[10\]"):
        driver.pipeline.update_plan(
            dataclasses.replace(driver.pipeline.plan, B=20))


def test_driver_exact_mode_default_governor_is_pinned():
    """Satellite: the default single-bucket governor on the exact-averaging
    (jnp.mean) path reproduces the pre-ladder behavior — B never moves, the
    ladder has exactly one bucket, and mu still adapts."""
    stream = StreamConfig(streaming_rate=1e3, processing_rate=1e6,
                          comms_rate=1e6)
    driver, ctx = _lm_driver(stream, _FakeClock(50.0), GovernorConfig())
    try:
        assert len(driver.ladder) == 1 and driver.ladder.buckets == (BATCH,)
        driver.run(3)
        assert driver.pipeline.plan.B == BATCH
        assert driver.pipeline.plan.mu > 0  # mu adaptation still live
        assert all("bucket_switch" not in r for r in driver.history)
    finally:
        ctx.__exit__(None, None, None)
