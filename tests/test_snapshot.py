"""Full-loop async checkpoint/restore (train/snapshot.py, docs/DESIGN.md
§Fault-tolerant streaming):

* `RunSnapshotter` mechanics: cadence grid, EWMA cost governor, depth-1
  busy skip, writer failures recorded without touching the training
  thread, last-k retention through the writer
* in-process kill-and-resume: a resumed driver is bit-identical to the
  uninterrupted one — exact-mode LM engine WITH the async prefetch ring,
  and the elastic krasulina engine under fault-injected churn (resume from
  a checkpoint taken while the cohort was shrunk; later rejoin retraces
  nothing it already compiled)
* SIGKILL regression: a worker process is killed mid-stream and mid-save
  (torn step directory); the resumed process skips the torn checkpoint via
  `newest_valid` and still reproduces the uninterrupted final state
  bit-for-bit, with the persistent compilation cache making the warm
  restart compile-free
"""
import dataclasses
import os
import signal
import subprocess
import sys
import threading
import time
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import (AveragingConfig, GovernorConfig, RunConfig,
                                SHAPES, StreamConfig)
from repro.configs.paper_pca import FIG7, PCARunConfig
from repro.core import krasulina, rates
from repro.core.faults import FaultSchedule
from repro.data.lm import MarkovTokenStream
from repro.data.pipeline import StreamingPipeline
from repro.launch.mesh import make_mesh
from repro.launch.sharding import activation_rules
from repro.models.common import mesh_rules
from repro.data.synthetic import make_pca_host_sampler, make_pca_stream
from repro.train import checkpoint, snapshot
from repro.train.driver import EngineConfig, StreamingDriver
from repro.train.snapshot import RunSnapshotter
from repro.train.trainer import init_state


class _FakeClock:
    def __init__(self, dt):
        self.t, self.dt = 0.0, dt

    def __call__(self):
        self.t += self.dt
        return self.t


def _leaves(state):
    return checkpoint._flatten(state)


def _assert_states_equal(a, b):
    fa, fb = _leaves(a), _leaves(b)
    assert fa.keys() == fb.keys()
    for k in fa:
        np.testing.assert_array_equal(np.asarray(fa[k]), np.asarray(fb[k]),
                                      err_msg=k)


# ---------------------------------------------------------------------------
# RunSnapshotter mechanics (stub driver: no engine needed)
# ---------------------------------------------------------------------------

def _stub_driver(step=0):
    pipe = StreamingPipeline(
        lambda rng, n: {"x": np.zeros((n, 2), np.float32)},
        StreamConfig(), n_nodes=1, rounds_R=1, batch=4)
    d = types.SimpleNamespace(
        state={"w": jnp.arange(4.0)}, pipeline=pipe, _supersteps_done=step,
        _last_splitter_state=None, _last_round_s=None, _sig_seen={},
        _hysteresis=rates.BucketHysteresis(2), _estimator=None,
        _straggler=None, _membership=None, _publisher=None)
    return d


def test_snapshotter_validates_args(tmp_path):
    for kw in ({"every": 0}, {"keep_last": 0}, {"overhead_budget": -0.1},
               {"alpha": 0.0}, {"alpha": 1.5}):
        with pytest.raises(ValueError):
            RunSnapshotter(str(tmp_path), **kw)


def test_snapshotter_cadence_grid(tmp_path):
    d = _stub_driver()
    with RunSnapshotter(str(tmp_path), every=2, overhead_budget=0,
                        block=True) as sn:
        for step in (1, 2, 3, 4):
            d._supersteps_done = step
            sn.maybe_snapshot(d)
    assert sn.stats.dispatches == 2 and sn.stats.saves == 2
    assert sn.stats.skipped_cadence == 2
    assert checkpoint.list_steps(str(tmp_path)) == [2, 4]


def test_snapshotter_budget_governor_skips(tmp_path):
    """With a 1 s/reading fake clock every dispatch 'costs' 1 s; a 0.5
    overhead budget must skip every other cadence hit."""
    d = _stub_driver()
    with RunSnapshotter(str(tmp_path), every=1, overhead_budget=0.5,
                        block=True, clock=_FakeClock(1.0)) as sn:
        for step in (1, 2, 3):
            d._supersteps_done = step
            sn.maybe_snapshot(d)
    assert sn.stats.dispatches == 2
    assert sn.stats.skipped_budget == 1


def test_snapshotter_busy_writer_skips_not_blocks(tmp_path, monkeypatch):
    release, entered = threading.Event(), threading.Event()
    orig = checkpoint.save

    def slow_save(*a, **kw):
        entered.set()
        release.wait(10.0)
        return orig(*a, **kw)

    monkeypatch.setattr(checkpoint, "save", slow_save)
    d = _stub_driver(step=1)
    with RunSnapshotter(str(tmp_path), every=1, overhead_budget=0) as sn:
        assert sn.maybe_snapshot(d) is not None
        assert entered.wait(10.0)
        d._supersteps_done = 2
        t0 = time.perf_counter()
        assert sn.maybe_snapshot(d) is None  # writer busy: skip, don't wait
        assert time.perf_counter() - t0 < 5.0
        assert sn.stats.skipped_busy == 1
        release.set()
        sn.flush()
    assert sn.stats.saves == 1


def test_snapshotter_failure_recorded_never_raised(tmp_path, monkeypatch):
    def boom(*a, **kw):
        raise OSError("disk on fire")

    monkeypatch.setattr(checkpoint, "save", boom)
    d = _stub_driver(step=1)
    with RunSnapshotter(str(tmp_path), every=1, overhead_budget=0,
                        block=True) as sn:
        assert sn.maybe_snapshot(d) is not None  # dispatched fine
    assert sn.stats.failures == 1 and sn.stats.saves == 0
    assert "disk on fire" in sn.stats.last_error


def test_snapshotter_retention_keeps_last_k(tmp_path):
    d = _stub_driver()
    with RunSnapshotter(str(tmp_path), every=1, keep_last=2,
                        overhead_budget=0, block=True) as sn:
        for step in (1, 2, 3, 4, 5):
            d._supersteps_done = step
            sn.maybe_snapshot(d)
    assert checkpoint.list_steps(str(tmp_path)) == [4, 5]


def test_restore_driver_requires_a_valid_checkpoint(tmp_path):
    with pytest.raises(FileNotFoundError):
        snapshot.restore_driver(_stub_driver(), str(tmp_path / "nowhere"))
    # a root whose every step directory is torn is as good as empty
    d = _stub_driver(step=3)
    with RunSnapshotter(str(tmp_path), every=1, overhead_budget=0,
                        block=True) as sn:
        sn.maybe_snapshot(d)
    os.remove(os.path.join(checkpoint.step_dir(str(tmp_path), 3),
                           "manifest.json"))
    with pytest.raises(FileNotFoundError, match="torn or corrupt"):
        snapshot.restore_driver(_stub_driver(), str(tmp_path))


# ---------------------------------------------------------------------------
# In-process kill-and-resume: exact-mode LM engine, prefetch ring on
# ---------------------------------------------------------------------------

SEQ, BATCH = 16, 4


def _lm_cfg():
    cfg = dataclasses.replace(
        reduced(get_config("granite-8b"), layers=1, d_model=16),
        vocab_size=32, d_ff=32)
    return RunConfig(model=cfg, shape=SHAPES["train_4k"],
                     averaging=AveragingConfig("exact", 1),
                     stream=StreamConfig(streaming_rate=1e3,
                                         processing_rate=1e6, comms_rate=1e6),
                     optimizer="adam", learning_rate=1e-3,
                     param_dtype="float32", remat=False)


def _lm_sample_fn():
    data = MarkovTokenStream(32, seed=0)

    def draw(rng, n):
        toks = data.sample(rng, n, SEQ + 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    return draw


def _lm_driver(mesh, run_cfg, clock, **kw):
    state = init_state(run_cfg, jax.random.PRNGKey(0))
    return StreamingDriver(
        run_cfg, mesh, state, _lm_sample_fn(), batch=BATCH,
        engine=EngineConfig(superstep=2, prefetch_depth=2, replan_every=1,
                            warmup_supersteps=0),
        clock=clock, **kw)


def test_resume_bit_identical_exact_mode_with_prefetch(tmp_path):
    """Kill after CUT supersteps, resume from the newest snapshot: params,
    history tail, stream counters, and the online rate-estimator fit are all
    bit-identical to the uninterrupted run. The prefetch ring stays ON —
    the splitter snapshot rides the ring's `meta` hook, so supersteps that
    were staged but never consumed at the cut are re-dealt, not skipped."""
    TOTAL, CUT = 8, 4
    run_cfg = _lm_cfg()
    mesh = make_mesh((1, 1), ("data", "model"))
    with mesh_rules(mesh, activation_rules(mesh, run_cfg.shape)):
        with _lm_driver(mesh, run_cfg, _FakeClock(1e-3)) as ref:
            ref_state, ref_hist = ref.run(TOTAL)
            ref_est = ref._estimator.state_dict()

        with _lm_driver(mesh, run_cfg, _FakeClock(1e-3),
                        snapshotter=RunSnapshotter(
                            str(tmp_path), every=1, overhead_budget=0,
                            block=True)) as victim:
            victim.run(CUT)
        assert checkpoint.list_steps(str(tmp_path))[-1] == CUT

        clk = _FakeClock(1e-3)
        for _ in range(2 * CUT):  # the driver reads the clock 2x/superstep
            clk()
        with _lm_driver(mesh, run_cfg, clk,
                        resume_from=str(tmp_path)) as resumed:
            assert resumed.resumed_from == checkpoint.step_dir(
                str(tmp_path), CUT)
            assert resumed._supersteps_done == CUT
            res_state, res_hist = resumed.run(TOTAL - CUT)
            res_est = resumed._estimator.state_dict()

    _assert_states_equal(ref_state, res_state)
    assert res_est == ref_est
    assert len(res_hist) == TOTAL - CUT
    for r_ref, r_res in zip(ref_hist[CUT:], res_hist):
        assert r_ref["round"] == r_res["round"]
        assert r_ref["counters"] == r_res["counters"]
        np.testing.assert_array_equal(
            np.asarray(r_ref["metrics"]["loss"]),
            np.asarray(r_res["metrics"]["loss"]))


# ---------------------------------------------------------------------------
# In-process resume under churn (elastic krasulina engine)
# ---------------------------------------------------------------------------

def _elastic_driver(faults, *, clock, traces=None, gov=None, n=5, batch=10,
                    **kw):
    run_cfg = PCARunConfig(
        pca=FIG7, averaging=AveragingConfig(mode="gossip", rounds=2))
    builder = krasulina.krasulina_superstep_builder(
        run_cfg.averaging, n, lambda t: 10.0 / t)
    if traces is not None:
        inner = builder

        def builder(B, membership=None):  # noqa: F811
            raw = inner(B, membership)
            m = n if membership is None else membership.n_active

            def counted(s, b):
                traces.append((B, m))
                return raw(s, b)

            return counted

    w0 = jax.random.normal(jax.random.PRNGKey(0), (FIG7.dim,))
    state = krasulina.init_krasulina_state(w0 / jnp.linalg.norm(w0),
                                           run_cfg.averaging, n)
    return StreamingDriver(
        run_cfg, None, state, make_pca_host_sampler(make_pca_stream(FIG7)),
        superstep_builder=builder, n_nodes=n, batch=batch, faults=faults,
        engine=EngineConfig(superstep=2, prefetch_depth=0, replan_every=1,
                            warmup_supersteps=0, warmup_per_bucket=0,
                            governor=gov or GovernorConfig()),
        clock=clock, **kw)


def test_resume_under_churn_bit_identical(tmp_path):
    """Resume from a checkpoint taken while the cohort was SHRUNK (node 4
    dead): the relabeled cohort, its re-derived bucket ladder, and the whole
    trajectory — including the later rejoin — are bit-identical to the
    uninterrupted run."""
    TOTAL, CUT = 8, 3  # cut lands mid-drop-era (supersteps 2-4 run with N=4)
    faults = FaultSchedule.parse("death:4@2-5", 5)

    with _elastic_driver(faults, clock=_FakeClock(1e-3)) as ref:
        ref_state, ref_hist = ref.run(TOTAL)

    with _elastic_driver(faults, clock=_FakeClock(1e-3),
                         snapshotter=RunSnapshotter(
                             str(tmp_path), every=1, overhead_budget=0,
                             block=True)) as victim:
        victim.run(CUT)
        assert victim.membership.n_active == 4  # mid-shrink, as intended

    clk = _FakeClock(1e-3)
    for _ in range(2 * CUT):
        clk()
    with _elastic_driver(faults, clock=clk,
                         resume_from=str(tmp_path)) as resumed:
        # churn continuity restored before the first resumed superstep
        assert resumed.membership.n_active == 4
        assert resumed.membership == ref_hist[CUT - 1]["plan"].membership
        assert resumed.ladder.buckets == resumed._ladder_for(4).buckets
        assert resumed.pipeline.plan.B == 12  # ceil(10/4)*4, the shrunk-era B
        res_state, res_hist = resumed.run(TOTAL - CUT)

    _assert_states_equal(ref_state, res_state)
    assert resumed.membership.is_full  # rejoined at superstep 5
    eras = [(r["bucket"], r["n_active"]) for r in res_hist]
    assert eras == [(r["bucket"], r["n_active"]) for r in ref_hist[CUT:]]
    for r_ref, r_res in zip(ref_hist[CUT:], res_hist):
        assert r_ref["counters"] == r_res["counters"]
        np.testing.assert_array_equal(
            np.asarray(r_ref["metrics"]["consensus_err"]),
            np.asarray(r_res["metrics"]["consensus_err"]))


def test_resume_rejoin_is_zero_retrace_and_straggler_state_survives(tmp_path):
    """Two drop eras: resume lands in the full-cohort gap between them. The
    resumed process compiles each (B, cohort) signature once on first use;
    the SECOND rejoin reuses the already-compiled full-cohort executable —
    zero retrace — and the straggler EWMAs (a 3x-slowed node) come back
    bit-identical."""
    TOTAL, CUT = 10, 5
    spec = "death:4@2-4,slow:1@0-10x3,death:4@6-8"
    gov = GovernorConfig(straggler_policy="drop", straggler_slow_factor=4.0)

    with _elastic_driver(FaultSchedule.parse(spec, 5), gov=gov,
                         clock=_FakeClock(1e-3)) as ref:
        ref_state, ref_hist = ref.run(TOTAL)
        ref_straggler = ref._straggler.state_dict()

    with _elastic_driver(FaultSchedule.parse(spec, 5), gov=gov,
                         clock=_FakeClock(1e-3),
                         snapshotter=RunSnapshotter(
                             str(tmp_path), every=1, overhead_budget=0,
                             block=True)) as victim:
        victim.run(CUT)
        assert victim.membership.is_full  # cut in the between-eras gap

    clk = _FakeClock(1e-3)
    for _ in range(2 * CUT):
        clk()
    traces = []
    with _elastic_driver(FaultSchedule.parse(spec, 5), gov=gov, clock=clk,
                         traces=traces, resume_from=str(tmp_path)) as resumed:
        res_state, res_hist = resumed.run(TOTAL - CUT)
        res_straggler = resumed._straggler.state_dict()

    _assert_states_equal(ref_state, res_state)
    assert res_straggler == ref_straggler
    # supersteps 5, 6-7, 8-9: (10,5) then (12,4) then (10,5) again — the
    # second full-cohort era must NOT have traced a third time
    assert traces == [(10, 5), (12, 4)]
    eras = [(r["bucket"], r["n_active"]) for r in res_hist]
    assert eras == [(10, 5), (12, 4), (12, 4), (10, 5), (10, 5)]


# ---------------------------------------------------------------------------
# SIGKILL regression (subprocess worker)
# ---------------------------------------------------------------------------

WORKER = os.path.join(os.path.dirname(__file__), "snapshot_worker.py")
TOTAL = 8


def _worker_cmd(root, *, out="", resume=False, cache_dir="", snapshots=True):
    cmd = [sys.executable, WORKER, "--root", str(root),
           "--supersteps", str(TOTAL)]
    if out:
        cmd += ["--out", str(out)]
    if resume:
        cmd += ["--resume"]
    if cache_dir:
        cmd += ["--cache-dir", str(cache_dir)]
    if not snapshots:
        cmd += ["--no-snapshots"]
    return cmd


def _env(extra=None):
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("SNAPSHOT_SLOW_AFTER_STEP", None)
    if extra:
        env.update(extra)
    return env


def _run_to_completion(cmd, env, timeout=300):
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=timeout)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "DONE" in out.stdout
    return out.stdout


def _kill_when(cmd, env, marker, timeout=300):
    """Start the worker, SIGKILL it as soon as `marker` appears on stdout."""
    proc = subprocess.Popen(cmd, env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True)
    deadline = time.monotonic() + timeout
    try:
        for line in proc.stdout:
            if time.monotonic() > deadline:
                raise TimeoutError(f"no {marker!r} within {timeout}s")
            if line.startswith(marker):
                os.kill(proc.pid, signal.SIGKILL)
                proc.wait(timeout=30)
                assert proc.returncode == -signal.SIGKILL
                return
        raise AssertionError(f"worker exited before printing {marker!r}")
    finally:
        if proc.poll() is None:
            proc.kill()
        proc.stdout.close()
        proc.wait(timeout=30)


@pytest.fixture(scope="module")
def reference_run(tmp_path_factory):
    """One uninterrupted worker run shared by every SIGKILL scenario."""
    d = tmp_path_factory.mktemp("snapref")
    out = d / "ref.npz"
    _run_to_completion(
        _worker_cmd(d / "unused-root", out=out, snapshots=False), _env())
    return np.load(out)


def _assert_matches_reference(ref, out_path):
    got = np.load(out_path)
    start = int(got["resumed_at"])
    assert 0 < start < TOTAL  # genuinely resumed mid-stream
    for k in ref.files:
        if k.startswith("state::"):
            np.testing.assert_array_equal(ref[k], got[k], err_msg=k)
    np.testing.assert_array_equal(ref["counters"], got["counters"])
    np.testing.assert_array_equal(ref["eras"][start:], got["eras"])
    return start


def test_sigkill_mid_stream_resume_bit_identical(tmp_path, reference_run):
    """SIGKILL the training process right after superstep 3's checkpoint is
    durable (mid-shrink era, node 4 dead); a fresh process resuming from the
    root reproduces the uninterrupted final state bit-for-bit. The warm
    restart hits the persistent compilation cache: zero new entries."""
    root, cache = tmp_path / "ckpt", tmp_path / "cc"
    _kill_when(_worker_cmd(root, cache_dir=cache), _env(), "CKPT 3")
    assert checkpoint.newest_valid(str(root)) is not None

    def superstep_entries():
        # the two (B, cohort) era executables land under the jit names of
        # the full-cohort builder ("superstep") and the membership-aware
        # one ("fn"); everything else in the cache is small op-by-op jits
        return sorted(f for f in os.listdir(cache)
                      if f.startswith(("jit_superstep", "jit_fn")) and
                      f.endswith("-cache"))

    # the killed run persisted both compiled (B, cohort) superstep
    # executables: (10, 5) from the full era and (12, 4) from the shrink
    cold = superstep_entries()
    assert len(cold) == 2

    out = tmp_path / "resumed.npz"
    _run_to_completion(
        _worker_cmd(root, out=out, resume=True, cache_dir=cache), _env())
    start = _assert_matches_reference(reference_run, out)
    assert start >= 3  # resumed at (or after) the checkpoint we killed at
    # warm restart: the resumed process re-traces both signatures but every
    # superstep XLA compile is a cache hit — zero new superstep executables
    # (small op-by-op entries MAY appear for code paths the victim never
    # reached, e.g. the rejoin consensus sync)
    assert superstep_entries() == cold


def test_sigkill_mid_save_leaves_torn_step_and_resumes_from_newest_valid(
        tmp_path, reference_run):
    """SIGKILL while the writer is mid-save for step 3 (after its first leaf
    write, before the manifest): the step directory is torn, `newest_valid`
    falls back to step 2, and the resumed run still matches the
    uninterrupted reference bit-for-bit."""
    root = tmp_path / "ckpt"
    _kill_when(_worker_cmd(root), _env({"SNAPSHOT_SLOW_AFTER_STEP": "3"}),
               "SLOW-SAVE 3")
    torn = checkpoint.step_dir(str(root), 3)
    assert os.path.isdir(torn) and not checkpoint.is_valid(torn)
    assert checkpoint.newest_valid(str(root)) == \
        checkpoint.step_dir(str(root), 2)

    out = tmp_path / "resumed.npz"
    _run_to_completion(_worker_cmd(root, out=out, resume=True), _env())
    start = _assert_matches_reference(reference_run, out)
    assert start == 2  # the torn step 3 was skipped
