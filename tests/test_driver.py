"""Streaming-engine tests (train.driver + the superstep/prefetch machinery):

* superstep(K) == K sequential train steps — bit-identical in exact mode,
  within tolerance for decentralized (gossip) mode
* the async prefetch ring preserves sample order and keeps the splitter
  counters (samples_arrived, discards) coherent with the consumed batch
* the closed-loop governor raises mu when the measured rate is artificially
  slowed (injected clock), and the rate inversion round-trips eq. 4
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import AveragingConfig, RunConfig, SHAPES, StreamConfig
from repro.core import rates
from repro.data.lm import MarkovTokenStream
from repro.data.pipeline import DevicePrefetcher, StreamingPipeline
from repro.launch.mesh import make_mesh
from repro.launch.sharding import activation_rules
from repro.models.common import mesh_rules
from repro.train.driver import EngineConfig, StreamingDriver
from repro.train.trainer import (build_superstep, build_train_step, init_state,
                                 make_node_batch, replicate_for_nodes)

SEQ = 16
BATCH = 4


def _run_cfg(mode="exact", rounds=1, stream=StreamConfig()):
    cfg = dataclasses.replace(
        reduced(get_config("granite-8b"), layers=1, d_model=16),
        vocab_size=32, d_ff=32)
    return RunConfig(model=cfg, shape=SHAPES["train_4k"],
                     averaging=AveragingConfig(mode, rounds), stream=stream,
                     optimizer="adam", learning_rate=1e-3,
                     param_dtype="float32", remat=False)


def _sample_fn(vocab=32, seed=0):
    data = MarkovTokenStream(vocab, seed=seed)

    def draw(rng, n):
        toks = data.sample(rng, n, SEQ + 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    return draw


def _rounds(k, batch=BATCH, seed=0):
    draw = _sample_fn(seed=seed)
    rng = np.random.default_rng(seed)
    return [{kk: jnp.asarray(v) for kk, v in draw(rng, batch).items()}
            for _ in range(k)]


def _stack(batches):
    return {k: jnp.stack([b[k] for b in batches]) for k in batches[0]}


# ---------------------------------------------------------------------------
# Superstep parity
# ---------------------------------------------------------------------------

def test_superstep_exact_mode_bit_identical():
    """K-round superstep == K sequential jitted steps, bitwise (exact mode)."""
    run_cfg = _run_cfg("exact")
    mesh = make_mesh((1, 1), ("data", "model"))
    K = 4
    batches = _rounds(K)
    with mesh_rules(mesh, activation_rules(mesh, run_cfg.shape)):
        state0 = init_state(run_cfg, jax.random.PRNGKey(0))
        step = jax.jit(build_train_step(run_cfg, mesh)[0])
        superstep = jax.jit(build_superstep(run_cfg, mesh)[0])

        seq_state, seq_losses = state0, []
        for b in batches:
            seq_state, m = step(seq_state, b)
            seq_losses.append(np.asarray(m["loss"]))
        sup_state, sup_metrics = superstep(state0, _stack(batches))

    for a, b in zip(jax.tree.leaves(seq_state), jax.tree.leaves(sup_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # on-device metric accumulation: stacked [K], same values per round
    assert sup_metrics["loss"].shape == (K,)
    np.testing.assert_array_equal(np.stack(seq_losses),
                                  np.asarray(sup_metrics["loss"]))


def test_superstep_decentralized_matches_sequential():
    """Gossip mode (emulated N=4 nodes on one device): same trajectory within
    float tolerance."""
    run_cfg = _run_cfg("gossip", rounds=2)
    mesh = make_mesh((1, 1), ("data", "model"))
    n_nodes, K = 4, 3
    batches = [make_node_batch(b, n_nodes) for b in _rounds(K, batch=4 * BATCH)]
    with mesh_rules(mesh, activation_rules(mesh, run_cfg.shape, node_axis=True)):
        state0 = replicate_for_nodes(
            init_state(run_cfg, jax.random.PRNGKey(0)), n_nodes)
        step = jax.jit(build_train_step(run_cfg, mesh, n_nodes=n_nodes)[0])
        superstep = jax.jit(build_superstep(run_cfg, mesh, n_nodes=n_nodes)[0])

        seq_state = state0
        for b in batches:
            seq_state, m = step(seq_state, b)
        sup_state, ms = superstep(state0, _stack(batches))

    assert float(ms["consensus_err"][-1]) > 0.0  # inexact averaging is live
    for a, b in zip(jax.tree.leaves(seq_state), jax.tree.leaves(sup_state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Prefetch ring
# ---------------------------------------------------------------------------

def test_prefetch_preserves_order_and_counters():
    """Prefetched stream == synchronous stream, and each consumed batch comes
    with the counter snapshot a synchronous loop would have observed."""
    def mk_pipe():
        return StreamingPipeline(
            lambda rng, n: {"x": rng.normal(size=(n, 2))},
            StreamConfig(forced_mu=3), n_nodes=2, rounds_R=1, batch=8, seed=7)

    sync_pipe, pre_pipe = mk_pipe(), mk_pipe()
    n_steps, K = 6, 2

    sync_batches, sync_counts = [], []
    for _ in range(n_steps):
        sync_batches.append(sync_pipe.next_superstep(K))
        sync_counts.append(sync_pipe.counters())

    staged_log = []
    pf = DevicePrefetcher(lambda: pre_pipe.next_superstep(K),
                         stage=lambda b: (staged_log.append(True), b)[1],
                         counters=pre_pipe.counters, depth=2)
    with pf:
        for want, want_c in zip(sync_batches, sync_counts):
            got = next(pf)
            np.testing.assert_array_equal(got["x"], want["x"])
            assert pf.counters == want_c
    # staging ran on the producer side for every consumed superstep
    assert len(staged_log) >= n_steps
    # coherence: consumer-visible counters lag the producer's read-ahead
    assert pf.counters.samples_arrived <= pre_pipe.samples_arrived


def test_prefetch_finite_source_and_errors():
    it = iter(range(5))
    pf = DevicePrefetcher(lambda: next(it), depth=2)
    assert list(pf) == [0, 1, 2, 3, 4]
    # exhausted ring keeps raising instead of blocking on the dead worker
    with pytest.raises(StopIteration):
        next(pf)
    pf.close()

    def boom():
        raise RuntimeError("producer died")

    pf = DevicePrefetcher(boom, depth=1)
    for _ in range(2):  # the error is latched, not one-shot
        with pytest.raises(RuntimeError, match="producer died"):
            next(pf)
    pf.close()


def test_prefetch_close_while_worker_blocked_on_full_ring():
    """Regression: close() with the worker parked in `_put_stopaware` (ring
    full, consumer gone) must shut down promptly — no deadlock — and a
    producer error that never reached the consumer is re-raised exactly
    once, even if it was stranded by the shutdown itself."""
    import threading
    import time as _time

    produced = threading.Event()

    def produce():
        if produced.is_set():
            raise RuntimeError("late failure")  # fails once the ring is full
        produced.set()
        return 0

    pf = DevicePrefetcher(produce, depth=1)
    # let the worker fill the depth-1 ring and then die trying to enqueue
    # the error behind it; the consumer never drains anything
    assert produced.wait(timeout=5.0)
    deadline = _time.time() + 5.0
    while pf._q.qsize() < 1 and _time.time() < deadline:
        _time.sleep(0.01)
    with pytest.raises(RuntimeError, match="late failure"):
        pf.close()  # must return (not deadlock) AND surface the error
    assert not pf._thread.is_alive()
    pf.close()  # idempotent: the error is re-raised exactly once
    # a post-close consumer must not block on the dead worker either
    with pytest.raises((StopIteration, RuntimeError)):
        next(pf)


def test_prefetch_close_after_error_delivered_does_not_reraise():
    """An error already surfaced through __next__ is not raised again by
    close() (the pre-existing latched-error contract)."""
    def boom():
        raise RuntimeError("seen already")

    pf = DevicePrefetcher(boom, depth=1)
    with pytest.raises(RuntimeError, match="seen already"):
        next(pf)
    pf.close()  # must NOT raise


def test_pipeline_update_plan_keeps_B_fixed():
    pipe = StreamingPipeline(lambda rng, n: {"x": rng.normal(size=(n, 2))},
                             StreamConfig(), 2, 1, batch=8)
    new = dataclasses.replace(pipe.plan, mu=5)
    pipe.update_plan(new)
    assert pipe.plan.mu == 5
    next(pipe)
    assert pipe.samples_arrived == 13 and pipe.samples_discarded == 5
    with pytest.raises(ValueError):
        pipe.update_plan(dataclasses.replace(pipe.plan, B=16))


# ---------------------------------------------------------------------------
# Closed-loop governor
# ---------------------------------------------------------------------------

def test_measured_rate_inverts_effective_rate():
    B, N, R, Rp, Rc = 64, 4, 3, 1e4, 1e5
    round_s = B / (N * Rp) + R / Rc  # eq. 4 timeline
    got = rates.measured_processing_rate(B, N, R, round_s, Rc)
    assert got == pytest.approx(Rp, rel=1e-9)
    assert rates.measured_effective_rate(round_s) == pytest.approx(
        rates.effective_rate(B, N, R, Rp, Rc), rel=1e-9)


def test_replan_raises_mu_when_slow():
    stream = StreamConfig(streaming_rate=1e3, processing_rate=1e6,
                          comms_rate=1e6)
    nominal = rates.plan(stream, N=2, R=1, B=8)
    assert nominal.mu == 0  # config constants claim the system keeps up
    fast = rates.replan(stream, 2, 1, 8, wall_s_per_round=1e-3)
    slow = rates.replan(stream, 2, 1, 8, wall_s_per_round=1.0)
    assert fast.mu == 0
    assert slow.mu > 0 and slow.regime == "under-provisioned"
    assert slow.B == nominal.B  # shape-stable adaptation


def test_replan_distrusts_disproven_comms_model():
    """A round observed FASTER than the modeled comm floor R/R_c proves the
    comms constant wrong; the re-plan must attribute wall time to compute
    (mu = 0 for a run that keeps up), not discard real samples on the model's
    say-so."""
    stream = StreamConfig(streaming_rate=1e3, processing_rate=1e6,
                          comms_rate=1e3)
    R, B, N = 10, 8, 2  # modeled comm floor: R/Rc = 10 ms
    got = rates.replan(stream, N, R, B, wall_s_per_round=2e-3)
    assert got.mu == 0 and got.regime == "resourceful"
    Rp = rates.measured_processing_rate(B, N, R, 2e-3, stream.comms_rate)
    assert Rp == pytest.approx(B / (N * 2e-3))  # sane, not clamp-driven 1e12


def test_replan_honors_forced_mu():
    """A user-pinned mu is an experiment knob; the feedback loop must not
    silently overwrite it."""
    stream = StreamConfig(streaming_rate=1e3, processing_rate=1e6,
                          comms_rate=1e6, forced_mu=7)
    slow = rates.replan(stream, 2, 1, 8, wall_s_per_round=1.0)
    assert slow.mu == 7


class _FakeClock:
    """Monotonic clock that jumps `dt` seconds per reading."""

    def __init__(self, dt):
        self.t, self.dt = 0.0, dt

    def __call__(self):
        self.t += self.dt
        return self.t


@pytest.mark.parametrize("dt,expect_discard", [(1e-4, False), (50.0, True)])
def test_driver_closed_loop_adapts_mu(dt, expect_discard):
    """With an artificially slow clock the governor must re-plan mu > 0; with
    a fast one it must keep mu = 0 (nominal config already keeps up)."""
    stream = StreamConfig(streaming_rate=1e3, processing_rate=1e6,
                          comms_rate=1e6)
    run_cfg = _run_cfg(stream=stream)
    mesh = make_mesh((1, 1), ("data", "model"))
    with mesh_rules(mesh, activation_rules(mesh, run_cfg.shape)):
        state = init_state(run_cfg, jax.random.PRNGKey(0))
        driver = StreamingDriver(
            run_cfg, mesh, state, _sample_fn(), batch=BATCH,
            engine=EngineConfig(superstep=2, prefetch_depth=0, replan_every=1,
                                warmup_supersteps=0),
            clock=_FakeClock(dt))
        assert driver.pipeline.plan.mu == 0
        _, history = driver.run(3)
    assert len(history) == 3
    if expect_discard:
        assert driver.pipeline.plan.mu > 0
        assert driver.pipeline.plan.regime == "under-provisioned"
        assert driver.pipeline.samples_discarded > 0  # later rounds paid mu
    else:
        assert driver.pipeline.plan.mu == 0
        assert driver.pipeline.samples_discarded == 0


def test_driver_governor_skips_compile_warmup():
    """Default warm-up gating: the (slow) compile supersteps must not feed the
    governor, even when their wall time screams under-provisioned."""
    stream = StreamConfig(streaming_rate=1e3, processing_rate=1e6,
                          comms_rate=1e6)
    run_cfg = _run_cfg(stream=stream)
    mesh = make_mesh((1, 1), ("data", "model"))
    with mesh_rules(mesh, activation_rules(mesh, run_cfg.shape)):
        state = init_state(run_cfg, jax.random.PRNGKey(0))
        driver = StreamingDriver(
            run_cfg, mesh, state, _sample_fn(), batch=BATCH,
            engine=EngineConfig(superstep=2, prefetch_depth=0, replan_every=1),
            clock=_FakeClock(50.0))
        _, history = driver.run(2)
        assert all("replanned" not in rec for rec in history)
        assert driver.pipeline.plan.mu == 0
        # warm-up over (also across run() calls): the governor engages
        driver.run(1)
    assert driver.pipeline.plan.mu > 0


def test_driver_runs_with_prefetch_and_counts_rounds():
    run_cfg = _run_cfg()
    mesh = make_mesh((1, 1), ("data", "model"))
    with mesh_rules(mesh, activation_rules(mesh, run_cfg.shape)):
        state = init_state(run_cfg, jax.random.PRNGKey(0))
        with StreamingDriver(
                run_cfg, mesh, state, _sample_fn(), batch=BATCH,
                engine=EngineConfig(superstep=3, prefetch_depth=2,
                                    replan_every=0)) as driver:
            _, history = driver.run(2)
    assert [rec["round"] for rec in history] == [3, 6]
    assert history[-1]["counters"].samples_consumed == 6 * BATCH
    assert all(np.isfinite(rec["metrics"]["loss"]) for rec in history)


# ---------------------------------------------------------------------------
# Train-to-serve publication (PR 7)
# ---------------------------------------------------------------------------

def test_driver_publishes_consensus_snapshots():
    """With a publisher attached the driver publishes at superstep
    boundaries: versions are monotone, each history record carries the
    published version (or None on a governed skip), and the snapshot param
    tree matches the model param structure — including the consensus mean
    over the node axis in decentralized mode."""
    from repro.serve.publisher import SnapshotPublisher

    for mode, n_nodes in (("exact", 1), ("gossip", 1)):
        run_cfg = _run_cfg(mode=mode, rounds=2)
        mesh = make_mesh((1, 1), ("data", "model"))
        decentralized = mode != "exact"
        pub = SnapshotPublisher(overhead_budget=0.0)  # ungoverned: always
        with mesh_rules(mesh, activation_rules(mesh, run_cfg.shape,
                                               node_axis=decentralized)):
            state = init_state(run_cfg, jax.random.PRNGKey(0))
            if decentralized:
                state = replicate_for_nodes(state, n_nodes)
            driver = StreamingDriver(
                run_cfg, mesh, state, _sample_fn(), batch=BATCH,
                n_nodes=n_nodes, publisher=pub,
                engine=EngineConfig(superstep=2, prefetch_depth=0,
                                    replan_every=0, warmup_supersteps=0))
            _, history = driver.run(3)
        assert pub.version == 3
        assert [r["published_version"] for r in history] == [1, 2, 3]
        snap = pub.snapshot()
        ref = jax.eval_shape(lambda: driver.state.params)
        leaves = jax.tree_util.tree_leaves(snap.params)
        ref_leaves = jax.tree_util.tree_leaves(ref)
        if decentralized:
            # node axis averaged away: snapshot leaves drop the leading dim
            assert all(s.shape == r.shape[1:]
                       for s, r in zip(leaves, ref_leaves))
        else:
            assert all(s.shape == r.shape
                       for s, r in zip(leaves, ref_leaves))
        assert snap.superstep == 3


def test_driver_publish_governor_skip_records_none():
    """A budget-starved publisher skips mid-run publishes; the driver records
    published_version=None for those supersteps and the first publish still
    always lands."""
    from repro.serve.publisher import SnapshotPublisher

    run_cfg = _run_cfg()
    mesh = make_mesh((1, 1), ("data", "model"))
    pub = SnapshotPublisher(overhead_budget=1e-12)  # everything over budget
    pub.stats.cost_ewma_s = 10.0  # pretend publishes are very expensive
    with mesh_rules(mesh, activation_rules(mesh, run_cfg.shape)):
        state = init_state(run_cfg, jax.random.PRNGKey(0))
        driver = StreamingDriver(
            run_cfg, mesh, state, _sample_fn(), batch=BATCH, publisher=pub,
            engine=EngineConfig(superstep=2, prefetch_depth=0,
                                replan_every=0, warmup_supersteps=0))
        _, history = driver.run(3)
    versions = [r["published_version"] for r in history]
    assert versions[0] == 1  # unconditional first publish
    assert versions[1:] == [None, None]
    assert pub.stats.skipped_budget == 2
