"""Checkpoint round-trip: save/restore a real TrainState, structure + values."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import RunConfig, SHAPES
from repro.train import checkpoint as ckpt
from repro.train.trainer import init_state


def test_roundtrip(tmp_path):
    cfg = reduced(get_config("phi4-mini-3.8b"))
    run = RunConfig(model=cfg, shape=SHAPES["train_4k"], param_dtype="float32")
    state = init_state(run, jax.random.PRNGKey(0))
    path = str(tmp_path / "ck")
    ckpt.save(path, state, step=42, meta={"arch": cfg.name})
    restored = ckpt.restore(path, jax.eval_shape(lambda: state))
    assert ckpt.loaded_step(path) == 42
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                            np.asarray(b)),
                 state, restored)


def test_restore_with_put(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "nested": {"b": jnp.ones(4)}}
    path = str(tmp_path / "ck2")
    ckpt.save(path, tree)
    seen = []
    out = ckpt.restore(path, jax.eval_shape(lambda: tree),
                       put=lambda key, arr: (seen.append(key), jnp.asarray(arr) * 2)[1])
    assert sorted(seen) == ["a", "nested::b"]
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  2 * np.arange(6.0).reshape(2, 3))
