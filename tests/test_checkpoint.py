"""Checkpoint round-trip: save/restore a real TrainState, structure + values."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import RunConfig, SHAPES
from repro.train import checkpoint as ckpt
from repro.train.trainer import init_state


def test_roundtrip(tmp_path):
    cfg = reduced(get_config("phi4-mini-3.8b"))
    run = RunConfig(model=cfg, shape=SHAPES["train_4k"], param_dtype="float32")
    state = init_state(run, jax.random.PRNGKey(0))
    path = str(tmp_path / "ck")
    ckpt.save(path, state, step=42, meta={"arch": cfg.name})
    restored = ckpt.restore(path, jax.eval_shape(lambda: state))
    assert ckpt.loaded_step(path) == 42
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                            np.asarray(b)),
                 state, restored)


def test_restore_with_put(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "nested": {"b": jnp.ones(4)}}
    path = str(tmp_path / "ck2")
    ckpt.save(path, tree)
    seen = []
    out = ckpt.restore(path, jax.eval_shape(lambda: tree),
                       put=lambda key, arr: (seen.append(key), jnp.asarray(arr) * 2)[1])
    assert sorted(seen) == ["a", "nested::b"]
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  2 * np.arange(6.0).reshape(2, 3))


def test_restore_mismatch_names_missing_and_extra_keys(tmp_path):
    """A renamed/stale structure fails with the actual key diff, not a bare
    KeyError mid-load."""
    path = str(tmp_path / "ck3")
    ckpt.save(path, {"a": jnp.ones(2), "old": jnp.ones(3)})
    target = {"a": jnp.ones(2), "renamed": jnp.ones(3)}
    with pytest.raises(ValueError) as ei:
        ckpt.restore(path, jax.eval_shape(lambda: target))
    msg = str(ei.value)
    assert "missing from checkpoint: ['renamed']" in msg
    assert "present in checkpoint but not in target: ['old']" in msg


def test_save_is_crash_safe(tmp_path, monkeypatch):
    """Crash mid-save must never leave a manifest pointing at missing
    leaves: all .npy files land BEFORE the manifest, and the manifest
    itself arrives via atomic os.replace — an older checkpoint stays
    restorable until the new one is fully durable."""
    import numpy as _np

    path = str(tmp_path / "ck4")
    tree_v1 = {"a": jnp.zeros(2), "b": jnp.zeros(3)}
    ckpt.save(path, tree_v1, step=1)

    calls = {"n": 0}
    real_save = _np.save

    def dying_save(f, arr, **kw):
        calls["n"] += 1
        if calls["n"] > 1:
            raise OSError("disk full")  # crash after the first leaf
        return real_save(f, arr, **kw)

    monkeypatch.setattr(_np, "save", dying_save)
    with pytest.raises(OSError):
        ckpt.save(path, {"a": jnp.ones(2), "b": jnp.ones(3)}, step=2)
    monkeypatch.setattr(_np, "save", real_save)

    # the old manifest is intact and still restores the OLD values
    assert ckpt.loaded_step(path) == 1
    restored = ckpt.restore(path, jax.eval_shape(lambda: tree_v1))
    np.testing.assert_array_equal(np.asarray(restored["b"]), np.zeros(3))
    # no half-written manifest is left behind
    import os
    assert not os.path.exists(os.path.join(path, "manifest.json.tmp"))


def test_save_overwrites_atomically(tmp_path):
    """A completed re-save replaces the manifest in one step."""
    path = str(tmp_path / "ck5")
    ckpt.save(path, {"a": jnp.zeros(2)}, step=1)
    ckpt.save(path, {"a": jnp.ones(2)}, step=2)
    assert ckpt.loaded_step(path) == 2
    out = ckpt.restore(path, jax.eval_shape(lambda: {"a": jnp.zeros(2)}))
    np.testing.assert_array_equal(np.asarray(out["a"]), np.ones(2))


def test_crc_detects_torn_leaf(tmp_path):
    """A bit-flipped leaf file fails restore loudly, naming the leaf."""
    import os

    path = str(tmp_path / "ck6")
    tree = {"a": jnp.arange(8.0), "nested": {"b": jnp.ones(4)}}
    ckpt.save(path, tree)
    fname = ckpt.load_manifest(path)["leaves"]["nested::b"]["file"]
    fpath = os.path.join(path, fname)
    raw = bytearray(open(fpath, "rb").read())
    raw[-1] ^= 0xFF  # corrupt the last data byte
    open(fpath, "wb").write(bytes(raw))
    with pytest.raises(ValueError, match="nested::b.*CRC32"):
        ckpt.restore(path, jax.eval_shape(lambda: tree))
    # verify=False skips the check (explicit opt-out still loads)
    ckpt.restore(path, jax.eval_shape(lambda: tree), verify=False)
    assert not ckpt.is_valid(path)


def test_successful_save_cleans_orphans(tmp_path):
    """Leaf debris from a crashed save is removed once a later save lands a
    durable manifest; files the manifest references survive."""
    import os

    path = str(tmp_path / "ck7")
    ckpt.save(path, {"a": jnp.zeros(2)}, step=1)
    orphan = os.path.join(path, "stale_leaf.00000000.npy")
    np.save(orphan, np.zeros(3))
    ckpt.save(path, {"a": jnp.ones(2)}, step=2)
    assert not os.path.exists(orphan)
    npys = [f for f in os.listdir(path) if f.endswith(".npy")]
    assert npys == [ckpt.load_manifest(path)["leaves"]["a"]["file"]]
    out = ckpt.restore(path, jax.eval_shape(lambda: {"a": jnp.zeros(2)}))
    np.testing.assert_array_equal(np.asarray(out["a"]), np.ones(2))


def test_leaf_write_retries_transient_oserror(tmp_path, monkeypatch):
    """Two transient OSErrors then success: save completes; with retries
    exhausted the last error propagates."""
    import numpy as _np

    fails = {"n": 2}
    real_save = _np.save

    def flaky_save(f, arr, **kw):
        if fails["n"] > 0:
            fails["n"] -= 1
            raise OSError("NFS blip")
        return real_save(f, arr, **kw)

    monkeypatch.setattr(_np, "save", flaky_save)
    path = str(tmp_path / "ck8")
    ckpt.save(path, {"a": jnp.ones(2)}, retries=3, backoff_s=0.001)
    assert ckpt.is_valid(path)

    fails["n"] = 99
    with pytest.raises(OSError):
        ckpt.save(str(tmp_path / "ck9"), {"a": jnp.ones(2)}, retries=2,
                  backoff_s=0.001)


def test_newest_valid_skips_torn_checkpoint(tmp_path):
    """A step-layout root with a torn newest checkpoint resumes from the
    next-newest valid one; prune keeps the last k."""
    import os

    root = str(tmp_path / "run")
    tree = {"a": jnp.zeros(2)}
    for step in (1, 2, 3):
        ckpt.save(ckpt.step_dir(root, step), {"a": jnp.full(2, float(step))},
                  step=step)
    assert ckpt.list_steps(root) == [1, 2, 3]
    assert ckpt.newest_valid(root) == ckpt.step_dir(root, 3)

    # tear step 3 two ways: corrupt a leaf, then drop the manifest entirely
    p3 = ckpt.step_dir(root, 3)
    fname = ckpt.load_manifest(p3)["leaves"]["a"]["file"]
    open(os.path.join(p3, fname), "wb").write(b"not an npy")
    assert ckpt.newest_valid(root) == ckpt.step_dir(root, 2)
    os.remove(os.path.join(p3, "manifest.json"))
    assert ckpt.newest_valid(root) == ckpt.step_dir(root, 2)

    # retention: keep_last=1 keeps torn step 3 (newest dir) AND the newest
    # valid checkpoint (step 2); only step 1 goes
    removed = ckpt.prune(root, keep_last=1)
    assert removed == [ckpt.step_dir(root, 1)]
    assert ckpt.list_steps(root) == [2, 3]
    assert ckpt.newest_valid(root) == ckpt.step_dir(root, 2)
    with pytest.raises(ValueError):
        ckpt.prune(root, keep_last=0)
