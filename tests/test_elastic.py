"""Elastic node membership (docs/DESIGN.md §Elastic membership): fault
injection, straggler policy, masked mixing, and the driver under churn.

* `Membership` mask algebra and the masked mixing operators: doubly
  stochastic over the active cohort, dropped rows degraded to self-weight 1,
  dense-vs-circulant parity under the same mask, rejoin bit-identical to the
  never-left operator
* `FaultSchedule` DSL parse + replayable death/slow/flaky scripts
* `PerNodeRoundTime` / `StragglerPolicy`: EWMA smoothing, drop/deadline
  verdicts debounced through per-node hysteresis, the never-empty guarantee
* N-aware `BucketLadder` (satellite): cohort re-derivation and stale-ladder
  rejection when the cohort size changes
* estimator coherence across membership eras (`observe_cohort`)
* `swap_membership` plan-swap semantics on the governed pipeline
* fake-clock driver acceptance: a FaultSchedule killing a node mid-stream and
  rejoining later completes with ZERO recompiles on rejoin (trace-counted),
  the governor re-plans (B, mu) at each membership change, and a straggler
  is dropped/readmitted within hysteresis patience
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (AveragingConfig, GovernorConfig, StreamConfig)
from repro.configs.paper_pca import FIG7, PCARunConfig
from repro.core import krasulina, mixing, rates
from repro.core.faults import FaultSchedule, NodeFault
from repro.core.mixing import Membership
from repro.data.pipeline import StreamingPipeline
from repro.data.synthetic import make_pca_host_sampler, make_pca_stream
from repro.train.driver import EngineConfig, StreamingDriver, elastic_superstep
from _trace import wrap_builder


# ---------------------------------------------------------------------------
# Membership mask
# ---------------------------------------------------------------------------

def test_membership_basic_algebra():
    m = Membership.full(4)
    assert m.n_active == 4 and m.is_full and m.active_ids == (0, 1, 2, 3)
    d = m.drop(1, 3)
    assert d.n_active == 2 and d.active_ids == (0, 2) and not d.is_full
    assert m.is_full  # frozen: drop returns a new mask
    r = d.rejoin(1).rejoin(3)
    assert r == m and hash(r) == hash(m)  # value equality keys registries


def test_membership_rejects_malformed():
    with pytest.raises(ValueError):
        Membership(3, (True, True))  # mask length mismatch
    with pytest.raises(ValueError):
        Membership(2, (False, False))  # nobody left
    with pytest.raises(ValueError):
        Membership.full(2).drop(0).drop(1)


# ---------------------------------------------------------------------------
# Masked mixing operators
# ---------------------------------------------------------------------------

def test_masked_matrix_full_membership_is_identity_op():
    A = mixing.ring_matrix(6)
    assert mixing.masked_matrix(A, Membership.full(6)) is A


def test_masked_matrix_doubly_stochastic_with_self_weight_rows():
    A = mixing.ring_matrix(6)
    mem = Membership.full(6).drop(2, 5)
    M = mixing.masked_matrix(A, mem)
    assert mixing.is_doubly_stochastic(M)
    # dropped nodes hold their state: identity rows AND columns (no mass
    # leaks to or from a dead node)
    for i in (2, 5):
        e = np.zeros(6)
        e[i] = 1.0
        np.testing.assert_array_equal(M[i], e)
        np.testing.assert_array_equal(M[:, i], e)
    # with a CONNECTED induced subgraph (ring minus one node = a path) the
    # active block still contracts toward cohort consensus; note a drop
    # pattern that disconnects the induced graph stalls dense-mask
    # consensus — the device path avoids this by relabeling the cohort
    # into its own ring (`masked_schedule`)
    one = Membership.full(6).drop(2)
    ids = list(one.active_ids)
    M1 = mixing.masked_matrix(A, one)
    assert mixing.is_doubly_stochastic(M1)
    assert mixing.lambda2(M1[np.ix_(ids, ids)]) < 1.0 - 1e-9


def test_masked_matrix_single_survivor_is_identity():
    A = mixing.ring_matrix(4)
    M = mixing.masked_matrix(A, Membership(4, (False, True, False, False)))
    np.testing.assert_array_equal(M, np.eye(4))


def test_masked_matrix_rejoin_bit_identical():
    """Leaving and rejoining restores the exact operator of the never-left
    mask — full membership is the unmasked matrix itself."""
    A = mixing.ring_matrix(5)
    mem = Membership.full(5).drop(3).rejoin(3)
    np.testing.assert_array_equal(mixing.masked_matrix(A, mem), A)
    assert mixing.masked_schedule("ring", mem) == mixing.schedule("ring", 5)


@pytest.mark.parametrize("topo", ["ring", "circulant2"])
@pytest.mark.parametrize("rounds", [1, 3])
def test_masked_dense_vs_circulant_parity(topo, rounds):
    """The device gossip path (relabeled-cohort circulant schedule on the
    compacted [m, d] block) equals the dense matrix form of the same masked
    schedule."""
    mem = Membership.full(8).drop(1, 6)
    m = mem.n_active
    sched = mixing.masked_schedule(topo, mem)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(m, 5)).astype(np.float32))
    circ = mixing.circulant_mix_op(sched, m, rounds)(x)
    dense = mixing.dense_mix_op(mixing.schedule_matrix(sched, m), rounds)(x)
    np.testing.assert_allclose(np.asarray(circ), np.asarray(dense),
                               rtol=2e-5, atol=2e-6)
    # and the cohort operator is doubly stochastic in its own right
    assert mixing.is_doubly_stochastic(mixing.schedule_matrix(sched, m))


# ---------------------------------------------------------------------------
# FaultSchedule
# ---------------------------------------------------------------------------

def test_fault_dsl_parse_roundtrip():
    fs = FaultSchedule.parse("death:1@5-12, slow:0@3-9x4, flaky:2@4-20p3", 4)
    assert fs.faults == (
        NodeFault(node=1, kind="death", start=5, end=12),
        NodeFault(node=0, kind="slow", start=3, end=9, factor=4.0),
        NodeFault(node=2, kind="flaky", start=4, end=20, period=3))
    # open-ended death
    fs = FaultSchedule.parse("death:3@7", 4)
    assert fs.faults[0].end == -1
    assert not fs.alive(100).active[3]


def test_fault_dsl_rejects_malformed():
    for bad in ("death:1", "explode:0@3", "slow:0@3-9", "flaky:1@2-8",
                "death:0@9-4"):
        with pytest.raises(ValueError):
            FaultSchedule.parse(bad, 4)
    with pytest.raises(ValueError):
        FaultSchedule.parse("death:5@2", 4)  # node out of range


def test_fault_schedule_death_window_and_rejoin():
    fs = FaultSchedule.parse("death:1@5-12", 4)
    assert fs.alive(4).is_full
    assert fs.alive(5).active_ids == (0, 2, 3)
    assert fs.alive(11).active_ids == (0, 2, 3)
    assert fs.alive(12).is_full  # rejoined, bit-identical to never-left
    assert fs.alive(12) == Membership.full(4)
    assert fs.events_between(0, 20) and not fs.events_between(6, 10)


def test_fault_schedule_slow_and_per_node_times():
    fs = FaultSchedule.parse("slow:0@3-9x4,death:2@4-6", 4)
    np.testing.assert_array_equal(fs.time_factors(2), np.ones(4))
    np.testing.assert_array_equal(fs.time_factors(3), [4.0, 1, 1, 1])
    assert fs.round_s_per_node(4, 0.5) == [2.0, 0.5, None, 0.5]
    assert fs.round_s_per_node(9, 0.5) == [0.5] * 4


def test_fault_schedule_flaky_alternation():
    fs = FaultSchedule.parse("flaky:2@4-10p2", 3)
    # starts dead at 4, alternates every 2 steps, window-exclusive at 10
    dead = [not fs.alive(s).active[2] for s in range(3, 11)]
    assert dead == [False, True, True, False, False, True, True, False]


def test_fault_schedule_never_empties():
    fs = FaultSchedule.parse("death:0@2,death:1@3", 2)
    fs.alive(2)
    with pytest.raises(ValueError, match="kills every node"):
        fs.alive(3)


# ---------------------------------------------------------------------------
# Per-node round times + straggler policy
# ---------------------------------------------------------------------------

def test_per_node_round_time_ewma_and_median():
    t = rates.PerNodeRoundTime(3, alpha=0.5)
    assert t.median() is None
    t.observe_all([1.0, 2.0, None])  # dead node skipped
    t.observe_all([3.0, 2.0, None])
    assert t.value(0) == pytest.approx(2.0)  # 0.5*3 + 0.5*1
    assert t.value(2) is None
    assert t.median((0, 1)) == pytest.approx(2.0)
    with pytest.raises(ValueError):
        t.observe_all([1.0, 2.0])  # wrong arity


def test_straggler_wait_mode_is_lockstep():
    pol = rates.StragglerPolicy(4, "wait")
    mem = Membership.full(4).drop(2)
    pol.observe([9.0, 1.0, None, 1.0])
    assert pol.propose(mem) == mem  # never drops anyone
    assert pol.effective_round_s(mem, [9.0, 1.0, None, 1.0]) == 9.0


def test_straggler_drop_debounced_and_recovers():
    pol = rates.StragglerPolicy(4, "drop", slow_factor=2.0, patience=2,
                                alpha=1.0)  # alpha=1: EWMA == last reading
    full = Membership.full(4)
    pol.observe([10.0, 1.0, 1.0, 1.0])
    assert pol.propose(full).is_full        # first verdict: pending
    pol.observe([10.0, 1.0, 1.0, 1.0])
    assert pol.propose(full).active_ids == (1, 2, 3)  # confirmed at patience
    # recovery is debounced by the same patience
    pol.observe([1.0, 1.0, 1.0, 1.0])
    assert pol.propose(full).active_ids == (1, 2, 3)
    pol.observe([1.0, 1.0, 1.0, 1.0])
    assert pol.propose(full).is_full


def test_straggler_deadline_mode_caps_round_time():
    pol = rates.StragglerPolicy(3, "deadline", deadline_s=2.0, patience=1,
                                alpha=1.0)
    full = Membership.full(3)
    pol.observe([5.0, 1.0, 1.0])
    got = pol.propose(full)
    assert got.active_ids == (1, 2)
    assert pol.effective_round_s(full, [5.0, 1.0, 1.0]) == 2.0  # capped
    assert pol.effective_round_s(got, [5.0, 1.0, 1.0]) == 1.0


def test_straggler_never_empties_cohort():
    pol = rates.StragglerPolicy(2, "deadline", deadline_s=1.0, patience=1,
                                alpha=1.0)
    full = Membership.full(2)
    pol.observe([5.0, 3.0])  # everyone blows the deadline
    got = pol.propose(full)
    assert got.n_active == 1 and got.active_ids == (1,)  # least-slow kept


def test_straggler_respects_fault_layer_deaths():
    """Nodes the fault layer killed stay out even if their (frozen) EWMA
    looks fine; the straggler only rules on the survivors."""
    pol = rates.StragglerPolicy(4, "drop", slow_factor=2.0, patience=1,
                                alpha=1.0)
    pol.observe([1.0, None, 1.0, 5.0])
    got = pol.propose(Membership.full(4).drop(1))
    assert got.active_ids == (0, 2)  # 1 stays dead, 3 evicted vs the median


def test_straggler_policy_validation():
    with pytest.raises(ValueError):
        rates.StragglerPolicy(2, "yolo")
    with pytest.raises(ValueError):
        rates.StragglerPolicy(2, "drop", slow_factor=1.0)


# ---------------------------------------------------------------------------
# N-aware BucketLadder (satellite: cohort re-derivation)
# ---------------------------------------------------------------------------

def test_ladder_records_N_and_rejects_stale_snap():
    lad = rates.BucketLadder.from_buckets((8, 16), 4)
    assert lad.N == 4
    assert lad.snap(9, N=4) == 16
    with pytest.raises(ValueError, match="re-derive via `for_cohort`"):
        lad.snap(9, N=3)
    # an N-less ladder (legacy construction) never asserts
    assert rates.BucketLadder((8, 16)).snap(9, N=3) == 16


def test_ladder_rejects_buckets_not_multiple_of_N():
    with pytest.raises(ValueError):
        rates.BucketLadder((6, 8), N=4)


def test_ladder_for_cohort_rederives_and_identity():
    lad = rates.BucketLadder.from_buckets((8, 16), 4)
    assert lad.for_cohort(4) is lad  # same cohort: same object, same compiles
    sub = lad.for_cohort(3)
    assert sub.N == 3 and sub.buckets == (9, 18)
    assert all(b % 3 == 0 for b in sub.buckets)
    # horizon ceiling re-clips at the new N
    sub = lad.for_cohort(3, horizon_samples=100.0)
    assert max(sub.buckets) <= 10 and all(b % 3 == 0 for b in sub.buckets)


def test_ladder_cohort_rederivation_from_base_is_stable():
    """Deriving from the FULL-membership base ladder is idempotent per
    cohort — the discipline the driver follows so a rejoin restores the
    exact original buckets (chained derivation would drift: 8@N4 -> 9@N3
    -> 12@N4)."""
    base = rates.BucketLadder.from_buckets((8, 16), 4)
    drifted = base.for_cohort(3).for_cohort(4)
    assert drifted.buckets != base.buckets  # the hazard is real
    assert base.for_cohort(3) == base.for_cohort(3)
    assert base.for_cohort(4) is base


# ---------------------------------------------------------------------------
# Estimator coherence across membership eras
# ---------------------------------------------------------------------------

def test_observe_cohort_keeps_one_fit_across_eras():
    """Rounds timed at a partial cohort enter the affine fit at the
    equivalent full-cohort regressor x = B*N/m, so ground truth observed
    across two membership eras still recovers (R_p, R_c)."""
    N, R, Rp, Rc = 4, 8, 1e5, 2e3
    est = rates.RoundTimeEstimator(N, R, window=64)
    for B in (32, 64, 128):
        est.observe(B, B / (N * Rp) + R / Rc)          # full-cohort era
    for B in (24, 48, 96):
        est.observe_cohort(B, 3, B / (3 * Rp) + R / Rc)  # one node down
    got = est.estimate()
    assert got is not None
    assert got.Rp == pytest.approx(Rp, rel=1e-6)
    assert got.Rc == pytest.approx(Rc, rel=1e-6)


# ---------------------------------------------------------------------------
# swap_membership on the governed pipeline
# ---------------------------------------------------------------------------

def _pipe(stream=StreamConfig(), batch=10, n=5, **kw):
    return StreamingPipeline(lambda rng, k: {"x": rng.normal(size=(k, 2))},
                             stream, n_nodes=n, rounds_R=1, batch=batch, **kw)


def test_swap_membership_initial_stamp_keeps_exact_B():
    pipe = _pipe(batch=10)
    got = pipe.swap_membership(Membership.full(5))
    assert got.B == 10 and got.membership == Membership.full(5)
    # idempotent: same cohort is a no-op
    assert pipe.swap_membership(Membership.full(5)) is got


def test_swap_membership_ungoverned_rounds_B_to_cohort():
    pipe = _pipe(batch=10)
    pipe.swap_membership(Membership.full(5))
    got = pipe.swap_membership(Membership.full(5).drop(4))
    assert got.B == 12 and got.B % 4 == 0  # ceil(10/4)*4
    assert got.membership.n_active == 4
    # the next superstep is dealt at the cohort width
    assert pipe.next_superstep(2)["x"].shape == (2, 12, 2)
    assert pipe.last_superstep_plan.membership.n_active == 4


def test_swap_membership_governed_reinverts_eq4_at_cohort():
    """The plan is re-derived at N = n_active: fewer nodes means less
    aggregate compute, so the keep-up mu grows for the same stream."""
    # aggregate compute: 4 nodes keep up with the stream (4*300 > 1e3),
    # 3 nodes cannot (3*300 < 1e3) — the swap must notice immediately
    stream = StreamConfig(streaming_rate=1e3, processing_rate=300.0,
                          comms_rate=1e6)
    pipe = _pipe(stream=stream, batch=None, n=4)
    pipe.swap_membership(Membership.full(4))
    full_plan = pipe.plan
    assert full_plan.mu == 0 and full_plan.regime == "resourceful"
    got = pipe.swap_membership(Membership.full(4).drop(3))
    assert got.B % 3 == 0
    assert got.mu > 0 and got.regime == "under-provisioned"
    # returning to full membership re-derives the original plan
    back = pipe.swap_membership(Membership.full(4))
    assert (back.B, back.mu) == (full_plan.B, full_plan.mu)


def test_swap_membership_snaps_onto_cohort_ladder():
    base = rates.BucketLadder.from_buckets((10, 20), 5)
    pipe = _pipe(batch=10, ladder=base)
    pipe.swap_membership(Membership.full(5), base)
    got = pipe.swap_membership(Membership.full(5).drop(0), base.for_cohort(4))
    assert got.B in (12, 20) and got.B % 4 == 0
    assert pipe.ladder.N == 4


# ---------------------------------------------------------------------------
# elastic_superstep gather/scatter wrapper
# ---------------------------------------------------------------------------

def test_elastic_superstep_gathers_active_rows_only():
    n, d = 4, 3
    state = {"w": jnp.arange(float(n * d)).reshape(n, d), "t": jnp.asarray(7)}
    ids = jnp.asarray([0, 2, 3], jnp.int32)

    def cohort_fn(sub, batches):
        assert sub["w"].shape == (3, d)  # dense cohort block
        return {"w": sub["w"] + 1.0, "t": sub["t"] + 1}, {"m": sub["w"].sum()}

    out, metrics = jax.jit(elastic_superstep(cohort_fn, n))(state, ids, {})
    want = np.arange(float(n * d)).reshape(n, d)
    want[[0, 2, 3]] += 1.0
    np.testing.assert_array_equal(np.asarray(out["w"]), want)  # row 1 frozen
    assert int(out["t"]) == 8  # scalar leaves pass straight through


# ---------------------------------------------------------------------------
# Driver under churn (fake clock, trace-counted)
# ---------------------------------------------------------------------------

class _FakeClock:
    def __init__(self, dt):
        self.t, self.dt = 0.0, dt

    def __call__(self):
        self.t += self.dt
        return self.t


def _elastic_driver(faults=None, *, stream=StreamConfig(), gov=None,
                    clock=None, batch=10, n=5, prefetch=0, traces=None,
                    horizon=None):
    run_cfg = PCARunConfig(
        pca=FIG7, averaging=AveragingConfig(mode="gossip", rounds=2),
        stream=stream)
    builder = krasulina.krasulina_superstep_builder(
        run_cfg.averaging, n, lambda t: 10.0 / t)
    if traces is not None:
        builder = wrap_builder(
            builder, traces,
            tag=lambda B, mem: (B, n if mem is None else mem.n_active))

    w0 = jax.random.normal(jax.random.PRNGKey(0), (FIG7.dim,))
    state = krasulina.init_krasulina_state(w0 / jnp.linalg.norm(w0),
                                           run_cfg.averaging, n)
    return StreamingDriver(
        run_cfg, None, state, make_pca_host_sampler(make_pca_stream(FIG7)),
        superstep_builder=builder, n_nodes=n, batch=batch, faults=faults,
        horizon=horizon,
        engine=EngineConfig(superstep=2, prefetch_depth=prefetch,
                            replan_every=1, warmup_supersteps=0,
                            warmup_per_bucket=0,
                            governor=gov or GovernorConfig()),
        clock=clock or _FakeClock(1e-3))


def test_driver_requires_decentralized_for_elastic():
    run_cfg = PCARunConfig(pca=FIG7, averaging=AveragingConfig(mode="exact"))
    w0 = jax.random.normal(jax.random.PRNGKey(0), (FIG7.dim,))
    state = krasulina.init_krasulina_state(w0, run_cfg.averaging, 4)
    with pytest.raises(ValueError, match="decentralized"):
        StreamingDriver(run_cfg, None, state,
                        make_pca_host_sampler(make_pca_stream(FIG7)),
                        n_nodes=4, batch=8,
                        faults=FaultSchedule.parse("death:1@2-4", 4))


def test_driver_rejects_mismatched_fault_schedule():
    with pytest.raises(ValueError, match="covers 3 nodes"):
        _elastic_driver(FaultSchedule.parse("death:1@2-4", 3), n=5)


def test_driver_rejects_legacy_builder_for_partial_cohort():
    run_cfg = PCARunConfig(
        pca=FIG7, averaging=AveragingConfig(mode="gossip", rounds=2))
    full = krasulina.build_krasulina_superstep(run_cfg.averaging, 4,
                                               lambda t: 10.0 / t)
    w0 = jax.random.normal(jax.random.PRNGKey(0), (FIG7.dim,))
    state = krasulina.init_krasulina_state(w0 / jnp.linalg.norm(w0),
                                           run_cfg.averaging, 4)
    driver = StreamingDriver(
        run_cfg, None, state, make_pca_host_sampler(make_pca_stream(FIG7)),
        superstep_builder=lambda B: full, n_nodes=4, batch=8,
        faults=FaultSchedule.parse("death:1@1", 4),
        engine=EngineConfig(superstep=1, prefetch_depth=0, replan_every=0),
        clock=_FakeClock(1e-3))
    driver.run(1)  # full membership: fine
    with pytest.raises(ValueError, match="membership-aware"):
        driver.run(1)  # node 1 dies: the 1-arg builder cannot serve it


def test_driver_churn_death_rejoin_zero_recompile():
    """Acceptance: a FaultSchedule kills node 4 mid-stream and rejoins it
    later; the run completes, dealing each era at a cohort-divisible B, and
    the rejoin superstep reuses the full-cohort executable — zero retrace."""
    traces = []
    faults = FaultSchedule.parse("death:4@2-5", 5)
    driver = _elastic_driver(faults, traces=traces)
    driver.run(5)  # supersteps 0..4: full, full, drop-era x3
    assert driver.membership.n_active == 4
    assert driver.pipeline.plan.B == 12  # ceil(10/4)*4
    assert set(traces) == {(10, 5), (12, 4)}
    n_before = len(traces)
    driver.run(3)  # superstep 5 rejoins: back to the (10, 5) executable
    assert driver.membership.is_full
    assert driver.pipeline.plan.B == 10
    assert len(traces) == n_before, "rejoin must not retrace"
    assert driver.compiled_signatures == ((10, 5), (12, 4))
    # every superstep ran under the cohort that dealt it
    eras = [(r["bucket"], r["n_active"]) for r in driver.history]
    assert eras == [(10, 5)] * 2 + [(12, 4)] * 3 + [(10, 5)] * 3
    # membership events recorded the swap plans
    evs = driver.membership_events
    assert [e["superstep"] for e in evs] == [2, 5]
    assert evs[0]["to"].n_active == 4 and evs[1]["to"].is_full
    assert evs[0]["plan"].B == 12 and evs[1]["plan"].B == 10
    assert all(np.isfinite(r["metrics"]["consensus_err"])
               for r in driver.history)


def test_driver_flaky_node_same_size_cohorts_share_executable():
    """Flaky churn revisits the same cohort SIZE with different masks; the
    runtime-ids design means they all share one executable per (B, m)."""
    traces = []
    faults = FaultSchedule(5, (
        NodeFault(node=1, kind="death", start=1, end=3),
        NodeFault(node=3, kind="death", start=4, end=6)))
    driver = _elastic_driver(faults, traces=traces)
    driver.run(8)
    # two distinct 4-node masks, one (12, 4) executable
    assert set(traces) == {(10, 5), (12, 4)}
    masks = {e["to"] for e in driver.membership_events
             if not e["to"].is_full}
    assert len(masks) == 2
    assert driver.compiled_signatures == ((10, 5), (12, 4))


def test_driver_governed_replan_follows_cohort():
    """Under a governed stream the swap re-inverts eq. 4 at the cohort
    immediately (within the same superstep — well inside hysteresis
    patience), and subsequent re-plans target N = n_active."""
    stream = StreamConfig(streaming_rate=1e3, processing_rate=1e6,
                          comms_rate=1e6)
    faults = FaultSchedule.parse("death:2@2-6", 5)
    driver = _elastic_driver(faults, stream=stream, batch=None,
                             clock=_FakeClock(50.0))
    driver.run(8)
    evs = driver.membership_events
    assert [e["superstep"] for e in evs] == [2, 6]
    for e in evs:
        m = e["to"].n_active
        assert e["plan"].membership == e["to"]
        assert e["plan"].B % m == 0
        assert e["plan"].mu >= 0
    # the slow clock drives the governor under-provisioned; its re-plans
    # carry the live cohort, not the boot-time membership
    replans = [r["replanned"] for r in driver.history if "replanned" in r]
    assert replans and all(p.membership is not None for p in replans)
    drop_era = [r for r in driver.history if r["n_active"] == 4]
    assert drop_era and all(r["plan"].membership.n_active == 4
                            for r in drop_era)


def test_driver_straggler_drop_and_readmit():
    """A sustained 10x slowdown evicts the node once its EWMA round time
    crosses the threshold and `patience` consecutive verdicts agree;
    recovery readmits it the same way. (The EWMA smooths the verdict, so
    the drop lands a few supersteps into the slowdown — sustained, not
    instantaneous, eviction is the point of the policy.)"""
    faults = FaultSchedule.parse("slow:0@2-30x10", 5)
    gov = GovernorConfig(straggler_policy="drop", straggler_slow_factor=2.0,
                         straggler_patience=2)
    driver = _elastic_driver(faults, gov=gov)
    driver.run(50)
    evs = driver.membership_events
    assert evs, "the straggler was never dropped"
    assert evs[0]["to"].active_ids == (1, 2, 3, 4)
    assert 2 < evs[0]["superstep"] < 30  # dropped while actually slow
    # recovery at step 30 readmits once the EWMA decays below threshold
    assert evs[-1]["to"].is_full
    assert driver.membership.is_full
    # the drop-era plan was dealt at the 4-node cohort
    assert evs[0]["plan"].B % 4 == 0


def test_driver_straggler_without_faults_runs_full_membership():
    """A drop policy with no fault layer (and uniform timings) never
    produces a membership event, but the elastic path is live."""
    gov = GovernorConfig(straggler_policy="drop", straggler_patience=2)
    driver = _elastic_driver(None, gov=gov)
    driver.run(4)
    assert driver.membership == Membership.full(5)
    assert driver.membership_events == []
    assert driver.compiled_signatures == ((10, 5),)


def test_driver_churn_with_prefetch_ring_drains_old_cohort():
    """With a prefetch ring, supersteps dealt before a death drain under the
    membership that dealt them (their samples were drawn); accounting and
    executables stay coherent."""
    faults = FaultSchedule.parse("death:3@2-900", 5)
    driver = _elastic_driver(faults, prefetch=2)
    with driver:
        driver.run(8)
    eras = [(r["bucket"], r["n_active"]) for r in driver.history]
    # monotone era boundary: full-cohort items all drain before drop-era ones
    assert eras == sorted(eras, key=lambda e: -e[1])
    assert eras[0] == (10, 5) and eras[-1] == (12, 4)
    assert sum(1 for e in eras if e == (10, 5)) >= 2
    for r in driver.history:
        assert r["bucket"] % r["n_active"] == 0


def test_driver_rejoin_sync_pulls_node_to_cohort_mean():
    """`_sync_rejoined` overwrites the rejoining rows with the donors' mean
    on every [N, ...] leaf and leaves scalars alone."""
    driver = _elastic_driver(FaultSchedule.parse("death:1@1-2", 5))
    w = np.arange(15.0).reshape(5, 3)
    driver.state = {"w": jnp.asarray(w), "t": jnp.asarray(3)}
    driver._sync_rejoined(Membership.full(5).drop(1, 3),
                          Membership.full(5).drop(3))
    got = np.asarray(driver.state["w"])
    donors_mean = w[[0, 2, 4]].mean(0)
    np.testing.assert_allclose(got[1], donors_mean)
    np.testing.assert_array_equal(got[[0, 2, 3, 4]], w[[0, 2, 3, 4]])
    assert int(driver.state["t"]) == 3


def test_driver_no_rejoin_sync_keeps_stale_row():
    gov = GovernorConfig(sync_on_rejoin=False)
    driver = _elastic_driver(FaultSchedule.parse("death:1@1-2", 5), gov=gov)
    w = np.arange(15.0).reshape(5, 3)
    driver.state = {"w": jnp.asarray(w)}
    prev = Membership.full(5).drop(1)
    driver._membership = prev
    driver._apply_membership(2)  # rejoin step: sync gated off
    np.testing.assert_array_equal(np.asarray(driver.state["w"]), w)
    assert driver.membership.is_full


# ---------------------------------------------------------------------------
# PR 7 regression: straggler EWMAs seed from measured rounds, not a constant
# ---------------------------------------------------------------------------

def test_straggler_seed_from_measured_times_detects_faster():
    """Seeding every node's EWMA with the same synthetic constant (the old
    1.0 s fallback) masks slow/fast ratios until the seed decays at
    0.5^k — detection of a real straggler is delayed by many rounds. Seeding
    from the first MEASURED observation detects at the patience bound."""
    def rounds_to_evict(synthetic_seed):
        pol = rates.StragglerPolicy(4, "drop", slow_factor=2.0, patience=2)
        full = Membership.full(4)
        if synthetic_seed:  # pre-fix driver behavior: base = 1.0 s fallback
            pol.observe([1.0, 1.0, 1.0, 1.0])
            pol.propose(full)
        # true times: 1 ms rounds, node 0 sustained 10x slow
        for k in range(1, 40):
            pol.observe([1e-2, 1e-3, 1e-3, 1e-3])
            if not pol.propose(full).is_full:
                return k
        raise AssertionError("straggler never detected")

    fast = rounds_to_evict(synthetic_seed=False)
    slow = rounds_to_evict(synthetic_seed=True)
    assert fast == 2  # patience consecutive verdicts, no warm-up lag
    assert slow >= fast + 4, (fast, slow)  # the polluted EWMA delays eviction


def test_driver_withholds_observation_until_first_measured_round():
    """The driver feeds the straggler policy only times scaled from MEASURED
    rounds: before the first timed superstep nothing is observed (no
    synthetic seed), and afterwards every EWMA is on the measured-ms scale,
    not a made-up 1.0 s constant."""
    faults = FaultSchedule.parse("slow:0@0-30x10", 5)
    gov = GovernorConfig(straggler_policy="drop", straggler_slow_factor=2.0,
                         straggler_patience=2)
    driver = _elastic_driver(faults, gov=gov)
    assert not driver._straggler.times.seeded
    driver.run(1)  # membership for superstep 0 resolves pre-measurement
    driver.run(5)
    times = driver._straggler.times
    assert times.seeded
    vals = [times.value(i) for i in range(5) if times.value(i) is not None]
    assert vals and max(vals) < 0.5, vals  # ms-scale, no 1.0 s pollution
    # and the sustained straggler was evicted promptly (patience + seed lag
    # of the measured base only)
    evs = driver.membership_events
    assert evs and evs[0]["to"].active_ids == (1, 2, 3, 4)
    assert evs[0]["superstep"] <= 4


# ---------------------------------------------------------------------------
# PR 7: masked_matrix falls back to cohort relabeling when the induced
# subgraph disconnects
# ---------------------------------------------------------------------------

def test_masked_matrix_disconnected_drop_set_relabels_cohort():
    """Adversarial drop set: killing alternate nodes of a ring leaves the
    survivors with NO edges among themselves (the induced subgraph is fully
    disconnected). The dense mask must not silently return a stalled
    operator (lambda_2 = 1); it relabels the cohort onto its own ring."""
    A = mixing.ring_matrix(6)
    mem = Membership.full(6).drop(1, 3, 5)
    ids = list(mem.active_ids)
    M = mixing.masked_matrix(A, mem)
    assert mixing.is_doubly_stochastic(M)
    block = M[np.ix_(ids, ids)]
    # the active block contracts (relabeled ring), instead of stalling at I
    assert mixing.lambda2(block) < 1.0 - 1e-9
    np.testing.assert_allclose(block, mixing.ring_matrix(3), atol=1e-12)
    # dead nodes still hold their state exactly
    for i in (1, 3, 5):
        e = np.zeros(6)
        e[i] = 1.0
        np.testing.assert_array_equal(M[i], e)
        np.testing.assert_array_equal(M[:, i], e)


def test_masked_matrix_partitioned_drop_set_relabels_cohort():
    """A drop set that PARTITIONS the survivors (two arcs of a ring that
    cannot reach each other) also triggers the relabeling fallback — the
    Metropolis block would be block-diagonal with lambda_2 = 1."""
    A = mixing.ring_matrix(8)
    mem = Membership.full(8).drop(0, 4)  # survivors split into 1-3 and 5-7
    ids = list(mem.active_ids)
    M = mixing.masked_matrix(A, mem)
    assert mixing.is_doubly_stochastic(M)
    assert mixing.lambda2(M[np.ix_(ids, ids)]) < 1.0 - 1e-9
