"""The PCA track on the consensus + streaming engine (PR 4):

* gossip-averaged D-Krasulina converges to the exact-averaging oracle as the
  consensus tightens (R large => per-node iterates match `jnp.mean` step 6
  within tolerance) on the Fig. 7 config
* the fused xi+gossip kernel (Pallas, interpret mode here) matches the strict
  per-round XLA oracle, including ragged-d padding
* the K-round Krasulina superstep is exactly K sequential rounds, and the
  closed-loop governor raises mu on the PCA workload under a fake slow clock
* Theorem 5 stepsize/Q sanity on the Fig. 7 constants
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AveragingConfig, StreamConfig
from repro.configs.paper_pca import FIG7, PCARunConfig
from repro.core import krasulina, mixing, problems, rates
from repro.data.synthetic import make_pca_host_sampler, make_pca_stream
from repro.kernels import ops, ref
from repro.train.driver import EngineConfig, StreamingDriver


def _fig7_setup(seed=0):
    stream = make_pca_stream(FIG7)
    metric = lambda w: problems.pca_excess_risk(w, stream.cov, stream.lambda1)
    w0 = jax.random.normal(jax.random.PRNGKey(seed), (FIG7.dim,))
    return stream, metric, w0 / jnp.linalg.norm(w0)


# ---------------------------------------------------------------------------
# Gossip vs exact oracle
# ---------------------------------------------------------------------------

def test_gossip_tracks_exact_oracle_with_tight_consensus():
    """R large enough that A^R ~ 1/N 11^T: the gossip trajectory must match
    the exact-averaging oracle (Fig. 7 config) within float tolerance, and
    the oracle itself is the `averaging=None` path of the same family."""
    stream, metric, w0 = _fig7_setup()
    N, B, steps = 4, 100, 300
    step = lambda t: 10.0 / t
    exact = krasulina.run_dm_krasulina(stream.draw, w0, N=N, B=B, steps=steps,
                                       stepsize=step, trace_metric=metric)
    # ring on N=4: lambda_2 = 1/3, so R=12 contracts disagreement by ~2e-6
    gossip = krasulina.run_d_krasulina(
        stream.draw, w0, N=N, B=B, steps=steps, stepsize=step,
        averaging=AveragingConfig(mode="gossip", rounds=12),
        trace_metric=metric)
    np.testing.assert_allclose(np.asarray(gossip.w), np.asarray(exact.w),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gossip.trace_metric[-1]),
                               np.asarray(exact.trace_metric[-1]),
                               rtol=1e-2, atol=1e-4)
    # per-node iterates are in near-consensus
    spread = float(jnp.max(jnp.abs(gossip.w_nodes - gossip.w[None])))
    assert spread < 1e-3, spread
    # and both found the top eigenvector
    assert float(exact.trace_metric[-1]) < 1e-2
    assert float(gossip.trace_metric[-1]) < 1e-2


def test_gossip_loose_consensus_still_converges_with_spread():
    """R=1 on a ring leaves visible node disagreement (the paper's inexact
    regime) but the node-mean iterate still converges."""
    stream, metric, w0 = _fig7_setup()
    res = krasulina.run_d_krasulina(
        stream.draw, w0, N=8, B=80, steps=600, stepsize=lambda t: 10.0 / t,
        averaging=AveragingConfig(mode="gossip", rounds=1),
        trace_metric=metric)
    spread = float(jnp.max(jnp.linalg.norm(res.w_nodes - res.w[None], axis=1)))
    assert spread > 1e-6  # inexact averaging is live
    assert float(res.trace_metric[-1]) < 5e-2


def test_exact_path_is_mean_oracle_shape_contract():
    stream, metric, w0 = _fig7_setup()
    res = krasulina.run_d_krasulina(stream.draw, w0, N=5, B=50, steps=10,
                                    stepsize=lambda t: 10.0 / t,
                                    trace_metric=metric)
    assert res.w_nodes.shape == (5, FIG7.dim)
    # exact mode: every node carries the shared iterate
    np.testing.assert_array_equal(np.asarray(res.w_nodes),
                                  np.tile(np.asarray(res.w)[None], (5, 1)))


# ---------------------------------------------------------------------------
# Fused xi+gossip kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N,Bn,d,R,block_d", [
    (4, 2, 32, 1, 32),
    (8, 4, 70, 3, 32),   # ragged d: pad columns must stay inert
    (8, 3, 256, 8, 64),
])
def test_xi_gossip_kernel_matches_per_round_oracle(N, Bn, d, R, block_d):
    w = jax.random.normal(jax.random.PRNGKey(0), (N, d))
    z = jax.random.normal(jax.random.PRNGKey(1), (N, Bn, d))
    sched = mixing.schedule("ring", N)
    oracle = ref.gossip_mix_ref(jax.vmap(ref.krasulina_xi_ref)(w, z), sched, R)
    from repro.kernels.krasulina_update import krasulina_xi_gossip_pallas
    shifts = tuple(s for s, _ in sched)
    weights = tuple(wt for _, wt in sched)
    kern = krasulina_xi_gossip_pallas(w, z, shifts, weights, R,
                                      block_d=block_d, interpret=True)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(oracle),
                               rtol=1e-4, atol=1e-5)
    # the dispatching wrapper's XLA path (composed schedule) agrees too
    xla = ops.krasulina_xi_gossip(w, z, sched, R)
    np.testing.assert_allclose(np.asarray(xla), np.asarray(oracle),
                               rtol=1e-4, atol=1e-5)


def test_xi_gossip_zero_rounds_is_plain_xi():
    w = jax.random.normal(jax.random.PRNGKey(2), (4, 16))
    z = jax.random.normal(jax.random.PRNGKey(3), (4, 3, 16))
    sched = mixing.schedule("ring", 4)
    got = ops.krasulina_xi_gossip(w, z, sched, 0)
    want = jax.vmap(ref.krasulina_xi_ref)(w, z)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_run_d_krasulina_fused_matches_mix_path():
    """fuse_xi=True (the combined kernel's dispatch path) and fuse_xi=False
    (MixOp over vmap'd xi) are the same algorithm."""
    stream, metric, w0 = _fig7_setup()
    avg = AveragingConfig(mode="gossip", rounds=4)
    kw = dict(N=4, B=40, steps=50, stepsize=lambda t: 10.0 / t,
              averaging=avg, trace_metric=metric, seed=9)
    a = krasulina.run_d_krasulina(stream.draw, w0, fuse_xi=True, **kw)
    b = krasulina.run_d_krasulina(stream.draw, w0, fuse_xi=False, **kw)
    np.testing.assert_allclose(np.asarray(a.w_nodes), np.asarray(b.w_nodes),
                               rtol=1e-4, atol=1e-5)


def test_run_d_krasulina_rejects_hierarchical():
    """Pod-structured averaging needs a mesh; the PCA track must refuse it
    instead of silently running flat gossip."""
    stream, metric, w0 = _fig7_setup()
    avg = AveragingConfig(mode="hierarchical", rounds=2)
    with pytest.raises(ValueError, match="exact|gossip"):
        krasulina.run_d_krasulina(stream.draw, w0, N=4, B=40, steps=2,
                                  stepsize=lambda t: 1.0 / t, averaging=avg)
    with pytest.raises(ValueError, match="exact|gossip"):
        krasulina.build_krasulina_superstep(avg, 4, lambda t: 1.0 / t)


def test_run_d_krasulina_stochastic_noise_fresh_per_step():
    """int8_stoch gossip must not replay the same per-round noise every scan
    step: with the round counter folded into the key, two consecutive rounds
    fed IDENTICAL samples produce different mixed updates."""
    avg = AveragingConfig(mode="gossip", rounds=2, quantization="int8_stoch")
    mix = krasulina.make_gossip_mix(avg, 4)
    w = jax.random.normal(jax.random.PRNGKey(0), (4, 10))
    z = jax.random.normal(jax.random.PRNGKey(1), (4, 5, 10))
    h1 = krasulina._gossip_xi(w, z, mix, False, jnp.asarray(1))
    h1b = krasulina._gossip_xi(w, z, mix, False, jnp.asarray(1))
    h2 = krasulina._gossip_xi(w, z, mix, False, jnp.asarray(2))
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h1b))
    assert not np.array_equal(np.asarray(h1), np.asarray(h2))


def test_run_d_krasulina_quantized_gossip_runs():
    """Quantized consensus (Section VI) composes with the PCA track; the
    combined kernel must refuse to fuse (nonlinear compressor)."""
    stream, metric, w0 = _fig7_setup()
    avg = AveragingConfig(mode="gossip", rounds=4, quantization="sign")
    mix = krasulina.make_gossip_mix(avg, 4)
    assert krasulina._resolve_fuse_xi(mix, None) is False
    res = krasulina.run_d_krasulina(
        stream.draw, w0, N=4, B=40, steps=200, stepsize=lambda t: 10.0 / t,
        averaging=avg, trace_metric=metric)
    assert np.isfinite(float(res.trace_metric[-1]))
    assert float(res.trace_metric[-1]) < float(res.trace_metric[0])


# ---------------------------------------------------------------------------
# Superstep + driver integration
# ---------------------------------------------------------------------------

def test_krasulina_superstep_equals_sequential_rounds():
    """One K-round superstep == K sequential round_fn applications (gossip
    mode, explicit batches)."""
    stream, metric, w0 = _fig7_setup()
    N, Bn, K = 4, 5, 3
    avg = AveragingConfig(mode="gossip", rounds=4)
    superstep = krasulina.build_krasulina_superstep(
        avg, N, lambda t: 10.0 / t, metric=metric)
    state0 = krasulina.init_krasulina_state(w0, avg, N)
    rng = np.random.default_rng(0)
    batches = {"z": jnp.asarray(
        rng.standard_normal((K, N, Bn, FIG7.dim)).astype(np.float32))}
    sup_state, ms = jax.jit(superstep)(state0, batches)

    seq_state = state0
    seq_metrics = []
    for k in range(K):
        seq_state, m = superstep(
            seq_state, {"z": batches["z"][k:k + 1]})
        seq_metrics.append(float(m["metric"][0]))
    assert int(sup_state.t) == K == int(seq_state.t)
    np.testing.assert_allclose(np.asarray(sup_state.w),
                               np.asarray(seq_state.w), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(ms["metric"]),
                               np.asarray(seq_metrics), rtol=1e-5, atol=1e-6)
    assert ms["metric"].shape == (K,) == ms["consensus_err"].shape


class _FakeClock:
    def __init__(self, dt):
        self.t, self.dt = 0.0, dt

    def __call__(self):
        self.t += self.dt
        return self.t


@pytest.mark.parametrize("dt,expect_discard", [(1e-4, False), (50.0, True)])
def test_pca_driver_governor_adapts_mu(dt, expect_discard):
    """The closed-loop governor provisions the PCA stream exactly as it does
    logreg: a fake slow clock must push the plan into the under-provisioned
    regime (mu > 0, Theorem 5's discard knob) while B stays shape-stable."""
    stream, metric, w0 = _fig7_setup()
    run_cfg = PCARunConfig(
        pca=FIG7, averaging=AveragingConfig(mode="gossip", rounds=2),
        stream=StreamConfig(streaming_rate=1e3, processing_rate=1e6,
                            comms_rate=1e6))
    N = 4
    superstep = krasulina.build_krasulina_superstep(
        run_cfg.averaging, N, lambda t: 10.0 / t, metric=metric)
    state = krasulina.init_krasulina_state(w0, run_cfg.averaging, N)
    driver = StreamingDriver(
        run_cfg, None, state, make_pca_host_sampler(stream),
        superstep_fn=superstep, n_nodes=N, batch=100,
        engine=EngineConfig(superstep=2, prefetch_depth=0, replan_every=1,
                            warmup_supersteps=0),
        clock=_FakeClock(dt))
    assert driver.pipeline.plan.mu == 0
    _, history = driver.run(3)
    assert len(history) == 3
    assert all(np.isfinite(rec["metrics"]["metric"]) for rec in history)
    if expect_discard:
        assert driver.pipeline.plan.mu > 0
        assert driver.pipeline.plan.regime == "under-provisioned"
        assert driver.pipeline.plan.B == 100  # shape-stable adaptation
        assert driver.pipeline.samples_discarded > 0
    else:
        assert driver.pipeline.plan.mu == 0
        assert driver.pipeline.samples_discarded == 0


def test_pca_driver_with_prefetch_converges():
    """End-to-end: prefetch ring + K-round superstep reduce the Fig. 7
    excess risk; counters stay coherent with the consumed rounds."""
    stream, metric, w0 = _fig7_setup()
    run_cfg = PCARunConfig(averaging=AveragingConfig(mode="gossip", rounds=4))
    N, K = 4, 4
    superstep = krasulina.build_krasulina_superstep(
        run_cfg.averaging, N, lambda t: 10.0 / t, metric=metric)
    state = krasulina.init_krasulina_state(w0, run_cfg.averaging, N)
    with StreamingDriver(run_cfg, None, state, make_pca_host_sampler(stream),
                         superstep_fn=superstep, n_nodes=N, batch=100,
                         engine=EngineConfig(superstep=K, prefetch_depth=2,
                                             replan_every=0)) as driver:
        final, history = driver.run(15)
    assert [rec["round"] for rec in history] == [K * (i + 1) for i in range(15)]
    assert history[-1]["counters"].samples_consumed == 15 * K * 100
    assert int(final.t) == 15 * K
    assert history[-1]["metrics"]["metric"] < history[0]["metrics"]["metric"]
    assert history[-1]["metrics"]["metric"] < 5e-2


# ---------------------------------------------------------------------------
# Theorem 5 constants
# ---------------------------------------------------------------------------

def test_theorem5_Q_and_stepsize_sanity_fig7():
    """eq. 22 on the Fig. 7 constants: Q is finite, positive, monotone in the
    problem hardness (d, kappa=lambda1/gap, sigma_B^2), and the resulting
    c/(Q+t) schedule is decreasing with eta_1 << gap (the regime Theorem 5's
    induction needs)."""
    kappa = FIG7.lambda1 / FIG7.eigengap
    c = 10.0  # the c0 > 2 constant the experiments use
    Q = krasulina.theorem5_Q(FIG7.dim, kappa, sigma_B2=1.0, c=c)
    assert np.isfinite(Q) and Q > 0
    assert krasulina.theorem5_Q(2 * FIG7.dim, kappa, 1.0, c) > Q
    assert krasulina.theorem5_Q(FIG7.dim, 2 * kappa, 1.0, c) > Q
    assert krasulina.theorem5_Q(FIG7.dim, kappa, 2.0, c) > Q
    etas = [rates.krasulina_stepsize(t, c, Q) for t in (1, 10, 100, 10_000)]
    assert all(a > b for a, b in zip(etas, etas[1:]))  # decreasing
    assert etas[0] == pytest.approx(c / (Q + 1))
    assert etas[0] < FIG7.eigengap  # theory-scale Q keeps eta_1 tiny
