"""Property tests for consensus mixing (paper eq. 17): double stochasticity,
|lambda_2|^R geometric contraction, and equivalence of the device-path circulant
schedule with its dense-matrix form.
"""
import numpy as np
import pytest
from _prop import given, settings, st

import jax.numpy as jnp

from repro.configs.base import AveragingConfig
from repro.core import averaging, mixing


@given(st.integers(2, 64), st.sampled_from(["ring", "circulant2", "torus"]))
@settings(max_examples=40, deadline=None)
def test_schedule_doubly_stochastic(n, topo):
    A = mixing.schedule_matrix(mixing.schedule(topo, n), n)
    assert mixing.is_doubly_stochastic(A)
    assert mixing.lambda2(A) < 1.0 - 1e-9  # connected => contraction


@given(st.integers(8, 40), st.integers(1, 12))
@settings(max_examples=25, deadline=None)
def test_consensus_contraction_rate(n, rounds):
    """||A^R v - vbar|| <= lambda_2^R ||v - vbar|| for symmetric mixing."""
    A = mixing.schedule_matrix(mixing.schedule("ring", n), n)
    lam2 = mixing.lambda2(A)
    rng = np.random.default_rng(0)
    v = rng.normal(size=(n, 3))
    vbar = v.mean(0, keepdims=True)
    out = np.linalg.matrix_power(A, rounds) @ v
    lhs = np.linalg.norm(out - vbar)
    rhs = (lam2**rounds) * np.linalg.norm(v - vbar) + 1e-9
    assert lhs <= rhs * (1 + 1e-6)


@given(st.integers(10, 60))
@settings(max_examples=15, deadline=None)
def test_expander_matrix(n):
    A = mixing.random_regular_expander(n, deg=4, seed=1)
    assert mixing.is_doubly_stochastic(A)
    assert mixing.lambda2(A) < 1.0


@pytest.mark.parametrize("topo", ["ring", "circulant2", "torus"])
@pytest.mark.parametrize("rounds", [1, 3])
def test_device_gossip_matches_dense(topo, rounds):
    """gossip_average (roll-based, device path) == dense A^R matmul."""
    n = 12
    rng = np.random.default_rng(2)
    v = rng.normal(size=(n, 5)).astype(np.float32)
    cfg = AveragingConfig(mode="gossip", rounds=rounds, topology=topo)
    got = averaging.gossip_average({"g": jnp.asarray(v)}, n, cfg)["g"]
    A = mixing.schedule_matrix(mixing.schedule(topo, n), n)
    want = np.linalg.matrix_power(A, rounds) @ v
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-6)


def test_exact_average():
    v = jnp.arange(12.0).reshape(6, 2)
    out = averaging.exact_average({"g": v})["g"]
    np.testing.assert_allclose(np.asarray(out), np.tile(np.asarray(v).mean(0), (6, 1)))


def test_hierarchical_average():
    n, pods = 8, 2
    rng = np.random.default_rng(3)
    v = rng.normal(size=(n, 4)).astype(np.float32)
    cfg = AveragingConfig(mode="hierarchical", rounds=50, topology="ring")
    out = np.asarray(averaging.hierarchical_average({"g": jnp.asarray(v)}, pods,
                                                    n // pods, cfg)["g"])
    # 50 gossip rounds over 2 pods converges to the global mean
    np.testing.assert_allclose(out, np.tile(v.mean(0), (n, 1)), atol=1e-5)


def test_consensus_error_diagnostic():
    v = jnp.asarray(np.random.default_rng(4).normal(size=(6, 3)).astype(np.float32))
    e0 = averaging.consensus_error({"g": v})
    cfg = AveragingConfig(mode="gossip", rounds=30, topology="ring")
    mixed = averaging.gossip_average({"g": v}, 6, cfg)
    e1 = averaging.consensus_error(mixed)
    assert e1 < e0
    assert e1 < 1e-3


@pytest.mark.parametrize("per_pod,feat", [(4, 8), (3, 7), (4, 5)])
def test_hierarchical_reduce_scatter_matches_broadcast_form(per_pod, feat):
    """The reduce-scatter formulation must equal the legacy broadcast-then-
    gossip pod mean (gossip is linear and chunkwise over the pod axis),
    including when the feature dim needs padding to a multiple of per_pod."""
    pods = 4
    n = pods * per_pod
    rng = np.random.default_rng(6)
    v = rng.normal(size=(n, feat)).astype(np.float32)
    cfg = AveragingConfig(mode="hierarchical", rounds=3, topology="ring")
    got = np.asarray(averaging.hierarchical_average({"g": jnp.asarray(v)},
                                                    pods, per_pod, cfg)["g"])
    # oracle: pod means -> dense R-round gossip over pods -> broadcast
    pm = v.reshape(pods, per_pod, feat).mean(1)
    A = mixing.schedule_matrix(mixing.schedule("ring", pods), pods)
    mixed = np.linalg.matrix_power(A, 3) @ pm
    want = np.repeat(mixed, per_pod, axis=0)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


class _FakeMesh:
    """Just enough of jax.sharding.Mesh for resolve_auto_impl."""

    def __init__(self, shape):
        self.axis_names = tuple(shape)
        self.shape = dict(shape)
        self.devices = np.empty(tuple(shape.values()), dtype=object)


def test_resolve_auto_impl_layouts():
    # sharded node axis (data/pod extent > 1): the explicit shard_map
    # partitioning rule (circulant_mix_op downgrades to "roll" when the rule
    # does not cover the (n, schedule, split))
    assert mixing.resolve_auto_impl(_FakeMesh({"data": 8, "model": 1})) == "shard"
    assert mixing.resolve_auto_impl(
        _FakeMesh({"pod": 2, "data": 4, "model": 2})) == "shard"
    # node axis local but model-sharded trailing dims: matmul would flatten
    # (and so gather) them — must stay on roll
    assert mixing.resolve_auto_impl(_FakeMesh({"data": 1, "model": 4})) == "roll"
    # single-device mesh on this CPU container: the dense-matmul fast path
    assert mixing.resolve_auto_impl(
        _FakeMesh({"data": 1, "model": 1})) == "matmul"
    # no mesh info, single local device: fast path is provably safe
    assert mixing.resolve_auto_impl(None) == "matmul"


@pytest.mark.parametrize("rounds", [1, 4])
def test_auto_impl_matches_oracle_on_single_device(rounds):
    """impl='auto' resolves to the matmul fast path here and must agree with
    the dense matrix-power oracle."""
    n = 12
    sched = mixing.schedule("ring", n)
    op = mixing.circulant_mix_op(sched, n, rounds, impl="auto")
    assert op.impl == "matmul" and op.A_eff is not None
    v = np.random.default_rng(8).normal(size=(n, 6)).astype(np.float32)
    want = np.linalg.matrix_power(
        mixing.schedule_matrix(sched, n), rounds) @ v
    np.testing.assert_allclose(np.asarray(op(jnp.asarray(v))), want,
                               rtol=2e-5, atol=2e-6)


def test_quantized_gossip_still_averages_approximately():
    n = 8
    v = jnp.asarray(np.random.default_rng(5).normal(size=(n, 16)).astype(np.float32))
    cfg = AveragingConfig(mode="gossip", rounds=20, topology="ring", quantization="int8")
    out = averaging.gossip_average({"g": v}, n, cfg)["g"]
    bar = jnp.mean(v, axis=0)
    rel = jnp.linalg.norm(out - bar[None]) / jnp.linalg.norm(bar)
    assert rel < 0.05
