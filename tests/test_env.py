"""Launcher perf hygiene (launch/env.py): pure env-dict mutations, idempotent
TPU-gated XLA flag injection, and the --no-env-tuning escape hatch."""
import os

from repro.launch import env


def test_tuned_env_is_pure_and_sets_defaults():
    base = {}
    before = dict(base)
    out = env.tuned_env(base, tpu=True)
    assert base == before  # pure: the input dict is never mutated
    assert out["TF_CPP_MIN_LOG_LEVEL"] == "4"
    assert out["XLA_FLAGS"] == env.XLA_STEP_MARKER
    assert out["TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD"] == \
        env.TCMALLOC_REPORT_THRESHOLD


def test_tuned_env_preserves_user_choices():
    base = {"TF_CPP_MIN_LOG_LEVEL": "0",
            "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD": "1",
            "LD_PRELOAD": "/opt/custom.so"}
    out = env.tuned_env(base, tpu=True)
    assert "TF_CPP_MIN_LOG_LEVEL" not in out
    assert "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD" not in out
    assert "LD_PRELOAD" not in out  # user preload wins over tcmalloc


def test_step_marker_is_tpu_only():
    """CPU/GPU XLA builds hard-fail on unknown XLA_FLAGS entries, so the
    step marker must never be injected off-TPU."""
    out = env.tuned_env({}, tpu=False)
    assert "XLA_FLAGS" not in out
    # explicit platform request counts as TPU presence
    assert env.tpu_available({"JAX_PLATFORMS": "tpu,cpu"})
    assert not env.tpu_available({"JAX_PLATFORMS": "cpu"})


def test_xla_flags_injection_is_idempotent_and_additive():
    out = env.tuned_env({"XLA_FLAGS": "--xla_foo=bar"}, tpu=True)
    assert out["XLA_FLAGS"] == f"{env.XLA_STEP_MARKER} --xla_foo=bar"
    # a user-chosen step-marker location is never overridden or duplicated
    again = env.tuned_env({"XLA_FLAGS": out["XLA_FLAGS"]}, tpu=True)
    assert "XLA_FLAGS" not in again
    custom = env.tuned_env({"XLA_FLAGS": "--xla_step_marker_location=0"},
                           tpu=True)
    assert "XLA_FLAGS" not in custom


def test_wants_tuning_escape_hatch():
    assert env.wants_tuning(["prog", "--arch", "granite-8b"])
    assert not env.wants_tuning(["prog", "--no-env-tuning"])
    assert env.apply_from_argv(["prog", "--no-env-tuning"]) == {}


def test_compilation_cache_argv_peek_and_env():
    peek = env.compilation_cache_dir_from_argv
    assert peek(["prog", "--arch", "x"]) is None
    assert peek(["prog", "--compilation-cache-dir", "/tmp/cc"]) == "/tmp/cc"
    assert peek(["prog", "--compilation-cache-dir=/tmp/cc2"]) == "/tmp/cc2"
    assert peek(["prog", "--compilation-cache-dir"]) is None  # dangling flag
    cc = env.compilation_cache_env("/tmp/cc")
    assert cc["JAX_COMPILATION_CACHE_DIR"] == "/tmp/cc"
    # thresholds zeroed so sub-second test compiles still hit the cache
    assert cc["JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS"] == "0"
    assert cc["JAX_PERSISTENT_CACHE_MIN_ENTRY_SIZE_BYTES"] == "-1"


def test_compilation_cache_is_independent_of_tuning_escape_hatch(monkeypatch):
    for k in env.compilation_cache_env("/x"):
        monkeypatch.delenv(k, raising=False)
    changes = env.apply_from_argv(
        ["prog", "--no-env-tuning", "--compilation-cache-dir", "/tmp/cc3"])
    assert changes == env.compilation_cache_env("/tmp/cc3")
    assert os.environ["JAX_COMPILATION_CACHE_DIR"] == "/tmp/cc3"


def test_apply_mutates_target_and_reports_changes():
    target = {}
    changes = env.apply(target)
    assert changes and all(target[k] == v for k, v in changes.items())
    assert target["TF_CPP_MIN_LOG_LEVEL"] == "4"
    # second apply is a no-op on the already-tuned dict (except LD_PRELOAD,
    # which depends on whether the container ships tcmalloc)
    changes2 = {k: v for k, v in env.apply(target).items()
                if k != "LD_PRELOAD"}
    assert changes2 == {}


def test_find_tcmalloc_only_returns_existing_paths():
    tc = env.find_tcmalloc()
    assert tc is None or os.path.exists(tc)
    if tc is not None:
        assert "tcmalloc" in tc
