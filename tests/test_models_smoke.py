"""Per-architecture smoke tests: a REDUCED variant of each assigned architecture
(2 layers, d_model<=512, <=4 experts) runs one forward + one train step on CPU,
asserting output shapes and no NaNs; decode-capable archs also run a prefill +
decode step against a KV cache.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import registry

jax.config.update("jax_enable_x64", False)

B, S = 2, 64


def _reduced(arch):
    return reduced(get_config(arch))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = _reduced(arch)
    key = jax.random.PRNGKey(0)
    params = registry.init_params(key, cfg, jnp.float32)
    batch = registry.synth_batch(jax.random.PRNGKey(1), cfg, B, S, mode="train")

    (loss, metrics), grads = jax.jit(
        lambda p, b: jax.value_and_grad(
            lambda q: registry.loss_fn(q, cfg, b, remat=True), has_aux=True)(p)
    )(params, batch)

    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: non-finite loss {loss}"
    flat = jax.tree.leaves(grads)
    assert all(jnp.all(jnp.isfinite(g)) for g in flat), f"{arch}: non-finite grads"
    # one SGD step changes the loss
    params2 = jax.tree.map(lambda p, g: p - 1e-2 * g, params, grads)
    (loss2, _) = registry.loss_fn(params2, cfg, batch, remat=False)
    assert jnp.isfinite(loss2)
    assert loss2 != loss


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_logits_shape(arch):
    cfg = _reduced(arch)
    params = registry.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    batch = registry.synth_batch(jax.random.PRNGKey(1), cfg, B, S, mode="train")
    logits, aux, _ = registry.forward(params, cfg, batch, remat=False)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode(arch):
    cfg = _reduced(arch)
    params = registry.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    max_len = S + 4
    cache = registry.init_cache(cfg, B, max_len, jnp.float32)
    pre_batch = registry.synth_batch(jax.random.PRNGKey(1), cfg, B, S, mode="prefill")
    logits, cache = registry.prefill(params, cfg, pre_batch, cache)
    assert logits.shape == (B, S, cfg.vocab_size)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    logits2, cache = registry.decode_step(params, cfg, tok, cache,
                                          jnp.asarray(S, jnp.int32))
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits2))


@pytest.mark.parametrize("arch", ["granite-8b", "mamba2-2.7b", "recurrentgemma-9b"])
def test_decode_matches_prefill(arch):
    """Incremental decoding must reproduce teacher-forced logits."""
    cfg = _reduced(arch)
    params = registry.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 16), 0, cfg.vocab_size, jnp.int32)
    full, _, _ = registry.forward(params, cfg, {"tokens": toks}, remat=False)

    cache = registry.init_cache(cfg, 1, 16, jnp.float32)
    logits, cache = registry.prefill(params, cfg, {"tokens": toks[:, :8]}, cache)
    assert jnp.allclose(logits, full[:, :8], atol=2e-3), arch
    step_logits = []
    for i in range(8, 16):
        lg, cache = registry.decode_step(params, cfg, toks[:, i:i + 1], cache,
                                         jnp.asarray(i, jnp.int32))
        step_logits.append(lg)
    inc = jnp.concatenate(step_logits, axis=1)
    assert jnp.allclose(inc, full[:, 8:], atol=5e-3), (
        f"{arch}: max err {jnp.max(jnp.abs(inc - full[:, 8:]))}")


def test_param_count_sane():
    # full configs should land in the right ballpark of their nominal sizes
    approx = {
        "granite-8b": (6e9, 10e9),
        "phi4-mini-3.8b": (3e9, 5.5e9),
        "mamba2-2.7b": (2e9, 3.5e9),
        "starcoder2-15b": (12e9, 18e9),
        "chameleon-34b": (30e9, 38e9),
    }
    for arch, (lo, hi) in approx.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n / 1e9:.2f}B params out of range"
