"""Buffer-donation feature detection (`core.dsgd.donation_supported`) and the
end-to-end donated TrainState path.

The old code hard-coded `backend in ("tpu", "gpu")` — a stale caveat: the
pinned jax's PJRT CPU client implements donation (no "not usable" warning,
input buffer consumed). The probe detects that instead of trusting a list,
so `jit_driver` and the streaming driver now donate on this container too.

Contract: donation is a pure memory optimization — exact-mode training
results are BIT-IDENTICAL with donation forced off.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import AveragingConfig, RunConfig, SHAPES
from repro.core import dsgd
from repro.data.lm import MarkovTokenStream
from repro.launch.mesh import make_mesh
from repro.launch.sharding import activation_rules
from repro.models.common import mesh_rules
from repro.train.driver import EngineConfig, StreamingDriver
from repro.train.trainer import init_state

SEQ, BATCH = 16, 4


def test_probe_detects_donation_on_pinned_jax():
    got = dsgd.donation_supported()
    assert isinstance(got, bool)
    # the pinned jax implements CPU donation — the whole point of retiring
    # the backend-list caveat; if this fires after a jax bump, the probe
    # (not this test) decides what the drivers do
    assert got, "pinned jax should honor donation on this backend"
    # probe result is cached: second call must not recompile
    assert dsgd.donation_supported() is got


def _train(steps=4, force_off=False):
    model = dataclasses.replace(
        reduced(get_config("granite-8b"), layers=1, d_model=16),
        vocab_size=32, d_ff=32)
    run_cfg = RunConfig(model=model, shape=SHAPES["train_4k"],
                        averaging=AveragingConfig("exact"),
                        optimizer="adam", learning_rate=1e-3,
                        param_dtype="float32", remat=False)
    mesh = make_mesh((1, 1), ("data", "model"))
    data = MarkovTokenStream(model.vocab_size, seed=0)

    def sample(rng, n):
        toks = data.sample(rng, n, SEQ + 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    with mesh_rules(mesh, activation_rules(mesh, run_cfg.shape)):
        state = init_state(run_cfg, jax.random.PRNGKey(0))
        with StreamingDriver(run_cfg, mesh, state, sample,
                             engine=EngineConfig(superstep=2, prefetch_depth=0,
                                                 replan_every=0),
                             batch=BATCH) as drv:
            if force_off:
                drv._donate = ()
                drv._compiled.clear()
            drv.run(steps)
            losses = [h["metrics"]["loss"] for h in drv.history]
            params = jax.tree.map(np.asarray, jax.tree.leaves(drv.state.params))
    return losses, params


def test_exact_mode_bit_identical_with_donation_off():
    l_on, p_on = _train()
    l_off, p_off = _train(force_off=True)
    assert l_on == l_off
    for a, b in zip(p_on, p_off):
        np.testing.assert_array_equal(a, b)


def test_jit_driver_donates_carry():
    """`jit_driver`'s donated scan consumes its input state on backends where
    the probe says donation works."""
    f = dsgd.jit_driver(lambda s, ts: s * 2.0)
    x = jnp.ones((4, 8))
    y = jax.block_until_ready(f(x, None))
    assert bool(np.all(np.asarray(y) == 2.0))
    if dsgd.donation_supported():
        assert x.is_deleted()
