"""Executed by test_shard_gossip.py in a subprocess with 4 fake host devices:
exercises the shard_map gossip partitioning rules (kernels/consensus.py,
kernels/krasulina_update.py) on a REALLY sharded node axis and prints JSON
results for the parent to assert on."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import functools
import json

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import mixing
from repro.kernels import ops, ref
from _trace import hlo_collective_permutes

N, D, R = 16, 1 << 12, 3


def main():
    res = {"n_devices": len(jax.devices())}
    mesh = Mesh(np.asarray(jax.devices()).reshape(4, 1), ("data", "model"))
    sharding = NamedSharding(mesh, P("data", None))
    x = jax.device_put(
        jax.random.normal(jax.random.PRNGKey(0), (N, D), jnp.float32),
        sharding)
    sched = mixing.schedule("ring", N, 0.5)

    # auto-resolution picks the shard rule on this layout
    res["auto_impl"] = mixing.resolve_auto_impl(mesh)
    op = mixing.circulant_mix_op(sched, N, R, mesh=mesh)
    res["op_impl"] = op.impl

    # exact path: bitwise vs the per-round oracle, 2 ppermutes per round
    f = jax.jit(op)
    got = np.asarray(jax.block_until_ready(f(x)))
    oracle = np.asarray(ref.gossip_mix_ref(np.asarray(x), tuple(sched), R))
    res["exact_bit_identical"] = bool(np.array_equal(got, oracle))
    res["exact_ppermutes"] = hlo_collective_permutes(f, x)

    # quantized node-stats wire: sign is bitwise; int8 matches to f32
    # round-off (association differs across program layouts)
    for quant in ("sign", "int8", "int8_stoch"):
        opq = mixing.circulant_mix_op(sched, N, R, quantization=quant,
                                      mesh=mesh, stats="node", block_d=512)
        res[f"{quant}_impl"] = opq.impl
        gotq = np.asarray(jax.block_until_ready(jax.jit(opq)(x)))
        key0 = (jax.random.PRNGKey(opq.seed)
                if quant in mixing.STOCHASTIC else None)
        oq = np.asarray(ref.gossip_mix_quant_ref(
            np.asarray(x), tuple(sched), R, quant, block_d=512,
            key=key0, per_node=True))
        res[f"{quant}_bit_identical"] = bool(np.array_equal(gotq, oq))
        denom = max(float(np.abs(oq).max()), 1e-30)
        res[f"{quant}_rel_err"] = float(np.abs(gotq - oq).max() / denom)

    # krasulina fused xi+gossip: xi node-local, rounds match the strict
    # per-round oracle to f32 round-off
    d, B = 256, 16
    w = jax.device_put(jax.random.normal(jax.random.PRNGKey(1), (N, d)),
                       sharding)
    z = jax.device_put(jax.random.normal(jax.random.PRNGKey(2), (N, B, d)),
                       NamedSharding(mesh, P("data", None, None)))
    info = ops.node_shard_info(mesh, N, tuple(sched))
    res["shard_info"] = [list(info[0]), info[1]]
    fk = jax.jit(functools.partial(
        ops.sharded_krasulina_xi_gossip, sched=tuple(sched), rounds=R,
        mesh=mesh, node_axes=info[0], ring_axis=info[1]))
    gotk = np.asarray(jax.block_until_ready(fk(w, z=z)))
    ok = np.asarray(ref.gossip_mix_ref(
        jax.vmap(ref.krasulina_xi_ref)(w, z), tuple(sched), R))
    res["krasulina_rel_err"] = float(
        np.abs(gotk - ok).max() / max(float(np.abs(ok).max()), 1e-30))
    res["krasulina_ppermutes"] = hlo_collective_permutes(fk, w, z)

    # packed pack/unpack resharding parity under a MODEL-PARALLEL layout
    # (ROADMAP caveat -> core.averaging.resolve_packed gate): leaves sharded
    # over the model axis, mixed through ONE packed [N, D] buffer, must match
    # the per-leaf dispatch bitwise — the pack is a pure relayout
    import dataclasses

    from repro.configs.base import AveragingConfig
    from repro.core import averaging

    mesh_mp = Mesh(np.asarray(jax.devices()).reshape(2, 2), ("data", "model"))
    n_mp = 8
    tree = {
        "w1": jax.device_put(
            jax.random.normal(jax.random.PRNGKey(5), (n_mp, 12, 16)),
            NamedSharding(mesh_mp, P("data", None, "model"))),
        "w2": jax.device_put(
            jax.random.normal(jax.random.PRNGKey(6), (n_mp, 64)),
            NamedSharding(mesh_mp, P("data", "model"))),
        "b": jax.device_put(
            jax.random.normal(jax.random.PRNGKey(7), (n_mp, 48)),
            NamedSharding(mesh_mp, P("data", None))),
    }
    cfg_avg = AveragingConfig("gossip", rounds=2, topology="ring")
    mix_mp = averaging.make_gossip_mix(cfg_avg, n_mp, mesh=mesh_mp)
    res["mp_mix_impl"] = mix_mp.impl
    got_p = jax.jit(lambda tr: averaging.gossip_average(
        tr, n_mp, dataclasses.replace(cfg_avg, packed=True), mix_mp))(tree)
    got_l = jax.jit(lambda tr: averaging.gossip_average(
        tr, n_mp, dataclasses.replace(cfg_avg, packed=False), mix_mp))(tree)
    # not bitwise: XLA picks different fusions/FMA contractions for the
    # packed [N, D] program vs the per-leaf shapes — parity is f32 round-off
    res["mp_packed_rel_err"] = max(
        float(np.abs(np.asarray(got_p[k]) - np.asarray(got_l[k])).max()
              / max(float(np.abs(np.asarray(got_l[k])).max()), 1e-30))
        for k in tree)
    sched8 = tuple(mixing.schedule("ring", n_mp, 0.0))
    oracle_ok = True
    for k, v in tree.items():
        want = np.asarray(ref.gossip_mix_ref(
            np.asarray(v).reshape(n_mp, -1), sched8, 2)).reshape(v.shape)
        oracle_ok &= bool(np.allclose(np.asarray(got_p[k]), want,
                                      rtol=1e-5, atol=1e-6))
    res["mp_packed_vs_oracle"] = oracle_ok
    # the tri-state default gates packed OFF under the model split and ON on
    # node-only layouts; explicit True overrides the gate
    res["mp_auto_packed"] = averaging.resolve_packed(cfg_avg, mesh_mp)
    res["flat_auto_packed"] = averaging.resolve_packed(cfg_avg, mesh)
    res["mp_forced_packed"] = averaging.resolve_packed(
        dataclasses.replace(cfg_avg, packed=True), mesh_mp)

    # uncoverable layout (n=6 does not tile the 4-way device split): the
    # factory downgrades to the sharding-safe roll and stays correct
    op_small = mixing.circulant_mix_op(mixing.schedule("ring", 6, 0.0), 6, R,
                                       mesh=mesh)
    res["small_impl"] = op_small.impl
    xs = jax.random.normal(jax.random.PRNGKey(3), (6, D))
    got_s = np.asarray(jax.jit(op_small)(xs))
    want_s = np.asarray(ref.gossip_mix_ref(
        np.asarray(xs), tuple(mixing.schedule("ring", 6, 0.0)), R))
    res["small_close"] = bool(np.allclose(got_s, want_s, rtol=1e-5,
                                          atol=1e-6))
    print(json.dumps(res))


if __name__ == "__main__":
    main()
