"""Streaming governor + pipeline: the splitter semantics of Fig. 3(c)/Fig. 4
(B samples split N ways, mu discarded, t' accounting) and hypothesis properties
of the pipeline bookkeeping."""
import numpy as np
import pytest
from _prop import given, settings, st

from repro.configs.base import StreamConfig
from repro.core.streaming import make_governed_stream
from repro.data.pipeline import StreamingPipeline


def _draw(rng, n):
    return rng.normal(size=(n, 3))


def test_governed_stream_splits_evenly():
    sc = StreamConfig(streaming_rate=1e5, processing_rate=5e4, comms_rate=1e4)
    gs = make_governed_stream(_draw, sc, n_nodes=8, rounds_R=2)
    batch = next(gs)
    assert batch.shape[0] == 8
    assert batch.shape[1] == gs.plan.B // 8
    assert gs.samples_arrived == gs.plan.B + gs.plan.mu


def test_forced_mu_accounting():
    sc = StreamConfig(forced_mu=16)
    gs = make_governed_stream(_draw, sc, n_nodes=4, rounds_R=1, B=32)
    for _ in range(5):
        next(gs)
    assert gs.samples_consumed == 5 * 32
    assert gs.samples_discarded == 5 * 16
    assert gs.samples_arrived == 5 * 48


@given(st.integers(1, 16), st.integers(1, 8), st.integers(0, 64))
@settings(max_examples=30, deadline=None)
def test_pipeline_invariants(nodes_pow, rounds, mu):
    n_nodes = nodes_pow
    B = n_nodes * 8
    sc = StreamConfig(forced_mu=mu)
    pipe = StreamingPipeline(lambda rng, n: {"x": rng.normal(size=(n, 2))},
                             sc, n_nodes, rounds, batch=B)
    b = next(pipe)
    assert b["x"].shape[0] == B
    assert pipe.samples_arrived == B + mu


def test_governed_stream_superstep_and_replan():
    sc = StreamConfig(forced_mu=4)
    gs = make_governed_stream(_draw, sc, n_nodes=2, rounds_R=1, B=8)
    sup = gs.next_superstep(3)
    assert sup.shape == (3, 2, 4, 3)  # [K, N, B/N, d]
    assert gs.samples_arrived == 3 * 12 and gs.rounds == 3
    # closed-loop plan swap: counters carry over, B must stay fixed
    import dataclasses
    gs.update_plan(dataclasses.replace(gs.plan, mu=10))
    next(gs)
    assert gs.samples_arrived == 3 * 12 + 18
    with pytest.raises(ValueError):
        gs.update_plan(dataclasses.replace(gs.plan, B=16))


def test_pipeline_superstep_counters():
    sc = StreamConfig(forced_mu=2)
    pipe = StreamingPipeline(lambda rng, n: {"x": rng.normal(size=(n, 2))},
                             sc, n_nodes=2, rounds_R=1, batch=6)
    sup = pipe.next_superstep(4)
    assert sup["x"].shape == (4, 6, 2)
    c = pipe.counters()
    assert (c.samples_arrived, c.samples_consumed, c.samples_discarded,
            c.rounds) == (32, 24, 8, 4)


def test_pipeline_with_rate_planner():
    sc = StreamConfig(streaming_rate=2e5, processing_rate=1e5, comms_rate=1e4)
    pipe = StreamingPipeline(lambda rng, n: {"x": rng.normal(size=(n, 2))},
                             sc, n_nodes=4, rounds_R=1)
    assert pipe.plan.B % 4 == 0
    assert pipe.plan.mu == 0  # planner chooses B that keeps up
    b = next(pipe)
    assert b["x"].shape[0] == pipe.plan.B
