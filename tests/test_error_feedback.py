"""Error-feedback compressed gossip (tentpole: `core.averaging.
ef_average_and_error` + `OptState.ef_residual`).

* residual algebra on the packed buffers: v = g + e, q = C(v) with
  sender-local per-node tile stats, mixed = LINEAR R-round consensus of q,
  e' = v - q — verified leaf-by-leaf against a hand-rolled oracle
* `make_gossip_mix` drops the per-round compressor when error_feedback is on
  (the operator must stay linear, so the fused/shard impls apply)
* exact wire (quantization="none"): bit-identical to the EF-off path, zero
  residual forever
* trainer integration: EF sign/int8 trains a reduced LM to a loss within
  1.2x of the uncompressed excess at matched steps, residual norms flow
  into the step metrics, and the residual state rides OptState
* hierarchical mode rejects EF (gossip-only contract)
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import AveragingConfig, RunConfig, SHAPES
from repro.core import packing
from repro.core.averaging import ef_average_and_error, make_gossip_mix
from repro.core.quantize import tile_compress
from repro.data.lm import MarkovTokenStream
from repro.launch.mesh import make_mesh
from repro.launch.sharding import activation_rules
from repro.models.common import mesh_rules
from repro.train.trainer import (build_train_step, init_state,
                                 make_node_batch, replicate_for_nodes)

SEQ, BATCH, N = 16, 4, 4


# ---------------------------------------------------------------------------
# Operator algebra
# ---------------------------------------------------------------------------

def _tree(n=4, seed=0):
    r = np.random.default_rng(seed)
    return {"a": jnp.asarray(r.normal(size=(n, 6)).astype(np.float32)),
            "b": jnp.asarray(r.normal(size=(n, 3, 5)).astype(np.float32))}


@pytest.mark.parametrize("quant", ["sign", "int8"])
def test_ef_residual_algebra_matches_oracle(quant):
    cfg = AveragingConfig("gossip", rounds=2, quantization=quant,
                          quant_block_d=8, error_feedback="grads")
    g = _tree()
    e = jax.tree.map(lambda x: 0.1 * x, _tree(seed=1))
    mix = make_gossip_mix(cfg, N)
    assert mix.quantization == "none"  # EF linearizes the operator
    mixed, new_e, cerr, ef_norm, ef_rel = ef_average_and_error(
        g, e, cfg, n_nodes=N, mix=mix)

    # oracle on the packed buffer: compress once, mix linearly, residual
    bufs, spec = packing.pack_tree(g)
    ebufs, _ = packing.pack_tree(e)
    v = bufs[0] + ebufs[0]
    q = tile_compress(v, quant, cfg.quant_block_d, per_node=True)
    want_mixed = mix(q)
    want_e = v - q
    got_mixed = packing.pack_tree(mixed)[0][0]
    got_e = packing.pack_tree(new_e)[0][0]
    np.testing.assert_array_equal(np.asarray(got_mixed),
                                  np.asarray(want_mixed))
    np.testing.assert_array_equal(np.asarray(got_e), np.asarray(want_e))
    np.testing.assert_allclose(float(ef_norm),
                               float(jnp.linalg.norm(want_e)), rtol=1e-6)
    assert 0.0 < float(ef_rel) < 1.0
    assert float(cerr) > 0.0


def test_ef_exact_wire_is_identity_on_residual():
    cfg = AveragingConfig("gossip", rounds=2, error_feedback="grads")
    g = _tree()
    zero = jax.tree.map(jnp.zeros_like, g)
    mixed, new_e, _, ef_norm, _ = ef_average_and_error(
        g, zero, cfg, n_nodes=N)
    assert float(ef_norm) == 0.0
    for leaf in jax.tree.leaves(new_e):
        assert not np.asarray(leaf).any()
    # and equals plain linear gossip of g
    plain = make_gossip_mix(dataclasses.replace(cfg, error_feedback="off"), N)
    want = jax.tree.map(plain, g)
    for a, b in zip(jax.tree.leaves(mixed), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ef_rejects_nonlinear_mix():
    cfg = AveragingConfig("gossip", rounds=2, quantization="sign",
                          error_feedback="grads")
    bad = make_gossip_mix(dataclasses.replace(cfg, error_feedback="off"), N)
    with pytest.raises(ValueError, match="LINEAR"):
        ef_average_and_error(_tree(), _tree(seed=1), cfg, n_nodes=N, mix=bad)


# ---------------------------------------------------------------------------
# Trainer integration
# ---------------------------------------------------------------------------

def _run_cfg(avg):
    cfg = dataclasses.replace(
        reduced(get_config("granite-8b"), layers=1, d_model=16),
        vocab_size=32, d_ff=32)
    return RunConfig(model=cfg, shape=SHAPES["train_4k"], averaging=avg,
                     optimizer="adam", learning_rate=1e-3,
                     param_dtype="float32", remat=False)


def _train(avg, steps=6):
    run_cfg = _run_cfg(avg)
    mesh = make_mesh((1, 1), ("data", "model"))
    data = MarkovTokenStream(32, seed=0)
    rng = np.random.default_rng(0)
    with mesh_rules(mesh, activation_rules(mesh, run_cfg.shape,
                                           node_axis=True)):
        state = replicate_for_nodes(
            init_state(run_cfg, jax.random.PRNGKey(0)), N)
        step = jax.jit(build_train_step(run_cfg, mesh, n_nodes=N)[0])
        ms = []
        for _ in range(steps):
            toks = data.sample(rng, N * BATCH, SEQ + 1)
            batch = make_node_batch(
                {"tokens": jnp.asarray(toks[:, :-1]),
                 "labels": jnp.asarray(toks[:, 1:])}, N)
            state, m = step(state, batch)
            ms.append({k: float(np.asarray(v)) for k, v in m.items()})
    return state, ms


def test_trainer_ef_none_bit_identical_to_ef_off():
    s_off, _ = _train(AveragingConfig("gossip", rounds=2))
    s_ef, ms = _train(AveragingConfig("gossip", rounds=2,
                                      error_feedback="grads"))
    for a, b in zip(jax.tree.leaves(s_off.params), jax.tree.leaves(s_ef.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert all(m["ef_norm"] == 0.0 for m in ms)


@pytest.mark.parametrize("quant", ["sign", "int8"])
def test_trainer_ef_compressed_tracks_uncompressed(quant):
    _, m_off = _train(AveragingConfig("gossip", rounds=2))
    s_ef, m_ef = _train(AveragingConfig("gossip", rounds=2,
                                        quantization=quant,
                                        error_feedback="grads"))
    l0, l_off, l_ef = m_off[0]["loss"], m_off[-1]["loss"], m_ef[-1]["loss"]
    # excess-risk contract: compressed progress within 1.2x of uncompressed
    assert (l0 - l_ef) >= (l0 - l_off) / 1.2
    # residual norms are live in the metrics and in OptState
    assert all(np.isfinite(m["ef_norm"]) for m in m_ef)
    assert m_ef[-1]["ef_norm"] > 0.0 and 0.0 < m_ef[-1]["ef_rel"] < 1.0
    leaves = jax.tree.leaves(s_ef.opt.ef_residual)
    assert leaves and all(np.isfinite(np.asarray(x)).all() for x in leaves)


def test_ef_requires_gossip_mode():
    run_cfg = _run_cfg(AveragingConfig("hierarchical", rounds=2,
                                       quantization="sign",
                                       error_feedback="grads"))
    mesh = make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="gossip"):
        build_train_step(run_cfg, mesh, n_nodes=N)
