"""Unit tests for the sharding-rule engine: name-table resolution, divisibility
fallbacks, ZeRO/FSDP dp-axis injection, and cache specs per shape."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.launch import sharding as shlib


class FakeMesh:
    """Duck-typed mesh: only .shape and axis_names are consulted by the rules."""

    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
POD = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _leaf(shape):
    return jax.ShapeDtypeStruct(shape, jnp.bfloat16)


def test_basic_name_specs():
    params = {"wq": _leaf((4096, 4096)), "w_down": _leaf((14336, 4096)),
              "scale": _leaf((4096,))}
    specs = shlib.param_specs(params, MESH)
    assert specs["wq"] == P(None, "model")
    assert specs["w_down"] == P("model", None)
    assert specs["scale"] == P(None)


def test_stacked_layers_get_lead_padding():
    params = {"wq": _leaf((36, 4096, 4096))}
    specs = shlib.param_specs(params, MESH)
    assert specs["wq"] == P(None, None, "model")


def test_vocab_fallback_to_dmodel():
    # 50280 % 16 != 0 -> embed falls back to (None, model)
    specs = shlib.param_specs({"embed": _leaf((50280, 2560))}, MESH)
    assert specs["embed"] == P(None, "model")
    specs = shlib.param_specs({"embed": _leaf((49152, 4096))}, MESH)
    assert specs["embed"] == P("model", None)


def test_moe_expert_fallback():
    # 60 experts % 16 != 0 -> tensor-shard within experts (d_ff 1408 % 16 == 0)
    specs = shlib.param_specs({"we_gate": _leaf((24, 60, 2048, 1408))}, MESH)
    assert specs["we_gate"] == P(None, None, None, "model")
    # 16 experts -> true expert parallelism
    specs = shlib.param_specs({"we_gate": _leaf((12, 16, 5120, 8192))}, MESH)
    assert specs["we_gate"] == P(None, "model", None, None)


def test_node_axes_prepended():
    specs = shlib.param_specs({"wq": _leaf((16, 4096, 4096))}, MESH,
                              node_axes=("data",))
    assert specs["wq"] == P(("data",), None, "model")


def test_zero1_adds_dp_on_divisible_dim():
    specs = shlib.zero1_specs({"wq": _leaf((36, 4096, 4096))}, MESH)
    # 36 % 16 != 0, so dp lands on the 4096 dim
    assert specs["wq"] == P(None, ("data",), "model")


def test_zero1_skips_when_nothing_divides():
    specs = shlib.zero1_specs({"lam": _leaf((37,))}, MESH)
    assert specs["lam"] == P(None)


def test_cache_specs_decode_batch_sharded():
    cache = {"layers": [{"k": _leaf((40, 128, 32768, 4, 128)),
                         "v": _leaf((40, 128, 32768, 4, 128))}]}
    specs = shlib.cache_specs(cache, MESH, SHAPES["decode_32k"])
    # KH=4 < 16 -> falls to sequence sharding over model; batch over data
    assert specs["layers"][0]["k"] == P(None, ("data",), "model", None, None)


def test_cache_specs_long500k_sequence_sharded():
    cache = {"layers": [{"k": _leaf((36, 1, 524288, 8, 128))}]}
    specs = shlib.cache_specs(cache, MESH, SHAPES["long_500k"])
    assert specs["layers"][0]["k"] == P(None, None, ("data",), None, "model")


def test_ssd_state_heads_over_model():
    cache = {"layers": [{"h": _leaf((64, 128, 80, 64, 128)),
                         "conv": _leaf((64, 128, 3, 5376))}]}
    specs = shlib.cache_specs(cache, MESH, SHAPES["decode_32k"])
    assert specs["layers"][0]["h"] == P(None, ("data",), "model", None, None)
    assert specs["layers"][0]["conv"] == P(None, ("data",), None, "model")


def test_multipod_dp_is_pod_and_data():
    specs = shlib.zero1_specs({"wq": _leaf((4096, 4096))}, POD)
    assert specs["wq"] == P(("pod", "data"), "model")


@pytest.mark.parametrize("arch", ["granite-8b", "qwen2-moe-a2.7b", "mamba2-2.7b",
                                  "seamless-m4t-medium"])
def test_full_param_tree_resolves(arch):
    from repro.models import registry
    cfg = get_config(arch)
    shapes = jax.eval_shape(
        lambda k: registry.init_params(k, cfg, jnp.bfloat16), jax.random.PRNGKey(0))
    specs = shlib.param_specs(shapes, MESH)
    # every sharded dim divides evenly
    for leaf, spec in zip(jax.tree.leaves(shapes),
                          jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is not None:
                size = 16 if not isinstance(ax, tuple) else 16 ** len(ax)
                assert dim % size == 0, (arch, leaf.shape, spec)
