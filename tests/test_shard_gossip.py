"""shard_map gossip partitioning rules on a REAL 4-device node-axis split
(subprocess, since jax pins the device count at import — shard_worker.py).

Contracts (tentpole: sharded-node-axis decentralized training):

* `resolve_auto_impl` picks "shard" on a sharded node axis and
  `circulant_mix_op` keeps it when the rule covers the (n, schedule, split)
* exact gossip is BIT-IDENTICAL to the per-round `ref.gossip_mix_ref` oracle
  and lowers to exactly 2 collective-permutes per round (one halo hop up +
  one down for the ring reach) — the roll fallback's wraparound concats are
  gone from the HLO
* quantized `stats="node"` wire values: sign is bitwise vs the
  `per_node=True` oracle; deterministic int8 matches to f32 round-off
  (weighted-sum association differs across program layouts); stochastic int8
  draws independent threefry noise per shard — statistically equivalent,
  bounded by the quantization step
* the fused Krasulina xi+gossip rule communicates ONLY in the consensus
  rounds (same 2R collective-permutes) and matches the strict per-round
  oracle to f32 round-off
* a layout the rule cannot cover (n not a multiple of the split) downgrades
  to the sharding-safe roll and stays correct
"""
import json
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")
ROUNDS = 3  # keep in sync with shard_worker.R


@pytest.fixture(scope="module")
def res():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + HERE
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    p = subprocess.run(
        [sys.executable, os.path.join(HERE, "shard_worker.py")],
        capture_output=True, text=True, timeout=900, env=env)
    assert p.returncode == 0, p.stderr[-3000:]
    return json.loads(p.stdout.strip().splitlines()[-1])


def test_auto_resolves_to_shard_rule(res):
    assert res["n_devices"] == 4
    assert res["auto_impl"] == "shard"
    assert res["op_impl"] == "shard"
    assert res["shard_info"] == [["data"], "data"]


def test_exact_gossip_bit_identical_to_per_round_oracle(res):
    assert res["exact_bit_identical"]


def test_exact_gossip_lowering_is_two_ppermutes_per_round(res):
    assert res["exact_ppermutes"] == 2 * ROUNDS


def test_quantized_node_stats_wire_parity(res):
    assert res["sign_impl"] == "shard" and res["int8_impl"] == "shard"
    assert res["sign_bit_identical"]
    assert res["int8_rel_err"] < 1e-5
    # stochastic: independent threefry draws per layout, bounded by the
    # quantization step — NOT bitwise by design
    assert res["int8_stoch_rel_err"] < 0.05


def test_krasulina_fused_rule_matches_per_round_oracle(res):
    assert res["krasulina_rel_err"] < 1e-5
    assert res["krasulina_ppermutes"] == 2 * ROUNDS


def test_uncovered_layout_downgrades_to_roll(res):
    assert res["small_impl"] == "roll"
    assert res["small_close"]


def test_packed_resharding_parity_model_parallel(res):
    """Model-parallel layout (2x2 data x model mesh, leaves sharded over the
    model axis): the packed [N, D] gossip pass equals the per-leaf dispatch
    to f32 round-off (XLA fuses the two programs differently, so not
    bitwise) and matches the per-round oracle — the pack is a pure relayout,
    validating the ROADMAP caveat the `resolve_packed` gate encodes."""
    assert res["mp_packed_rel_err"] < 1e-6
    assert res["mp_packed_vs_oracle"]


def test_resolve_packed_gates_on_model_split(res):
    # "auto" -> off under the model split, on for node-only layouts;
    # explicit True opts back in
    assert res["mp_auto_packed"] is False
    assert res["flat_auto_packed"] is True
    assert res["mp_forced_packed"] is True
