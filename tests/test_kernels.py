"""Per-kernel validation: Pallas (interpret=True on CPU) vs the pure-jnp oracle
in repro.kernels.ref, swept over shapes, dtypes and mask configurations, plus
hypothesis property tests on the Krasulina kernel's invariants.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.core.problems import krasulina_xi as core_xi
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.krasulina_update import krasulina_xi_pallas


# ---------------------------------------------------------------------------
# Krasulina kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,d", [(8, 16), (256, 128), (300, 257), (1024, 64), (5, 3072)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_krasulina_kernel_matches_ref(B, d, dtype):
    kw, kz = jax.random.split(jax.random.PRNGKey(0))
    w = jax.random.normal(kw, (d,), dtype)
    z = jax.random.normal(kz, (B, d), dtype)
    got = krasulina_xi_pallas(w, z, interpret=True)
    want = ref.krasulina_xi_ref(w, z)
    # f32 bound scales with the d-length accumulations (summation-order noise
    # between the tiled kernel and the one-shot reference)
    rtol, atol = (1e-4, 5e-4) if dtype == jnp.float32 else (5e-2, 5e-2)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=rtol, atol=atol)


def test_krasulina_ref_matches_core_problems():
    """ref.py oracle == the algorithmic definition used by core.krasulina."""
    kw, kz = jax.random.split(jax.random.PRNGKey(1))
    w = jax.random.normal(kw, (32,))
    z = jax.random.normal(kz, (64, 32))
    np.testing.assert_allclose(np.asarray(ref.krasulina_xi_ref(w, z)),
                               np.asarray(core_xi(w, z)), rtol=1e-5, atol=1e-6)


@given(st.integers(1, 64), st.integers(2, 48), st.integers(16, 400))
@settings(max_examples=20, deadline=None)
def test_krasulina_kernel_property(seed, d, B):
    """Invariant (Krasulina = projected update): xi is orthogonal to nothing in
    general, but <xi, w> relates to the Rayleigh quotient: w^T xi = 0 exactly."""
    kw, kz = jax.random.split(jax.random.PRNGKey(seed))
    w = jax.random.normal(kw, (d,))
    z = jax.random.normal(kz, (B, d))
    xi = krasulina_xi_pallas(w, z, interpret=True, block_b=64)
    # w^T xi = w^T Z^T Z w / B - (|Zw|^2/B / |w|^2) * w^T w = 0
    ortho = float(jnp.abs(w @ xi) / (jnp.linalg.norm(w) * jnp.linalg.norm(xi) + 1e-9))
    assert ortho < 1e-3


# ---------------------------------------------------------------------------
# Flash attention kernel
# ---------------------------------------------------------------------------

CASES = [
    # (B, H, Sq, Sk, D, causal, window, chunk)
    (1, 2, 128, 128, 64, True, 0, 0),
    (2, 2, 256, 256, 64, True, 0, 0),
    (1, 1, 256, 256, 128, True, 64, 0),   # sliding window
    (1, 2, 256, 256, 64, True, 0, 128),   # chunked-local (iRoPE)
    (1, 1, 200, 200, 64, True, 0, 0),     # non-divisible seq (padding path)
    (1, 1, 128, 384, 64, True, 0, 0),     # decode-ish: Sq < Sk
]


@pytest.mark.parametrize("B,H,Sq,Sk,D,causal,window,chunk", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, H, Sq, Sk, D, causal, window, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, D), dtype)
    k = jax.random.normal(ks[1], (B, H, Sk, D), dtype)
    v = jax.random.normal(ks[2], (B, H, Sk, D), dtype)
    # align positions so q block i attends where a suffix-query would
    got = flash_attention(q, k, v, causal=causal, window=window, chunk=chunk,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window, chunk=chunk)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_flash_attention_matches_model_blockwise():
    """The Pallas kernel and the model-side blockwise_attention agree (they are
    alternative implementations of the same contract)."""
    from repro.models.layers import blockwise_attention
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    B, H, S, D = 1, 4, 192, 64
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    got = flash_attention(q, k, v, causal=True, interpret=True)
    # blockwise_attention uses [B, S, H, D] layout
    want = blockwise_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3), causal=True, kv_block=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want.transpose(0, 2, 1, 3)),
                               rtol=2e-4, atol=2e-4)


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_flash_attention_rows_convex(seed):
    """Each output row is a convex combination of value rows => within [min, max]."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    B, H, S, D = 1, 1, 128, 32
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    out = flash_attention(q, k, v, causal=True, interpret=True)
    assert float(jnp.max(out)) <= float(jnp.max(v)) + 1e-4
    assert float(jnp.min(out)) >= float(jnp.min(v)) - 1e-4
