"""Scenario-harness tests (core/scenarios.py + the mixing contracts it must
keep — docs/DESIGN.md §Scenario harness):

* property suite (tests/_prop.py): every topology the registry can produce is
  doubly stochastic with lambda_2 < 1 at any size; every registered
  scenario's realized per-round operators stay doubly stochastic and their
  B-round window products contract (eq. 17 B-connectivity); lossy
  realizations are the Metropolis reweighting of the surviving graph
* parity regression: a constant-schedule `ScheduledMixOp` is bit-identical
  to the static `CirculantMixOp` / `DenseMixOp` on both the Krasulina and
  the LM superstep
* determinism: link-drop masks are a pure function of (seed, round, edge) —
  identical across schedule instances, driver runs, and prefetch depths;
  `FaultSchedule.parse(str(s)) == s` round-trips the extended DSL
* statistics: per-node label skew matches its Beta(alpha, alpha) draw; the
  drifting PCA stream's top eigenvector rotates at the configured rate
* engine integration: mid-stream topology switches retrace nothing
  (trace-counted); link-only fault schedules stay on the non-elastic driver
  path and surface bw_factor / link_drops in the history
"""
import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, __file__.rsplit("/", 1)[0])
from _prop import given, settings, st
from _trace import traced

from repro.configs import get_config, reduced
from repro.configs.base import (AveragingConfig, GovernorConfig, RunConfig,
                                ScenarioConfig, SHAPES, StreamConfig)
from repro.configs.paper_logreg import LogRegConfig
from repro.configs.paper_pca import FIG7, PCARunConfig
from repro.core import krasulina, mixing, scenarios
from repro.core.faults import FaultSchedule, LinkFault
from repro.data import synthetic
from repro.data.lm import MarkovTokenStream
from repro.launch.mesh import make_mesh
from repro.launch.sharding import activation_rules
from repro.models.common import mesh_rules
from repro.train.driver import EngineConfig, StreamingDriver
from repro.train.trainer import (build_superstep, init_state,
                                 make_node_batch, replicate_for_nodes)


# ---------------------------------------------------------------------------
# Property suite: operator contracts for everything the registry can produce
# ---------------------------------------------------------------------------


@settings(max_examples=24, deadline=None)
@given(st.sampled_from(scenarios.TOPOLOGIES), st.integers(2, 12),
       st.integers(0, 5))
def test_topology_operator_contracts(topology, n, seed):
    A = scenarios.topology_matrix(topology, n, seed=seed)
    assert A.shape == (n, n)
    assert np.all(A >= -1e-12)
    assert mixing.is_doubly_stochastic(A)
    assert mixing.lambda2(A) < 1.0 - 1e-9


@settings(max_examples=8, deadline=None)
@given(st.sampled_from(sorted(scenarios.SCENARIOS)))
def test_registered_scenario_rounds_doubly_stochastic(name):
    scn = scenarios.get_scenario(name)
    for A in scenarios.one_round_matrices(scn):
        assert np.all(np.asarray(A) >= -1e-12)
        assert mixing.is_doubly_stochastic(np.asarray(A))


@settings(max_examples=8, deadline=None)
@given(st.sampled_from(sorted(scenarios.SCENARIOS)))
def test_registered_scenario_b_connected(name):
    """eq. 17: every full-period window product of realized one-round
    operators contracts — the union graph over the window connects."""
    scn = scenarios.get_scenario(name)
    assert scenarios.window_lambda2(scn) < 1.0 - 1e-9


def test_tv_schedule_b_connected_at_window_b():
    """The time-varying schedule is B-connected at B = one topology cycle,
    not just over the (possibly much longer) link period."""
    scn = scenarios.get_scenario("tv_rte/clean/iid_pca")
    assert scenarios.window_lambda2(scn, window=6) < 1.0 - 1e-9


@settings(max_examples=10, deadline=None)
@given(st.integers(4, 10), st.integers(1, 40))
def test_lossy_realization_is_metropolis_of_surviving_graph(n, t):
    """When the drop leaves the graph connected, the realized operator is
    exactly `metropolis_weights` of the surviving adjacency."""
    sched = FaultSchedule(n, links=(LinkFault(0, 1, "link", 1, 64, prob=1.0),),
                          seed=3)
    A = scenarios.topology_matrix("circulant2", n)
    got = sched.lossy_matrix(A, t)
    adj = (A > 0).astype(float)
    np.fill_diagonal(adj, 0.0)
    adj[0, 1] = adj[1, 0] = 0.0
    expect = mixing.metropolis_weights(adj)
    np.testing.assert_allclose(got, expect, atol=1e-12)
    assert mixing.is_doubly_stochastic(got)


def test_lossy_disconnection_degrades_to_self_weight():
    """Dropping the only edge of a 2-ring folds its mass onto the diagonal:
    still doubly stochastic, consensus paused for the round (the B-round
    window recovers it — eq. 17)."""
    sched = FaultSchedule(2, links=(LinkFault(0, 1, "link", 1, 8, prob=1.0),),
                          seed=0)
    A = scenarios.topology_matrix("ring", 2)
    got = sched.lossy_matrix(A, 3)
    np.testing.assert_allclose(got, np.eye(2), atol=1e-12)


# ---------------------------------------------------------------------------
# Parity: constant-schedule ScheduledMixOp == static mix ops, bitwise
# ---------------------------------------------------------------------------

N = 8
R = 2


def test_scheduled_equals_circulant_on_krasulina_superstep():
    av = AveragingConfig(mode="gossip", rounds=R, topology="ring")
    static = mixing.circulant_mix_op(mixing.schedule("ring", N), N, R,
                                     impl="matmul")
    sched = mixing.scheduled_mix_op([mixing.schedule("ring", N)], N, R)
    stepsize = lambda t: 5.0 / t
    a = krasulina.build_krasulina_superstep(av, N, stepsize, mix=static,
                                            fuse_xi=False)
    b = krasulina.build_krasulina_superstep(av, N, stepsize, mix=sched)
    w0 = jax.random.normal(jax.random.PRNGKey(1), (FIG7.dim,))
    state = krasulina.init_krasulina_state(w0 / jnp.linalg.norm(w0), av, N)
    batches = {"z": jax.random.normal(jax.random.PRNGKey(2),
                                      (3, N, 4, FIG7.dim))}
    sa, ma = jax.jit(a)(state, batches)
    sb, mb = jax.jit(b)(state, batches)
    np.testing.assert_array_equal(np.asarray(sa.w), np.asarray(sb.w))
    np.testing.assert_array_equal(np.asarray(ma["consensus_err"]),
                                  np.asarray(mb["consensus_err"]))


def test_scheduled_equals_dense_on_krasulina_superstep():
    av = AveragingConfig(mode="gossip", rounds=R)
    A = scenarios.topology_matrix("geometric", N, seed=4)
    static = mixing.dense_mix_op(A, R)
    sched = mixing.scheduled_mix_op([A], N, R)
    stepsize = lambda t: 5.0 / t
    a = krasulina.build_krasulina_superstep(av, N, stepsize, mix=static,
                                            fuse_xi=False)
    b = krasulina.build_krasulina_superstep(av, N, stepsize, mix=sched)
    w0 = jax.random.normal(jax.random.PRNGKey(1), (FIG7.dim,))
    state = krasulina.init_krasulina_state(w0 / jnp.linalg.norm(w0), av, N)
    batches = {"z": jax.random.normal(jax.random.PRNGKey(2),
                                      (3, N, 4, FIG7.dim))}
    sa, _ = jax.jit(a)(state, batches)
    sb, _ = jax.jit(b)(state, batches)
    np.testing.assert_array_equal(np.asarray(sa.w), np.asarray(sb.w))


def _lm_run_cfg(rounds=R):
    cfg = dataclasses.replace(
        reduced(get_config("granite-8b"), layers=1, d_model=16),
        vocab_size=32, d_ff=32)
    return RunConfig(model=cfg, shape=SHAPES["train_4k"],
                     averaging=AveragingConfig("gossip", rounds),
                     optimizer="adam", learning_rate=1e-3,
                     param_dtype="float32", remat=False)


def test_scheduled_equals_circulant_on_lm_superstep():
    run_cfg = _lm_run_cfg()
    mesh = make_mesh((1, 1), ("data", "model"))
    n_nodes, K, seq = 4, 2, 16
    static = mixing.circulant_mix_op(mixing.schedule("ring", n_nodes),
                                     n_nodes, R, impl="matmul")
    sched = mixing.scheduled_mix_op([mixing.schedule("ring", n_nodes)],
                                    n_nodes, R)
    data = MarkovTokenStream(32, seed=0)
    rng = np.random.default_rng(0)
    toks = np.stack([data.sample(rng, 8, seq + 1) for _ in range(K)])
    batch = make_node_batch({"tokens": jnp.asarray(toks[:, :, :-1]),
                             "labels": jnp.asarray(toks[:, :, 1:])},
                            n_nodes, axis=1)
    with mesh_rules(mesh, activation_rules(mesh, run_cfg.shape,
                                           node_axis=True)):
        state0 = replicate_for_nodes(init_state(run_cfg,
                                                jax.random.PRNGKey(0)),
                                     n_nodes)
        sup_a = jax.jit(build_superstep(run_cfg, mesh, n_nodes=n_nodes,
                                        mix=static)[0])
        sup_b = jax.jit(build_superstep(run_cfg, mesh, n_nodes=n_nodes,
                                        mix=sched)[0])
        sa, ma = sup_a(state0, batch)
        sb, mb = sup_b(state0, batch)
    for x, y in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    np.testing.assert_array_equal(np.asarray(ma["consensus_err"]),
                                  np.asarray(mb["consensus_err"]))


def test_scheduled_mix_rejects_quantized_lm_config():
    run_cfg = _lm_run_cfg()
    run_cfg = dataclasses.replace(
        run_cfg, averaging=dataclasses.replace(run_cfg.averaging,
                                               quantization="int8"))
    mesh = make_mesh((1, 1), ("data", "model"))
    sched = mixing.scheduled_mix_op([mixing.schedule("ring", 4)], 4, R)
    with pytest.raises(ValueError, match="linear-only"):
        build_superstep(run_cfg, mesh, n_nodes=4, mix=sched)


# ---------------------------------------------------------------------------
# Zero retraces: the phase is runtime data
# ---------------------------------------------------------------------------


def test_phase_switch_is_not_a_retrace():
    scn = scenarios.get_scenario("tv_rte/clean/iid_pca")
    mix = scenarios.build_mix(scn)
    traces = []

    def _step(x, t):
        return mix(x, t=t)

    step = jax.jit(traced(_step, traces))

    x = jax.random.normal(jax.random.PRNGKey(0), (scn.n_nodes, 3))
    outs = [np.asarray(step(x, jnp.asarray(t))) for t in range(1, 13)]
    assert len(traces) == 1
    # the schedule actually varies (ring round vs torus round)...
    assert not np.array_equal(outs[0], outs[2])
    # ...and repeats with the period
    np.testing.assert_array_equal(outs[0], outs[6])


def test_scheduled_phase_lookup_matches_schedule():
    scn = scenarios.get_scenario("tv_rte/clean/iid_pca")
    mix = scenarios.build_mix(scn)
    mats = scenarios.one_round_matrices(scn)
    period = scenarios.scenario_period(scn)
    assert mix.period == period
    x = jax.random.normal(jax.random.PRNGKey(3), (scn.n_nodes, 5))
    for t in range(1, period + 1):
        want = np.linalg.matrix_power(np.asarray(mats[t % period]),
                                      scn.rounds) @ np.asarray(x)
        got = np.asarray(mix(x, t=jnp.asarray(t)))
        np.testing.assert_allclose(got, want, atol=1e-5)


# ---------------------------------------------------------------------------
# Determinism: counter-based link RNG + DSL round-trip
# ---------------------------------------------------------------------------


def test_link_drops_deterministic_across_instances():
    spec = "link:0-1@1-40p0.4,link:2-3@1-40p0.4"
    a = FaultSchedule.parse(spec, 8, seed=5)
    b = FaultSchedule.parse(spec, 8, seed=5)
    drops = [a.link_drops(t) for t in range(1, 41)]
    assert drops == [b.link_drops(t) for t in range(1, 41)]
    assert any(drops), "p=0.4 over 40 rounds must realize some drop"
    c = FaultSchedule.parse(spec, 8, seed=6)
    assert drops != [c.link_drops(t) for t in range(1, 41)], \
        "a different seed must realize a different drop sequence"


@settings(max_examples=12, deadline=None)
@given(st.sampled_from([
    "link:0-1@1-64p0.1",
    "bw:2-3@5-15x4",
    "bw:2-3@5x2.5",
    "death:1@5-12,slow:0@3-9x4,link:0-1@1-64p0.25,bw:1-2@2-9x8",
    "flaky:2@4p3,link:3-4@1-7p1",
]), st.integers(0, 3))
def test_fault_dsl_round_trip(spec, seed):
    s = FaultSchedule.parse(spec, 8, seed=seed)
    assert FaultSchedule.parse(str(s), 8, seed=seed) == s


def _lossy_driver(prefetch):
    scn = scenarios.make_scenario("ring", "lossy", "iid_pca", n_nodes=8)
    stream = scenarios.build_stream(scn)
    run_cfg = PCARunConfig(pca=FIG7,
                           averaging=scenarios.averaging_config(scn),
                           stream=StreamConfig())
    inner = krasulina.krasulina_superstep_builder(
        run_cfg.averaging, 8, lambda t: 10.0 / t,
        mix=scenarios.build_mix(scn))
    w0 = jax.random.normal(jax.random.PRNGKey(0), (FIG7.dim,))
    state = krasulina.init_krasulina_state(w0 / jnp.linalg.norm(w0),
                                           run_cfg.averaging, 8)
    return StreamingDriver(
        run_cfg, None, state, stream.sample, superstep_builder=inner,
        n_nodes=8, batch=16, faults=scenarios.fault_schedule(scn),
        engine=EngineConfig(superstep=2, prefetch_depth=prefetch,
                            replan_every=0, warmup_supersteps=0,
                            warmup_per_bucket=0, governor=GovernorConfig()))


def test_lossy_run_bit_identical_across_prefetch_depths():
    finals = []
    for prefetch in (0, 2):
        with _lossy_driver(prefetch) as drv:
            drv.run(3)
            finals.append(np.asarray(drv.state.w).copy())
    np.testing.assert_array_equal(finals[0], finals[1])


def test_link_only_faults_stay_non_elastic_and_observable():
    with _lossy_driver(0) as drv:
        assert not drv._elastic  # link models never force the elastic path
        drv.run(2)
        rec = drv.history[-1]
        assert rec["bw_factor"] == 1.0  # lossy axis has no bandwidth cap
        assert "link_drops" in rec


# ---------------------------------------------------------------------------
# Non-IID stream statistics
# ---------------------------------------------------------------------------


def test_skewed_logreg_matches_dirichlet_partition():
    cfg = LogRegConfig(dim=5, generator="cond_gauss", noise_var=2.0)
    lr = synthetic.make_skewed_logreg_sampler(cfg, 4, alpha=0.4, seed=1)
    n = 40_000
    batch = lr.sample(np.random.default_rng(0), n)
    y = batch["y"].reshape(4, n // 4)
    emp = (y > 0).mean(axis=1)
    np.testing.assert_allclose(emp, lr.node_pos_prob, atol=0.02)
    # severe skew: the per-node proportions actually differ across nodes
    assert lr.node_pos_prob.std() > 0.05
    iid = synthetic.make_skewed_logreg_sampler(cfg, 4, alpha=float("inf"),
                                               seed=1)
    np.testing.assert_array_equal(iid.node_pos_prob, np.full(4, 0.5))


def test_skewed_logreg_deterministic_and_w_star_shape():
    cfg = LogRegConfig(dim=5, generator="cond_gauss", noise_var=2.0)
    a = synthetic.make_skewed_logreg_sampler(cfg, 4, alpha=0.4, seed=1)
    b = synthetic.make_skewed_logreg_sampler(cfg, 4, alpha=0.4, seed=1)
    np.testing.assert_array_equal(a.node_pos_prob, b.node_pos_prob)
    np.testing.assert_array_equal(a.w_star, b.w_star)
    assert a.w_star.shape == (cfg.dim + 1,)
    ba = a.sample(np.random.default_rng(3), 64)
    bb = b.sample(np.random.default_rng(3), 64)
    np.testing.assert_array_equal(ba["x"], bb["x"])
    np.testing.assert_array_equal(ba["y"], bb["y"])


def test_drifting_pca_rotates_at_configured_rate():
    rate = 5e-5
    drift = synthetic.make_drifting_pca_sampler(FIG7, rate=rate)
    v0 = drift.top_eigvec_at(0)
    t = 20_000
    vt = drift.top_eigvec_at(t)
    # ground-truth clock: the rotation angle is exactly rate * t
    np.testing.assert_allclose(abs(float(v0 @ vt)), abs(np.cos(rate * t)),
                               atol=1e-9)
    # empirical: each drawn batch follows the sampler's internal sample clock
    rng = np.random.default_rng(0)
    z0 = drift.sample(rng, t)["z"]  # clock 0 -> t
    z1 = drift.sample(rng, t)["z"]  # clock t -> 2t
    for z, expect in ((z0, v0), (z1, vt)):
        _, vecs = np.linalg.eigh(np.cov(z.T))
        top = vecs[:, -1]
        assert abs(float(top @ expect)) > 0.95
    # the rotation is real: batch 2's top eigenvector left batch 1's
    _, vecs = np.linalg.eigh(np.cov(z1.T))
    assert abs(float(vecs[:, -1] @ v0)) < abs(np.cos(rate * t)) + 0.1


def test_drift_rate_zero_is_stationary():
    drift = synthetic.make_drifting_pca_sampler(FIG7, rate=0.0)
    np.testing.assert_allclose(drift.cov_at(0), drift.cov_at(10_000),
                               atol=1e-12)


# ---------------------------------------------------------------------------
# Registry semantics
# ---------------------------------------------------------------------------


def test_registry_build_is_deterministic():
    scn = scenarios.get_scenario("ring/lossy/iid_pca")
    a, b = scenarios.build_mix(scn), scenarios.build_mix(scn)
    np.testing.assert_array_equal(np.asarray(a.A_stack),
                                  np.asarray(b.A_stack))
    np.testing.assert_array_equal(np.asarray(a.phase_by_round),
                                  np.asarray(b.phase_by_round))
    reseeded = scenarios.build_mix(dataclasses.replace(scn, seed=9))
    assert not np.array_equal(np.asarray(a.A_stack),
                              np.asarray(reseeded.A_stack))


def test_scenario_rejects_open_ended_link_fault():
    scn = ScenarioConfig(name="bad", n_nodes=4, links="link:0-1@5p0.5")
    with pytest.raises(ValueError, match="bounded window"):
        scenarios.build_mix(scn)


def test_scenario_rejects_node_faults_in_links():
    scn = ScenarioConfig(name="bad", n_nodes=4, links="death:1@5-12")
    with pytest.raises(ValueError, match="node faults"):
        scenarios.build_mix(scn)


def test_unknown_scenario_and_axis_coverage():
    with pytest.raises(KeyError, match="registered"):
        scenarios.get_scenario("nope")
    # the benchmark matrix spans >= 3 values per axis
    assert len(scenarios.TOPOLOGY_AXIS) >= 3
    assert len(scenarios.LINK_AXIS) >= 3
    assert len(scenarios.STREAM_AXIS) >= 3
