"""Convergence tests for the paper's four algorithms, validated against the
paper's own claims:

* DMB (Thm 4): O(B) speed-up in iterations; mini-batching up to B ~ sqrt(t')
  does not hurt sample efficiency; mu << B discards are tolerated (Fig. 6).
* DM-Krasulina (Thm 5/Cor 1): excess risk O(1/t'); large-B degradation (Fig. 7).
* D-SGD / AD-SGD (Thms 6-7): gossip with enough rounds ~ exact averaging,
  beats local SGD (Fig. 9).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_logreg import FIG6, FIG9
from repro.configs.paper_pca import PCAConfig
from repro.core import dmb, dsgd, krasulina, mixing, problems
from repro.data.synthetic import make_logreg_stream, make_pca_stream


def _logreg_setup(cfg):
    stream = make_logreg_stream(cfg)
    grad = lambda w, x, y: problems.logistic_grad(w, x, y)
    metric = lambda w: jnp.sum((w - stream.w_star) ** 2)
    return stream, grad, metric


def test_dmb_converges_and_minibatch_speedup():
    stream, grad, metric = _logreg_setup(FIG6)
    d = FIG6.dim + 1
    w0 = jnp.zeros(d)
    stepsize = lambda t: 2.0 / jnp.sqrt(t)  # c picked by trial, like the paper

    # B=100: 200 rounds = 20k samples
    res = dmb.run_dmb(grad, stream.draw, w0, N=10, B=100, steps=200,
                      stepsize=stepsize, trace_metric=metric)
    err_final = float(res.trace_metric[-1])
    err_init = float(metric(w0))
    assert err_final < 0.05 * err_init, f"DMB did not converge: {err_final}"

    # same t' with B=1000 (fewer iterations, bigger batches) is comparable
    res2 = dmb.run_dmb(grad, stream.draw, w0, N=10, B=1000, steps=20,
                       stepsize=lambda t: 8.0 / jnp.sqrt(t), trace_metric=metric)
    assert float(res2.trace_metric[-1]) < 0.15 * err_init


def test_dmb_discards_small_mu_tolerated():
    stream, grad, metric = _logreg_setup(FIG6)
    w0 = jnp.zeros(FIG6.dim + 1)
    stepsize = lambda t: 0.5 / jnp.sqrt(t)
    base = dmb.run_dmb(grad, stream.draw, w0, N=10, B=500, mu=0, steps=60,
                       stepsize=stepsize, trace_metric=metric, seed=1)
    lossy = dmb.run_dmb(grad, stream.draw, w0, N=10, B=500, mu=100, steps=60,
                        stepsize=stepsize, trace_metric=metric, seed=1)
    # mu = B/5 discards barely change the final error (Fig. 6b)
    assert float(lossy.trace_metric[-1]) < 3.0 * float(base.trace_metric[-1]) + 1e-3
    # but the lossy run consumed more arrived samples for the same iterations
    assert int(lossy.trace_t_prime[-1]) == 60 * 600


def test_dmb_polyak_average_tracks():
    stream, grad, metric = _logreg_setup(FIG6)
    w0 = jnp.zeros(FIG6.dim + 1)
    res = dmb.run_dmb(grad, stream.draw, w0, N=5, B=100, steps=300,
                      stepsize=lambda t: 5.0 / jnp.sqrt(t), trace_metric=metric)
    assert float(metric(res.w_av)) < 0.1 * float(metric(w0))


def test_dm_krasulina_converges():
    cfg = PCAConfig(dim=10, eigengap=0.1)
    stream = make_pca_stream(cfg)
    metric = lambda w: problems.sin2_error(w, stream.top_eigvec)
    w0 = jax.random.normal(jax.random.PRNGKey(3), (cfg.dim,))
    w0 = w0 / jnp.linalg.norm(w0)
    res = krasulina.run_dm_krasulina(
        stream.draw, w0, N=10, B=100, steps=1000,
        stepsize=lambda t: 10.0 / t, trace_metric=metric)
    assert float(res.trace_metric[-1]) < 1e-2, float(res.trace_metric[-1])
    # excess risk (paper's metric) also small
    xr = problems.pca_excess_risk(res.w, stream.cov, stream.lambda1)
    assert float(xr) < 5e-3


def test_dm_krasulina_b_speedup_same_samples():
    """Fig. 7a: for fixed t', B in {10, 100} reach similar excess risk."""
    cfg = PCAConfig(dim=10, eigengap=0.1)
    stream = make_pca_stream(cfg)
    metric = lambda w: problems.sin2_error(w, stream.top_eigvec)
    w0 = jax.random.normal(jax.random.PRNGKey(3), (cfg.dim,))
    t_prime = 100_000
    errs = {}
    for B in (10, 100):
        res = krasulina.run_dm_krasulina(
            stream.draw, w0, N=10 if B >= 10 else 1, B=B, steps=t_prime // B,
            stepsize=lambda t: 10.0 / t, trace_metric=metric, seed=5)
        errs[B] = float(res.trace_metric[-1])
    assert errs[100] < 10 * max(errs[10], 1e-4) + 1e-3


def test_dsgd_gossip_approaches_exact():
    stream, grad, metric = _logreg_setup(FIG9)
    d = FIG9.dim + 1
    w0 = jnp.zeros(d)
    N = 16
    A = jnp.asarray(mixing.random_regular_expander(N, deg=6, seed=0))
    step = lambda t: 2.5 / jnp.sqrt(t)

    res_many = dsgd.run_dsgd(grad, stream.draw, w0, A, B=N * 4, rounds=8,
                             steps=150, stepsize=step, trace_metric=metric, seed=2)
    res_local = dsgd.run_local_sgd(grad, stream.draw, w0, N=N, B=N * 4, steps=150,
                                   stepsize=step, trace_metric=metric, seed=2)
    # collaboration beats local SGD (Fig. 9)
    assert float(res_many.trace_metric[-1]) < float(res_local.trace_metric[-1])
    # nodes reach near-consensus with 8 rounds/iter
    spread = jnp.max(jnp.std(res_many.w, axis=0))
    assert float(spread) < 0.15


def test_adsgd_converges_in_excess_risk():
    """AD-SGD with Theorem 7's growing stepsize eta_t = eta*(t+1)/2 drives the
    *excess risk* (the paper's metric — Fig. 9 plots risk, not parameter error;
    this generator is nearly separable so parameter error converges slowly)."""
    stream, grad, _ = _logreg_setup(FIG9)
    xe, ye = stream.draw(jax.random.PRNGKey(99), 50_000)
    bayes = problems.logistic_loss(stream.w_star, xe, ye)
    metric = lambda w: problems.logistic_loss(w, xe, ye) - bayes
    w0 = jnp.zeros(FIG9.dim + 1)
    N = 16
    A = jnp.asarray(mixing.random_regular_expander(N, deg=6, seed=0))
    res = dsgd.run_dsgd(grad, stream.draw, w0, A, B=N * 4, rounds=6, steps=300,
                        stepsize=lambda t: 0.05 * (t + 1.0) / 2.0,
                        trace_metric=metric, accelerated=True, seed=4,
                        project=lambda w: problems.project_ball(w, 10.0))
    assert float(res.trace_metric[-1]) < 0.05, float(res.trace_metric[-1])
    # and it improves monotonically-ish over the run
    assert float(res.trace_metric[-1]) < 0.1 * float(res.trace_metric[0])


def test_dgd_baseline_runs():
    stream, grad, metric = _logreg_setup(FIG9)
    w0 = jnp.zeros(FIG9.dim + 1)
    N = 8
    A = jnp.asarray(mixing.random_regular_expander(N, deg=4, seed=1))
    res = dsgd.run_dgd(grad, stream.draw, w0, A, B=16, steps=300,
                       stepsize=lambda t: 1.0 / jnp.sqrt(t), trace_metric=metric)
    assert float(res.trace_metric[-1]) < float(metric(w0))
