"""Integration tests for the framework trainer on a REAL multi-device mesh
(8 fake host devices in a subprocess, since jax pins the device count at
import): exact vs gossip vs hierarchical averaging semantics at LM scale.
"""
import json
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")


def _run(mode: str, rounds: int = 2):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    p = subprocess.run(
        [sys.executable, os.path.join(HERE, "dist_worker.py"), mode, str(rounds)],
        capture_output=True, text=True, timeout=900, env=env)
    assert p.returncode == 0, p.stderr[-3000:]
    return json.loads(p.stdout.strip().splitlines()[-1])


@pytest.fixture(scope="module")
def exact_res():
    return _run("exact")


@pytest.fixture(scope="module")
def gossip_res():
    return _run("gossip", rounds=2)


def test_exact_trains_on_8_devices(exact_res):
    r = exact_res
    assert r["n_devices"] == 8 and r["n_nodes"] == 8
    assert r["losses"][-1] < r["losses"][0]
    assert all(e == 0.0 for e in r["consensus_errs"])


def test_gossip_trains_and_nodes_diverge(gossip_res):
    r = gossip_res
    assert r["losses"][-1] < r["losses"][0]
    # inexact averaging: mixed gradients still disagree across nodes...
    assert max(r["consensus_errs"]) > 0.0
    # ...so decentralized parameters drift apart (epsilon-consensus, not zero)
    assert 0.0 < r["param_spread"] < 0.5


def test_gossip_more_rounds_tighter_consensus(gossip_res):
    tight = _run("gossip", rounds=8)
    assert tight["consensus_errs"][-1] < gossip_res["consensus_errs"][-1]


def test_gossip_close_to_exact_in_loss(exact_res, gossip_res):
    # same stream, same init: trajectories should be close but not identical
    le, lg = exact_res["losses"][-1], gossip_res["losses"][-1]
    assert abs(le - lg) / le < 0.2
    assert exact_res["losses"] != gossip_res["losses"]
