"""The rate model (paper Section II-C, eqs. 3-4) and the provisioning planner."""
import math

import pytest
from _prop import given, settings, st

from repro.configs.base import StreamConfig
from repro.core import rates


def test_effective_rate_matches_eq4():
    # Fig. 5's setting: N=10, Rp=1.25e5, Rc in {1e3, 1e4}
    B, N, R, Rp, Rc = 500, 10, 10, 1.25e5, 1e4
    Re = rates.effective_rate(B, N, R, Rp, Rc)
    assert Re == pytest.approx(1.0 / (B / (N * Rp) + R / Rc))


def test_nondistributed_special_case():
    # N=1, R=0 -> R_e = R_p / B (paper, below eq. 4)
    assert rates.effective_rate(200, 1, 0, 1e5, 1e3) == pytest.approx(1e5 / 200)


@given(st.integers(1, 64), st.integers(1, 20),
       st.floats(1e3, 1e7), st.floats(1e3, 1e7), st.floats(1e2, 1e6))
@settings(max_examples=80, deadline=None)
def test_max_rounds_consistency(N, R, Rs, Rp, Rc):
    """If R <= max_rounds(B,...) then the system keeps up: R_s <= B*R_e."""
    B = 64 * N
    rmax = rates.max_rounds(B, N, Rs, Rp, Rc)
    if rmax >= 1 and R <= rmax:
        Re = rates.effective_rate(B, N, R, Rp, Rc)
        assert Rs <= B * Re * (1 + 1e-9)


@given(st.floats(1e4, 1e6), st.floats(1e4, 1e6), st.floats(1e3, 1e5),
       st.integers(2, 32), st.integers(1, 8))
@settings(max_examples=60, deadline=None)
def test_planner_keeps_up_when_feasible(Rs, Rp, Rc, N, R):
    sc = StreamConfig(streaming_rate=Rs, processing_rate=Rp, comms_rate=Rc)
    if Rs >= N * Rp * 0.999:
        return  # infeasible; planner raises (tested separately)
    p = rates.plan(sc, N, R)
    assert p.B % N == 0
    # with the planned B the system keeps up without discards
    assert p.mu == 0
    assert Rs <= p.B * p.Re * (1 + 1e-6)


def test_planner_infeasible_raises():
    sc = StreamConfig(streaming_rate=1e6, processing_rate=1e4, comms_rate=1e4)
    with pytest.raises(ValueError):
        rates.plan(sc, 10, 1)  # N*Rp = 1e5 < Rs


def test_planner_underprovisioned_mu():
    # force a small B so the system cannot keep up -> mu > 0 (Alg. 1 step 9)
    sc = StreamConfig(streaming_rate=1e6, processing_rate=1.25e5, comms_rate=1e3)
    p = rates.plan(sc, 10, 10, B=500)
    assert p.regime == "under-provisioned"
    assert p.mu > 0
    Re = rates.effective_rate(500, 10, 10, 1.25e5, 1e3)
    assert p.mu == math.ceil(1e6 / Re - 500)


def test_horizon_ceiling_thm4():
    # B is clipped to sqrt(t') per Theorem 4's order-optimality condition
    sc = StreamConfig(streaming_rate=1e5, processing_rate=1e5, comms_rate=1e5)
    p = rates.plan(sc, 10, 1, B=100_000, horizon_samples=1e6)
    assert p.B <= math.sqrt(1e6)


def test_min_comms_rate_eq26():
    # eq. (26): increasing B relaxes the R_c requirement
    r1 = rates.min_comms_rate_for_optimality(100, 10, 5, 1e5, 1e5)
    r2 = rates.min_comms_rate_for_optimality(1000, 10, 5, 1e5, 1e5)
    assert r2 < r1


def test_dmb_stepsize_thm4():
    assert rates.dmb_stepsize(1, L=2.0, sigma=1.0, D_W=1.0) == pytest.approx(1 / 3)
    assert rates.dmb_stepsize(100, 2.0, 1.0, 1.0) == pytest.approx(1 / 12)
