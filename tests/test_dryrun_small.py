"""CI-scale proof of the dry-run machinery: a subprocess with 8 fake devices
lowers + compiles train and decode steps for reduced archs on a 4x2 mesh and
reports memory/cost/collective stats — the same code path the production
16x16 / 2x16x16 sweep uses (artifacts in artifacts/dryrun)."""
import json
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")

WORKER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax, jax.numpy as jnp
import dataclasses
import repro.launch.dryrun as dr
import repro.configs as C
from repro.launch import sharding as shlib
from repro.models.common import mesh_rules

arch, shape = sys.argv[1], sys.argv[2]
orig_get = C.get_config
small = C.reduced(orig_get(arch))
dr.get_config = lambda a: small
# shrink the input shapes to CI size
base = C.SHAPES[shape]
tiny = dataclasses.replace(base, seq_len=256, global_batch=8)
dr.SHAPES = dict(C.SHAPES); dr.SHAPES[shape] = tiny

from repro.launch.mesh import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
rules = shlib.activation_rules(mesh, tiny)
with mesh_rules(mesh, rules):
    fn, args, _ = dr.build_lowerable(arch, shape, mesh, "exact", 1, microbatches=1)
    compiled = fn.lower(*args).compile()
ma = compiled.memory_analysis()
ca = dr.cost_analysis_dict(compiled)
print(json.dumps({
    "temp_gib": ma.temp_size_in_bytes / 2**30,
    "flops": ca.get("flops", 0.0),
    "collectives": dr.parse_collectives(compiled.as_text()),
}))
"""


def _run(arch, shape):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    p = subprocess.run([sys.executable, "-c", WORKER, arch, shape],
                       capture_output=True, text=True, timeout=900, env=env)
    assert p.returncode == 0, p.stderr[-3000:]
    return json.loads(p.stdout.strip().splitlines()[-1])


# NOTE: reduced qwen2-moe (4 experts on a model=2 axis) trips an XLA SPMD
# partitioner CHECK (device_groups 2 vs 8) at this toy mesh; the full config on
# the production 16x16 / 2x16x16 meshes compiles fine (see artifacts/dryrun).
# The MoE family is covered here by reduced llama4 instead.
@pytest.mark.parametrize("arch,shape", [
    ("granite-8b", "train_4k"),
    ("llama4-scout-17b-a16e", "train_4k"),
    ("mamba2-2.7b", "decode_32k"),
])
def test_small_mesh_dryrun_compiles(arch, shape):
    rec = _run(arch, shape)
    assert rec["flops"] > 0
    assert rec["temp_gib"] < 8.0
    # data-parallel training must exhibit gradient aggregation collectives
    if shape == "train_4k":
        assert rec["collectives"].get("all-reduce", 0) > 0 or \
            rec["collectives"].get("reduce-scatter", 0) > 0
