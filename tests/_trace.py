"""Shared trace/retrace counting helpers for the test suite.

jit runs the *python* body of a function once per trace (one per new input
signature), never per call — so a python-side append inside the wrapped body
counts compilations exactly. Three test families share the idiom: the
adaptive-B governor (one trace per bucket), elastic membership (one trace per
(bucket, cohort), zero on rejoin), and the scenario harness (phase switches
are runtime data, zero retraces). `hlo_collective_permutes` is the companion
*lowering* counter: the shard_map gossip tests pin the exact number of
collective-permute ops their partitioning rule emits.
"""
import inspect


def traced(fn, log, tag=1):
    """Wrap `fn` so each jit TRACE (not call) appends `tag` to `log`."""

    def counted(*args, **kwargs):
        log.append(tag)  # runs once per jit trace, not per call
        return fn(*args, **kwargs)

    return counted


def wrap_builder(builder, log, tag=None):
    """Wrap a driver superstep builder so every supestep it builds logs one
    tag per jit trace.

    `builder` may take `(B)` or `(B, membership)` (both driver protocols).
    The default tag is the bucket `B`, or `(B, membership.n_active)` when a
    cohort membership is passed — pass `tag=fn(B, membership)` to override.
    """
    takes_membership = "membership" in inspect.signature(builder).parameters

    def build(B, membership=None):
        raw = builder(B, membership) if takes_membership else builder(B)
        if tag is not None:
            t = tag(B, membership)
        elif membership is None:
            t = B
        else:
            t = (B, membership.n_active)
        return traced(raw, log, t)

    return build


def hlo_collective_permutes(jitted, *args) -> int:
    """Number of collective-permute ops in the compiled HLO of
    `jitted(*args)` — counts both the fused and the async-pair
    (`-start`/`-done`) lowerings once each."""
    txt = jitted.lower(*args).compile().as_text()
    return (txt.count("collective-permute(")
            + txt.count("collective-permute-start("))
