"""Ring-buffer windowed KV cache (perf iteration): a W-slot ring must reproduce
full-cache decoding exactly for sliding-window attention."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models import registry


@pytest.mark.parametrize("prefill_len", [100, 150])  # < W and > W after ring
def test_ring_matches_full_cache(prefill_len):
    cfg = dataclasses.replace(reduced(get_config("starcoder2-15b")),
                              ring_buffer_cache=True)
    W = cfg.sliding_window
    assert W == 128
    total = prefill_len + 10
    params = registry.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, total), 0,
                              cfg.vocab_size, jnp.int32)
    full, _, _ = registry.forward(params, cfg, {"tokens": toks}, remat=False)

    cache = registry.init_cache(cfg, 1, total, jnp.float32)
    # the attention cache is ring-sized (capped at W), not seq-sized
    assert jax.tree.leaves(cache)[0].shape[2] == min(W, total)
    logits, cache = registry.prefill(params, cfg,
                                     {"tokens": toks[:, :prefill_len]}, cache)
    assert jnp.allclose(logits[:, -1], full[:, prefill_len - 1], atol=3e-3)
    outs = []
    for i in range(prefill_len, total):
        lg, cache = registry.decode_step(params, cfg, toks[:, i:i + 1], cache,
                                         jnp.asarray(i, jnp.int32))
        outs.append(lg)
    inc = jnp.concatenate(outs, 1)
    assert jnp.allclose(inc, full[:, prefill_len:], atol=5e-3), float(
        jnp.max(jnp.abs(inc - full[:, prefill_len:])))
