"""Serving engine: prefill + greedy decode consistency, batching, sampling."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models import registry
from repro.serve import engine


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("granite-8b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    return cfg, params


def test_generate_shapes(setup):
    cfg, params = setup
    prompt = registry.synth_batch(jax.random.PRNGKey(1), cfg, 2, 16, mode="prefill")
    out = engine.generate(params, cfg, prompt, max_len=32, steps=8,
                          dtype=jnp.float32)
    assert out.shape == (2, 8)
    assert jnp.all((out >= 0) & (out < cfg.vocab_size))


def test_greedy_decode_deterministic(setup):
    cfg, params = setup
    prompt = registry.synth_batch(jax.random.PRNGKey(2), cfg, 1, 16, mode="prefill")
    a = engine.generate(params, cfg, prompt, 32, 6, dtype=jnp.float32)
    b = engine.generate(params, cfg, prompt, 32, 6, dtype=jnp.float32)
    assert jnp.array_equal(a, b)


def test_temperature_sampling_differs(setup):
    cfg, params = setup
    st = engine.init_serve(cfg, 1, 24, jnp.float32)
    prompt = registry.synth_batch(jax.random.PRNGKey(3), cfg, 1, 16, mode="prefill")
    st = engine.prefill(params, cfg, prompt, st)
    _, t1 = engine.serve_step(params, cfg, st, temperature=2.0,
                              key=jax.random.PRNGKey(1))
    _, t2 = engine.serve_step(params, cfg, st, temperature=2.0,
                              key=jax.random.PRNGKey(7))
    _, g = engine.serve_step(params, cfg, st)
    assert t1.shape == g.shape == (1, 1)


def test_serve_state_index_advances(setup):
    cfg, params = setup
    st = engine.init_serve(cfg, 1, 24, jnp.float32)
    prompt = registry.synth_batch(jax.random.PRNGKey(4), cfg, 1, 8, mode="prefill")
    st = engine.prefill(params, cfg, prompt, st)
    assert int(st.index) == 8
    st, _ = engine.serve_step(params, cfg, st)
    assert int(st.index) == 9
