"""Serving engine: prefill + greedy decode consistency, batching, sampling."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models import registry
from repro.serve import engine


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("granite-8b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    return cfg, params


def test_generate_shapes(setup):
    cfg, params = setup
    prompt = registry.synth_batch(jax.random.PRNGKey(1), cfg, 2, 16, mode="prefill")
    out = engine.generate(params, cfg, prompt, max_len=32, steps=8,
                          dtype=jnp.float32)
    assert out.shape == (2, 8)
    assert jnp.all((out >= 0) & (out < cfg.vocab_size))


def test_greedy_decode_deterministic(setup):
    cfg, params = setup
    prompt = registry.synth_batch(jax.random.PRNGKey(2), cfg, 1, 16, mode="prefill")
    a = engine.generate(params, cfg, prompt, 32, 6, dtype=jnp.float32)
    b = engine.generate(params, cfg, prompt, 32, 6, dtype=jnp.float32)
    assert jnp.array_equal(a, b)


def test_temperature_sampling_differs(setup):
    cfg, params = setup
    st = engine.init_serve(cfg, 1, 24, jnp.float32)
    prompt = registry.synth_batch(jax.random.PRNGKey(3), cfg, 1, 16, mode="prefill")
    st = engine.prefill(params, cfg, prompt, st)
    _, t1 = engine.serve_step(params, cfg, st, temperature=2.0,
                              key=jax.random.PRNGKey(1))
    _, t2 = engine.serve_step(params, cfg, st, temperature=2.0,
                              key=jax.random.PRNGKey(7))
    _, g = engine.serve_step(params, cfg, st)
    assert t1.shape == g.shape == (1, 1)


def test_serve_state_index_advances(setup):
    cfg, params = setup
    st = engine.init_serve(cfg, 1, 24, jnp.float32)
    prompt = registry.synth_batch(jax.random.PRNGKey(4), cfg, 1, 8, mode="prefill")
    st = engine.prefill(params, cfg, prompt, st)
    assert int(st.index) == 8
    st, _ = engine.serve_step(params, cfg, st)
    assert int(st.index) == 9


# ---------------------------------------------------------------------------
# Continuous batching (PR 7: train-to-serve hot publication)
# ---------------------------------------------------------------------------

import numpy as np

from repro.serve.engine import ContinuousBatchingEngine
from repro.serve.publisher import SnapshotPublisher


def _prompts(cfg, n, length, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=length) for _ in range(n)]


def test_continuous_matches_eager_generate(setup):
    """More requests than slots: admissions churn through the pool, and every
    request's token ids equal the static batch-1 generate path."""
    cfg, params = setup
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_len=32)
    prompts = _prompts(cfg, 5, 8, seed=1)
    rids = [eng.submit(p, 6) for p in prompts]
    eng.drain()
    for rid, p in zip(rids, prompts):
        req = eng.result(rid)
        assert len(req.tokens) == 6
        ref = engine.generate(params, cfg, {"tokens": jnp.asarray(p[None])},
                              32, 6, dtype=jnp.float32)
        assert ref[0].tolist() == req.tokens


def test_continuous_mamba_family_rides_same_plumbing():
    """Recurrent-state families use the identical slot cache path (their
    sequences are non-degenerate under random init, exercising the cache)."""
    cfg = reduced(get_config("mamba2-2.7b"))
    params = registry.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_len=48)
    prompts = _prompts(cfg, 3, 10, seed=3)
    rids = [eng.submit(p, 12) for p in prompts]
    eng.drain()
    for rid, p in zip(rids, prompts):
        ref = engine.generate(params, cfg, {"tokens": jnp.asarray(p[None])},
                              48, 12, dtype=jnp.float32)
        assert ref[0].tolist() == eng.result(rid).tokens


def test_decode_spanning_swap_bit_identical(setup):
    """A request alive across a version flip produces exactly the token ids
    of decoding each segment under its own params (zero in-flight loss, no
    cache invalidation)."""
    cfg, params = setup
    p_b = jax.tree.map(lambda a: -a, params)  # definitely different logits
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_len=48)
    prompt = _prompts(cfg, 1, 10, seed=5)[0]
    rid = eng.submit(prompt, 10)
    for _ in range(4):
        eng.step()
    n_a = len(next(iter(eng._active.values())).tokens)  # tokens under v0
    assert 0 < n_a < 10
    eng.swap_params(p_b, version=1)
    eng.drain()
    req = eng.result(rid)
    assert req.versions == [0] * n_a + [1] * (10 - n_a)

    # segmented reference on the scalar serve path
    st = engine.init_serve(cfg, 1, 48, jnp.float32)
    st = engine.prefill(params, cfg, {"tokens": jnp.asarray(prompt[None])}, st)
    ref = [int(st.last_tokens[0, 0])]
    for _ in range(9):
        p = params if len(ref) < n_a else p_b
        st, t = engine.serve_step(p, cfg, st)
        ref.append(int(t[0, 0]))
    assert ref == req.tokens


def test_zero_loss_across_three_swaps(setup):
    """Traffic continues across >= 3 swaps: every submitted request completes
    with exactly max_new tokens and the per-token version trace is monotone."""
    cfg, params = setup
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_len=32)
    rids = [eng.submit(p, 8) for p in _prompts(cfg, 6, 8, seed=7)]
    swaps = 0
    while eng.n_active or eng.n_queued:
        eng.step()
        if swaps < 3 and eng.decode_steps % 3 == 0 and eng.decode_steps > 0:
            eng.swap_params(jax.tree.map(lambda a: a * 0.99, eng.params))
            swaps += 1
    assert swaps == 3 and eng.swaps == 3
    spanning = 0
    for rid in rids:
        req = eng.result(rid)
        assert len(req.tokens) == 8, "request dropped tokens across a swap"
        assert req.versions == sorted(req.versions), "non-monotone versions"
        spanning += len(set(req.versions)) > 1
    assert spanning >= 1


def test_engine_validates_pool_and_monotone_versions(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="bad pool"):
        ContinuousBatchingEngine(cfg, params, slots=0)
    eng = ContinuousBatchingEngine(cfg, params, slots=1, max_len=16)
    with pytest.raises(ValueError, match="exceeds max_len"):
        eng.submit(np.arange(10), 8)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(np.zeros((0,)), 4)
    eng.swap_params(params, version=3)
    with pytest.raises(ValueError, match="non-monotone"):
        eng.swap_params(params, version=3)


def test_encdec_family_rejected():
    cfg = reduced(get_config("seamless-m4t-medium"))
    with pytest.raises(NotImplementedError, match="decoder-only"):
        ContinuousBatchingEngine(cfg, params=None)


# ---------------------------------------------------------------------------
# SnapshotPublisher
# ---------------------------------------------------------------------------

def test_publisher_versions_monotone_and_double_buffered():
    pub = SnapshotPublisher(overhead_budget=0.0)  # ungoverned
    assert pub.snapshot() is None and pub.version == 0
    tree = {"w": jnp.arange(4.0)}
    s1 = pub.publish(tree, 1)
    s2 = pub.publish(jax.tree.map(lambda a: a + 1, tree), 2)
    s3 = pub.publish(jax.tree.map(lambda a: a + 2, tree), 3)
    assert (s1.version, s2.version, s3.version) == (1, 2, 3)
    assert pub.snapshot() is s3
    assert pub._back is s2  # predecessor stays live (double buffer)
    # published leaves are fresh buffers, not aliases of the source tree
    np.testing.assert_array_equal(np.asarray(s3.params["w"]),
                                  np.arange(4.0) + 2)
    assert s3.params["w"] is not tree["w"]


def test_publisher_extract_and_staleness():
    # extract: consensus mean over a leading node axis, weighted by a mask
    def extract(tree, mask):
        w = mask / jnp.sum(mask)
        return jax.tree.map(lambda p: jnp.tensordot(w, p, axes=1), tree)

    pub = SnapshotPublisher(overhead_budget=0.0, extract=extract, block=True)
    tree = {"w": jnp.asarray([[1.0, 1.0], [3.0, 3.0], [100.0, 100.0]])}
    mask = jnp.asarray([1.0, 1.0, 0.0])  # node 2 inactive
    snap = pub.publish(tree, superstep=4, aux=mask)
    np.testing.assert_allclose(np.asarray(snap.params["w"]), [2.0, 2.0])
    st = pub.staleness(7)
    assert st["supersteps"] == 3 and st["wall_s"] >= 0.0
    assert pub.staleness(4)["supersteps"] == 0


def test_publisher_budget_governor_skips_and_recovers():
    t = [0.0]
    pub = SnapshotPublisher(overhead_budget=0.5, clock=lambda: t[0])
    tree = {"w": jnp.ones(2)}

    def publish_at(now, step):
        t[0] = now
        return pub.maybe_publish(tree, step)

    assert publish_at(0.0, 0) is not None  # first publish unconditional
    cost = pub.stats.cost_ewma_s  # 0 under the fake clock
    pub.stats.cost_ewma_s = 1.0  # pretend publishes cost 1s
    assert publish_at(1.0, 1) is None  # 1.0 > 0.5 * 1.0 elapsed: skip
    assert pub.stats.skipped_budget == 1
    assert publish_at(3.0, 2) is not None  # 1.0 <= 0.5 * 3.0: allowed
    assert pub.version == 2
    del cost


def test_publisher_min_interval_and_reset_stats():
    t = [0.0]
    pub = SnapshotPublisher(overhead_budget=0.0, min_interval_s=10.0,
                            clock=lambda: t[0])
    tree = {"w": jnp.ones(2)}
    assert pub.maybe_publish(tree, 0) is not None
    t[0] = 5.0
    assert pub.maybe_publish(tree, 1) is None  # inside min interval
    assert pub.stats.skipped_interval == 1
    t[0] = 11.0
    assert pub.maybe_publish(tree, 2) is not None
    pub.stats.cost_ewma_s = 0.25
    pub.reset_stats()
    assert pub.stats.publishes == 0 and pub.stats.cost_ewma_s == 0.25
    pub.reset_stats(keep_ewma=False)
    assert pub.stats.cost_ewma_s is None


def test_publisher_configure_is_idempotent():
    first = lambda tree: tree
    second = lambda tree: None
    pub = SnapshotPublisher()
    pub.configure(extract=first)
    pub.configure(extract=second)  # ignored: an extract is already installed
    assert pub._extract is first


def test_engine_poll_adopts_only_newer_versions(setup):
    cfg, params = setup
    pub = SnapshotPublisher(overhead_budget=0.0)
    eng = ContinuousBatchingEngine(cfg, params, slots=1, max_len=16)
    assert not eng.poll(pub)  # nothing published yet
    pub.publish(params, 1)
    assert eng.poll(pub) and eng.version == 1
    assert not eng.poll(pub)  # same version: no swap
    assert eng.swaps == 1
