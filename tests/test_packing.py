"""Property tests for `core.packing` (flat-buffer pack/unpack) and the packed
consensus paths: round-tripping arbitrary mixed-dtype pytrees, packed gossip /
hierarchical parity with the per-leaf path in exact, roll, matmul, kernel, and
quantized modes, the packed consensus-error reduction vs the per-leaf oracle,
and the pytree-parameter DMB driver."""
import numpy as np
import pytest
from _prop import given, settings, st

import jax
import jax.numpy as jnp

from repro.configs.base import AveragingConfig
from repro.core import averaging, dmb, mixing, packing

DTYPES = ("float32", "bfloat16", "float16", "int32")


def _rand_tree(seed, n_leaves, n, dtypes=DTYPES, lead=1):
    """Random nested pytree; every leaf shares the leading [n] axis (lead=1)
    or none (lead=0), with mixed trailing ranks and dtypes."""
    rng = np.random.default_rng(seed)
    tree = {"sub": {}, "flat": []}
    for i in range(n_leaves):
        rank = int(rng.integers(0, 3))
        shape = ((n,) if lead else ()) + tuple(
            int(rng.integers(1, 5)) for _ in range(rank))
        dt = dtypes[int(rng.integers(len(dtypes)))]
        if dt == "int32":
            leaf = jnp.asarray(rng.integers(-99, 99, size=shape), jnp.int32)
        else:
            leaf = jnp.asarray(rng.normal(size=shape).astype(np.float32), dt)
        if i % 3 == 0:
            tree["sub"][f"l{i}"] = leaf
        else:
            tree["flat"].append(leaf)
    return tree


# ---------------------------------------------------------------------------
# Round-tripping
# ---------------------------------------------------------------------------

@given(st.integers(1, 9), st.integers(1, 8), st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_pack_roundtrip_mixed_dtypes(n_leaves, n, seed):
    tree = _rand_tree(seed, n_leaves, n)
    bufs, spec = packing.pack_tree(tree)
    # dtype-preserving: one buffer per distinct dtype, every buffer [n, D_g]
    assert len(bufs) == len({jnp.dtype(d).name for d in spec.dtypes})
    for g, buf in enumerate(bufs):
        assert buf.shape == (n, spec.group_width(g))
        assert jnp.dtype(buf.dtype).name == spec.dtypes[spec.groups[g][0]]
        assert len(spec.segment_ids(g)) == spec.group_width(g)
    back = packing.unpack_tree(bufs, spec)
    assert jax.tree.structure(back) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(st.integers(1, 7), st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_pack_roundtrip_lead0(n_leaves, seed):
    """lead=0 (the DMB parameter-vector form): whole leaves flatten."""
    tree = _rand_tree(seed, n_leaves, 1, lead=0)
    bufs, spec = packing.pack_tree(tree, lead=0)
    for buf in bufs:
        assert buf.ndim == 1
    back = packing.unpack_tree(bufs, spec)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pack_spec_reuse_across_lead_sizes():
    """A spec built from params [N, ...] must repack grads of another node
    count (emulated N) — the segment map is leading-axis independent."""
    t4 = {"a": jnp.ones((4, 3)), "b": jnp.zeros((4, 2, 2))}
    t9 = {"a": jnp.ones((9, 3)), "b": jnp.zeros((9, 2, 2))}
    _, spec = packing.pack_tree(t4)
    bufs, _ = packing.pack_tree(t9, spec)
    assert bufs[0].shape == (9, 7)
    back = packing.unpack_tree(bufs, spec)
    assert back["b"].shape == (9, 2, 2)


def test_pack_rejects_mismatched_leading_axes():
    with pytest.raises(ValueError):
        packing.pack_tree({"a": jnp.ones((4, 3)), "b": jnp.ones((5, 3))})
    _, spec = packing.pack_tree({"a": jnp.ones((4, 3))})
    with pytest.raises(ValueError):
        packing.pack_tree({"a": jnp.ones((4, 7))}, spec)


# ---------------------------------------------------------------------------
# Packed averaging parity vs the per-leaf path
# ---------------------------------------------------------------------------

def _float_tree(seed, n_leaves, n):
    return _rand_tree(seed, n_leaves, n, dtypes=("float32",))


def _assert_tree_close(got, want, **kw):
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **kw)


@pytest.mark.parametrize("impl", ["roll", "matmul", "kernel"])
def test_packed_gossip_matches_per_leaf(impl):
    n, rounds = 8, 5
    tree = _float_tree(1, 7, n)
    cfg = AveragingConfig(mode="gossip", rounds=rounds, topology="circulant2")
    mix = mixing.circulant_mix_op(mixing.schedule("circulant2", n), n, rounds,
                                  impl=impl)
    got = averaging.gossip_average(tree, n, cfg, mix)
    want = averaging.gossip_average(
        tree, n, AveragingConfig(mode="gossip", rounds=rounds,
                                 topology="circulant2", packed=False), mix)
    _assert_tree_close(got, want, rtol=2e-5, atol=2e-6)


def test_packed_gossip_unfused_exact_loop():
    """fuse=False (the per-round oracle loop) through the packed path."""
    n, rounds = 6, 4
    tree = _float_tree(2, 5, n)
    sched = mixing.schedule("ring", n)
    mix = mixing.circulant_mix_op(sched, n, rounds, fuse=False)
    cfg = AveragingConfig(mode="gossip", rounds=rounds)
    got = averaging.gossip_average(tree, n, cfg, mix)
    A_R = np.linalg.matrix_power(mixing.schedule_matrix(sched, n), rounds)
    ref = jax.tree.map(
        lambda g: (A_R @ np.asarray(g).reshape(n, -1)).reshape(g.shape), tree)
    _assert_tree_close(got, ref, rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("quant", ["sign", "int8", "int8_stoch"])
def test_packed_quantized_global_stats_is_per_leaf(quant):
    """stats="global" pins the per-leaf oracle: packed on or off must be
    BIT-identical (the packed path is required to fall back)."""
    n = 8
    tree = _float_tree(3, 6, n)
    on = AveragingConfig(mode="gossip", rounds=4, quantization=quant)
    off = AveragingConfig(mode="gossip", rounds=4, quantization=quant,
                          packed=False)
    got = averaging.gossip_average(tree, n, on)
    want = averaging.gossip_average(tree, n, off)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("quant", ["sign", "int8"])
def test_packed_quantized_segment_stats_matches_per_leaf(quant):
    """Segment statistics on the packed buffer reproduce the per-leaf path's
    scales, so one packed pass == N-leaf global-stats loop (fp tolerance)."""
    n = 8
    tree = _float_tree(4, 7, n)
    seg = AveragingConfig(mode="gossip", rounds=4, quantization=quant,
                          quant_stats="segment")
    oracle = AveragingConfig(mode="gossip", rounds=4, quantization=quant,
                             packed=False)
    got = averaging.gossip_average(tree, n, seg)
    want = averaging.gossip_average(tree, n, oracle)
    _assert_tree_close(got, want, rtol=1e-5, atol=1e-5)


def test_packed_quantized_tile_stats_matches_tile_reference():
    """stats="tile" routes the packed buffer through the fused quantized path;
    oracle: the XLA tile chain on the manually packed buffer."""
    from repro.kernels import ref

    n = 8
    tree = _float_tree(5, 6, n)
    cfg = AveragingConfig(mode="gossip", rounds=3, quantization="int8",
                          quant_stats="tile", quant_block_d=16)
    got = averaging.gossip_average(tree, n, cfg)
    bufs, spec = packing.pack_tree(tree)
    sched = mixing.schedule("ring", n)
    want = packing.unpack_tree(
        (ref.gossip_mix_quant_ref(bufs[0], sched, 3, "int8", block_d=16),),
        spec)
    _assert_tree_close(got, want, rtol=1e-5, atol=1e-5)


def test_packed_hierarchical_matches_per_leaf():
    pods, per_pod = 4, 2
    n = pods * per_pod
    tree = _float_tree(6, 6, n)
    kw = dict(mode="hierarchical", rounds=3)
    got = averaging.hierarchical_average(
        tree, pods, per_pod, AveragingConfig(**kw))
    want = averaging.hierarchical_average(
        tree, pods, per_pod, AveragingConfig(packed=False, **kw))
    _assert_tree_close(got, want, rtol=2e-5, atol=2e-6)


def test_hierarchical_quantized_global_ignores_packed_flag():
    """Quantized global stats pin per-leaf oracle semantics: the packed flag
    must be a no-op (bit-identical)."""
    pods, per_pod = 4, 2
    tree = _float_tree(8, 5, pods * per_pod)
    kw = dict(mode="hierarchical", rounds=3, quantization="sign")
    got = averaging.hierarchical_average(
        tree, pods, per_pod, AveragingConfig(**kw))
    want = averaging.hierarchical_average(
        tree, pods, per_pod, AveragingConfig(packed=False, **kw))
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_hierarchical_quantized_packed_buffer_oracle():
    """Segment-stats quantized hierarchical packs the tree and mixes the one
    buffer (segment scales degrade to masked-global over the scattered
    layout); oracle: `_hmix_buffer` on the manually packed buffer."""
    pods, per_pod = 4, 2
    tree = _float_tree(6, 6, pods * per_pod)
    kw = dict(mode="hierarchical", rounds=3, quantization="sign",
              quant_stats="segment")
    got = averaging.hierarchical_average(
        tree, pods, per_pod, AveragingConfig(**kw))
    bufs, spec = packing.pack_tree(tree)
    mix = averaging.make_gossip_mix(AveragingConfig(**kw), pods)
    oracle = packing.unpack_tree(
        (averaging._hmix_buffer(bufs[0], pods, per_pod, mix),), spec)
    _assert_tree_close(got, oracle, rtol=1e-6, atol=1e-7)


def test_average_and_error_matches_separate_calls():
    n = 8
    tree = _float_tree(7, 6, n)
    cfg = AveragingConfig(mode="gossip", rounds=2)
    mixed, err = averaging.average_and_error(tree, cfg, n_nodes=n)
    want = averaging.gossip_average(tree, n, cfg)
    _assert_tree_close(mixed, want, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(float(err),
                               float(averaging.consensus_error_per_leaf(want)),
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# Packed consensus error vs per-leaf oracle
# ---------------------------------------------------------------------------

@given(st.integers(1, 8), st.integers(2, 9), st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_consensus_error_packed_matches_per_leaf_oracle(n_leaves, n, seed):
    tree = _rand_tree(seed, n_leaves, n, dtypes=("float32", "bfloat16"))
    got = float(averaging.consensus_error(tree))
    want = float(averaging.consensus_error_per_leaf(tree))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


def test_consensus_error_empty_tree():
    assert float(averaging.consensus_error({})) == 0.0


def test_segment_stats_no_cancellation_after_large_leaf():
    """Regression: a small leaf packed AFTER a transformer-scale leaf must
    keep exact segment statistics — a float32 running-sum formulation
    catastrophically cancels here (zero/negative sums for the tail segment)."""
    from repro.core.quantize import segment_scales

    rng = np.random.default_rng(13)
    big = jnp.asarray(100.0 * rng.normal(size=(2, 1 << 20)).astype(np.float32))
    small = jnp.asarray(1e-3 * rng.normal(size=(2, 8)).astype(np.float32))
    tree = {"a_big": big, "b_small": small}
    # packed consensus error: finite and matching the per-leaf oracle
    got = float(averaging.consensus_error(tree))
    want = float(averaging.consensus_error_per_leaf(tree))
    assert np.isfinite(got)
    np.testing.assert_allclose(got, want, rtol=1e-4)
    # sign-compressor segment scale of the tail leaf: exact per-leaf mean|x|
    bufs, spec = packing.pack_tree(tree)
    widths = tuple(spec.leaf_width(i) for i in spec.groups[0])
    scales = segment_scales(bufs[0], widths, "mean_abs")
    tail = float(scales[-1])
    np.testing.assert_allclose(tail, float(jnp.mean(jnp.abs(small))),
                               rtol=1e-5)
    assert tail > 0.0


# ---------------------------------------------------------------------------
# DMB with pytree parameters (packed once outside the scan)
# ---------------------------------------------------------------------------

def test_run_dmb_pytree_w_matches_flat():
    rng = np.random.default_rng(11)
    d = 4
    w_star = rng.normal(size=(d,)).astype(np.float32)

    def draw(key, m):
        x = jax.random.normal(key, (m, d))
        y = x @ jnp.asarray(w_star)
        return x, y

    def grad_flat(w, x, y):
        r = x @ w[:d] + w[d] - y
        return jnp.concatenate([x.T @ r, jnp.sum(r)[None]]) / x.shape[0]

    def grad_tree(w, x, y):
        r = x @ w["w"] + w["b"] - y
        return {"w": x.T @ r / x.shape[0], "b": jnp.mean(r) * jnp.ones(1)}

    kw = dict(N=4, B=8, steps=25, stepsize=lambda t: 0.3 / jnp.sqrt(t), seed=5)
    flat = dmb.run_dmb(grad_flat, draw, jnp.zeros(d + 1), **kw)
    tree = dmb.run_dmb(grad_tree, draw,
                       {"w": jnp.zeros(d), "b": jnp.zeros(1)}, **kw)
    np.testing.assert_allclose(np.asarray(tree.w["w"]),
                               np.asarray(flat.w[:d]), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(tree.w_av["w"]),
                               np.asarray(flat.w_av[:d]), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(tree.w["b"]),
                               np.asarray(flat.w[d:]), rtol=1e-5, atol=1e-6)


def test_run_dmb_pytree_project_and_metric_see_tree():
    seen = []

    def draw(key, m):
        return (jax.random.normal(key, (m, 2)),)

    def grad(w, x):
        return {"w": jnp.mean(x, 0) * 0 + w["w"]}

    def project(w):
        assert set(w) == {"w"}
        return jax.tree.map(lambda a: jnp.clip(a, -1, 1), w)

    def metric(w):
        seen.append(True)
        return jnp.sum(w["w"])

    res = dmb.run_dmb(grad, draw, {"w": jnp.ones(2)}, N=2, B=4, steps=3,
                      stepsize=lambda t: 0.1, project=project,
                      trace_metric=metric)
    assert set(res.w) == {"w"} and res.w["w"].shape == (2,)
    assert res.trace_metric.shape == (3,)
