"""Tier-1 guard against benchmark bit-rot: `benchmarks/run.py --quick` must
execute every suite at smoke scale and produce a parseable --json artifact."""
import json
import os
import subprocess
import sys

HERE = os.path.dirname(__file__)
ROOT = os.path.abspath(os.path.join(HERE, ".."))


def test_run_quick_all_suites(tmp_path):
    out = tmp_path / "bench_quick.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    p = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--quick", "--json", str(out)],
        capture_output=True, text=True, timeout=900, cwd=ROOT, env=env)
    assert p.returncode == 0, (p.stdout[-2000:], p.stderr[-3000:])

    artifact = json.loads(out.read_text())
    assert artifact["schema"] == "repro-bench-v1"
    assert artifact["quick"] is True
    assert artifact["failed"] == []
    names = [r["name"] for r in artifact["rows"]]
    # every suite contributed at least one row — including the packed,
    # quantized, and compressor-accuracy consensus sub-suites (PR 3), the
    # PCA engine sub-suites (PR 4), and the adaptive-B governor suite (PR 5)
    for prefix in ("fig5/", "fig6a/", "fig7a/", "fig9/", "consensus/",
                   "consensus/packed/", "consensus/quantized/",
                   "consensus/quant_accuracy/", "kernel/", "pipeline/",
                   "krasulina/fused/", "krasulina/gossip/",
                   "governor/cold_switch/", "governor/warm_switch/",
                   "elastic/throughput/", "scenarios/matrix/", "serve/",
                   "checkpoint/", "scenarios/lm/", "pipeline/prefetch_sweep/",
                   "lm_decentralized/"):
        assert any(n.startswith(prefix) for n in names), (prefix, names)
    # the engine rows carry machine-readable throughput
    pipe = [r for r in artifact["rows"] if r["name"].startswith("pipeline/")]
    assert all("rounds_per_s=" in r["derived"] for r in pipe)
    # the quantized rows carry the per-leaf-loop baseline and speedup
    q = [r for r in artifact["rows"]
         if r["name"].startswith("consensus/quantized/")]
    assert q and all("speedup=" in r["derived"] for r in q)
    acc = [r for r in artifact["rows"]
           if r["name"].startswith("consensus/quant_accuracy/")]
    assert acc and all("excess_risk=" in r["derived"] for r in acc)
    # the PCA engine rows: fused xi+gossip carries its baseline + speedup,
    # the gossip-vs-exact study carries the convergence metrics
    kf = [r for r in artifact["rows"] if r["name"].startswith("krasulina/fused/")]
    assert kf and all("speedup=" in r["derived"] for r in kf)
    kg = [r for r in artifact["rows"] if r["name"].startswith("krasulina/gossip/")]
    assert kg and all("excess_risk=" in r["derived"]
                      and "consensus_err=" in r["derived"] for r in kg)
    # governor contract rows are deterministic counts, asserted even in
    # quick mode: steady-state bucket switches must never retrace, and the
    # online (R_p, R_c) estimator row carries its recovery error
    ss = [r for r in artifact["rows"] if r["name"] == "governor/steady_state"]
    assert ss and "retraces=0;" in ss[0]["derived"]
    ge = [r for r in artifact["rows"] if r["name"] == "governor/estimator"]
    assert ge and "err_pct=" in ge[0]["derived"]
    # elastic-membership contract rows (PR 6), deterministic in quick mode
    # too: the rejoin superstep must reuse the full-cohort executable (zero
    # retraces), and consensus error under churn stays within 2x of the
    # lockstep baseline at a matched sample budget
    rj = [r for r in artifact["rows"] if r["name"] == "elastic/rejoin"]
    assert rj and "retraces=0;" in rj[0]["derived"]
    ce = [r for r in artifact["rows"] if r["name"] == "elastic/consensus"]
    assert ce and "ratio=" in ce[0]["derived"]
    assert float(ce[0]["derived"].split("ratio=")[1].split(";")[0]) <= 2.0
    # train-to-serve contract rows (PR 7): snapshot publication overhead on
    # the closed loop stays under the 5% budget, and continuous-batching
    # traffic crosses >= 3 mid-stream version swaps with zero dropped
    # in-flight requests

    def field(row, key):
        return float(row["derived"].split(f"{key}=")[1].split(";")[0])

    sp = [r for r in artifact["rows"] if r["name"] == "serve/publish"]
    assert sp and field(sp[0], "overhead_frac") <= 0.05
    sz = [r for r in artifact["rows"] if r["name"] == "serve/zero_loss"]
    assert sz and field(sz[0], "dropped") == 0
    assert field(sz[0], "swaps") >= 3
    assert field(sz[0], "submitted") == field(sz[0], "completed")
    st = [r for r in artifact["rows"] if r["name"] == "serve/staleness"]
    assert st and field(st[0], "max_supersteps") <= field(st[0],
                                                          "max_publish_gap")
    # fault-tolerance contract rows (PR 8): async snapshot dispatch stays
    # under 5% of loop wall with the writer thread owning all disk I/O, and
    # a driver resumed from the cut finishes bit-identical to the
    # uninterrupted run
    ck = [r for r in artifact["rows"] if r["name"] == "checkpoint/overhead"]
    assert ck and field(ck[0], "overhead_frac") <= 0.05
    assert field(ck[0], "failures") == 0
    cr = [r for r in artifact["rows"] if r["name"] == "checkpoint/resume"]
    assert cr and field(cr[0], "bit_identical") == 1
    # scenario-harness contract rows (PR 9), deterministic in quick mode:
    # the topology x link x stream matrix carries excess risk per cell,
    # mid-stream topology switches never retrace, the B-connected
    # time-varying schedule stays within 2x of the static ring at a matched
    # budget, the lossy cell converges bit-deterministically, and
    # rate-limited links push the estimator's R_c down / replanned mu up
    mx = [r for r in artifact["rows"]
          if r["name"].startswith("scenarios/matrix/")]
    assert len(mx) >= 27 and all("excess_risk=" in r["derived"] for r in mx)
    sr = [r for r in artifact["rows"] if r["name"] == "scenarios/retrace"]
    assert sr and field(sr[0], "retraces") == 0
    tv = [r for r in artifact["rows"] if r["name"] == "scenarios/tv_vs_static"]
    assert tv and field(tv[0], "ratio") <= 2.0
    lo = [r for r in artifact["rows"] if r["name"] == "scenarios/lossy"]
    assert lo and field(lo[0], "deterministic") == 1
    assert field(lo[0], "convergent") == 1
    gv = [r for r in artifact["rows"] if r["name"] == "scenarios/governor"]
    assert gv and field(gv[0], "direction") == 1
    assert field(gv[0], "est_Rc_limited") < field(gv[0], "est_Rc_clean")
    assert field(gv[0], "mu_limited") > field(gv[0], "mu_clean")
    # decentralized-LM contract rows (PR 10): the sharded gossip rule is
    # bit-identical to the per-round oracle even at smoke scale, the
    # error-feedback compressed runs keep their progress within 1.2x of the
    # uncompressed baseline, and the LM scenario cell (launcher --scenario
    # path) converges under the time-varying lossy schedule
    ep = [r for r in artifact["rows"]
          if r["name"] == "lm_decentralized/mix/exact_parity"]
    assert ep and field(ep[0], "bit_identical") == 1
    for q in ("sign", "int8"):
        row = [r for r in artifact["rows"]
               if r["name"] == f"lm_decentralized/train/ef_{q}"]
        assert row and field(row[0], "ef_excess_x") <= 1.2
        assert field(row[0], "ef_norm") >= 0.0
    lm = [r for r in artifact["rows"]
          if r["name"].startswith("scenarios/lm/")]
    assert lm and field(lm[0], "convergent") == 1
    # the prefetch-depth sweep records the sweet-spot finding as a row
    sw = [r for r in artifact["rows"]
          if r["name"] == "pipeline/prefetch_sweep/sweet_spot"]
    assert sw and "best_depth=" in sw[0]["derived"]


def test_committed_lm_decentralized_artifact():
    """The committed BENCH_lm_decentralized.json carries the full-mode
    contract rows: shard_map gossip >= 1.5x the composed-roll fallback on the
    4-way sharded node axis, exact parity bitwise, EF progress within 1.2x."""
    artifact = json.loads(
        open(os.path.join(ROOT, "BENCH_lm_decentralized.json")).read())
    assert artifact["schema"] == "repro-bench-v1"
    assert artifact["quick"] is False
    assert artifact["failed"] == []
    rows = {r["name"]: r["derived"] for r in artifact["rows"]}

    def field(derived, key):
        return float(derived.split(f"{key}=")[1].split(";")[0].rstrip("x"))

    assert field(rows["lm_decentralized/mix/exact_parity"],
                 "bit_identical") == 1
    assert field(rows["lm_decentralized/mix/shard_vs_roll"], "speedup") >= 1.5
    assert field(rows["lm_decentralized/train/gossip_shard"],
                 "tokens_per_s") > 0
    for q in ("sign", "int8"):
        assert field(rows[f"lm_decentralized/train/ef_{q}"],
                     "ef_excess_x") <= 1.2
