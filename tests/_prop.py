"""Property-test shim: uses `hypothesis` when installed; otherwise falls back
to a deterministic fixed-seed sweep expressed as pytest parametrization, so the
suite collects and runs (with reduced case counts) in minimal environments.

Usage in test modules:  ``from _prop import given, settings, st``
"""
from __future__ import annotations

import inspect

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    _FALLBACK_EXAMPLES = 8  # per-test cap for the seed sweep


    class _Strategy:
        def __init__(self, sample):
            self.sample = sample


    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(len(elements)))])

        @staticmethod
        def lists(elem, min_size=0, max_size=10, **_kw):
            def sample(rng):
                k = int(rng.integers(min_size, max_size + 1))
                return [elem.sample(rng) for _ in range(k)]
            return _Strategy(sample)


    st = _Strategies()


    def settings(max_examples=_FALLBACK_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._prop_max_examples = max_examples
            return fn
        return deco


    def given(*strats):
        def deco(fn):
            n = min(getattr(fn, "_prop_max_examples", _FALLBACK_EXAMPLES),
                    _FALLBACK_EXAMPLES)
            rng = np.random.default_rng(0)
            # hypothesis binds positional strategies to the RIGHTMOST test
            # parameters (fixtures etc. stay on the left) — match that
            names = list(inspect.signature(fn).parameters)[-len(strats):]
            cases = [[s.sample(rng) for s in strats] for _ in range(n)]
            if len(strats) == 1:
                return pytest.mark.parametrize(
                    names[0], [c[0] for c in cases])(fn)
            return pytest.mark.parametrize(
                ",".join(names), [tuple(c) for c in cases])(fn)
        return deco
