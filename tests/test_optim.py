"""Optimizer unit tests, including the paper's accelerated updates (eqs. 9-11)
and Polyak-Ruppert averaging (eq. 7)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.optim.optimizers import (accel_point, init_optimizer, make_optimizer,
                                    polyak_init, polyak_update)


def quad_grad(params):
    return jax.tree.map(lambda p: 2.0 * p.astype(jnp.float32), params)


@pytest.mark.parametrize("name,lr", [("sgd", 0.1), ("adam", 0.2), ("accel", 0.05)])
def test_optimizers_minimize_quadratic(name, lr):
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array([1.0])}
    state = init_optimizer(name, params)
    update = make_optimizer(name, lr)
    for _ in range(200):
        at = accel_point(state, params) if name == "accel" else params
        grads = quad_grad(at)
        params, state = update(grads, state, params)
    norm = sum(float(jnp.sum(p**2)) for p in jax.tree.leaves(params))
    assert norm < 1e-2, f"{name}: {norm}"


def test_sgd_momentum_state():
    params = {"w": jnp.ones(3)}
    state = init_optimizer("sgd", params)
    update = make_optimizer("sgd", 0.1, momentum=0.9)
    p1, s1 = update({"w": jnp.ones(3)}, state, params)
    p2, s2 = update({"w": jnp.ones(3)}, s1, p1)
    # second step moves further (momentum accumulates)
    d1 = float(jnp.linalg.norm(params["w"] - p1["w"]))
    d2 = float(jnp.linalg.norm(p1["w"] - p2["w"]))
    assert d2 > d1


def test_adam_bias_correction_first_step():
    params = {"w": jnp.zeros(4)}
    update = make_optimizer("adam", 1e-1, b1=0.9, b2=0.999, eps=1e-12)
    g = {"w": jnp.full(4, 0.5)}
    p1, _ = update(g, init_optimizer("adam", params), params)
    # with bias correction the first step is ~ -lr * sign(g)
    np.testing.assert_allclose(np.asarray(p1["w"]), -0.1 * np.ones(4), rtol=1e-3)


def test_weight_decay():
    params = {"w": jnp.ones(2)}
    update = make_optimizer("sgd", 0.1, weight_decay=0.5)
    p1, _ = update({"w": jnp.zeros(2)}, init_optimizer("sgd", params), params)
    np.testing.assert_allclose(np.asarray(p1["w"]), 0.95 * np.ones(2), rtol=1e-6)


def test_bf16_params_fp32_master_updates():
    """tiny updates must not vanish in bf16 (fp32 master weights)."""
    params = {"w": jnp.ones(2, jnp.bfloat16)}
    update = make_optimizer("adam", 1e-4)
    state = init_optimizer("adam", params, master_weights=True)
    p, s = params, state
    for _ in range(10):
        p, s = update({"w": jnp.full(2, 1e-3, jnp.bfloat16)}, s, p)
    assert p["w"].dtype == jnp.bfloat16
    # the fp32 master moved even though bf16 storage may round
    assert float(s.master["w"][0]) != 1.0
    # without master weights the same updates vanish entirely
    p2, s2 = {"w": jnp.ones(2, jnp.bfloat16)}, init_optimizer("adam", params)
    for _ in range(10):
        p2, s2 = update({"w": jnp.full(2, 1e-3, jnp.bfloat16)}, s2, p2)
    assert float(p2["w"][0]) == 1.0


@given(st.lists(st.floats(0.01, 2.0), min_size=2, max_size=10))
@settings(max_examples=25, deadline=None)
def test_polyak_is_stepsize_weighted_average(etas):
    """eq. (7): w_av = sum(eta_t w_t) / sum(eta_t)."""
    ws = [jnp.array([float(i), -float(i)]) for i in range(len(etas))]
    state = polyak_init({"w": ws[0]})
    for eta, w in zip(etas, ws):
        state = polyak_update(state, {"w": w}, jnp.asarray(eta))
    want = sum(e * np.asarray(w) for e, w in zip(etas, ws)) / sum(etas)
    np.testing.assert_allclose(np.asarray(state.avg["w"]), want, rtol=1e-5)
