"""Tests for the fused consensus engine (core.mixing.MixOp + the Pallas gossip
kernel): the precomputed R-round operator must match the per-round oracle
(`schedule_matrix` + `np.linalg.matrix_power`), the kernel must match the
per-round `roll_mix` loop, and quantized configs must keep exact per-round
semantics (no operator collapsing)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AveragingConfig
from repro.core import averaging, dsgd, mixing, quantize
from repro.core.quantize import COMPRESSORS
from repro.kernels import ref
from repro.kernels.consensus import gossip_mix_pallas, gossip_mix_quant_pallas
from repro.kernels.ops import gossip_mix


def _x(n, d=24, seed=0, dtype=np.float32):
    return jnp.asarray(np.random.default_rng(seed).normal(size=(n, d)).astype(dtype))


# ---------------------------------------------------------------------------
# Dense engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,rounds", [(8, 1), (16, 4), (16, 8), (24, 13)])
def test_dense_mix_op_matches_matrix_power(n, rounds):
    A = mixing.random_regular_expander(n, deg=4, seed=1)
    h = _x(n)
    want = np.linalg.matrix_power(A, rounds) @ np.asarray(h)
    mix = mixing.dense_mix_op(jnp.asarray(A, jnp.float32), rounds)
    np.testing.assert_allclose(np.asarray(mix(h)), want, rtol=1e-5, atol=1e-5)
    # the unfused fallback is the original per-round scan
    unfused = mixing.dense_mix_op(jnp.asarray(A, jnp.float32), rounds, fuse=False)
    assert unfused.A_eff is None
    np.testing.assert_allclose(np.asarray(unfused(h)), want, rtol=1e-5, atol=1e-5)


def test_dense_mix_op_zero_rounds_is_identity():
    h = _x(6)
    mix = mixing.dense_mix_op(jnp.eye(6), 0)
    assert mix(h) is h


def test_consensus_oracle_agrees_with_mix_op():
    n, rounds = 16, 8
    A = jnp.asarray(mixing.random_regular_expander(n, deg=6, seed=0), jnp.float32)
    h = _x(n)
    np.testing.assert_allclose(np.asarray(dsgd.consensus(h, A, rounds)),
                               np.asarray(mixing.dense_mix_op(A, rounds)(h)),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Circulant engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo", ["ring", "circulant2", "torus"])
@pytest.mark.parametrize("n,rounds", [(8, 1), (12, 3), (16, 8), (17, 5)])
def test_compose_schedule_matches_matrix_power(topo, n, rounds):
    sched = mixing.schedule(topo, n)
    fused = mixing.compose_schedule(sched, rounds, n)
    got = mixing.schedule_matrix(fused, n)
    want = np.linalg.matrix_power(mixing.schedule_matrix(sched, n), rounds)
    np.testing.assert_allclose(got, want, atol=1e-12)
    # composition preserves double stochasticity and never exceeds n terms
    assert mixing.is_doubly_stochastic(got)
    assert len(fused) <= n


@pytest.mark.parametrize("impl", ["roll", "matmul", "kernel"])
@pytest.mark.parametrize("topo,rounds", [("ring", 8), ("circulant2", 3),
                                         ("torus", 5)])
def test_circulant_mix_op_matches_oracle(impl, topo, rounds):
    n = 16
    sched = mixing.schedule(topo, n)
    h = _x(n)
    want = np.linalg.matrix_power(mixing.schedule_matrix(sched, n), rounds) @ \
        np.asarray(h)
    op = mixing.circulant_mix_op(sched, n, rounds, impl=impl)
    np.testing.assert_allclose(np.asarray(op(h)), want, rtol=1e-5, atol=1e-5)
    # the unfused escape hatch is the original per-round loop
    loop_op = mixing.circulant_mix_op(sched, n, rounds, fuse=False)
    assert loop_op.fused_sched is None
    np.testing.assert_allclose(np.asarray(loop_op(h)), want,
                               rtol=1e-5, atol=1e-5)


def test_circulant_mix_op_high_rank_leaves():
    """Trainer-style leaves [n, a, b] flatten correctly under every impl."""
    n, rounds = 8, 4
    sched = mixing.schedule("ring", n)
    x = jnp.asarray(np.random.default_rng(3).normal(size=(n, 3, 5)).astype(np.float32))
    outs = [np.asarray(mixing.circulant_mix_op(sched, n, rounds, impl=impl)(x))
            for impl in ("roll", "matmul", "kernel")]
    A_R = np.linalg.matrix_power(mixing.schedule_matrix(sched, n), rounds)
    want = (A_R @ np.asarray(x).reshape(n, -1)).reshape(n, 3, 5)
    for got in outs:
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Pallas kernel (interpret mode on CPU)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d", [(8, 64), (16, 512), (16, 700), (5, 33)])
@pytest.mark.parametrize("topo,rounds", [("ring", 1), ("ring", 8),
                                         ("circulant2", 4)])
def test_gossip_kernel_matches_roll_mix(n, d, topo, rounds):
    sched = mixing.schedule(topo, n)
    x = _x(n, d, seed=4)
    got = gossip_mix(x, sched, rounds, force_pallas=True)
    want = x
    for _ in range(rounds):
        want = mixing.roll_mix(want, sched, lambda m: m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_gossip_kernel_bf16():
    n, d = 16, 256
    sched = mixing.schedule("ring", n)
    x = _x(n, d, seed=5, dtype=np.float32).astype(jnp.bfloat16)
    got = gossip_mix_pallas(x, tuple(s for s, _ in sched),
                            tuple(w for _, w in sched), 4, interpret=True)
    assert got.dtype == jnp.bfloat16
    want = np.asarray(x, np.float32)
    A4 = np.linalg.matrix_power(mixing.schedule_matrix(sched, n), 4)
    np.testing.assert_allclose(np.asarray(got, np.float32), A4 @ want,
                               rtol=5e-2, atol=5e-2)


def test_gossip_kernel_small_block_tiling():
    """Grid tiling over d must be seam-free."""
    n, d = 8, 130
    sched = mixing.schedule("ring", n)
    x = _x(n, d, seed=6)
    got = gossip_mix_pallas(x, tuple(s for s, _ in sched),
                            tuple(w for _, w in sched), 3,
                            block_d=32, interpret=True)
    want = x
    for _ in range(3):
        want = mixing.roll_mix(want, sched, lambda m: m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Quantize-fused kernel (interpret mode on CPU) vs the XLA tile oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quant", ["sign", "int8"])
@pytest.mark.parametrize("n,d,block_d", [(8, 64, 64), (8, 130, 32), (5, 33, 16)])
def test_quant_gossip_kernel_matches_tile_reference(quant, n, d, block_d):
    """The fused quantized kernel's in-register per-tile statistics must match
    `tile_compress` chained per round, including the masked ragged tail."""
    sched = mixing.schedule("ring", n)
    x = _x(n, d, seed=20)
    got = gossip_mix_quant_pallas(x, tuple(s for s, _ in sched),
                                  tuple(w for _, w in sched), 3, quant,
                                  block_d=block_d, interpret=True)
    want = ref.gossip_mix_quant_ref(x, sched, 3, quant, block_d=block_d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_quant_gossip_kernel_valid_d_masks_pad_columns():
    """Zero pad columns past valid_d must not perturb any tile statistic:
    kernel output on the padded buffer == reference on the unpadded one."""
    n, d, pad = 8, 40, 9
    sched = mixing.schedule("circulant2", n)
    x = _x(n, d, seed=21)
    xp = jnp.pad(x, ((0, 0), (0, pad)))
    got = gossip_mix_quant_pallas(xp, tuple(s for s, _ in sched),
                                  tuple(w for _, w in sched), 2, "sign",
                                  block_d=16, valid_d=d, interpret=True)
    want = ref.gossip_mix_quant_ref(xp, sched, 2, "sign", block_d=16,
                                    valid_d=d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # the unmasked kernel would fold the zeros into the mean-|x| scale
    unmasked = gossip_mix_quant_pallas(xp, tuple(s for s, _ in sched),
                                       tuple(w for _, w in sched), 2, "sign",
                                       block_d=16, interpret=True)
    assert not np.allclose(np.asarray(got)[:, :d], np.asarray(unmasked)[:, :d],
                           atol=1e-6)


def test_quant_kernel_rejects_stochastic():
    with pytest.raises(ValueError):
        gossip_mix_quant_pallas(_x(4, 8), (0, 1), (0.5, 0.5), 1, "int8_stoch")


# ---------------------------------------------------------------------------
# Stochastic int8 compressor (threefry-keyed)
# ---------------------------------------------------------------------------

def test_int8_stoch_rounds_to_adjacent_levels():
    x = _x(1, 400, seed=22)[0]
    key = jax.random.PRNGKey(3)
    out = quantize.int8_stoch_compress(x, key=key)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = np.asarray(out / scale)
    # every dequantized value is an integer level adjacent to x/scale
    np.testing.assert_allclose(q, np.round(q), atol=1e-4)
    assert np.all(np.abs(q - np.asarray(x / scale)) <= 1.0 + 1e-4)
    # keyed: deterministic under the same key, different under another
    out2 = quantize.int8_stoch_compress(x, key=key)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))
    out3 = quantize.int8_stoch_compress(x, key=jax.random.PRNGKey(4))
    assert not np.array_equal(np.asarray(out), np.asarray(out3))


def test_int8_stoch_is_unbiased():
    """E[dequant] = x: averaging over many keys shrinks the rounding error."""
    x = _x(1, 64, seed=23)[0]
    outs = np.stack([np.asarray(quantize.int8_stoch_compress(
        x, key=jax.random.PRNGKey(k))) for k in range(200)])
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    bias = np.abs(outs.mean(0) - np.asarray(x))
    assert np.max(bias) < 0.25 * scale  # ~4 sigma of the mean of 200 draws


def test_int8_stoch_selectable_via_config_and_still_averages():
    n = 8
    v = _x(n, 16, seed=24)
    cfg = AveragingConfig(mode="gossip", rounds=40, topology="ring",
                          quantization="int8_stoch")
    out = averaging.gossip_average({"g": v}, n, cfg)["g"]
    bar = jnp.mean(v, axis=0)
    rel = jnp.linalg.norm(out - bar[None]) / jnp.linalg.norm(bar)
    # stochastic rounding injects unbiased per-round noise, so the residual
    # floor is higher than the deterministic compressor's
    assert rel < 0.05


# ---------------------------------------------------------------------------
# Hierarchical padding: pad columns masked out of compressor statistics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quant", ["sign", "int8"])
@pytest.mark.parametrize("per_pod,feat", [(3, 7), (4, 5)])
def test_hierarchical_quantized_padded_matches_unpadded_broadcast(
        quant, per_pod, feat):
    """Regression (pad-perturbation fix): the zero-padded reduce-scatter form
    must equal the unpadded broadcast-then-gossip oracle for quantized
    configs — the pad columns may not leak into the compressor statistics."""
    pods = 4
    n = pods * per_pod
    v = _x(n, feat, seed=25)
    cfg = AveragingConfig(mode="hierarchical", rounds=3, topology="ring",
                          quantization=quant)
    got = np.asarray(averaging.hierarchical_average({"g": v}, pods, per_pod,
                                                    cfg)["g"])
    # oracle: unpadded broadcast form — full pod means gossiped with
    # global-stats compression over [pods, feat]
    pm = jnp.mean(v.reshape(pods, per_pod, feat), axis=1)
    compress = COMPRESSORS[quant]
    sched = mixing.schedule("ring", pods)
    x = pm
    for _ in range(cfg.rounds):
        out = None
        for s, w in sched:
            m = x if s == 0 else compress(jnp.roll(x, s, axis=0))
            term = w * m
            out = term if out is None else out + term
        x = out
    want = np.repeat(np.asarray(x), per_pod, axis=0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Quantized configs: per-round semantics, bit-identical to pre-refactor code
# ---------------------------------------------------------------------------

def _legacy_gossip_average(tree, n_nodes, cfg):
    """The pre-refactor implementation, verbatim (per-round roll loop with the
    compressor applied to every non-self message, every round)."""
    sched = mixing.schedule(cfg.topology, n_nodes, cfg.self_weight)
    compress = COMPRESSORS[cfg.quantization]

    def _roll_mix(x):
        out = None
        for shift, w in sched:
            msg = x if shift == 0 else compress(jnp.roll(x, shift, axis=0))
            term = w * msg
            out = term if out is None else out + term
        return out

    def mix(g):
        for _ in range(cfg.rounds):
            g = _roll_mix(g)
        return g

    return jax.tree.map(mix, tree)


@pytest.mark.parametrize("quant", ["sign", "int8"])
@pytest.mark.parametrize("topo", ["ring", "circulant2"])
def test_quantized_gossip_bit_identical_to_legacy(quant, topo):
    n = 8
    cfg = AveragingConfig(mode="gossip", rounds=5, topology=topo,
                          quantization=quant)
    tree = {"g": _x(n, 40, seed=7), "h": _x(n, 9, seed=8)}
    got = averaging.gossip_average(tree, n, cfg)
    want = _legacy_gossip_average(tree, n, cfg)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(want[k]))


def test_quantized_mix_op_keeps_per_round_operator():
    """No collapsing under quantization: the fused operator must be absent,
    and the result must differ from applying the (linear) collapsed operator."""
    n, rounds = 8, 5
    sched = mixing.schedule("ring", n)
    op = mixing.circulant_mix_op(sched, n, rounds, quantization="sign")
    assert op.fused_sched is None and op.A_eff is None
    x = _x(n, 16, seed=9)
    collapsed = mixing.circulant_mix_op(sched, n, rounds)(x)
    assert not np.allclose(np.asarray(op(x)), np.asarray(collapsed), atol=1e-4)


def test_unquantized_gossip_average_matches_legacy_loop():
    """Fused (default) unquantized gossip == per-round loop to float accuracy."""
    n = 12
    cfg = AveragingConfig(mode="gossip", rounds=8, topology="torus")
    tree = {"g": _x(n, 30, seed=10)}
    got = averaging.gossip_average(tree, n, cfg)["g"]
    want = _legacy_gossip_average(tree, n, cfg)["g"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-6)


def test_hierarchical_average_uses_engine():
    n, pods = 8, 2
    v = _x(n, 4, seed=11)
    cfg = AveragingConfig(mode="hierarchical", rounds=50, topology="ring")
    out = np.asarray(averaging.hierarchical_average({"g": v}, pods, n // pods,
                                                    cfg)["g"])
    np.testing.assert_allclose(out, np.tile(np.asarray(v).mean(0), (n, 1)),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# Driver integration: run_dsgd through the fused engine
# ---------------------------------------------------------------------------

def test_run_dsgd_fused_matches_unfused():
    from repro.core import problems
    rng = np.random.default_rng(12)
    n, d, B = 8, 5, 16
    A = jnp.asarray(mixing.random_regular_expander(n, deg=4, seed=2), jnp.float32)
    w_star = jnp.asarray(rng.normal(size=(d + 1,)).astype(np.float32))

    def draw(key, m):
        x = jax.random.normal(key, (m, d))
        y = jnp.sign(x @ w_star[:-1] + w_star[-1])
        return x, y

    grad = lambda w, x, y: problems.logistic_grad(w, x, y)
    kw = dict(B=B, rounds=6, steps=20, stepsize=lambda t: 0.5 / jnp.sqrt(t),
              seed=3)
    w0 = jnp.zeros(d + 1)
    fused = dsgd.run_dsgd(grad, draw, w0, A, **kw)
    unfused = dsgd.run_dsgd(grad, draw, w0, A,
                            mix=mixing.dense_mix_op(A, 6, fuse=False), **kw)
    np.testing.assert_allclose(np.asarray(fused.w), np.asarray(unfused.w),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Per-step PRNG key for stochastic compressors (ROADMAP caveat (4) from PR 3)
# ---------------------------------------------------------------------------

def _stoch_mix(stats: str = "global"):
    sched = mixing.schedule("ring", 4)
    return mixing.circulant_mix_op(sched, 4, rounds=3,
                                   quantization="int8_stoch", stats=stats,
                                   seed=11)


@pytest.mark.parametrize("stats", ["global", "segment", "tile"])
def test_mix_op_per_step_key_overrides_static_seed(stats):
    """key=None reproduces the seed-derived noise bit-identically (today's
    static behavior); distinct per-step keys draw fresh per-round noise; and
    passing the seed-derived key explicitly is the identity of the default."""
    mix = _stoch_mix(stats)
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 96))
    kw = {"seg_widths": (32, 64)} if stats == "segment" else {}
    default = np.asarray(mix(x, **kw))
    np.testing.assert_array_equal(default, np.asarray(mix(x, **kw)))
    np.testing.assert_array_equal(
        default, np.asarray(mix(x, key=jax.random.PRNGKey(mix.seed), **kw)))
    stepped = np.asarray(mix(x, key=jax.random.PRNGKey(123), **kw))
    assert not np.array_equal(default, stepped)
    # still a consensus operator: column sums (the network average) preserved
    # in expectation — sanity-check magnitudes stay comparable
    np.testing.assert_allclose(stepped.mean(), default.mean(), atol=0.05)


def test_mix_op_key_ignored_by_deterministic_compressors():
    sched = mixing.schedule("ring", 4)
    mix = mixing.circulant_mix_op(sched, 4, rounds=2, quantization="int8")
    x = jax.random.normal(jax.random.PRNGKey(6), (4, 32))
    np.testing.assert_array_equal(
        np.asarray(mix(x)), np.asarray(mix(x, key=jax.random.PRNGKey(42))))


def test_averaging_threads_per_step_key():
    """`average_gradients(..., key=)` reaches the compressor: two steps with
    different keys mix differently, key=None stays the static sequence (what
    a lax.scan over steps used to replay every step)."""
    cfg = AveragingConfig(mode="gossip", rounds=2, quantization="int8_stoch",
                          quant_stats="segment")
    tree = {"a": jax.random.normal(jax.random.PRNGKey(0), (4, 24)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (4, 8))}
    mix = averaging.make_gossip_mix(cfg, 4)
    k0, k1 = jax.random.PRNGKey(100), jax.random.PRNGKey(101)
    s0 = averaging.average_gradients(tree, cfg, n_nodes=4, mix=mix, key=k0)
    s0b = averaging.average_gradients(tree, cfg, n_nodes=4, mix=mix, key=k0)
    s1 = averaging.average_gradients(tree, cfg, n_nodes=4, mix=mix, key=k1)
    static = averaging.average_gradients(tree, cfg, n_nodes=4, mix=mix)
    np.testing.assert_array_equal(np.asarray(s0["a"]), np.asarray(s0b["a"]))
    assert not np.array_equal(np.asarray(s0["a"]), np.asarray(s1["a"]))
    np.testing.assert_array_equal(
        np.asarray(static["a"]),
        np.asarray(averaging.average_gradients(tree, cfg, n_nodes=4,
                                               mix=mix)["a"]))
