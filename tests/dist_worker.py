"""Executed by test_trainer_dist.py in a subprocess with 8 fake host devices:
trains a reduced arch with each averaging mode on a real (8, 1) mesh and prints
JSON metrics for the parent test to assert on."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import AveragingConfig, RunConfig, SHAPES, StreamConfig
from repro.core.averaging import consensus_error
from repro.data.lm import MarkovTokenStream
from repro.launch.mesh import make_host_mesh, n_data_nodes
from repro.launch.sharding import activation_rules
from repro.models.common import mesh_rules
from repro.train.trainer import (build_train_step, init_state, make_node_batch,
                                 replicate_for_nodes)


def train(mode: str, rounds: int, steps: int = 12, arch: str = "granite-8b"):
    cfg = reduced(get_config(arch))
    run = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                    averaging=AveragingConfig(mode=mode, rounds=rounds),
                    optimizer="adam", learning_rate=2e-3, param_dtype="float32")
    mesh = make_host_mesh()
    n_nodes = n_data_nodes(mesh)
    decentralized = mode != "exact"
    data = MarkovTokenStream(cfg.vocab_size, seed=0)
    rng = np.random.default_rng(0)

    with mesh_rules(mesh, activation_rules(mesh, run.shape, decentralized)):
        state = init_state(run, jax.random.PRNGKey(0))
        if decentralized:
            state = replicate_for_nodes(state, n_nodes)
        step, _ = build_train_step(run, mesh)
        step = jax.jit(step)
        losses, cerrs = [], []
        for _ in range(steps):
            toks = data.sample(rng, 16, 65)
            batch = {"tokens": jnp.asarray(toks[:, :-1]),
                     "labels": jnp.asarray(toks[:, 1:])}
            if decentralized:
                batch = make_node_batch(batch, n_nodes)
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
            cerrs.append(float(metrics["consensus_err"]))
        # node disagreement on the parameters themselves
        if decentralized:
            spread = float(consensus_error(
                {"p": jax.tree.leaves(state.params)[0]}))
        else:
            spread = 0.0
    return {"mode": mode, "losses": losses, "consensus_errs": cerrs,
            "param_spread": spread, "n_nodes": n_nodes,
            "n_devices": len(jax.devices())}


if __name__ == "__main__":
    mode = sys.argv[1] if len(sys.argv) > 1 else "exact"
    rounds = int(sys.argv[2]) if len(sys.argv) > 2 else 2
    print(json.dumps(train(mode, rounds)))
