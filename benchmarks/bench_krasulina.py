"""Figures 7-8: DM-Krasulina for streaming 1-PCA.

Fig 7 (synthetic, d=10, lambda_1=1, gap=0.1, t'=2e5 scaled from 1e6):
(a) B in {1, 10, 100, 1000}: excess risk O(1/t') for B <= (t')^{1-2/c0};
(b) (N, B) = (10, 100), mu in {0, 10, 100, 200, 1000}.

Fig 8 (CIFAR-like: synthetic spiked covariance with d=3072 matched to
CIFAR-10's scale — documented deviation, CIFAR not bundled offline):
B in {1, 10, 100} at t' = 5e4 (dataset-sized).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs.paper_pca import FIG7, HIGHD
from repro.core import krasulina, problems
from repro.data.synthetic import make_pca_stream


def run(highd: bool = True, quick: bool = False) -> None:
    if quick:
        highd = False
    stream = make_pca_stream(FIG7)
    metric = lambda w: problems.pca_excess_risk(w, stream.cov, stream.lambda1)
    w0 = jax.random.normal(jax.random.PRNGKey(0), (FIG7.dim,))
    w0 = w0 / jnp.linalg.norm(w0)
    T_PRIME = 2_000 if quick else 200_000

    errs = {}
    for B in ((1, 10) if quick else (1, 10, 100, 1000)):
        steps = max(1, T_PRIME // B)
        res = krasulina.run_dm_krasulina(
            stream.draw, w0, N=min(10, B), B=B, steps=steps,
            stepsize=lambda t: 10.0 / t, trace_metric=metric)
        errs[B] = float(res.trace_metric[-1])
        emit(f"fig7a/B{B}", 0.0, f"excess_risk={errs[B]:.6f};steps={steps}")
    if not quick:  # the O(1/t') regime needs the full horizon
        assert errs[100] < 20 * max(errs[1], 1e-5) + 1e-3, "B=100 keeps O(1/t')"

    for mu in ((0, 100) if quick else (0, 10, 100, 200, 1000)):
        steps = max(1, T_PRIME // (100 + mu))  # fixed arrival budget (Fig. 7b)
        res = krasulina.run_dm_krasulina(
            stream.draw, w0, N=10, B=100, mu=mu, steps=steps,
            stepsize=lambda t: 10.0 / t, trace_metric=metric, seed=1)
        emit(f"fig7b/mu{mu}", 0.0,
             f"excess_risk={float(res.trace_metric[-1]):.6f};steps={steps}")

    if highd:
        hstream = make_pca_stream(HIGHD)
        hm = lambda w: problems.sin2_error(w, hstream.top_eigvec)
        w0h = jax.random.normal(jax.random.PRNGKey(1), (HIGHD.dim,))
        for B in (10, 100, 1000):
            steps = max(1, 50_000 // B)
            res = krasulina.run_dm_krasulina(
                hstream.draw, w0h, N=10, B=B, steps=steps,
                stepsize=lambda t: 5.0 / t, trace_metric=hm, seed=2)
            emit(f"fig8/B{B}", 0.0, f"sin2={float(res.trace_metric[-1]):.5f}")
