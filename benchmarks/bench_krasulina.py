"""Figures 7-8: the D(M)-Krasulina family for streaming 1-PCA.

Fig 7 (synthetic, d=10, lambda_1=1, gap=0.1, t'=2e5 scaled from 1e6):
(a) B in {1, 10, 100, 1000}: excess risk O(1/t') for B <= (t')^{1-2/c0};
(b) (N, B) = (10, 100), mu in {0, 10, 100, 200, 1000}.

Fig 8 (CIFAR-like: synthetic spiked covariance with d=3072 matched to
CIFAR-10's scale — documented deviation, CIFAR not bundled offline):
B in {1, 10, 100} at t' = 5e4 (dataset-sized).

Engine suites (PR 4 — the PCA track on the consensus engine):

* fused  — the combined xi+gossip hot path (`kernels.ops.krasulina_xi_gossip`:
  per-node pseudo-gradients + ALL R consensus rounds in one pass) vs the
  unfused per-round baseline (vmap'd xi, then R sequential roll_mix rounds).
  Contract: >=3x at R>=8 on this container (full mode).
* gossip — convergence of gossip-averaged D-Krasulina vs the exact-averaging
  oracle on the Fig. 7 config: excess risk and consensus spread per (R,
  quantization), the PCA analogue of the consensus/quant_accuracy study.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.configs.base import AveragingConfig
from repro.configs.paper_pca import FIG7, HIGHD
from repro.core import krasulina, mixing, problems
from repro.data.synthetic import make_pca_stream
from repro.kernels import ops


def _tmin(fn, *args) -> float:
    """Speedup-contract timing: min over a longer loop (scheduler noise on
    this container only ever inflates)."""
    return time_fn(fn, *args, warmup=2, iters=9, agg="min")


def _fused_xi_gossip(N: int, R: int, d: int, Bn: int,
                     assert_contract: bool) -> None:
    """The combined xi+gossip pass vs the unfused per-round baseline: vmap'd
    per-node xi written out, then R sequential (deg+1)-roll consensus rounds
    over the [N, d] state (the seed-era dataflow)."""
    kw = jax.random.PRNGKey(0)
    w = jax.random.normal(kw, (N, d), jnp.float32)
    z = jax.random.normal(jax.random.PRNGKey(1), (N, Bn, d), jnp.float32)
    sched = mixing.schedule("ring", N)
    loop_op = mixing.circulant_mix_op(sched, N, R, fuse=False)  # per-round
    baseline = jax.jit(
        lambda w, z: loop_op(jax.vmap(ops.krasulina_xi)(w, z)))
    fused = jax.jit(lambda w, z: ops.krasulina_xi_gossip(w, z, sched, R))
    np.testing.assert_allclose(np.asarray(fused(w, z)),
                               np.asarray(baseline(w, z)),
                               rtol=2e-4, atol=2e-5)
    t_base = _tmin(baseline, w, z)
    t_fused = _tmin(fused, w, z)
    speedup = t_base / t_fused
    emit(f"krasulina/fused/N{N}_R{R}_d{d}_Bn{Bn}", t_fused,
         f"per_round_us={t_base:.1f};speedup={speedup:.2f}x")
    if assert_contract:
        # PR 4 acceptance: the fused xi+gossip path >=3x over the unfused
        # per-round baseline on this container
        assert speedup >= 3.0, (N, R, d, Bn, speedup)


def _gossip_vs_exact(steps: int, B: int) -> None:
    """Gossip-averaged D-Krasulina vs the exact oracle on the Fig. 7 stream:
    same draws/init/stepsize, averaging mode as the only variable."""
    stream = make_pca_stream(FIG7)
    metric = lambda w: problems.pca_excess_risk(w, stream.cov, stream.lambda1)
    w0 = jax.random.normal(jax.random.PRNGKey(0), (FIG7.dim,))
    w0 = w0 / jnp.linalg.norm(w0)
    N = 10
    step = lambda t: 10.0 / t

    ex = krasulina.run_dm_krasulina(stream.draw, w0, N=N, B=B, steps=steps,
                                    stepsize=step, trace_metric=metric, seed=3)
    oracle = float(ex.trace_metric[-1])
    emit(f"krasulina/gossip/exact/steps{steps}", 0.0,
         f"excess_risk={oracle:.6f};consensus_err=0.0000")
    for name, avg in (
            ("ring_R2", AveragingConfig(mode="gossip", rounds=2)),
            ("ring_R8", AveragingConfig(mode="gossip", rounds=8)),
            ("ring_R8_sign", AveragingConfig(mode="gossip", rounds=8,
                                             quantization="sign")),
    ):
        res = krasulina.run_d_krasulina(
            stream.draw, w0, N=N, B=B, steps=steps, stepsize=step,
            averaging=avg, trace_metric=metric, seed=3)
        risk = float(res.trace_metric[-1])
        spread = float(jnp.max(jnp.linalg.norm(
            res.w_nodes - res.w[None], axis=1)) / jnp.linalg.norm(res.w))
        emit(f"krasulina/gossip/{name}/steps{steps}", 0.0,
             f"excess_risk={risk:.6f};consensus_err={spread:.4f}")


def run(highd: bool = True, quick: bool = False) -> None:
    if quick:
        highd = False
        _fused_xi_gossip(8, 4, 4_096, 4, assert_contract=False)
        _gossip_vs_exact(steps=30, B=50)
    else:
        for N, R in ((16, 8), (16, 16)):
            _fused_xi_gossip(N, R, 32_768, 4, assert_contract=True)
        _gossip_vs_exact(steps=2_000, B=100)
    stream = make_pca_stream(FIG7)
    metric = lambda w: problems.pca_excess_risk(w, stream.cov, stream.lambda1)
    w0 = jax.random.normal(jax.random.PRNGKey(0), (FIG7.dim,))
    w0 = w0 / jnp.linalg.norm(w0)
    T_PRIME = 2_000 if quick else 200_000

    errs = {}
    for B in ((1, 10) if quick else (1, 10, 100, 1000)):
        steps = max(1, T_PRIME // B)
        res = krasulina.run_dm_krasulina(
            stream.draw, w0, N=min(10, B), B=B, steps=steps,
            stepsize=lambda t: 10.0 / t, trace_metric=metric)
        errs[B] = float(res.trace_metric[-1])
        emit(f"fig7a/B{B}", 0.0, f"excess_risk={errs[B]:.6f};steps={steps}")
    if not quick:  # the O(1/t') regime needs the full horizon
        assert errs[100] < 20 * max(errs[1], 1e-5) + 1e-3, "B=100 keeps O(1/t')"

    for mu in ((0, 100) if quick else (0, 10, 100, 200, 1000)):
        steps = max(1, T_PRIME // (100 + mu))  # fixed arrival budget (Fig. 7b)
        res = krasulina.run_dm_krasulina(
            stream.draw, w0, N=10, B=100, mu=mu, steps=steps,
            stepsize=lambda t: 10.0 / t, trace_metric=metric, seed=1)
        emit(f"fig7b/mu{mu}", 0.0,
             f"excess_risk={float(res.trace_metric[-1]):.6f};steps={steps}")

    if highd:
        hstream = make_pca_stream(HIGHD)
        hm = lambda w: problems.sin2_error(w, hstream.top_eigvec)
        w0h = jax.random.normal(jax.random.PRNGKey(1), (HIGHD.dim,))
        for B in (10, 100, 1000):
            steps = max(1, 50_000 // B)
            res = krasulina.run_dm_krasulina(
                hstream.draw, w0h, N=10, B=B, steps=steps,
                stepsize=lambda t: 5.0 / t, trace_metric=hm, seed=2)
            emit(f"fig8/B{B}", 0.0, f"sin2={float(res.trace_metric[-1]):.5f}")
