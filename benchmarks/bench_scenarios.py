"""Scenario-matrix benchmark (docs/DESIGN.md §Scenario harness): excess risk
across the topology x link x stream grid the paper's assumptions span.

Each cell of `core.scenarios`' 3 x 3 x 3 matrix (time-varying topology
schedules x link loss / bandwidth caps x IID / drifting / label-skewed
streams) runs the streaming engine end-to-end — governed splitter, K-round
superstep, `ScheduledMixOp` time-varying consensus — at a matched sample
budget and seed, so the only thing that varies between cells is the scenario.
PCA cells run gossip Krasulina (excess risk via `core.problems.
pca_excess_risk` against the stream's covariance at the final drift clock);
logreg cells run a gossip SGD superstep (excess risk vs the Bayes separator
on a pooled held-out draw).

Rows:

* matrix      -- `scenarios/matrix/<topo>/<link>/<stream>` per cell:
                 us/round plus excess_risk / consensus_err / rounds
* retrace     -- CONTRACT: mid-stream topology switches compile NOTHING —
                 one jit trace for the whole time-varying run
                 (trace-counted, not inferred)
* tv_vs_static-- CONTRACT: the B-connected time-varying schedule stays
                 within 2x of the static ring's excess risk at a matched
                 budget (eq. 17 — every window of the schedule mixes)
* lossy       -- CONTRACT: the Bernoulli-loss cell still converges
                 (excess risk falls below its start) and is bit-deterministic
                 across runs and prefetch depths (counter-based link RNG)
* governor    -- CONTRACT: under a bandwidth-capped link model the
                 estimator's R_c moves DOWN and the replanned mu moves UP
                 vs the clean cell (eq. 4 re-inverted from measured round
                 times; `core.rates.rate_limited` is the ground truth)
* lm           -- `scenarios/lm/<topo>/<link>`: one LM cell on the launcher's
                 `--scenario` path (the token stream stays
                 `data.lm.MarkovTokenStream`; the scenario contributes the
                 time-varying mixing schedule + lossy link model through
                 `trainer.superstep_builder(mix=...)`). CONTRACT: the cell
                 converges — final loss below the first superstep's — and
                 consensus error stays finite under the Bernoulli link drops

All contract rows are asserted in quick AND full mode — every run here is
deterministic (ungoverned plans, seeded samplers, counter-based link drops).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.base import AveragingConfig, GovernorConfig, StreamConfig
from repro.configs.paper_pca import PCARunConfig
from repro.core import krasulina, problems, rates, scenarios
from repro.train.driver import EngineConfig, StreamingDriver

N = 8
B = 16
K = 2
SEED = 0


def _logreg_builder(n_nodes: int, stepsize: float, mix):
    """Gossip SGD on the logreg cells: per-node `core.problems.logistic_grad`
    step, then the scenario's time-varying consensus operator (the carry's
    round counter is the schedule clock, as in the Krasulina path)."""

    def build(Bq: int, membership=None):
        def superstep(state, batches):
            def step(carry, batch):
                w, t = carry
                t = t + 1
                g = jax.vmap(problems.logistic_grad)(w, batch["x"],
                                                     batch["y"])
                w = mix(w - stepsize * g, t=t)
                wbar = jnp.mean(w, axis=0)
                spread = jnp.mean(jnp.sum((w - wbar) ** 2, axis=-1))
                return (w, t), {"metric": jnp.zeros(()),
                                "consensus_err": spread}

            return jax.lax.scan(step, state, batches)

        return superstep

    return build


def _driver(scn, stream, traces, prefetch: int = 0):
    """One scenario cell on the streaming engine: ungoverned plan (matched
    budget, deterministic), scenario links on the driver's fault schedule
    (link-only -> standard non-elastic path + bw/drop observability)."""
    mix = scenarios.build_mix(scn)
    run_cfg = PCARunConfig(pca=scenarios.PCA_CFG,
                           averaging=scenarios.averaging_config(scn),
                           stream=StreamConfig())
    if stream.kind.endswith("logreg"):
        d = scenarios.LOGREG_CFG.dim
        inner = _logreg_builder(N, 0.2, mix)
        state = (jnp.zeros((N, d + 1)), jnp.zeros((), jnp.int32))
    else:
        inner = krasulina.krasulina_superstep_builder(
            run_cfg.averaging, N, lambda t: 10.0 / t, mix=mix)
        w0 = jax.random.normal(jax.random.PRNGKey(SEED),
                               (scenarios.PCA_CFG.dim,))
        state = krasulina.init_krasulina_state(w0 / jnp.linalg.norm(w0),
                                               run_cfg.averaging, N)

    def builder(Bq, membership=None):
        raw = inner(Bq, membership)

        def counted(s, b):
            traces.append(Bq)  # once per jit trace, not per call
            return raw(s, b)

        return counted

    return StreamingDriver(
        run_cfg, None, state, stream.sample, superstep_builder=builder,
        n_nodes=N, batch=B, faults=scenarios.fault_schedule(scn), seed=SEED,
        engine=EngineConfig(superstep=K, prefetch_depth=prefetch,
                            replan_every=0, warmup_supersteps=0,
                            warmup_per_bucket=0, governor=GovernorConfig()))


def _excess_risk(scn, stream, driver) -> float:
    """Cell excess risk at the final iterate (node mean)."""
    w = np.asarray(driver.state[0]) if isinstance(driver.state, tuple) \
        else np.asarray(driver.state.w)
    wbar = w.mean(axis=0)
    if stream.kind == "iid_pca":
        return float(problems.pca_excess_risk(
            jnp.asarray(wbar), stream.pca.cov, stream.pca.lambda1))
    if stream.kind == "drift_pca":
        cov = jnp.asarray(stream.drift.cov_at(driver.pipeline.samples_consumed),
                          jnp.float32)
        return float(problems.pca_excess_risk(jnp.asarray(wbar), cov,
                                              stream.drift.lambda1))
    # pooled held-out draw from the same skewed mixture: w* is its Bayes
    # separator, so risk(w) - risk(w*) >= 0 up to sampling noise
    batch = stream.logreg.sample(np.random.default_rng(10_000), 8192)
    x, y = jnp.asarray(batch["x"]), jnp.asarray(batch["y"])
    return float(problems.logistic_loss(jnp.asarray(wbar), x, y)
                 - problems.logistic_loss(jnp.asarray(stream.logreg.w_star),
                                          x, y))


def _run_cell(topo: str, link: str, skey: str, steps: int,
              prefetch: int = 0):
    scn = scenarios.make_scenario(topo, link, skey, n_nodes=N, seed=SEED)
    stream = scenarios.build_stream(scn)
    traces: list = []
    with _driver(scn, stream, traces, prefetch=prefetch) as drv:
        t0 = time.perf_counter()
        drv.run(steps)
        wall = time.perf_counter() - t0
        err = _excess_risk(scn, stream, drv)
        final = (np.asarray(drv.state[0]) if isinstance(drv.state, tuple)
                 else np.asarray(drv.state.w)).copy()
        cons = drv.history[-1]["metrics"]["consensus_err"]
    return {"excess": err, "consensus": cons, "wall": wall,
            "traces": len(traces), "rounds": steps * K, "final": final}


def _bench_matrix(quick: bool) -> dict:
    steps = 4 if quick else 10
    cells = {}
    for topo in scenarios.TOPOLOGY_AXIS:
        for link in scenarios.LINK_AXIS:
            for skey in scenarios.STREAM_AXIS:
                r = _run_cell(topo, link, skey, steps)
                cells[(topo, link, skey)] = r
                emit(f"scenarios/matrix/{topo}/{link}/{skey}",
                     r["wall"] / r["rounds"] * 1e6,
                     f"excess_risk={r['excess']:.5f};"
                     f"consensus_err={r['consensus']:.3e};"
                     f"rounds={r['rounds']};traces={r['traces']}")
    return cells


def _bench_contracts(cells: dict, quick: bool) -> None:
    steps = 4 if quick else 10

    # zero retraces across mid-stream topology switches: the time-varying
    # cell cycles ring -> torus -> expander every 2 rounds, yet compiles
    # exactly once (the phase is runtime data in the ScheduledMixOp)
    tv = cells[("tv_rte", "clean", "iid_pca")]
    retraces = tv["traces"] - 1
    switches = tv["rounds"] // 2 - 1
    emit("scenarios/retrace", 0.0,
         f"retraces={retraces};topology_switches={switches};"
         f"jit_traces={tv['traces']}")
    assert retraces == 0, ("topology switches retraced the superstep", tv)

    # eq. 17: the B-connected schedule tracks the static ring at matched
    # budget (same seed, same sample sequence — only the mixing varies)
    static = cells[("ring", "clean", "iid_pca")]
    ratio = tv["excess"] / max(static["excess"], 1e-12)
    emit("scenarios/tv_vs_static", 0.0,
         f"ratio={ratio:.3f};tv_excess={tv['excess']:.5f};"
         f"static_excess={static['excess']:.5f};rounds={tv['rounds']}")
    assert ratio <= 2.0, ("time-varying schedule lost >2x vs static ring",
                          tv["excess"], static["excess"])

    # Bernoulli link loss: still converges, and the realization is a pure
    # function of (seed, round, edge) — bit-identical across a rerun and
    # across prefetch depths 0 vs 2
    lossy = cells[("ring", "lossy", "iid_pca")]
    rerun = _run_cell("ring", "lossy", "iid_pca", steps)
    deep = _run_cell("ring", "lossy", "iid_pca", steps, prefetch=2)
    identical = (np.array_equal(lossy["final"], rerun["final"])
                 and np.array_equal(lossy["final"], deep["final"]))
    w0 = jax.random.normal(jax.random.PRNGKey(SEED),
                           (scenarios.PCA_CFG.dim,))
    pca = scenarios.build_stream(
        scenarios.make_scenario("ring", "lossy", "iid_pca", n_nodes=N)).pca
    start = float(problems.pca_excess_risk(w0 / jnp.linalg.norm(w0),
                                           pca.cov, pca.lambda1))
    convergent = lossy["excess"] < start
    emit("scenarios/lossy", 0.0,
         f"deterministic={int(identical)};convergent={int(convergent)};"
         f"excess_risk={lossy['excess']:.5f};start={start:.5f}")
    assert identical, "lossy cell not bit-deterministic across runs/prefetch"
    assert convergent, ("lossy cell did not converge", lossy["excess"], start)


def _bench_governor_direction(quick: bool) -> None:
    R = 2
    Rp_true, Rc_true = 1e5, 2e3
    # R_s high enough that the round interval matters: arrivals per round
    # exceed B in both regimes, so the discard count mu is the adaptation
    nominal = StreamConfig(streaming_rate=1e5, processing_rate=Rp_true,
                           comms_rate=Rc_true)
    scn = scenarios.get_scenario("ring/ratelimited/iid_pca")
    bw = scenarios.comm_factor(scn, 5)  # inside the bw window
    assert bw > 1.0, ("scenario's bandwidth window not active", bw)
    limited = rates.rate_limited(nominal, bw)
    out = {}
    for label, truth_stream in (("clean", nominal), ("limited", limited)):
        est = rates.RoundTimeEstimator(N, R, window=64)
        rng = np.random.default_rng(0)
        for _ in range(4 if quick else 16):
            for Bq in (32, 64, 128, 256):
                truth = Bq / (N * Rp_true) + R / truth_stream.comms_rate
                est.observe(Bq, truth * (1.0 + 0.02 * rng.normal()))
        e = est.estimate()
        wall = 64 / (N * Rp_true) + R / truth_stream.comms_rate
        # the governor never sees the cap: it replans from the NOMINAL
        # config plus what it measured (eq. 4 re-inverted)
        p = rates.replan(nominal, N, R, 64, wall, estimate=e)
        out[label] = (e, p)
    (e0, p0), (e1, p1) = out["clean"], out["limited"]
    direction = int(e1.Rc < e0.Rc and p1.mu > p0.mu)
    emit("scenarios/governor", 0.0,
         f"direction={direction};est_Rc_clean={e0.Rc:.1f};"
         f"est_Rc_limited={e1.Rc:.1f};mu_clean={p0.mu};mu_limited={p1.mu};"
         f"bw_factor={bw:g}")
    assert direction == 1, ("rate-limited links must push est R_c down and "
                            "mu up", out)


def _bench_lm_cell(quick: bool) -> None:
    """One LM cell under the scenario harness — the launcher's `--scenario`
    path: reduced `configs/` transformer, `MarkovTokenStream` tokens, the
    scenario's time-varying `ScheduledMixOp` via `trainer.superstep_builder
    (mix=...)`, and its lossy link model on the driver's fault schedule."""
    import dataclasses

    from repro.configs import get_config, reduced
    from repro.configs.base import RunConfig, SHAPES
    from repro.data.lm import MarkovTokenStream
    from repro.launch.mesh import make_mesh
    from repro.launch.sharding import activation_rules
    from repro.models.common import mesh_rules
    from repro.train import trainer

    topo, link = "tv_rte", "lossy"
    scn = scenarios.make_scenario(topo, link, "iid_pca", n_nodes=N, seed=SEED)
    model = dataclasses.replace(
        reduced(get_config("granite-8b"), layers=1, d_model=16),
        vocab_size=32, d_ff=32)
    run_cfg = RunConfig(model=model, shape=SHAPES["train_4k"],
                        averaging=scenarios.averaging_config(scn),
                        stream=StreamConfig(),
                        optimizer="adam", learning_rate=1e-3,
                        param_dtype="float32", remat=False)
    mesh = make_mesh((1, 1), ("data", "model"))
    data = MarkovTokenStream(model.vocab_size, seed=SEED)
    seq = 16

    def sample(rng, n):
        toks = data.sample(rng, n, seq + 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    steps = 4 if quick else 10
    with mesh_rules(mesh, activation_rules(mesh, run_cfg.shape,
                                           node_axis=True)):
        state = trainer.replicate_for_nodes(
            trainer.init_state(run_cfg, jax.random.PRNGKey(SEED)), N)
        builder = trainer.superstep_builder(run_cfg, mesh, n_nodes=N,
                                            mix=scenarios.build_mix(scn))
        with StreamingDriver(run_cfg, mesh, state, sample,
                             superstep_builder=builder, n_nodes=N, batch=N * 4,
                             faults=scenarios.fault_schedule(scn), seed=SEED,
                             engine=EngineConfig(superstep=K, prefetch_depth=0,
                                                 replan_every=0,
                                                 warmup_supersteps=0,
                                                 warmup_per_bucket=0,
                                                 governor=GovernorConfig())) as drv:
            t0 = time.perf_counter()
            drv.run(steps)
            wall = time.perf_counter() - t0
            first = drv.history[0]["metrics"]["loss"]
            last = drv.history[-1]["metrics"]["loss"]
            cons = drv.history[-1]["metrics"]["consensus_err"]
    convergent = int(last < first)
    emit(f"scenarios/lm/{topo}/{link}", wall / (steps * K) * 1e6,
         f"loss={last:.4f};first_loss={first:.4f};"
         f"convergent={convergent};consensus_err={cons:.3e};"
         f"rounds={steps * K};vocab={model.vocab_size};seq={seq}")
    assert convergent, ("LM scenario cell did not converge", first, last)
    assert np.isfinite(cons), cons


def run(quick: bool = False) -> None:
    cells = _bench_matrix(quick)
    _bench_contracts(cells, quick)
    _bench_governor_direction(quick)
    _bench_lm_cell(quick)
