"""Kernel micro-benchmarks: Pallas (interpret on CPU — correctness proxy) and
the jnp reference path (XLA-compiled — the actual CPU timing), over the shapes
the framework hits. On TPU the Pallas path compiles natively; here the derived
column records bytes and arithmetic intensity for the roofline discussion.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels import ref


def run(quick: bool = False) -> None:
    # Krasulina xi: memory-bound BLAS-2 pass — 4*B*d flops (two fused matvecs)
    # over one streamed read of Z; bytes follow the ACTUAL array dtype (f32
    # here, 4 B/elem), so ai = 1 flop/byte at f32 and 2 at bf16
    for B, d in (((256, 128),) if quick else ((1024, 512), (4096, 3072))):
        kw, kz = jax.random.split(jax.random.PRNGKey(0))
        w = jax.random.normal(kw, (d,), jnp.float32)
        z = jax.random.normal(kz, (B, d), jnp.float32)
        f = jax.jit(ref.krasulina_xi_ref)
        us = time_fn(f, w, z)
        flops = 4 * B * d
        bytes_ = z.size * z.dtype.itemsize
        emit(f"kernel/krasulina/B{B}_d{d}", us,
             f"ai={flops / bytes_:.2f}flops_per_byte")

    # blockwise attention reference path
    for S in ((128,) if quick else (512, 1024)):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (1, 8, S, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 8, S, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 8, S, 64), jnp.float32)
        f = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
        us = time_fn(f, q, k, v)
        emit(f"kernel/attention/S{S}", us, f"flops={4 * 8 * S * S * 64:.0f}")
