"""Consensus engine benchmark: per-step consensus wall time vs R, per-round
loop vs the precomputed fused operator (core.mixing.MixOp).

The per-round loop is the slowest-possible form of eq. 17 — R sequential dense
matmuls (dense path) or (deg+1)*R weighted rolls (circulant path) per step.
The fused engine precomputes the R-round operator once outside the step, so
per-step cost is ~one round. Rows emit the fused time with the loop time and
speedup in the derived column; the dense rows assert the >=2x contract at
R>=8, N>=16 and allclose(1e-5) against the per-round oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import dsgd, mixing

D = 65_536  # per-node state width: big enough that work, not dispatch, is timed


def _dense(N: int, R: int) -> None:
    A = jnp.asarray(mixing.random_regular_expander(N, deg=6, seed=0), jnp.float32)
    h = jax.random.normal(jax.random.PRNGKey(0), (N, D), jnp.float32)
    loop = jax.jit(lambda h: dsgd.consensus(h, A, R))
    mix = mixing.dense_mix_op(A, R)
    fused = jax.jit(lambda h: mix(h))
    np.testing.assert_allclose(np.asarray(fused(h)), np.asarray(loop(h)),
                               rtol=1e-5, atol=1e-5)
    t_loop = time_fn(loop, h, iters=5)
    t_fused = time_fn(fused, h, iters=5)
    speedup = t_loop / t_fused
    emit(f"consensus/dense/N{N}_R{R}_d{D}", t_fused,
         f"loop_us={t_loop:.1f};speedup={speedup:.2f}x")
    if R >= 8 and N >= 16:
        assert speedup >= 2.0, (N, R, speedup)


def _circulant(N: int, R: int, topo: str) -> None:
    sched = mixing.schedule(topo, N)
    h = jax.random.normal(jax.random.PRNGKey(1), (N, D), jnp.float32)
    loop_op = mixing.circulant_mix_op(sched, N, R, fuse=False)  # per-round loop
    loop = jax.jit(lambda h: loop_op(h))
    t_loop = time_fn(loop, h, iters=5)
    oracle = np.asarray(loop(h))
    for impl in ("roll", "matmul"):
        mix = mixing.circulant_mix_op(sched, N, R, impl=impl)
        fused = jax.jit(lambda h: mix(h))
        np.testing.assert_allclose(np.asarray(fused(h)), oracle,
                                   rtol=1e-5, atol=1e-5)
        t_fused = time_fn(fused, h, iters=5)
        emit(f"consensus/circulant/{topo}/N{N}_R{R}_{impl}", t_fused,
             f"loop_us={t_loop:.1f};speedup={t_loop / t_fused:.2f}x")


def run(quick: bool = False) -> None:
    global D
    if quick:  # dispatch-dominated at smoke scale: keep timings, drop contracts
        D_full, D = D, 4_096
        try:
            _dense(8, 4)
            _circulant(8, 4, "ring")
        finally:
            D = D_full
        return
    for N, R in ((16, 8), (16, 16), (64, 8)):
        _dense(N, R)
    for N, R in ((16, 8), (16, 16), (64, 8)):
        _circulant(N, R, "ring")
