"""Consensus engine benchmark: per-step consensus wall time vs R, per-round
loop vs the precomputed fused operator (core.mixing.MixOp), plus the packed
flat-buffer + quantized suites and the tile-vs-global compressor accuracy
study (PR 3).

Suites:

* dense / circulant — the PR 1 contract: R sequential matmuls / (deg+1)*R
  weighted rolls vs the precomputed R-round operator (>=2x at R>=8, N>=16).
* packed — unquantized gossip on a many-leaf pytree: per-leaf dispatch
  (`packed=False`) vs ONE flat [N, D] buffer per step (`core.packing`).
* quantized — the per-leaf per-round quantized loop (the pre-PR path: global
  stats, one roll/compress chain per leaf per round) vs the packed buffer
  with tile-statistics fused execution (`quant_stats="tile"`; the Pallas
  kernel on TPU, the single-dispatch XLA tile chain here). Contract: >=5x
  steady-state on the many-tiny-leaf tree in full mode. The segment-stats
  middle tier (per-leaf scales, packed execution) is timed alongside.
* quant_accuracy — convergence of quantized decentralized logistic regression
  (the paper's Fig. 9 conditional-Gaussian problem) under global vs tile
  compressor statistics: final excess risk and consensus error per config,
  the Section VI semantics study the tile fusion requires.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.configs.base import AveragingConfig
from repro.configs.paper_logreg import FIG9
from repro.core import averaging, dsgd, mixing, problems
from repro.data.synthetic import make_logreg_stream

D = 65_536  # per-node state width: big enough that work, not dispatch, is timed


def _dense(N: int, R: int) -> None:
    A = jnp.asarray(mixing.random_regular_expander(N, deg=6, seed=0), jnp.float32)
    h = jax.random.normal(jax.random.PRNGKey(0), (N, D), jnp.float32)
    loop = jax.jit(lambda h: dsgd.consensus(h, A, R))
    mix = mixing.dense_mix_op(A, R)
    fused = jax.jit(lambda h: mix(h))
    np.testing.assert_allclose(np.asarray(fused(h)), np.asarray(loop(h)),
                               rtol=1e-5, atol=1e-5)
    t_loop = time_fn(loop, h, iters=5)
    t_fused = time_fn(fused, h, iters=5)
    speedup = t_loop / t_fused
    emit(f"consensus/dense/N{N}_R{R}_d{D}", t_fused,
         f"loop_us={t_loop:.1f};speedup={speedup:.2f}x")
    if R >= 8 and N >= 16:
        assert speedup >= 2.0, (N, R, speedup)


def _circulant(N: int, R: int, topo: str) -> None:
    sched = mixing.schedule(topo, N)
    h = jax.random.normal(jax.random.PRNGKey(1), (N, D), jnp.float32)
    loop_op = mixing.circulant_mix_op(sched, N, R, fuse=False)  # per-round loop
    loop = jax.jit(lambda h: loop_op(h))
    t_loop = time_fn(loop, h, iters=5)
    oracle = np.asarray(loop(h))
    for impl in ("roll", "matmul"):
        mix = mixing.circulant_mix_op(sched, N, R, impl=impl)
        fused = jax.jit(lambda h: mix(h))
        np.testing.assert_allclose(np.asarray(fused(h)), oracle,
                                   rtol=1e-5, atol=1e-5)
        t_fused = time_fn(fused, h, iters=5)
        emit(f"consensus/circulant/{topo}/N{N}_R{R}_{impl}", t_fused,
             f"loop_us={t_loop:.1f};speedup={t_loop / t_fused:.2f}x")


# ---------------------------------------------------------------------------
# Packed + quantized suites (many-leaf pytrees)
# ---------------------------------------------------------------------------

_WIDTHS = (8, 16, 32, 64, 12, 24)  # tiny-leaf regime: biases/norms/projections


def _leafy_tree(n: int, n_leaves: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return {f"l{i}": jnp.asarray(
        rng.normal(size=(n, _WIDTHS[i % len(_WIDTHS)])).astype(np.float32))
        for i in range(n_leaves)}


def _tmin(fn, *args) -> float:
    """Speedup-contract timing: min over a longer loop (scheduler noise on
    this container only ever inflates)."""
    return time_fn(fn, *args, warmup=2, iters=9, agg="min")


def _packed(N: int, R: int, n_leaves: int) -> None:
    """Unquantized gossip: per-leaf tree.map dispatch vs one packed buffer."""
    tree = _leafy_tree(N, n_leaves)
    cfg = AveragingConfig(mode="gossip", rounds=R)
    mix = averaging.make_gossip_mix(cfg, N)
    per_leaf_cfg = dataclasses.replace(cfg, packed=False)
    per_leaf = jax.jit(lambda t: averaging.gossip_average(t, N, per_leaf_cfg, mix))
    packed = jax.jit(lambda t: averaging.gossip_average(t, N, cfg, mix))
    a, b = per_leaf(tree), packed(tree)
    for k in tree:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   rtol=1e-5, atol=1e-5)
    t_leaf = _tmin(per_leaf, tree)
    t_packed = _tmin(packed, tree)
    emit(f"consensus/packed/N{N}_R{R}_leaves{n_leaves}", t_packed,
         f"per_leaf_us={t_leaf:.1f};speedup={t_leaf / t_packed:.2f}x")


def _quantized(N: int, R: int, n_leaves: int, quant: str,
               assert_contract: bool) -> None:
    """Quantized gossip: the pre-PR per-leaf per-round loop (global stats)
    vs the packed flat buffer through segment stats and the fused tile path."""
    tree = _leafy_tree(N, n_leaves)
    base_cfg = AveragingConfig(mode="gossip", rounds=R, quantization=quant,
                               packed=False)
    base_mix = averaging.make_gossip_mix(base_cfg, N)
    base = jax.jit(lambda t: averaging.gossip_average(t, N, base_cfg, base_mix))
    t_base = _tmin(base, tree)
    for stats in ("segment", "tile"):
        cfg = AveragingConfig(mode="gossip", rounds=R, quantization=quant,
                              quant_stats=stats)
        mix = averaging.make_gossip_mix(cfg, N)
        fused = jax.jit(lambda t: averaging.gossip_average(t, N, cfg, mix))
        t_fused = _tmin(fused, tree)
        speedup = t_base / t_fused
        emit(f"consensus/quantized/{quant}/{stats}/N{N}_R{R}_leaves{n_leaves}",
             t_fused, f"per_leaf_loop_us={t_base:.1f};speedup={speedup:.2f}x")
        if assert_contract and stats == "tile":
            # the PR 3 acceptance contract: packed + fused tile kernel >=5x
            # over the per-leaf per-round baseline on a many-leaf pytree
            assert speedup >= 5.0, (quant, N, R, n_leaves, speedup)


# ---------------------------------------------------------------------------
# Accuracy study: global vs tile compressor statistics (Section VI semantics)
# ---------------------------------------------------------------------------


def _quant_accuracy(steps: int, block_d: int) -> None:
    """Decentralized logistic regression (paper Fig. 9 generator, d=20) with
    quantized ring gossip: identical streams/init, compressor statistics as
    the only variable. Emits final excess risk and consensus error per
    config; `stats="global"` is the paper-faithful oracle, `stats="tile"` is
    the fused-kernel semantics at tile width `block_d` (< d+1, so the scale
    really is per-tile)."""
    N, B, R = 16, 64, 2
    stream = make_logreg_stream(FIG9)
    d = FIG9.dim + 1
    w0 = jnp.zeros((N, d))
    key_eval = jax.random.PRNGKey(99)
    x_eval, y_eval = stream.draw(key_eval, 20_000)
    risk_star = float(problems.logistic_loss(stream.w_star, x_eval, y_eval))

    def run(cfg: AveragingConfig):
        mix = averaging.make_gossip_mix(cfg, N)

        def step(carry, t):
            w, key = carry
            key, kd = jax.random.split(key)
            x, y = stream.draw(kd, B)
            xs = x.reshape(N, B // N, -1)
            ys = y.reshape(N, B // N)
            g = jax.vmap(lambda wn, xn, yn: problems.logistic_grad(wn, xn, yn))(
                w, xs, ys)
            h = mix(g)
            w = w - (0.5 / jnp.sqrt(t)) * h
            return (w, key), None

        (w, _), _ = jax.lax.scan(step, (w0, jax.random.PRNGKey(7)),
                                 jnp.arange(1., steps + 1.))
        risk = float(problems.logistic_loss(jnp.mean(w, 0), x_eval, y_eval))
        cerr = float(averaging.consensus_error({"w": w}))
        return risk - risk_star, cerr

    base, cerr0 = run(AveragingConfig(mode="gossip", rounds=R))
    emit(f"consensus/quant_accuracy/none/global/steps{steps}", 0.0,
         f"excess_risk={base:.5f};consensus_err={cerr0:.4f}")
    for quant in ("sign", "int8", "int8_stoch"):
        for stats in ("global", "tile"):
            if quant == "int8_stoch" and stats == "global":
                continue  # the keyed global path mirrors int8's numerics
            cfg = AveragingConfig(mode="gossip", rounds=R, quantization=quant,
                                  quant_stats=stats, quant_block_d=block_d)
            risk, cerr = run(cfg)
            emit(f"consensus/quant_accuracy/{quant}/{stats}/steps{steps}", 0.0,
                 f"excess_risk={risk:.5f};consensus_err={cerr:.4f}")


def run(quick: bool = False) -> None:
    global D
    if quick:  # dispatch-dominated at smoke scale: keep timings, drop contracts
        D_full, D = D, 4_096
        try:
            _dense(8, 4)
            _circulant(8, 4, "ring")
            _packed(4, 2, 24)
            _quantized(4, 2, 24, "sign", assert_contract=False)
            _quant_accuracy(steps=30, block_d=8)
        finally:
            D = D_full
        return
    # packed + quantized first: their contract rows are timing-sensitive and
    # the dense/circulant suites churn hundreds of MB through the allocator
    _packed(4, 6, 256)
    for quant in ("sign", "int8"):
        _quantized(4, 8, 256, quant, assert_contract=True)
    _quant_accuracy(steps=400, block_d=8)
    for N, R in ((16, 8), (16, 16), (64, 8)):
        _dense(N, R)
    for N, R in ((16, 8), (16, 16), (64, 8)):
        _circulant(N, R, "ring")
