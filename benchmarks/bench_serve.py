"""Train-to-serve closed loop (docs/DESIGN.md §Train-to-serve publication):
a gossip LM learner runs supersteps while a continuous-batching decode engine
serves Poisson traffic off the learner's published consensus snapshots.

Between supersteps the serving engine polls the `serve.publisher`
double-buffer and hot-swaps to any newer param version (between decode steps,
zero in-flight loss), then decodes a fixed window of steps admitting
deterministic virtual Poisson arrivals. Rows:

* tokens_per_s -- decode throughput of the continuous-batching engine while
                  the learner trains in the same process
* latency      -- per-decode-step wall p50/p99 (each step = one token for
                  every occupied slot)
* publish      -- CONTRACT: snapshot-publish overhead (publisher dispatch
                  cost over total closed-loop wall) <= 5%, enforced by the
                  publisher's EWMA budget governor
* zero_loss    -- CONTRACT: >= 3 version swaps mid-traffic and zero dropped
                  in-flight requests (every submitted request completes with
                  exactly max_new tokens; at least one decode spans a swap)
* staleness    -- max served-snapshot staleness in supersteps, bounded by the
                  largest gap between consecutive publishes
* train_delta  -- learner superstep wall with publishing vs a no-publish
                  baseline at matched work (informational on shared CPU)
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import SHAPES, get_config, reduced
from repro.configs.base import (AveragingConfig, GovernorConfig, RunConfig,
                                StreamConfig)
from repro.data.lm import MarkovTokenStream
from repro.launch import sharding as shlib
from repro.launch.mesh import make_host_mesh, n_data_nodes
from repro.models.common import mesh_rules
from repro.serve.engine import ContinuousBatchingEngine
from repro.serve.publisher import SnapshotPublisher
from repro.train.driver import EngineConfig, StreamingDriver
from repro.train.trainer import init_state, replicate_for_nodes

SEQ = 32
BATCH = 4
K = 2  # rounds per superstep
PROMPT = 8
GEN = 10
SLOTS = 2
MAX_LEN = 32


def _run_cfg():
    return RunConfig(
        model=reduced(get_config("granite-8b")), shape=SHAPES["train_4k"],
        averaging=AveragingConfig("gossip", 2, "ring"),
        stream=StreamConfig(), optimizer="adam", learning_rate=3e-4,
        param_dtype="float32")


def _sampler(vocab):
    data = MarkovTokenStream(vocab, seed=0)

    def sample(rng, n):
        t = data.sample(rng, n, SEQ + 1)
        return {"tokens": t[:, :-1], "labels": t[:, 1:]}

    return sample


def _driver(run, mesh, publisher):
    n = n_data_nodes(mesh)
    state = replicate_for_nodes(init_state(run, jax.random.PRNGKey(0)), n)
    eng = EngineConfig(superstep=K, prefetch_depth=0, replan_every=0,
                      warmup_supersteps=0, warmup_per_bucket=0,
                      governor=GovernorConfig())
    return StreamingDriver(run, mesh, state, _sampler(run.model.vocab_size),
                           engine=eng, batch=BATCH, publisher=publisher)


def _arrivals(n_req: int, steps_per_req: float, seed: int = 0) -> np.ndarray:
    """Deterministic virtual Poisson arrival times in decode-step units
    (exponential inter-arrivals; independent of wall clock, so the closed
    loop replays identically across runs)."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(steps_per_req, size=n_req))


def _bench_closed_loop(quick: bool) -> None:
    supersteps = 8 if quick else 16
    steps_per_sup = 10 if quick else 16
    n_req = 10 if quick else 32

    run = _run_cfg()
    mesh = make_host_mesh()
    rules = shlib.activation_rules(mesh, run.shape, node_axis=True)
    pub = SnapshotPublisher(overhead_budget=0.04)  # margin under the 5% row
    arrivals = _arrivals(n_req, steps_per_sup * supersteps / n_req)

    with mesh_rules(mesh, rules):
        drv = _driver(run, mesh, pub)
        with drv:
            drv.run(1)  # absorb train compiles; also the first publish
            # settle the publish-cost EWMA at its steady (post-compile) value,
            # then open a fresh measurement window — one-time compile cost is
            # not what the 5% contract governs
            for _ in range(3):
                pub.publish(drv.state, 1, aux=drv._publish_aux())
            pub.reset_stats()
            eng = ContinuousBatchingEngine(
                run.model, pub.snapshot().params, slots=SLOTS,
                max_len=MAX_LEN, version=pub.snapshot().version)
            # absorb serve compiles (prefill@PROMPT, insert, decode)
            warm = eng.submit(np.arange(PROMPT), 2)
            eng.drain()
            assert eng.result(warm) is not None

            prng = np.random.default_rng(1)
            rids, next_arr, vstep = [], 0, 0
            step_walls, stale_sup, pub_sups = [], [], [pub.snapshot().superstep]
            train_wall = serve_wall = 0.0
            s = 0
            t_loop0 = time.perf_counter()
            # run the planned supersteps, then keep training (bounded) until
            # the governor has allowed >= 3 publishes mid-traffic — the
            # zero-loss contract needs that many live swaps
            while s < supersteps or (eng.swaps < 3 and s < supersteps + 32):
                t0 = time.perf_counter()
                drv.run(1)
                train_wall += time.perf_counter() - t0
                live = s + 2  # 1 warmup superstep + s+1 timed ones
                if eng.poll(pub):
                    pub_sups.append(pub.snapshot().superstep)
                stale_sup.append(pub.staleness(live)["supersteps"])
                t0 = time.perf_counter()
                for _ in range(steps_per_sup):
                    vstep += 1  # virtual clock: ticks even while slots idle
                    while (next_arr < n_req
                           and arrivals[next_arr] <= vstep):
                        rids.append(eng.submit(
                            prng.integers(0, run.model.vocab_size,
                                          size=PROMPT), GEN))
                        next_arr += 1
                    if not (eng.n_active or eng.n_queued):
                        continue
                    t1 = time.perf_counter()
                    eng.step()
                    step_walls.append(time.perf_counter() - t1)
                serve_wall += time.perf_counter() - t0
                s += 1
            supersteps = s  # actual supersteps run (matched-work baseline)
            # late arrivals + tail: drain remaining traffic under live swaps
            while next_arr < n_req:
                rids.append(eng.submit(
                    prng.integers(0, run.model.vocab_size, size=PROMPT), GEN))
                next_arr += 1
            t0 = time.perf_counter()
            eng.drain()
            serve_wall += time.perf_counter() - t0
            loop_wall = time.perf_counter() - t_loop0

    done = [eng.result(r) for r in rids]
    dropped = sum(1 for d in done if d is None or len(d.tokens) != GEN)
    spanning = sum(1 for d in done if d is not None
                   and len(set(d.versions)) > 1)
    toks = sum(len(d.tokens) for d in done if d is not None)
    ws = sorted(step_walls)
    p50 = ws[len(ws) // 2] * 1e6
    p99 = ws[min(len(ws) - 1, int(len(ws) * 0.99))] * 1e6

    emit("serve/tokens_per_s", serve_wall / max(toks, 1) * 1e6,
         f"tok_s={toks / max(serve_wall, 1e-9):.1f};tokens={toks};"
         f"decode_steps={eng.decode_steps};slots={SLOTS}")
    emit("serve/latency", p50,
         f"p50_us={p50:.0f};p99_us={p99:.0f};steps={len(ws)}")

    st = pub.stats
    frac = st.total_cost_s / max(loop_wall, 1e-9)
    emit("serve/publish", st.cost_ewma_s * 1e6,
         f"overhead_frac={frac:.4f};publishes={st.publishes};"
         f"swaps={eng.swaps};skipped_budget={st.skipped_budget};"
         f"total_cost_s={st.total_cost_s:.3f};loop_wall_s={loop_wall:.3f}")
    # publish-overhead contract: the EWMA budget governor keeps snapshot
    # dispatch under 5% of closed-loop wall (budget set to 4% for margin)
    assert frac <= 0.05, ("publish overhead above budget", frac)
    emit("serve/zero_loss", 0.0,
         f"submitted={len(rids)};completed={len(rids) - dropped};"
         f"dropped={dropped};spanning_swap={spanning};swaps={eng.swaps}")
    # hot-swap contract: live traffic across >= 3 mid-stream publications,
    # nothing dropped, and at least one request decoded under two versions
    assert dropped == 0, ("in-flight requests dropped across swaps", dropped)
    assert eng.swaps >= 3, ("too few mid-traffic version swaps", eng.swaps)
    assert spanning >= 1, "no request spanned a version swap"

    gaps = [b - a for a, b in zip(pub_sups, pub_sups[1:])] or [1]
    emit("serve/staleness", 0.0,
         f"max_supersteps={max(stale_sup)};mean={np.mean(stale_sup):.2f};"
         f"max_publish_gap={max(gaps)};wall_s={pub.staleness(0)['wall_s']:.3f}")
    # staleness contract: the served snapshot never trails the live iterate
    # by more than the largest publish gap the governor allowed
    assert max(stale_sup) <= max(gaps), (stale_sup, pub_sups)

    # no-publish baseline at matched train work (informational: shared-CPU
    # wall noise; the within-run overhead_frac above is the contract)
    with mesh_rules(mesh, rules):
        base = _driver(run, mesh, None)
        with base:
            base.run(1)
            t0 = time.perf_counter()
            base.run(supersteps)
            base_wall = time.perf_counter() - t0
    delta = (train_wall - base_wall) / max(base_wall, 1e-9)
    emit("serve/train_delta", train_wall / supersteps * 1e6,
         f"train_wall_s={train_wall:.3f};baseline_wall_s={base_wall:.3f};"
         f"delta_frac={delta:.4f}")


def run(quick: bool = False) -> None:
    _bench_closed_loop(quick)
