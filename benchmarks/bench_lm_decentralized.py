"""Decentralized LM training on a REALLY sharded node axis (tentpole:
shard_map gossip kernels + error-feedback compressed averaging).

The interesting layouts need more than one device, and jax pins the device
count at import — so this suite re-execs itself as a subprocess worker with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` and re-emits the
worker's rows. Two sections:

* **mix** — the consensus operator alone on a [16, D] f32 buffer sharded
  4-ways: the shard_map partitioning rule (per-round halo ppermutes + fused
  slice-sum, `kernels.consensus.gossip_mix_shard`) vs the composed-roll
  fallback it replaces. Contract (full mode): >= 1.5x, and the shard result
  bit-identical to the per-round `ref.gossip_mix_ref` oracle.
* **train** — a reduced `configs/` transformer (granite-8b family) streaming
  `data.lm.MarkovTokenStream` through gossip averaging with N=8 nodes
  sharded over the 4 devices: tokens/s + consensus error for the shard rule
  vs the forced roll fallback, then error-feedback sign/int8 compressed
  gossip at matched steps. Contract: EF progress within 1.2x of the
  uncompressed run (`ef_excess_x <= 1.2`), residual norms live.

`run --quick` shrinks D, the model, and the step counts; the speedup
contract only binds in full mode (smoke scale is dispatch-dominated). The
committed ``BENCH_lm_decentralized.json`` carries the full-mode rows;
`tests/test_benchmarks_quick.py` asserts both the quick rows and the
committed artifact's contract rows.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from benchmarks.common import emit

_WORKER_TIMEOUT = 900


def run(quick: bool = False) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    cmd = [sys.executable, "-m", "benchmarks.bench_lm_decentralized",
           "--worker"] + (["--quick"] if quick else [])
    p = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       timeout=_WORKER_TIMEOUT)
    if p.returncode != 0:
        raise RuntimeError(f"lm_decentralized worker failed:\n{p.stderr[-3000:]}")
    rows = json.loads(p.stdout.strip().splitlines()[-1])
    for r in rows:
        emit(r["name"], r["us_per_call"], r["derived"])
    by_name = {r["name"]: r["derived"] for r in rows}
    assert "bit_identical=1" in by_name["lm_decentralized/mix/exact_parity"]
    for q in ("sign", "int8"):
        d = dict(kv.split("=") for kv in
                 by_name[f"lm_decentralized/train/ef_{q}"].split(";") if kv)
        assert float(d["ef_excess_x"]) <= 1.2, (q, d)
    if not quick:
        d = dict(kv.split("=") for kv in
                 by_name["lm_decentralized/mix/shard_vs_roll"].split(";") if kv)
        assert float(d["speedup"].rstrip("x")) >= 1.5, d


# ---------------------------------------------------------------------------
# Worker (4 fake host devices)
# ---------------------------------------------------------------------------


def _worker(quick: bool) -> None:
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from benchmarks.common import time_fn
    from repro.configs import get_config, reduced
    from repro.configs.base import AveragingConfig, RunConfig, SHAPES
    from repro.core import mixing
    from repro.core.averaging import make_gossip_mix
    from repro.data.lm import MarkovTokenStream
    from repro.kernels import ref
    from repro.launch.mesh import make_host_mesh
    from repro.launch.sharding import activation_rules
    from repro.models.common import mesh_rules
    from repro.train.trainer import (build_train_step, init_state,
                                     make_node_batch, replicate_for_nodes)

    assert len(jax.devices()) == 4, jax.devices()
    rows = []

    def wemit(name, us, derived):
        rows.append({"name": name, "us_per_call": us, "derived": derived})

    # ---- mix section -----------------------------------------------------
    N, R = 16, 4
    D = 1 << 16 if quick else 1 << 20
    mesh = make_host_mesh()
    sharding = NamedSharding(mesh, P("data", None))
    x = jax.device_put(
        jax.random.normal(jax.random.PRNGKey(0), (N, D), jnp.float32),
        sharding)
    sched = mixing.schedule("ring", N, 0.5)
    op_shard = mixing.circulant_mix_op(sched, N, R, mesh=mesh)
    assert op_shard.impl == "shard", op_shard.impl
    op_roll = mixing.circulant_mix_op(sched, N, R, impl="roll")
    f_shard = jax.jit(op_shard)
    f_roll = jax.jit(op_roll, in_shardings=(sharding,),
                     out_shardings=sharding)

    got = np.asarray(f_shard(x))
    oracle = np.asarray(ref.gossip_mix_ref(np.asarray(x), tuple(sched), R))
    wemit("lm_decentralized/mix/exact_parity", 0.0,
          f"bit_identical={int(np.array_equal(got, oracle))};N={N};R={R}")

    iters = 3 if quick else 7
    t_shard = time_fn(f_shard, x, warmup=2, iters=iters, agg="min")
    t_roll = time_fn(f_roll, x, warmup=2, iters=iters, agg="min")
    wemit("lm_decentralized/mix/shard_vs_roll", t_shard,
          f"roll_us={t_roll:.1f};speedup={t_roll / t_shard:.2f}x;"
          f"N={N};R={R};d={D};devices=4")

    # ---- train section ---------------------------------------------------
    import dataclasses
    if quick:
        model = dataclasses.replace(
            reduced(get_config("granite-8b"), layers=1, d_model=64),
            vocab_size=256, d_ff=128)
        seq, bn, steps, n_nodes = 32, 2, 3, 8
    else:
        # the largest transformer this 2-vCPU container turns over in a few
        # seconds per step: 2 layers, d_model=256, 2k vocab
        model = dataclasses.replace(
            reduced(get_config("granite-8b"), layers=2, d_model=256),
            vocab_size=2048)
        # 20 timed steps: the sign-EF residual needs ~10 steps to reach
        # steady state, and the progress contract divides by the loss drop —
        # an 8-step window leaves both in the transient/noise regime
        seq, bn, steps, n_nodes = 64, 2, 20, 8

    def build_run(avg):
        return RunConfig(model=model, shape=SHAPES["train_4k"], averaging=avg,
                         optimizer="adam", learning_rate=1e-3,
                         param_dtype="float32", remat=False)

    data = MarkovTokenStream(model.vocab_size, seed=0)

    def batches(k):
        rng = np.random.default_rng(0)
        out = []
        for _ in range(k):
            toks = data.sample(rng, n_nodes * bn, seq + 1)
            out.append(make_node_batch(
                {"tokens": jnp.asarray(toks[:, :-1]),
                 "labels": jnp.asarray(toks[:, 1:])}, n_nodes))
        return out

    def train(avg, mix=None):
        """Same stream/init for every variant; returns (losses, cerrs,
        tokens_per_s, last_metrics)."""
        run_cfg = build_run(avg)
        with mesh_rules(mesh, activation_rules(mesh, run_cfg.shape,
                                               node_axis=True)):
            state = replicate_for_nodes(
                init_state(run_cfg, jax.random.PRNGKey(0)), n_nodes)
            step = jax.jit(build_train_step(run_cfg, mesh,
                                            n_nodes=n_nodes, mix=mix)[0])
            bs = batches(steps + 2)
            losses, cerrs, last = [], [], {}
            # two warm-up steps: uncommitted- and committed-state signatures
            for b in bs[:2]:
                state, m = step(state, b)
                jax.block_until_ready(m["loss"])
            t0 = time.perf_counter()
            for b in bs[2:]:
                state, m = step(state, b)
                losses.append(float(m["loss"]))
                cerrs.append(float(m["consensus_err"]))
                last = m
            dt = time.perf_counter() - t0
        toks = steps * n_nodes * bn * seq
        return losses, cerrs, toks / dt, last

    gossip = AveragingConfig("gossip", rounds=2)
    mix_shard = make_gossip_mix(gossip, n_nodes, mesh=mesh)
    assert mix_shard.impl == "shard", mix_shard.impl
    mix_roll = make_gossip_mix(gossip, n_nodes, impl="roll")

    l_s, c_s, tps_s, _ = train(gossip, mix=mix_shard)
    _, _, tps_r, _ = train(gossip, mix=mix_roll)
    wemit("lm_decentralized/train/gossip_shard", 1e6 / tps_s * (n_nodes * bn * seq),
          f"tokens_per_s={tps_s:.0f};consensus_err={c_s[-1]:.4f};"
          f"loss={l_s[-1]:.4f};n_nodes={n_nodes};devices=4;"
          f"model=granite-8b_reduced_L{model.num_layers}_d{model.d_model}_"
          f"V{model.vocab_size};seq={seq}")
    wemit("lm_decentralized/train/gossip_roll_fallback",
          1e6 / tps_r * (n_nodes * bn * seq),
          f"tokens_per_s={tps_r:.0f};step_speedup_shard_vs_roll="
          f"{tps_s / tps_r:.2f}x")

    prog_unc = max(l_s[0] - l_s[-1], 1e-9)
    for quant in ("sign", "int8"):
        avg = AveragingConfig("gossip", rounds=2, quantization=quant,
                              error_feedback="grads")
        l, c, tps, last = train(avg, mix=mix_shard)
        prog = max(l[0] - l[-1], 1e-9)
        wemit(f"lm_decentralized/train/ef_{quant}",
              1e6 / tps * (n_nodes * bn * seq),
              f"tokens_per_s={tps:.0f};loss={l[-1]:.4f};"
              f"uncompressed_loss={l_s[-1]:.4f};"
              f"ef_excess_x={prog_unc / prog:.3f};"
              f"consensus_err={c[-1]:.4f};"
              f"ef_norm={float(last['ef_norm']):.4f};"
              f"ef_rel={float(last['ef_rel']):.4f}")

    print(json.dumps(rows))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.worker:
        _worker(args.quick)
    else:
        run(quick=args.quick)
