"""Shared helpers for the benchmark harness: timing, CSV emission, and the
machine-readable record log behind `run.py --json`."""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterable, List

ROWS: List[str] = []
RECORDS: List[Dict[str, object]] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    RECORDS.append({"name": name, "us_per_call": us_per_call,
                    "derived": derived})
    print(row, flush=True)


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3,
            agg: str = "median") -> float:
    """Wall time in microseconds (jax fns should be jitted + blocked).
    `agg`: "median" (default), or "min" for speedup-contract rows — on a
    shared 2-vCPU container scheduler noise only ever inflates timings."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    if agg == "min":
        return min(ts) * 1e6
    ts.sort()
    return ts[len(ts) // 2] * 1e6
