"""Streaming-engine benchmark: steps/s and samples/s for the three execution
modes of the training loop, across superstep sizes K in {1, 4, 16}.

* sync-per-round        -- the pre-engine loop: one jitted step per Python
                           iteration with host-side sample synthesis, a
                           blocking H2D copy, and a blocking metric fetch in
                           between (the self-inflicted R_p throttle of ISSUE 2)
* superstep             -- K rounds folded into one jitted lax.scan
                           (train.trainer.build_superstep); dispatch + metric
                           fetch amortized over K
* superstep+prefetch    -- same, plus the async device-prefetch ring
                           (data.pipeline.DevicePrefetcher): host synthesis
                           and H2D staging overlap device compute

The contract row asserts superstep+prefetch at K=16 is >= 2x the sync-per-round
baseline in rounds/s on this container (reduced config). A decentralized
(gossip, emulated N=8 nodes) superstep row exercises the vmap'd node-axis path
through the same engine.

The `pipeline/prefetch_sweep/*` rows sweep prefetch_depth over {0, 1, 2, 4}
at the largest K and record the measured sweet spot as
`pipeline/prefetch_sweep/sweet_spot` (best_depth + the depth-2-vs-0 and
4-vs-2 ratios) so the engine default (`EngineConfig.prefetch_depth = 2`) is
backed by a diffable row instead of prose. On this container the micro-scale
LM synthesizes batches faster than the device consumes them, so depths
beyond 1 measure within run-to-run noise — depth 2 buys jitter absorption,
depth 4 only staging memory; the row is where a real-accelerator run would
show the knee moving.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config, reduced
from repro.configs.base import AveragingConfig, RunConfig, SHAPES
from repro.data.lm import MarkovTokenStream
from repro.data.pipeline import StreamingPipeline
from repro.launch.mesh import make_mesh
from repro.launch.sharding import activation_rules
from repro.models.common import mesh_rules
from repro.train.driver import EngineConfig, StreamingDriver
from repro.train.trainer import (build_train_step, init_state,
                                 replicate_for_nodes)

SEQ = 16
BATCH = 4
REPEATS = 3  # best-of: the 2-vCPU container is noisy; min is the honest rate


def _run_cfg(mode: str = "exact", rounds: int = 1) -> RunConfig:
    # micro-scale LM: per-round device compute ~1 ms on the CPU container, so
    # the benchmark isolates the engine's fixed-cost amortization (dispatch,
    # metric fetch, host synthesis) rather than XLA kernel throughput
    cfg = dataclasses.replace(
        reduced(get_config("granite-8b"), layers=1, d_model=16), vocab_size=32,
        d_ff=32)
    return RunConfig(model=cfg, shape=SHAPES["train_4k"],
                     averaging=AveragingConfig(mode, rounds),
                     optimizer="adam", learning_rate=1e-3,
                     param_dtype="float32", remat=False)


def _sample_fn(vocab: int):
    data = MarkovTokenStream(vocab, seed=0)

    def draw(rng: np.random.Generator, n: int):
        toks = data.sample(rng, n, SEQ + 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    return draw


def _sync_per_round(run_cfg: RunConfig, mesh, rounds: int) -> float:
    """The pre-engine loop, timed per round (after a warm-up compile round)."""
    sample = _sample_fn(run_cfg.model.vocab_size)
    pipe = StreamingPipeline(sample, run_cfg.stream, 1, run_cfg.averaging.rounds,
                             batch=BATCH)
    with mesh_rules(mesh, activation_rules(mesh, run_cfg.shape)):
        state = init_state(run_cfg, jax.random.PRNGKey(0))
        step, _ = build_train_step(run_cfg, mesh)
        step = jax.jit(step)

        def one_round(state):
            batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
            state, metrics = step(state, batch)
            float(metrics["loss"])  # the per-round blocking fetch
            return state

        # two warm-up rounds: the first compiles against the freshly-built
        # (uncommitted) state, the second against the committed device state —
        # both signatures must be cached before the timed region
        state = one_round(one_round(state))
        best = float("inf")
        for _ in range(REPEATS):
            t0 = time.perf_counter()
            for _ in range(rounds):
                state = one_round(state)
            best = min(best, (time.perf_counter() - t0) / rounds)
        return best


def _engine(run_cfg: RunConfig, mesh, k: int, prefetch: int, rounds: int,
            n_nodes: int = 1) -> float:
    """Driver-based loop, timed per round (after a warm-up superstep)."""
    sample = _sample_fn(run_cfg.model.vocab_size)
    decentralized = run_cfg.averaging.mode != "exact"
    with mesh_rules(mesh, activation_rules(mesh, run_cfg.shape,
                                           node_axis=decentralized)):
        state = init_state(run_cfg, jax.random.PRNGKey(0))
        if decentralized:
            state = replicate_for_nodes(state, n_nodes)
        engine = EngineConfig(superstep=k, prefetch_depth=prefetch,
                              replan_every=0)
        with StreamingDriver(run_cfg, mesh, state, sample, engine=engine,
                             batch=BATCH * n_nodes, n_nodes=n_nodes) as driver:
            # two warm-up supersteps (uncommitted- and committed-state jit
            # signatures); the persistent ring stays hot for the timed runs
            driver.run(2)
            n_super = max(1, rounds // k)
            best = float("inf")
            for _ in range(REPEATS):
                t0 = time.perf_counter()
                driver.run(n_super)
                best = min(best, (time.perf_counter() - t0) / (n_super * k))
            return best


def run(quick: bool = False) -> None:
    mesh = make_mesh((1, 1), ("data", "model"))
    run_cfg = _run_cfg()
    # non-quick: 96 rounds = 6 supersteps at K=16, well past the depth-2 ring
    # a warm-up can leave full — the timed window measures steady-state
    # producer/consumer throughput, not pre-staged batches
    rounds = 8 if quick else 96
    ks = (1, 4) if quick else (1, 4, 16)

    t_sync = _sync_per_round(run_cfg, mesh, rounds)
    emit("pipeline/sync_per_round", t_sync * 1e6,
         f"rounds_per_s={1 / t_sync:.1f};samples_per_s={BATCH / t_sync:.0f}")

    speedups = {}
    for k in ks:
        for label, prefetch in (("superstep", 0), ("superstep+prefetch", 2)):
            t = _engine(run_cfg, mesh, k, prefetch, rounds)
            speedups[(label, k)] = t_sync / t
            emit(f"pipeline/{label}/K{k}", t * 1e6,
                 f"rounds_per_s={1 / t:.1f};samples_per_s={BATCH / t:.0f};"
                 f"speedup_vs_sync={t_sync / t:.2f}x")

    # prefetch-depth sweep at the largest K: quantify the depth-2 knee
    # (depth 1 hides steady-state synthesis, depth 2 also absorbs the
    # container's scheduling jitter, depth 4 is pure staging memory)
    sweep = {}
    for depth in (0, 1, 2, 4):
        t = _engine(run_cfg, mesh, ks[-1], depth, rounds)
        sweep[depth] = t
        emit(f"pipeline/prefetch_sweep/depth{depth}", t * 1e6,
             f"rounds_per_s={1 / t:.1f};K={ks[-1]}")
    best = min(sweep, key=sweep.get)
    emit("pipeline/prefetch_sweep/sweet_spot", sweep[best] * 1e6,
         f"best_depth={best};rounds_per_s={1 / sweep[best]:.1f};"
         f"depth2_vs_depth0={sweep[0] / sweep[2]:.2f}x;"
         f"depth4_vs_depth2={sweep[2] / sweep[4]:.2f}x;K={ks[-1]}")

    # decentralized node axis through the same engine (emulated N=8 on 1 device)
    k_dec = ks[-1]
    t = _engine(_run_cfg("gossip", rounds=2), mesh, k_dec, 2, rounds, n_nodes=8)
    emit(f"pipeline/gossip_superstep+prefetch/K{k_dec}", t * 1e6,
         f"rounds_per_s={1 / t:.1f};samples_per_s={8 * BATCH / t:.0f}")

    if not quick:
        assert speedups[("superstep+prefetch", 16)] >= 2.0, (
            "superstep+prefetch at K=16 must be >= 2x sync-per-round",
            speedups)
