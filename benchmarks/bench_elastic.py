"""Elastic-membership benchmark (docs/DESIGN.md §Elastic membership): what
node churn actually costs on the streaming engine.

A deterministic `core.faults.FaultSchedule` kills one node mid-stream and
rejoins it later; the gossip Krasulina driver runs the churn scenario against
a lockstep (no-fault) baseline at a matched sample budget. Rows:

* throughput  -- rounds/s for the churn run vs the lockstep baseline (the
                 drop era runs the cohort superstep on fewer rows)
* consensus   -- final consensus error of churn vs lockstep; CONTRACT
                 (asserted in quick and full mode — the run is deterministic:
                 ungoverned plan, scripted faults, seeded sampler): churn
                 stays within 2x of lockstep at a matched sample budget
* rejoin      -- CONTRACT: the rejoin superstep reuses the full-cohort
                 executable — zero retraces (trace-counted, not inferred)
* swap_us     -- host-side cost of one `swap_membership` plan swap, the only
                 engine work a join/leave adds outside compiled code
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs.base import AveragingConfig, GovernorConfig, StreamConfig
from repro.configs.paper_pca import FIG7, PCARunConfig
from repro.core import krasulina, rates
from repro.core.faults import FaultSchedule
from repro.core.mixing import Membership
from repro.data.pipeline import StreamingPipeline
from repro.data.synthetic import make_pca_host_sampler, make_pca_stream
from repro.train.driver import EngineConfig, StreamingDriver

N = 5
B = 10
K = 2


def _driver(faults, traces):
    run_cfg = PCARunConfig(
        pca=FIG7, averaging=AveragingConfig(mode="gossip", rounds=2),
        stream=StreamConfig())  # ungoverned: deterministic (B, mu) per cohort
    inner = krasulina.krasulina_superstep_builder(
        run_cfg.averaging, N, lambda t: 10.0 / t)

    def builder(Bq, membership=None):
        raw = inner(Bq, membership)
        m = N if membership is None else membership.n_active

        def counted(s, b):
            traces.append((Bq, m))  # once per jit trace, not per call
            return raw(s, b)

        return counted

    w0 = jax.random.normal(jax.random.PRNGKey(0), (FIG7.dim,))
    state = krasulina.init_krasulina_state(w0 / jnp.linalg.norm(w0),
                                           run_cfg.averaging, N)
    return StreamingDriver(
        run_cfg, None, state, make_pca_host_sampler(make_pca_stream(FIG7)),
        superstep_builder=builder, n_nodes=N, batch=B, faults=faults,
        engine=EngineConfig(superstep=K, prefetch_depth=0, replan_every=0,
                            warmup_supersteps=0, warmup_per_bucket=0,
                            governor=GovernorConfig()))


def _timed_run(driver, supersteps):
    t0 = time.perf_counter()
    driver.run(supersteps)
    return time.perf_counter() - t0


def _bench_churn(quick: bool) -> None:
    steps = 10 if quick else 40
    die, back = steps // 4, 3 * steps // 4
    faults = FaultSchedule.parse(f"death:{N - 1}@{die}-{back}", N)

    traces: list = []
    churn = _driver(faults, traces)
    churn.run(2)  # absorb the initial-signature compiles
    n_traces0 = len(traces)
    wall = _timed_run(churn, steps)
    rounds = steps * K
    consumed_churn = churn.pipeline.samples_consumed
    err_churn = churn.history[-1]["metrics"]["consensus_err"]
    # the rejoin contract: returning to the full cohort reuses its compiled
    # executable — only the drop-era (B', m-1) signature was traced mid-run
    mid_traces = traces[n_traces0:]
    retraces = sum(1 for t in mid_traces if t[1] == N)
    emit("elastic/rejoin", 0.0,
         f"retraces={retraces};mid_run_traces={len(mid_traces)};"
         f"signatures={len(churn.compiled_signatures)};"
         f"events={len(churn.membership_events)}")
    assert retraces == 0, ("rejoin retraced the full-cohort superstep",
                           traces)
    assert churn.membership.is_full

    base = _driver(None, [])
    base.run(2)
    # matched sample budget: the drop era deals B rounded up to the smaller
    # cohort, so the churn run consumed slightly more samples per superstep
    base_steps = -(-consumed_churn // (K * B))
    wall_base = _timed_run(base, base_steps)
    err_base = base.history[-1]["metrics"]["consensus_err"]

    # median per-superstep throughput is robust to the one-time drop-era
    # compile (the first visit of the (B', m-1) signature pays one retrace —
    # the same cold-switch cost the governor suite measures)
    def median_rps(d):
        xs = sorted(r["rounds_per_s"] for r in d.history[2:])
        return xs[len(xs) // 2]

    emit("elastic/throughput/churn", wall / rounds * 1e6,
         f"rounds_per_s={median_rps(churn):.1f};supersteps={steps};"
         f"samples={consumed_churn};wall_s={wall:.3f}")
    emit("elastic/throughput/lockstep", wall_base / (base_steps * K) * 1e6,
         f"rounds_per_s={median_rps(base):.1f};"
         f"supersteps={base_steps};samples={base.pipeline.samples_consumed};"
         f"wall_s={wall_base:.3f}")
    ratio = err_churn / max(err_base, 1e-30)
    emit("elastic/consensus", 0.0,
         f"err_churn={err_churn:.3e};err_lockstep={err_base:.3e};"
         f"ratio={ratio:.3f}")
    # graceful degradation contract: churn costs consensus error, but within
    # 2x of lockstep at a matched sample budget (the rejoin sync pulls the
    # returning node back to the cohort mean)
    assert ratio <= 2.0, ("consensus error under churn out of tolerance",
                          err_churn, err_base)


def _bench_swap(quick: bool) -> None:
    pipe = StreamingPipeline(
        lambda rng, n: {"x": rng.normal(size=(n, 2))},
        StreamConfig(streaming_rate=1e3, processing_rate=1e6,
                     comms_rate=1e6),
        n_nodes=N, rounds_R=2, horizon=1e6)
    base = rates.BucketLadder.from_buckets((10, 20), N, horizon_samples=1e6)
    pipe.swap_membership(Membership.full(N), base)
    masks = [Membership.full(N).drop(N - 1), Membership.full(N)]
    ladders = [base.for_cohort(N - 1, horizon_samples=1e6), base]
    i = 0

    def swap():
        nonlocal i
        i += 1
        return pipe.swap_membership(masks[i % 2], ladders[i % 2])

    us = time_fn(swap, warmup=2, iters=5 if quick else 21)
    emit("elastic/swap_us", us, f"n_nodes={N};ladder={len(base)}")


def run(quick: bool = False) -> None:
    _bench_churn(quick)
    _bench_swap(quick)
