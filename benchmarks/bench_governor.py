"""Adaptive-B governor benchmark (docs/DESIGN.md §Adaptive batch buckets):
what a bucket switch actually costs on the streaming engine.

* cold switch  -- the governor moves B to a bucket visited for the first
                  time: the superstep pays one XLA retrace (the lazy
                  per-bucket compile), which is why the driver's warm-up gate
                  excludes it from replan input
* warm switch  -- steady state: the target bucket's executable already
                  exists, so the switch is a plan swap only — the timed
                  superstep must run at cached-dispatch speed with ZERO
                  retraces (trace-counted, not inferred from timing)
* estimator    -- the online least-squares (R_p, R_c) fit against a
                  synthetic eq.-4 ground truth: the committed artifact
                  records the R_c recovery error (contract: within 20%)
* replan_us    -- host-side cost of one governor decision (observed-rate
                  fit + bucket selection + plan), the per-superstep
                  overhead the closed loop adds to the driver

Contract rows (asserted in BOTH quick and full mode — they are
deterministic counts, not timings): steady-state switches must retrace
zero times, and the estimator must land within 20% of ground truth.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import emit, time_fn
from repro.configs import get_config, reduced
from repro.configs.base import (AveragingConfig, GovernorConfig, RunConfig,
                                SHAPES, StreamConfig)
from repro.core import rates
from repro.data.lm import MarkovTokenStream
from repro.launch.mesh import make_mesh
from repro.launch.sharding import activation_rules
from repro.models.common import mesh_rules
from repro.train.driver import EngineConfig, StreamingDriver
from repro.train.trainer import build_superstep, init_state

SEQ = 16


def _run_cfg() -> RunConfig:
    cfg = dataclasses.replace(
        reduced(get_config("granite-8b"), layers=1, d_model=16), vocab_size=32,
        d_ff=32)
    return RunConfig(model=cfg, shape=SHAPES["train_4k"],
                     averaging=AveragingConfig("exact", 1),
                     optimizer="adam", learning_rate=1e-3,
                     param_dtype="float32", remat=False)


def _sample_fn(vocab: int):
    data = MarkovTokenStream(vocab, seed=0)

    def draw(rng: np.random.Generator, n: int):
        toks = data.sample(rng, n, SEQ + 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    return draw


def _switch_to(driver: StreamingDriver, B: int) -> None:
    """Manual plan swap to bucket B (replan_every=0 keeps the loop open so
    the benchmark controls exactly when switches happen)."""
    driver.pipeline.update_plan(dataclasses.replace(driver.pipeline.plan, B=B))


def _timed_superstep(driver: StreamingDriver) -> float:
    t0 = time.perf_counter()
    driver.run(1)
    return time.perf_counter() - t0


def _bench_switches(quick: bool) -> None:
    buckets = (4, 8) if quick else (4, 8, 16)
    cycles = 1 if quick else 3
    run_cfg = _run_cfg()
    mesh = make_mesh((1, 1), ("data", "model"))
    traces = []
    with mesh_rules(mesh, activation_rules(mesh, run_cfg.shape)):
        state = init_state(run_cfg, jax.random.PRNGKey(0))
        base, _ = build_superstep(run_cfg, mesh)

        def builder(B):
            def counted(s, b):
                traces.append(B)  # executes once per jit trace, not per call
                return base(s, b)
            return counted

        gov = GovernorConfig(buckets=buckets, estimate_rates=False)
        with StreamingDriver(
                run_cfg, mesh, state, _sample_fn(run_cfg.model.vocab_size),
                superstep_builder=builder, batch=buckets[0],
                engine=EngineConfig(superstep=2, prefetch_depth=0,
                                    replan_every=0, governor=gov)) as driver:
            driver.run(2)  # initial-signature compiles (fresh + committed)
            cold = {}
            for b in buckets[1:]:
                _switch_to(driver, b)
                cold[b] = _timed_superstep(driver)  # pays the bucket's trace
                emit(f"governor/cold_switch/B{b}", cold[b] * 1e6,
                     "retraces=1")
            traces_before = len(traces)
            warm = {b: float("inf") for b in buckets}
            switches = 0
            for _ in range(cycles):
                for b in buckets:  # revisit every bucket, already compiled
                    if driver.pipeline.plan.B == b:
                        continue
                    _switch_to(driver, b)
                    switches += 1
                    warm[b] = min(warm[b], _timed_superstep(driver))
            retraces = len(traces) - traces_before
            for b, t in sorted(warm.items()):
                if t == float("inf"):
                    continue
                extra = (f";speedup_vs_cold={cold[b] / t:.1f}x"
                         if b in cold else "")
                emit(f"governor/warm_switch/B{b}", t * 1e6,
                     f"retraces=0{extra}")
            emit("governor/steady_state", 0.0,
                 f"retraces={retraces};switches={switches};"
                 f"compiled_buckets={len(driver.compiled_buckets)}")
            # the whole point of the ladder: switching between registered
            # buckets never recompiles (deterministic count — asserted in
            # quick mode too)
            assert retraces == 0, (
                "steady-state bucket switch retraced", retraces, traces)
            if not quick:
                worst = max(cold[b] / warm[b] for b in cold
                            if warm[b] != float("inf"))
                assert worst >= 5.0, (
                    "warm switch should be far cheaper than a cold compile",
                    cold, warm)


def _bench_estimator(quick: bool) -> None:
    N, R = 4, 8
    Rp_true, Rc_true = 1e5, 2e3
    est = rates.RoundTimeEstimator(N, R, window=64)
    rng = np.random.default_rng(0)
    rounds = 4 if quick else 16
    for _ in range(rounds):
        for B in (32, 64, 128, 256):
            truth = B / (N * Rp_true) + R / Rc_true
            est.observe(B, truth * (1.0 + 0.02 * rng.normal()))
    got = est.estimate()
    err = abs(got.Rc - Rc_true) / Rc_true * 100
    emit("governor/estimator", 0.0,
         f"est_Rc={got.Rc:.1f};true_Rc={Rc_true:.1f};err_pct={err:.2f};"
         f"est_Rp={got.Rp:.1f};true_Rp={Rp_true:.1f}")
    assert err <= 20.0, ("online R_c estimate out of tolerance", got)

    # host-side cost of one full governor decision
    stream = StreamConfig(streaming_rate=1e4, processing_rate=1e5,
                          comms_rate=1e3)
    ladder = rates.BucketLadder((32, 64, 128, 256))

    def decide():
        e = est.estimate()
        return rates.replan(stream, N, R, 64, 1e-3, ladder=ladder, estimate=e)

    us = time_fn(decide, warmup=2, iters=5)
    emit("governor/replan_us", us, f"buckets={len(ladder)}")


def run(quick: bool = False) -> None:
    _bench_switches(quick)
    _bench_estimator(quick)
