"""Fault-tolerance benchmark (docs/DESIGN.md §Fault-tolerant streaming): what
async checkpointing actually costs the training loop, and what a durable
snapshot costs end to end.

* overhead  -- CONTRACT (asserted in quick and full mode): with per-superstep
               snapshots the training-thread cost — the jitted state copy
               dispatch plus host-side meta capture; the writer thread owns
               all disk I/O — stays under 5% of loop wall (governor budget
               set to 4% for margin)
* save_us / restore_us -- synchronous durable-save and verified-restore
               latency for the run state (leaf writes + CRC manifest; CRC
               check + device_put on restore), with MB/s derived
* resume    -- CONTRACT: a driver resumed from the snapshot taken at the cut
               finishes bit-identical to the uninterrupted run (deterministic
               clock, scripted faults — the kill-and-resume regression of
               tests/test_snapshot.py at benchmark scale)
"""
from __future__ import annotations

import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.configs.base import AveragingConfig, GovernorConfig
from repro.configs.paper_pca import FIG7, PCARunConfig
from repro.core import krasulina
from repro.core.faults import FaultSchedule
from repro.data.synthetic import make_pca_host_sampler, make_pca_stream
from repro.train import checkpoint
from repro.train.driver import EngineConfig, StreamingDriver
from repro.train.snapshot import RunSnapshotter

N = 5
B = 10
K = 2


class _FakeClock:
    def __init__(self, dt):
        self.t, self.dt = 0.0, dt

    def __call__(self):
        self.t += self.dt
        return self.t


def _driver(faults=None, *, clock=None, **kw):
    run_cfg = PCARunConfig(
        pca=FIG7, averaging=AveragingConfig(mode="gossip", rounds=2))
    builder = krasulina.krasulina_superstep_builder(
        run_cfg.averaging, N, lambda t: 10.0 / t)
    w0 = jax.random.normal(jax.random.PRNGKey(0), (FIG7.dim,))
    state = krasulina.init_krasulina_state(w0 / jnp.linalg.norm(w0),
                                           run_cfg.averaging, N)
    return StreamingDriver(
        run_cfg, None, state, make_pca_host_sampler(make_pca_stream(FIG7)),
        superstep_builder=builder, n_nodes=N, batch=B, faults=faults,
        engine=EngineConfig(superstep=K, prefetch_depth=0, replan_every=0,
                            warmup_supersteps=0, warmup_per_bucket=0,
                            governor=GovernorConfig()),
        clock=clock or time.perf_counter, **kw)


def _bench_overhead(quick: bool) -> None:
    steps = 40 if quick else 160
    root = tempfile.mkdtemp(prefix="bench_ckpt_")
    try:
        sn = RunSnapshotter(root, every=1, keep_last=2, overhead_budget=0.04)
        with _driver(snapshotter=sn) as d:
            # absorb the engine compiles AND the snapshotter's one-time jitted
            # copy-fn compile (it primes the cost EWMA the governor works from)
            d.run(2)
            cost0, n0 = sn.stats.total_cost_s, sn.stats.dispatches
            t0 = time.perf_counter()
            d.run(steps)
            wall = time.perf_counter() - t0
            sn.flush()
        st = sn.stats
        cost = st.total_cost_s - cost0  # training-thread cost, timed window
        dispatches = st.dispatches - n0
        frac = cost / max(wall, 1e-9)
        emit("checkpoint/overhead", cost / max(dispatches, 1) * 1e6,
             f"overhead_frac={frac:.4f};saves={st.saves};"
             f"dispatches={dispatches};skipped_budget={st.skipped_budget};"
             f"skipped_busy={st.skipped_busy};failures={st.failures};"
             f"total_cost_s={cost:.4f};loop_wall_s={wall:.3f}")
        # async-checkpoint contract: the writer thread owns the disk; the
        # training thread pays copy dispatch + meta capture only, < 5% of wall
        assert frac <= 0.05, ("snapshot overhead above budget", frac)
        assert st.failures == 0, st.last_error
    finally:
        shutil.rmtree(root, ignore_errors=True)


def _bench_capture(quick: bool) -> None:
    """Ungoverned micro-row: the per-snapshot cost the TRAINING thread pays
    when a snapshot is dispatched — the jitted state-copy dispatch plus the
    host-side meta capture. Disk never appears here; that is the writer's."""
    from repro.train import snapshot as snap

    root = tempfile.mkdtemp(prefix="bench_ckpt_")
    try:
        sn = RunSnapshotter(root, every=1, overhead_budget=0)
        with _driver() as d:
            d.run(2)
            copy = sn._copy_fn()
            copy(d.state)  # absorb the copy-fn compile
            us = time_fn(lambda: (copy(d.state), snap.capture_meta(d)),
                         warmup=3, iters=20 if quick else 50)
            emit("checkpoint/capture_us", us,
                 f"leaves={len(checkpoint._flatten(d.state))};"
                 f"supersteps_done={d._supersteps_done}")
        sn.close()
    finally:
        shutil.rmtree(root, ignore_errors=True)


def _bench_save_restore(quick: bool) -> None:
    with _driver() as d:
        d.run(2)
        state = d.state
    leaves = checkpoint._flatten(state)
    nbytes = sum(np.asarray(v).nbytes for v in leaves.values())
    root = tempfile.mkdtemp(prefix="bench_ckpt_")
    try:
        step = [0]

        def save():
            step[0] += 1
            checkpoint.save(checkpoint.step_dir(root, step[0]), state,
                            step=step[0])

        iters = 3 if quick else 11
        us = time_fn(save, warmup=1, iters=iters)
        emit("checkpoint/save_us", us,
             f"bytes={nbytes};mb_s={nbytes / us:.1f};leaves={len(leaves)}")

        path = checkpoint.step_dir(root, step[0])

        def restore():
            return checkpoint.restore(path, state)

        us = time_fn(restore, warmup=1, iters=iters)
        emit("checkpoint/restore_us", us,
             f"bytes={nbytes};mb_s={nbytes / us:.1f};verify=crc32")
    finally:
        shutil.rmtree(root, ignore_errors=True)


def _bench_resume(quick: bool) -> None:
    total, cut = (8, 3) if quick else (16, 7)
    faults = FaultSchedule.parse(f"death:{N - 1}@2-5", N)
    root = tempfile.mkdtemp(prefix="bench_ckpt_")
    try:
        with _driver(faults, clock=_FakeClock(1e-3)) as ref:
            ref.run(total)
            ref_leaves = checkpoint._flatten(ref.state)

        t0 = time.perf_counter()
        with _driver(faults, clock=_FakeClock(1e-3),
                     snapshotter=RunSnapshotter(
                         root, every=1, overhead_budget=0,
                         block=True)) as victim:
            victim.run(cut)

        clk = _FakeClock(1e-3)
        for _ in range(2 * cut):  # the driver reads the clock 2x/superstep
            clk()
        with _driver(faults, clock=clk, resume_from=root) as resumed:
            resumed.run(total - cut)
            res_leaves = checkpoint._flatten(resumed.state)
        wall = time.perf_counter() - t0

        identical = int(all(
            np.array_equal(np.asarray(ref_leaves[k]), np.asarray(res_leaves[k]))
            for k in ref_leaves))
        emit("checkpoint/resume", wall / max(total, 1) * 1e6,
             f"bit_identical={identical};supersteps={total};cut={cut};"
             f"checkpoints={len(checkpoint.list_steps(root))}")
        # kill-and-resume contract: resumed == uninterrupted, bitwise
        assert identical == 1, "resumed run diverged from uninterrupted run"
    finally:
        shutil.rmtree(root, ignore_errors=True)


def run(quick: bool = False) -> None:
    _bench_overhead(quick)
    _bench_capture(quick)
    _bench_save_restore(quick)
    _bench_resume(quick)
