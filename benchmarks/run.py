"""Benchmark harness entrypoint: one module per paper table/figure plus the
kernel micro-benchmarks and the roofline report.

Prints ``name,us_per_call,derived`` CSV (benchmarks.common.emit).
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated subset: "
                         "rates,dmb,krasulina,dsgd,consensus,kernels,roofline")
    args = ap.parse_args()

    from benchmarks import (bench_consensus, bench_dmb, bench_dsgd,
                            bench_kernels, bench_krasulina, bench_rates,
                            bench_roofline)

    suites = {
        "rates": bench_rates.run,       # Fig. 5
        "dmb": bench_dmb.run,           # Fig. 6
        "krasulina": bench_krasulina.run,  # Figs. 7-8
        "dsgd": bench_dsgd.run,         # Fig. 9
        "consensus": bench_consensus.run,  # fused engine vs per-round loop
        "kernels": bench_kernels.run,
        "roofline": bench_roofline.run,  # deliverable (g)
    }
    chosen = [s.strip() for s in args.only.split(",") if s.strip()] or list(suites)
    print("name,us_per_call,derived")
    failed = []
    for name in chosen:
        try:
            suites[name]()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
