"""Benchmark harness entrypoint: one module per paper table/figure plus the
kernel micro-benchmarks, the streaming-engine pipeline suite, and the roofline
report.

Prints ``name,us_per_call,derived`` CSV (benchmarks.common.emit). `--json OUT`
additionally writes the rows as a machine-readable artifact
(BENCH_pipeline.json-style) so the perf trajectory is diffable across PRs.
`--quick` runs every suite at smoke scale (tiny shapes, paper-regime asserts
off) — the tier-1 test suite executes it to catch benchmark bit-rot.
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated subset: "
                         "rates,dmb,krasulina,dsgd,consensus,kernels,pipeline,"
                         "governor,elastic,scenarios,serve,checkpoint,"
                         "lm_decentralized,roofline")
    ap.add_argument("--quick", action="store_true",
                    help="smoke mode: tiny shapes, no paper-regime asserts")
    ap.add_argument("--json", default="", metavar="OUT",
                    help="write rows as a JSON artifact to this path")
    args = ap.parse_args()

    from benchmarks import (bench_checkpoint, bench_consensus, bench_dmb,
                            bench_dsgd, bench_elastic, bench_governor,
                            bench_kernels, bench_krasulina,
                            bench_lm_decentralized, bench_pipeline,
                            bench_rates, bench_roofline, bench_scenarios,
                            bench_serve, common)

    suites = {
        "rates": bench_rates.run,       # Fig. 5
        "dmb": bench_dmb.run,           # Fig. 6
        "krasulina": bench_krasulina.run,  # Figs. 7-8
        "dsgd": bench_dsgd.run,         # Fig. 9
        "consensus": bench_consensus.run,  # fused engine vs per-round loop
        "kernels": bench_kernels.run,
        "pipeline": bench_pipeline.run,  # streaming engine (superstep/prefetch)
        "governor": bench_governor.run,  # adaptive-B bucket ladder
        "elastic": bench_elastic.run,   # node churn vs lockstep baseline
        "scenarios": bench_scenarios.run,  # topology x link x stream matrix
        "serve": bench_serve.run,       # train-to-serve closed loop
        "checkpoint": bench_checkpoint.run,  # async snapshot / kill-resume
        "lm_decentralized": bench_lm_decentralized.run,  # sharded gossip + EF
        "roofline": bench_roofline.run,  # deliverable (g)
    }
    chosen = [s.strip() for s in args.only.split(",") if s.strip()] or list(suites)
    print("name,us_per_call,derived")
    failed = []
    for name in chosen:
        try:
            suites[name](quick=args.quick)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if args.json:
        import jax

        artifact = {
            "schema": "repro-bench-v1",
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "quick": args.quick,
            "suites": chosen,
            "failed": failed,
            "rows": common.RECORDS,
        }
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=1)
        print(f"json artifact -> {args.json}", file=sys.stderr)
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
