"""Roofline report over the dry-run artifacts (deliverable g): one row per
(arch x shape x mesh) with the three terms, the dominant bottleneck, and
MODEL_FLOPS/HLO_FLOPs. Skips gracefully when artifacts are missing (run
`python -m repro.launch.sweep` first).
"""
from __future__ import annotations

from benchmarks.common import emit
from repro import roofline


def run(quick: bool = False) -> None:
    # reads dry-run artifacts (or skips gracefully) — same cost either way
    recs = roofline.load_artifacts()
    if not recs:
        emit("roofline/missing", 0.0, "run `python -m repro.launch.sweep` first")
        return
    rows = [roofline.analyze(r) for r in recs]
    rows.sort(key=lambda r: (r.mesh, r.arch, r.shape))
    for r in rows:
        emit(f"roofline/{r.mesh}/{r.arch}/{r.shape}",
             r.step_time_s * 1e6,
             f"dom={r.dominant};compute_s={r.compute_s:.4f};"
             f"memory_s={r.memory_s:.4f};collective_s={r.collective_s:.4f};"
             f"useful={r.useful_ratio:.2f};mfu={r.mfu:.3f};peak_gib={r.peak_gib:.1f}")
