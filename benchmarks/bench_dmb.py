"""Figure 6: DMB on streaming logistic regression (d=5, w* ~ N(0,I)).

(a) resourceful regime, B in {1, 10, 100, 1000, 10000}: error ~O(1/t') for
    B <= sqrt(t'), degrading for B = 1e4 > sqrt(t').
(b) under-provisioned regime (N, B) = (10, 500), mu in {0, 100, 500, 1000,
    2000, 5000}: small mu is tolerated, large mu degrades.

Scaled to t' = 2e5 samples (the paper uses 1e6; same regimes, CPU-friendly).
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs.paper_logreg import FIG6
from repro.core import dmb, problems
from repro.data.synthetic import make_logreg_stream

T_PRIME = 200_000
# stepsize constants per B, picked by trial like the paper ("we ran the
# experiment for multiple choices of c and picked the best")
C_FOR_B = {1: 0.1, 10: 0.3, 100: 2.0, 1000: 8.0, 10000: 8.0}


def run(quick: bool = False) -> None:
    t_prime = 2_000 if quick else T_PRIME
    stream = make_logreg_stream(FIG6)
    grad = lambda w, x, y: problems.logistic_grad(w, x, y)
    metric = lambda w: jnp.sum((w - stream.w_star) ** 2)
    w0 = jnp.zeros(FIG6.dim + 1)

    errs = {}
    for B in ((1, 10, 100) if quick else (1, 10, 100, 1000, 10_000)):
        steps = max(1, t_prime // B)
        c = C_FOR_B[B]
        res = dmb.run_dmb(grad, stream.draw, w0, N=min(10, B), B=B, steps=steps,
                          stepsize=lambda t: c / jnp.sqrt(t), trace_metric=metric)
        err = float(res.trace_metric[-1])
        errs[B] = err
        us = time_fn(lambda: res.w, iters=1)  # trivially 0; rounds timed below
        emit(f"fig6a/B{B}", us, f"err={err:.5f};steps={steps}")

    if not quick:  # paper-regime asserts need the full t' horizon
        # Theorem 4 regimes: B <= sqrt(t') ~ 450 stays near-optimal; B=1e4 degrades
        assert errs[100] < 10 * errs[1] + 1e-3
        assert errs[10_000] > errs[100], "B >> sqrt(t') should degrade (Fig 6a)"

    # under-provisioned regime: FIXED arrival budget t' — mu discarded samples
    # per round mean fewer algorithmic iterations for the same stream (Fig. 6b)
    errs_mu = {}
    for mu in ((0, 500) if quick else (0, 100, 500, 1000, 2000, 5000)):
        steps = max(1, t_prime // (500 + mu))
        res = dmb.run_dmb(grad, stream.draw, w0, N=10, B=500, mu=mu, steps=steps,
                          stepsize=lambda t: 2.0 / jnp.sqrt(t), trace_metric=metric,
                          seed=1)
        errs_mu[mu] = float(res.trace_metric[-1])
        emit(f"fig6b/mu{mu}", 0.0,
             f"err={errs_mu[mu]:.5f};steps={steps};t_prime={int(res.trace_t_prime[-1])}")
    if not quick:
        # mu = B/5 is tolerated; mu = 10B costs an order of magnitude
        assert errs_mu[100] < 3 * errs_mu[0] + 1e-4
        assert errs_mu[5000] > errs_mu[0]
