"""Figure 9: D-SGD / AD-SGD vs centralized, local and DGD baselines on
6-regular random expander graphs; binary logistic regression on conditional
Gaussians (d=20, sigma_x^2=2), rho = 1/2, regimes t' = N^2 and t' = N^{3/2}.

Per the paper: B/N = ceil(0.1 * log(t') / (rho * log(1/lambda_2))).
Excess risk is estimated on a held-out batch against the Bayes separator.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.paper_logreg import FIG9
from repro.core import dmb, dsgd, mixing, problems
from repro.data.synthetic import make_logreg_stream

N = 16
RHO = 0.5


def run(quick: bool = False) -> None:
    stream = make_logreg_stream(FIG9)
    grad = lambda w, x, y: problems.logistic_grad(w, x, y)
    xe, ye = stream.draw(jax.random.PRNGKey(99), 2_000 if quick else 50_000)
    bayes = problems.logistic_loss(stream.w_star, xe, ye)
    metric = lambda w: problems.logistic_loss(w, xe, ye) - bayes
    w0 = jnp.zeros(FIG9.dim + 1)

    A = jnp.asarray(mixing.random_regular_expander(N, deg=6, seed=0))
    lam2 = mixing.lambda2(np.asarray(A))

    regimes = ((("N2", N**2 * 4),) if quick else
               (("N2", N**2 * 64), ("N32", int(N**1.5) * 64)))
    for regime, t_prime in regimes:
        Bn = max(1, math.ceil(0.1 * math.log(t_prime) / (RHO * math.log(1 / lam2))))
        B = Bn * N
        steps = max(1, t_prime // B)
        R = max(1, int(B * RHO / N))  # rounds affordable at rho

        res_d = dsgd.run_dsgd(grad, stream.draw, w0, A, B=B, rounds=R, steps=steps,
                              stepsize=lambda t: 2.5 / jnp.sqrt(t),
                              trace_metric=metric, seed=3)
        res_a = dsgd.run_dsgd(grad, stream.draw, w0, A, B=B, rounds=R, steps=steps,
                              stepsize=lambda t: 0.05 * (t + 1.0) / 2.0,
                              trace_metric=metric, accelerated=True, seed=3,
                              project=lambda w: problems.project_ball(w, 10.0))
        res_c = dmb.run_dmb(grad, stream.draw, w0, N=1, B=B, steps=steps,
                            stepsize=lambda t: 2.5 / jnp.sqrt(t),
                            trace_metric=metric, seed=3)
        res_l = dsgd.run_local_sgd(grad, stream.draw, w0, N=N, B=B, steps=steps,
                                   stepsize=lambda t: 2.5 / jnp.sqrt(t),
                                   trace_metric=metric, seed=3)
        res_g_naive = dsgd.run_dgd(grad, stream.draw, w0, A, B=B, steps=steps,
                                   stepsize=lambda t: 1.0 / jnp.sqrt(t),
                                   trace_metric=metric, mode="naive", seed=3)
        res_g_mb = dsgd.run_dgd(grad, stream.draw, w0, A, B=B, steps=steps,
                                stepsize=lambda t: 1.0 / jnp.sqrt(t),
                                trace_metric=metric, mode="minibatched", seed=3)
        vals = {}
        for name, res in (("dsgd", res_d), ("adsgd", res_a), ("central", res_c),
                          ("local", res_l), ("dgd_naive", res_g_naive),
                          ("dgd_mb", res_g_mb)):
            vals[name] = float(res.trace_metric[-1])
            emit(f"fig9/{regime}/{name}", 0.0,
                 f"excess_risk={vals[name]:.5f};B={B};R={R};steps={steps}")
        if not quick:
            # the paper's ordering: collaboration beats local
            assert vals["dsgd"] < vals["local"], (regime, vals)
