"""Figure 5: the impact of mini-batch size B on R_s / R_e under the exact
averaging paradigm (N = 10, R_s = 1e6, R_p = 1.25e5, R_c in {1e3, 1e4}).

Emits, per (R_c, B): the ratio R_s/R_e and whether the system keeps up
(R_s/R_e <= B). The paper's qualitative claim — the ratio drops below the
B-line for sufficiently large B — is checked programmatically.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import rates


def run(quick: bool = False) -> None:
    # pure rate-model arithmetic — already smoke-scale; `quick` is accepted
    # for harness uniformity and changes nothing
    N, Rs, Rp, R = 10, 1e6, 1.25e5, 10
    for Rc in (1e3, 1e4):
        crossed = None
        for B in (100, 200, 500, 1000, 2000, 5000, 10_000, 20_000, 50_000):
            Re = rates.effective_rate(B, N, R, Rp, Rc)
            ratio = Rs / Re
            keeps_up = ratio <= B
            if keeps_up and crossed is None:
                crossed = B
            emit(f"fig5/Rc{int(Rc)}/B{B}", 0.0,
                 f"ratio={ratio:.0f};keeps_up={int(keeps_up)}")
        emit(f"fig5/Rc{int(Rc)}/crossover", 0.0, f"B_star={crossed}")
        assert crossed is not None, "mini-batching must eventually keep up (Fig 5)"
