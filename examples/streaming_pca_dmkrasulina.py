"""Paper Section IV-D (Fig. 7): DM-Krasulina estimating the top eigenvector of
a streaming covariance (d=10, eigengap 0.1), including the Pallas kernel path
for the fused mini-batch pseudo-gradient.

Run:  PYTHONPATH=src python examples/streaming_pca_dmkrasulina.py
"""
import jax
import jax.numpy as jnp

from repro.configs.paper_pca import FIG7
from repro.core import krasulina, problems
from repro.data.synthetic import make_pca_stream
from repro.kernels import ops

stream = make_pca_stream(FIG7)
metric = lambda w: problems.pca_excess_risk(w, stream.cov, stream.lambda1)
w0 = jax.random.normal(jax.random.PRNGKey(0), (FIG7.dim,))
w0 = w0 / jnp.linalg.norm(w0)

print("Fig 7(a): excess risk vs B at t' = 1e5 samples")
for B in (1, 10, 100, 1000):
    res = krasulina.run_dm_krasulina(
        stream.draw, w0, N=min(10, B), B=B, steps=max(1, 100_000 // B),
        stepsize=lambda t: 10.0 / t, trace_metric=metric)
    print(f"  B={B:5d}  excess risk = {float(res.trace_metric[-1]):.6f}")

print("Fig 7(b): mu discards at (N,B)=(10,100)")
for mu in (0, 10, 100, 1000):
    res = krasulina.run_dm_krasulina(
        stream.draw, w0, N=10, B=100, mu=mu, steps=1000,
        stepsize=lambda t: 10.0 / t, trace_metric=metric, seed=1)
    print(f"  mu={mu:5d}  excess risk = {float(res.trace_metric[-1]):.6f}")

# the TPU kernel computes the same xi (validated in interpret mode on CPU):
z = stream.draw(jax.random.PRNGKey(2), 256)
xi_kernel = ops.krasulina_xi(w0, z, force_pallas=True)
xi_ref = problems.krasulina_xi(w0, z)
print(f"Pallas kernel max |xi - ref| = {float(jnp.max(jnp.abs(xi_kernel - xi_ref))):.2e}")
